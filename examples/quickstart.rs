//! Quickstart: evaluate PIMfused against the GDDR6-AiM-like baseline on
//! end-to-end ResNet18 and print the paper's headline comparison.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```

use pimfused::config::{ArchConfig, System};
use pimfused::coordinator::run_ppa;
use pimfused::workload::Workload;

fn main() -> anyhow::Result<()> {
    // The paper's baseline: AiM-like, GBUF = 2 KB, no LBUFs (§V-A3).
    let baseline = ArchConfig::baseline();
    // The headline PIMfused configuration: 4-bank PIMcores, G32K_L256.
    let fused4 = ArchConfig::system(System::Fused4, 32 * 1024, 256);

    println!("workload: end-to-end ResNet18 (224x224)\n");
    let base = run_ppa(&baseline, Workload::ResNet18Full)?;
    println!(
        "{:<22} cycles={:>12}  energy={:>8.3} mJ  area={:>6.3} mm2",
        base.label,
        base.cycles,
        base.energy_pj / 1e9,
        base.area_mm2
    );

    for sys in [System::Fused16, System::Fused4] {
        let cfg = ArchConfig::system(sys, 32 * 1024, 256);
        let r = run_ppa(&cfg, Workload::ResNet18Full)?;
        let n = r.normalize(&base);
        println!(
            "{:<22} cycles={:>12}  energy={:>8.3} mJ  area={:>6.3} mm2   vs baseline: {}",
            r.label,
            r.cycles,
            r.energy_pj / 1e9,
            r.area_mm2,
            n.render()
        );
    }

    let ours = run_ppa(&fused4, Workload::ResNet18Full)?.normalize(&base);
    println!(
        "\npaper headline (Fused4 @ G32K_L256): cycles=30.6% energy=83.4% area=76.5%\n\
         this reproduction                  : {}",
        ours.render()
    );
    Ok(())
}
