use pimfused::coordinator::experiments::*;
use pimfused::dataflow::CostModel;
fn main() {
    let m = CostModel::default();
    if std::env::args().any(|a| a == "--energy") {
        use pimfused::config::{ArchConfig, System};
        use pimfused::coordinator::run_ppa_with;
        use pimfused::workload::Workload;
        for (name, cfg) in [
            ("baseline", ArchConfig::baseline()),
            ("fused4_hl", ArchConfig::system(System::Fused4, 32 * 1024, 256)),
            ("fused16_hl", ArchConfig::system(System::Fused16, 32 * 1024, 256)),
        ] {
            let r = run_ppa_with(&cfg, Workload::ResNet18Full, m).unwrap();
            println!("== {name} {} total={:.3} mJ cycles={}", r.label, r.energy_pj / 1e9, r.cycles);
            for c in &r.energy.components {
                println!("   {:<20} {:>10.4} mJ", c.name, c.energy_pj / 1e9);
            }
        }
        return;
    }
    println!("HEADLINE (paper: cycles=30.6% energy=83.4% area=76.5%)");
    println!("  measured: {}", headline(m).unwrap().render());
    let s = vd_stats(m).unwrap();
    println!("V-D (paper: repl +18.2%, redundant +17.3%, perf 91.2%)");
    println!("  measured: repl +{:.1}%, redundant +{:.1}%, perf {:.1}%",
        (s.fusion.replication-1.0)*100.0, (s.fusion.redundant_macs-1.0)*100.0, s.perf_improvement*100.0);
    println!("\nFIG5 (GBUF sweep, L0):\n{}", render(&fig5(m).unwrap()));
    println!("FIG6 (LBUF sweep, G2K):\n{}", render(&fig6(m).unwrap()));
    println!("FIG7 (joint):\n{}", render(&fig7(m).unwrap()));
}
// (appended) energy breakdown helper invoked via `--energy`
