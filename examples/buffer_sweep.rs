//! Buffer design-space sweep: evaluate all three systems across a
//! GBUF × LBUF grid in parallel and print the Pareto frontier
//! (performance vs area), reproducing the §V-D trade-off discussion.
//!
//! ```text
//! cargo run --release --example buffer_sweep
//! ```

use pimfused::config::{ArchConfig, System};
use pimfused::coordinator::{run_ppa, sweep, SweepPoint};
use pimfused::dataflow::CostModel;
use pimfused::ppa::Normalized;
use pimfused::util::table::{pct_or_x, Table};
use pimfused::workload::Workload;

fn main() -> anyhow::Result<()> {
    let gbufs = [2 * 1024, 8 * 1024, 32 * 1024];
    let lbufs = [0usize, 128, 256];
    let mut points = Vec::new();
    for sys in System::ALL {
        for &g in &gbufs {
            for &l in &lbufs {
                points.push(SweepPoint {
                    cfg: ArchConfig::system(sys, g, l),
                    workload: Workload::ResNet18Full,
                });
            }
        }
    }

    let base = run_ppa(&ArchConfig::baseline(), Workload::ResNet18Full)?;
    let t0 = std::time::Instant::now();
    let results = sweep(&points, CostModel::default());
    let dt = t0.elapsed();

    let mut rows: Vec<(String, Normalized)> = Vec::new();
    for r in results {
        let r = r?;
        rows.push((r.label.clone(), r.normalize(&base)));
    }

    let mut table = Table::new(vec!["config", "cycles", "energy", "area"]);
    for (label, n) in &rows {
        table.row(vec![
            label.clone(),
            pct_or_x(n.cycles),
            pct_or_x(n.energy),
            pct_or_x(n.area),
        ]);
    }
    println!("{}", table.render());

    // Pareto frontier on (cycles, area).
    let mut frontier: Vec<&(String, Normalized)> = Vec::new();
    for cand in &rows {
        let dominated = rows.iter().any(|o| {
            (o.1.cycles < cand.1.cycles && o.1.area <= cand.1.area)
                || (o.1.cycles <= cand.1.cycles && o.1.area < cand.1.area)
        });
        if !dominated {
            frontier.push(cand);
        }
    }
    frontier.sort_by(|a, b| a.1.cycles.partial_cmp(&b.1.cycles).unwrap());
    println!("Pareto frontier (cycles vs area):");
    for (label, n) in frontier {
        println!("  {:<24} {}", label, n.render());
    }
    println!(
        "\nswept {} configurations in {:.2?} ({:.1} points/s)",
        rows.len(),
        dt,
        rows.len() as f64 / dt.as_secs_f64()
    );
    Ok(())
}
