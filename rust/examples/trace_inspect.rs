//! Fig. 3 walkthrough: generate the Table-I command trace for the paper's
//! 8-layer example graph under both dataflows and contrast the
//! cross-bank traffic (the quantity PIMfused optimizes).
//!
//! ```text
//! cargo run --release --example trace_inspect
//! ```

use pimfused::config::{ArchConfig, System};
use pimfused::dataflow::{plan, CostModel};
use pimfused::sim::simulate;
use pimfused::trace::gen::generate;
use pimfused::workload::Workload;

fn main() {
    let g = Workload::Fig3.graph();
    let model = CostModel::default();

    for (title, cfg) in [
        ("layer-by-layer (Fig. 3(b)) — AiM-like/G2K_L0", ArchConfig::baseline()),
        (
            "PIMfused dataflow (Fig. 3(c)) — Fused4/G8K_L128",
            ArchConfig::system(System::Fused4, 8 * 1024, 128),
        ),
    ] {
        let p = plan(&g, &cfg);
        let t = generate(&g, &cfg, &p, model);
        let s = t.stats();
        let r = simulate(&cfg, &t);
        println!("=== {title} ===");
        println!("{}", t.dump(48));
        println!(
            "fused kernels: {}   commands: {}\n\
             cross-bank bytes : {:>10} (read {} + write {})\n\
             broadcast bytes  : {:>10}\n\
             near-bank bytes  : {:>10} (+{} open-row re-reads)\n\
             memory cycles    : {:>10}\n",
            p.num_fused_kernels(),
            s.num_cmds,
            s.cross_bank_total(),
            s.cross_bank_read,
            s.cross_bank_write,
            s.broadcast,
            s.near_bank_read + s.near_bank_write,
            s.near_bank_hit,
            r.cycles,
        );
    }
}
