//! Buffer design-space sweep: evaluate all three systems across a
//! GBUF × LBUF grid in parallel and print the Pareto frontier
//! (performance vs area), reproducing the §V-D trade-off discussion.
//!
//! Uses the Experiment API v2 [`SweepGrid`] builder with a per-point
//! progress callback and the built-in normalized table.
//!
//! ```text
//! cargo run --release --example buffer_sweep
//! ```

use pimfused::config::System;
use pimfused::coordinator::{Session, SweepGrid};
use pimfused::ppa::Normalized;
use pimfused::workload::Workload;
use std::io::Write;

fn main() -> anyhow::Result<()> {
    let session = Session::new();
    let grid = SweepGrid::new()
        .systems(System::ALL)
        .gbuf_bytes([2 * 1024, 8 * 1024, 32 * 1024])
        .lbuf_bytes([0, 128, 256])
        .workload(Workload::ResNet18Full);

    let t0 = std::time::Instant::now();
    let results = grid.run_with_progress(&session, |p| {
        eprint!("\r  sweeping {:>2}/{} ({})        ", p.completed, p.total, p.point.cfg.label());
        let _ = std::io::stderr().flush();
    })?;
    eprintln!();
    let dt = t0.elapsed();
    results.ensure_ok()?;

    println!("{}", results.table());

    // Pareto frontier on (cycles, area).
    let rows: Vec<(String, Normalized)> = results
        .iter()
        .map(|row| (row.point.cfg.label(), row.norm.expect("ensure_ok")))
        .collect();
    let mut frontier: Vec<&(String, Normalized)> = Vec::new();
    for cand in &rows {
        let dominated = rows.iter().any(|o| {
            (o.1.cycles < cand.1.cycles && o.1.area <= cand.1.area)
                || (o.1.cycles <= cand.1.cycles && o.1.area < cand.1.area)
        });
        if !dominated {
            frontier.push(cand);
        }
    }
    frontier.sort_by(|a, b| a.1.cycles.partial_cmp(&b.1.cycles).unwrap());
    println!("Pareto frontier (cycles vs area):");
    for (label, n) in frontier {
        println!("  {:<24} {}", label, n.render());
    }
    println!(
        "\nswept {} configurations in {:.2?} ({:.1} points/s)",
        results.len(),
        dt,
        results.len() as f64 / dt.as_secs_f64()
    );
    Ok(())
}
