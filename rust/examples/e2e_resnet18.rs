//! End-to-end driver: proves all three layers compose on a real workload.
//!
//! 1. **L2 golden model through PJRT** — loads the AOT-compiled ResNet18
//!    (`artifacts/resnet18_32.hlo.txt`, lowered once by `make artifacts`),
//!    feeds it the same synthetic input/weights the Rust validator uses,
//!    and checks the JAX numerics against the Rust reference executor.
//! 2. **L1 fused-tile kernel contract** — uses the L3 tiling engine's halo
//!    demands to slice a haloed tile, runs the Pallas fused two-conv
//!    kernel artifact on it via PJRT, and checks it equals the Rust
//!    reference's corresponding output slice.
//! 3. **L3 dataflow validation** — executes the PIMfused plan tile-by-tile
//!    in Rust (bit-exact against the layer-by-layer reference).
//! 4. **PPA reproduction** — simulates the full 224px workload on all
//!    three systems and prints the paper-vs-measured headline.
//!
//! ```text
//! make artifacts && cargo run --release --example e2e_resnet18
//! ```

use anyhow::{anyhow, Context, Result};
use pimfused::cnn::resnet::resnet18_at;
use pimfused::cnn::Op;
use pimfused::config::{ArchConfig, System};
use pimfused::coordinator::Session;
use pimfused::dataflow::plan;
use pimfused::runtime::{artifacts_dir, Runtime};
use pimfused::util::rng::XorShift64;
use pimfused::validate::{run_reference, synth_input, synth_weights, validate_plan};
use pimfused::workload::Workload;

const SEED: u64 = 0xE2E;

fn main() -> Result<()> {
    if Runtime::available() {
        let rt = Runtime::cpu()?;
        println!("PJRT platform: {}\n", rt.platform());
        step1_golden_resnet(&rt)?;
        step2_fused_tile_kernel(&rt)?;
    } else {
        println!(
            "[1/4][2/4] skipped: built without the `pjrt` feature (no PJRT \
             runtime in the offline crate set)\n"
        );
    }
    step3_dataflow_validation()?;
    step4_ppa()?;
    println!("\nE2E: all stages passed.");
    Ok(())
}

/// L2 check: AOT ResNet18 (JAX, 32px) vs the Rust reference executor.
fn step1_golden_resnet(rt: &Runtime) -> Result<()> {
    let g = resnet18_at(32);
    let input = synth_input(&g, SEED);
    let reference = run_reference(&g, &input, SEED);
    let rust_out = reference.last().unwrap();

    let model = rt
        .load_hlo(artifacts_dir().join("resnet18_32.hlo.txt"))
        .context("stage 1")?;

    // Inputs: image first, then every conv/fc weight tensor in node order
    // (the python model mirrors the Rust builder — see compile/model.py).
    let mut datas: Vec<Vec<f32>> = vec![input.data().to_vec()];
    let mut shapes: Vec<Vec<usize>> = vec![vec![3, 32, 32]];
    for n in &g.nodes {
        match n.op {
            Op::Conv { cout, k, .. } => {
                datas.push(synth_weights(n, SEED));
                shapes.push(vec![cout, g.nodes[n.inputs[0]].shape.c, k, k]);
            }
            Op::Fc { cout } => {
                datas.push(synth_weights(n, SEED));
                shapes.push(vec![cout, g.nodes[n.inputs[0]].shape.elems()]);
            }
            _ => {}
        }
    }
    let args: Vec<(&[f32], &[usize])> = datas
        .iter()
        .zip(&shapes)
        .map(|(d, s)| (d.as_slice(), s.as_slice()))
        .collect();
    let outs = model.run_f32(&args)?;
    let jax_out = &outs[0];

    if jax_out.len() != rust_out.data().len() {
        return Err(anyhow!("output length mismatch"));
    }
    // Tolerance note: XLA's conv reductions associate f32 sums in a
    // different order than the Rust scalar loops; through 20 chained
    // conv layers the reassociation error compounds to ~1e-3 relative.
    // 1e-2 cleanly separates "same computation" from any real bug
    // (a single missing halo pixel produces O(1) relative error).
    let mut worst = 0.0f32;
    for (a, b) in jax_out.iter().zip(rust_out.data()) {
        let rel = (a - b).abs() / b.abs().max(1.0);
        worst = worst.max(rel);
    }
    println!(
        "[1/4] L2 golden model: JAX ResNet18@32px vs Rust reference over {} logits: max rel err {:.2e} {}",
        jax_out.len(),
        worst,
        ok(worst < 1e-2)
    );
    if worst >= 1e-2 {
        return Err(anyhow!("golden model mismatch"));
    }
    Ok(())
}

/// L1 check: the Pallas fused two-conv tile artifact against the Rust
/// reference, with the halo geometry produced by the L3 tiling engine.
fn step2_fused_tile_kernel(rt: &Runtime) -> Result<()> {
    use pimfused::cnn::{Graph, Shape};
    use pimfused::dataflow::tiling::{demand_for_tile, Rect};

    // Two fused 3x3 convs over an 8-channel map — the artifact's shapes:
    // haloed input 12x12 -> tile 8x8 (interior tile of a 20x20 map,
    // which after pad=1 covers the demanded region exactly).
    let mut g = Graph::new("pair", Shape::new(8, 20, 20));
    let conv = |relu| Op::Conv { cout: 8, k: 3, stride: 1, pad: 1, bn: true, relu };
    let c1 = g.add("c1", conv(true), vec![0]);
    let c2 = g.add("c2", conv(false), vec![c1]);

    let input = synth_input(&g, SEED + 1);
    let reference = run_reference(&g, &input, SEED + 1);

    // Interior tile [6,14) x [6,14): the L3 halo math demands [4,16)².
    let tile = Rect::new(6, 6, 14, 14);
    let demand = demand_for_tile(&g, 1, 2, tile);
    let ext = demand.external[&0];
    assert_eq!((ext.w(), ext.h()), (12, 12), "halo demand should be 12x12");

    let halo = input.slice(&ext);
    let w1 = synth_weights(&g.nodes[c1], SEED + 1);
    let w2 = synth_weights(&g.nodes[c2], SEED + 1);

    let model = rt
        .load_hlo(artifacts_dir().join("fused_block_tile.hlo.txt"))
        .context("stage 2")?;
    let outs = model.run_f32(&[
        (halo.data(), &[8usize, 12, 12][..]),
        (&w1, &[8usize, 8, 3, 3][..]),
        (&w2, &[8usize, 8, 3, 3][..]),
    ])?;
    let got = &outs[0];

    let want = reference[c2].slice(&tile);
    let mut worst = 0.0f32;
    for (a, b) in got.iter().zip(want.data()) {
        worst = worst.max((a - b).abs());
    }
    println!(
        "[2/4] L1 fused-tile kernel: Pallas artifact on L3-demanded halo vs Rust slice: max |Δ| {:.2e} {}",
        worst,
        ok(worst < 1e-4)
    );
    if worst >= 1e-4 {
        return Err(anyhow!("fused tile kernel mismatch"));
    }
    Ok(())
}

/// L3 check: the full PIMfused plan executed tile-by-tile on real data.
fn step3_dataflow_validation() -> Result<()> {
    let g = resnet18_at(32);
    for sys in [System::Fused16, System::Fused4] {
        let cfg = ArchConfig::system(sys, 32 * 1024, 256);
        let p = plan(&g, &cfg);
        let delta = validate_plan(&g, &p, SEED).map_err(anyhow::Error::msg)?;
        println!(
            "[3/4] L3 dataflow validation: {} plan on ResNet18@32px: max |Δ| {delta} {}",
            cfg.label(),
            ok(delta == 0.0)
        );
    }
    Ok(())
}

/// The paper's headline PPA, on the real 224px workload.
fn step4_ppa() -> Result<()> {
    let n = Session::new()
        .experiment(ArchConfig::system(System::Fused4, 32 * 1024, 256))
        .workload(Workload::ResNet18Full)
        .normalized()?;
    println!(
        "[4/4] PPA on ResNet18_Full: {}  (paper: cycles=30.6% energy=83.4% area=76.5%)",
        n.render()
    );
    Ok(())
}

fn ok(b: bool) -> &'static str {
    if b { "OK" } else { "FAIL" }
}

// Silence the unused-import lint when XorShift64 isn't needed directly.
#[allow(dead_code)]
fn _seed_note(_x: XorShift64) {}
