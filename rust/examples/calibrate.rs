//! Calibration report: every paper anchor (headline, §V-D stats, Figs.
//! 5-7) against the measured reproduction, plus a per-component energy
//! breakdown via `--energy`. One [`Session`] feeds all figures, so the
//! workload graphs and baseline reports are built once.

use pimfused::coordinator::experiments::*;
use pimfused::coordinator::Session;
use pimfused::dataflow::CostModel;

fn main() {
    let m = CostModel::default();
    if std::env::args().any(|a| a == "--energy") {
        use pimfused::config::{ArchConfig, System};
        use pimfused::workload::Workload;
        let session = Session::with_model(m);
        for (name, cfg) in [
            ("baseline", ArchConfig::baseline()),
            ("fused4_hl", ArchConfig::system(System::Fused4, 32 * 1024, 256)),
            ("fused16_hl", ArchConfig::system(System::Fused16, 32 * 1024, 256)),
        ] {
            let r = session.experiment(cfg).workload(Workload::ResNet18Full).run().unwrap();
            println!("== {name} {} total={:.3} mJ cycles={}", r.label, r.energy_pj / 1e9, r.cycles);
            for c in &r.energy.components {
                println!("   {:<20} {:>10.4} mJ", c.name, c.energy_pj / 1e9);
            }
        }
        return;
    }
    println!("HEADLINE (paper: cycles=30.6% energy=83.4% area=76.5%)");
    println!("  measured: {}", headline(m).unwrap().render());
    let s = vd_stats(m).unwrap();
    println!("V-D (paper: repl +18.2%, redundant +17.3%, perf 91.2%)");
    println!("  measured: repl +{:.1}%, redundant +{:.1}%, perf {:.1}%",
        (s.fusion.replication-1.0)*100.0, (s.fusion.redundant_macs-1.0)*100.0, s.perf_improvement*100.0);
    let session = Session::with_model(m);
    println!("\nFIG5 (GBUF sweep, L0):\n{}", render(&fig5_in(&session).unwrap()));
    println!("FIG6 (LBUF sweep, G2K):\n{}", render(&fig6_in(&session).unwrap()));
    println!("FIG7 (joint):\n{}", render(&fig7_in(&session).unwrap()));
}
