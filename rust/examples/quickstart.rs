//! Quickstart: evaluate PIMfused against the GDDR6-AiM-like baseline on
//! end-to-end ResNet18 and print the paper's headline comparison —
//! the smallest useful [`Session`] (Experiment API v2) program.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```

use pimfused::config::{ArchConfig, System};
use pimfused::coordinator::Session;
use pimfused::workload::Workload;

fn main() -> anyhow::Result<()> {
    // A session owns the shared state: the cost model, the baseline used
    // for normalization, and memoized graphs/plans/baseline reports.
    let session = Session::new();

    println!("workload: end-to-end ResNet18 (224x224)\n");
    let base = session.baseline(Workload::ResNet18Full)?;
    println!(
        "{:<22} cycles={:>12}  energy={:>8.3} mJ  area={:>6.3} mm2",
        base.label,
        base.cycles,
        base.energy_pj / 1e9,
        base.area_mm2
    );

    for sys in [System::Fused16, System::Fused4] {
        let r = session
            .experiment(ArchConfig::system(sys, 32 * 1024, 256))
            .workload(Workload::ResNet18Full)
            .run()?;
        let n = r.normalize(&base);
        println!(
            "{:<22} cycles={:>12}  energy={:>8.3} mJ  area={:>6.3} mm2   vs baseline: {}",
            r.label,
            r.cycles,
            r.energy_pj / 1e9,
            r.area_mm2,
            n.render()
        );
    }

    // The headline PIMfused configuration: 4-bank PIMcores, G32K_L256.
    // `.normalized()` reuses the memoized baseline report from above.
    let ours = session
        .experiment(ArchConfig::system(System::Fused4, 32 * 1024, 256))
        .workload(Workload::ResNet18Full)
        .normalized()?;
    println!(
        "\npaper headline (Fused4 @ G32K_L256): cycles=30.6% energy=83.4% area=76.5%\n\
         this reproduction                  : {}",
        ours.render()
    );
    Ok(())
}
