//! Component-level energy & area estimation — the Accelergy box of the
//! paper's profiling framework (Fig. 4, §V-A1).
//!
//! A system is a hierarchy of [`Component`]s, each mapping an action count
//! from the simulator's [`ActionCounts`] to energy via a per-action cost
//! (primitive constants in [`primitives`], SRAM costs from the CACTI-like
//! [`cacti`] model). Area rolls up the same hierarchy from the
//! architecture configuration.

pub mod cacti;
pub mod primitives;

use crate::config::{ArchConfig, System};
use crate::sim::ActionCounts;
use primitives as p;

/// One named energy contribution (for reporting/debugging breakdowns).
#[derive(Debug, Clone, PartialEq)]
pub struct Component {
    /// Dotted component path, e.g. `dram.row_act` or `gbuf.sram`.
    pub name: &'static str,
    /// This component's contribution in picojoules.
    pub energy_pj: f64,
}

/// Energy report: total plus the per-component breakdown.
#[derive(Debug, Clone, PartialEq)]
pub struct EnergyReport {
    /// Per-component contributions, in the fixed order [`energy`] emits.
    pub components: Vec<Component>,
}

impl EnergyReport {
    /// Total energy in picojoules (the sum over all components).
    pub fn total_pj(&self) -> f64 {
        self.components.iter().map(|c| c.energy_pj).sum()
    }

    /// Energy of one named component in picojoules (0.0 when absent).
    pub fn component(&self, name: &str) -> f64 {
        self.components
            .iter()
            .filter(|c| c.name == name)
            .map(|c| c.energy_pj)
            .sum()
    }
}

/// Estimate total energy for a simulated run.
///
/// The LBUF feed term reconstructs the operand bytes the LBUF intercepted:
/// the full per-MAC feed is `2 bytes × MACs`; whatever the banks did not
/// serve (unique + hit) came from LBUF/registers.
///
/// `dram.row_act` prices [`ActionCounts::row_activations`], which the
/// engines tally from the same per-bank row maps the event scheduler
/// meters its ACT windows from — ACT energy and ACT scheduling can no
/// longer disagree (DESIGN.md §6.2).
pub fn energy(cfg: &ArchConfig, a: &ActionCounts) -> EnergyReport {
    let e_gbuf = cacti::sram_energy_pj_per_byte(cfg.gbuf_bytes);
    let e_lbuf = cacti::sram_energy_pj_per_byte(cfg.lbuf_bytes.max(32));

    let lbuf_feed_bytes = (2 * a.pimcore_macs)
        .saturating_sub(a.near_col_hit_bytes + a.near_col_read_bytes)
        as f64;

    let components = vec![
        Component { name: "dram.row_act", energy_pj: a.row_activations as f64 * p::E_ROW_ACT_PJ },
        Component {
            name: "dram.near_col",
            energy_pj: (a.near_col_read_bytes + a.near_col_write_bytes) as f64
                * p::e_near_pj_per_byte(),
        },
        Component {
            name: "dram.row_hit_feed",
            energy_pj: a.near_col_hit_bytes as f64 * p::E_ROW_HIT_PJ_PER_BYTE,
        },
        Component {
            name: "dram.cross_col",
            energy_pj: (a.cross_col_read_bytes + a.cross_col_write_bytes) as f64
                * p::e_near_pj_per_byte(),
        },
        Component { name: "bus.wire", energy_pj: a.bus_bytes as f64 * p::E_BUS_PJ_PER_BYTE },
        Component {
            name: "gbuf.sram",
            energy_pj: (a.gbuf_read_bytes + a.gbuf_write_bytes) as f64 * e_gbuf,
        },
        Component {
            name: "lbuf.sram",
            energy_pj: (a.lbuf_read_bytes + a.lbuf_write_bytes) as f64 * e_lbuf
                + lbuf_feed_bytes * e_lbuf,
        },
        Component { name: "pimcore.mac", energy_pj: a.pimcore_macs as f64 * p::E_MAC_PJ },
        Component { name: "pimcore.alu", energy_pj: a.pimcore_eltwise as f64 * p::E_ALU_PJ },
        Component { name: "gbcore.alu", energy_pj: a.gbcore_eltwise as f64 * p::E_ALU_PJ },
        Component { name: "host.io", energy_pj: a.host_bytes as f64 * p::E_HOST_PJ_PER_BYTE },
    ];
    EnergyReport { components }
}

/// Area report (mm² of PIM additions to the DRAM die).
#[derive(Debug, Clone, PartialEq)]
pub struct AreaReport {
    /// All PIMcores (per-core datapath area × core count).
    pub pimcores_mm2: f64,
    /// The shared GBcore datapath.
    pub gbcore_mm2: f64,
    /// The global buffer SRAM macro.
    pub gbuf_mm2: f64,
    /// All per-core LBUF SRAM macros.
    pub lbufs_mm2: f64,
    /// Command decode/control overhead.
    pub control_mm2: f64,
}

impl AreaReport {
    /// Total PIM-addition area in mm².
    pub fn total_mm2(&self) -> f64 {
        self.pimcores_mm2 + self.gbcore_mm2 + self.gbuf_mm2 + self.lbufs_mm2 + self.control_mm2
    }
}

/// Estimate the PIM-addition area of an architecture.
pub fn area(cfg: &ArchConfig) -> AreaReport {
    let per_core = match cfg.system {
        System::AimLike => p::A_PIMCORE_AIM_MM2,
        System::Fused16 => p::A_PIMCORE_FUSED1_MM2,
        System::Fused4 => p::A_PIMCORE_FUSED4_MM2,
    };
    AreaReport {
        pimcores_mm2: per_core * cfg.num_pimcores() as f64,
        gbcore_mm2: p::A_GBCORE_MM2,
        gbuf_mm2: cacti::sram_area_mm2(cfg.gbuf_bytes),
        lbufs_mm2: cacti::sram_area_mm2(cfg.lbuf_bytes) * cfg.num_pimcores() as f64,
        control_mm2: p::A_CONTROL_MM2,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::resnet::resnet18;
    use crate::dataflow::{plan, CostModel};
    use crate::sim::simulate;
    use crate::trace::gen::generate;

    fn run(sys: System, gbuf: usize, lbuf: usize) -> (ArchConfig, ActionCounts) {
        let g = resnet18();
        let cfg = ArchConfig::system(sys, gbuf, lbuf);
        let p = plan(&g, &cfg);
        let t = generate(&g, &cfg, &p, CostModel::default());
        (cfg.clone(), simulate(&cfg, &t).actions)
    }

    #[test]
    fn energy_positive_and_dominated_by_memory() {
        let (cfg, a) = run(System::AimLike, 2048, 0);
        let e = energy(&cfg, &a);
        assert!(e.total_pj() > 0.0);
        let mem: f64 = e.component("dram.near_col")
            + e.component("dram.cross_col")
            + e.component("dram.row_act")
            + e.component("dram.row_hit_feed");
        assert!(
            mem > e.component("pimcore.mac"),
            "memory {} should exceed compute {}",
            mem,
            e.component("pimcore.mac")
        );
    }

    #[test]
    fn energy_additive_over_action_merge() {
        let (cfg, a) = run(System::Fused4, 8192, 128);
        let mut doubled = a;
        doubled.add(&a);
        let e1 = energy(&cfg, &a).total_pj();
        let e2 = energy(&cfg, &doubled).total_pj();
        assert!((e2 - 2.0 * e1).abs() / e1 < 1e-9);
    }

    #[test]
    fn baseline_area_composition() {
        let base = area(&ArchConfig::baseline());
        // 16 lean PIMcores dominate the baseline budget.
        assert!(base.pimcores_mm2 > base.gbcore_mm2);
        assert!(base.gbuf_mm2 < 0.02);
        assert_eq!(base.lbufs_mm2, 0.0);
        assert!((0.3..0.6).contains(&base.total_mm2()));
    }

    #[test]
    fn fused4_area_below_baseline_fused16_above() {
        // Fig. 5/6's area shapes: Fused4 saves area (4 cores), Fused16
        // costs more (16 fatter cores), at matched buffer configs.
        let base = area(&ArchConfig::baseline()).total_mm2();
        let f4 = area(&ArchConfig::system(System::Fused4, 2048, 0)).total_mm2();
        let f16 = area(&ArchConfig::system(System::Fused16, 2048, 0)).total_mm2();
        assert!(f4 < base, "Fused4 {f4} !< base {base}");
        assert!(f16 > base, "Fused16 {f16} !> base {base}");
        let r4 = f4 / base;
        assert!((0.35..0.60).contains(&r4), "Fused4 @G2K_L0 ratio {r4:.3} vs paper 0.446");
    }

    #[test]
    fn headline_area_band() {
        // §V-D: Fused4 @ G32K_L256 sits at 76.5% of baseline area in the
        // paper; our component constants must land in the same regime.
        let base = area(&ArchConfig::baseline()).total_mm2();
        let f4 = area(&ArchConfig::system(System::Fused4, 32 * 1024, 256)).total_mm2();
        let r = f4 / base;
        assert!((0.55..0.95).contains(&r), "headline area ratio {r:.3}");
    }

    #[test]
    fn ideal_lbuf_area_is_dramatic() {
        // §V-D: G64K_L100K's area "rises dramatically".
        let modest = area(&ArchConfig::system(System::Fused4, 64 * 1024, 256)).total_mm2();
        let ideal = area(&ArchConfig::system(System::Fused4, 64 * 1024, 100 * 1024)).total_mm2();
        assert!(ideal > 2.0 * modest);
    }

    #[test]
    fn lbuf_energy_cheaper_than_bank_feed() {
        // The energy rationale for LBUFs: intercepted feed bytes move from
        // row-hit DRAM reads (2 pJ/B) to small-SRAM reads (<1 pJ/B).
        let (cfg0, a0) = run(System::AimLike, 2048, 0);
        let (cfg1, a1) = run(System::AimLike, 2048, 256);
        let e0 = energy(&cfg0, &a0).total_pj();
        let e1 = energy(&cfg1, &a1).total_pj();
        assert!(e1 < e0, "LBUF should cut energy: {e1} !< {e0}");
    }
}
