//! Analytic SRAM/register-file area & energy model @22nm — the stand-in
//! for Accelergy's CACTI plugin (§V-A1).
//!
//! Two implementation styles compete and the cheaper wins, which
//! reproduces the CACTI behaviour the paper leans on ("small SRAMs (<1KB)
//! are dominated by peripheral circuitry"): tiny buffers synthesize as
//! register files (low fixed cost, steep per-byte slope), larger ones as
//! SRAM macros (peripheral floor, shallow slope).

/// Area in mm² of a buffer of `bytes` capacity @22nm.
pub fn sram_area_mm2(bytes: usize) -> f64 {
    if bytes == 0 {
        return 0.0;
    }
    let regfile = 0.0008 + 6.0e-6 * bytes as f64;
    let sram = 0.009 + 1.12e-6 * bytes as f64;
    regfile.min(sram)
}

/// Dynamic energy in pJ per byte accessed, for a buffer of `bytes`
/// capacity. Grows weakly with capacity (longer bit/word lines).
pub fn sram_energy_pj_per_byte(bytes: usize) -> f64 {
    if bytes == 0 {
        return 0.0;
    }
    0.35 + 0.12 * (bytes as f64 / 1024.0).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_bytes_cost_nothing() {
        assert_eq!(sram_area_mm2(0), 0.0);
        assert_eq!(sram_energy_pj_per_byte(0), 0.0);
    }

    #[test]
    fn area_monotone_in_capacity() {
        let sizes = [64usize, 128, 256, 512, 2048, 8192, 32768, 65536, 102400];
        let areas: Vec<f64> = sizes.iter().map(|&b| sram_area_mm2(b)).collect();
        for w in areas.windows(2) {
            assert!(w[1] > w[0]);
        }
    }

    #[test]
    fn small_buffers_are_peripheral_dominated() {
        // Key takeaway 2's area premise: 64B -> 512B adds little area.
        let a64 = sram_area_mm2(64);
        let a512 = sram_area_mm2(512);
        assert!(a512 / a64 < 4.0, "512B should be <4x the 64B area");
        // ... while 100KB LBUFs are "dramatic" (paper §V-D).
        assert!(sram_area_mm2(100 * 1024) / a512 > 25.0);
    }

    #[test]
    fn style_crossover_exists() {
        // Register-file style wins small, SRAM style wins large.
        assert!(sram_area_mm2(64) < 0.0015);
        let big = sram_area_mm2(64 * 1024);
        assert!((0.05..0.12).contains(&big), "64KB = {big} mm2");
    }

    #[test]
    fn energy_grows_weakly() {
        let e64 = sram_energy_pj_per_byte(64);
        let e64k = sram_energy_pj_per_byte(64 * 1024);
        assert!(e64k > e64);
        assert!(e64k / e64 < 5.0);
    }
}
