//! Primitive action energies and component areas @22nm — the stand-in for
//! the paper's "in-house post-synthesis data" and its Accelergy DRAM
//! plugin configuration (§V-A1).
//!
//! Sources for each constant:
//! * GDDR6 access energy ≈ 7–8 pJ/bit including I/O (public GDDR5 numbers
//!   scaled one node, as the paper does) → [`E_DRAM_FULL_PJ_PER_BYTE`].
//! * The paper states near-bank accesses cost **40%** of the full access
//!   because they bypass the I/O path → [`NEAR_BANK_ENERGY_FRACTION`].
//! * Open-row (row-buffer-hit) column reads skip the array access and pay
//!   only column mux + sense-amp readout → [`E_ROW_HIT_PJ_PER_BYTE`].
//! * Row activation energy for a 2 KB page is ~0.9 nJ (DRAMPower-class
//!   numbers) → [`E_ROW_ACT_PJ`].
//! * Internal bus wire energy ~0.25 pJ/bit at channel scale (the paper
//!   "models the internal bus between banks and the GBUF with wire
//!   models") → [`E_BUS_PJ_PER_BYTE`].
//! * BF16 MAC / 16-bit ALU op energies are standard 22nm post-synthesis
//!   ballparks (0.5–0.7 pJ and 0.1–0.2 pJ).

/// Full GDDR6 access energy (array + periphery + I/O), pJ per byte.
pub const E_DRAM_FULL_PJ_PER_BYTE: f64 = 62.0;

/// Paper §V-A1: near-bank accesses consume 40% of the full access energy.
pub const NEAR_BANK_ENERGY_FRACTION: f64 = 0.40;

/// Near-bank column access (first touch), pJ per byte.
pub fn e_near_pj_per_byte() -> f64 {
    E_DRAM_FULL_PJ_PER_BYTE * NEAR_BANK_ENERGY_FRACTION
}

/// Open-row re-read (row-buffer hit), pJ per byte.
pub const E_ROW_HIT_PJ_PER_BYTE: f64 = 1.0;

/// One row activation (ACT+PRE of a 2 KB page), pJ.
pub const E_ROW_ACT_PJ: f64 = 900.0;

/// Shared internal bus, pJ per byte moved.
pub const E_BUS_PJ_PER_BYTE: f64 = 2.0;

/// Off-chip host interface, pJ per byte (full access energy).
pub const E_HOST_PJ_PER_BYTE: f64 = E_DRAM_FULL_PJ_PER_BYTE;

/// One BF16 multiply-accumulate in a PIMcore, pJ.
pub const E_MAC_PJ: f64 = 0.6;

/// One 16-bit element-wise op (BN step, ReLU, add, max-compare), pJ.
pub const E_ALU_PJ: f64 = 0.15;

// ----------------------------------------------------------------------
// Component areas (mm² @22nm). Derived from the PPA ratios the paper
// reports for its three systems; see DESIGN.md §7 and the area tests.
// ----------------------------------------------------------------------

/// GDDR6-AiM-like 1-bank PIMcore: 16-lane BF16 MAC + BN + ReLU.
pub const A_PIMCORE_AIM_MM2: f64 = 0.020;

/// PIMfused 1-bank PIMcore (Fused16): adds pooling, residual add and the
/// LBUF datapath — the "new components in red" of Fig. 2.
pub const A_PIMCORE_FUSED1_MM2: f64 = 0.0334;

/// PIMfused 4-bank PIMcore (Fused4): the full feature set with a 64-lane
/// datapath striped over 4 banks. MAC lanes are a minority of core area
/// (control, sequencing and the bank mux dominate at this scale), so 4×
/// the lanes costs ~2×, not 4× — and there are 4× fewer cores.
pub const A_PIMCORE_FUSED4_MM2: f64 = 0.040;

/// Channel-level GBcore: pool/add/relu SIMD + data-reduction control.
pub const A_GBCORE_MM2: f64 = 0.060;

/// Fixed channel control/bus overhead of the PIM additions.
pub const A_CONTROL_MM2: f64 = 0.008;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn near_bank_discount_matches_paper() {
        assert!((e_near_pj_per_byte() - 24.8).abs() < 1e-9);
        assert!(e_near_pj_per_byte() < E_DRAM_FULL_PJ_PER_BYTE);
    }

    #[test]
    fn energy_ordering_is_physical() {
        // hit < near < full; compute « data movement per byte-equivalent.
        assert!(E_ROW_HIT_PJ_PER_BYTE < e_near_pj_per_byte());
        assert!(e_near_pj_per_byte() < E_HOST_PJ_PER_BYTE);
        assert!(E_MAC_PJ < E_ROW_HIT_PJ_PER_BYTE * 2.0);
    }

    #[test]
    fn pimcore_area_ordering() {
        // AiM's lean core < Fused16's full-feature 1-bank core < Fused4's
        // 4-bank, 64-lane core < the GBcore.
        assert!(A_PIMCORE_AIM_MM2 < A_PIMCORE_FUSED1_MM2);
        assert!(A_PIMCORE_FUSED1_MM2 < A_PIMCORE_FUSED4_MM2);
        assert!(A_GBCORE_MM2 > A_PIMCORE_FUSED4_MM2);
        // ...but 4 Fused4 cores undercut 16 of either 1-bank core.
        assert!(4.0 * A_PIMCORE_FUSED4_MM2 < 16.0 * A_PIMCORE_AIM_MM2);
    }
}
