//! Human byte-size parsing and formatting.
//!
//! The paper denotes buffer configurations as `GmK_Ln` — e.g. `G32K_L256`
//! means GBUF = 32 KB, LBUF = 256 B. This module parses the size atoms
//! (`32K`, `256`, `100K`, `2M`) and prints them back the same way.

/// Parse a size like `"256"`, `"32K"`, `"2M"` into bytes.
/// Suffixes are binary (K = 1024). Case-insensitive. A trailing `B` is
/// accepted (`"64B"`, `"2KB"`).
pub fn parse_bytes(s: &str) -> Result<usize, String> {
    let t = s.trim().to_ascii_uppercase();
    let t = t.strip_suffix('B').unwrap_or(&t);
    if t.is_empty() {
        return Err(format!("empty size string {s:?}"));
    }
    let (num, mult) = match t.chars().last().unwrap() {
        'K' => (&t[..t.len() - 1], 1024usize),
        'M' => (&t[..t.len() - 1], 1024 * 1024),
        'G' => (&t[..t.len() - 1], 1024 * 1024 * 1024),
        _ => (&t[..], 1),
    };
    let v: f64 = num
        .parse()
        .map_err(|_| format!("bad size number in {s:?}"))?;
    if v < 0.0 {
        return Err(format!("negative size {s:?}"));
    }
    Ok((v * mult as f64).round() as usize)
}

/// Format bytes compactly the way the paper writes them: `0`, `256`, `2K`,
/// `100K`, `1M`. Exact multiples only get the suffix.
pub fn fmt_bytes(b: usize) -> String {
    const K: usize = 1024;
    const M: usize = 1024 * 1024;
    if b >= M && b % M == 0 {
        format!("{}M", b / M)
    } else if b >= K && b % K == 0 {
        format!("{}K", b / K)
    } else {
        format!("{b}")
    }
}

/// Render a buffer configuration in the paper's `GmK_Ln` notation.
pub fn fmt_bufcfg(gbuf: usize, lbuf: usize) -> String {
    format!("G{}_L{}", fmt_bytes(gbuf), fmt_bytes(lbuf))
}

/// Parse the paper's `GmK_Ln` notation back into `(gbuf, lbuf)` bytes.
pub fn parse_bufcfg(s: &str) -> Result<(usize, usize), String> {
    let t = s.trim();
    let rest = t
        .strip_prefix(['G', 'g'])
        .ok_or_else(|| format!("bufcfg {s:?} must start with G"))?;
    let (g, l) = rest
        .split_once(['_', '-'])
        .ok_or_else(|| format!("bufcfg {s:?} missing _L separator"))?;
    let l = l
        .strip_prefix(['L', 'l'])
        .ok_or_else(|| format!("bufcfg {s:?} missing L part"))?;
    Ok((parse_bytes(g)?, parse_bytes(l)?))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_plain_and_suffixed() {
        assert_eq!(parse_bytes("256").unwrap(), 256);
        assert_eq!(parse_bytes("2K").unwrap(), 2048);
        assert_eq!(parse_bytes("2k").unwrap(), 2048);
        assert_eq!(parse_bytes("2KB").unwrap(), 2048);
        assert_eq!(parse_bytes("1M").unwrap(), 1 << 20);
        assert_eq!(parse_bytes("0").unwrap(), 0);
        assert_eq!(parse_bytes("1.5K").unwrap(), 1536);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(parse_bytes("").is_err());
        assert!(parse_bytes("abc").is_err());
        assert!(parse_bytes("-4K").is_err());
    }

    #[test]
    fn fmt_roundtrip() {
        for b in [0usize, 1, 64, 256, 512, 2048, 100 * 1024, 1 << 20] {
            assert_eq!(parse_bytes(&fmt_bytes(b)).unwrap(), b);
        }
    }

    #[test]
    fn bufcfg_notation_matches_paper() {
        assert_eq!(fmt_bufcfg(32 * 1024, 256), "G32K_L256");
        assert_eq!(fmt_bufcfg(2 * 1024, 0), "G2K_L0");
        assert_eq!(fmt_bufcfg(64 * 1024, 100 * 1024), "G64K_L100K");
        assert_eq!(parse_bufcfg("G32K_L256").unwrap(), (32 * 1024, 256));
        assert_eq!(parse_bufcfg("g2k_l0").unwrap(), (2048, 0));
        assert!(parse_bufcfg("32K_L256").is_err());
        assert!(parse_bufcfg("G32K").is_err());
    }
}
