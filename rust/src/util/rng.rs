//! Deterministic xorshift64* PRNG.
//!
//! Every randomized test and workload generator in this crate seeds one of
//! these explicitly, so all results are reproducible run-to-run (a hard
//! requirement for the paper-reproduction benches).

/// xorshift64* generator (Vigna 2016). Not cryptographic; plenty for
/// workload synthesis and property-test case generation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct XorShift64 {
    state: u64,
}

impl XorShift64 {
    /// Create a generator from a seed. A zero seed is remapped (xorshift
    /// has an all-zeros fixed point).
    pub fn new(seed: u64) -> Self {
        Self {
            state: if seed == 0 { 0x9E3779B97F4A7C15 } else { seed },
        }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in `[0, bound)`. `bound` must be non-zero.
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // Multiply-shift trick; bias is < 2^-32 for the bounds we use.
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform usize in `[lo, hi]` inclusive.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + self.next_below((hi - lo + 1) as u64) as usize
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform f32 in `[-1, 1)`; handy for synthetic tensor data.
    #[inline]
    pub fn next_f32_signed(&mut self) -> f32 {
        self.next_f32() * 2.0 - 1.0
    }

    /// Uniform f64 in `[0, 1)` (53 mantissa bits).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Exponential draw with the given mean (inverse-CDF transform).
    /// The Poisson arrival process in [`crate::serve`] draws its
    /// interarrival gaps from this. `u < 1` always, so `ln(1 - u)` is
    /// finite and the result is non-negative.
    #[inline]
    pub fn next_exp(&mut self, mean: f64) -> f64 {
        debug_assert!(mean > 0.0);
        -(1.0 - self.next_f64()).ln() * mean
    }

    /// Bernoulli draw with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = XorShift64::new(7);
        let mut b = XorShift64::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn zero_seed_is_remapped() {
        let mut r = XorShift64::new(0);
        assert_ne!(r.next_u64(), 0);
    }

    #[test]
    fn next_below_respects_bound() {
        let mut r = XorShift64::new(42);
        for _ in 0..10_000 {
            assert!(r.next_below(13) < 13);
        }
    }

    #[test]
    fn range_inclusive_hits_endpoints() {
        let mut r = XorShift64::new(3);
        let (mut saw_lo, mut saw_hi) = (false, false);
        for _ in 0..10_000 {
            match r.range(2, 4) {
                2 => saw_lo = true,
                4 => saw_hi = true,
                3 => {}
                _ => panic!("out of range"),
            }
        }
        assert!(saw_lo && saw_hi);
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = XorShift64::new(11);
        for _ in 0..10_000 {
            let v = r.next_f32();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = XorShift64::new(13);
        for _ in 0..10_000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn exp_mean_is_roughly_right() {
        let mut r = XorShift64::new(17);
        let n = 100_000;
        let mean = 250.0;
        let sum: f64 = (0..n).map(|_| r.next_exp(mean)).sum();
        let got = sum / n as f64;
        assert!((got - mean).abs() < mean * 0.02, "sample mean {got} vs {mean}");
        let mut s = XorShift64::new(17);
        for _ in 0..10_000 {
            assert!(s.next_exp(mean) >= 0.0);
        }
    }

    #[test]
    fn rough_uniformity() {
        let mut r = XorShift64::new(5);
        let mut buckets = [0usize; 10];
        for _ in 0..100_000 {
            buckets[r.range(0, 9)] += 1;
        }
        for &b in &buckets {
            assert!((8_000..12_000).contains(&b), "bucket {b} not ~10k");
        }
    }
}
