//! Fixed-width ASCII table rendering for CLI reports and benches.
//!
//! The benches regenerate the paper's figures as text tables (one row per
//! plotted point), so a small dependable renderer beats pulling in a crate.

/// A simple left-aligned-first-column, right-aligned-rest table.
#[derive(Debug, Default, Clone)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// A table with the given column headers and no rows yet.
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        Self {
            header: header.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    /// Append one row; panics if its width differs from the header's.
    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width {} != header width {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells);
        self
    }

    /// Whether no rows have been added.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render the table with `+---+` rules and aligned columns.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut w = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            w[i] = w[i].max(h.len());
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], w: &[usize]| -> String {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                if i == 0 {
                    s += &format!(" {:<width$} |", c, width = w[i]);
                } else {
                    s += &format!(" {:>width$} |", c, width = w[i]);
                }
            }
            s.push('\n');
            s
        };
        let rule = {
            let mut s = String::from("+");
            for wi in &w {
                s += &"-".repeat(wi + 2);
                s.push('+');
            }
            s.push('\n');
            s
        };
        out += &rule;
        out += &fmt_row(&self.header, &w);
        out += &rule;
        for r in &self.rows {
            out += &fmt_row(r, &w);
        }
        out += &rule;
        out
    }
}

/// Format a ratio as the paper does: `30.6%` (one decimal).
pub fn pct(x: f64) -> String {
    format!("{:.1}%", x * 100.0)
}

/// Format a ratio as a multiplier when >= 1 (`1.1x`), else percent.
pub fn pct_or_x(x: f64) -> String {
    if x >= 1.0 {
        format!("{x:.2}x")
    } else {
        pct(x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(vec!["cfg", "cycles"]);
        t.row(vec!["G2K_L0", "100.0%"]);
        t.row(vec!["G32K_L256", "30.6%"]);
        let s = t.render();
        assert!(s.contains("| G2K_L0    |"));
        assert!(s.contains("|  30.6% |"));
        // All lines equal width.
        let widths: Vec<usize> = s.lines().map(|l| l.len()).collect();
        assert!(widths.windows(2).all(|w| w[0] == w[1]));
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn rejects_ragged_rows() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["only-one"]);
    }

    #[test]
    fn pct_formats() {
        assert_eq!(pct(0.306), "30.6%");
        assert_eq!(pct_or_x(1.1), "1.10x");
        assert_eq!(pct_or_x(0.834), "83.4%");
    }
}
