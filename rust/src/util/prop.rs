//! Miniature property-testing framework (offline substitute for `proptest`).
//!
//! The vendored crate set on this image has no `proptest`, so invariant
//! tests use this instead: a [`Gen`] wraps the crate PRNG, strategies are
//! plain closures `FnMut(&mut Gen) -> T`, and [`check`] runs a property over
//! many generated cases with greedy input shrinking on failure (halving
//! numeric fields via the case's [`Shrink`] impl when provided).
//!
//! Usage:
//! ```no_run
//! use pimfused::util::prop::{check, Gen};
//! check("sum commutes", 256, |g: &mut Gen| (g.usize_in(0, 99), g.usize_in(0, 99)),
//!       |&(a, b)| a + b == b + a);
//! ```

use super::rng::XorShift64;

/// Case generator handed to strategies.
pub struct Gen {
    rng: XorShift64,
    /// Grows over the run so later cases are "bigger", like proptest sizes.
    pub size: usize,
}

impl Gen {
    /// A generator seeded deterministically (every run reproduces).
    pub fn new(seed: u64) -> Self {
        Self { rng: XorShift64::new(seed), size: 4 }
    }

    /// Uniform usize in `[lo, hi]` inclusive.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.range(lo, hi)
    }

    /// A raw 64-bit draw.
    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    /// Uniform f32 in `[-1, 1)`.
    pub fn f32_signed(&mut self) -> f32 {
        self.rng.next_f32_signed()
    }

    /// A fair coin flip.
    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }

    /// Pick one element of a slice uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.range(0, xs.len() - 1)]
    }

    /// A vector whose length scales with the current case size.
    pub fn vec_of<T>(&mut self, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        let n = self.usize_in(0, self.size);
        (0..n).map(|_| f(self)).collect()
    }
}

/// Types that can propose strictly-smaller variants of themselves.
pub trait Shrink: Sized {
    /// Candidate smaller inputs, tried in order.
    fn shrink(&self) -> Vec<Self> {
        Vec::new()
    }
}

impl Shrink for usize {
    fn shrink(&self) -> Vec<Self> {
        if *self == 0 { vec![] } else { vec![*self / 2, *self - 1] }
    }
}

impl Shrink for u64 {
    fn shrink(&self) -> Vec<Self> {
        if *self == 0 { vec![] } else { vec![*self / 2, *self - 1] }
    }
}

impl<A: Shrink + Clone, B: Shrink + Clone> Shrink for (A, B) {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        for a in self.0.shrink() {
            out.push((a, self.1.clone()));
        }
        for b in self.1.shrink() {
            out.push((self.0.clone(), b));
        }
        out
    }
}

impl<A: Shrink + Clone, B: Shrink + Clone, C: Shrink + Clone> Shrink for (A, B, C) {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        for a in self.0.shrink() {
            out.push((a, self.1.clone(), self.2.clone()));
        }
        for b in self.1.shrink() {
            out.push((self.0.clone(), b, self.2.clone()));
        }
        for c in self.2.shrink() {
            out.push((self.0.clone(), self.1.clone(), c));
        }
        out
    }
}

/// Run `property` over `cases` generated inputs; panic with the (shrunk)
/// counterexample on failure. Deterministic: seeded from the test name.
pub fn check<T, G, P>(name: &str, cases: usize, mut strategy: G, mut property: P)
where
    T: std::fmt::Debug + Clone + Shrink,
    G: FnMut(&mut Gen) -> T,
    P: FnMut(&T) -> bool,
{
    let seed = name.bytes().fold(0xcbf29ce484222325u64, |h, b| {
        (h ^ b as u64).wrapping_mul(0x100000001b3)
    });
    let mut gen = Gen::new(seed);
    for i in 0..cases {
        gen.size = 4 + i * 64 / cases.max(1);
        let case = strategy(&mut gen);
        if !property(&case) {
            let shrunk = shrink_loop(case, &mut property);
            panic!("property '{name}' failed on case {i}; minimal counterexample: {shrunk:?}");
        }
    }
}

/// Like [`check`] but without shrinking, for non-`Shrink` case types.
pub fn check_no_shrink<T, G, P>(name: &str, cases: usize, mut strategy: G, mut property: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Gen) -> T,
    P: FnMut(&T) -> bool,
{
    let seed = name.bytes().fold(0xcbf29ce484222325u64, |h, b| {
        (h ^ b as u64).wrapping_mul(0x100000001b3)
    });
    let mut gen = Gen::new(seed);
    for i in 0..cases {
        gen.size = 4 + i * 64 / cases.max(1);
        let case = strategy(&mut gen);
        assert!(
            property(&case),
            "property '{name}' failed on case {i}: {case:?}"
        );
    }
}

fn shrink_loop<T: Shrink + Clone + std::fmt::Debug>(
    mut failing: T,
    property: &mut impl FnMut(&T) -> bool,
) -> T {
    // Greedy descent: keep taking the first still-failing shrink candidate.
    'outer: loop {
        for cand in failing.shrink() {
            if !property(&cand) {
                failing = cand;
                continue 'outer;
            }
        }
        return failing;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("add-commutes", 128, |g| (g.usize_in(0, 1000), g.usize_in(0, 1000)), |&(a, b)| {
            a + b == b + a
        });
    }

    #[test]
    #[should_panic(expected = "minimal counterexample")]
    fn failing_property_reports_counterexample() {
        check("always-small", 128, |g| g.usize_in(0, 1000), |&a| a < 10);
    }

    #[test]
    fn shrink_finds_boundary() {
        // The minimal failing usize for `a < 10` is 10 itself.
        let shrunk = shrink_loop(977usize, &mut |&a| a < 10);
        assert_eq!(shrunk, 10);
    }

    #[test]
    fn deterministic_generation() {
        let mut g1 = Gen::new(9);
        let mut g2 = Gen::new(9);
        for _ in 0..50 {
            assert_eq!(g1.u64(), g2.u64());
        }
    }
}
