//! Small self-contained utilities shared across the crate.
//!
//! The build image is offline and the vendored crate set does not include
//! `rand`, `proptest`, `prettytable` etc., so this module provides the tiny
//! slices of those crates the project needs:
//!
//! * [`rng`] — a deterministic xorshift64* PRNG (seedable, `Copy`).
//! * [`prop`] — a miniature property-testing framework used by the
//!   invariant tests (see DESIGN.md §8).
//! * [`size`] — parsing/formatting of human byte sizes (`"32K"`, `"256"`).
//! * [`table`] — fixed-width ASCII table rendering for benches/CLI reports.

pub mod prop;
pub mod rng;
pub mod size;
pub mod table;

/// Integer ceiling division. Used pervasively by the tiling math.
#[inline]
pub fn ceil_div(a: usize, b: usize) -> usize {
    debug_assert!(b > 0, "ceil_div by zero");
    a.div_ceil(b)
}

/// Round `a` up to the next multiple of `b`.
#[inline]
pub fn round_up(a: usize, b: usize) -> usize {
    ceil_div(a, b) * b
}

/// Relative difference `|a-b| / max(|a|,|b|, eps)`; safe at zero.
#[inline]
pub fn rel_diff(a: f64, b: f64) -> f64 {
    let m = a.abs().max(b.abs()).max(1e-12);
    (a - b).abs() / m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_div_exact_and_inexact() {
        assert_eq!(ceil_div(8, 4), 2);
        assert_eq!(ceil_div(9, 4), 3);
        assert_eq!(ceil_div(1, 4), 1);
        assert_eq!(ceil_div(0, 4), 0);
    }

    #[test]
    fn round_up_basics() {
        assert_eq!(round_up(5, 4), 8);
        assert_eq!(round_up(8, 4), 8);
        assert_eq!(round_up(0, 4), 0);
    }

    #[test]
    fn rel_diff_symmetry_and_zero() {
        assert!(rel_diff(1.0, 1.0) < 1e-15);
        assert!((rel_diff(2.0, 1.0) - 0.5).abs() < 1e-12);
        assert_eq!(rel_diff(0.0, 0.0), 0.0);
    }
}
