//! CNN graph IR: layers, shape inference, and per-layer cost statistics.
//!
//! The paper treats element-wise fusions (`CONV_BN_RELU`) as a single layer
//! (§IV, Fig. 3) and counts ResNet18 layers accordingly; this IR mirrors
//! that convention — BN/ReLU are flags on [`Op::Conv`], residual joins are
//! explicit [`Op::AddRelu`] nodes, and pooling is its own node.
//!
//! Node ids are topologically ordered and layer-sequential, so a *fused
//! kernel* is a contiguous id range (see [`crate::dataflow::fused`]).

pub mod resnet;

use crate::config::ELEM_BYTES;

/// Feature-map shape, channel-major (`c`, `h`, `w`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Shape {
    /// Channels.
    pub c: usize,
    /// Spatial height.
    pub h: usize,
    /// Spatial width.
    pub w: usize,
}

impl Shape {
    /// A `c × h × w` shape.
    pub fn new(c: usize, h: usize, w: usize) -> Self {
        Self { c, h, w }
    }

    /// Total element count (`c·h·w`).
    pub fn elems(&self) -> usize {
        self.c * self.h * self.w
    }

    /// Total size in bytes at the model's element width.
    pub fn bytes(&self) -> usize {
        self.elems() * ELEM_BYTES
    }
}

/// Pooling flavor.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PoolKind {
    /// Max pooling (window compare).
    Max,
    /// Average pooling (window accumulate + scale).
    Avg,
}

/// Layer operator. Spatial ops carry (k, stride, pad) window geometry.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Op {
    /// Network input placeholder.
    Input,
    /// Convolution with optional folded BatchNorm and ReLU
    /// (the paper's `CONV_BN` / `CONV_BN_RELU` execution flags).
    Conv {
        cout: usize,
        k: usize,
        stride: usize,
        pad: usize,
        bn: bool,
        relu: bool,
    },
    /// Spatial pooling (the paper's `POOL` flag).
    Pool {
        kind: PoolKind,
        k: usize,
        stride: usize,
        pad: usize,
    },
    /// Global average pool (spatial collapse to 1×1).
    GlobalAvgPool,
    /// Residual join with ReLU (the paper's `ADD_RELU` flag). Two inputs.
    AddRelu,
    /// Fully connected layer (1×1 spatial).
    Fc { cout: usize },
}

/// Node id within a [`Graph`].
pub type NodeId = usize;

/// One graph node: operator plus data-dependency edges.
#[derive(Debug, Clone)]
pub struct Node {
    /// Topological, layer-sequential id (position in [`Graph::nodes`]).
    pub id: NodeId,
    /// Human-readable layer name (e.g. `conv2_1a`).
    pub name: String,
    /// The layer operator.
    pub op: Op,
    /// Producer nodes (1 for most ops, 2 for AddRelu, 0 for Input).
    pub inputs: Vec<NodeId>,
    /// Inferred output shape.
    pub shape: Shape,
    // Cached at build time (derivable from inputs but hot in the mappers).
    pub(crate) cached_cin: usize,
    pub(crate) cached_in_elems: usize,
}

impl Node {
    /// Weight bytes this layer must stage (conv/fc kernels; BN folded).
    pub fn weight_bytes(&self) -> usize {
        match self.op {
            Op::Conv { cout, k, .. } => {
                // cin derives from the producer; stored at build time in
                // `weight_elems` via Graph::finish_node. Recomputed here
                // from the cached cin.
                self.cached_cin * cout * k * k * ELEM_BYTES
            }
            Op::Fc { cout } => self.cached_cin * cout * ELEM_BYTES,
            _ => 0,
        }
    }

    /// Multiply-accumulate count for the whole layer.
    pub fn macs(&self) -> usize {
        match self.op {
            Op::Conv { cout, k, .. } => {
                self.shape.h * self.shape.w * cout * self.cached_cin * k * k
            }
            Op::Fc { cout } => self.cached_cin * cout,
            _ => 0,
        }
    }

    /// Element-wise operation count (pool compares/adds, residual adds,
    /// BN+ReLU post-ops), used by the compute-latency and energy models.
    pub fn eltwise_ops(&self) -> usize {
        match self.op {
            Op::Conv { bn, relu, .. } => {
                let mut per_elem = 0;
                if bn {
                    per_elem += 2; // scale + shift (folded BN)
                }
                if relu {
                    per_elem += 1;
                }
                self.shape.elems() * per_elem
            }
            Op::Pool { k, .. } => self.shape.elems() * k * k,
            Op::GlobalAvgPool => self.cached_in_elems,
            Op::AddRelu => self.shape.elems() * 2, // add + relu
            Op::Fc { .. } | Op::Input => 0,
        }
    }

    /// Is this a layer PIMcores execute in the layer-by-layer dataflow
    /// (CONV/FC on PIMcores; POOL/ADD on the GBcore — Fig. 3(b))?
    pub fn is_mac_layer(&self) -> bool {
        matches!(self.op, Op::Conv { .. } | Op::Fc { .. })
    }

}

/// A CNN as an ordered DAG of nodes. Node 0 is always the [`Op::Input`].
#[derive(Debug, Clone)]
pub struct Graph {
    /// Network name (the workload label in reports).
    pub name: String,
    /// Nodes in topological id order (`nodes[i].id == i`).
    pub nodes: Vec<Node>,
}

impl Graph {
    /// Start a graph with an input of the given shape.
    pub fn new(name: &str, input: Shape) -> Self {
        let node = Node {
            id: 0,
            name: "input".to_string(),
            op: Op::Input,
            inputs: vec![],
            shape: input,
            cached_cin: 0,
            cached_in_elems: 0,
        };
        Self { name: name.to_string(), nodes: vec![node] }
    }

    fn infer_shape(&self, op: &Op, inputs: &[NodeId]) -> Shape {
        let in_shape = self.nodes[inputs[0]].shape;
        let spatial = |k: usize, s: usize, p: usize, d: usize| (d + 2 * p - k) / s + 1;
        match *op {
            Op::Input => in_shape,
            Op::Conv { cout, k, stride, pad, .. } => Shape::new(
                cout,
                spatial(k, stride, pad, in_shape.h),
                spatial(k, stride, pad, in_shape.w),
            ),
            Op::Pool { k, stride, pad, .. } => Shape::new(
                in_shape.c,
                spatial(k, stride, pad, in_shape.h),
                spatial(k, stride, pad, in_shape.w),
            ),
            Op::GlobalAvgPool => Shape::new(in_shape.c, 1, 1),
            Op::AddRelu => {
                let b = self.nodes[inputs[1]].shape;
                assert_eq!(in_shape, b, "AddRelu operand shapes must match");
                in_shape
            }
            Op::Fc { cout } => Shape::new(cout, 1, 1),
        }
    }

    /// Append a node; returns its id. Inputs must already exist (enforces
    /// topological id order, which the fused-kernel partitioner relies on).
    pub fn add(&mut self, name: &str, op: Op, inputs: Vec<NodeId>) -> NodeId {
        let id = self.nodes.len();
        for &i in &inputs {
            assert!(i < id, "node {name} input {i} not yet defined");
        }
        assert!(!inputs.is_empty(), "non-input node {name} needs inputs");
        let shape = self.infer_shape(&op, &inputs);
        let in0 = &self.nodes[inputs[0]];
        let node = Node {
            id,
            name: name.to_string(),
            op,
            cached_cin: in0.shape.c,
            cached_in_elems: in0.shape.elems(),
            inputs,
            shape,
        };
        self.nodes.push(node);
        id
    }

    /// All non-input layer nodes, in execution order.
    pub fn layers(&self) -> impl Iterator<Item = &Node> {
        self.nodes.iter().filter(|n| !matches!(n.op, Op::Input))
    }

    /// Number of layers by the paper's counting (element-wise fused).
    pub fn num_layers(&self) -> usize {
        self.nodes.len() - 1
    }

    /// Consumers of each node (reverse edges), for demand propagation.
    pub fn consumers(&self) -> Vec<Vec<NodeId>> {
        let mut cons = vec![Vec::new(); self.nodes.len()];
        for n in &self.nodes {
            for &i in &n.inputs {
                cons[i].push(n.id);
            }
        }
        cons
    }

    /// Total MACs across the network.
    pub fn total_macs(&self) -> usize {
        self.nodes.iter().map(|n| n.macs()).sum()
    }

    /// Total weight bytes across the network.
    pub fn total_weight_bytes(&self) -> usize {
        self.nodes.iter().map(|n| n.weight_bytes()).sum()
    }

    /// Truncate to the first `n` layers (plus input); consumers outside the
    /// prefix are dropped. Used for the `ResNet18_First8Layers` workload.
    pub fn prefix(&self, n: usize) -> Graph {
        assert!(n + 1 <= self.nodes.len(), "prefix longer than graph");
        let nodes = self.nodes[..=n].to_vec();
        Graph { name: format!("{}_first{}", self.name, n), nodes }
    }

    /// Structural sanity: ids consecutive, edges backwards, shapes positive.
    pub fn validate(&self) -> Result<(), String> {
        for (i, n) in self.nodes.iter().enumerate() {
            if n.id != i {
                return Err(format!("node {i} has id {}", n.id));
            }
            if n.shape.c == 0 || n.shape.h == 0 || n.shape.w == 0 {
                return Err(format!("node {} has empty shape", n.name));
            }
            for &p in &n.inputs {
                if p >= i {
                    return Err(format!("node {} has forward edge to {p}", n.name));
                }
            }
            match n.op {
                Op::AddRelu if n.inputs.len() != 2 => {
                    return Err(format!("AddRelu {} needs 2 inputs", n.name))
                }
                Op::Input if i != 0 => return Err("Input must be node 0".into()),
                _ => {}
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Graph {
        let mut g = Graph::new("tiny", Shape::new(3, 8, 8));
        let c0 = g.add(
            "conv0",
            Op::Conv { cout: 4, k: 3, stride: 1, pad: 1, bn: true, relu: true },
            vec![0],
        );
        let p = g.add(
            "pool",
            Op::Pool { kind: PoolKind::Max, k: 2, stride: 2, pad: 0 },
            vec![c0],
        );
        let c1 = g.add(
            "conv1",
            Op::Conv { cout: 4, k: 3, stride: 1, pad: 1, bn: true, relu: false },
            vec![p],
        );
        let a = g.add("add", Op::AddRelu, vec![c1, p]);
        g.add("fc", Op::Fc { cout: 10 }, vec![a]);
        g
    }

    #[test]
    fn shapes_infer_correctly() {
        let g = tiny();
        g.validate().unwrap();
        assert_eq!(g.nodes[1].shape, Shape::new(4, 8, 8)); // same-pad conv
        assert_eq!(g.nodes[2].shape, Shape::new(4, 4, 4)); // 2x2/2 pool
        assert_eq!(g.nodes[4].shape, Shape::new(4, 4, 4)); // add preserves
        assert_eq!(g.nodes[5].shape, Shape::new(10, 1, 1)); // fc
    }

    #[test]
    fn costs_are_sane() {
        let g = tiny();
        // conv0: 8*8*4*3*3*3 MACs.
        assert_eq!(g.nodes[1].macs(), 8 * 8 * 4 * 3 * 3 * 3);
        // conv0 weights: 3*4*3*3 elems * 2B.
        assert_eq!(g.nodes[1].weight_bytes(), 3 * 4 * 9 * 2);
        // pool does k*k compares per output elem.
        assert_eq!(g.nodes[2].eltwise_ops(), 4 * 4 * 4 * 4);
        // add_relu: 2 ops per elem.
        assert_eq!(g.nodes[4].eltwise_ops(), 4 * 4 * 4 * 2);
        assert!(g.total_macs() > 0);
    }

    #[test]
    fn prefix_truncates() {
        let g = tiny();
        let p = g.prefix(2);
        assert_eq!(p.num_layers(), 2);
        p.validate().unwrap();
    }

    #[test]
    fn consumers_are_reverse_edges() {
        let g = tiny();
        let cons = g.consumers();
        assert_eq!(cons[2], vec![3, 4]); // pool feeds conv1 and the residual
    }

    #[test]
    #[should_panic(expected = "not yet defined")]
    fn forward_edges_rejected() {
        let mut g = Graph::new("bad", Shape::new(1, 4, 4));
        g.add("c", Op::Conv { cout: 1, k: 1, stride: 1, pad: 0, bn: false, relu: false }, vec![5]);
    }
}
