//! Benchmark network builders.
//!
//! [`resnet18`] follows the paper's layer counting exactly (element-wise
//! fusions are one layer, residual ADD_RELU is one layer): the first 8
//! layers are the stem (CONV7×7, MAXPOOL) plus residual stage 1, each
//! later residual stage with a downsample is 7 layers — matching §V-A3's
//! fused-kernel boundaries (8 / 7 / 7 for Fused4).

use super::{Graph, Op, PoolKind, Shape};

/// Standard ImageNet-resolution ResNet18 (input 3×224×224).
pub fn resnet18() -> Graph {
    resnet18_at(224)
}

/// ResNet18 at a custom square input resolution (must be divisible by 32).
/// Smaller resolutions are used by fast tests and the e2e example.
pub fn resnet18_at(res: usize) -> Graph {
    assert!(res % 32 == 0, "resnet18 input resolution must be divisible by 32");
    let mut g = Graph::new(&format!("resnet18_{res}"), Shape::new(3, res, res));

    // Stem: L0 conv7x7/2 + L1 maxpool3x3/2  (2 layers)
    let conv = |cout, k, stride, pad, relu| Op::Conv { cout, k, stride, pad, bn: true, relu };
    let mut x = g.add("conv1", conv(64, 7, 2, 3, true), vec![0]);
    x = g.add(
        "maxpool",
        Op::Pool { kind: PoolKind::Max, k: 3, stride: 2, pad: 1 },
        vec![x],
    );

    // Residual stages. Stage 1 has identity skips (3 layers per block:
    // conv, conv, add). Stages 2-4 start with a strided block whose skip
    // is a 1x1 downsample conv (4 layers), then an identity block (3).
    let stage = |g: &mut Graph, x: usize, sidx: usize, cout: usize, stride: usize| {
        let mut inp = x;
        for b in 0..2 {
            let s = if b == 0 { stride } else { 1 };
            let pfx = format!("s{sidx}b{b}");
            let c1 = g.add(&format!("{pfx}.conv1"), conv(cout, 3, s, 1, true), vec![inp]);
            let c2 = g.add(&format!("{pfx}.conv2"), conv(cout, 3, 1, 1, false), vec![c1]);
            let skip = if s != 1 || g.nodes[inp].shape.c != cout {
                g.add(&format!("{pfx}.down"), conv(cout, 1, s, 0, false), vec![inp])
            } else {
                inp
            };
            inp = g.add(&format!("{pfx}.add"), Op::AddRelu, vec![c2, skip]);
        }
        inp
    };

    x = stage(&mut g, x, 1, 64, 1); //  +6 layers → L2..L7
    x = stage(&mut g, x, 2, 128, 2); // +7 layers → L8..L14
    x = stage(&mut g, x, 3, 256, 2); // +7 layers → L15..L21
    x = stage(&mut g, x, 4, 512, 2); // +7 layers → L22..L28

    x = g.add("gap", Op::GlobalAvgPool, vec![x]);
    g.add("fc", Op::Fc { cout: 1000 }, vec![x]);
    g
}

/// The first-8-layers workload of §V-A2 (`ResNet18_First8Layers`):
/// stem + residual stage 1, ending at the L7 ADD_RELU.
pub fn resnet18_first8() -> Graph {
    let mut g = resnet18().prefix(8);
    g.name = "resnet18_first8".into();
    g
}

/// The 8-layer example graph of Fig. 3(a): CONV, POOL, CONV, CONV, ADD,
/// CONV, CONV, ADD — used by the trace-walkthrough example and tests.
pub fn fig3_example() -> Graph {
    let mut g = Graph::new("fig3", Shape::new(16, 32, 32));
    let conv = |cout, k, stride, pad, relu| Op::Conv { cout, k, stride, pad, bn: true, relu };
    let l0 = g.add("L0.conv", conv(16, 3, 1, 1, true), vec![0]);
    let l1 = g.add("L1.pool", Op::Pool { kind: PoolKind::Max, k: 2, stride: 2, pad: 0 }, vec![l0]);
    let l2 = g.add("L2.conv", conv(16, 3, 1, 1, true), vec![l1]);
    let l3 = g.add("L3.conv", conv(16, 3, 1, 1, false), vec![l2]);
    let l4 = g.add("L4.add", Op::AddRelu, vec![l3, l1]);
    let l5 = g.add("L5.conv", conv(32, 3, 2, 1, true), vec![l4]);
    let l6 = g.add("L6.conv", conv(32, 3, 1, 1, false), vec![l5]);
    let l5s = g.add("L7a.down", conv(32, 1, 2, 0, false), vec![l4]);
    g.add("L7.add", Op::AddRelu, vec![l6, l5s]);
    g
}

/// A minimal two-conv graph matching the Fig. 1 motivating example.
pub fn fig1_example() -> Graph {
    let mut g = Graph::new("fig1", Shape::new(16, 16, 16));
    let conv = |cout| Op::Conv { cout, k: 3, stride: 1, pad: 1, bn: true, relu: true };
    let l0 = g.add("L0", conv(16), vec![0]);
    g.add("L1", conv(16), vec![l0]);
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resnet18_layer_count_matches_paper_counting() {
        let g = resnet18();
        g.validate().unwrap();
        // 2 stem + 6 + 7 + 7 + 7 residual + gap + fc = 31 layers.
        assert_eq!(g.num_layers(), 31);
    }

    #[test]
    fn resnet18_shapes_match_reference() {
        let g = resnet18();
        let by_name = |n: &str| g.nodes.iter().find(|x| x.name == n).unwrap().shape;
        assert_eq!(by_name("conv1"), Shape::new(64, 112, 112));
        assert_eq!(by_name("maxpool"), Shape::new(64, 56, 56));
        assert_eq!(by_name("s1b1.add"), Shape::new(64, 56, 56));
        assert_eq!(by_name("s2b1.add"), Shape::new(128, 28, 28));
        assert_eq!(by_name("s3b1.add"), Shape::new(256, 14, 14));
        assert_eq!(by_name("s4b1.add"), Shape::new(512, 7, 7));
        assert_eq!(by_name("fc"), Shape::new(1000, 1, 1));
    }

    #[test]
    fn resnet18_macs_match_published_flops() {
        // ResNet18 @224 is the commonly-quoted ~1.8 GMACs of conv+fc.
        let g = resnet18();
        let gmacs = g.total_macs() as f64 / 1e9;
        assert!((1.7..1.95).contains(&gmacs), "got {gmacs} GMACs");
    }

    #[test]
    fn first8_ends_at_stage1_add() {
        let g = resnet18_first8();
        g.validate().unwrap();
        assert_eq!(g.num_layers(), 8);
        assert_eq!(g.nodes.last().unwrap().name, "s1b1.add");
        // All first-8 fmaps live at 56x56 or larger (the "shallow layers
        // have large spatial dims" premise of the hybrid dataflow).
        for n in g.layers() {
            assert!(n.shape.h >= 56);
        }
    }

    #[test]
    fn fused_kernel_boundaries_are_8_7_7() {
        // §V-A3: first 8 layers, next 7, next 7 — check those ranges are
        // exactly the stem+stage1, stage2, stage3 of our builder.
        let g = resnet18();
        // nodes[0] is the input, so layer Li is nodes[i+1].
        assert_eq!(g.nodes[9].name, "s2b0.conv1"); // L8 starts stage 2
        assert_eq!(g.nodes[16].name, "s3b0.conv1"); // L15 starts stage 3
        assert_eq!(g.nodes[23].name, "s4b0.conv1"); // L22 starts stage 4
    }

    #[test]
    fn fig3_graph_is_eight_layers() {
        let g = fig3_example();
        g.validate().unwrap();
        assert_eq!(g.num_layers(), 9); // 8 logical + downsample branch conv
    }

    #[test]
    fn small_resolution_variant_validates() {
        let g = resnet18_at(32);
        g.validate().unwrap();
        assert_eq!(g.nodes.iter().find(|x| x.name == "s4b1.add").unwrap().shape, Shape::new(512, 1, 1));
    }
}
