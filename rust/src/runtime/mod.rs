//! PJRT runtime: load the JAX/Pallas AOT artifacts (`artifacts/*.hlo.txt`)
//! and execute them from Rust — the L3↔L2 bridge of the three-layer stack.
//!
//! Python runs only at build time (`make artifacts`); this module makes
//! the compiled computations callable from the coordinator's (host-side)
//! golden-model checks. Interchange is HLO **text**, not serialized
//! protos: jax ≥ 0.5 emits 64-bit instruction ids that the crate's
//! xla_extension 0.5.1 rejects, while the text parser reassigns ids
//! (see /opt/xla-example/README.md and python/compile/aot.py).

use anyhow::{anyhow, Context, Result};
use std::path::{Path, PathBuf};

/// A PJRT CPU client (one per process is plenty).
pub struct Runtime {
    client: xla::PjRtClient,
}

/// A compiled executable plus its source path (for error reporting).
pub struct LoadedModel {
    exe: xla::PjRtLoadedExecutable,
    pub path: PathBuf,
}

impl Runtime {
    /// Create the CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load and compile an HLO-text artifact.
    pub fn load_hlo(&self, path: impl AsRef<Path>) -> Result<LoadedModel> {
        let path = path.as_ref();
        if !path.exists() {
            return Err(anyhow!(
                "artifact {} not found — run `make artifacts` first",
                path.display()
            ));
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(LoadedModel { exe, path: path.to_path_buf() })
    }
}

impl LoadedModel {
    /// Execute with f32 inputs of the given shapes; returns the flattened
    /// f32 output(s). The AOT pipeline lowers with `return_tuple=True`,
    /// so results arrive as a tuple even for single outputs.
    pub fn run_f32(&self, inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, dims) in inputs {
            let expect: usize = dims.iter().product();
            if expect != data.len() {
                return Err(anyhow!(
                    "input length {} != shape {:?} product {}",
                    data.len(),
                    dims,
                    expect
                ));
            }
            let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
            literals.push(xla::Literal::vec1(data).reshape(&dims_i64)?);
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing {}", self.path.display()))?[0][0]
            .to_literal_sync()?;
        let outs = result.to_tuple()?;
        outs.into_iter()
            .map(|l| l.to_vec::<f32>().map_err(anyhow::Error::from))
            .collect()
    }
}

/// Repository-relative artifacts directory (honors `PIMFUSED_ARTIFACTS`).
pub fn artifacts_dir() -> PathBuf {
    if let Ok(d) = std::env::var("PIMFUSED_ARTIFACTS") {
        return PathBuf::from(d);
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn missing_artifact_is_a_clean_error() {
        let rt = Runtime::cpu().unwrap();
        let err = match rt.load_hlo("/nonexistent/model.hlo.txt") {
            Err(e) => e,
            Ok(_) => panic!("expected error for missing artifact"),
        };
        assert!(err.to_string().contains("make artifacts"));
    }

    #[test]
    fn shape_mismatch_is_rejected_before_execution() {
        // Uses the reference example's HLO if present; otherwise skipped
        // (the integration test in rust/tests covers the built artifacts).
        let probe = artifacts_dir().join("tile_conv_bn_relu.hlo.txt");
        if !probe.exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let rt = Runtime::cpu().unwrap();
        let m = rt.load_hlo(&probe).unwrap();
        let bad = m.run_f32(&[(&[0.0f32; 4], &[2usize, 3][..])]);
        assert!(bad.is_err());
    }

    #[test]
    fn cpu_client_reports_platform() {
        let rt = Runtime::cpu().unwrap();
        assert!(rt.platform().to_lowercase().contains("cpu") || !rt.platform().is_empty());
    }
}
