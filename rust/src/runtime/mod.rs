//! PJRT runtime: load the JAX/Pallas AOT artifacts (`artifacts/*.hlo.txt`)
//! and execute them from Rust — the L3↔L2 bridge of the three-layer stack.
//!
//! Python runs only at build time (`make artifacts`); this module makes
//! the compiled computations callable from the coordinator's (host-side)
//! golden-model checks. Interchange is HLO **text**, not serialized
//! protos: jax ≥ 0.5 emits 64-bit instruction ids that the crate's
//! xla_extension 0.5.1 rejects, while the text parser reassigns ids
//! (see /opt/xla-example/README.md and python/compile/aot.py).
//!
//! ## Feature gating
//!
//! The PJRT bindings (`xla` / xla_extension) are not part of the offline
//! crate set, so the real implementation is gated behind the `pjrt` cargo
//! feature. Without it this module compiles a **stub** with the same API:
//! [`Runtime::cpu`] succeeds (so artifact probes and error-path tests
//! run), but [`Runtime::load_hlo`] fails with a clear message. Callers
//! that want to degrade gracefully check [`Runtime::available`] first
//! (see `examples/e2e_resnet18.rs` and `tests/artifacts_roundtrip.rs`).

use std::path::PathBuf;

impl Runtime {
    /// Whether this build carries the PJRT-backed runtime (`pjrt` feature).
    pub const fn available() -> bool {
        cfg!(feature = "pjrt")
    }
}

#[cfg(feature = "pjrt")]
mod pjrt_impl {
    use anyhow::{anyhow, Context, Result};
    use std::path::{Path, PathBuf};

    /// A PJRT CPU client (one per process is plenty).
    pub struct Runtime {
        client: xla::PjRtClient,
    }

    /// A compiled executable plus its source path (for error reporting).
    pub struct LoadedModel {
        exe: xla::PjRtLoadedExecutable,
        pub path: PathBuf,
    }

    impl Runtime {
        /// Create the CPU PJRT client.
        pub fn cpu() -> Result<Self> {
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            Ok(Self { client })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load and compile an HLO-text artifact.
        pub fn load_hlo(&self, path: impl AsRef<Path>) -> Result<LoadedModel> {
            let path = path.as_ref();
            if !path.exists() {
                return Err(anyhow!(
                    "artifact {} not found — run `make artifacts` first",
                    path.display()
                ));
            }
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {}", path.display()))?;
            Ok(LoadedModel { exe, path: path.to_path_buf() })
        }
    }

    impl LoadedModel {
        /// Execute with f32 inputs of the given shapes; returns the flattened
        /// f32 output(s). The AOT pipeline lowers with `return_tuple=True`,
        /// so results arrive as a tuple even for single outputs.
        pub fn run_f32(&self, inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
            let mut literals = Vec::with_capacity(inputs.len());
            for (data, dims) in inputs {
                let expect: usize = dims.iter().product();
                if expect != data.len() {
                    return Err(anyhow!(
                        "input length {} != shape {:?} product {}",
                        data.len(),
                        dims,
                        expect
                    ));
                }
                let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
                literals.push(xla::Literal::vec1(data).reshape(&dims_i64)?);
            }
            let result = self
                .exe
                .execute::<xla::Literal>(&literals)
                .with_context(|| format!("executing {}", self.path.display()))?[0][0]
                .to_literal_sync()?;
            let outs = result.to_tuple()?;
            outs.into_iter()
                .map(|l| l.to_vec::<f32>().map_err(anyhow::Error::from))
                .collect()
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod stub {
    use anyhow::{anyhow, Result};
    use std::path::{Path, PathBuf};

    /// Stub runtime compiled when the `pjrt` feature is off.
    pub struct Runtime {
        _private: (),
    }

    /// Stub model handle; never successfully constructed without `pjrt`.
    pub struct LoadedModel {
        pub path: PathBuf,
    }

    impl Runtime {
        /// Succeeds so callers can probe artifacts and exercise the
        /// missing-artifact error path; actual loads fail cleanly.
        pub fn cpu() -> Result<Self> {
            Ok(Self { _private: () })
        }

        pub fn platform(&self) -> String {
            "stub (built without the `pjrt` feature)".to_string()
        }

        /// Keeps the missing-artifact diagnostics of the real runtime,
        /// then fails with the feature hint.
        pub fn load_hlo(&self, path: impl AsRef<Path>) -> Result<LoadedModel> {
            let path = path.as_ref();
            if !path.exists() {
                return Err(anyhow!(
                    "artifact {} not found — run `make artifacts` first",
                    path.display()
                ));
            }
            Err(anyhow!(
                "cannot load {}: pimfused was built without the `pjrt` feature \
                 (the offline crate set has no xla bindings)",
                path.display()
            ))
        }
    }

    impl LoadedModel {
        pub fn run_f32(&self, _inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
            Err(anyhow!(
                "cannot execute {}: pimfused was built without the `pjrt` feature",
                self.path.display()
            ))
        }
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt_impl::{LoadedModel, Runtime};
#[cfg(not(feature = "pjrt"))]
pub use stub::{LoadedModel, Runtime};

/// Repository-relative artifacts directory (honors `PIMFUSED_ARTIFACTS`).
pub fn artifacts_dir() -> PathBuf {
    if let Ok(d) = std::env::var("PIMFUSED_ARTIFACTS") {
        return PathBuf::from(d);
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn missing_artifact_is_a_clean_error() {
        let rt = Runtime::cpu().unwrap();
        let err = match rt.load_hlo("/nonexistent/model.hlo.txt") {
            Err(e) => e,
            Ok(_) => panic!("expected error for missing artifact"),
        };
        assert!(err.to_string().contains("make artifacts"));
    }

    #[test]
    fn shape_mismatch_is_rejected_before_execution() {
        // Uses the reference example's HLO if present; otherwise skipped
        // (the integration test in rust/tests covers the built artifacts).
        if !Runtime::available() {
            eprintln!("skipping: built without the `pjrt` feature");
            return;
        }
        let probe = artifacts_dir().join("tile_conv_bn_relu.hlo.txt");
        if !probe.exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let rt = Runtime::cpu().unwrap();
        let m = rt.load_hlo(&probe).unwrap();
        let bad = m.run_f32(&[(&[0.0f32; 4], &[2usize, 3][..])]);
        assert!(bad.is_err());
    }

    #[test]
    fn cpu_client_reports_platform() {
        let rt = Runtime::cpu().unwrap();
        assert!(rt.platform().to_lowercase().contains("cpu") || !rt.platform().is_empty());
    }

    #[test]
    fn stub_loads_fail_with_feature_hint_when_gated() {
        if Runtime::available() {
            return;
        }
        // An existing path (the crate manifest) must still be refused.
        let rt = Runtime::cpu().unwrap();
        let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("Cargo.toml");
        let err = rt.load_hlo(manifest).unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
    }
}
