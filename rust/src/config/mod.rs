//! System configuration: DRAM timing, architecture geometry, buffer sizes,
//! dataflow strategy, and the three named systems evaluated by the paper
//! (*AiM-like*, *Fused16*, *Fused4*; §V-A3).
//!
//! Buffer configurations use the paper's `GmK_Ln` notation — see
//! [`crate::util::size::parse_bufcfg`].

mod timing;

pub use timing::{ActLayout, DramTiming, MAX_ACT_SLOTS, TCK_NS};

use crate::util::size::{fmt_bufcfg, parse_bufcfg};

/// Bytes per tensor element. GDDR6-AiM computes in BF16 (§II, [4]).
pub const ELEM_BYTES: usize = 2;

/// Bytes moved by one DRAM column access (256-bit I/O per bank, as in
/// GDDR6-AiM's 16-wide BF16 MAC datapath).
pub const COL_BYTES: usize = 32;

/// DRAM row (page) size per bank. 2 KB is the GDDR6 norm and what the
/// row-activate amortization in the simulator assumes.
pub const ROW_BYTES: usize = 2048;

/// Which dataflow drives a workload's mapping (§IV).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dataflow {
    /// Conventional per-layer execution; PIMcores partition output channels.
    LayerByLayer,
    /// PIMfused hybrid: fused-layer kernels for shallow layers (spatial
    /// `tiles_x × tiles_y` tiling), layer-by-layer for the rest.
    PimFused {
        /// Spatial tile grid along the output `ox` dimension.
        tiles_x: usize,
        /// Spatial tile grid along the output `oy` dimension.
        tiles_y: usize,
    },
}

impl Dataflow {
    /// Whether this dataflow fuses shallow layers into spatial kernels.
    pub fn is_fused(&self) -> bool {
        matches!(self, Dataflow::PimFused { .. })
    }

    /// The spatial tile grid (`1×1` for layer-by-layer).
    pub fn tile_grid(&self) -> (usize, usize) {
        match self {
            Dataflow::LayerByLayer => (1, 1),
            Dataflow::PimFused { tiles_x, tiles_y } => (*tiles_x, *tiles_y),
        }
    }
}

/// Which simulation engine turns a command trace into cycles (DESIGN.md
/// §6). Both engines report identical [`crate::sim::ActionCounts`] (so
/// energy is engine-independent); they differ only in how command
/// durations compose into total cycles.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Engine {
    /// Commands execute strictly back-to-back ([`crate::sim::engine`]):
    /// total cycles are the sum of per-command durations. Fast, simple,
    /// and systematically conservative about overlap.
    Analytic,
    /// Discrete-event scheduling with per-resource busy-until timelines
    /// ([`crate::sim::event`]): independent commands overlap wherever
    /// their data dependencies and resource reservations allow, and the
    /// result carries a per-resource occupancy breakdown.
    Event,
}

/// One row per engine: (variant, display name, CLI aliases) — the same
/// table treatment as [`System`], so `name` and `parse` cannot drift.
const ENGINE_TABLE: &[(Engine, &str, &[&str])] = &[
    (Engine::Analytic, "analytic", &["serial"]),
    (Engine::Event, "event", &["evt"]),
];

impl Engine {
    /// Every engine, in `ENGINE_TABLE` order.
    pub const ALL: [Engine; 2] = [Engine::Analytic, Engine::Event];

    fn row(&self) -> &'static (Engine, &'static str, &'static [&'static str]) {
        ENGINE_TABLE
            .iter()
            .find(|row| row.0 == *self)
            .expect("every Engine variant must have an ENGINE_TABLE row")
    }

    /// Display name, e.g. `event`.
    pub fn name(&self) -> &'static str {
        self.row().1
    }

    /// Parse a CLI spelling: the display name or any alias,
    /// case-insensitively.
    pub fn parse(s: &str) -> Result<Self, String> {
        let t = s.trim().to_ascii_lowercase();
        for &(e, name, aliases) in ENGINE_TABLE {
            if t == name || aliases.contains(&t.as_str()) {
                return Ok(e);
            }
        }
        let names: Vec<&str> = ENGINE_TABLE.iter().map(|row| row.1).collect();
        Err(format!("unknown engine {s:?} ({})", names.join("|")))
    }
}

/// How a multi-channel configuration partitions a CNN across channels
/// (DESIGN.md §12). Irrelevant (and ignored) when
/// [`ArchConfig::channels`] is 1.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PartitionKind {
    /// Data-parallel by batch: each channel runs the whole network on its
    /// share of the batch. A single inference occupies one channel; the
    /// extra channels pay off as serving throughput, not single-shot
    /// latency.
    Data,
    /// Model-parallel by output channels (Cout): every layer's output
    /// channels shard across the DRAM channels, and each layer boundary
    /// all-gathers the sharded feature map over the host interconnect.
    Model,
}

/// One row per partition kind: (variant, display name, CLI aliases) —
/// the same table treatment as [`System`], so `name` and `parse` cannot
/// drift.
const PARTITION_TABLE: &[(PartitionKind, &str, &[&str])] = &[
    (PartitionKind::Data, "data", &["batch", "dp"]),
    (PartitionKind::Model, "model", &["cout", "mp"]),
];

impl PartitionKind {
    /// Every partition kind, in `PARTITION_TABLE` order.
    pub const ALL: [PartitionKind; 2] = [PartitionKind::Data, PartitionKind::Model];

    fn row(&self) -> &'static (PartitionKind, &'static str, &'static [&'static str]) {
        PARTITION_TABLE
            .iter()
            .find(|row| row.0 == *self)
            .expect("every PartitionKind variant must have a PARTITION_TABLE row")
    }

    /// Display name, e.g. `data`.
    pub fn name(&self) -> &'static str {
        self.row().1
    }

    /// Parse a CLI spelling: the display name or any alias,
    /// case-insensitively.
    pub fn parse(s: &str) -> Result<Self, String> {
        let t = s.trim().to_ascii_lowercase();
        for &(p, name, aliases) in PARTITION_TABLE {
            if t == name || aliases.contains(&t.as_str()) {
                return Ok(p);
            }
        }
        let names: Vec<&str> = PARTITION_TABLE.iter().map(|row| row.1).collect();
        Err(format!("unknown partition {s:?} ({})", names.join("|")))
    }
}

/// The three systems of §V-A3.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum System {
    /// GDDR6-AiM-like baseline: 16 × 1-bank PIMcores (MAC/BN/RELU only),
    /// one GBcore, layer-by-layer dataflow.
    AimLike,
    /// PIMfused with 16 × 1-bank PIMcores; fused layers tiled 4×4.
    Fused16,
    /// PIMfused with 4 × 4-bank PIMcores; fused layers tiled 2×2.
    Fused4,
}

/// One row per system: (variant, display name, CLI aliases). `name` and
/// `parse` are both driven from this table so they cannot drift.
const SYSTEM_TABLE: &[(System, &str, &[&str])] = &[
    (System::AimLike, "AiM-like", &["aim", "aimlike", "baseline"]),
    (System::Fused16, "Fused16", &[]),
    (System::Fused4, "Fused4", &[]),
];

impl System {
    /// Every named system, in the paper's order.
    pub const ALL: [System; 3] = [System::AimLike, System::Fused16, System::Fused4];

    fn row(&self) -> &'static (System, &'static str, &'static [&'static str]) {
        SYSTEM_TABLE
            .iter()
            .find(|row| row.0 == *self)
            .expect("every System variant must have a SYSTEM_TABLE row")
    }

    /// Display name, e.g. `AiM-like`.
    pub fn name(&self) -> &'static str {
        self.row().1
    }

    /// Parse a CLI spelling: the display name or any alias,
    /// case-insensitively.
    pub fn parse(s: &str) -> Result<Self, String> {
        let t = s.trim().to_ascii_lowercase();
        for &(sys, name, aliases) in SYSTEM_TABLE {
            if t == name.to_ascii_lowercase() || aliases.contains(&t.as_str()) {
                return Ok(sys);
            }
        }
        let names: Vec<String> =
            SYSTEM_TABLE.iter().map(|row| row.1.to_ascii_lowercase()).collect();
        Err(format!("unknown system {s:?} ({})", names.join("|")))
    }
}

/// Full architecture configuration for one simulated DRAM-PIM channel.
///
/// `Eq + Hash` (every field is an integer, bool, or enum) so configs can
/// key memo caches — the serving driver caches one service profile per
/// `(Workload, ArchConfig)`.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct ArchConfig {
    /// Which named system this configuration instantiates.
    pub system: System,
    /// Banks in the GDDR6 channel (16 in the paper).
    pub num_banks: usize,
    /// Banks served by one PIMcore (1 or 4 in the paper).
    pub banks_per_pimcore: usize,
    /// Channel-level global buffer size in bytes (GBUF, in the GBcore).
    pub gbuf_bytes: usize,
    /// Per-PIMcore local buffer size in bytes (LBUF; 0 = absent, as in AiM).
    pub lbuf_bytes: usize,
    /// BF16 MACs one PIMcore retires per memory cycle. Tied to the per-bank
    /// 256-bit read path: 16 MACs/bank-cycle, so 4-bank PIMcores are 4× wider.
    pub macs_per_cycle: usize,
    /// Elementwise ops (BN, ReLU, add, pool-compare) one PIMcore retires per
    /// cycle; matches the MAC datapath width.
    pub eltwise_per_cycle: usize,
    /// Throughput of the GBcore in elements/cycle for pool/add/relu work.
    pub gbcore_eltwise_per_cycle: usize,
    /// Dataflow strategy the mapper uses for this system.
    pub dataflow: Dataflow,
    /// DRAM timing parameters.
    pub timing: DramTiming,
    /// Simulation engine the coordinator runs this config through.
    pub engine: Engine,
    /// Model host I/O's physical bank residency: `HOST_WRITE`/`HOST_READ`
    /// stream through their destination banks (per-bank slices that
    /// conflict with PIM traffic, write recovery, ACT-window slots) in
    /// addition to occupying the off-chip interface. On by default —
    /// `false` reproduces the interface-only model (DESIGN.md §6.2).
    pub host_residency: bool,
    /// Let a sequential transfer's per-bank slices *slide* inside its
    /// bus/interface interval: the event scheduler places each bank's
    /// slice at that bank's earliest fit at-or-after its nominal stagger
    /// offset (modeling a controller that serves busy banks later in the
    /// burst order); when no sliding placement fits the window, the
    /// whole transfer slides forward minimally, degenerating to the
    /// rigid `i/N` stagger in the worst case. On by default — `false`
    /// pins every slice at its fixed offset (DESIGN.md §6.2).
    pub slice_pipelining: bool,
    /// Track each bank's open row across commands: a read that resumes the
    /// exact row its banks left open waives one `tRP + tRCD` re-open per
    /// command, and cross-bank transfers meter their ACT windows from the
    /// feature map's per-bank [`crate::trace::RowMap`] instead of an even
    /// split. Rows close on writes (auto-precharge policy) and after a
    /// refresh-scale gap ([`DramTiming::t_refi`]). On by default — `false`
    /// restores the every-command-reopens model and the legacy even ACT
    /// split (DESIGN.md §6.2).
    pub open_row_reuse: bool,
    /// Capture a per-command schedule timeline ([`crate::obs::ScheduleTrace`])
    /// when the event engine runs this config. Off by default: tracing-off
    /// runs take the ordinary non-recording scheduler path and their report
    /// output is byte-identical to a build without the observability layer
    /// (DESIGN.md §10).
    pub tracing: bool,
    /// Fault-injection knobs ([`crate::fault::FaultConfig`]): retired
    /// banks, dead PIMcores, and per-command transient errors. The
    /// all-zero default injects nothing and leaves every code path and
    /// serialized byte identical to a fault-free build (DESIGN.md §11).
    pub faults: crate::fault::FaultConfig,
    /// Independent DRAM-PIM channels (devices) the workload scales out
    /// over. Each channel is a full copy of this geometry with its own
    /// schedule; cross-channel traffic meters on a shared host
    /// interconnect ([`crate::sim::channel`]). The default 1 keeps every
    /// code path — and every serialized byte — identical to the
    /// single-channel model (DESIGN.md §12).
    pub channels: usize,
    /// How the CNN partitions across channels when `channels > 1`.
    pub partition: PartitionKind,
}

impl ArchConfig {
    /// Instantiate one of the paper's named systems with a buffer config.
    pub fn system(system: System, gbuf_bytes: usize, lbuf_bytes: usize) -> Self {
        let (banks_per_pimcore, dataflow) = match system {
            System::AimLike => (1, Dataflow::LayerByLayer),
            System::Fused16 => (1, Dataflow::PimFused { tiles_x: 4, tiles_y: 4 }),
            System::Fused4 => (4, Dataflow::PimFused { tiles_x: 2, tiles_y: 2 }),
        };
        let num_banks = 16;
        Self {
            system,
            num_banks,
            banks_per_pimcore,
            gbuf_bytes,
            lbuf_bytes,
            macs_per_cycle: 16 * banks_per_pimcore,
            eltwise_per_cycle: 16 * banks_per_pimcore,
            gbcore_eltwise_per_cycle: 16,
            dataflow,
            timing: DramTiming::gddr6(),
            engine: Engine::Analytic,
            host_residency: true,
            slice_pipelining: true,
            open_row_reuse: true,
            tracing: false,
            faults: crate::fault::FaultConfig::default(),
            channels: 1,
            partition: PartitionKind::Data,
        }
    }

    /// Builder-style engine selection: `ArchConfig::system(..).with_engine(e)`.
    pub fn with_engine(mut self, engine: Engine) -> Self {
        self.engine = engine;
        self
    }

    /// Builder-style host-residency selection (see the field docs);
    /// `with_host_residency(false)` restores the interface-only host model.
    pub fn with_host_residency(mut self, on: bool) -> Self {
        self.host_residency = on;
        self
    }

    /// Builder-style slice-pipelining selection (see the field docs);
    /// `with_slice_pipelining(false)` pins every per-bank slice at its
    /// rigid stagger offset for A/B comparison.
    pub fn with_slice_pipelining(mut self, on: bool) -> Self {
        self.slice_pipelining = on;
        self
    }

    /// Builder-style open-row selection (see the field docs);
    /// `with_open_row_reuse(false)` makes every command re-pay its row
    /// opens and restores the even cross-bank ACT split for A/B
    /// comparison.
    pub fn with_open_row_reuse(mut self, on: bool) -> Self {
        self.open_row_reuse = on;
        self
    }

    /// Builder-style schedule-trace capture (see the field docs);
    /// `with_tracing(true)` makes event-engine runs carry a
    /// [`crate::obs::ScheduleTrace`] on their report.
    pub fn with_tracing(mut self, on: bool) -> Self {
        self.tracing = on;
        self
    }

    /// Builder-style fault injection (see the field docs);
    /// `with_faults(FaultConfig::default())` restores the fault-free
    /// model.
    pub fn with_faults(mut self, faults: crate::fault::FaultConfig) -> Self {
        self.faults = faults;
        self
    }

    /// Builder-style channel-count selection (see the field docs);
    /// `with_channels(1)` restores the single-channel model.
    pub fn with_channels(mut self, channels: usize) -> Self {
        self.channels = channels;
        self
    }

    /// Builder-style partition selection for multi-channel configs (see
    /// the field docs); ignored while `channels == 1`.
    pub fn with_partition(mut self, partition: PartitionKind) -> Self {
        self.partition = partition;
        self
    }

    /// The paper's baseline: AiM-like with GBUF = 2 KB, LBUF = 0 (§V-A3).
    pub fn baseline() -> Self {
        Self::system(System::AimLike, 2 * 1024, 0)
    }

    /// Number of PIMcores in the channel.
    pub fn num_pimcores(&self) -> usize {
        self.num_banks / self.banks_per_pimcore
    }

    /// Paper notation, e.g. `Fused4/G32K_L256`. Multi-channel configs
    /// append the channel axis (`Fused4/G32K_L256/c4-model`);
    /// single-channel labels are byte-identical to the pre-axis form.
    pub fn label(&self) -> String {
        let base =
            format!("{}/{}", self.system.name(), fmt_bufcfg(self.gbuf_bytes, self.lbuf_bytes));
        if self.channels > 1 {
            format!("{base}/c{}-{}", self.channels, self.partition.name())
        } else {
            base
        }
    }

    /// Parse `"fused4:G32K_L256"` into a config.
    pub fn parse(spec: &str) -> Result<Self, String> {
        let (sys, buf) = spec
            .split_once(':')
            .ok_or_else(|| format!("config spec {spec:?} must be <system>:<GmK_Ln>"))?;
        let system = System::parse(sys)?;
        let (g, l) = parse_bufcfg(buf)?;
        Ok(Self::system(system, g, l))
    }

    /// Sanity-check internal consistency; the coordinator calls this before
    /// every run so misconfigurations fail loudly rather than skewing PPA.
    pub fn validate(&self) -> Result<(), String> {
        if self.num_banks == 0 || self.banks_per_pimcore == 0 {
            return Err("bank counts must be non-zero".into());
        }
        if self.num_banks % self.banks_per_pimcore != 0 {
            return Err(format!(
                "banks_per_pimcore {} must divide num_banks {}",
                self.banks_per_pimcore, self.num_banks
            ));
        }
        if self.macs_per_cycle == 0 || self.eltwise_per_cycle == 0 {
            return Err("compute throughputs must be non-zero".into());
        }
        if self.dataflow.is_fused() {
            let (tx, ty) = self.dataflow.tile_grid();
            if tx * ty != self.num_pimcores() {
                return Err(format!(
                    "fused tile grid {}x{} must equal the PIMcore count {}",
                    tx,
                    ty,
                    self.num_pimcores()
                ));
            }
        }
        if self.channels == 0 {
            return Err("channels must be at least 1".into());
        }
        if self.channels > crate::sim::channel::MAX_CHANNELS {
            return Err(format!(
                "channels {} exceeds the supported maximum {}",
                self.channels,
                crate::sim::channel::MAX_CHANNELS
            ));
        }
        self.faults.validate(self.num_banks, self.banks_per_pimcore, self.channels)?;
        self.timing.validate()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_match_paper() {
        let b = ArchConfig::baseline();
        assert_eq!(b.num_banks, 16);
        assert_eq!(b.num_pimcores(), 16);
        assert_eq!(b.gbuf_bytes, 2048);
        assert_eq!(b.lbuf_bytes, 0);
        assert_eq!(b.dataflow, Dataflow::LayerByLayer);

        let f16 = ArchConfig::system(System::Fused16, 2048, 0);
        assert_eq!(f16.num_pimcores(), 16);
        assert_eq!(f16.dataflow.tile_grid(), (4, 4));

        let f4 = ArchConfig::system(System::Fused4, 2048, 0);
        assert_eq!(f4.num_pimcores(), 4);
        assert_eq!(f4.dataflow.tile_grid(), (2, 2));
        // 4-bank PIMcores have 4x the MAC width (one 256-bit path per bank).
        assert_eq!(f4.macs_per_cycle, 64);
    }

    #[test]
    fn presets_validate() {
        for sys in System::ALL {
            ArchConfig::system(sys, 32 * 1024, 256).validate().unwrap();
        }
    }

    #[test]
    fn label_and_parse_roundtrip() {
        let c = ArchConfig::system(System::Fused4, 32 * 1024, 256);
        assert_eq!(c.label(), "Fused4/G32K_L256");
        let p = ArchConfig::parse("fused4:G32K_L256").unwrap();
        assert_eq!(p, c);
        assert!(ArchConfig::parse("nope:G2K_L0").is_err());
        assert!(ArchConfig::parse("fused4").is_err());
    }

    #[test]
    fn system_table_drives_name_and_parse() {
        assert_eq!(SYSTEM_TABLE.len(), System::ALL.len());
        for (row, sys) in SYSTEM_TABLE.iter().zip(System::ALL) {
            assert_eq!(row.0, sys, "SYSTEM_TABLE and ALL must agree on order");
        }
        for sys in System::ALL {
            assert_eq!(System::parse(sys.name()).unwrap(), sys);
            assert_eq!(System::parse(&sys.name().to_ascii_uppercase()).unwrap(), sys);
        }
        assert_eq!(System::parse("aim").unwrap(), System::AimLike);
        assert_eq!(System::parse("baseline").unwrap(), System::AimLike);
        assert_eq!(System::parse("Fused4").unwrap(), System::Fused4);
        assert!(System::parse("nope").is_err());
    }

    #[test]
    fn engine_table_drives_name_and_parse() {
        assert_eq!(ENGINE_TABLE.len(), Engine::ALL.len());
        for (row, e) in ENGINE_TABLE.iter().zip(Engine::ALL) {
            assert_eq!(row.0, e, "ENGINE_TABLE and ALL must agree on order");
        }
        for e in Engine::ALL {
            assert_eq!(Engine::parse(e.name()).unwrap(), e);
            assert_eq!(Engine::parse(&e.name().to_ascii_uppercase()).unwrap(), e);
        }
        assert_eq!(Engine::parse("evt").unwrap(), Engine::Event);
        assert_eq!(Engine::parse("serial").unwrap(), Engine::Analytic);
        assert!(Engine::parse("nope").is_err());
    }

    #[test]
    fn engine_defaults_to_analytic() {
        for sys in System::ALL {
            assert_eq!(ArchConfig::system(sys, 2048, 0).engine, Engine::Analytic);
        }
        let c = ArchConfig::baseline().with_engine(Engine::Event);
        assert_eq!(c.engine, Engine::Event);
        c.validate().unwrap();
    }

    #[test]
    fn host_residency_defaults_on() {
        for sys in System::ALL {
            assert!(ArchConfig::system(sys, 2048, 0).host_residency);
        }
        let c = ArchConfig::baseline().with_host_residency(false);
        assert!(!c.host_residency);
        c.validate().unwrap();
    }

    #[test]
    fn slice_pipelining_defaults_on() {
        for sys in System::ALL {
            assert!(ArchConfig::system(sys, 2048, 0).slice_pipelining);
        }
        let c = ArchConfig::baseline().with_slice_pipelining(false);
        assert!(!c.slice_pipelining);
        c.validate().unwrap();
    }

    #[test]
    fn open_row_reuse_defaults_on() {
        for sys in System::ALL {
            assert!(ArchConfig::system(sys, 2048, 0).open_row_reuse);
        }
        let c = ArchConfig::baseline().with_open_row_reuse(false);
        assert!(!c.open_row_reuse);
        c.validate().unwrap();
    }

    #[test]
    fn tracing_defaults_off() {
        for sys in System::ALL {
            assert!(!ArchConfig::system(sys, 2048, 0).tracing);
        }
        let c = ArchConfig::baseline().with_tracing(true);
        assert!(c.tracing);
        c.validate().unwrap();
    }

    #[test]
    fn faults_default_to_none() {
        use crate::fault::FaultConfig;
        for sys in System::ALL {
            assert!(ArchConfig::system(sys, 2048, 0).faults.is_none());
        }
        let fc = FaultConfig { seed: 7, retired_banks: 2, ..Default::default() };
        let c = ArchConfig::baseline().with_faults(fc);
        assert_eq!(c.faults, fc);
        c.validate().unwrap();
    }

    #[test]
    fn fault_validation_is_wired_into_config_validate() {
        use crate::fault::FaultConfig;
        // Too many retired banks for the channel.
        let c = ArchConfig::baseline()
            .with_faults(FaultConfig { retired_banks: 16, ..Default::default() });
        assert!(c.validate().is_err());
        // All cores dead.
        let c = ArchConfig::baseline()
            .with_faults(FaultConfig { dead_cores: 16, ..Default::default() });
        assert!(c.validate().is_err());
        // Probability above 1.
        let c = ArchConfig::baseline()
            .with_faults(FaultConfig { transient_ppm: 1_000_001, ..Default::default() });
        assert!(c.validate().is_err());
        // A 4-bank-fan-in system tolerates at most 12 retired banks.
        let good = ArchConfig::system(System::Fused4, 2048, 0)
            .with_faults(FaultConfig { retired_banks: 12, ..Default::default() });
        good.validate().unwrap();
        let bad = ArchConfig::system(System::Fused4, 2048, 0)
            .with_faults(FaultConfig { retired_banks: 13, ..Default::default() });
        assert!(bad.validate().is_err());
    }

    #[test]
    fn partition_table_drives_name_and_parse() {
        assert_eq!(PARTITION_TABLE.len(), PartitionKind::ALL.len());
        for (row, p) in PARTITION_TABLE.iter().zip(PartitionKind::ALL) {
            assert_eq!(row.0, p, "PARTITION_TABLE and ALL must agree on order");
        }
        for p in PartitionKind::ALL {
            assert_eq!(PartitionKind::parse(p.name()).unwrap(), p);
            assert_eq!(PartitionKind::parse(&p.name().to_ascii_uppercase()).unwrap(), p);
        }
        assert_eq!(PartitionKind::parse("batch").unwrap(), PartitionKind::Data);
        assert_eq!(PartitionKind::parse("cout").unwrap(), PartitionKind::Model);
        assert!(PartitionKind::parse("nope").is_err());
    }

    #[test]
    fn channels_default_to_one() {
        for sys in System::ALL {
            let c = ArchConfig::system(sys, 2048, 0);
            assert_eq!(c.channels, 1);
            assert_eq!(c.partition, PartitionKind::Data);
        }
        let c = ArchConfig::baseline().with_channels(4).with_partition(PartitionKind::Model);
        assert_eq!(c.channels, 4);
        assert_eq!(c.partition, PartitionKind::Model);
        c.validate().unwrap();
    }

    #[test]
    fn channel_labels_extend_only_above_one() {
        let c = ArchConfig::system(System::Fused4, 32 * 1024, 256);
        assert_eq!(c.label(), "Fused4/G32K_L256");
        assert_eq!(c.clone().with_channels(1).label(), "Fused4/G32K_L256");
        assert_eq!(c.clone().with_channels(4).label(), "Fused4/G32K_L256/c4-data");
        assert_eq!(
            c.with_channels(2).with_partition(PartitionKind::Model).label(),
            "Fused4/G32K_L256/c2-model"
        );
    }

    #[test]
    fn bad_channel_counts_rejected() {
        assert!(ArchConfig::baseline().with_channels(0).validate().is_err());
        assert!(ArchConfig::baseline()
            .with_channels(crate::sim::channel::MAX_CHANNELS + 1)
            .validate()
            .is_err());
        ArchConfig::baseline().with_channels(crate::sim::channel::MAX_CHANNELS).validate().unwrap();
    }

    #[test]
    fn bad_tile_grid_rejected() {
        let mut c = ArchConfig::system(System::Fused16, 2048, 0);
        c.dataflow = Dataflow::PimFused { tiles_x: 3, tiles_y: 3 };
        assert!(c.validate().is_err());
    }
}
