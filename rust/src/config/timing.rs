//! GDDR6 timing parameters, in memory-clock cycles.
//!
//! Values follow public GDDR6 datasheet norms (16 Gb/s/pin parts, tCK ≈
//! 0.75 ns command clock) and are the knobs the Ramulator2-like engine in
//! [`crate::sim`] enforces. The paper reports *relative* memory cycles, so
//! what matters is that the ratios between row activation, column access,
//! and PIM command overheads are realistic — these are.

/// DRAM timing constraints (cycles).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DramTiming {
    /// ACT to internal RD/WR delay.
    pub t_rcd: u64,
    /// PRE to ACT delay (row precharge).
    pub t_rp: u64,
    /// ACT to PRE minimum (row restore).
    pub t_ras: u64,
    /// Column-to-column delay — one column burst every tCCD.
    pub t_ccd: u64,
    /// RD to first data (CAS latency). Pipeline fill, paid once per burst
    /// train, not per column.
    pub t_cl: u64,
    /// Write recovery.
    pub t_wr: u64,
    /// ACT-to-ACT across banks (rank-level).
    pub t_rrd: u64,
    /// Four-activate window.
    pub t_faw: u64,
    /// Cycles for one PIM command decode/issue from the memory controller
    /// (custom commands in Table I ride the normal command bus).
    pub t_cmd: u64,
    /// Extra cycles to route one column of data over the channel-internal
    /// bus between a bank and the GBUF (the shared-bus hop of §I).
    pub t_bus_hop: u64,
}

impl DramTiming {
    /// GDDR6 norms at the command clock (see module docs).
    pub fn gddr6() -> Self {
        Self {
            t_rcd: 24,
            t_rp: 24,
            t_ras: 52,
            t_ccd: 2,
            t_cl: 24,
            t_wr: 24,
            t_rrd: 6,
            t_faw: 32,
            t_cmd: 1,
            t_bus_hop: 2,
        }
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.t_ccd == 0 || self.t_rcd == 0 || self.t_rp == 0 {
            return Err("core DRAM timings must be non-zero".into());
        }
        if self.t_ras < self.t_rcd {
            return Err("tRAS must cover tRCD".into());
        }
        if self.t_faw < self.t_rrd {
            return Err("tFAW must be at least tRRD".into());
        }
        Ok(())
    }

    /// Cycles to stream `cols` column accesses from one already-open row:
    /// pipeline fill (tCL) then one burst per tCCD.
    pub fn burst_cycles(&self, cols: u64) -> u64 {
        if cols == 0 {
            0
        } else {
            self.t_cl + cols * self.t_ccd
        }
    }

    /// Cycles to open a row (PRE of the old one + ACT + tRCD). The engine
    /// charges this whenever a transfer crosses a row boundary.
    pub fn row_open_cycles(&self) -> u64 {
        self.t_rp + self.t_rcd
    }

    /// Minimum spacing the activation-window constraints allow between
    /// row activations within one bank group: ACTs may not issue closer
    /// together than `tRRD`, nor faster than four per `tFAW` window. The
    /// event engine's scheduler meters each bank group's activations at
    /// this rate (DESIGN.md §6.2).
    pub fn act_slot_cycles(&self) -> u64 {
        self.t_rrd.max(self.t_faw.div_ceil(4))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gddr6_defaults_validate() {
        DramTiming::gddr6().validate().unwrap();
    }

    #[test]
    fn burst_cycles_scale_linearly_after_fill() {
        let t = DramTiming::gddr6();
        assert_eq!(t.burst_cycles(0), 0);
        let one = t.burst_cycles(1);
        let ten = t.burst_cycles(10);
        assert_eq!(ten - one, 9 * t.t_ccd);
    }

    #[test]
    fn act_slot_is_the_binding_window() {
        // GDDR6 norms: tFAW/4 = 8 dominates tRRD = 6.
        assert_eq!(DramTiming::gddr6().act_slot_cycles(), 8);
        // A tRRD-bound part: spacing is tRRD.
        let mut t = DramTiming::gddr6();
        t.t_rrd = 12;
        t.t_faw = 16;
        assert_eq!(t.act_slot_cycles(), 12);
    }

    #[test]
    fn invalid_timing_rejected() {
        let mut t = DramTiming::gddr6();
        t.t_ccd = 0;
        assert!(t.validate().is_err());
        let mut t2 = DramTiming::gddr6();
        t2.t_ras = 1;
        assert!(t2.validate().is_err());
    }
}
