//! GDDR6 timing parameters, in memory-clock cycles.
//!
//! Values follow public GDDR6 datasheet norms (16 Gb/s/pin parts, tCK ≈
//! 0.75 ns command clock) and are the knobs the Ramulator2-like engine in
//! [`crate::sim`] enforces. The paper reports *relative* memory cycles, so
//! what matters is that the ratios between row activation, column access,
//! and PIM command overheads are realistic — these are.

/// Command-clock period in nanoseconds (tCK at the 16 Gb/s/pin GDDR6
/// operating point the module docs assume). The serving simulator uses
/// it to convert wall-clock offered load (req/s) into memory cycles.
pub const TCK_NS: f64 = 0.75;

/// DRAM timing constraints (cycles).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct DramTiming {
    /// ACT to internal RD/WR delay.
    pub t_rcd: u64,
    /// PRE to ACT delay (row precharge).
    pub t_rp: u64,
    /// ACT to PRE minimum (row restore).
    pub t_ras: u64,
    /// Column-to-column delay — one column burst every tCCD.
    pub t_ccd: u64,
    /// RD to first data (CAS latency). Pipeline fill, paid once per burst
    /// train, not per column.
    pub t_cl: u64,
    /// Write recovery.
    pub t_wr: u64,
    /// ACT-to-ACT across banks (rank-level).
    pub t_rrd: u64,
    /// Four-activate window.
    pub t_faw: u64,
    /// Cycles for one PIM command decode/issue from the memory controller
    /// (custom commands in Table I ride the normal command bus).
    pub t_cmd: u64,
    /// Extra cycles to route one column of data over the channel-internal
    /// bus between a bank and the GBUF (the shared-bus hop of §I).
    pub t_bus_hop: u64,
    /// Refresh-interval scale (cycles): how long a bank's open row stays
    /// reusable. The open-row tracker (DESIGN.md §6.2) treats a row left
    /// open longer than this as closed — an all-bank refresh will have
    /// precharged it — so commands arriving after a refresh-scale gap
    /// re-pay the full row open.
    pub t_refi: u64,
}

impl DramTiming {
    /// GDDR6 norms at the command clock (see module docs).
    pub fn gddr6() -> Self {
        Self {
            t_rcd: 24,
            t_rp: 24,
            t_ras: 52,
            t_ccd: 2,
            t_cl: 24,
            t_wr: 24,
            t_rrd: 6,
            t_faw: 32,
            t_cmd: 1,
            t_bus_hop: 2,
            t_refi: 5200, // ≈ 3.9 µs at tCK = 0.75 ns
        }
    }

    /// The command-clock frequency in Hz implied by [`TCK_NS`]
    /// (≈ 1.33 GHz). All cycle counts in this crate are in this clock;
    /// the serving simulator divides by it to report req/s.
    pub fn clock_hz(&self) -> f64 {
        1e9 / TCK_NS
    }

    /// Sanity-check the timing constants' internal consistency.
    pub fn validate(&self) -> Result<(), String> {
        if self.t_ccd == 0 || self.t_rcd == 0 || self.t_rp == 0 {
            return Err("core DRAM timings must be non-zero".into());
        }
        if self.t_ras < self.t_rcd {
            return Err("tRAS must cover tRCD".into());
        }
        if self.t_faw < self.t_rrd {
            return Err("tFAW must be at least tRRD".into());
        }
        Ok(())
    }

    /// Cycles to stream `cols` column accesses from one already-open row:
    /// pipeline fill (tCL) then one burst per tCCD.
    pub fn burst_cycles(&self, cols: u64) -> u64 {
        if cols == 0 {
            0
        } else {
            self.t_cl + cols * self.t_ccd
        }
    }

    /// Cycles to open a row (PRE of the old one + ACT + tRCD). The engines
    /// charge this on every row *miss*; with [`open_row_reuse`] on, a read
    /// that resumes the exact row its banks left open waives one of these
    /// per command (DESIGN.md §6.2).
    ///
    /// [`open_row_reuse`]: crate::config::ArchConfig::open_row_reuse
    pub fn row_open_cycles(&self) -> u64 {
        self.t_rp + self.t_rcd
    }

    /// Minimum spacing the activation-window constraints allow between
    /// row activations within one bank group: ACTs may not issue closer
    /// together than `tRRD`, nor faster than four per `tFAW` window. The
    /// event engine's scheduler meters each bank group's activations at
    /// this rate (DESIGN.md §6.2).
    pub fn act_slot_cycles(&self) -> u64 {
        self.t_rrd.max(self.t_faw.div_ceil(4))
    }

    /// How a command's `acts` row activations occupy its bank group's
    /// tFAW/tRRD window timeline during a data phase of `data_span`
    /// cycles (DESIGN.md §6.2).
    ///
    /// When the group is ACT-saturated (`acts * slot ≥ data_span`) the
    /// activations cannot spread: the layout degrades to one bulk window
    /// capped at the span (which preserves the event-engine invariant
    /// that a command's schedule charge never exceeds its analytic
    /// charge). Otherwise the activations interleave: up to
    /// [`MAX_ACT_SLOTS`] windows, each covering an equal share of the
    /// activations at [`DramTiming::act_slot_cycles`] per ACT, spread
    /// evenly across the data span — so a second dense-activation
    /// command can place its windows in the gaps instead of queueing
    /// behind one front-loaded bulk reservation.
    pub fn act_layout(&self, acts: u64, data_span: u64) -> ActLayout {
        let slot = self.act_slot_cycles();
        if acts == 0 || data_span == 0 || slot == 0 {
            return ActLayout { slots: 0, span: 0, stride: 0 };
        }
        let window = acts * slot;
        if acts == 1 || window >= data_span {
            return ActLayout { slots: 1, span: window.min(data_span), stride: 0 };
        }
        // Spread: rounding acts up into equal slots can overshoot the
        // span; shrink the slot count until the windows fit disjointly.
        let mut slots = acts.min(MAX_ACT_SLOTS);
        let mut span = acts.div_ceil(slots) * slot;
        while slots > 1 && slots * span > data_span {
            slots -= 1;
            span = acts.div_ceil(slots) * slot;
        }
        if slots == 1 {
            return ActLayout { slots: 1, span: span.min(data_span), stride: 0 };
        }
        ActLayout { slots, span, stride: (data_span - span) / (slots - 1) }
    }
}

/// Cap on the discrete ACT windows one command reserves per bank group:
/// bounds the scheduler's per-command reservation-request size (a dense
/// stream can touch thousands of rows) while still letting commands
/// interleave at sub-window granularity.
pub const MAX_ACT_SLOTS: u64 = 8;

/// One bank group's ACT-window reservations for a single command, as
/// computed by [`DramTiming::act_layout`]: `slots` windows of `span`
/// cycles each, the k-th starting `k * stride` cycles into the command's
/// data phase. Invariants: `stride ≥ span` whenever `slots > 1` (windows
/// are disjoint) and the last window ends within the data span.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ActLayout {
    /// Number of disjoint ACT windows to reserve.
    pub slots: u64,
    /// Cycles each window spans.
    pub span: u64,
    /// Cycles between consecutive window starts (0 for a single slot).
    pub stride: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gddr6_defaults_validate() {
        DramTiming::gddr6().validate().unwrap();
    }

    #[test]
    fn burst_cycles_scale_linearly_after_fill() {
        let t = DramTiming::gddr6();
        assert_eq!(t.burst_cycles(0), 0);
        let one = t.burst_cycles(1);
        let ten = t.burst_cycles(10);
        assert_eq!(ten - one, 9 * t.t_ccd);
    }

    #[test]
    fn act_slot_is_the_binding_window() {
        // GDDR6 norms: tFAW/4 = 8 dominates tRRD = 6.
        assert_eq!(DramTiming::gddr6().act_slot_cycles(), 8);
        // A tRRD-bound part: spacing is tRRD.
        let mut t = DramTiming::gddr6();
        t.t_rrd = 12;
        t.t_faw = 16;
        assert_eq!(t.act_slot_cycles(), 12);
    }

    #[test]
    fn act_layout_spreads_when_unsaturated() {
        let t = DramTiming::gddr6(); // slot = 8
        // 4 ACTs over a 224-cycle span: one window per ACT, evenly spread.
        let l = t.act_layout(4, 224);
        assert_eq!((l.slots, l.span), (4, 8));
        assert_eq!(l.stride, (224 - 8) / 3);
        assert!(l.stride >= l.span, "windows must be disjoint");
        assert!((l.slots - 1) * l.stride + l.span <= 224, "last window within the span");
    }

    #[test]
    fn act_layout_saturated_degrades_to_capped_bulk_window() {
        let t = DramTiming::gddr6();
        // 100 ACTs * 8 = 800 ≥ span 300: one bulk window capped at span.
        assert_eq!(t.act_layout(100, 300), ActLayout { slots: 1, span: 300, stride: 0 });
        // Exactly saturated counts as saturated (no room to interleave).
        assert_eq!(t.act_layout(10, 80), ActLayout { slots: 1, span: 80, stride: 0 });
        // A single ACT is one slot at the front.
        assert_eq!(t.act_layout(1, 300), ActLayout { slots: 1, span: 8, stride: 0 });
    }

    #[test]
    fn act_layout_caps_slot_count_and_chunks_acts() {
        let t = DramTiming::gddr6();
        // 20 ACTs over a wide span: MAX_ACT_SLOTS windows of ceil(20/8)=3
        // ACTs each (24 cycles), still disjoint and within the span.
        let l = t.act_layout(20, 10_000);
        assert_eq!((l.slots, l.span), (MAX_ACT_SLOTS, 3 * 8));
        assert!(l.stride >= l.span);
        assert!((l.slots - 1) * l.stride + l.span <= 10_000);
        // Reserved cycles never undercut one slot per ACT.
        assert!(l.slots * l.span >= 20 * 8);
    }

    #[test]
    fn act_layout_shrinks_slots_when_rounding_overshoots() {
        let t = DramTiming::gddr6();
        // 9 ACTs, span 80: window 72 < 80 so unsaturated, but 8 slots of
        // ceil(9/8)=2 ACTs (16 cycles) would need 128 > 80 — the layout
        // must shrink the slot count until the windows fit (5 × 16 = 80).
        let l = t.act_layout(9, 80);
        assert_eq!((l.slots, l.span, l.stride), (5, 16, 16));
        assert!(l.slots * l.span <= 80, "windows must fit the span: {l:?}");
        assert!(l.slots == 1 || l.stride >= l.span, "{l:?}");
    }

    #[test]
    fn act_layout_zero_cases() {
        let t = DramTiming::gddr6();
        assert_eq!(t.act_layout(0, 100).slots, 0);
        assert_eq!(t.act_layout(5, 0).slots, 0);
    }

    #[test]
    fn invalid_timing_rejected() {
        let mut t = DramTiming::gddr6();
        t.t_ccd = 0;
        assert!(t.validate().is_err());
        let mut t2 = DramTiming::gddr6();
        t2.t_ras = 1;
        assert!(t2.validate().is_err());
    }
}
