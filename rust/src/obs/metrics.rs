//! [`MetricsRegistry`]: a process-local counter/gauge/series registry.
//!
//! The coordinator's [`crate::coordinator::Session`], the sweep runner's
//! [`crate::coordinator::SweepResults`], and the serving simulator all
//! expose a `publish_metrics(&MetricsRegistry)` hook that folds their
//! counters into one registry; [`MetricsRegistry::to_json`] snapshots
//! everything as deterministic, hand-rolled JSON (schema
//! `pimfused-metrics-v1`).
//!
//! [`BenchRecord`] wraps a registry with a bench name and mode so
//! `bench_sched` / `bench_serve` emit their `guardrail:` numbers in one
//! machine-readable schema (`pimfused-bench-v1`, `--json <path>`).

use crate::coordinator::serialize::{json_escape, json_f64};
use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::Mutex;

#[derive(Default)]
struct Inner {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    series: BTreeMap<String, Vec<f64>>,
}

/// Thread-safe registry of named counters (monotonic `u64`), gauges
/// (point-in-time `f64`) and series (append-only `f64` samples).
///
/// Interior-mutable behind one mutex, so a `&MetricsRegistry` can be
/// shared with sweep worker threads the same way a
/// [`crate::coordinator::Session`] is.
#[derive(Default)]
pub struct MetricsRegistry {
    inner: Mutex<Inner>,
}

impl MetricsRegistry {
    /// An empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// Add 1 to counter `name` (creating it at 0).
    pub fn inc(&self, name: &str) {
        self.add(name, 1);
    }

    /// Add `v` to counter `name` (creating it at 0).
    pub fn add(&self, name: &str, v: u64) {
        *self.inner.lock().unwrap().counters.entry(name.to_string()).or_default() += v;
    }

    /// Set gauge `name` to `v` (last write wins).
    pub fn gauge(&self, name: &str, v: f64) {
        self.inner.lock().unwrap().gauges.insert(name.to_string(), v);
    }

    /// Append `v` to series `name`.
    pub fn push_sample(&self, name: &str, v: f64) {
        self.inner.lock().unwrap().series.entry(name.to_string()).or_default().push(v);
    }

    /// Current value of counter `name` (0 if never written).
    pub fn counter(&self, name: &str) -> u64 {
        self.inner.lock().unwrap().counters.get(name).copied().unwrap_or(0)
    }

    /// Current value of gauge `name`, if set.
    pub fn gauge_value(&self, name: &str) -> Option<f64> {
        self.inner.lock().unwrap().gauges.get(name).copied()
    }

    /// Number of samples in series `name` (0 if never written).
    pub fn series_len(&self, name: &str) -> usize {
        self.inner.lock().unwrap().series.get(name).map_or(0, Vec::len)
    }

    /// True when nothing has been published yet.
    pub fn is_empty(&self) -> bool {
        let m = self.inner.lock().unwrap();
        m.counters.is_empty() && m.gauges.is_empty() && m.series.is_empty()
    }

    /// The `"counters": {...}, "gauges": {...}, "series": {...}` body
    /// shared by the metrics and bench schemas (keys sorted, values in
    /// insertion order for series).
    fn body(&self, out: &mut String) {
        let m = self.inner.lock().unwrap();
        out.push_str("  \"counters\": {");
        for (i, (k, v)) in m.counters.iter().enumerate() {
            let sep = if i == 0 { "" } else { "," };
            let _ = write!(out, "{sep}\n    \"{}\": {v}", json_escape(k));
        }
        out.push_str(if m.counters.is_empty() { "},\n" } else { "\n  },\n" });
        out.push_str("  \"gauges\": {");
        for (i, (k, v)) in m.gauges.iter().enumerate() {
            let sep = if i == 0 { "" } else { "," };
            let _ = write!(out, "{sep}\n    \"{}\": {}", json_escape(k), json_f64(*v));
        }
        out.push_str(if m.gauges.is_empty() { "},\n" } else { "\n  },\n" });
        out.push_str("  \"series\": {");
        for (i, (k, vs)) in m.series.iter().enumerate() {
            let sep = if i == 0 { "" } else { "," };
            let vals: Vec<String> = vs.iter().map(|v| json_f64(*v)).collect();
            let _ = write!(out, "{sep}\n    \"{}\": [{}]", json_escape(k), vals.join(", "));
        }
        out.push_str(if m.series.is_empty() { "}\n" } else { "\n  }\n" });
    }

    /// Snapshot the registry as deterministic JSON (schema
    /// `pimfused-metrics-v1`).
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\n  \"schema\": \"pimfused-metrics-v1\",\n");
        self.body(&mut out);
        out.push_str("}\n");
        out
    }
}

/// One benchmark emission: a named registry snapshot in the unified
/// `pimfused-bench-v1` schema. `bench_sched` and `bench_serve` publish
/// their `guardrail:` numbers here and write it with
/// [`BenchRecord::write`] when invoked with `--json <path>`.
pub struct BenchRecord {
    /// Benchmark name (`bench_sched`, `bench_serve`).
    pub bench: String,
    /// Run mode (`full`, `smoke`).
    pub mode: String,
    /// The numbers: counters/gauges/series, bench-defined names.
    pub metrics: MetricsRegistry,
}

impl BenchRecord {
    /// An empty record for bench `bench` running in `mode`.
    pub fn new(bench: &str, mode: &str) -> Self {
        BenchRecord {
            bench: bench.to_string(),
            mode: mode.to_string(),
            metrics: MetricsRegistry::new(),
        }
    }

    /// Serialize as deterministic JSON (schema `pimfused-bench-v1`).
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\n  \"schema\": \"pimfused-bench-v1\",\n");
        let _ = writeln!(out, "  \"bench\": \"{}\",", json_escape(&self.bench));
        let _ = writeln!(out, "  \"mode\": \"{}\",", json_escape(&self.mode));
        self.metrics.body(&mut out);
        out.push_str("}\n");
        out
    }

    /// Write [`BenchRecord::to_json`] to `path`.
    pub fn write(&self, path: &std::path::Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_json())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_gauges_series_accumulate() {
        let m = MetricsRegistry::new();
        assert!(m.is_empty());
        m.inc("a");
        m.add("a", 2);
        m.gauge("g", 1.5);
        m.gauge("g", 2.5);
        m.push_sample("s", 1.0);
        m.push_sample("s", 2.0);
        assert_eq!(m.counter("a"), 3);
        assert_eq!(m.counter("missing"), 0);
        assert_eq!(m.gauge_value("g"), Some(2.5), "gauges overwrite");
        assert_eq!(m.gauge_value("missing"), None);
        assert_eq!(m.series_len("s"), 2);
        assert!(!m.is_empty());
    }

    #[test]
    fn empty_snapshot_is_stable() {
        let m = MetricsRegistry::new();
        assert_eq!(
            m.to_json(),
            "{\n  \"schema\": \"pimfused-metrics-v1\",\n  \"counters\": {},\n  \"gauges\": {},\n  \"series\": {}\n}\n"
        );
    }

    #[test]
    fn snapshot_sorts_keys_and_is_valid_shape() {
        let m = MetricsRegistry::new();
        m.inc("z.count");
        m.inc("a.count");
        m.gauge("mid", 0.5);
        m.push_sample("q", 3.0);
        let json = m.to_json();
        let a = json.find("a.count").unwrap();
        let z = json.find("z.count").unwrap();
        assert!(a < z, "keys must serialize sorted");
        assert!(json.contains("\"mid\": 0.5"));
        assert!(json.contains("\"q\": [3]"));
        assert_eq!(json.matches('{').count(), json.matches('}').count());
    }

    #[test]
    fn bench_record_carries_name_and_mode() {
        let b = BenchRecord::new("bench_sched", "smoke");
        b.metrics.gauge("worst_ratio", 1.25);
        let json = b.to_json();
        assert!(json.starts_with("{\n  \"schema\": \"pimfused-bench-v1\",\n"));
        assert!(json.contains("\"bench\": \"bench_sched\""));
        assert!(json.contains("\"mode\": \"smoke\""));
        assert!(json.contains("\"worst_ratio\": 1.25"));
    }
}
