//! Observability: schedule tracing, timeline exports, phase profiles,
//! and the metrics registry (DESIGN.md §10).
//!
//! The event scheduler already re-runs every schedule in a recording
//! mode for its legality audit; this module turns those records into a
//! first-class profiling surface:
//!
//! * [`ScheduleTrace`] — the committed per-command timeline (one
//!   [`TraceSpan`] per resource reservation), certified against the
//!   run's [`crate::sim::ResourceOccupancy`] by [`ScheduleTrace::verify`].
//! * [`chrome_trace_json`] / [`trace_csv`] — exporters ([`TraceFormat`]
//!   selects one from the CLI's `--trace-out` flag).
//! * [`PhaseProfile`] — per-layer × per-phase cycle attribution plus the
//!   busiest-command ranking (`pimfused profile`'s default output).
//! * [`MetricsRegistry`] / [`BenchRecord`] — the counter/gauge/series
//!   registry sessions, sweeps, the serving simulator and the guardrail
//!   benches publish into.
//!
//! Capture is **opt-in**: set [`crate::config::ArchConfig::tracing`]
//! (or call [`ScheduleTrace::capture`] directly, as below) and the
//! trace rides on [`crate::ppa::PpaReport::schedule`]. With tracing off
//! the scheduler takes its ordinary non-recording path and report
//! output is byte-identical to a build without this module.
//!
//! ```
//! use pimfused::config::ArchConfig;
//! use pimfused::obs::{chrome_trace_json, PhaseProfile, ScheduleTrace};
//! use pimfused::trace::{CmdKind, RowMap, Trace};
//!
//! // A two-command schedule: move a tile up to the GBUF, then back.
//! let mut t = Trace::default();
//! t.push_dep(1, CmdKind::Bk2Gbuf { bytes: 2048, rows: RowMap::EMPTY }, &[], Some(1));
//! t.push_dep(2, CmdKind::Gbuf2Bk { bytes: 1024, rows: RowMap::EMPTY }, &[1], Some(2));
//!
//! let cfg = ArchConfig::baseline();
//! let (report, trace) = ScheduleTrace::capture(&cfg, &t);
//! trace.verify(&report.occupancy).unwrap();
//!
//! let json = chrome_trace_json(&trace);
//! assert!(json.contains("\"traceEvents\""));
//!
//! let profile = PhaseProfile::from_trace(&trace);
//! assert_eq!(profile.makespan, report.occupancy.makespan);
//! assert_eq!(profile.layers.len(), 2);
//! ```

mod export;
mod metrics;
mod phase;
mod schedule;

pub use export::{chrome_trace_json, trace_csv, TraceFormat, TRACE_CSV_HEADER};
pub use metrics::{BenchRecord, MetricsRegistry};
pub use phase::{LayerPhase, PhaseProfile, TopCmd};
pub use schedule::{CmdMeta, ResourceClass, ResourceId, ScheduleTrace, TraceSpan};
