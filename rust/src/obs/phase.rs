//! Per-layer phase attribution: where each layer's cycles went.
//!
//! [`PhaseProfile::from_trace`] folds a [`ScheduleTrace`] into one row
//! per CNN graph node (layer), splitting the layer's busy cycles into
//! phases by a fixed attribution rule (DESIGN.md §10):
//!
//! * spans on the **command bus** count as `cmdbus` (issue slots),
//! * spans on an **ACT group** count as `act` (reserved tFAW/tRRD
//!   window cycles — reserved, not busy),
//! * every other span counts by its command's Table-I mnemonic:
//!   `PIMcore_CMP` / `GBcore_CMP` → `compute` (including their operand
//!   streams on banks and the bus), `PIM_BK2LBUF` / `PIM_LBUF2BK` →
//!   `near_bank`, `PIM_BK2GBUF` / `PIM_GBUF2BK` → `cross_bank`,
//!   `HOST_WRITE` / `HOST_READ` → `host`.
//!
//! `stall` is the layer's wall-clock window minus the union of its busy
//! intervals — cycles in which *no* resource was doing the layer's work
//! (dependency or contention waits). Phases sum resource-cycles and can
//! exceed the window (parallel resources); `stall` is wall-clock.

use crate::obs::schedule::{ResourceClass, ScheduleTrace};
use crate::trace::NodeId;
use crate::util::table::Table;
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Busy-cycle breakdown of one CNN graph node (layer).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct LayerPhase {
    /// The graph node id.
    pub node: NodeId,
    /// Commands the trace scheduled for this node.
    pub cmds: usize,
    /// First issue-slot cycle of the node's commands.
    pub start: u64,
    /// Last completion cycle of the node's commands.
    pub end: u64,
    /// Busy cycles of `PIMcore_CMP` / `GBcore_CMP` spans (compute plus
    /// their operand streams).
    pub compute: u64,
    /// Busy cycles of `PIM_BK2LBUF` / `PIM_LBUF2BK` spans.
    pub near_bank: u64,
    /// Busy cycles of `PIM_BK2GBUF` / `PIM_GBUF2BK` spans.
    pub cross_bank: u64,
    /// Busy cycles of `CH_XCHG` spans on the shared host interconnect —
    /// cross-channel shard gathers of a multi-channel run
    /// ([`crate::sim::channel`]). Always 0 for single-channel schedules.
    pub cross_channel: u64,
    /// Busy cycles of `HOST_WRITE` / `HOST_READ` spans.
    pub host: u64,
    /// Reserved ACT-window cycles (tFAW/tRRD throttling slots).
    pub act_window: u64,
    /// Command-bus issue-slot cycles.
    pub cmdbus: u64,
    /// Wall-clock cycles of the layer's window in which none of its
    /// spans were busy.
    pub stall: u64,
}

/// One entry of the bottleneck ranking: a command and its total tallied
/// busy cycles across all resources.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TopCmd {
    /// Command index in the source trace.
    pub cmd: usize,
    /// Graph node the command belongs to.
    pub node: NodeId,
    /// Table-I mnemonic.
    pub kind: &'static str,
    /// Total busy cycles the command's spans tallied.
    pub busy: u64,
    /// Issue-slot start cycle.
    pub start: u64,
    /// Completion cycle.
    pub done: u64,
}

/// Per-layer × per-phase cycle attribution of one schedule, plus the
/// commands ranked by total busy cycles. Built by
/// [`PhaseProfile::from_trace`]; the table the `pimfused profile`
/// subcommand prints is [`PhaseProfile::render`].
#[derive(Debug, Clone, PartialEq)]
pub struct PhaseProfile {
    /// Total schedule length in cycles.
    pub makespan: u64,
    /// One row per graph node, ascending node id.
    pub layers: Vec<LayerPhase>,
    /// Every command, descending total busy cycles (ties by index).
    pub top: Vec<TopCmd>,
}

impl PhaseProfile {
    /// Attribute a captured schedule trace (see the module docs for the
    /// attribution rule).
    pub fn from_trace(t: &ScheduleTrace) -> PhaseProfile {
        let mut layers: BTreeMap<NodeId, LayerPhase> = BTreeMap::new();
        let mut windows: BTreeMap<NodeId, Vec<(u64, u64)>> = BTreeMap::new();
        let mut per_cmd: Vec<u64> = vec![0; t.cmds.len()];
        for c in &t.cmds {
            let e = layers.entry(c.node).or_insert(LayerPhase {
                node: c.node,
                start: c.start,
                end: c.done,
                ..LayerPhase::default()
            });
            e.cmds += 1;
            e.start = e.start.min(c.start);
            e.end = e.end.max(c.done);
        }
        for sp in &t.spans {
            let e = layers.get_mut(&sp.node).expect("span without a command");
            match sp.res.class() {
                ResourceClass::CmdBus => e.cmdbus += sp.busy,
                ResourceClass::Act => e.act_window += sp.end - sp.start,
                ResourceClass::Interconnect => e.cross_channel += sp.busy,
                _ => match sp.kind {
                    "PIMcore_CMP" | "GBcore_CMP" => e.compute += sp.busy,
                    "PIM_BK2LBUF" | "PIM_LBUF2BK" => e.near_bank += sp.busy,
                    "PIM_BK2GBUF" | "PIM_GBUF2BK" => e.cross_bank += sp.busy,
                    _ => e.host += sp.busy,
                },
            }
            per_cmd[sp.cmd] += sp.busy;
            if sp.busy > 0 {
                windows.entry(sp.node).or_default().push((sp.start, sp.start + sp.busy));
            }
        }
        for (node, iv) in windows.iter_mut() {
            let e = layers.get_mut(node).unwrap();
            e.stall = (e.end - e.start).saturating_sub(union_len(iv));
        }
        // A layer with no busy span at all stalls for its whole window.
        for e in layers.values_mut() {
            if !windows.contains_key(&e.node) {
                e.stall = e.end - e.start;
            }
        }
        let mut top: Vec<TopCmd> = t
            .cmds
            .iter()
            .enumerate()
            .map(|(i, c)| TopCmd {
                cmd: i,
                node: c.node,
                kind: c.kind,
                busy: per_cmd[i],
                start: c.start,
                done: c.done,
            })
            .collect();
        top.sort_by(|a, b| b.busy.cmp(&a.busy).then(a.cmd.cmp(&b.cmd)));
        PhaseProfile { makespan: t.makespan, layers: layers.into_values().collect(), top }
    }

    /// The `k` busiest commands (fewer if the trace is shorter).
    pub fn top_k(&self, k: usize) -> &[TopCmd] {
        &self.top[..k.min(self.top.len())]
    }

    /// Render the per-layer breakdown table plus the top-`top` bottleneck
    /// commands — the default `pimfused profile` output.
    ///
    /// The `cross-chan` column appears only when some layer actually has
    /// cross-channel cycles, so single-channel profiles stay
    /// byte-identical to a build without the channels axis.
    pub fn render(&self, top: usize) -> String {
        let xc = self.layers.iter().any(|l| l.cross_channel > 0);
        let mut hdr = vec!["node", "cmds", "window", "compute", "near-bank", "cross-bank"];
        if xc {
            hdr.push("cross-chan");
        }
        hdr.extend(["host", "act", "cmdbus", "stall"]);
        let mut t = Table::new(hdr);
        let phase_row = |head: String, window: String, p: &LayerPhase| -> Vec<String> {
            let mut cells = vec![
                head,
                p.cmds.to_string(),
                window,
                p.compute.to_string(),
                p.near_bank.to_string(),
                p.cross_bank.to_string(),
            ];
            if xc {
                cells.push(p.cross_channel.to_string());
            }
            cells.extend([
                p.host.to_string(),
                p.act_window.to_string(),
                p.cmdbus.to_string(),
                p.stall.to_string(),
            ]);
            cells
        };
        let mut total = LayerPhase::default();
        for l in &self.layers {
            t.row(phase_row(l.node.to_string(), format!("{}..{}", l.start, l.end), l));
            total.cmds += l.cmds;
            total.compute += l.compute;
            total.near_bank += l.near_bank;
            total.cross_bank += l.cross_bank;
            total.cross_channel += l.cross_channel;
            total.host += l.host;
            total.act_window += l.act_window;
            total.cmdbus += l.cmdbus;
            total.stall += l.stall;
        }
        t.row(phase_row("total".to_string(), format!("0..{}", self.makespan), &total));
        let mut out = t.render();
        let _ = writeln!(out, "top {} commands by busy cycles:", top.min(self.top.len()));
        let mut tt = Table::new(vec!["cmd", "node", "kind", "busy_cycles", "start", "done"]);
        for c in self.top_k(top) {
            tt.row(vec![
                c.cmd.to_string(),
                c.node.to_string(),
                c.kind.to_string(),
                c.busy.to_string(),
                c.start.to_string(),
                c.done.to_string(),
            ]);
        }
        out += &tt.render();
        out
    }
}

/// Total length of the union of (possibly overlapping) intervals.
/// Sorts in place.
fn union_len(iv: &mut [(u64, u64)]) -> u64 {
    iv.sort_unstable();
    let mut total = 0u64;
    let mut cur: Option<(u64, u64)> = None;
    for &(s, e) in iv.iter() {
        match cur {
            Some((cs, ce)) if s <= ce => cur = Some((cs, ce.max(e))),
            Some((cs, ce)) => {
                total += ce - cs;
                cur = Some((s, e));
            }
            None => cur = Some((s, e)),
        }
    }
    if let Some((cs, ce)) = cur {
        total += ce - cs;
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn union_len_merges_overlaps() {
        assert_eq!(union_len(&mut []), 0);
        assert_eq!(union_len(&mut [(0, 10)]), 10);
        assert_eq!(union_len(&mut [(0, 10), (5, 15)]), 15);
        assert_eq!(union_len(&mut [(20, 30), (0, 10)]), 20);
        assert_eq!(union_len(&mut [(0, 10), (10, 20)]), 20, "touching intervals merge");
        assert_eq!(union_len(&mut [(0, 30), (5, 10)]), 30, "contained interval adds nothing");
    }
}
