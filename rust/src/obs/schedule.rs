//! [`ScheduleTrace`]: the event scheduler's committed timeline as data.
//!
//! The event engine's recording mode ([`crate::sim::event`]) remembers
//! every resource reservation each command's issue committed. This module
//! promotes those records into a stable, self-describing trace — one
//! [`TraceSpan`] per reservation, resolved from the scheduler's internal
//! resource-arena indices to named [`ResourceId`]s — that the exporters
//! ([`crate::obs::chrome_trace_json`] / [`crate::obs::trace_csv`]) and
//! the phase profiler ([`crate::obs::PhaseProfile`]) consume.
//!
//! A trace is **certified**: [`ScheduleTrace::verify`] cross-checks it
//! against the run's [`ResourceOccupancy`] — spans must be disjoint per
//! resource, lie within the makespan, and their per-resource busy sums
//! must equal the occupancy tallies *exactly* (no tolerance). The
//! property test in `tests/obs_api.rs` runs this over random
//! config × workload points.

use crate::config::ArchConfig;
use crate::sim::event::resources::{self, Resv};
use crate::sim::{EventReport, ResourceOccupancy};
use crate::trace::{NodeId, Trace, MAX_CORES};
use std::collections::BTreeMap;

/// The resource classes of the event scheduler's arena, in export order.
///
/// Each class becomes one pseudo-process in the Chrome-trace export
/// (pid = [`ResourceClass::pid`]); resources within a class (banks,
/// PIMcores, ACT groups) become its threads.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum ResourceClass {
    /// The contended command bus (one issue slot per command).
    CmdBus,
    /// The shared internal bus / GBUF port.
    Bus,
    /// The GBcore compute datapath.
    Gbcore,
    /// The off-chip host interface.
    Host,
    /// A tFAW/tRRD activation-window bank group.
    Act,
    /// A PIMcore datapath.
    Core,
    /// A DRAM bank.
    Bank,
    /// The shared host interconnect that meters cross-channel exchanges
    /// (multi-channel runs only; see [`crate::sim::channel`]).
    Interconnect,
}

/// One row per class: `(class, export name)`. Single source of truth for
/// [`ResourceClass::name`] and the drift test below.
const CLASS_TABLE: &[(ResourceClass, &str)] = &[
    (ResourceClass::CmdBus, "cmdbus"),
    (ResourceClass::Bus, "bus"),
    (ResourceClass::Gbcore, "gbcore"),
    (ResourceClass::Host, "host"),
    (ResourceClass::Act, "act"),
    (ResourceClass::Core, "core"),
    (ResourceClass::Bank, "bank"),
    (ResourceClass::Interconnect, "interconnect"),
];

impl ResourceClass {
    /// Every class, in export order.
    pub const ALL: [ResourceClass; 8] = [
        ResourceClass::CmdBus,
        ResourceClass::Bus,
        ResourceClass::Gbcore,
        ResourceClass::Host,
        ResourceClass::Act,
        ResourceClass::Core,
        ResourceClass::Bank,
        ResourceClass::Interconnect,
    ];

    fn row(&self) -> &'static (ResourceClass, &'static str) {
        &CLASS_TABLE[CLASS_TABLE.iter().position(|(c, _)| c == self).unwrap()]
    }

    /// Stable export name (`cmdbus`, `bus`, ..., `bank`) — the `cat`
    /// field and process name in the Chrome-trace export, the `resource`
    /// column in the CSV export.
    pub fn name(&self) -> &'static str {
        self.row().1
    }

    /// Chrome-trace pseudo-process id for this class (1-based, stable).
    pub fn pid(&self) -> u64 {
        CLASS_TABLE.iter().position(|(c, _)| c == self).unwrap() as u64 + 1
    }
}

/// One named resource of the schedule: a class plus, for the per-bank /
/// per-core / per-group classes, an index within the class.
///
/// Ordering is class-major then index — the order resources appear in
/// the exports.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum ResourceId {
    /// The command bus.
    CmdBus,
    /// The shared internal bus / GBUF port.
    Bus,
    /// The GBcore compute datapath.
    Gbcore,
    /// The host interface.
    Host,
    /// Activation-window slots of bank group `.0`.
    ActGroup(usize),
    /// PIMcore `.0`'s datapath.
    Core(usize),
    /// Bank `.0`.
    Bank(usize),
    /// The shared host interconnect (multi-channel runs only).
    Interconnect,
}

impl ResourceId {
    /// The class this resource belongs to.
    pub fn class(&self) -> ResourceClass {
        match self {
            ResourceId::CmdBus => ResourceClass::CmdBus,
            ResourceId::Bus => ResourceClass::Bus,
            ResourceId::Gbcore => ResourceClass::Gbcore,
            ResourceId::Host => ResourceClass::Host,
            ResourceId::ActGroup(_) => ResourceClass::Act,
            ResourceId::Core(_) => ResourceClass::Core,
            ResourceId::Bank(_) => ResourceClass::Bank,
            ResourceId::Interconnect => ResourceClass::Interconnect,
        }
    }

    /// Index within the class (0 for the singleton classes) — the
    /// Chrome-trace thread id and the CSV `res_index` column.
    pub fn index(&self) -> usize {
        match self {
            ResourceId::ActGroup(i) | ResourceId::Core(i) | ResourceId::Bank(i) => *i,
            _ => 0,
        }
    }

    /// Human-readable label, e.g. `bus`, `bank3`, `core0`, `act1`.
    pub fn label(&self) -> String {
        match self {
            ResourceId::ActGroup(_) | ResourceId::Core(_) | ResourceId::Bank(_) => {
                format!("{}{}", self.class().name(), self.index())
            }
            _ => self.class().name().to_string(),
        }
    }
}

/// Map a scheduler resource-arena index to its public [`ResourceId`].
fn res_id(res: usize) -> ResourceId {
    match res {
        resources::CMDBUS => ResourceId::CmdBus,
        resources::BUS => ResourceId::Bus,
        resources::GBCORE => ResourceId::Gbcore,
        resources::HOST => ResourceId::Host,
        _ => {
            if let Some(g) = resources::res_act_group(res) {
                ResourceId::ActGroup(g)
            } else if let Some(c) = resources::res_core(res) {
                ResourceId::Core(c)
            } else if let Some(b) = resources::res_bank(res) {
                ResourceId::Bank(b)
            } else {
                unreachable!("unknown resource-arena index {res}")
            }
        }
    }
}

/// One committed resource reservation of one command.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceSpan {
    /// Index of the owning command in the source [`Trace`].
    pub cmd: usize,
    /// CNN graph node (layer) the command belongs to.
    pub node: NodeId,
    /// Table-I mnemonic of the owning command
    /// ([`crate::trace::CmdKind::mnemonic`]).
    pub kind: &'static str,
    /// The reserved resource.
    pub res: ResourceId,
    /// Reservation start cycle (inclusive).
    pub start: u64,
    /// Reservation end cycle (exclusive); `end - start` includes any
    /// non-busy tail (write recovery, ACT-window slots).
    pub end: u64,
    /// Cycles of the reservation tallied as busy work in
    /// [`ResourceOccupancy`] — 0 for reserved-but-idle spans (ACT-window
    /// slots, the GBcore's bus-blocking hold, write-recovery tails are
    /// excluded from `busy` but included in `end`).
    pub busy: u64,
    /// How many cycles slice pipelining slid this span past its rigid
    /// stagger offset (0 for non-slice spans and rigid placements).
    pub slid: u64,
}

/// Per-command metadata: the issue/completion window of one command.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CmdMeta {
    /// CNN graph node (layer) the command belongs to.
    pub node: NodeId,
    /// Table-I mnemonic of the command.
    pub kind: &'static str,
    /// Issue-slot start cycle.
    pub start: u64,
    /// Completion cycle (write recovery included).
    pub done: u64,
}

/// The event scheduler's committed timeline for one trace: every
/// reservation of every command, in trace order, plus per-command
/// issue/completion windows.
///
/// Captured by [`ScheduleTrace::capture`] (or by any
/// [`crate::coordinator::Session`] run whose config has
/// [`crate::config::ArchConfig::tracing`] on — the trace then rides on
/// [`crate::ppa::PpaReport::schedule`]).
#[derive(Debug, Clone, PartialEq)]
pub struct ScheduleTrace {
    /// Total schedule length in cycles.
    pub makespan: u64,
    /// PIMcores in the channel.
    pub num_cores: usize,
    /// Banks in the channel.
    pub num_banks: usize,
    /// Activation-window bank groups.
    pub num_groups: usize,
    /// Per-command issue/completion windows, indexed by command.
    pub cmds: Vec<CmdMeta>,
    /// Every committed reservation, grouped by command in trace order.
    pub spans: Vec<TraceSpan>,
}

impl ScheduleTrace {
    /// Run the event scheduler in recording mode on `trace` and capture
    /// its committed timeline. Always uses the event engine regardless of
    /// `cfg.engine` (the analytic engine has no schedule to trace); the
    /// returned [`EventReport`] is the same result a plain event-engine
    /// run of the same config produces.
    pub fn capture(cfg: &ArchConfig, trace: &Trace) -> (EventReport, ScheduleTrace) {
        let (report, sched, records) = crate::sim::event::simulate_recorded(cfg, trace);
        let mut cmds = Vec::with_capacity(trace.cmds.len());
        let mut spans = Vec::new();
        for (i, recs) in records.iter().enumerate() {
            let node = trace.cmds[i].node;
            let kind = trace.cmds[i].kind.mnemonic();
            // The command's window spans every issue attempt: first
            // attempt's start to last attempt's completion (one attempt
            // unless a transient fault plan forced replays).
            cmds.push(CmdMeta { node, kind, start: sched.starts[i], done: sched.dones[i] });
            for rec in recs {
                for rv in &rec.resv {
                    let Resv { res, start, end, span, slid, tally } = *rv;
                    spans.push(TraceSpan {
                        cmd: i,
                        node,
                        kind,
                        res: res_id(res),
                        start,
                        end,
                        busy: if tally { span } else { 0 },
                        slid,
                    });
                }
            }
        }
        let occ = report.occupancy;
        let st = ScheduleTrace {
            makespan: occ.makespan,
            num_cores: occ.num_cores,
            num_banks: occ.num_banks,
            num_groups: occ.num_groups,
            cmds,
            spans,
        };
        (report, st)
    }

    /// Certify this trace against the occupancy tallies of the run that
    /// produced it. Checks, all exact:
    ///
    /// * spans are disjoint per resource and lie within the makespan;
    /// * per-resource busy sums equal the [`ResourceOccupancy`] tallies
    ///   (cores, banks, bus, GBcore, host, command bus);
    /// * per-group reserved ACT cycles equal `act_busy`;
    /// * busy cycles of slid spans equal `slid_slices`;
    /// * per-bank host-command busy cycles equal `host_bank_busy`.
    pub fn verify(&self, occ: &ResourceOccupancy) -> Result<(), String> {
        if self.makespan != occ.makespan {
            return Err(format!("makespan {} != occupancy {}", self.makespan, occ.makespan));
        }
        let mut by_res: BTreeMap<ResourceId, Vec<(u64, u64, usize)>> = BTreeMap::new();
        let mut busy: BTreeMap<ResourceId, u64> = BTreeMap::new();
        let mut reserved: BTreeMap<ResourceId, u64> = BTreeMap::new();
        let mut slid_busy = 0u64;
        let mut host_bank = [0u64; MAX_CORES];
        for sp in &self.spans {
            if sp.start > sp.end {
                return Err(format!("cmd {} span on {:?} is inverted", sp.cmd, sp.res));
            }
            if sp.end > self.makespan {
                return Err(format!(
                    "cmd {} span on {:?} ends at {} past makespan {}",
                    sp.cmd, sp.res, sp.end, self.makespan
                ));
            }
            by_res.entry(sp.res).or_default().push((sp.start, sp.end, sp.cmd));
            *busy.entry(sp.res).or_default() += sp.busy;
            *reserved.entry(sp.res).or_default() += sp.end - sp.start;
            if sp.slid > 0 {
                slid_busy += sp.busy;
            }
            if let ResourceId::Bank(b) = sp.res {
                if sp.kind.starts_with("HOST") {
                    host_bank[b] += sp.busy;
                }
            }
        }
        for (res, iv) in by_res.iter_mut() {
            iv.sort_unstable();
            for w in iv.windows(2) {
                if w[0].1 > w[1].0 {
                    return Err(format!(
                        "{:?} double-booked: cmd {} [{}, {}) overlaps cmd {} [{}, {})",
                        res, w[0].2, w[0].0, w[0].1, w[1].2, w[1].0, w[1].1
                    ));
                }
            }
        }
        let got = |r: ResourceId| busy.get(&r).copied().unwrap_or(0);
        let check = |name: String, traced: u64, tallied: u64| {
            if traced != tallied {
                Err(format!("{name}: traced busy {traced} != occupancy {tallied}"))
            } else {
                Ok(())
            }
        };
        check("cmdbus".into(), got(ResourceId::CmdBus), occ.cmdbus_busy)?;
        check("bus".into(), got(ResourceId::Bus), occ.bus_busy)?;
        check("gbcore".into(), got(ResourceId::Gbcore), occ.gbcore_busy)?;
        check("host".into(), got(ResourceId::Host), occ.host_busy)?;
        for c in 0..self.num_cores {
            check(format!("core{c}"), got(ResourceId::Core(c)), occ.core_busy[c])?;
        }
        for b in 0..self.num_banks {
            check(format!("bank{b}"), got(ResourceId::Bank(b)), occ.bank_busy[b])?;
            check(format!("host@bank{b}"), host_bank[b], occ.host_bank_busy[b])?;
        }
        for g in 0..self.num_groups {
            let r = reserved.get(&ResourceId::ActGroup(g)).copied().unwrap_or(0);
            check(format!("act{g}"), r, occ.act_busy[g])?;
        }
        check("slid slices".into(), slid_busy, occ.slid_slices)?;
        for (i, c) in self.cmds.iter().enumerate() {
            if c.start > c.done || c.done > self.makespan {
                return Err(format!(
                    "cmd {} window [{}, {}] escapes makespan {}",
                    i, c.start, c.done, self.makespan
                ));
            }
        }
        Ok(())
    }

    /// Fold a multi-channel run's committed interconnect schedule into
    /// this (channel-0) trace: one `CH_XCHG` span on
    /// [`ResourceId::Interconnect`] per cross-channel transfer, each
    /// attributed to the producing node's last command, and the makespan
    /// raised to the composed multi-channel total (`makespan`).
    ///
    /// The result is what `pimfused profile --channels N` renders. It is
    /// deliberately **not** [`ScheduleTrace::verify`]-able afterwards:
    /// the composed makespan and the interconnect class exist only in
    /// the multi-channel view, never in channel 0's
    /// [`ResourceOccupancy`], so certification stays a single-channel
    /// property and multi-channel callers skip the verify step.
    pub fn attach_exchanges(&mut self, report: &crate::sim::ChannelReport, makespan: u64) {
        for x in &report.exchanges {
            let cmd = self.cmds.iter().rposition(|c| c.node == x.node).unwrap_or(0);
            self.spans.push(TraceSpan {
                cmd,
                node: x.node,
                kind: "CH_XCHG",
                res: ResourceId::Interconnect,
                start: x.start,
                end: x.end,
                busy: x.end - x.start,
                slid: 0,
            });
        }
        self.makespan = self.makespan.max(makespan);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn class_table_cannot_drift() {
        assert_eq!(CLASS_TABLE.len(), ResourceClass::ALL.len());
        for (i, c) in ResourceClass::ALL.iter().enumerate() {
            assert_eq!(CLASS_TABLE[i].0, *c, "ALL and CLASS_TABLE must agree on order");
            assert_eq!(c.pid(), i as u64 + 1);
        }
    }

    #[test]
    fn labels_and_indices() {
        assert_eq!(ResourceId::Bus.label(), "bus");
        assert_eq!(ResourceId::Bank(3).label(), "bank3");
        assert_eq!(ResourceId::Core(0).label(), "core0");
        assert_eq!(ResourceId::ActGroup(1).label(), "act1");
        assert_eq!(ResourceId::Bank(3).index(), 3);
        assert_eq!(ResourceId::Host.index(), 0);
        assert_eq!(ResourceId::Interconnect.label(), "interconnect");
        assert_eq!(ResourceId::Interconnect.index(), 0);
        assert_eq!(ResourceId::Interconnect.class().pid(), 8, "appended class keeps pids stable");
    }

    #[test]
    fn resource_order_is_class_major() {
        let mut v =
            vec![ResourceId::Bank(0), ResourceId::CmdBus, ResourceId::Core(2), ResourceId::Bus];
        v.sort();
        assert_eq!(
            v,
            vec![ResourceId::CmdBus, ResourceId::Bus, ResourceId::Core(2), ResourceId::Bank(0)]
        );
    }

    #[test]
    fn res_id_round_trips_the_arena() {
        assert_eq!(res_id(resources::CMDBUS), ResourceId::CmdBus);
        assert_eq!(res_id(resources::BUS), ResourceId::Bus);
        assert_eq!(res_id(resources::GBCORE), ResourceId::Gbcore);
        assert_eq!(res_id(resources::HOST), ResourceId::Host);
        for r in 0..resources::NUM_RES {
            let id = res_id(r); // must not hit the unreachable arm
            if let Some(b) = resources::res_bank(r) {
                assert_eq!(id, ResourceId::Bank(b));
            }
            if let Some(g) = resources::res_act_group(r) {
                assert_eq!(id, ResourceId::ActGroup(g));
            }
            if let Some(c) = resources::res_core(r) {
                assert_eq!(id, ResourceId::Core(c));
            }
        }
    }
}
