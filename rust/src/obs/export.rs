//! Trace exporters: Chrome-trace / Perfetto JSON and a compact CSV.
//!
//! Both serializers are hand-rolled (no serde, like
//! `coordinator/serialize.rs`) and byte-deterministic for a given
//! [`ScheduleTrace`] — the golden tests in `tests/obs_api.rs` pin the
//! exact bytes on a small schedule.

use crate::coordinator::serialize::csv_escape;
use crate::obs::schedule::{ResourceClass, ResourceId, ScheduleTrace};
use std::fmt::Write as _;

/// The trace export formats `--trace-out` selects between.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceFormat {
    /// Chrome-trace / Perfetto `trace_events` JSON ([`chrome_trace_json`]).
    Chrome,
    /// Compact per-span CSV ([`trace_csv`]).
    Csv,
}

/// One row per format: `(format, canonical name, aliases)`. Single source
/// of truth for [`TraceFormat::name`] / [`TraceFormat::parse`].
const FORMAT_TABLE: &[(TraceFormat, &str, &[&str])] = &[
    (TraceFormat::Chrome, "chrome", &["perfetto", "json"]),
    (TraceFormat::Csv, "csv", &[]),
];

impl TraceFormat {
    /// Every format, in [`FORMAT_TABLE`] order.
    pub const ALL: [TraceFormat; 2] = [TraceFormat::Chrome, TraceFormat::Csv];

    fn row(&self) -> &'static (TraceFormat, &'static str, &'static [&'static str]) {
        &FORMAT_TABLE[FORMAT_TABLE.iter().position(|(f, _, _)| f == self).unwrap()]
    }

    /// Canonical CLI name (`chrome` or `csv`).
    pub fn name(&self) -> &'static str {
        self.row().1
    }

    /// Parse a CLI spelling (canonical name or alias, e.g. `perfetto`).
    pub fn parse(s: &str) -> Option<TraceFormat> {
        FORMAT_TABLE
            .iter()
            .find(|(_, name, aliases)| *name == s || aliases.contains(&s))
            .map(|(f, _, _)| *f)
    }

    /// Render `t` in this format (dispatches to [`chrome_trace_json`] /
    /// [`trace_csv`]).
    pub fn export(&self, t: &ScheduleTrace) -> String {
        match self {
            TraceFormat::Chrome => chrome_trace_json(t),
            TraceFormat::Csv => trace_csv(t),
        }
    }
}

/// The distinct resources the trace touches, class-major sorted.
fn resources_present(t: &ScheduleTrace) -> Vec<ResourceId> {
    let mut v: Vec<ResourceId> = t.spans.iter().map(|s| s.res).collect();
    v.sort_unstable();
    v.dedup();
    v
}

/// Serialize a schedule trace in the Chrome-trace `trace_events` JSON
/// format (loadable in `chrome://tracing` and Perfetto).
///
/// Each [`ResourceClass`] becomes a pseudo-process (`process_name`
/// metadata, pid = [`ResourceClass::pid`]); each resource in the class
/// becomes a thread (`thread_name` metadata, tid = [`ResourceId::index`]).
/// Every span is one complete (`"ph": "X"`) event named by its command's
/// Table-I mnemonic, with `ts`/`dur` in **cycles** (not microseconds) and
/// the command index, node, tallied busy cycles, and slide distance in
/// `args`.
pub fn chrome_trace_json(t: &ScheduleTrace) -> String {
    let resources = resources_present(t);
    let mut classes: Vec<ResourceClass> = resources.iter().map(|r| r.class()).collect();
    classes.dedup(); // class-major sort ⇒ duplicates are adjacent
    let mut events: Vec<String> =
        Vec::with_capacity(classes.len() + resources.len() + t.spans.len());
    for c in &classes {
        events.push(format!(
            "{{\"name\": \"process_name\", \"ph\": \"M\", \"pid\": {}, \"args\": {{\"name\": \"{}\"}}}}",
            c.pid(),
            c.name()
        ));
    }
    for r in &resources {
        events.push(format!(
            "{{\"name\": \"thread_name\", \"ph\": \"M\", \"pid\": {}, \"tid\": {}, \"args\": {{\"name\": \"{}\"}}}}",
            r.class().pid(),
            r.index(),
            r.label()
        ));
    }
    for sp in &t.spans {
        events.push(format!(
            "{{\"name\": \"{}\", \"cat\": \"{}\", \"ph\": \"X\", \"ts\": {}, \"dur\": {}, \"pid\": {}, \"tid\": {}, \"args\": {{\"cmd\": {}, \"node\": {}, \"busy\": {}, \"slid\": {}}}}}",
            sp.kind,
            sp.res.class().name(),
            sp.start,
            sp.end - sp.start,
            sp.res.class().pid(),
            sp.res.index(),
            sp.cmd,
            sp.node,
            sp.busy,
            sp.slid
        ));
    }
    let mut out = String::from("{\n  \"displayTimeUnit\": \"ns\",\n  \"traceEvents\": [\n");
    for (i, e) in events.iter().enumerate() {
        let sep = if i + 1 == events.len() { "" } else { "," };
        let _ = writeln!(out, "    {e}{sep}");
    }
    out.push_str("  ]\n}\n");
    out
}

/// Header row of [`trace_csv`], one column per [`crate::obs::TraceSpan`]
/// field (the resource splits into class name + index).
pub const TRACE_CSV_HEADER: &str = "cmd,node,kind,resource,res_index,start,end,busy,slid";

/// Serialize a schedule trace as compact CSV, one row per span in trace
/// order, header [`TRACE_CSV_HEADER`].
pub fn trace_csv(t: &ScheduleTrace) -> String {
    let mut out = String::with_capacity(t.spans.len() * 40 + 64);
    out.push_str(TRACE_CSV_HEADER);
    out.push('\n');
    for sp in &t.spans {
        let _ = writeln!(
            out,
            "{},{},{},{},{},{},{},{},{}",
            sp.cmd,
            sp.node,
            csv_escape(sp.kind),
            sp.res.class().name(),
            sp.res.index(),
            sp.start,
            sp.end,
            sp.busy,
            sp.slid
        );
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn format_table_cannot_drift() {
        assert_eq!(FORMAT_TABLE.len(), TraceFormat::ALL.len());
        for (i, f) in TraceFormat::ALL.iter().enumerate() {
            assert_eq!(FORMAT_TABLE[i].0, *f);
            assert_eq!(TraceFormat::parse(f.name()), Some(*f), "canonical name parses");
        }
        assert_eq!(TraceFormat::parse("perfetto"), Some(TraceFormat::Chrome));
        assert_eq!(TraceFormat::parse("json"), Some(TraceFormat::Chrome));
        assert_eq!(TraceFormat::parse("bogus"), None);
    }

    #[test]
    fn empty_trace_exports_are_well_formed() {
        let t = ScheduleTrace {
            makespan: 0,
            num_cores: 0,
            num_banks: 0,
            num_groups: 0,
            cmds: vec![],
            spans: vec![],
        };
        let json = chrome_trace_json(&t);
        assert!(json.contains("\"traceEvents\""));
        assert_eq!(trace_csv(&t), format!("{TRACE_CSV_HEADER}\n"));
    }
}
