//! PPA (performance / power / area) reports and baseline normalization —
//! what the paper's figures plot.

use crate::config::Engine;
use crate::energy::{AreaReport, EnergyReport};
use crate::sim::{ResourceOccupancy, SimResult};

/// One system+workload evaluation.
#[derive(Debug, Clone)]
pub struct PpaReport {
    /// Configuration label, e.g. `Fused4/G32K_L256`.
    pub label: String,
    /// Workload name.
    pub workload: String,
    /// Simulation engine that produced the cycle count.
    pub engine: Engine,
    /// Memory-system cycles (performance metric, §V-A1).
    pub cycles: u64,
    /// Total energy in pJ.
    pub energy_pj: f64,
    /// PIM-addition area in mm².
    pub area_mm2: f64,
    /// Full simulation breakdown for audits (per-path cycles, actions).
    pub sim: SimResult,
    /// Per-component energy breakdown.
    pub energy: EnergyReport,
    /// Per-component area breakdown.
    pub area: AreaReport,
    /// Per-resource utilization (event engine only).
    pub occupancy: Option<ResourceOccupancy>,
    /// The committed schedule timeline, captured only when the config ran
    /// the event engine with [`crate::config::ArchConfig::tracing`] on.
    pub schedule: Option<crate::obs::ScheduleTrace>,
    /// Multi-channel summary (per-channel cycles, interconnect busy,
    /// exchange schedule). `None` for single-channel runs, so their
    /// reports — and everything serialized from them — are byte-identical
    /// to a build without the channels axis.
    pub channels: Option<crate::sim::ChannelReport>,
}

/// PPA ratios relative to a baseline run (the paper normalizes everything
/// to AiM-like @ G2K_L0).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Normalized {
    /// Cycle ratio vs the baseline (lower is faster).
    pub cycles: f64,
    /// Energy ratio vs the baseline.
    pub energy: f64,
    /// Area ratio vs the baseline.
    pub area: f64,
}

impl PpaReport {
    /// The PPA ratios of this report relative to `base`.
    pub fn normalize(&self, base: &PpaReport) -> Normalized {
        Normalized {
            cycles: self.cycles as f64 / base.cycles as f64,
            energy: self.energy_pj / base.energy_pj,
            area: self.area_mm2 / base.area_mm2,
        }
    }

    /// Bottleneck utilization of the event schedule: the busiest
    /// resource's share of the makespan (1.0 ⇒ resource-bound, lower ⇒
    /// dependency-bound). `None` for analytic runs, which carry no
    /// occupancy breakdown.
    pub fn bottleneck_utilization(&self) -> Option<f64> {
        self.occupancy.map(|o| {
            if o.makespan == 0 {
                0.0
            } else {
                o.busiest() as f64 / o.makespan as f64
            }
        })
    }

    /// Host I/O's share of total bank occupancy in the event schedule —
    /// how much of the banks' busy time is the host streaming the network
    /// input/output through them rather than PIM traffic. `None` for
    /// analytic runs; `0.0` when host bank residency is disabled.
    pub fn host_bank_share(&self) -> Option<f64> {
        self.occupancy.map(|o| {
            let total: u64 = o.bank_busy[..o.num_banks].iter().sum();
            if total == 0 {
                0.0
            } else {
                o.host_bank_total() as f64 / total as f64
            }
        })
    }

    /// ACT-slot utilization of the event schedule: the share of all bank
    /// groups' tFAW/tRRD window-cycles the schedule reserves. `None` for
    /// analytic runs.
    pub fn act_utilization(&self) -> Option<f64> {
        self.occupancy.map(|o| o.act_utilization())
    }

    /// Share of the run's serial work spent re-executing commands that
    /// hit transient faults (`replayed / (cycles + replayed)` under the
    /// analytic engine's serial accounting). `0.0` for fault-free runs —
    /// a cheap "how much did reliability cost" headline for degraded
    /// reports.
    pub fn replay_overhead(&self) -> f64 {
        let total = self.sim.cycles + self.sim.replayed_cycles;
        if total == 0 {
            0.0
        } else {
            self.sim.replayed_cycles as f64 / total as f64
        }
    }

    /// Host-interconnect utilization of a multi-channel run: the shared
    /// interconnect's busy share of the composed makespan. `None` for
    /// single-channel runs (no interconnect exists), `Some(0.0)` for
    /// multi-channel runs that never exchange (data-parallel).
    pub fn interconnect_utilization(&self) -> Option<f64> {
        self.channels.as_ref().map(|c| c.interconnect_utilization(self.cycles))
    }

    /// Per-layer phase attribution of the captured schedule
    /// ([`crate::obs::PhaseProfile`]). `None` unless the report was run
    /// with [`crate::config::ArchConfig::tracing`] on the event engine.
    pub fn phase_profile(&self) -> Option<crate::obs::PhaseProfile> {
        self.schedule.as_ref().map(crate::obs::PhaseProfile::from_trace)
    }
}

impl Normalized {
    /// `cycles=30.6% energy=83.4% area=76.5%` in the paper's style.
    pub fn render(&self) -> String {
        use crate::util::table::pct_or_x;
        format!(
            "cycles={} energy={} area={}",
            pct_or_x(self.cycles),
            pct_or_x(self.energy),
            pct_or_x(self.area)
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energy::{AreaReport, EnergyReport};

    fn dummy(cycles: u64, energy_pj: f64, area_mm2: f64) -> PpaReport {
        PpaReport {
            label: "x".into(),
            workload: "w".into(),
            engine: Engine::Analytic,
            cycles,
            energy_pj,
            area_mm2,
            sim: SimResult::default(),
            energy: EnergyReport { components: vec![] },
            area: AreaReport {
                pimcores_mm2: area_mm2,
                gbcore_mm2: 0.0,
                gbuf_mm2: 0.0,
                lbufs_mm2: 0.0,
                control_mm2: 0.0,
            },
            occupancy: None,
            schedule: None,
            channels: None,
        }
    }

    #[test]
    fn normalization_is_ratio() {
        let base = dummy(1000, 200.0, 0.4);
        let ours = dummy(306, 166.8, 0.306);
        let n = ours.normalize(&base);
        assert!((n.cycles - 0.306).abs() < 1e-9);
        assert!((n.energy - 0.834).abs() < 1e-9);
        assert!((n.area - 0.765).abs() < 1e-9);
        assert_eq!(n.render(), "cycles=30.6% energy=83.4% area=76.5%");
    }

    #[test]
    fn bottleneck_utilization_reads_the_occupancy() {
        let mut r = dummy(100, 1.0, 1.0);
        assert_eq!(r.bottleneck_utilization(), None, "analytic runs have no occupancy");
        let occ = ResourceOccupancy { makespan: 200, bus_busy: 150, ..Default::default() };
        r.occupancy = Some(occ);
        assert_eq!(r.bottleneck_utilization(), Some(0.75));
        r.occupancy = Some(ResourceOccupancy::default());
        assert_eq!(r.bottleneck_utilization(), Some(0.0), "empty schedule is 0, not NaN");
    }

    #[test]
    fn host_bank_share_and_act_utilization_read_the_occupancy() {
        let mut r = dummy(100, 1.0, 1.0);
        assert_eq!(r.host_bank_share(), None);
        assert_eq!(r.act_utilization(), None);
        let mut occ = ResourceOccupancy {
            num_banks: 2,
            num_groups: 1,
            makespan: 100,
            ..Default::default()
        };
        occ.bank_busy[0] = 30;
        occ.bank_busy[1] = 10;
        occ.host_bank_busy[0] = 8;
        occ.host_bank_busy[1] = 2;
        occ.act_busy[0] = 25;
        r.occupancy = Some(occ);
        assert_eq!(r.host_bank_share(), Some(0.25));
        assert_eq!(r.act_utilization(), Some(0.25));
        r.occupancy = Some(ResourceOccupancy::default());
        assert_eq!(r.host_bank_share(), Some(0.0), "empty schedule is 0, not NaN");
    }

    #[test]
    fn replay_overhead_is_a_fraction_of_serial_work() {
        let mut r = dummy(100, 1.0, 1.0);
        assert_eq!(r.replay_overhead(), 0.0, "fault-free runs replay nothing");
        r.sim.cycles = 300;
        r.sim.replayed_cycles = 100;
        assert_eq!(r.replay_overhead(), 0.25);
    }

    #[test]
    fn over_unity_renders_as_multiplier() {
        let base = dummy(100, 100.0, 1.0);
        let worse = dummy(110, 100.0, 1.0);
        assert!(worse.normalize(&base).render().starts_with("cycles=1.10x"));
    }
}
