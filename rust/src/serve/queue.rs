//! Bounded admission queue with time-weighted depth accounting.
//!
//! Requests that arrive while the queue is full are **dropped** (counted;
//! whether the client retries is the driver's policy — see
//! [`crate::serve::ServeConfig::client_retries`]). The queue tracks its
//! maximum depth and a time-weighted depth integral so the driver can
//! report mean queue depth over the run. Capacity counts *waiting*
//! requests only; a batch in service has already left the queue.
//!
//! Each entry remembers both when it was queued (dispatch triggers and
//! depth accounting) and the request's *original* arrival (latency and
//! deadline accounting) — the two differ only for client re-offers.

use std::collections::VecDeque;

/// FIFO admission queue of `(queued_at, original_arrival)` request
/// entries, bounded at `depth`.
#[derive(Debug, Clone)]
pub struct AdmissionQueue {
    waiting: VecDeque<(u64, u64)>,
    depth: usize,
    dropped: usize,
    max_depth: usize,
    /// Sum of `queue length × cycles` over the events seen so far.
    depth_integral: u128,
    last_event: u64,
}

impl AdmissionQueue {
    /// An empty queue holding at most `depth` waiting requests.
    pub fn new(depth: usize) -> Self {
        Self {
            waiting: VecDeque::with_capacity(depth.min(4096)),
            depth,
            dropped: 0,
            max_depth: 0,
            depth_integral: 0,
            last_event: 0,
        }
    }

    /// Advance the depth integral to `now`. Events must arrive in
    /// non-decreasing time order (the driver's event loop guarantees it).
    fn advance(&mut self, now: u64) {
        debug_assert!(now >= self.last_event, "queue events must be time-ordered");
        self.depth_integral +=
            (now - self.last_event) as u128 * self.waiting.len() as u128;
        self.last_event = now;
    }

    /// Offer a request arriving at `arrival`; returns `false` (and counts
    /// a drop) when the queue is full.
    pub fn offer(&mut self, arrival: u64) -> bool {
        self.offer_from(arrival, arrival)
    }

    /// Offer a request at time `now` that originally arrived at `orig`
    /// (`orig <= now`; they differ for client re-offers after a
    /// rejection). Returns `false` (and counts a drop) when full.
    pub fn offer_from(&mut self, now: u64, orig: u64) -> bool {
        debug_assert!(orig <= now, "a request cannot be re-offered before it arrived");
        self.advance(now);
        if self.waiting.len() >= self.depth {
            self.dropped += 1;
            return false;
        }
        self.waiting.push_back((now, orig));
        self.max_depth = self.max_depth.max(self.waiting.len());
        true
    }

    /// Pop up to `k` requests — `(queued_at, original_arrival)` pairs in
    /// FIFO order — at dispatch time `now`. Never pops a request that was
    /// not queued by `now` — a batch can only contain requests that
    /// exist yet.
    pub fn take(&mut self, now: u64, k: usize) -> Vec<(u64, u64)> {
        self.advance(now);
        let mut n = 0;
        while n < k && self.waiting.get(n).map_or(false, |&(a, _)| a <= now) {
            n += 1;
        }
        self.waiting.drain(..n).collect()
    }

    /// Waiting requests right now.
    pub fn len(&self) -> usize {
        self.waiting.len()
    }

    /// Whether nothing is waiting.
    pub fn is_empty(&self) -> bool {
        self.waiting.is_empty()
    }

    /// Queued-at time of the oldest waiting request, if any.
    pub fn head_arrival(&self) -> Option<u64> {
        self.waiting.front().map(|&(a, _)| a)
    }

    /// Queued-at time of the `idx`-th oldest waiting request, if any.
    /// The dispatcher uses `nth_arrival(batch - 1)` as the instant a
    /// full batch came into existence.
    pub fn nth_arrival(&self, idx: usize) -> Option<u64> {
        self.waiting.get(idx).map(|&(a, _)| a)
    }

    /// Queued-at time of the newest waiting request, if any.
    pub fn back_arrival(&self) -> Option<u64> {
        self.waiting.back().map(|&(a, _)| a)
    }

    /// Requests dropped because the queue was full.
    pub fn dropped(&self) -> usize {
        self.dropped
    }

    /// The deepest the queue ever got.
    pub fn max_depth(&self) -> usize {
        self.max_depth
    }

    /// Time-weighted mean depth over `[0, end]` (the driver passes the
    /// run's makespan; the queue is empty after the last dispatch, so no
    /// depth is unaccounted).
    pub fn mean_depth(&self, end: u64) -> f64 {
        if end == 0 {
            return 0.0;
        }
        let total = self.depth_integral
            + (end.saturating_sub(self.last_event)) as u128 * self.waiting.len() as u128;
        total as f64 / end as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order_and_bounded_drops() {
        let mut q = AdmissionQueue::new(2);
        assert!(q.offer(10));
        assert!(q.offer(20));
        assert!(!q.offer(30), "third offer exceeds depth 2");
        assert_eq!(q.dropped(), 1);
        assert_eq!(q.max_depth(), 2);
        assert_eq!(q.head_arrival(), Some(10));
        assert_eq!(q.take(50, 2), vec![(10, 10), (20, 20)]);
        assert!(q.is_empty());
        // Space freed: the next offer is admitted again.
        assert!(q.offer(60));
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn take_caps_at_queue_length() {
        let mut q = AdmissionQueue::new(8);
        q.offer(1);
        q.offer(2);
        assert_eq!(q.take(5, 100), vec![(1, 1), (2, 2)]);
        assert_eq!(q.take(6, 4), Vec::<(u64, u64)>::new());
    }

    #[test]
    fn re_offers_keep_their_original_arrival() {
        let mut q = AdmissionQueue::new(2);
        assert!(q.offer_from(30, 5), "re-offer queues at 30, arrived at 5");
        assert_eq!(q.head_arrival(), Some(30), "triggers key off the queued-at time");
        assert_eq!(q.take(40, 1), vec![(30, 5)], "latency keys off the original arrival");
    }

    #[test]
    fn nth_and_back_arrivals() {
        let mut q = AdmissionQueue::new(8);
        q.offer(5);
        q.offer(9);
        q.offer(12);
        assert_eq!(q.nth_arrival(0), Some(5));
        assert_eq!(q.nth_arrival(2), Some(12));
        assert_eq!(q.nth_arrival(3), None);
        assert_eq!(q.back_arrival(), Some(12));
    }

    #[test]
    fn mean_depth_is_time_weighted() {
        let mut q = AdmissionQueue::new(8);
        q.offer(0); // depth 1 over [0, 10)
        q.offer(10); // depth 2 over [10, 20)
        let taken = q.take(20, 2); // empty over [20, 40)
        assert_eq!(taken.len(), 2);
        // (1*10 + 2*10 + 0*20) / 40 = 0.75
        assert!((q.mean_depth(40) - 0.75).abs() < 1e-12);
        assert_eq!(q.mean_depth(0), 0.0);
    }
}
