//! Request-stream serving simulator: open-loop arrivals, a bounded
//! batching queue, and steady-state latency/throughput metrics layered on
//! the PPA engines (DESIGN.md §9).
//!
//! One inference's cycle count answers "how fast is one picture?"; a
//! serving simulation answers the deployment question — *what latency do
//! requests see at a given offered load, and where does the system
//! saturate?* The pieces:
//!
//! - [`arrivals`]: deterministic-seed Poisson or fixed-rate request
//!   streams ([`ArrivalKind`]), open-loop (arrivals never back off).
//! - [`queue`]: a bounded FIFO admission queue ([`AdmissionQueue`]) that
//!   drops on overflow and tracks time-weighted depth.
//! - [`sim`]: the driver ([`ServeDriver`]) — memoizes one schedule per
//!   `(workload, config)` into a [`ServiceProfile`] and replays it per
//!   batch; [`simulate_stream`] is the pure event loop.
//! - [`stats`]: warmup-trimmed nearest-rank percentiles
//!   ([`LatencyStats`]) and the full [`ServeReport`].
//!
//! Entry points: [`crate::coordinator::Session::serve`] for one rate,
//! [`crate::coordinator::Session::serve_sweep`] for a
//! utilization-vs-latency curve, and the `pimfused serve` subcommand.
//!
//! ```
//! use pimfused::config::{ArchConfig, Engine, System};
//! use pimfused::coordinator::Session;
//! use pimfused::serve::ServeConfig;
//! use pimfused::workload::Workload;
//!
//! let session = Session::new();
//! let cfg = ArchConfig::system(System::Fused4, 32 * 1024, 256)
//!     .with_engine(Engine::Event);
//! let sc = ServeConfig::new(cfg, Workload::Fig1, 50_000.0).requests(200);
//! let report = session.serve(&sc).unwrap();
//! assert_eq!(report.completed + report.dropped, 200);
//! assert!(report.latency.p99 >= report.latency.p50);
//! ```

pub mod arrivals;
pub mod queue;
pub mod sim;
pub mod stats;

pub use arrivals::{arrival_times, ArrivalKind};
pub use queue::AdmissionQueue;
pub use sim::{simulate_stream, simulate_stream_metered, ServeDriver, ServiceProfile};
pub use stats::{latency_stats, LatencyStats, ServeReport};

use crate::config::ArchConfig;
use crate::workload::Workload;

/// Everything one serving run needs: the system under test, the workload,
/// and the request-stream shape. Build with [`ServeConfig::new`] plus the
/// builder setters; [`ServeConfig::validate`] runs before every
/// simulation.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeConfig {
    /// Architecture configuration to serve on (its engine decides the
    /// service profile's fidelity — see [`ServiceProfile::from_report`]).
    pub cfg: ArchConfig,
    /// Workload every request runs (one request = one inference).
    pub workload: Workload,
    /// Arrival process (default [`ArrivalKind::Poisson`]).
    pub arrival: ArrivalKind,
    /// Offered load in requests per second of wall-clock time.
    pub rate: f64,
    /// Number of requests to generate (default 1000).
    pub requests: usize,
    /// Maximum batch size the dispatcher forms (default 1 = no batching).
    pub batch: usize,
    /// Cycles a partial batch waits for stragglers before dispatching
    /// anyway (default 0 = dispatch eagerly whenever the server is free).
    pub batch_timeout: u64,
    /// Admission queue capacity; arrivals beyond it are dropped
    /// (default 64).
    pub queue_depth: usize,
    /// Seed for the arrival stream (default 42).
    pub seed: u64,
    /// Fraction of completions trimmed from the front as warmup before
    /// computing latency statistics, in `[0, 1)` (default 0.1).
    pub warmup: f64,
    /// Per-request latency SLO in cycles, measured from the request's
    /// *original* arrival (default 0 = no deadline). With a deadline
    /// set, admission sheds requests whose projected completion cannot
    /// make it, and requests served past it count as deadline misses,
    /// not completions.
    pub deadline: u64,
    /// How many times a rejected request (queue full or deadline shed)
    /// re-offers itself before giving up (default 0 = open-loop clients
    /// never retry).
    pub client_retries: u32,
    /// Base client backoff in cycles: the `k`-th retry re-offers after
    /// `backoff << (k-1)` cycles (exponential; 0 retries on the next
    /// cycle). Default 0.
    pub backoff: u64,
}

impl ServeConfig {
    /// A serving config at the given offered rate with the defaults
    /// documented on each field.
    pub fn new(cfg: ArchConfig, workload: Workload, rate: f64) -> Self {
        ServeConfig {
            cfg,
            workload,
            arrival: ArrivalKind::Poisson,
            rate,
            requests: 1000,
            batch: 1,
            batch_timeout: 0,
            queue_depth: 64,
            seed: 42,
            warmup: 0.1,
            deadline: 0,
            client_retries: 0,
            backoff: 0,
        }
    }

    /// Builder-style arrival-process selection.
    pub fn arrival(mut self, a: ArrivalKind) -> Self {
        self.arrival = a;
        self
    }

    /// Builder-style request-count selection.
    pub fn requests(mut self, n: usize) -> Self {
        self.requests = n;
        self
    }

    /// Builder-style maximum batch size.
    pub fn batch(mut self, b: usize) -> Self {
        self.batch = b;
        self
    }

    /// Builder-style batch timeout in cycles.
    pub fn batch_timeout(mut self, t: u64) -> Self {
        self.batch_timeout = t;
        self
    }

    /// Builder-style admission-queue capacity.
    pub fn queue_depth(mut self, d: usize) -> Self {
        self.queue_depth = d;
        self
    }

    /// Builder-style arrival seed.
    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }

    /// Builder-style warmup fraction.
    pub fn warmup(mut self, w: f64) -> Self {
        self.warmup = w;
        self
    }

    /// Builder-style per-request deadline in cycles (0 disables).
    pub fn deadline(mut self, d: u64) -> Self {
        self.deadline = d;
        self
    }

    /// Builder-style client retry budget per rejected request.
    pub fn client_retries(mut self, r: u32) -> Self {
        self.client_retries = r;
        self
    }

    /// Builder-style base client backoff in cycles.
    pub fn backoff(mut self, b: u64) -> Self {
        self.backoff = b;
        self
    }

    /// Sanity-check the stream parameters (and the architecture config);
    /// the driver calls this before every run so misconfigurations fail
    /// loudly instead of producing silent nonsense.
    pub fn validate(&self) -> Result<(), String> {
        if !self.rate.is_finite() || self.rate <= 0.0 {
            return Err(format!("rate must be a positive finite req/s (got {})", self.rate));
        }
        if self.requests == 0 {
            return Err("requests must be >= 1".into());
        }
        if self.batch == 0 {
            return Err("batch must be >= 1".into());
        }
        if self.queue_depth < self.batch {
            return Err(format!(
                "queue depth {} must be >= batch {} (a full batch must fit)",
                self.queue_depth, self.batch
            ));
        }
        if !self.warmup.is_finite() || !(0.0..1.0).contains(&self.warmup) {
            return Err(format!("warmup must be in [0, 1) (got {})", self.warmup));
        }
        self.cfg.validate()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> ServeConfig {
        ServeConfig::new(ArchConfig::baseline(), Workload::Fig1, 1000.0)
    }

    #[test]
    fn defaults_are_documented_values() {
        let sc = base();
        assert_eq!(sc.arrival, ArrivalKind::Poisson);
        assert_eq!(sc.requests, 1000);
        assert_eq!(sc.batch, 1);
        assert_eq!(sc.batch_timeout, 0);
        assert_eq!(sc.queue_depth, 64);
        assert_eq!(sc.seed, 42);
        assert_eq!(sc.warmup, 0.1);
        assert_eq!(sc.deadline, 0, "deadlines are off unless asked for");
        assert_eq!(sc.client_retries, 0, "open-loop clients never retry by default");
        assert_eq!(sc.backoff, 0);
        sc.validate().unwrap();
    }

    #[test]
    fn validate_rejects_bad_parameters() {
        assert!(ServeConfig { rate: 0.0, ..base() }.validate().is_err());
        assert!(ServeConfig { rate: -5.0, ..base() }.validate().is_err());
        assert!(ServeConfig { rate: f64::NAN, ..base() }.validate().is_err());
        assert!(base().requests(0).validate().is_err());
        assert!(base().batch(0).validate().is_err());
        let e = base().batch(8).queue_depth(4).validate().unwrap_err();
        assert!(e.contains("must be >= batch"), "{e}");
        assert!(base().warmup(1.0).validate().is_err());
        assert!(base().warmup(-0.1).validate().is_err());
        // Architecture validation is included.
        let mut sc = base();
        sc.cfg.banks_per_pimcore = 3;
        assert!(sc.validate().is_err());
    }
}
