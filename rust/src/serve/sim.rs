//! The steady-state serving driver.
//!
//! The expensive part of serving — turning `(workload, config)` into a
//! cycle count — is the regular PPA pipeline, and it is **memoized**: the
//! driver schedules each distinct `(workload, config)` exactly once
//! (through the [`Session`] caches) into a [`ServiceProfile`], then
//! replays that profile per admitted batch. A 10 000-request run costs
//! one schedule plus 10 000 profile lookups.
//!
//! Batches follow a pipeline initiation-interval model: the first request
//! of a batch costs the full single-inference schedule, each further
//! request costs only the bottleneck resource's busy time (the channel
//! cannot retire inferences faster than its busiest resource). Under the
//! analytic engine there is no occupancy breakdown, so the steady-state
//! cost equals the single-inference cost and batching does not help —
//! the contrast against the event engine is itself a fidelity statement
//! (DESIGN.md §9).

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::config::ArchConfig;
use crate::coordinator::Session;
use crate::ppa::PpaReport;
use crate::serve::arrivals::arrival_times;
use crate::serve::queue::AdmissionQueue;
use crate::serve::stats::{latency_stats, ServeReport};
use crate::serve::ServeConfig;
use crate::workload::Workload;
use anyhow::Result;

/// The memoized service cost of one `(workload, config)` pair.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ServiceProfile {
    /// Cycles one isolated inference takes (the schedule's makespan).
    pub single_cycles: u64,
    /// Marginal cycles per additional request in a batch: the pipeline
    /// initiation interval, bounded below by the busiest resource.
    pub steady_cycles: u64,
    /// Parallel serving lanes: a data-parallel multi-channel config runs
    /// independent requests on independent channels, so a batch splits
    /// into `ceil(b / lanes)` waves. 1 everywhere else (single-channel,
    /// and model-parallel — where all channels cooperate on one request
    /// and the payoff is a shorter `single_cycles` instead).
    pub lanes: usize,
}

impl ServiceProfile {
    /// Derive a profile from a PPA report. Event-engine reports carry a
    /// per-resource occupancy breakdown, whose busiest entry is the
    /// initiation interval; analytic reports have none, so the steady
    /// cost degenerates to the full single-inference cost. Data-parallel
    /// multi-channel reports contribute their surviving channel count as
    /// serving lanes.
    pub fn from_report(report: &PpaReport) -> Self {
        let single = report.cycles.max(1);
        let steady = match &report.occupancy {
            Some(occ) => occ.busiest().clamp(1, single),
            None => single,
        };
        let lanes = match &report.channels {
            Some(c) if c.partition == crate::config::PartitionKind::Data => {
                c.channels.saturating_sub(c.dead_channels).max(1)
            }
            _ => 1,
        };
        ServiceProfile { single_cycles: single, steady_cycles: steady, lanes }
    }

    /// Service cycles for a batch of `b` requests (`b >= 1`): the batch
    /// splits into `ceil(b / lanes)` waves; the first wave pays the full
    /// schedule, each further wave pays the initiation interval. With one
    /// lane this is the plain affine model (first request full, the rest
    /// marginal).
    pub fn batch_cycles(&self, b: usize) -> u64 {
        debug_assert!(b >= 1);
        let waves = crate::util::ceil_div(b, self.lanes.max(1)) as u64;
        self.single_cycles + (waves - 1) * self.steady_cycles
    }
}

/// Serving driver bound to a [`Session`]. Holds the per-`(workload,
/// config)` [`ServiceProfile`] memo; everything downstream of the memo is
/// pure, so a `&ServeDriver` is shareable across sweep worker threads.
pub struct ServeDriver<'s> {
    session: &'s Session,
    profiles: Mutex<HashMap<(Workload, ArchConfig), ServiceProfile>>,
    schedule_runs: AtomicUsize,
}

impl<'s> ServeDriver<'s> {
    /// A driver with an empty profile memo.
    pub fn new(session: &'s Session) -> Self {
        ServeDriver {
            session,
            profiles: Mutex::new(HashMap::new()),
            schedule_runs: AtomicUsize::new(0),
        }
    }

    /// The memoized service profile for `(workload, cfg)`; schedules
    /// through the session pipeline on first use. The pipeline runs
    /// outside the memo lock ([`Session::serve_sweep`] warms the memo
    /// serially before fanning out, so parallel workers only take hits).
    pub fn profile(&self, w: Workload, cfg: &ArchConfig) -> Result<ServiceProfile> {
        let key = (w, cfg.clone());
        if let Some(p) = self.profiles.lock().unwrap().get(&key) {
            return Ok(*p);
        }
        let report = self.session.run(cfg, w)?;
        self.schedule_runs.fetch_add(1, Ordering::Relaxed);
        let prof = ServiceProfile::from_report(&report);
        Ok(*self.profiles.lock().unwrap().entry(key).or_insert(prof))
    }

    /// How many times the driver ran the full schedule pipeline (the
    /// memoization test asserts this stays at one per distinct pair).
    pub fn schedule_runs(&self) -> usize {
        self.schedule_runs.load(Ordering::Relaxed)
    }

    /// Publish the driver's memo counters into a metrics registry
    /// (`serve.*` namespace): schedules actually built vs profile-memo
    /// entries (the gap to requests served is the cache-hit count). See
    /// [`crate::obs::MetricsRegistry`].
    pub fn publish_metrics(&self, m: &crate::obs::MetricsRegistry) {
        m.add("serve.schedule_runs", self.schedule_runs() as u64);
        m.add("serve.profile_entries", self.profiles.lock().unwrap().len() as u64);
    }

    /// Run one serving simulation end-to-end: validate, resolve the
    /// service profile, replay the request stream.
    pub fn run(&self, sc: &ServeConfig) -> Result<ServeReport> {
        sc.validate().map_err(anyhow::Error::msg)?;
        let prof = self.profile(sc.workload, &sc.cfg)?;
        Ok(simulate_stream(sc, prof))
    }
}

/// Replay an open-loop request stream against a service profile. Pure:
/// the report is a function of `(sc, prof)` alone, which is what makes
/// serving results byte-reproducible across runs and thread schedules.
///
/// The event loop merges three time-ordered streams — fresh arrivals,
/// client re-offers, and batch dispatches — always processing the
/// earliest event (offers win ties with dispatches, so a request landing
/// exactly at dispatch time joins the batch; fresh arrivals win ties
/// with re-offers). A dispatch fires at the earliest instant the server
/// is free **and** the dispatch condition holds: a full batch exists,
/// the batch timeout has expired at the queue head, or no further offer
/// is coming (partial batches drain eagerly).
///
/// Deadline-aware admission (see [`ServeConfig::deadline`]): an offer
/// whose projected completion — server-free time plus the queue's
/// steady-state backlog plus one full service — already overshoots its
/// deadline is **shed** at admission rather than queued to miss. A
/// request served past its deadline still occupies the server but counts
/// as a deadline miss, not a completion. Rejected offers (queue full or
/// shed) re-offer up to [`ServeConfig::client_retries`] times with
/// exponential backoff before counting as a drop.
pub fn simulate_stream(sc: &ServeConfig, prof: ServiceProfile) -> ServeReport {
    simulate_stream_metered(sc, prof, None)
}

/// [`simulate_stream`] with a live metrics tap: when a registry is given,
/// the loop pushes a `serve.queue_depth` sample (waiting requests at each
/// batch dispatch) and a `serve.latency_cycles` sample per completed
/// request (deadline misses excluded) into it as the stream replays.
/// `None` is exactly [`simulate_stream`] — the report is identical
/// either way.
pub fn simulate_stream_metered(
    sc: &ServeConfig,
    prof: ServiceProfile,
    metrics: Option<&crate::obs::MetricsRegistry>,
) -> ServeReport {
    let clock = sc.cfg.timing.clock_hz();
    let arrivals = arrival_times(sc.arrival, sc.requests, clock / sc.rate, sc.seed);
    let mut q = AdmissionQueue::new(sc.queue_depth);
    let mut shapes: HashMap<usize, u64> = HashMap::new();
    let mut latencies: Vec<u64> = Vec::with_capacity(sc.requests);
    // Pending client re-offers as a `(re-offer time, request index)`
    // min-heap, plus each request's rejection count so far.
    let mut retry: BinaryHeap<Reverse<(u64, usize)>> = BinaryHeap::new();
    let mut attempts: Vec<u32> = vec![0; arrivals.len()];
    let (mut dropped_queue_full, mut dropped_deadline_shed) = (0usize, 0usize);
    let (mut dropped_deadline_miss, mut dropped_retry_exhausted) = (0usize, 0usize);
    let mut served = 0usize;
    let mut free_at = 0u64;
    let mut busy = 0u64;
    let mut batches = 0usize;
    let mut i = 0usize;
    while i < arrivals.len() || !retry.is_empty() || !q.is_empty() {
        // The next offer is the earlier of the next fresh arrival and the
        // next client re-offer (fresh wins ties — it "arrived first").
        let fresh = arrivals.get(i).map(|&a| (a, i, true));
        let re = retry.peek().map(|&Reverse((t, s))| (t, s, false));
        let offer = match (fresh, re) {
            (Some(f), Some(r)) => Some(if r.0 < f.0 { r } else { f }),
            (f, r) => f.or(r),
        };
        let dispatch = if q.is_empty() {
            None
        } else {
            let head = q.head_arrival().unwrap();
            let trigger = if q.len() >= sc.batch {
                // Full batch: ready the instant its batch-th member arrived.
                q.nth_arrival(sc.batch - 1).unwrap()
            } else if offer.is_none() {
                // No more offers coming: drain the partial batch eagerly.
                q.back_arrival().unwrap()
            } else if sc.batch_timeout == 0 {
                head
            } else {
                head.saturating_add(sc.batch_timeout)
            };
            Some(free_at.max(trigger))
        };
        match (offer, dispatch) {
            (Some((at, seq, is_fresh)), d) if d.map_or(true, |dt| at <= dt) => {
                if is_fresh {
                    i += 1;
                } else {
                    retry.pop();
                }
                let orig = arrivals[seq];
                // Backlog projection at the offer instant: the server
                // frees, works off everything already queued at the
                // steady-state rate, then serves this request.
                let projected = free_at.max(at)
                    + q.len() as u64 * prof.steady_cycles
                    + prof.single_cycles;
                let queue_full = q.len() >= sc.queue_depth;
                let shed = !queue_full
                    && sc.deadline > 0
                    && projected > orig.saturating_add(sc.deadline);
                if !queue_full && !shed {
                    q.offer_from(at, orig);
                } else if attempts[seq] < sc.client_retries {
                    attempts[seq] += 1;
                    let wait =
                        sc.backoff.saturating_mul(1u64 << (attempts[seq] - 1).min(63));
                    // backoff 0 still re-offers strictly later, never now.
                    retry.push(Reverse((at.saturating_add(wait.max(1)), seq)));
                } else if sc.client_retries > 0 {
                    dropped_retry_exhausted += 1;
                } else if queue_full {
                    dropped_queue_full += 1;
                } else {
                    dropped_deadline_shed += 1;
                }
            }
            (_, Some(dt)) => {
                if let Some(m) = metrics {
                    m.push_sample("serve.queue_depth", q.len() as f64);
                }
                let taken = q.take(dt, sc.batch);
                debug_assert!(!taken.is_empty(), "dispatch must make progress");
                let b = taken.len();
                let service = *shapes.entry(b).or_insert_with(|| prof.batch_cycles(b));
                let done = dt + service;
                busy += service;
                for (_, orig) in taken {
                    let lat = done - orig;
                    served += 1;
                    if sc.deadline > 0 && lat > sc.deadline {
                        dropped_deadline_miss += 1;
                        continue;
                    }
                    if let Some(m) = metrics {
                        m.push_sample("serve.latency_cycles", lat as f64);
                    }
                    latencies.push(lat);
                }
                batches += 1;
                free_at = done;
            }
            (None, None) => unreachable!("loop invariant: offers or queue non-empty"),
        }
    }
    debug_assert_eq!(q.dropped(), 0, "fullness is pre-checked; the driver classifies drops");
    let makespan = free_at;
    let completed = latencies.len();
    let mut trimmed = (sc.warmup * completed as f64).floor() as usize;
    if completed > 0 {
        // Always keep at least one post-warmup sample.
        trimmed = trimmed.min(completed - 1);
    }
    let latency = latency_stats(&latencies[trimmed..]);
    let per_makespan = |n: usize| if makespan > 0 { n as f64 / makespan as f64 * clock } else { 0.0 };
    ServeReport {
        label: sc.cfg.label(),
        system: sc.cfg.system.name().to_string(),
        workload: sc.workload.name().to_string(),
        engine: sc.cfg.engine,
        arrival: sc.arrival,
        rate_rps: sc.rate,
        requests: sc.requests,
        batch: sc.batch,
        batch_timeout: sc.batch_timeout,
        queue_depth: sc.queue_depth,
        seed: sc.seed,
        deadline: sc.deadline,
        client_retries: sc.client_retries,
        backoff: sc.backoff,
        completed,
        dropped: dropped_queue_full
            + dropped_deadline_shed
            + dropped_deadline_miss
            + dropped_retry_exhausted,
        dropped_queue_full,
        dropped_deadline_shed,
        dropped_deadline_miss,
        dropped_retry_exhausted,
        batches,
        mean_batch: if batches > 0 { served as f64 / batches as f64 } else { 0.0 },
        warmup_trimmed: trimmed,
        latency,
        throughput_rps: per_makespan(served),
        goodput_rps: per_makespan(completed),
        utilization: if makespan > 0 { busy as f64 / makespan as f64 } else { 0.0 },
        queue_mean: q.mean_depth(makespan),
        queue_max: q.max_depth(),
        service_single: prof.single_cycles,
        service_steady: prof.steady_cycles,
        batch_shapes: shapes.len(),
        makespan_cycles: makespan,
    }
}

impl Session {
    /// Run one serving simulation on this session (see
    /// [`crate::serve`]). Convenience for
    /// `ServeDriver::new(self).run(sc)`; sweeping several rates through
    /// [`Session::serve_sweep`] shares one driver (and one schedule).
    pub fn serve(&self, sc: &ServeConfig) -> Result<ServeReport> {
        ServeDriver::new(self).run(sc)
    }

    /// Evaluate `base` at each offered rate — the utilization-vs-latency
    /// curve. The service profile is warmed serially first, so the
    /// parallel path only takes memo hits and the report list is
    /// byte-identical to the serial path's (asserted in
    /// `tests/serve_api.rs`).
    pub fn serve_sweep(
        &self,
        base: &ServeConfig,
        rates: &[f64],
        parallel: bool,
    ) -> Result<Vec<ServeReport>> {
        base.validate().map_err(anyhow::Error::msg)?;
        let driver = ServeDriver::new(self);
        driver.profile(base.workload, &base.cfg)?;
        let eval = |rate: &f64| -> Result<ServeReport> {
            let mut sc = base.clone();
            sc.rate = *rate;
            driver.run(&sc)
        };
        if !parallel || rates.len() < 2 {
            return rates.iter().map(eval).collect();
        }
        let n_threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        let chunk = crate::util::ceil_div(rates.len(), n_threads);
        let reports: Vec<Result<ServeReport>> = std::thread::scope(|s| {
            let eval = &eval;
            let handles: Vec<_> = rates
                .chunks(chunk.max(1))
                .map(|rs| s.spawn(move || rs.iter().map(eval).collect::<Vec<_>>()))
                .collect();
            handles
                .into_iter()
                .flat_map(|h| h.join().expect("serve sweep worker panicked"))
                .collect()
        });
        reports.into_iter().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::arrivals::ArrivalKind;

    fn sc_with(rate_gap_cycles: f64) -> ServeConfig {
        let cfg = ArchConfig::baseline();
        let clock = cfg.timing.clock_hz();
        ServeConfig::new(cfg, Workload::Fig1, clock / rate_gap_cycles)
            .arrival(ArrivalKind::Fixed)
            .requests(50)
            .warmup(0.0)
    }

    #[test]
    fn batch_cycles_is_affine() {
        let p = ServiceProfile { single_cycles: 1000, steady_cycles: 40, lanes: 1 };
        assert_eq!(p.batch_cycles(1), 1000);
        assert_eq!(p.batch_cycles(2), 1040);
        assert_eq!(p.batch_cycles(9), 1320);
    }

    #[test]
    fn lanes_split_batches_into_waves() {
        // Four data-parallel channels: a batch of four runs as one wave.
        let p = ServiceProfile { single_cycles: 1000, steady_cycles: 40, lanes: 4 };
        assert_eq!(p.batch_cycles(1), 1000);
        assert_eq!(p.batch_cycles(4), 1000, "one wave fills all four lanes");
        assert_eq!(p.batch_cycles(5), 1040, "fifth request starts a second wave");
        assert_eq!(p.batch_cycles(9), 1080);
    }

    #[test]
    fn low_load_latency_equals_service_time() {
        // Gap 1000 cycles, service 100: no request ever waits.
        let sc = sc_with(1000.0);
        let prof = ServiceProfile { single_cycles: 100, steady_cycles: 100, lanes: 1 };
        let r = simulate_stream(&sc, prof);
        assert_eq!(r.completed, 50);
        assert_eq!(r.dropped, 0);
        assert_eq!(r.batches, 50, "every request is its own batch");
        assert_eq!((r.latency.p50, r.latency.p99, r.latency.max), (100, 100, 100));
        assert_eq!(r.latency.mean, 100.0);
        assert_eq!(r.makespan_cycles, 50 * 1000 + 100);
        assert!(r.utilization < 0.11, "mostly idle: {}", r.utilization);
    }

    #[test]
    fn saturation_drops_and_pegs_utilization() {
        // Gap 100 cycles, service 1000: offered load is 10x capacity.
        let sc = sc_with(100.0).requests(200).queue_depth(4);
        let prof = ServiceProfile { single_cycles: 1000, steady_cycles: 1000, lanes: 1 };
        let r = simulate_stream(&sc, prof);
        assert!(r.dropped > 0, "overload must overflow the queue");
        assert_eq!(r.completed + r.dropped, 200);
        assert_eq!(r.queue_max, 4, "queue pegged at its capacity");
        assert!(r.utilization > 0.95, "server never idles: {}", r.utilization);
    }

    #[test]
    fn batch_timeout_delays_partial_batches() {
        // Gap 1000, batch 4 never fills, timeout 500: each request
        // dispatches alone at arrival + 500 — except the last, which
        // drains eagerly once the stream is over.
        let sc = sc_with(1000.0).requests(3).batch(4).batch_timeout(500);
        let prof = ServiceProfile { single_cycles: 100, steady_cycles: 10, lanes: 1 };
        let r = simulate_stream(&sc, prof);
        assert_eq!(r.completed, 3);
        assert_eq!(r.batches, 3);
        // Two timeout-delayed requests at 600 cycles, one eager at 100.
        assert_eq!(r.latency.max, 600);
        assert_eq!(r.latency.p50, 600);
        assert_eq!(r.latency.mean, (600.0 + 600.0 + 100.0) / 3.0);
    }

    #[test]
    fn batching_amortizes_service() {
        // Gap 100, single 1000, steady 10: batch 8 sustains the load
        // (8 requests cost 1070 cycles vs 800 cycles of arrivals is
        // still over, but far less over than 8x1000).
        let sc1 = sc_with(100.0).requests(160).queue_depth(200);
        let sc8 = sc_with(100.0).requests(160).queue_depth(200).batch(8);
        let prof = ServiceProfile { single_cycles: 1000, steady_cycles: 10, lanes: 1 };
        let r1 = simulate_stream(&sc1, prof);
        let r8 = simulate_stream(&sc8, prof);
        assert!(r8.mean_batch > 1.0, "batches must actually form");
        assert!(r8.throughput_rps > r1.throughput_rps, "batching must raise throughput");
        assert!(r8.batch_shapes >= 1);
        // Pure function: an identical rerun is identical.
        assert_eq!(simulate_stream(&sc8, prof), r8);
    }

    #[test]
    fn warmup_trims_the_front() {
        let sc = sc_with(1000.0).requests(10).warmup(0.3);
        let prof = ServiceProfile { single_cycles: 100, steady_cycles: 100, lanes: 1 };
        let r = simulate_stream(&sc, prof);
        assert_eq!(r.warmup_trimmed, 3);
        assert_eq!(r.latency.samples, 7);
    }

    #[test]
    fn deadline_misses_are_split_from_completions() {
        // Timeout-delayed requests finish at 600 cycles, the eager last
        // one at 100 (see batch_timeout_delays_partial_batches). With a
        // 550-cycle deadline the projection at admission (~100 cycles)
        // still admits everyone, so the two delayed requests become
        // deadline *misses* — served, but not completed.
        let sc = sc_with(1000.0).requests(3).batch(4).batch_timeout(500).deadline(550);
        let prof = ServiceProfile { single_cycles: 100, steady_cycles: 10, lanes: 1 };
        let r = simulate_stream(&sc, prof);
        assert_eq!(r.completed, 1);
        assert_eq!(r.dropped_deadline_miss, 2);
        assert_eq!(r.dropped, 2, "misses count as drops");
        assert_eq!(r.dropped_queue_full + r.dropped_deadline_shed, 0);
        assert_eq!(r.completed + r.dropped, 3, "conservation");
        assert_eq!(r.batches, 3, "misses still occupied the server");
        assert!(r.goodput_rps < r.throughput_rps, "misses dilute goodput");
    }

    #[test]
    fn slo_admission_sheds_doomed_requests_before_the_queue_fills() {
        // 10x overload with a 2000-cycle deadline: once two requests are
        // backed up, a new arrival's projected completion (>= 3000
        // cycles out) overshoots its deadline, so admission sheds it —
        // the queue never reaches its 8-deep capacity.
        let sc = sc_with(100.0).requests(50).queue_depth(8).deadline(2000);
        let prof = ServiceProfile { single_cycles: 1000, steady_cycles: 1000, lanes: 1 };
        let r = simulate_stream(&sc, prof);
        assert!(r.dropped_deadline_shed > 0, "overload must shed");
        assert_eq!(r.dropped_queue_full, 0, "shedding keeps the queue below capacity");
        assert!(r.queue_max < 8);
        assert_eq!(r.completed + r.dropped, 50, "conservation");
        // Shedding at admission means what *is* served meets its SLO.
        assert_eq!(r.dropped_deadline_miss, 0);
        assert!(r.latency.max <= 2000);
    }

    #[test]
    fn client_retries_recover_requests_a_full_queue_rejected() {
        // Burst at 10-cycle gaps against a 100-cycle server with a
        // 2-deep queue: most arrivals bounce. Retrying clients re-offer
        // with exponential backoff and land as the backlog drains.
        let plain = sc_with(10.0).requests(20).queue_depth(2);
        let retrying = sc_with(10.0).requests(20).queue_depth(2).client_retries(5).backoff(50);
        let prof = ServiceProfile { single_cycles: 100, steady_cycles: 100, lanes: 1 };
        let r0 = simulate_stream(&plain, prof);
        let r1 = simulate_stream(&retrying, prof);
        assert!(r0.dropped_queue_full > 0, "the burst must overflow the queue");
        assert!(r1.completed > r0.completed, "retries must recover rejected requests");
        // With a retry budget every terminal drop is a retry exhaustion.
        assert_eq!(r1.dropped_queue_full, 0);
        assert_eq!(r1.dropped, r1.dropped_retry_exhausted);
        assert_eq!(r1.completed + r1.dropped, 20, "conservation");
        // Pure function: an identical rerun is identical.
        assert_eq!(simulate_stream(&retrying, prof), r1);
    }
}
