//! Open-loop arrival processes for the serving simulator.
//!
//! Open-loop means requests arrive on their own schedule regardless of
//! how the server is doing — the honest model for internet traffic,
//! where a slow server does not slow the users down, it just grows the
//! queue. Both processes are driven by the crate PRNG
//! ([`crate::util::rng::XorShift64`]) from an explicit seed, so a
//! [`crate::serve::ServeReport`] is byte-reproducible.

use crate::util::rng::XorShift64;

/// The arrival process shaping the request stream.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ArrivalKind {
    /// Poisson process: exponential interarrival gaps around the mean.
    /// The standard open-loop traffic model; bursts happen.
    Poisson,
    /// Deterministic fixed-rate arrivals: every gap is exactly the mean.
    /// Useful for queueing-theory sanity tests (a D/D/1 stream below
    /// saturation never queues).
    Fixed,
}

/// One row per kind: (variant, display name, CLI aliases) — the same
/// table treatment as [`crate::config::Engine`].
const ARRIVAL_TABLE: &[(ArrivalKind, &str, &[&str])] = &[
    (ArrivalKind::Poisson, "poisson", &["exp"]),
    (ArrivalKind::Fixed, "fixed", &["det"]),
];

impl ArrivalKind {
    /// Every arrival kind, in `ARRIVAL_TABLE` order.
    pub const ALL: [ArrivalKind; 2] = [ArrivalKind::Poisson, ArrivalKind::Fixed];

    fn row(&self) -> &'static (ArrivalKind, &'static str, &'static [&'static str]) {
        ARRIVAL_TABLE
            .iter()
            .find(|row| row.0 == *self)
            .expect("every ArrivalKind variant must have an ARRIVAL_TABLE row")
    }

    /// Display name, e.g. `poisson`.
    pub fn name(&self) -> &'static str {
        self.row().1
    }

    /// Parse a CLI spelling: the display name or any alias,
    /// case-insensitively.
    pub fn parse(s: &str) -> Result<Self, String> {
        let t = s.trim().to_ascii_lowercase();
        for &(k, name, aliases) in ARRIVAL_TABLE {
            if t == name || aliases.contains(&t.as_str()) {
                return Ok(k);
            }
        }
        let names: Vec<&str> = ARRIVAL_TABLE.iter().map(|row| row.1).collect();
        Err(format!("unknown arrival process {s:?} ({})", names.join("|")))
    }
}

/// Generate `n` request arrival times in cycles, sorted non-decreasing,
/// with mean interarrival gap `mean_gap` cycles. Gaps accumulate in f64
/// and each cumulative time rounds to the nearest cycle, so scaling the
/// rate scales the whole stream (same seed → same unit draws).
pub fn arrival_times(kind: ArrivalKind, n: usize, mean_gap: f64, seed: u64) -> Vec<u64> {
    debug_assert!(mean_gap > 0.0);
    let mut rng = XorShift64::new(seed);
    let mut t = 0.0f64;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let gap = match kind {
            ArrivalKind::Fixed => mean_gap,
            ArrivalKind::Poisson => rng.next_exp(mean_gap),
        };
        t += gap;
        out.push(t.round() as u64);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_drives_name_and_parse() {
        assert_eq!(ARRIVAL_TABLE.len(), ArrivalKind::ALL.len());
        for (row, k) in ARRIVAL_TABLE.iter().zip(ArrivalKind::ALL) {
            assert_eq!(row.0, k, "ARRIVAL_TABLE and ALL must agree on order");
        }
        for k in ArrivalKind::ALL {
            assert_eq!(ArrivalKind::parse(k.name()).unwrap(), k);
            assert_eq!(ArrivalKind::parse(&k.name().to_ascii_uppercase()).unwrap(), k);
        }
        assert_eq!(ArrivalKind::parse("exp").unwrap(), ArrivalKind::Poisson);
        assert_eq!(ArrivalKind::parse("det").unwrap(), ArrivalKind::Fixed);
        let e = ArrivalKind::parse("nope").unwrap_err();
        assert!(e.contains("poisson|fixed"), "{e}");
    }

    #[test]
    fn fixed_arrivals_are_exact_multiples() {
        let ts = arrival_times(ArrivalKind::Fixed, 5, 100.0, 42);
        assert_eq!(ts, vec![100, 200, 300, 400, 500]);
    }

    #[test]
    fn arrivals_are_sorted_and_deterministic() {
        for kind in ArrivalKind::ALL {
            let a = arrival_times(kind, 500, 37.5, 7);
            let b = arrival_times(kind, 500, 37.5, 7);
            assert_eq!(a, b, "{} not deterministic", kind.name());
            assert!(a.windows(2).all(|w| w[0] <= w[1]), "{} not sorted", kind.name());
        }
    }

    #[test]
    fn poisson_mean_gap_is_roughly_right() {
        let n = 50_000;
        let ts = arrival_times(ArrivalKind::Poisson, n, 200.0, 11);
        let mean = *ts.last().unwrap() as f64 / n as f64;
        assert!((mean - 200.0).abs() < 200.0 * 0.03, "mean gap {mean}");
    }

    #[test]
    fn rate_scaling_scales_the_stream() {
        // Same seed, double the gap: every arrival lands ~2x later.
        let fast = arrival_times(ArrivalKind::Poisson, 100, 50.0, 3);
        let slow = arrival_times(ArrivalKind::Poisson, 100, 100.0, 3);
        for (f, s) in fast.iter().zip(&slow) {
            assert!((*s as f64 - 2.0 * *f as f64).abs() <= 2.0, "{f} vs {s}");
        }
    }
}
