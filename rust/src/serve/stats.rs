//! Latency percentile accumulation and the serving report.
//!
//! Percentiles use the **nearest-rank** definition (the smallest sample
//! such that at least `q·n` samples are ≤ it): no interpolation, so every
//! reported latency is one a request actually saw, and fixed inputs give
//! byte-identical reports.

use crate::config::Engine;
use crate::serve::arrivals::ArrivalKind;
use crate::util::table::{pct, Table};
use std::fmt::Write as _;

/// Summary statistics over a set of per-request latencies (cycles).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct LatencyStats {
    /// Number of samples the percentiles were computed over.
    pub samples: usize,
    /// Median latency in cycles (nearest rank).
    pub p50: u64,
    /// 95th-percentile latency in cycles (nearest rank).
    pub p95: u64,
    /// 99th-percentile latency in cycles (nearest rank).
    pub p99: u64,
    /// Arithmetic mean latency in cycles.
    pub mean: f64,
    /// Worst-case latency in cycles.
    pub max: u64,
}

/// Nearest-rank percentile of a sorted slice: element at ceil(q·n), 1-based.
fn percentile(sorted: &[u64], q: f64) -> u64 {
    debug_assert!(!sorted.is_empty());
    let n = sorted.len();
    let rank = ((q * n as f64).ceil() as usize).clamp(1, n);
    sorted[rank - 1]
}

/// Compute [`LatencyStats`] over a latency sample set (any order). An
/// empty slice yields the all-zero default.
pub fn latency_stats(latencies: &[u64]) -> LatencyStats {
    if latencies.is_empty() {
        return LatencyStats::default();
    }
    let mut sorted = latencies.to_vec();
    sorted.sort_unstable();
    let sum: u128 = sorted.iter().map(|&v| v as u128).sum();
    LatencyStats {
        samples: sorted.len(),
        p50: percentile(&sorted, 0.50),
        p95: percentile(&sorted, 0.95),
        p99: percentile(&sorted, 0.99),
        mean: sum as f64 / sorted.len() as f64,
        max: *sorted.last().unwrap(),
    }
}

/// Everything one serving run produced: the configuration echo (so a
/// report is self-describing in JSON/CSV output) plus steady-state
/// latency, throughput, and queue metrics.
///
/// Deterministic by construction: every field is a pure function of the
/// [`crate::serve::ServeConfig`], so two runs with the same config — or
/// the serial and threaded sweep paths — serialize byte-identically.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeReport {
    /// Config label in paper notation, e.g. `Fused4/G32K_L256`.
    pub label: String,
    /// System display name, e.g. `Fused4`.
    pub system: String,
    /// Workload display name, e.g. `ResNet18_Full`.
    pub workload: String,
    /// Simulation engine that produced the service profile.
    pub engine: Engine,
    /// Arrival process the request stream was drawn from.
    pub arrival: ArrivalKind,
    /// Offered load in requests per second of wall-clock time.
    pub rate_rps: f64,
    /// Requests generated (arrived, whether admitted or dropped).
    pub requests: usize,
    /// Maximum batch size the dispatcher forms.
    pub batch: usize,
    /// Cycles a partial batch waits for stragglers (0 = dispatch eagerly).
    pub batch_timeout: u64,
    /// Admission queue capacity (waiting requests).
    pub queue_depth: usize,
    /// PRNG seed the arrival stream was drawn from.
    pub seed: u64,
    /// Per-request latency SLO in cycles (0 = none; config echo).
    pub deadline: u64,
    /// Client retry budget per rejected request (config echo).
    pub client_retries: u32,
    /// Base client backoff in cycles (config echo).
    pub backoff: u64,
    /// Requests served *within their deadline* (with no deadline, every
    /// served request).
    pub completed: usize,
    /// Requests that never completed in time: the sum of the four
    /// `dropped_*` classifications below. `completed + dropped ==
    /// requests` always holds.
    pub dropped: usize,
    /// Drops because the admission queue was full (retry budget 0).
    pub dropped_queue_full: usize,
    /// Drops shed at admission because the projected completion already
    /// overshot the deadline (retry budget 0).
    pub dropped_deadline_shed: usize,
    /// Requests served past their deadline (they occupied the server but
    /// do not count as completions).
    pub dropped_deadline_miss: usize,
    /// Requests whose client retry budget ran out while being rejected.
    pub dropped_retry_exhausted: usize,
    /// Batches dispatched.
    pub batches: usize,
    /// Mean requests per dispatched batch (served, whether or not they
    /// made their deadline).
    pub mean_batch: f64,
    /// Completed requests trimmed from the front as warmup before
    /// computing [`ServeReport::latency`].
    pub warmup_trimmed: usize,
    /// Latency statistics over the post-warmup completions, in cycles
    /// (deadline misses excluded).
    pub latency: LatencyStats,
    /// *Served* requests (completions plus deadline misses) per second of
    /// wall-clock time over the makespan.
    pub throughput_rps: f64,
    /// Completed — deadline-meeting — requests per second of wall-clock
    /// time over the makespan. Equal to [`ServeReport::throughput_rps`]
    /// when no deadline is set.
    pub goodput_rps: f64,
    /// Fraction of the makespan the channel was busy serving batches.
    pub utilization: f64,
    /// Time-weighted mean admission-queue depth over the makespan.
    pub queue_mean: f64,
    /// Deepest the admission queue ever got.
    pub queue_max: usize,
    /// Service cycles for a batch of one (the memoized schedule result).
    pub service_single: u64,
    /// Marginal service cycles per extra request in a batch (the
    /// pipeline initiation interval).
    pub service_steady: u64,
    /// Distinct batch sizes dispatched (each costed once, then looked up).
    pub batch_shapes: usize,
    /// Cycle at which the last batch finished service.
    pub makespan_cycles: u64,
}

impl ServeReport {
    /// Publish the run's outcome into a metrics registry (`serve.*`
    /// namespace): request/batch counters plus throughput, utilization,
    /// queue and latency-percentile gauges. See
    /// [`crate::obs::MetricsRegistry`].
    pub fn publish_metrics(&self, m: &crate::obs::MetricsRegistry) {
        m.add("serve.requests", self.requests as u64);
        m.add("serve.completed", self.completed as u64);
        m.add("serve.dropped", self.dropped as u64);
        m.add("serve.dropped_queue_full", self.dropped_queue_full as u64);
        m.add("serve.dropped_deadline_shed", self.dropped_deadline_shed as u64);
        m.add("serve.dropped_deadline_miss", self.dropped_deadline_miss as u64);
        m.add("serve.dropped_retry_exhausted", self.dropped_retry_exhausted as u64);
        m.add("serve.batches", self.batches as u64);
        m.gauge("serve.throughput_rps", self.throughput_rps);
        m.gauge("serve.goodput_rps", self.goodput_rps);
        m.gauge("serve.utilization", self.utilization);
        m.gauge("serve.queue_mean", self.queue_mean);
        m.gauge("serve.queue_max", self.queue_max as f64);
        m.gauge("serve.latency_p50", self.latency.p50 as f64);
        m.gauge("serve.latency_p95", self.latency.p95 as f64);
        m.gauge("serve.latency_p99", self.latency.p99 as f64);
        m.gauge("serve.latency_mean", self.latency.mean);
        m.gauge("serve.latency_max", self.latency.max as f64);
    }

    /// Render the report as a human-readable text block (the default
    /// `pimfused serve` output).
    pub fn render(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(
            out,
            "serve: {} on {} ({} engine, {} arrivals, seed {})",
            self.label,
            self.workload,
            self.engine.name(),
            self.arrival.name(),
            self.seed
        );
        let _ = writeln!(
            out,
            "offered {:.1} req/s, {} requests, batch<={} (timeout {} cyc), queue depth {}",
            self.rate_rps, self.requests, self.batch, self.batch_timeout, self.queue_depth
        );
        if self.deadline > 0 || self.client_retries > 0 {
            let _ = writeln!(
                out,
                "deadline {} cyc, client retries {} (backoff {} cyc)",
                self.deadline, self.client_retries, self.backoff
            );
        }
        let mut t = Table::new(vec!["metric", "value"]);
        t.row(vec!["completed".to_string(), self.completed.to_string()]);
        t.row(vec!["dropped".to_string(), self.dropped.to_string()]);
        t.row(vec![
            "drop split".to_string(),
            format!(
                "{} queue-full, {} shed, {} missed, {} retries-exhausted",
                self.dropped_queue_full,
                self.dropped_deadline_shed,
                self.dropped_deadline_miss,
                self.dropped_retry_exhausted
            ),
        ]);
        t.row(vec![
            "batches".to_string(),
            format!("{} (mean {:.2} req)", self.batches, self.mean_batch),
        ]);
        t.row(vec!["throughput".to_string(), format!("{:.1} req/s", self.throughput_rps)]);
        t.row(vec!["goodput".to_string(), format!("{:.1} req/s", self.goodput_rps)]);
        t.row(vec!["utilization".to_string(), pct(self.utilization)]);
        t.row(vec!["p50 latency".to_string(), format!("{} cyc", self.latency.p50)]);
        t.row(vec!["p95 latency".to_string(), format!("{} cyc", self.latency.p95)]);
        t.row(vec!["p99 latency".to_string(), format!("{} cyc", self.latency.p99)]);
        t.row(vec!["mean latency".to_string(), format!("{:.1} cyc", self.latency.mean)]);
        t.row(vec!["max latency".to_string(), format!("{} cyc", self.latency.max)]);
        t.row(vec![
            "queue depth".to_string(),
            format!("mean {:.2}, max {}", self.queue_mean, self.queue_max),
        ]);
        t.row(vec![
            "service".to_string(),
            format!("{} cyc single, {} cyc steady", self.service_single, self.service_steady),
        ]);
        t.row(vec!["makespan".to_string(), format!("{} cyc", self.makespan_cycles)]);
        out += &t.render();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_sample_set_is_all_zero() {
        assert_eq!(latency_stats(&[]), LatencyStats::default());
    }

    #[test]
    fn single_sample_is_every_percentile() {
        let s = latency_stats(&[42]);
        assert_eq!(s.samples, 1);
        assert_eq!((s.p50, s.p95, s.p99, s.max), (42, 42, 42, 42));
        assert_eq!(s.mean, 42.0);
    }

    #[test]
    fn nearest_rank_on_a_known_set() {
        // 1..=100: nearest-rank pNN of n=100 is exactly NN.
        let v: Vec<u64> = (1..=100).collect();
        let s = latency_stats(&v);
        assert_eq!(s.p50, 50);
        assert_eq!(s.p95, 95);
        assert_eq!(s.p99, 99);
        assert_eq!(s.max, 100);
        assert_eq!(s.mean, 50.5);
    }

    #[test]
    fn order_does_not_matter() {
        let a = latency_stats(&[5, 1, 9, 3, 7]);
        let b = latency_stats(&[9, 7, 5, 3, 1]);
        assert_eq!(a, b);
        assert_eq!(a.p50, 5);
    }
}
