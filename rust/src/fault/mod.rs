//! Fault injection: seeded, deterministic bank/PIMcore failures and
//! transient per-command errors (DESIGN.md §11).
//!
//! The model distinguishes **permanent** faults — retired DRAM banks and
//! dead PIMcores, fixed for the lifetime of a run — from **transient**
//! faults — per-command errors that force the controller to replay the
//! command (bounded retries, then escalation to the host). Both are
//! expressed by a [`FaultConfig`] carried on
//! [`crate::config::ArchConfig`], expanded once per run into a
//! [`FaultPlan`] by seeded sampling ([`crate::util::rng::XorShift64`]),
//! so the same config always degrades the same way — across sessions,
//! engines, and serial-vs-threaded sweeps.
//!
//! Degradation is **core-granular**: a retired bank takes its owning
//! PIMcore offline (the lockstep fan-in would otherwise go ragged), and
//! a dead PIMcore idles its banks. Work remaps onto the surviving cores
//! by even spreading ([`FaultPlan::spread_even`]), which preserves
//! per-command totals (energy is conserved) while the per-core maximum —
//! what bounds a lockstep command — grows as `ceil(total / k)` for `k`
//! survivors. Retirement sets are *nested in the retired-bank count*
//! (the sample for `n+1` retired banks extends the sample for `n`), so
//! degraded cycle counts are monotone non-decreasing as banks retire.

use crate::config::ArchConfig;
use crate::trace::{BankMask, PerCore, MAX_CORES};
use crate::util::rng::XorShift64;

/// Transient-fault probabilities are integer parts-per-million so the
/// config stays `Eq + Hash` (memo-cache keys hash whole configs).
pub const PPM_SCALE: u32 = 1_000_000;

/// Fault-injection knobs, carried on [`ArchConfig::faults`]. The
/// all-zero default injects nothing and leaves every code path — and
/// every serialized byte — identical to a fault-free build.
///
/// [`ArchConfig::faults`]: crate::config::ArchConfig::faults
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub struct FaultConfig {
    /// Seed for the fault sampler (independent of workload seeds).
    pub seed: u64,
    /// Number of permanently retired DRAM banks.
    pub retired_banks: usize,
    /// Number of dead PIMcores (in addition to cores lost to retired
    /// banks).
    pub dead_cores: usize,
    /// Per-command transient-error probability in parts per million
    /// (`p = transient_ppm / 1e6`); each failed attempt is replayed.
    pub transient_ppm: u32,
    /// Replay budget per command; a command still failing after this
    /// many replays escalates to the host as a permanent fault.
    pub max_retries: u32,
    /// Number of whole channels retired in a multi-channel config
    /// ([`crate::config::ArchConfig::channels`]): the highest-indexed
    /// channels go offline and their work redistributes over the
    /// survivors (DESIGN.md §12). Ignored (and must be 0) when the
    /// config has a single channel.
    pub dead_channels: usize,
}

impl FaultConfig {
    /// Whether this config injects nothing at all (the default).
    pub fn is_none(&self) -> bool {
        self.retired_banks == 0
            && self.dead_cores == 0
            && self.transient_ppm == 0
            && self.dead_channels == 0
    }

    /// Whether any *permanent* fault (retired bank / dead core) is
    /// configured — what forces the trace generator to remap work.
    pub fn has_permanent(&self) -> bool {
        self.retired_banks > 0 || self.dead_cores > 0
    }

    /// One-line human summary (`banks=2 cores=1 p=0.001000 retries=3
    /// seed=7`) for report headers. A `channels=` knob appears only when
    /// whole channels are retired, so single-channel summaries stay
    /// byte-identical to the pre-axis form.
    pub fn summary(&self) -> String {
        let base = format!(
            "banks={} cores={} p={:.6} retries={} seed={}",
            self.retired_banks,
            self.dead_cores,
            self.transient_ppm as f64 / PPM_SCALE as f64,
            self.max_retries,
            self.seed
        );
        if self.dead_channels > 0 {
            format!("{base} channels={}", self.dead_channels)
        } else {
            base
        }
    }

    /// Check the knobs against the **per-channel** geometry plus the
    /// channel count. Bank/core knobs replicate identically in every
    /// channel, so they validate against one channel's bank count — not
    /// the `channels × num_banks` aggregate — and at least one PIMcore
    /// must survive per surviving channel with its full fan-in intact,
    /// else no remap target exists. `dead_channels` must leave at least
    /// one channel alive.
    pub fn validate(
        &self,
        num_banks: usize,
        banks_per_pimcore: usize,
        channels: usize,
    ) -> Result<(), String> {
        if self.dead_channels >= channels.max(1) {
            return Err(format!(
                "dead_channels {} must leave at least one of {} channels alive",
                self.dead_channels,
                channels.max(1)
            ));
        }
        if self.transient_ppm > PPM_SCALE {
            return Err(format!(
                "transient fault probability {} ppm exceeds {} (p > 1)",
                self.transient_ppm, PPM_SCALE
            ));
        }
        let cores = num_banks / banks_per_pimcore.max(1);
        if self.dead_cores >= cores && cores > 0 {
            return Err(format!(
                "dead_cores {} must leave at least one of {} PIMcores alive",
                self.dead_cores, cores
            ));
        }
        if self.retired_banks + banks_per_pimcore > num_banks {
            return Err(format!(
                "retired_banks {} must leave one PIMcore's fan-in ({} banks) of {} intact",
                self.retired_banks, banks_per_pimcore, num_banks
            ));
        }
        Ok(())
    }
}

/// Replay verdict for one command under transient faults
/// ([`FaultPlan::replays_for`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Replays {
    /// Replays the controller issues after the first attempt.
    pub count: u32,
    /// Whether the retry budget ran out (the command escalates to the
    /// host as a permanent fault; execution still completes).
    pub escalated: bool,
}

/// The expanded, deterministic fault state of one run: which cores
/// survive, which banks they keep, and the per-command replay draws.
///
/// Built once per run by [`FaultPlan::build`]; two builds from equal
/// configs compare equal (`Eq`), which the property suite exploits to
/// prove cross-session and serial-vs-threaded reproducibility.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultPlan {
    seed: u64,
    transient_ppm: u32,
    max_retries: u32,
    num_cores: usize,
    num_banks: usize,
    banks_per_core: usize,
    core_alive: [bool; MAX_CORES],
}

impl FaultPlan {
    /// Expand `cfg.faults` against `cfg`'s channel geometry. Sampling is
    /// a pure function of the fault seed: dead cores draw first, then
    /// retired banks draw one at a time with a deterministic forward
    /// probe that skips already-retired banks and the *protected* core
    /// (the lowest survivor, which guarantees a remap target). Because
    /// each extra retired bank only appends draws, the retirement set
    /// for `n+1` banks extends the set for `n` — survivor counts are
    /// monotone in the retired-bank count.
    pub fn build(cfg: &ArchConfig) -> FaultPlan {
        let fc = &cfg.faults;
        let bpc = cfg.banks_per_pimcore.max(1);
        let num_banks = cfg.num_banks.min(MAX_CORES);
        let num_cores = (num_banks / bpc).max(1);
        let mut core_alive = [false; MAX_CORES];
        for slot in core_alive.iter_mut().take(num_cores) {
            *slot = true;
        }
        let mut plan = FaultPlan {
            seed: fc.seed,
            transient_ppm: fc.transient_ppm,
            max_retries: fc.max_retries,
            num_cores,
            num_banks,
            banks_per_core: bpc,
            core_alive,
        };
        if fc.dead_cores == 0 && fc.retired_banks == 0 {
            return plan;
        }
        let mut rng = XorShift64::new(fc.seed);
        let dead_target = fc.dead_cores.min(num_cores - 1);
        let mut killed = 0;
        while killed < dead_target {
            let c = rng.next_below(num_cores as u64) as usize;
            if plan.core_alive[c] {
                plan.core_alive[c] = false;
                killed += 1;
            }
        }
        let protected = (0..num_cores)
            .find(|&c| plan.core_alive[c])
            .expect("dead-core sampling keeps one core alive");
        let mut retired = [false; MAX_CORES];
        let target = fc.retired_banks.min(num_banks.saturating_sub(bpc));
        let mut sampled = 0;
        while sampled < target {
            let mut b = rng.next_below(num_banks as u64) as usize;
            let mut probes = 0;
            while probes < num_banks && (retired[b] || b / bpc == protected) {
                b = (b + 1) % num_banks;
                probes += 1;
            }
            if probes == num_banks {
                break;
            }
            retired[b] = true;
            plan.core_alive[b / bpc] = false;
            sampled += 1;
        }
        plan
    }

    /// Whether any PIMcore is offline (permanent degradation active).
    pub fn is_degraded(&self) -> bool {
        self.alive_core_count() < self.num_cores
    }

    /// Whether transient faults are configured (commands may replay).
    pub fn has_transients(&self) -> bool {
        self.transient_ppm > 0
    }

    /// Number of PIMcores still online.
    pub fn alive_core_count(&self) -> usize {
        self.core_alive[..self.num_cores].iter().filter(|&&a| a).count()
    }

    /// Whether PIMcore `c` is online (out-of-range cores never are).
    pub fn core_alive(&self, c: usize) -> bool {
        c < self.num_cores && self.core_alive[c]
    }

    /// Online PIMcore indices, ascending.
    pub fn alive_cores(&self) -> impl Iterator<Item = usize> + '_ {
        (0..self.num_cores).filter(|&c| self.core_alive[c])
    }

    /// The banks of the surviving cores — every bank degraded host I/O
    /// and cross-bank walks are allowed to touch. Never contains a
    /// retired bank or a dead core's banks.
    pub fn surviving_banks(&self) -> BankMask {
        let bpc = self.banks_per_core;
        BankMask::from_fn(self.num_banks, |b| self.core_alive(b / bpc))
    }

    /// Number of banks behind surviving cores.
    pub fn surviving_bank_count(&self) -> usize {
        self.alive_core_count() * self.banks_per_core
    }

    /// Transient-fault replay draws for command `cmd_idx`: a dedicated
    /// PRNG stream per command (seed mixed with the index), so replay
    /// verdicts are independent of trace length and issue order — the
    /// analytic engine, the event scheduler, and the audit all see the
    /// same draws for the same command.
    pub fn replays_for(&self, cmd_idx: usize) -> Replays {
        if self.transient_ppm == 0 {
            return Replays::default();
        }
        let mix = (cmd_idx as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = XorShift64::new(self.seed ^ mix ^ 0xD1B5_4A32_D192_ED03);
        let mut count = 0u32;
        loop {
            if rng.next_below(PPM_SCALE as u64) >= self.transient_ppm as u64 {
                return Replays { count, escalated: false };
            }
            if count >= self.max_retries {
                return Replays { count, escalated: true };
            }
            count += 1;
        }
    }

    /// Spread `total` units of work evenly over the surviving cores of a
    /// `p`-core channel: each survivor gets `total / k`, with the
    /// remainder going one unit each to the lowest survivors. The sum is
    /// exactly `total` (energy tallies are conserved) and the maximum is
    /// `ceil(total / k)` — monotone non-decreasing as survivors vanish,
    /// which is what makes degraded cycle counts monotone.
    pub fn spread_even(&self, total: u64, p: usize) -> PerCore {
        let mut pc = PerCore::zero(p);
        let k = self.alive_core_count() as u64;
        if k == 0 || total == 0 {
            return pc;
        }
        let (per, rem) = (total / k, total % k);
        for (i, c) in self.alive_cores().enumerate() {
            if c < p {
                pc.set(c, per + u64::from((i as u64) < rem));
            }
        }
        pc
    }

    /// The same value on every surviving core of a `p`-core channel
    /// (zero on dead cores) — the degraded analogue of
    /// [`PerCore::uniform`].
    pub fn uniform_alive(&self, p: usize, v: u64) -> PerCore {
        let mut pc = PerCore::zero(p);
        for c in self.alive_cores() {
            if c < p {
                pc.set(c, v);
            }
        }
        pc
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ArchConfig, System};

    fn cfg_with(faults: FaultConfig) -> ArchConfig {
        let mut cfg = ArchConfig::system(System::Fused16, 32 * 1024, 256);
        cfg.faults = faults;
        cfg
    }

    #[test]
    fn default_config_injects_nothing() {
        let fc = FaultConfig::default();
        assert!(fc.is_none());
        assert!(!fc.has_permanent());
        fc.validate(16, 1, 1).unwrap();
        let plan = FaultPlan::build(&cfg_with(fc));
        assert!(!plan.is_degraded());
        assert!(!plan.has_transients());
        assert_eq!(plan.alive_core_count(), 16);
        assert_eq!(plan.surviving_bank_count(), 16);
        assert_eq!(plan.surviving_banks(), BankMask::all(16));
        assert_eq!(plan.replays_for(0), Replays::default());
    }

    #[test]
    fn validate_rejects_out_of_range_knobs() {
        let fc = FaultConfig { transient_ppm: PPM_SCALE + 1, ..Default::default() };
        assert!(fc.validate(16, 1, 1).is_err());
        let fc = FaultConfig { dead_cores: 16, ..Default::default() };
        assert!(fc.validate(16, 1, 1).is_err());
        assert!(FaultConfig { dead_cores: 15, ..Default::default() }.validate(16, 1, 1).is_ok());
        let fc = FaultConfig { retired_banks: 16, ..Default::default() };
        assert!(fc.validate(16, 1, 1).is_err());
        // 4-bank fan-in: at most 12 of 16 banks may retire.
        let fc = FaultConfig { retired_banks: 13, ..Default::default() };
        assert!(fc.validate(16, 4, 1).is_err());
        assert!(FaultConfig { retired_banks: 12, ..Default::default() }.validate(16, 4, 1).is_ok());
    }

    #[test]
    fn validate_checks_per_channel_geometry_not_aggregate() {
        // 13 retired banks overflow ONE channel's 4-bank fan-in headroom
        // even when 4 channels × 16 banks = 64 banks exist in aggregate:
        // bank/core faults replicate per channel, so the per-channel
        // geometry is what must stay viable.
        let fc = FaultConfig { retired_banks: 13, ..Default::default() };
        assert!(fc.validate(16, 4, 4).is_err());
        assert!(FaultConfig { retired_banks: 12, ..Default::default() }.validate(16, 4, 4).is_ok());
    }

    #[test]
    fn validate_bounds_dead_channels() {
        let fc = FaultConfig { dead_channels: 1, ..Default::default() };
        assert!(fc.validate(16, 1, 1).is_err(), "single channel cannot retire itself");
        fc.validate(16, 1, 2).unwrap();
        let fc = FaultConfig { dead_channels: 4, ..Default::default() };
        assert!(fc.validate(16, 1, 4).is_err());
        assert!(FaultConfig { dead_channels: 3, ..Default::default() }.validate(16, 1, 4).is_ok());
        assert!(!fc.is_none(), "dead channels count as injected faults");
    }

    #[test]
    fn same_seed_same_plan() {
        let fc = FaultConfig { seed: 7, retired_banks: 3, dead_cores: 2, ..Default::default() };
        let a = FaultPlan::build(&cfg_with(fc));
        let b = FaultPlan::build(&cfg_with(fc));
        assert_eq!(a, b);
        let c = FaultPlan::build(&cfg_with(FaultConfig { seed: 8, ..fc }));
        assert!(a != c || a.surviving_banks() == c.surviving_banks());
    }

    #[test]
    fn retirement_sets_are_nested_in_count() {
        for seed in [1u64, 42, 9999] {
            let mut prev = BankMask::all(16);
            let mut prev_alive = 16;
            for n in 0..=15 {
                let fc = FaultConfig { seed, retired_banks: n, ..Default::default() };
                let plan = FaultPlan::build(&cfg_with(fc));
                let banks = plan.surviving_banks();
                // Survivor set shrinks (or holds) as banks retire, and is
                // a subset of the previous survivor set.
                for b in banks.iter() {
                    assert!(prev.contains(b), "seed {seed} n {n}: bank {b} resurrected");
                }
                assert!(plan.alive_core_count() <= prev_alive);
                assert!(plan.alive_core_count() >= 1, "seed {seed} n {n}: no survivors");
                prev = banks;
                prev_alive = plan.alive_core_count();
            }
        }
    }

    #[test]
    fn retired_banks_take_their_core_offline() {
        // 4-bank fan-in: one retired bank kills a whole 4-bank core.
        let mut cfg = ArchConfig::system(System::Fused4, 32 * 1024, 256);
        cfg.faults = FaultConfig { seed: 3, retired_banks: 1, ..Default::default() };
        let plan = FaultPlan::build(&cfg);
        assert_eq!(plan.alive_core_count(), 3);
        assert_eq!(plan.surviving_bank_count(), 12);
        assert_eq!(plan.surviving_banks().count(), 12);
    }

    #[test]
    fn spread_even_conserves_totals_and_bounds_the_max() {
        let fc = FaultConfig { seed: 5, retired_banks: 6, dead_cores: 3, ..Default::default() };
        let plan = FaultPlan::build(&cfg_with(fc));
        let k = plan.alive_core_count() as u64;
        for total in [0u64, 1, 7, 1000, 12345] {
            let pc = plan.spread_even(total, 16);
            assert_eq!(pc.sum(), total);
            assert_eq!(pc.max(), if total == 0 { 0 } else { total.div_ceil(k) });
            for c in 0..16 {
                if !plan.core_alive(c) {
                    assert_eq!(pc.get(c), 0, "dead core {c} got work");
                }
            }
        }
        let u = plan.uniform_alive(16, 9);
        assert_eq!(u.sum(), 9 * k);
        assert_eq!(u.max(), 9);
    }

    #[test]
    fn replays_are_deterministic_and_bounded() {
        let fc = FaultConfig { seed: 11, transient_ppm: 500_000, max_retries: 3, ..Default::default() };
        let plan = FaultPlan::build(&cfg_with(fc));
        assert!(plan.has_transients());
        let mut total = 0u64;
        for i in 0..1000 {
            let r = plan.replays_for(i);
            assert_eq!(r, plan.replays_for(i), "replay draw not deterministic");
            assert!(r.count <= 3);
            if r.escalated {
                assert_eq!(r.count, 3, "escalation only after the full budget");
            }
            total += r.count as u64;
        }
        // p = 0.5 over 1000 commands: replays happen, but not everywhere.
        assert!(total > 200 && total < 2000, "replay mass {total} implausible for p=0.5");
    }

    #[test]
    fn certain_failure_always_escalates() {
        let fc = FaultConfig { seed: 1, transient_ppm: PPM_SCALE, max_retries: 2, ..Default::default() };
        let plan = FaultPlan::build(&cfg_with(fc));
        for i in 0..16 {
            assert_eq!(plan.replays_for(i), Replays { count: 2, escalated: true });
        }
        // A zero retry budget escalates on the first failure.
        let fc0 = FaultConfig { max_retries: 0, ..fc };
        let plan0 = FaultPlan::build(&cfg_with(fc0));
        assert_eq!(plan0.replays_for(0), Replays { count: 0, escalated: true });
    }

    #[test]
    fn summary_names_every_knob() {
        let fc = FaultConfig {
            seed: 7,
            retired_banks: 2,
            dead_cores: 1,
            transient_ppm: 1000,
            max_retries: 3,
            dead_channels: 0,
        };
        let s = fc.summary();
        for needle in ["banks=2", "cores=1", "p=0.001000", "retries=3", "seed=7"] {
            assert!(s.contains(needle), "{s}");
        }
        // The channels knob appears only when channels actually retire,
        // so single-channel summaries keep their pre-axis bytes.
        assert!(!s.contains("channels="), "{s}");
        let s2 = FaultConfig { dead_channels: 2, ..fc }.summary();
        assert!(s2.contains("channels=2"), "{s2}");
    }
}
