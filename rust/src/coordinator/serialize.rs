//! Hand-rolled JSON / CSV serialization for [`SweepResults`] and
//! [`ServeReport`] lists.
//!
//! The offline crate set has no `serde`, so the writers below emit the
//! formats directly. The schemas are flat and stable — golden-tested in
//! `tests/session_api.rs` and `tests/serve_api.rs`, so treat any change
//! as a breaking change to downstream tooling parsing
//! `pimfused ... --json` / `--csv` output.

use super::grid::{SweepResults, SweepRow};
use crate::serve::ServeReport;
use std::fmt::Write as _;

/// Escape a string for a JSON string literal (without the quotes).
pub(crate) fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

/// Escape a CSV field: quote when it contains a delimiter, quote, or
/// newline; double any embedded quotes.
pub(crate) fn csv_escape(s: &str) -> String {
    if s.contains([',', '"', '\n', '\r']) {
        format!("\"{}\"", s.replace('"', "\"\""))
    } else {
        s.to_string()
    }
}

/// A JSON number: f64 via `Display` (shortest round-trip form); non-finite
/// values (never produced by the pipeline) degrade to `null`.
pub(crate) fn json_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

impl SweepResults {
    /// Serialize to pretty-printed JSON (2-space indent):
    ///
    /// ```json
    /// {
    ///   "baseline": "AiM-like/G2K_L0",
    ///   "rows": [
    ///     { "config": "...", "system": "...", "gbuf_bytes": 2048, ... }
    ///   ]
    /// }
    /// ```
    ///
    /// Failed points carry `"error": "<message>"` and `null` metrics.
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        out.push_str("{\n");
        let _ = writeln!(out, "  \"baseline\": \"{}\",", json_escape(&self.baseline_label));
        out.push_str("  \"rows\": [");
        for (i, row) in self.rows.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push('\n');
            json_row(&mut out, row);
        }
        if !self.rows.is_empty() {
            out.push('\n');
        }
        out.push_str("  ]\n}\n");
        out
    }

    /// Serialize to CSV with a fixed header row. Failed points leave the
    /// metric columns empty and put the message in `error`; analytic rows
    /// (no occupancy breakdown) leave the occupancy columns empty.
    ///
    /// When any row of the sweep is multi-channel, four channel columns
    /// (`channels,partition,interconnect_busy,interconnect_utilization`)
    /// are inserted before `error`; a single-channel-only sweep keeps the
    /// pre-axis header byte-for-byte (golden-tested in
    /// `tests/session_api.rs`).
    pub fn to_csv(&self) -> String {
        let multi = self.rows.iter().any(|r| r.point.cfg.channels > 1);
        let mut out = String::from(
            "config,system,gbuf_bytes,lbuf_bytes,workload,engine,cycles,energy_pj,area_mm2,\
             norm_cycles,norm_energy,norm_area,host_bank_busy,act_window_busy,slid_slices,",
        );
        if multi {
            out.push_str("channels,partition,interconnect_busy,interconnect_utilization,");
        }
        out.push_str("error\n");
        for row in &self.rows {
            let cfg = &row.point.cfg;
            let _ = write!(
                out,
                "{},{},{},{},{},{},",
                csv_escape(&cfg.label()),
                csv_escape(cfg.system.name()),
                cfg.gbuf_bytes,
                cfg.lbuf_bytes,
                csv_escape(row.point.workload.name()),
                cfg.engine.name(),
            );
            match (&row.report, row.norm) {
                (Ok(r), Some(n)) => {
                    let occ = r.occupancy;
                    let host_bk = occ.map(|o| o.host_bank_total().to_string()).unwrap_or_default();
                    let act_bk = occ.map(|o| o.act_busy_total().to_string()).unwrap_or_default();
                    let slid = occ.map(|o| o.slid_slices.to_string()).unwrap_or_default();
                    let _ = write!(
                        out,
                        "{},{},{},{},{},{},{},{},{},",
                        r.cycles,
                        r.energy_pj,
                        r.area_mm2,
                        n.cycles,
                        n.energy,
                        n.area,
                        host_bk,
                        act_bk,
                        slid
                    );
                    if multi {
                        let (ib, iu) = r
                            .channels
                            .as_ref()
                            .map(|c| {
                                (
                                    c.interconnect_busy.to_string(),
                                    c.interconnect_utilization(r.cycles).to_string(),
                                )
                            })
                            .unwrap_or_default();
                        let _ = write!(
                            out,
                            "{},{},{},{},",
                            cfg.channels,
                            cfg.partition.name(),
                            ib,
                            iu
                        );
                    }
                    out.push('\n');
                }
                _ => {
                    let err = row.report.as_ref().err().map(|e| e.to_string()).unwrap_or_default();
                    let _ = write!(out, ",,,,,,,,,");
                    if multi {
                        let _ = write!(out, ",,,,");
                    }
                    let _ = writeln!(out, "{}", csv_escape(&err));
                }
            }
        }
        out
    }
}

/// The flat per-report field list shared by the serve JSON and CSV
/// writers — and by the degrade sweep's serializers, which append it to
/// their failure-state columns (one definition, so the schemas cannot
/// drift): name, value-as-JSON (strings pre-quoted/escaped).
pub(crate) fn serve_fields(r: &ServeReport) -> Vec<(&'static str, String)> {
    vec![
        ("config", format!("\"{}\"", json_escape(&r.label))),
        ("system", format!("\"{}\"", json_escape(&r.system))),
        ("workload", format!("\"{}\"", json_escape(&r.workload))),
        ("engine", format!("\"{}\"", r.engine.name())),
        ("arrival", format!("\"{}\"", r.arrival.name())),
        ("rate_rps", json_f64(r.rate_rps)),
        ("seed", r.seed.to_string()),
        ("requests", r.requests.to_string()),
        ("batch", r.batch.to_string()),
        ("batch_timeout", r.batch_timeout.to_string()),
        ("queue_depth", r.queue_depth.to_string()),
        ("deadline_cycles", r.deadline.to_string()),
        ("client_retries", r.client_retries.to_string()),
        ("backoff_cycles", r.backoff.to_string()),
        ("completed", r.completed.to_string()),
        ("dropped", r.dropped.to_string()),
        ("dropped_queue_full", r.dropped_queue_full.to_string()),
        ("dropped_deadline_shed", r.dropped_deadline_shed.to_string()),
        ("dropped_deadline_miss", r.dropped_deadline_miss.to_string()),
        ("dropped_retry_exhausted", r.dropped_retry_exhausted.to_string()),
        ("batches", r.batches.to_string()),
        ("mean_batch", json_f64(r.mean_batch)),
        ("warmup_trimmed", r.warmup_trimmed.to_string()),
        ("p50_cycles", r.latency.p50.to_string()),
        ("p95_cycles", r.latency.p95.to_string()),
        ("p99_cycles", r.latency.p99.to_string()),
        ("mean_cycles", json_f64(r.latency.mean)),
        ("max_cycles", r.latency.max.to_string()),
        ("throughput_rps", json_f64(r.throughput_rps)),
        ("goodput_rps", json_f64(r.goodput_rps)),
        ("utilization", json_f64(r.utilization)),
        ("queue_depth_mean", json_f64(r.queue_mean)),
        ("queue_depth_max", r.queue_max.to_string()),
        ("service_single_cycles", r.service_single.to_string()),
        ("service_steady_cycles", r.service_steady.to_string()),
        ("batch_shapes", r.batch_shapes.to_string()),
        ("makespan_cycles", r.makespan_cycles.to_string()),
    ]
}

/// Serialize serving reports to pretty-printed JSON (2-space indent),
/// `{"rows": [...]}` with one flat object per report. Deterministic:
/// field order is fixed and every value is a pure function of the
/// [`crate::serve::ServeConfig`].
pub fn serve_to_json(reports: &[ServeReport]) -> String {
    let mut out = String::from("{\n  \"rows\": [");
    for (i, r) in reports.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str("\n    {\n");
        let fields = serve_fields(r);
        for (j, (name, value)) in fields.iter().enumerate() {
            let sep = if j + 1 == fields.len() { "" } else { "," };
            let _ = writeln!(out, "      \"{name}\": {value}{sep}");
        }
        out.push_str("    }");
    }
    if !reports.is_empty() {
        out.push('\n');
    }
    out.push_str("  ]\n}\n");
    out
}

/// Serialize serving reports to CSV: a fixed header row (the
/// [`serve_fields`] names, in order) plus one row per report.
pub fn serve_to_csv(reports: &[ServeReport]) -> String {
    let mut out = String::new();
    for r in reports {
        let fields = serve_fields(r);
        if out.is_empty() {
            let names: Vec<&str> = fields.iter().map(|(n, _)| *n).collect();
            out.push_str(&names.join(","));
            out.push('\n');
        }
        let row: Vec<String> = fields
            .into_iter()
            // JSON string values come pre-quoted; CSV wants them bare.
            .map(|(_, v)| csv_escape(v.trim_matches('"')))
            .collect();
        out.push_str(&row.join(","));
        out.push('\n');
    }
    if out.is_empty() {
        // Header-only output for an empty report list.
        let header: Vec<&str> = serve_field_names();
        out.push_str(&header.join(","));
        out.push('\n');
    }
    out
}

/// The serve schema's column names (kept adjacent to [`serve_fields`];
/// a unit test asserts they agree).
fn serve_field_names() -> Vec<&'static str> {
    vec![
        "config",
        "system",
        "workload",
        "engine",
        "arrival",
        "rate_rps",
        "seed",
        "requests",
        "batch",
        "batch_timeout",
        "queue_depth",
        "deadline_cycles",
        "client_retries",
        "backoff_cycles",
        "completed",
        "dropped",
        "dropped_queue_full",
        "dropped_deadline_shed",
        "dropped_deadline_miss",
        "dropped_retry_exhausted",
        "batches",
        "mean_batch",
        "warmup_trimmed",
        "p50_cycles",
        "p95_cycles",
        "p99_cycles",
        "mean_cycles",
        "max_cycles",
        "throughput_rps",
        "goodput_rps",
        "utilization",
        "queue_depth_mean",
        "queue_depth_max",
        "service_single_cycles",
        "service_steady_cycles",
        "batch_shapes",
        "makespan_cycles",
    ]
}

/// The per-resource utilization object for event-engine rows: busy cycles
/// per resource plus the schedule makespan (consumers derive fractions),
/// the contended command-bus occupancy, the total back-filled cycles the
/// scheduler placed into timeline gaps, the slice cycles placed off
/// their rigid stagger offsets (`slid`, zero when slice pipelining is
/// disabled), the host-residency share of every bank (`host_banks`,
/// zero when residency is disabled), and the reserved tFAW/tRRD window
/// cycles per bank group (`act_windows`).
fn json_utilization(occ: &crate::sim::ResourceOccupancy) -> String {
    let list = |vals: &[u64]| {
        vals.iter().map(|v| v.to_string()).collect::<Vec<_>>().join(", ")
    };
    format!(
        "{{\"makespan\": {}, \"bus\": {}, \"cmdbus\": {}, \"gbcore\": {}, \"host\": {}, \"backfilled\": {}, \"slid\": {}, \"cores\": [{}], \"banks\": [{}], \"host_banks\": [{}], \"act_windows\": [{}]}}",
        occ.makespan,
        occ.bus_busy,
        occ.cmdbus_busy,
        occ.gbcore_busy,
        occ.host_busy,
        occ.backfilled,
        occ.slid_slices,
        list(&occ.core_busy[..occ.num_cores]),
        list(&occ.bank_busy[..occ.num_banks]),
        list(&occ.host_bank_busy[..occ.num_banks]),
        list(&occ.act_busy[..occ.num_groups]),
    )
}

/// The multi-channel summary object for `channels > 1` rows: configured
/// and active channel counts, the partition strategy, interconnect busy
/// cycles and their share of the composed makespan, the total bytes
/// exchanged, the committed transfer count, and each channel's own
/// schedule length (0 for idle/retired channels).
fn json_channels(c: &crate::sim::ChannelReport, makespan: u64) -> String {
    let cycles =
        c.channel_cycles.iter().map(|v| v.to_string()).collect::<Vec<_>>().join(", ");
    format!(
        "{{\"channels\": {}, \"width\": {}, \"dead_channels\": {}, \"partition\": \"{}\", \"interconnect_busy\": {}, \"interconnect_utilization\": {}, \"exchange_bytes\": {}, \"exchange_count\": {}, \"channel_cycles\": [{}]}}",
        c.channels,
        c.width,
        c.dead_channels,
        c.partition.name(),
        c.interconnect_busy,
        json_f64(c.interconnect_utilization(makespan)),
        c.exchange_bytes,
        c.exchanges.len(),
        cycles,
    )
}

fn json_row(out: &mut String, row: &SweepRow) {
    let cfg = &row.point.cfg;
    out.push_str("    {\n");
    let _ = writeln!(out, "      \"config\": \"{}\",", json_escape(&cfg.label()));
    let _ = writeln!(out, "      \"system\": \"{}\",", json_escape(cfg.system.name()));
    let _ = writeln!(out, "      \"gbuf_bytes\": {},", cfg.gbuf_bytes);
    let _ = writeln!(out, "      \"lbuf_bytes\": {},", cfg.lbuf_bytes);
    let _ = writeln!(out, "      \"workload\": \"{}\",", json_escape(row.point.workload.name()));
    let _ = writeln!(out, "      \"engine\": \"{}\",", cfg.engine.name());
    match &row.report {
        Ok(r) => {
            let _ = writeln!(out, "      \"cycles\": {},", r.cycles);
            let _ = writeln!(out, "      \"energy_pj\": {},", json_f64(r.energy_pj));
            let _ = writeln!(out, "      \"area_mm2\": {},", json_f64(r.area_mm2));
            match row.norm {
                Some(n) => {
                    let _ = writeln!(
                        out,
                        "      \"norm\": {{\"cycles\": {}, \"energy\": {}, \"area\": {}}},",
                        json_f64(n.cycles),
                        json_f64(n.energy),
                        json_f64(n.area)
                    );
                }
                None => {
                    let _ = writeln!(out, "      \"norm\": null,");
                }
            }
            match &r.occupancy {
                Some(occ) => {
                    let _ = writeln!(out, "      \"utilization\": {},", json_utilization(occ));
                }
                None => {
                    let _ = writeln!(out, "      \"utilization\": null,");
                }
            }
            // Multi-channel rows only — single-channel rows keep the
            // pre-axis schema byte-for-byte.
            if let Some(c) = &r.channels {
                let _ = writeln!(out, "      \"channels\": {},", json_channels(c, r.cycles));
            }
            out.push_str("      \"error\": null\n");
        }
        Err(e) => {
            out.push_str("      \"cycles\": null,\n");
            out.push_str("      \"energy_pj\": null,\n");
            out.push_str("      \"area_mm2\": null,\n");
            out.push_str("      \"norm\": null,\n");
            out.push_str("      \"utilization\": null,\n");
            let _ = writeln!(out, "      \"error\": \"{}\"", json_escape(&e.to_string()));
        }
    }
    out.push_str("    }");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_escaping_covers_specials() {
        assert_eq!(json_escape(r#"a"b\c"#), r#"a\"b\\c"#);
        assert_eq!(json_escape("line1\nline2\ttab"), "line1\\nline2\\ttab");
        assert_eq!(json_escape("ctl\u{1}"), "ctl\\u0001");
        assert_eq!(json_escape("plain"), "plain");
    }

    #[test]
    fn csv_escaping_quotes_when_needed() {
        assert_eq!(csv_escape("plain"), "plain");
        assert_eq!(csv_escape("a,b"), "\"a,b\"");
        assert_eq!(csv_escape("say \"hi\""), "\"say \"\"hi\"\"\"");
        assert_eq!(csv_escape("two\nlines"), "\"two\nlines\"");
    }

    #[test]
    fn json_f64_is_plain_or_null() {
        assert_eq!(json_f64(1.5), "1.5");
        assert_eq!(json_f64(1.0), "1");
        assert_eq!(json_f64(f64::NAN), "null");
        assert_eq!(json_f64(f64::INFINITY), "null");
    }

    fn sample_report() -> ServeReport {
        use crate::config::Engine;
        use crate::serve::{ArrivalKind, LatencyStats};
        ServeReport {
            label: "Fused4/G32K_L256".to_string(),
            system: "Fused4".to_string(),
            workload: "Fig1_Example".to_string(),
            engine: Engine::Event,
            arrival: ArrivalKind::Poisson,
            rate_rps: 50000.0,
            requests: 100,
            batch: 4,
            batch_timeout: 0,
            queue_depth: 64,
            seed: 42,
            deadline: 0,
            client_retries: 0,
            backoff: 0,
            completed: 100,
            dropped: 0,
            dropped_queue_full: 0,
            dropped_deadline_shed: 0,
            dropped_deadline_miss: 0,
            dropped_retry_exhausted: 0,
            batches: 30,
            mean_batch: 100.0 / 30.0,
            warmup_trimmed: 10,
            latency: LatencyStats { samples: 90, p50: 5000, p95: 7000, p99: 7500, mean: 5100.5, max: 8000 },
            throughput_rps: 49000.25,
            goodput_rps: 49000.25,
            utilization: 0.75,
            queue_mean: 1.5,
            queue_max: 9,
            service_single: 4000,
            service_steady: 1500,
            batch_shapes: 3,
            makespan_cycles: 272000,
        }
    }

    #[test]
    fn serve_schemas_cannot_drift() {
        let fields = serve_fields(&sample_report());
        let names: Vec<&str> = fields.iter().map(|(n, _)| *n).collect();
        assert_eq!(names, serve_field_names());
    }

    #[test]
    fn serve_json_and_csv_carry_the_same_values() {
        let r = sample_report();
        let json = serve_to_json(&[r.clone()]);
        assert!(json.starts_with("{\n  \"rows\": [\n"));
        assert!(json.contains("\"config\": \"Fused4/G32K_L256\","));
        assert!(json.contains("\"p99_cycles\": 7500,"));
        assert!(json.contains("\"makespan_cycles\": 272000\n"));
        let csv = serve_to_csv(&[r]);
        let mut lines = csv.lines();
        assert_eq!(lines.next().unwrap(), serve_field_names().join(","));
        let row = lines.next().unwrap();
        assert!(row.starts_with("Fused4/G32K_L256,Fused4,Fig1_Example,event,poisson,50000,42,"));
        assert!(row.ends_with(",272000"));
        assert!(lines.next().is_none());
        // Empty input still yields the header (a parseable CSV).
        assert_eq!(serve_to_csv(&[]).lines().next().unwrap(), serve_field_names().join(","));
    }
}
