//! Hand-rolled JSON / CSV serialization for [`SweepResults`].
//!
//! The offline crate set has no `serde`, so the writers below emit the
//! formats directly. The schema is flat and stable — it is golden-tested
//! in `tests/session_api.rs`, so treat any change as a breaking change to
//! downstream tooling parsing `pimfused ... --json` output.

use super::grid::{SweepResults, SweepRow};
use std::fmt::Write as _;

/// Escape a string for a JSON string literal (without the quotes).
pub(crate) fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

/// Escape a CSV field: quote when it contains a delimiter, quote, or
/// newline; double any embedded quotes.
pub(crate) fn csv_escape(s: &str) -> String {
    if s.contains([',', '"', '\n', '\r']) {
        format!("\"{}\"", s.replace('"', "\"\""))
    } else {
        s.to_string()
    }
}

/// A JSON number: f64 via `Display` (shortest round-trip form); non-finite
/// values (never produced by the pipeline) degrade to `null`.
fn json_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

impl SweepResults {
    /// Serialize to pretty-printed JSON (2-space indent):
    ///
    /// ```json
    /// {
    ///   "baseline": "AiM-like/G2K_L0",
    ///   "rows": [
    ///     { "config": "...", "system": "...", "gbuf_bytes": 2048, ... }
    ///   ]
    /// }
    /// ```
    ///
    /// Failed points carry `"error": "<message>"` and `null` metrics.
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        out.push_str("{\n");
        let _ = writeln!(out, "  \"baseline\": \"{}\",", json_escape(&self.baseline_label));
        out.push_str("  \"rows\": [");
        for (i, row) in self.rows.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push('\n');
            json_row(&mut out, row);
        }
        if !self.rows.is_empty() {
            out.push('\n');
        }
        out.push_str("  ]\n}\n");
        out
    }

    /// Serialize to CSV with a fixed header row. Failed points leave the
    /// metric columns empty and put the message in `error`; analytic rows
    /// (no occupancy breakdown) leave the occupancy columns empty.
    pub fn to_csv(&self) -> String {
        let mut out = String::from(
            "config,system,gbuf_bytes,lbuf_bytes,workload,engine,cycles,energy_pj,area_mm2,\
             norm_cycles,norm_energy,norm_area,host_bank_busy,act_window_busy,slid_slices,error\n",
        );
        for row in &self.rows {
            let cfg = &row.point.cfg;
            let _ = write!(
                out,
                "{},{},{},{},{},{},",
                csv_escape(&cfg.label()),
                csv_escape(cfg.system.name()),
                cfg.gbuf_bytes,
                cfg.lbuf_bytes,
                csv_escape(row.point.workload.name()),
                cfg.engine.name(),
            );
            match (&row.report, row.norm) {
                (Ok(r), Some(n)) => {
                    let occ = r.occupancy;
                    let host_bk = occ.map(|o| o.host_bank_total().to_string()).unwrap_or_default();
                    let act_bk = occ.map(|o| o.act_busy_total().to_string()).unwrap_or_default();
                    let slid = occ.map(|o| o.slid_slices.to_string()).unwrap_or_default();
                    let _ = writeln!(
                        out,
                        "{},{},{},{},{},{},{},{},{},",
                        r.cycles,
                        r.energy_pj,
                        r.area_mm2,
                        n.cycles,
                        n.energy,
                        n.area,
                        host_bk,
                        act_bk,
                        slid
                    );
                }
                _ => {
                    let err = row.report.as_ref().err().map(|e| e.to_string()).unwrap_or_default();
                    let _ = writeln!(out, ",,,,,,,,,{}", csv_escape(&err));
                }
            }
        }
        out
    }
}

/// The per-resource utilization object for event-engine rows: busy cycles
/// per resource plus the schedule makespan (consumers derive fractions),
/// the contended command-bus occupancy, the total back-filled cycles the
/// scheduler placed into timeline gaps, the slice cycles placed off
/// their rigid stagger offsets (`slid`, zero when slice pipelining is
/// disabled), the host-residency share of every bank (`host_banks`,
/// zero when residency is disabled), and the reserved tFAW/tRRD window
/// cycles per bank group (`act_windows`).
fn json_utilization(occ: &crate::sim::ResourceOccupancy) -> String {
    let list = |vals: &[u64]| {
        vals.iter().map(|v| v.to_string()).collect::<Vec<_>>().join(", ")
    };
    format!(
        "{{\"makespan\": {}, \"bus\": {}, \"cmdbus\": {}, \"gbcore\": {}, \"host\": {}, \"backfilled\": {}, \"slid\": {}, \"cores\": [{}], \"banks\": [{}], \"host_banks\": [{}], \"act_windows\": [{}]}}",
        occ.makespan,
        occ.bus_busy,
        occ.cmdbus_busy,
        occ.gbcore_busy,
        occ.host_busy,
        occ.backfilled,
        occ.slid_slices,
        list(&occ.core_busy[..occ.num_cores]),
        list(&occ.bank_busy[..occ.num_banks]),
        list(&occ.host_bank_busy[..occ.num_banks]),
        list(&occ.act_busy[..occ.num_groups]),
    )
}

fn json_row(out: &mut String, row: &SweepRow) {
    let cfg = &row.point.cfg;
    out.push_str("    {\n");
    let _ = writeln!(out, "      \"config\": \"{}\",", json_escape(&cfg.label()));
    let _ = writeln!(out, "      \"system\": \"{}\",", json_escape(cfg.system.name()));
    let _ = writeln!(out, "      \"gbuf_bytes\": {},", cfg.gbuf_bytes);
    let _ = writeln!(out, "      \"lbuf_bytes\": {},", cfg.lbuf_bytes);
    let _ = writeln!(out, "      \"workload\": \"{}\",", json_escape(row.point.workload.name()));
    let _ = writeln!(out, "      \"engine\": \"{}\",", cfg.engine.name());
    match &row.report {
        Ok(r) => {
            let _ = writeln!(out, "      \"cycles\": {},", r.cycles);
            let _ = writeln!(out, "      \"energy_pj\": {},", json_f64(r.energy_pj));
            let _ = writeln!(out, "      \"area_mm2\": {},", json_f64(r.area_mm2));
            match row.norm {
                Some(n) => {
                    let _ = writeln!(
                        out,
                        "      \"norm\": {{\"cycles\": {}, \"energy\": {}, \"area\": {}}},",
                        json_f64(n.cycles),
                        json_f64(n.energy),
                        json_f64(n.area)
                    );
                }
                None => {
                    let _ = writeln!(out, "      \"norm\": null,");
                }
            }
            match &r.occupancy {
                Some(occ) => {
                    let _ = writeln!(out, "      \"utilization\": {},", json_utilization(occ));
                }
                None => {
                    let _ = writeln!(out, "      \"utilization\": null,");
                }
            }
            out.push_str("      \"error\": null\n");
        }
        Err(e) => {
            out.push_str("      \"cycles\": null,\n");
            out.push_str("      \"energy_pj\": null,\n");
            out.push_str("      \"area_mm2\": null,\n");
            out.push_str("      \"norm\": null,\n");
            out.push_str("      \"utilization\": null,\n");
            let _ = writeln!(out, "      \"error\": \"{}\"", json_escape(&e.to_string()));
        }
    }
    out.push_str("    }");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_escaping_covers_specials() {
        assert_eq!(json_escape(r#"a"b\c"#), r#"a\"b\\c"#);
        assert_eq!(json_escape("line1\nline2\ttab"), "line1\\nline2\\ttab");
        assert_eq!(json_escape("ctl\u{1}"), "ctl\\u0001");
        assert_eq!(json_escape("plain"), "plain");
    }

    #[test]
    fn csv_escaping_quotes_when_needed() {
        assert_eq!(csv_escape("plain"), "plain");
        assert_eq!(csv_escape("a,b"), "\"a,b\"");
        assert_eq!(csv_escape("say \"hi\""), "\"say \"\"hi\"\"\"");
        assert_eq!(csv_escape("two\nlines"), "\"two\nlines\"");
    }

    #[test]
    fn json_f64_is_plain_or_null() {
        assert_eq!(json_f64(1.5), "1.5");
        assert_eq!(json_f64(1.0), "1");
        assert_eq!(json_f64(f64::NAN), "null");
        assert_eq!(json_f64(f64::INFINITY), "null");
    }
}
