//! Graceful-degradation sweep: serve the same request stream while
//! retiring progressively more banks, and report how goodput decays.
//!
//! Each step clones the base [`ServeConfig`], sets
//! `cfg.faults.retired_banks` to the step's count (every other fault
//! parameter — seed, dead cores, transient rate — is inherited from the
//! base config), and runs one full serving simulation through a shared
//! [`ServeDriver`]. Retirement sets are nested by construction
//! ([`FaultPlan::build`]), so each step's failure set strictly extends
//! the previous one — the sweep is a single system losing capacity, not
//! sixteen unrelated systems.
//!
//! Under the `pimfused degrade` defaults — analytic engine, batch 1, no
//! deadline, queue deep enough that nothing drops — every request
//! completes and goodput is `requests / makespan`, which is provably
//! monotone non-increasing in the retired-bank count (losing a PIMcore
//! concentrates its work on the survivors, and the analytic engine
//! charges the slowest core). The property test below and
//! `tests/fault_api.rs` hold that line.

use crate::coordinator::serialize::{csv_escape, serve_fields};
use crate::coordinator::Session;
use crate::fault::FaultPlan;
use crate::serve::{ServeConfig, ServeDriver, ServeReport};
use crate::util::table::Table;
use anyhow::Result;
use std::fmt::Write as _;

/// One point of a degradation sweep: the failure state plus the full
/// serving outcome under it.
#[derive(Debug, Clone, PartialEq)]
pub struct DegradeStep {
    /// Banks retired at this step.
    pub retired_banks: usize,
    /// PIMcores still alive (a retired bank takes its whole core offline).
    pub alive_cores: usize,
    /// Banks still serviceable (the alive cores' banks).
    pub surviving_banks: usize,
    /// The serving report for this failure state.
    pub serve: ServeReport,
}

/// A full degradation sweep (see [`Session::degrade_sweep`]).
#[derive(Debug, Clone, PartialEq)]
pub struct DegradeReport {
    /// Config label of the healthy base system.
    pub label: String,
    /// Workload display name.
    pub workload: String,
    /// One step per retired-bank count, in increasing order starting
    /// at 0 (the healthy reference).
    pub steps: Vec<DegradeStep>,
}

impl DegradeReport {
    /// Render the sweep as a human-readable table (the default
    /// `pimfused degrade` output).
    pub fn render(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "degrade: {} on {}", self.label, self.workload);
        let mut t = Table::new(vec![
            "retired", "cores", "banks", "completed", "dropped", "goodput_rps", "p99_cyc",
        ]);
        for s in &self.steps {
            t.row(vec![
                s.retired_banks.to_string(),
                s.alive_cores.to_string(),
                s.surviving_banks.to_string(),
                s.serve.completed.to_string(),
                s.serve.dropped.to_string(),
                format!("{:.1}", s.serve.goodput_rps),
                s.serve.latency.p99.to_string(),
            ]);
        }
        out += &t.render();
        out
    }

    /// Serialize to pretty-printed JSON: `{"rows": [...]}` with one flat
    /// object per step — the failure-state columns followed by the full
    /// serve schema (same field set as `pimfused serve --json`).
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\n  \"rows\": [");
        for (i, s) in self.steps.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str("\n    {\n");
            let fields = self.step_fields(s);
            for (j, (name, value)) in fields.iter().enumerate() {
                let sep = if j + 1 == fields.len() { "" } else { "," };
                let _ = writeln!(out, "      \"{name}\": {value}{sep}");
            }
            out.push_str("    }");
        }
        if !self.steps.is_empty() {
            out.push('\n');
        }
        out.push_str("  ]\n}\n");
        out
    }

    /// Serialize to CSV: a fixed header (failure-state columns followed
    /// by the serve schema's names) plus one row per step.
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        for s in &self.steps {
            let fields = self.step_fields(s);
            if out.is_empty() {
                let names: Vec<&str> = fields.iter().map(|(n, _)| *n).collect();
                out.push_str(&names.join(","));
                out.push('\n');
            }
            let row: Vec<String> = fields
                .into_iter()
                // JSON string values come pre-quoted; CSV wants them bare.
                .map(|(_, v)| csv_escape(v.trim_matches('"')))
                .collect();
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }

    /// The flat per-step field list shared by [`DegradeReport::to_json`]
    /// and [`DegradeReport::to_csv`] (one definition, so the two schemas
    /// cannot drift).
    fn step_fields(&self, s: &DegradeStep) -> Vec<(&'static str, String)> {
        let mut fields = vec![
            ("retired_banks", s.retired_banks.to_string()),
            ("alive_cores", s.alive_cores.to_string()),
            ("surviving_banks", s.surviving_banks.to_string()),
        ];
        fields.extend(serve_fields(&s.serve));
        fields
    }
}

impl Session {
    /// Sweep retired-bank counts from 0 (healthy) to the maximum the
    /// fault model allows (`num_banks - banks_per_pimcore`, leaving one
    /// core alive), running one serving simulation per step through a
    /// shared [`ServeDriver`]. `step` is the retired-bank increment per
    /// point (clamped to at least 1); the final step always lands
    /// exactly on the maximum so the worst case is always measured.
    pub fn degrade_sweep(&self, base: &ServeConfig, step: usize) -> Result<DegradeReport> {
        base.validate().map_err(anyhow::Error::msg)?;
        let step = step.max(1);
        let max = base.cfg.num_banks - base.cfg.banks_per_pimcore;
        let driver = ServeDriver::new(self);
        let mut steps = Vec::new();
        let mut retired = 0usize;
        loop {
            let mut sc = base.clone();
            sc.cfg.faults.retired_banks = retired;
            let plan = FaultPlan::build(&sc.cfg);
            let serve = driver.run(&sc)?;
            steps.push(DegradeStep {
                retired_banks: retired,
                alive_cores: plan.alive_core_count(),
                surviving_banks: plan.surviving_bank_count(),
                serve,
            });
            if retired >= max {
                break;
            }
            retired = (retired + step).min(max);
        }
        Ok(DegradeReport {
            label: base.cfg.label(),
            workload: base.workload.name().to_string(),
            steps,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ArchConfig, System};
    use crate::serve::ArrivalKind;
    use crate::workload::Workload;

    /// The `pimfused degrade` default stream shape: saturating fixed
    /// arrivals, batch 1, a queue deep enough that nothing drops.
    fn degrade_sc() -> ServeConfig {
        let cfg = ArchConfig::system(System::Fused4, 8192, 128);
        let clock = cfg.timing.clock_hz();
        ServeConfig::new(cfg, Workload::Fig1, clock) // 1-cycle gap: service-bound
            .arrival(ArrivalKind::Fixed)
            .requests(40)
            .queue_depth(40)
    }

    #[test]
    fn goodput_decays_monotonically_as_banks_retire() {
        let s = Session::new();
        let r = s.degrade_sweep(&degrade_sc(), 4).unwrap();
        // Fused4 on 16 banks: steps at 0, 4, 8, 12 retired.
        let retired: Vec<usize> = r.steps.iter().map(|st| st.retired_banks).collect();
        assert_eq!(retired, vec![0, 4, 8, 12]);
        for st in &r.steps {
            assert_eq!(st.serve.completed, 40, "deep queue: every request completes");
            assert_eq!(st.serve.dropped, 0);
            assert_eq!(st.surviving_banks, st.alive_cores * 4);
        }
        for w in r.steps.windows(2) {
            assert!(
                w[1].serve.goodput_rps <= w[0].serve.goodput_rps,
                "goodput must not rise as banks retire: {} -> {}",
                w[0].serve.goodput_rps,
                w[1].serve.goodput_rps
            );
        }
        let (first, last) = (&r.steps[0], &r.steps[r.steps.len() - 1]);
        assert!(
            last.serve.goodput_rps < first.serve.goodput_rps,
            "losing 3 of 4 cores must cost goodput"
        );
        assert_eq!(last.alive_cores, 1);
    }

    #[test]
    fn step_lands_exactly_on_the_maximum() {
        let s = Session::new();
        let r = s.degrade_sweep(&degrade_sc(), 5).unwrap();
        let retired: Vec<usize> = r.steps.iter().map(|st| st.retired_banks).collect();
        assert_eq!(retired, vec![0, 5, 10, 12], "final step clamps to num_banks - bpc");
    }

    #[test]
    fn degrade_serialization_shapes() {
        let s = Session::new();
        let mut sc = degrade_sc();
        sc.requests = 10;
        sc.queue_depth = 10;
        let r = s.degrade_sweep(&sc, 12).unwrap();
        assert_eq!(r.steps.len(), 2);
        let json = r.to_json();
        assert!(json.starts_with("{\n  \"rows\": [\n"));
        assert!(json.contains("\"retired_banks\": 12,"));
        assert!(json.contains("\"goodput_rps\":"));
        let csv = r.to_csv();
        let mut lines = csv.lines();
        let header = lines.next().unwrap();
        assert!(header.starts_with("retired_banks,alive_cores,surviving_banks,config,"));
        assert_eq!(lines.count(), 2, "one row per step");
        // Render carries the failure-state columns.
        let text = r.render();
        assert!(text.contains("retired"));
        assert!(text.contains("goodput_rps"));
    }
}
