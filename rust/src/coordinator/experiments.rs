//! The paper's experiments, one function per figure/statistic.
//!
//! Every function returns structured rows *and* can render the paper-style
//! normalized table; the benches and the CLI call these, so "regenerate
//! Fig. N" is a single entry point (DESIGN.md §4 is the index).
//!
//! All figures run through [`Session`] / [`SweepGrid`] (Experiment API
//! v2). The `*_in` variants take an existing session so several figures
//! can share one set of memoized graphs and baseline reports (what
//! `examples/calibrate.rs` does).

use super::{Session, SweepGrid};
use crate::config::{ArchConfig, Engine, System};
use crate::dataflow::tiling::{fusion_cost, tile_segment, FusionCost};
use crate::dataflow::CostModel;
use crate::ppa::Normalized;
use crate::util::size::fmt_bufcfg;
use crate::util::table::{pct_or_x, Table};
use crate::workload::Workload;
use anyhow::Result;

/// One plotted point: system + buffer config + workload + engine,
/// normalized to the AiM-like G2K_L0 baseline on the same workload *and
/// the same engine* (so the ratios compare like with like).
#[derive(Debug, Clone)]
pub struct FigRow {
    /// The named system of this bar.
    pub system: System,
    /// GBUF size in bytes.
    pub gbuf: usize,
    /// LBUF size in bytes.
    pub lbuf: usize,
    /// The workload this point ran.
    pub workload: Workload,
    /// The simulation engine that produced the cycles.
    pub engine: Engine,
    /// PPA ratios vs the matching baseline run.
    pub norm: Normalized,
}

/// Shared driver: evaluate a (system × bufcfg × workload) grid, normalized
/// per-workload to the baseline. Convenience wrapper over [`grid_in`] with
/// a fresh [`Session`].
pub fn grid(
    systems: &[System],
    bufcfgs: &[(usize, usize)],
    workloads: &[Workload],
    model: CostModel,
) -> Result<Vec<FigRow>> {
    grid_in(&Session::with_model(model), systems, bufcfgs, workloads)
}

/// [`grid`] on an existing session, reusing its memoized graphs, plans and
/// baseline reports across figures. Runs the analytic engine; pick one
/// explicitly with [`grid_with`].
pub fn grid_in(
    session: &Session,
    systems: &[System],
    bufcfgs: &[(usize, usize)],
    workloads: &[Workload],
) -> Result<Vec<FigRow>> {
    grid_with(session, systems, bufcfgs, workloads, Engine::Analytic)
}

/// [`grid_in`] under an explicit simulation engine: every point runs
/// through `engine` and normalizes against the matching engine baseline
/// (the session memoizes baselines per `(workload, engine)`).
pub fn grid_with(
    session: &Session,
    systems: &[System],
    bufcfgs: &[(usize, usize)],
    workloads: &[Workload],
    engine: Engine,
) -> Result<Vec<FigRow>> {
    let results = SweepGrid::new()
        .systems(systems.iter().copied())
        .bufcfgs(bufcfgs.iter().copied())
        .workloads(workloads.iter().copied())
        .engine(engine)
        .run(session)?;
    results.ensure_ok()?;
    Ok(results
        .iter()
        .map(|row| FigRow {
            system: row.point.cfg.system,
            gbuf: row.point.cfg.gbuf_bytes,
            lbuf: row.point.cfg.lbuf_bytes,
            workload: row.point.workload,
            engine: row.point.cfg.engine,
            norm: row.norm.expect("ensure_ok guarantees normalized rows"),
        })
        .collect())
}

/// Fig. 5: PPA vs GBUF size with no LBUF (§V-B).
pub fn fig5(model: CostModel) -> Result<Vec<FigRow>> {
    fig5_in(&Session::with_model(model))
}

/// [`fig5`] on an existing session.
pub fn fig5_in(session: &Session) -> Result<Vec<FigRow>> {
    fig5_with(session, Engine::Analytic)
}

/// [`fig5`] under an explicit engine (`--engine event` regenerates the
/// figure with overlap-aware cycles).
pub fn fig5_with(session: &Session, engine: Engine) -> Result<Vec<FigRow>> {
    let gbufs = [2, 8, 16, 32, 64].map(|k| (k * 1024, 0));
    grid_with(session, &System::ALL, &gbufs, &Workload::PAPER, engine)
}

/// Fig. 6: PPA vs LBUF size with GBUF fixed at 2 KB (§V-C).
pub fn fig6(model: CostModel) -> Result<Vec<FigRow>> {
    fig6_in(&Session::with_model(model))
}

/// [`fig6`] on an existing session.
pub fn fig6_in(session: &Session) -> Result<Vec<FigRow>> {
    fig6_with(session, Engine::Analytic)
}

/// [`fig6`] under an explicit engine.
pub fn fig6_with(session: &Session, engine: Engine) -> Result<Vec<FigRow>> {
    let lbufs = [0usize, 64, 128, 256, 512].map(|l| (2048, l));
    grid_with(session, &System::ALL, &lbufs, &Workload::PAPER, engine)
}

/// Fig. 7: PPA with both buffers scaled, ResNet18_Full (§V-D).
pub fn fig7(model: CostModel) -> Result<Vec<FigRow>> {
    fig7_in(&Session::with_model(model))
}

/// [`fig7`] on an existing session.
pub fn fig7_in(session: &Session) -> Result<Vec<FigRow>> {
    fig7_with(session, Engine::Analytic)
}

/// [`fig7`] under an explicit engine.
pub fn fig7_with(session: &Session, engine: Engine) -> Result<Vec<FigRow>> {
    let cfgs = [
        (2 * 1024, 0),
        (8 * 1024, 128),
        (16 * 1024, 256),
        (32 * 1024, 256),
        (64 * 1024, 256),
        (64 * 1024, 100 * 1024),
    ];
    grid_with(session, &System::ALL, &cfgs, &[Workload::ResNet18Full], engine)
}

/// Render rows the way the paper annotates its bars.
pub fn render(rows: &[FigRow]) -> String {
    let mut t =
        Table::new(vec!["system", "bufcfg", "workload", "engine", "cycles", "energy", "area"]);
    for r in rows {
        t.row(vec![
            r.system.name().to_string(),
            fmt_bufcfg(r.gbuf, r.lbuf),
            r.workload.name().to_string(),
            r.engine.name().to_string(),
            pct_or_x(r.norm.cycles),
            pct_or_x(r.norm.energy),
            pct_or_x(r.norm.area),
        ]);
    }
    t.render()
}

/// §V-D / §I statistics: cost of fusing ResNet18's first 8 layers into 4
/// tiles (paper: +18.2% replication, +17.3% redundant computation, 91.2%
/// performance improvement), plus the measured cycle gain.
pub struct TakeawayStats {
    /// The fusion's data replication and redundant-MAC factors.
    pub fusion: FusionCost,
    /// Fused4 first8 cycles / AiM-like first8 cycles (well-buffered).
    pub perf_improvement: f64,
}

/// Compute [`TakeawayStats`] (the §V-D fusion-cost statistics).
pub fn vd_stats(model: CostModel) -> Result<TakeawayStats> {
    let session = Session::with_model(model);
    let g = session.graph(Workload::ResNet18First8)?;
    let tiles = tile_segment(&g, 1, 8, 2, 2);
    let fusion = fusion_cost(&g, 1, 8, &tiles);

    // "delivering a 91.2% performance improvement" — fused vs LbL on the
    // same well-provisioned PIMfused hardware (G32K_L256).
    let fused_cfg = ArchConfig::system(System::Fused4, 32 * 1024, 256);
    let fused = session.experiment(fused_cfg.clone()).workload(Workload::ResNet18First8).run()?;
    let mut lbl_cfg = fused_cfg;
    lbl_cfg.dataflow = crate::config::Dataflow::LayerByLayer;
    let lbl = session.experiment(lbl_cfg).workload(Workload::ResNet18First8).run()?;
    Ok(TakeawayStats {
        fusion,
        perf_improvement: 1.0 - fused.cycles as f64 / lbl.cycles as f64,
    })
}

/// The headline claim: Fused4 @ G32K_L256 vs AiM-like @ G2K_L0 on
/// ResNet18_Full (paper: cycles 30.6%, energy 83.4%, area 76.5%).
pub fn headline(model: CostModel) -> Result<Normalized> {
    Session::with_model(model)
        .experiment(ArchConfig::system(System::Fused4, 32 * 1024, 256))
        .workload(Workload::ResNet18Full)
        .normalized()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m() -> CostModel {
        CostModel::default()
    }

    #[test]
    fn fig5_shapes_hold() {
        let rows = fig5(m()).unwrap();
        assert_eq!(rows.len(), 3 * 5 * 2);
        let get = |s: System, g: usize, w: Workload| {
            rows.iter()
                .find(|r| r.system == s && r.gbuf == g * 1024 && r.workload == w)
                .unwrap()
                .norm
        };
        // Observation 1: AiM-like flat in GBUF.
        let aim2 = get(System::AimLike, 2, Workload::ResNet18Full);
        let aim64 = get(System::AimLike, 64, Workload::ResNet18Full);
        assert!(aim2.cycles / aim64.cycles < 1.1);
        // Observation 2: Fused16 gains substantially with GBUF.
        let f2 = get(System::Fused16, 2, Workload::ResNet18First8);
        let f32 = get(System::Fused16, 32, Workload::ResNet18First8);
        assert!(f2.cycles / f32.cycles > 2.0, "{} vs {}", f2.cycles, f32.cycles);
        // Observation 3: first8 gains exceed full gains at G32K.
        let f32full = get(System::Fused16, 32, Workload::ResNet18Full);
        assert!(f32.cycles < f32full.cycles);
        // Observation 4: Fused4 area well below baseline.
        let f4a = get(System::Fused4, 2, Workload::ResNet18Full).area;
        assert!(f4a < 0.7, "Fused4 area {f4a}");
    }

    #[test]
    fn fig6_shapes_hold() {
        let rows = fig6(m()).unwrap();
        let get = |s: System, l: usize, w: Workload| {
            rows.iter()
                .find(|r| r.system == s && r.lbuf == l && r.workload == w)
                .unwrap()
                .norm
        };
        // LBUF helps every system on first8...
        for s in System::ALL {
            let l0 = get(s, 0, Workload::ResNet18First8);
            let l512 = get(s, 512, Workload::ResNet18First8);
            assert!(l512.cycles < l0.cycles, "{s:?}");
        }
        // ...with saturation: 256 -> 512 adds much less than 0 -> 256.
        let c0 = get(System::AimLike, 0, Workload::ResNet18First8).cycles;
        let c256 = get(System::AimLike, 256, Workload::ResNet18First8).cycles;
        let c512 = get(System::AimLike, 512, Workload::ResNet18First8).cycles;
        assert!((c0 - c256) > 2.0 * (c256 - c512));
        // Full-network gains are smaller than first8 gains (deep layers).
        let full0 = get(System::AimLike, 0, Workload::ResNet18Full).cycles;
        let full512 = get(System::AimLike, 512, Workload::ResNet18Full).cycles;
        assert!(full512 / full0 > c512 / c0);
    }

    #[test]
    fn fig7_pareto_between_fused4_and_fused16() {
        let rows = fig7(m()).unwrap();
        let get = |s: System, g: usize, l: usize| {
            rows.iter()
                .find(|r| r.system == s && r.gbuf == g && r.lbuf == l)
                .unwrap()
                .norm
        };
        // The paper's Pareto trade: Fused16 buys speed with area. At mid
        // buffer sizes Fused16 is faster; Fused4 is always the area
        // winner. (At G32K_L256 our model has Fused4 slightly ahead on
        // cycles because its third fused kernel (stage 3) outweighs its
        // broadcast serialization — a documented deviation, see
        // EXPERIMENTS.md §Deviations.)
        let f16_mid = get(System::Fused16, 8 * 1024, 128);
        let f4_mid = get(System::Fused4, 8 * 1024, 128);
        assert!(f16_mid.cycles < f4_mid.cycles);
        let f16 = get(System::Fused16, 32 * 1024, 256);
        let f4 = get(System::Fused4, 32 * 1024, 256);
        assert!(f4.area < f16.area);
        assert!(f4.cycles < 1.0 && f16.cycles < 1.0);
        // Ideal LBUF: no real cycle gain over L256, but dramatic area.
        let l256 = get(System::Fused4, 64 * 1024, 256);
        let ideal = get(System::Fused4, 64 * 1024, 100 * 1024);
        assert!(ideal.cycles <= l256.cycles);
        assert!(ideal.area > 2.0 * l256.area);
    }

    #[test]
    fn figures_run_under_the_event_engine() {
        // ROADMAP "Event-engine figures": fig7 regenerated with --engine
        // event. One shared session memoizes graphs/plans across both
        // engines; each engine normalizes against its own baseline.
        let session = Session::new();
        let an = fig7_in(&session).unwrap();
        let ev = fig7_with(&session, Engine::Event).unwrap();
        assert_eq!(an.len(), ev.len());
        for (a, e) in an.iter().zip(&ev) {
            assert_eq!((a.system, a.gbuf, a.lbuf), (e.system, e.gbuf, e.lbuf));
            assert_eq!(a.engine, Engine::Analytic);
            assert_eq!(e.engine, Engine::Event);
        }
        // The baseline point normalizes to exactly 1.0 under both
        // engines (each against its own engine's baseline run).
        let base = |rows: &[FigRow]| {
            rows.iter()
                .find(|r| r.system == System::AimLike && r.gbuf == 2048 && r.lbuf == 0)
                .unwrap()
                .norm
                .cycles
        };
        assert!((base(&an) - 1.0).abs() < 1e-12);
        assert!((base(&ev) - 1.0).abs() < 1e-12);
        // Rendered tables name the engine per row.
        assert!(render(&ev).contains("event"));
    }

    #[test]
    fn vd_stats_near_paper() {
        let s = vd_stats(m()).unwrap();
        // Paper: +18.2% replication, +17.3% redundant compute, 91.2% perf.
        assert!((1.10..1.30).contains(&s.fusion.replication), "repl {}", s.fusion.replication);
        assert!((1.08..1.28).contains(&s.fusion.redundant_macs), "macs {}", s.fusion.redundant_macs);
        assert!(s.perf_improvement > 0.5, "perf improvement {}", s.perf_improvement);
    }

    #[test]
    fn headline_direction_holds() {
        // Fused4 @ G32K_L256 must beat the baseline on all three axes
        // (paper: 30.6% / 83.4% / 76.5%).
        let n = headline(m()).unwrap();
        assert!(n.cycles < 1.0, "cycles {}", n.cycles);
        assert!(n.energy < 1.0, "energy {}", n.energy);
        assert!(n.area < 1.0, "area {}", n.area);
    }

    #[test]
    fn render_produces_full_table() {
        let rows = fig7(m()).unwrap();
        let s = render(&rows);
        assert!(s.contains("Fused4"));
        assert!(s.contains("G32K_L256"));
    }
}
