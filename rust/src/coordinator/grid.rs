//! [`SweepGrid`]: the typed cartesian sweep builder of Experiment API v2,
//! and [`SweepResults`], the normalized result collection it produces.
//!
//! ```
//! use pimfused::config::System;
//! use pimfused::coordinator::{Session, SweepGrid};
//! use pimfused::workload::Workload;
//!
//! let session = Session::new();
//! let results = SweepGrid::new()
//!     .systems(System::ALL)
//!     .gbuf_bytes([2 * 1024, 32 * 1024])
//!     .lbuf_bytes([0, 256])
//!     .workload(Workload::Fig1)
//!     .run(&session)
//!     .unwrap();
//! assert_eq!(results.len(), 3 * 2 * 2);
//! println!("{}", results.table());
//! ```
//!
//! (A runnable doctest — `Fig1_Example` keeps it fast; swap in
//! `.workloads(Workload::PAPER)` for the paper's grids.)
//!
//! Point order is deterministic and documented: workload-major, then
//! system, then buffer config (GBUF-major). Results keep that order, so
//! `SweepResults::rows[i]` always corresponds to `points()[i]`.

use std::sync::atomic::{AtomicUsize, Ordering};

use super::session::Session;
use crate::config::{ArchConfig, Dataflow, Engine, PartitionKind, System};
use crate::ppa::{Normalized, PpaReport};
use crate::workload::Workload;
use anyhow::{bail, Result};

/// One point of a parameter sweep.
#[derive(Debug, Clone, PartialEq)]
pub struct SweepPoint {
    /// The architecture configuration to evaluate.
    pub cfg: ArchConfig,
    /// The workload to run it on.
    pub workload: Workload,
}

/// Below this point count, thread spawn overhead dominates (one PPA point
/// costs ~20 µs; EXPERIMENTS.md §Perf it. 2) and the executor runs serially.
const PARALLEL_THRESHOLD: usize = 64;

/// Run `eval` over `points`, fanning out across OS threads above
/// [`PARALLEL_THRESHOLD`]. Results keep input order; each point is
/// independent (the pipeline is pure).
pub(crate) fn run_points<F>(points: &[SweepPoint], eval: F) -> Vec<Result<PpaReport>>
where
    F: Fn(&SweepPoint) -> Result<PpaReport> + Sync,
{
    if points.len() < PARALLEL_THRESHOLD {
        return points.iter().map(&eval).collect();
    }
    let n_threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let chunk = crate::util::ceil_div(points.len().max(1), n_threads);
    std::thread::scope(|s| {
        let eval = &eval;
        let handles: Vec<_> = points
            .chunks(chunk.max(1))
            .map(|ps| s.spawn(move || ps.iter().map(eval).collect::<Vec<_>>()))
            .collect();
        handles.into_iter().flat_map(|h| h.join().expect("sweep worker panicked")).collect()
    })
}

/// Progress report handed to [`SweepGrid::run_with_progress`] callbacks
/// after each completed point (from whichever worker finished it).
#[derive(Debug, Clone, Copy)]
pub struct SweepProgress<'a> {
    /// Points finished so far (including this one).
    pub completed: usize,
    /// Total points in the sweep.
    pub total: usize,
    /// The point that just finished.
    pub point: &'a SweepPoint,
}

/// Typed cartesian builder over (systems × buffer configs × workloads).
///
/// Unset axes default to: all systems, the baseline `G2K_L0` buffer
/// config, and `ResNet18_Full`. [`SweepGrid::bufcfgs`] supplies explicit
/// `(gbuf, lbuf)` pairs (the Fig. 7 joint-scaling shape) and overrides
/// the `gbuf_bytes × lbuf_bytes` product.
#[derive(Debug, Clone, Default)]
pub struct SweepGrid {
    systems: Vec<System>,
    gbufs: Vec<usize>,
    lbufs: Vec<usize>,
    bufcfgs: Vec<(usize, usize)>,
    workloads: Vec<Workload>,
    engines: Vec<Engine>,
    channels: Vec<usize>,
    partitions: Vec<PartitionKind>,
    explicit_points: Vec<SweepPoint>,
}

impl SweepGrid {
    /// An empty grid; unset axes fill in defaults (see the type docs).
    pub fn new() -> Self {
        Self::default()
    }

    /// Escape hatch: a sweep over pre-built points (custom `ArchConfig`s,
    /// e.g. dataflow-ablation variants). Combines with any builder axes
    /// by appending after the generated grid.
    pub fn from_points(points: Vec<SweepPoint>) -> Self {
        Self { explicit_points: points, ..Self::default() }
    }

    /// Systems to sweep (default: all three named systems).
    pub fn systems(mut self, systems: impl IntoIterator<Item = System>) -> Self {
        self.systems = systems.into_iter().collect();
        self
    }

    /// GBUF sizes to sweep, in bytes (default: the 2 KB baseline).
    pub fn gbuf_bytes(mut self, gbufs: impl IntoIterator<Item = usize>) -> Self {
        self.gbufs = gbufs.into_iter().collect();
        self
    }

    /// LBUF sizes to sweep, in bytes (default: no LBUF).
    pub fn lbuf_bytes(mut self, lbufs: impl IntoIterator<Item = usize>) -> Self {
        self.lbufs = lbufs.into_iter().collect();
        self
    }

    /// Explicit `(gbuf, lbuf)` pairs; overrides the gbuf × lbuf product.
    pub fn bufcfgs(mut self, cfgs: impl IntoIterator<Item = (usize, usize)>) -> Self {
        self.bufcfgs = cfgs.into_iter().collect();
        self
    }

    /// Workloads to sweep (default: `ResNet18_Full`).
    pub fn workloads(mut self, workloads: impl IntoIterator<Item = Workload>) -> Self {
        self.workloads = workloads.into_iter().collect();
        self
    }

    /// Convenience for a single-workload sweep.
    pub fn workload(self, w: Workload) -> Self {
        self.workloads([w])
    }

    /// Simulation engines to sweep (innermost axis; default
    /// [`Engine::Analytic`] only).
    pub fn engines(mut self, engines: impl IntoIterator<Item = Engine>) -> Self {
        self.engines = engines.into_iter().collect();
        self
    }

    /// Convenience for a single-engine sweep.
    pub fn engine(self, e: Engine) -> Self {
        self.engines([e])
    }

    /// Channel counts to sweep (after the engine axis; default: 1).
    pub fn channels(mut self, channels: impl IntoIterator<Item = usize>) -> Self {
        self.channels = channels.into_iter().collect();
        self
    }

    /// Partition strategies to sweep (innermost axis; default
    /// [`PartitionKind::Data`], which is what single-channel configs
    /// carry anyway).
    pub fn partitions(mut self, partitions: impl IntoIterator<Item = PartitionKind>) -> Self {
        self.partitions = partitions.into_iter().collect();
        self
    }

    /// Convenience for a single-partition sweep.
    pub fn partition(self, p: PartitionKind) -> Self {
        self.partitions([p])
    }

    /// Expand the explicit [`SweepGrid::from_points`] extras across the
    /// engine axis: `from_points(..).engine(e)` means "run exactly these
    /// points under `e`"; with no engine axis set, each point keeps the
    /// engine already on its config.
    fn explicit_expanded(&self) -> Vec<SweepPoint> {
        let mut pts = Vec::new();
        for p in &self.explicit_points {
            if self.engines.is_empty() {
                pts.push(p.clone());
            } else {
                for &e in &self.engines {
                    let mut q = p.clone();
                    q.cfg.engine = e;
                    pts.push(q);
                }
            }
        }
        pts
    }

    /// The ordered point list this grid expands to: workload-major, then
    /// system, then buffer config (GBUF-major, LBUF-minor), then engine,
    /// then channel count, then partition, then any
    /// [`SweepGrid::from_points`] extras (engine axis applied, see
    /// [`SweepGrid::explicit_expanded`]).
    pub fn points(&self) -> Vec<SweepPoint> {
        let untouched = self.systems.is_empty()
            && self.gbufs.is_empty()
            && self.lbufs.is_empty()
            && self.bufcfgs.is_empty()
            && self.workloads.is_empty()
            && self.channels.is_empty()
            && self.partitions.is_empty();
        if untouched && !self.explicit_points.is_empty() {
            return self.explicit_expanded();
        }
        let systems = if self.systems.is_empty() { System::ALL.to_vec() } else { self.systems.clone() };
        let bufcfgs: Vec<(usize, usize)> = if !self.bufcfgs.is_empty() {
            self.bufcfgs.clone()
        } else {
            let gbufs = if self.gbufs.is_empty() { vec![2 * 1024] } else { self.gbufs.clone() };
            let lbufs = if self.lbufs.is_empty() { vec![0] } else { self.lbufs.clone() };
            gbufs.iter().flat_map(|&g| lbufs.iter().map(move |&l| (g, l))).collect()
        };
        let workloads = if self.workloads.is_empty() {
            vec![Workload::ResNet18Full]
        } else {
            self.workloads.clone()
        };
        let engines =
            if self.engines.is_empty() { vec![Engine::Analytic] } else { self.engines.clone() };
        let channels = if self.channels.is_empty() { vec![1] } else { self.channels.clone() };
        let partitions = if self.partitions.is_empty() {
            vec![PartitionKind::Data]
        } else {
            self.partitions.clone()
        };
        let mut pts = Vec::with_capacity(
            workloads.len()
                * systems.len()
                * bufcfgs.len()
                * engines.len()
                * channels.len()
                * partitions.len()
                + self.explicit_points.len(),
        );
        for &w in &workloads {
            for &s in &systems {
                for &(g, l) in &bufcfgs {
                    for &e in &engines {
                        for &ch in &channels {
                            for &pk in &partitions {
                                pts.push(SweepPoint {
                                    cfg: ArchConfig::system(s, g, l)
                                        .with_engine(e)
                                        .with_channels(ch)
                                        .with_partition(pk),
                                    workload: w,
                                });
                            }
                        }
                    }
                }
            }
        }
        pts.extend(self.explicit_expanded());
        pts
    }

    /// Evaluate every point through the session (parallel above the
    /// internal `PARALLEL_THRESHOLD`, 64 points) and normalize
    /// per-workload against the session baseline. `Err` only for baseline failures; per-point
    /// failures are recorded in their [`SweepRow`].
    pub fn run(&self, session: &Session) -> Result<SweepResults> {
        self.run_with_progress(session, |_| {})
    }

    /// [`SweepGrid::run`] with a per-point progress callback, invoked from
    /// worker threads as points complete (completion order, not point
    /// order).
    pub fn run_with_progress<F>(&self, session: &Session, progress: F) -> Result<SweepResults>
    where
        F: Fn(SweepProgress<'_>) + Send + Sync,
    {
        let points = self.points();
        // Warm each distinct baseline axis combination (and thereby the
        // workload's graph) and each distinct (workload, dataflow) plan
        // serially, so every parallel worker and every normalization hits
        // the session cache: exactly one baseline run per key, and no
        // worker ever builds while holding a cache mutex.
        let mut warmed: Vec<(Workload, Engine, bool, bool, usize, PartitionKind)> = Vec::new();
        let mut warmed_plans: Vec<(Workload, Dataflow, usize, PartitionKind)> = Vec::new();
        for p in &points {
            let bkey = (
                p.workload,
                p.cfg.engine,
                p.cfg.host_residency,
                p.cfg.slice_pipelining,
                p.cfg.channels,
                p.cfg.partition,
            );
            if !warmed.contains(&bkey) {
                session.baseline_matched(p.workload, &p.cfg)?;
                warmed.push(bkey);
            }
            let key = (p.workload, p.cfg.dataflow, p.cfg.channels, p.cfg.partition);
            if !warmed_plans.contains(&key) {
                // Ignore warm failures: a bad point must fail as its own
                // row (the per-point run re-validates), not abort the
                // whole sweep.
                let _ = session.warm(&p.cfg, p.workload);
                warmed_plans.push(key);
            }
        }
        let total = points.len();
        let done = AtomicUsize::new(0);
        let reports = run_points(&points, |pt| {
            let r = session.run(&pt.cfg, pt.workload);
            let completed = done.fetch_add(1, Ordering::Relaxed) + 1;
            progress(SweepProgress { completed, total, point: pt });
            r
        });
        let mut rows = Vec::with_capacity(total);
        for (pt, report) in points.into_iter().zip(reports) {
            let norm = match &report {
                Ok(r) => Some(r.normalize(&session.baseline_matched(pt.workload, &pt.cfg)?)),
                Err(_) => None,
            };
            rows.push(SweepRow { point: pt, report, norm });
        }
        Ok(SweepResults { baseline_label: session.baseline_config().label(), rows })
    }
}

/// One evaluated sweep point: the input point, its report (or error), and
/// its normalization against the session baseline for its workload.
#[derive(Debug)]
pub struct SweepRow {
    /// The input point this row evaluated.
    pub point: SweepPoint,
    /// The evaluation's report, or the error that failed it.
    pub report: Result<PpaReport>,
    /// Normalization against the session baseline (`None` on failure).
    pub norm: Option<Normalized>,
}

/// An ordered collection of sweep rows with built-in normalization,
/// tabling ([`SweepResults::table`]) and serialization
/// ([`SweepResults::to_json`] / [`SweepResults::to_csv`], in
/// `coordinator::serialize`).
#[derive(Debug)]
pub struct SweepResults {
    /// Label of the config every row is normalized against.
    pub baseline_label: String,
    /// Rows in [`SweepGrid::points`] order.
    pub rows: Vec<SweepRow>,
}

impl SweepResults {
    /// Number of evaluated points.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// Whether the sweep had no points at all.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Iterate the rows in point order.
    pub fn iter(&self) -> std::slice::Iter<'_, SweepRow> {
        self.rows.iter()
    }

    /// The successful reports, in point order.
    pub fn reports(&self) -> impl Iterator<Item = &PpaReport> {
        self.rows.iter().filter_map(|r| r.report.as_ref().ok())
    }

    /// Publish the sweep's outcome counters into a metrics registry
    /// (`sweep.*` namespace): points evaluated, failures, and a
    /// `sweep.cycles` series in point order (failed points are skipped).
    /// See [`crate::obs::MetricsRegistry`].
    pub fn publish_metrics(&self, m: &crate::obs::MetricsRegistry) {
        m.add("sweep.points", self.rows.len() as u64);
        m.add("sweep.errors", self.rows.iter().filter(|r| r.report.is_err()).count() as u64);
        for r in self.reports() {
            m.push_sample("sweep.cycles", r.cycles as f64);
        }
    }

    /// Error out on the first failed point, if any.
    pub fn ensure_ok(&self) -> Result<&Self> {
        for row in &self.rows {
            if let Err(e) = &row.report {
                bail!(
                    "sweep point {} on {} failed: {e}",
                    row.point.cfg.label(),
                    row.point.workload.name()
                );
            }
        }
        Ok(self)
    }

    /// Render the paper-style normalized table (config / workload /
    /// engine / cycles / energy / area, percentages relative to the
    /// baseline — each row against its own engine's baseline).
    pub fn table(&self) -> String {
        use crate::util::table::{pct_or_x, Table};
        let mut t = Table::new(vec!["config", "workload", "engine", "cycles", "energy", "area"]);
        for row in &self.rows {
            let engine = row.point.cfg.engine.name().to_string();
            match (&row.report, row.norm) {
                (Ok(r), Some(n)) => {
                    t.row(vec![
                        r.label.clone(),
                        r.workload.clone(),
                        engine,
                        pct_or_x(n.cycles),
                        pct_or_x(n.energy),
                        pct_or_x(n.area),
                    ]);
                }
                _ => {
                    t.row(vec![
                        row.point.cfg.label(),
                        row.point.workload.name().to_string(),
                        engine,
                        "error".to_string(),
                        "error".to_string(),
                        "error".to_string(),
                    ]);
                }
            }
        }
        t.render()
    }
}

impl<'a> IntoIterator for &'a SweepResults {
    type Item = &'a SweepRow;
    type IntoIter = std::slice::Iter<'a, SweepRow>;

    fn into_iter(self) -> Self::IntoIter {
        self.iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_axes_fill_in() {
        let pts = SweepGrid::new().points();
        // All systems × baseline bufcfg × ResNet18_Full.
        assert_eq!(pts.len(), 3);
        assert!(pts.iter().all(|p| p.workload == Workload::ResNet18Full));
        assert!(pts.iter().all(|p| p.cfg.gbuf_bytes == 2048 && p.cfg.lbuf_bytes == 0));
    }

    #[test]
    fn ordering_is_workload_major_then_system_then_bufcfg() {
        let pts = SweepGrid::new()
            .systems([System::AimLike, System::Fused4])
            .gbuf_bytes([2048, 8192])
            .lbuf_bytes([0, 64])
            .workloads([Workload::Fig1, Workload::Fig3])
            .points();
        assert_eq!(pts.len(), 2 * 2 * 4);
        assert_eq!(pts[0].workload, Workload::Fig1);
        assert_eq!(pts[8].workload, Workload::Fig3);
        // Within a workload: system-major.
        assert_eq!(pts[0].cfg.system, System::AimLike);
        assert_eq!(pts[4].cfg.system, System::Fused4);
        // Within a system: GBUF-major, LBUF-minor.
        assert_eq!((pts[0].cfg.gbuf_bytes, pts[0].cfg.lbuf_bytes), (2048, 0));
        assert_eq!((pts[1].cfg.gbuf_bytes, pts[1].cfg.lbuf_bytes), (2048, 64));
        assert_eq!((pts[2].cfg.gbuf_bytes, pts[2].cfg.lbuf_bytes), (8192, 0));
    }

    #[test]
    fn engine_axis_is_innermost_and_defaults_to_analytic() {
        let pts = SweepGrid::new()
            .systems([System::AimLike])
            .gbuf_bytes([2048, 8192])
            .workload(Workload::Fig1)
            .engines(Engine::ALL)
            .points();
        assert_eq!(pts.len(), 4);
        assert_eq!(pts[0].cfg.engine, Engine::Analytic);
        assert_eq!(pts[1].cfg.engine, Engine::Event);
        assert_eq!(pts[1].cfg.gbuf_bytes, 2048);
        assert_eq!(pts[2].cfg.gbuf_bytes, 8192);
        assert!(SweepGrid::new().points().iter().all(|p| p.cfg.engine == Engine::Analytic));
    }

    #[test]
    fn dual_engine_sweep_normalizes_per_engine() {
        let session = Session::new();
        let results = SweepGrid::new()
            .systems([System::AimLike])
            .gbuf_bytes([2048])
            .workload(Workload::Fig1)
            .engines(Engine::ALL)
            .run(&session)
            .unwrap();
        results.ensure_ok().unwrap();
        // Both rows are the baseline config itself, so each normalizes to
        // 1.0 against its own engine's baseline.
        for row in &results {
            let n = row.norm.unwrap();
            assert!((n.cycles - 1.0).abs() < 1e-12, "{:?}", row.point.cfg.engine);
        }
        let ev = results.rows[1].report.as_ref().unwrap();
        assert!(ev.occupancy.is_some(), "event rows carry occupancy");
    }

    #[test]
    fn channel_axes_are_innermost_after_engine() {
        let pts = SweepGrid::new()
            .systems([System::Fused4])
            .gbuf_bytes([32 * 1024])
            .lbuf_bytes([256])
            .workload(Workload::Fig1)
            .engines(Engine::ALL)
            .channels([1, 2])
            .partitions(PartitionKind::ALL)
            .points();
        assert_eq!(pts.len(), 2 * 2 * 2);
        // Partition is innermost, then channels, then engine.
        assert_eq!(
            (pts[0].cfg.engine, pts[0].cfg.channels, pts[0].cfg.partition),
            (Engine::Analytic, 1, PartitionKind::Data)
        );
        assert_eq!(pts[1].cfg.partition, PartitionKind::Model);
        assert_eq!((pts[2].cfg.channels, pts[2].cfg.partition), (2, PartitionKind::Data));
        assert_eq!(pts[4].cfg.engine, Engine::Event);
        // Defaults: single channel, data partition.
        assert!(SweepGrid::new()
            .points()
            .iter()
            .all(|p| p.cfg.channels == 1 && p.cfg.partition == PartitionKind::Data));
    }

    #[test]
    fn channel_axis_alone_builds_a_grid() {
        // Setting only .channels() must not fall through to the
        // explicit-points escape hatch logic — it's a touched axis.
        let pts = SweepGrid::new().channels([1, 2, 4]).points();
        assert_eq!(pts.len(), 3 * 3, "all systems × three channel counts");
        assert_eq!(pts[0].cfg.channels, 1);
        assert_eq!(pts[2].cfg.channels, 4);
    }

    #[test]
    fn bufcfg_pairs_override_product() {
        let pts = SweepGrid::new()
            .systems([System::Fused4])
            .bufcfgs([(2048, 0), (32 * 1024, 256)])
            .gbuf_bytes([999]) // ignored: explicit pairs win
            .workload(Workload::Fig1)
            .points();
        assert_eq!(pts.len(), 2);
        assert_eq!(pts[1].cfg.gbuf_bytes, 32 * 1024);
        assert_eq!(pts[1].cfg.lbuf_bytes, 256);
    }

    #[test]
    fn from_points_used_alone_is_exact() {
        let custom = vec![SweepPoint {
            cfg: ArchConfig::system(System::Fused16, 4096, 32),
            workload: Workload::Fig3,
        }];
        let pts = SweepGrid::from_points(custom.clone()).points();
        assert_eq!(pts, custom);
    }

    #[test]
    fn from_points_with_engine_axis_stays_exact() {
        let pt = SweepPoint {
            cfg: ArchConfig::system(System::Fused16, 4096, 32),
            workload: Workload::Fig3,
        };
        // `.engine(e)` re-targets the explicit points; it must not spawn
        // a surprise default cartesian grid alongside them.
        let pts = SweepGrid::from_points(vec![pt.clone()]).engine(Engine::Event).points();
        assert_eq!(pts.len(), 1);
        assert_eq!(pts[0].cfg.engine, Engine::Event);
        assert_eq!(pts[0].workload, pt.workload);
        // A multi-engine axis fans each explicit point out, engine-minor.
        let pts2 = SweepGrid::from_points(vec![pt.clone()]).engines(Engine::ALL).points();
        assert_eq!(pts2.len(), 2);
        assert_eq!(pts2[0].cfg.engine, Engine::Analytic);
        assert_eq!(pts2[1].cfg.engine, Engine::Event);
        // With no engine axis, an explicit point keeps its own engine.
        let ev = SweepPoint { cfg: pt.cfg.with_engine(Engine::Event), workload: pt.workload };
        let pts3 = SweepGrid::from_points(vec![ev]).points();
        assert_eq!(pts3[0].cfg.engine, Engine::Event);
    }

    #[test]
    fn progress_callback_sees_every_point() {
        let session = Session::new();
        let grid = SweepGrid::new()
            .systems([System::AimLike, System::Fused4])
            .gbuf_bytes([2048, 8192])
            .workload(Workload::Fig1);
        let seen = AtomicUsize::new(0);
        let results = grid
            .run_with_progress(&session, |p| {
                assert_eq!(p.total, 4);
                assert!(p.completed >= 1 && p.completed <= 4);
                seen.fetch_add(1, Ordering::Relaxed);
            })
            .unwrap();
        assert_eq!(seen.load(Ordering::Relaxed), 4);
        assert_eq!(results.len(), 4);
        results.ensure_ok().unwrap();
    }
}
