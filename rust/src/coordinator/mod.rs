//! The L3 experiment coordinator: runs (architecture × workload) points
//! through the full mapper → trace → simulator → energy pipeline, fans
//! parameter sweeps out across OS threads, and regenerates the paper's
//! figures (see [`experiments`]).

pub mod experiments;

use crate::config::ArchConfig;
use crate::dataflow::{plan, CostModel};
use crate::energy;
use crate::ppa::PpaReport;
use crate::sim::simulate;
use crate::trace::gen::generate;
use crate::workload::Workload;
use anyhow::{Context, Result};

/// Evaluate one configuration on one workload end-to-end.
pub fn run_ppa(cfg: &ArchConfig, workload: Workload) -> Result<PpaReport> {
    run_ppa_with(cfg, workload, CostModel::default())
}

/// [`run_ppa`] with an explicit cost model (used by calibration benches).
pub fn run_ppa_with(cfg: &ArchConfig, workload: Workload, model: CostModel) -> Result<PpaReport> {
    cfg.validate().map_err(anyhow::Error::msg).context("invalid architecture config")?;
    let g = workload.graph();
    g.validate().map_err(anyhow::Error::msg)?;
    let p = plan(&g, cfg);
    p.validate(&g).map_err(anyhow::Error::msg)?;
    let trace = generate(&g, cfg, &p, model);
    let sim = simulate(cfg, &trace);
    let e = energy::energy(cfg, &sim.actions);
    let a = energy::area(cfg);
    Ok(PpaReport {
        label: cfg.label(),
        workload: workload.name().to_string(),
        cycles: sim.cycles,
        energy_pj: e.total_pj(),
        area_mm2: a.total_mm2(),
        sim,
        energy: e,
        area: a,
    })
}

/// One point of a parameter sweep.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    pub cfg: ArchConfig,
    pub workload: Workload,
}

/// Run many points in parallel across OS threads (each point is
/// independent; the pipeline is pure). Results keep input order.
///
/// Small grids run serially: one PPA point costs ~20 µs, so below ~64
/// points thread spawn overhead dominates (EXPERIMENTS.md §Perf it. 2).
pub fn sweep(points: &[SweepPoint], model: CostModel) -> Vec<Result<PpaReport>> {
    if points.len() < 64 {
        return points.iter().map(|p| run_ppa_with(&p.cfg, p.workload, model)).collect();
    }
    let n_threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let chunk = crate::util::ceil_div(points.len().max(1), n_threads);
    std::thread::scope(|s| {
        let handles: Vec<_> = points
            .chunks(chunk.max(1))
            .map(|ps| {
                s.spawn(move || {
                    ps.iter()
                        .map(|p| run_ppa_with(&p.cfg, p.workload, model))
                        .collect::<Vec<_>>()
                })
            })
            .collect();
        handles.into_iter().flat_map(|h| h.join().expect("sweep worker panicked")).collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::System;

    #[test]
    fn run_ppa_produces_consistent_report() {
        let cfg = ArchConfig::baseline();
        let r = run_ppa(&cfg, Workload::ResNet18First8).unwrap();
        assert_eq!(r.label, "AiM-like/G2K_L0");
        assert_eq!(r.workload, "ResNet18_First8Layers");
        assert_eq!(r.cycles, r.sim.cycles);
        assert!((r.energy_pj - r.energy.total_pj()).abs() < 1e-6);
        assert!((r.area_mm2 - r.area.total_mm2()).abs() < 1e-12);
    }

    #[test]
    fn invalid_config_is_rejected() {
        let mut cfg = ArchConfig::baseline();
        cfg.banks_per_pimcore = 3; // doesn't divide 16
        assert!(run_ppa(&cfg, Workload::Fig1).is_err());
    }

    #[test]
    fn sweep_matches_serial_and_keeps_order() {
        let points: Vec<SweepPoint> = [2048usize, 8192, 32768]
            .iter()
            .flat_map(|&g| {
                System::ALL.iter().map(move |&s| SweepPoint {
                    cfg: ArchConfig::system(s, g, 128),
                    workload: Workload::ResNet18First8,
                })
            })
            .collect();
        let par = sweep(&points, CostModel::default());
        for (pt, res) in points.iter().zip(&par) {
            let serial = run_ppa(&pt.cfg, pt.workload).unwrap();
            let r = res.as_ref().unwrap();
            assert_eq!(r.cycles, serial.cycles, "order/determinism broken at {}", r.label);
            assert_eq!(r.label, pt.cfg.label());
        }
    }

    #[test]
    fn deterministic_across_runs() {
        let cfg = ArchConfig::system(System::Fused4, 32 * 1024, 256);
        let a = run_ppa(&cfg, Workload::ResNet18Full).unwrap();
        let b = run_ppa(&cfg, Workload::ResNet18Full).unwrap();
        assert_eq!(a.cycles, b.cycles);
        assert_eq!(a.energy_pj, b.energy_pj);
    }
}
