//! The L3 experiment coordinator — **Experiment API v2**.
//!
//! Everything the paper evaluates is some (architecture × buffer config ×
//! workload) grid run through the mapper → trace → simulator → energy
//! pipeline and normalized to the AiM-like `G2K_L0` baseline. API v2
//! expresses that as three types:
//!
//! * [`Session`] — owns shared, memoized state: workload graphs, mapped
//!   plans, per-workload baseline reports, and the
//!   [`crate::dataflow::CostModel`]. Builds each piece exactly once, no
//!   matter how many points touch it.
//! * [`Experiment`] — a builder for one evaluation:
//!   `session.experiment(cfg).workload(w).run()` →
//!   [`crate::ppa::PpaReport`] (or `.normalized()` →
//!   [`crate::ppa::Normalized`]).
//! * [`SweepGrid`] — a typed cartesian builder
//!   (`.systems(..).gbuf_bytes(..).lbuf_bytes(..).workloads(..)`) that
//!   yields deterministically-ordered points, fans them out across the
//!   thread-scoped parallel executor (with an optional per-point progress
//!   callback), and returns [`SweepResults`] with built-in normalization,
//!   [tabling](SweepResults::table) and hand-rolled
//!   [JSON](SweepResults::to_json)/[CSV](SweepResults::to_csv)
//!   serialization.
//!
//! The paper's figures live in [`experiments`], one function per figure,
//! all driven through a session. The v1 free functions (`run_ppa`,
//! `run_ppa_with`, `sweep`) were deprecated shims for one release (PR 1)
//! and are now gone; the doctest below is the runnable migration guide
//! (`cargo test` keeps it compiling and passing):
//!
//! ```
//! use pimfused::config::{ArchConfig, System};
//! use pimfused::coordinator::{Session, SweepGrid};
//! use pimfused::workload::Workload;
//!
//! // v1: `run_ppa(&cfg, w)`            → v2: a session experiment.
//! let session = Session::new();
//! let cfg = ArchConfig::system(System::Fused4, 8 * 1024, 128);
//! let report = session.experiment(cfg.clone()).workload(Workload::Fig1).run().unwrap();
//! assert!(report.cycles > 0);
//!
//! // v1: manual baseline + `normalize` → v2: the session-cached baseline.
//! let norm = session.normalized(&cfg, Workload::Fig1).unwrap();
//! assert!(norm.cycles > 0.0);
//!
//! // v1: hand-rolled point loops       → v2: a typed cartesian grid.
//! let results = SweepGrid::new()
//!     .systems([System::AimLike, System::Fused4])
//!     .gbuf_bytes([2 * 1024, 32 * 1024])
//!     .workload(Workload::Fig1)
//!     .run(&session)
//!     .unwrap();
//! assert_eq!(results.len(), 4);
//! ```
//!
//! Every experiment carries an [`crate::config::Engine`] selection on its
//! `ArchConfig`: sessions cache baseline reports per `(workload, engine,
//! host_residency, slice_pipelining)` so normalization always compares
//! like with like, and [`SweepGrid`] can sweep the engine as an axis.

mod degrade;
mod grid;
pub(crate) mod serialize;
mod session;

pub mod experiments;

pub use degrade::{DegradeReport, DegradeStep};
pub use grid::{SweepGrid, SweepPoint, SweepProgress, SweepResults, SweepRow};
pub use serialize::{serve_to_csv, serve_to_json};
pub use session::{Experiment, Session, SessionStats};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ArchConfig, System};
    use crate::workload::Workload;

    #[test]
    fn run_produces_consistent_report() {
        let s = Session::new();
        let r = s.run(&ArchConfig::baseline(), Workload::ResNet18First8).unwrap();
        assert_eq!(r.label, "AiM-like/G2K_L0");
        assert_eq!(r.workload, "ResNet18_First8Layers");
        assert_eq!(r.cycles, r.sim.cycles);
        assert!((r.energy_pj - r.energy.total_pj()).abs() < 1e-6);
        assert!((r.area_mm2 - r.area.total_mm2()).abs() < 1e-12);
    }

    #[test]
    fn grid_matches_serial_and_keeps_order() {
        let session = Session::new();
        let grid = SweepGrid::new()
            .gbuf_bytes([2048usize, 8192, 32768])
            .workload(Workload::ResNet18First8);
        let results = grid.run(&session).unwrap();
        let points = grid.points();
        assert_eq!(results.len(), points.len());
        let serial = Session::new();
        for (pt, row) in points.iter().zip(&results) {
            let want = serial.run(&pt.cfg, pt.workload).unwrap();
            let got = row.report.as_ref().unwrap();
            assert_eq!(got.cycles, want.cycles, "order/determinism broken at {}", got.label);
            assert_eq!(got.label, pt.cfg.label());
        }
    }

    #[test]
    fn deterministic_across_runs() {
        let cfg = ArchConfig::system(System::Fused4, 32 * 1024, 256);
        let a = Session::new().run(&cfg, Workload::ResNet18Full).unwrap();
        let b = Session::new().run(&cfg, Workload::ResNet18Full).unwrap();
        assert_eq!(a.cycles, b.cycles);
        assert_eq!(a.energy_pj, b.energy_pj);
    }

    /// The v2 migration target of the removed v1 shims: one-off
    /// experiments and point-list sweeps go through `Session` /
    /// `SweepGrid::from_points` and agree with direct session runs.
    #[test]
    fn from_points_sweep_matches_session_runs() {
        let cfg = ArchConfig::system(System::Fused16, 8192, 128);
        let one = Session::new().experiment(cfg.clone()).workload(Workload::Fig3).run().unwrap();
        let direct = Session::new().run(&cfg, Workload::Fig3).unwrap();
        assert_eq!(one.cycles, direct.cycles);
        assert_eq!(one.energy_pj, direct.energy_pj);

        let session = Session::new();
        let points = SweepGrid::new().workload(Workload::Fig1).points();
        let results = SweepGrid::from_points(points.clone()).run(&session).unwrap();
        results.ensure_ok().unwrap();
        assert_eq!(results.len(), points.len());
        for (pt, row) in points.iter().zip(&results) {
            let r = row.report.as_ref().unwrap();
            assert_eq!(r.label, pt.cfg.label());
            assert_eq!(r.cycles, session.run(&pt.cfg, pt.workload).unwrap().cycles);
        }
    }
}
