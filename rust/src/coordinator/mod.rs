//! The L3 experiment coordinator — **Experiment API v2**.
//!
//! Everything the paper evaluates is some (architecture × buffer config ×
//! workload) grid run through the mapper → trace → simulator → energy
//! pipeline and normalized to the AiM-like `G2K_L0` baseline. API v2
//! expresses that as three types:
//!
//! * [`Session`] — owns shared, memoized state: workload graphs, mapped
//!   plans, per-workload baseline reports, and the [`CostModel`]. Builds
//!   each piece exactly once, no matter how many points touch it.
//! * [`Experiment`] — a builder for one evaluation:
//!   `session.experiment(cfg).workload(w).run()` → [`PpaReport`]
//!   (or `.normalized()` → [`crate::ppa::Normalized`]).
//! * [`SweepGrid`] — a typed cartesian builder
//!   (`.systems(..).gbuf_bytes(..).lbuf_bytes(..).workloads(..)`) that
//!   yields deterministically-ordered points, fans them out across the
//!   thread-scoped parallel executor (with an optional per-point progress
//!   callback), and returns [`SweepResults`] with built-in normalization,
//!   [tabling](SweepResults::table) and hand-rolled
//!   [JSON](SweepResults::to_json)/[CSV](SweepResults::to_csv)
//!   serialization.
//!
//! The paper's figures live in [`experiments`], one function per figure,
//! all driven through a session. The v1 free functions ([`run_ppa`],
//! [`run_ppa_with`], [`sweep`]) remain as deprecated one-release shims;
//! see CHANGES.md for the old → new migration table.

mod grid;
mod serialize;
mod session;

pub mod experiments;

pub use grid::{SweepGrid, SweepPoint, SweepProgress, SweepResults, SweepRow};
pub use session::{Experiment, Session, SessionStats};

use crate::config::ArchConfig;
use crate::dataflow::CostModel;
use crate::ppa::PpaReport;
use crate::workload::Workload;
use anyhow::Result;

/// Evaluate one configuration on one workload end-to-end.
#[deprecated(
    since = "0.2.0",
    note = "use `Session::new().experiment(cfg).workload(w).run()` (Experiment API v2)"
)]
pub fn run_ppa(cfg: &ArchConfig, workload: Workload) -> Result<PpaReport> {
    Session::new().experiment(cfg.clone()).workload(workload).run()
}

/// [`run_ppa`] with an explicit cost model (used by calibration benches).
#[deprecated(
    since = "0.2.0",
    note = "use `Session::with_model(model).experiment(cfg).workload(w).run()` (Experiment API v2)"
)]
pub fn run_ppa_with(cfg: &ArchConfig, workload: Workload, model: CostModel) -> Result<PpaReport> {
    Session::with_model(model).experiment(cfg.clone()).workload(workload).run()
}

/// Run many points in parallel across OS threads. Results keep input
/// order.
#[deprecated(
    since = "0.2.0",
    note = "use `SweepGrid::run` (or `SweepGrid::from_points(..).run(&session)`) — Experiment API v2"
)]
pub fn sweep(points: &[SweepPoint], model: CostModel) -> Vec<Result<PpaReport>> {
    let session = Session::with_model(model);
    grid::run_points(points, |p| session.run(&p.cfg, p.workload))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::System;

    #[test]
    fn run_produces_consistent_report() {
        let s = Session::new();
        let r = s.run(&ArchConfig::baseline(), Workload::ResNet18First8).unwrap();
        assert_eq!(r.label, "AiM-like/G2K_L0");
        assert_eq!(r.workload, "ResNet18_First8Layers");
        assert_eq!(r.cycles, r.sim.cycles);
        assert!((r.energy_pj - r.energy.total_pj()).abs() < 1e-6);
        assert!((r.area_mm2 - r.area.total_mm2()).abs() < 1e-12);
    }

    #[test]
    fn grid_matches_serial_and_keeps_order() {
        let session = Session::new();
        let grid = SweepGrid::new()
            .gbuf_bytes([2048usize, 8192, 32768])
            .workload(Workload::ResNet18First8);
        let results = grid.run(&session).unwrap();
        let points = grid.points();
        assert_eq!(results.len(), points.len());
        let serial = Session::new();
        for (pt, row) in points.iter().zip(&results) {
            let want = serial.run(&pt.cfg, pt.workload).unwrap();
            let got = row.report.as_ref().unwrap();
            assert_eq!(got.cycles, want.cycles, "order/determinism broken at {}", got.label);
            assert_eq!(got.label, pt.cfg.label());
        }
    }

    #[test]
    fn deterministic_across_runs() {
        let cfg = ArchConfig::system(System::Fused4, 32 * 1024, 256);
        let a = Session::new().run(&cfg, Workload::ResNet18Full).unwrap();
        let b = Session::new().run(&cfg, Workload::ResNet18Full).unwrap();
        assert_eq!(a.cycles, b.cycles);
        assert_eq!(a.energy_pj, b.energy_pj);
    }

    /// The v1 shims must keep producing byte-identical results until they
    /// are removed.
    #[test]
    #[allow(deprecated)]
    fn deprecated_shims_match_v2() {
        let cfg = ArchConfig::system(System::Fused16, 8192, 128);
        let old = run_ppa(&cfg, Workload::Fig3).unwrap();
        let new = Session::new().run(&cfg, Workload::Fig3).unwrap();
        assert_eq!(old.cycles, new.cycles);
        assert_eq!(old.energy_pj, new.energy_pj);

        let points = SweepGrid::new().workload(Workload::Fig1).points();
        let old = sweep(&points, CostModel::default());
        assert_eq!(old.len(), points.len());
        for (pt, r) in points.iter().zip(&old) {
            assert_eq!(r.as_ref().unwrap().label, pt.cfg.label());
        }
    }
}
