//! [`Session`]: the owning context of Experiment API v2.
//!
//! A session holds everything that is shared between PPA evaluations —
//! the [`CostModel`], the baseline configuration used for normalization,
//! and memoized per-workload state (built graphs, mapped plans, baseline
//! reports). Free-function pipelines rebuilt all of that for every call;
//! a session builds each piece **exactly once** and hands out `Arc`s,
//! which is what makes large design-space sweeps cheap (ROADMAP: scale,
//! speed, new workloads).
//!
//! ```
//! use pimfused::config::{ArchConfig, System};
//! use pimfused::coordinator::Session;
//! use pimfused::workload::Workload;
//!
//! let session = Session::new();
//! let report = session
//!     .experiment(ArchConfig::system(System::Fused4, 32 * 1024, 256))
//!     .workload(Workload::Fig1)
//!     .run()
//!     .unwrap();
//! assert!(report.cycles > 0);
//! println!("{}: {} cycles", report.label, report.cycles);
//! ```
//!
//! All caches are interior-mutable behind mutexes, so a `&Session` can be
//! shared across the sweep executor's worker threads.
//!
//! The example above is a runnable doctest (`cargo test` keeps it
//! compiling and passing); `Fig1_Example` keeps it fast.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::cnn::Graph;
use crate::config::{ArchConfig, Dataflow, Engine, PartitionKind};
use crate::dataflow::{plan, CostModel, Plan};
use crate::energy;
use crate::ppa::{Normalized, PpaReport};
use crate::trace::gen::generate;
use crate::trace::partition::{build_channels, ChannelSet};
use crate::workload::Workload;
use anyhow::{Context, Result};

/// Shared, memoized state for a family of PPA evaluations.
///
/// See the module-level docs for the overall shape. Construction is
/// cheap; nothing is evaluated until the first [`Session::run`] /
/// [`Experiment::run`] / [`crate::coordinator::SweepGrid::run`].
pub struct Session {
    model: CostModel,
    baseline_cfg: ArchConfig,
    graphs: Mutex<HashMap<Workload, Arc<Graph>>>,
    // Plans are keyed by (workload, dataflow): `dataflow::plan` reads
    // only `cfg.dataflow` (LayerByLayer vs PimFused tile grid), so two
    // configs differing only in buffers/timing share one mapped plan.
    plans: Mutex<HashMap<(Workload, Dataflow), Arc<Plan>>>,
    // Baselines are keyed by (workload, engine, host-residency,
    // slice-pipelining, open-row-reuse, channels, partition):
    // normalization always compares like with like, so an event-engine
    // experiment is measured against the baseline config run through the
    // event engine, an interface-only host model against an
    // interface-only baseline, a rigid-stagger run against a
    // rigid-stagger baseline, an every-command-reopens run against the
    // same row model, and a 4-channel model-parallel run against the
    // baseline scaled out the same way.
    baselines: Mutex<BaselineCache>,
    // Channel sets are keyed by (workload, config) with the engine and
    // tracing axes canonicalized out — per-channel traces depend on
    // neither, so one partitioning serves both engines.
    channel_sets: Mutex<HashMap<(Workload, ArchConfig), Arc<ChannelSet>>>,
    counters: Counters,
}

/// Baseline memo: one entry per `(workload, engine, host_residency,
/// slice_pipelining, open_row_reuse, channels, partition)` normalization
/// axis combination.
type BaselineCache =
    HashMap<(Workload, Engine, bool, bool, bool, usize, PartitionKind), Arc<PpaReport>>;

#[derive(Default)]
struct Counters {
    graph_builds: AtomicUsize,
    plan_builds: AtomicUsize,
    baseline_runs: AtomicUsize,
    points_run: AtomicUsize,
    channel_set_builds: AtomicUsize,
}

/// Snapshot of a session's cache/work counters (see [`Session::stats`]).
///
/// The counting test in `tests/session_api.rs` uses this to prove that a
/// sweep builds each workload graph and baseline report exactly once.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SessionStats {
    /// Workload graphs built (one per distinct workload touched).
    pub graph_builds: usize,
    /// Plans mapped (one per distinct (workload, dataflow) pair).
    pub plan_builds: usize,
    /// Baseline reports evaluated (one per distinct workload normalized).
    pub baseline_runs: usize,
    /// Total pipeline evaluations, baselines included.
    pub points_run: usize,
    /// Multi-channel partitionings built (one per distinct
    /// `(workload, config)` with the engine/tracing axes canonicalized
    /// out — the determinism suite uses this to prove per-channel traces
    /// are generated exactly once).
    pub channel_set_builds: usize,
}

impl Session {
    /// A session with the default [`CostModel`] and the paper's baseline
    /// (`AiM-like/G2K_L0`) as the normalization reference.
    pub fn new() -> Self {
        Self::with_model(CostModel::default())
    }

    /// A session with an explicit cost model (calibration benches).
    pub fn with_model(model: CostModel) -> Self {
        Session {
            model,
            baseline_cfg: ArchConfig::baseline(),
            graphs: Mutex::new(HashMap::new()),
            plans: Mutex::new(HashMap::new()),
            baselines: Mutex::new(HashMap::new()),
            channel_sets: Mutex::new(HashMap::new()),
            counters: Counters::default(),
        }
    }

    /// Replace the normalization baseline (builder-style). Clears any
    /// baseline reports already memoized against the old config.
    pub fn with_baseline(mut self, cfg: ArchConfig) -> Self {
        self.baseline_cfg = cfg;
        self.baselines.lock().unwrap().clear();
        self
    }

    /// The session's cost model.
    pub fn model(&self) -> CostModel {
        self.model
    }

    /// The configuration all normalizations are relative to.
    pub fn baseline_config(&self) -> &ArchConfig {
        &self.baseline_cfg
    }

    /// Start building an [`Experiment`] on this session. The workload
    /// defaults to [`Workload::ResNet18Full`].
    pub fn experiment(&self, cfg: ArchConfig) -> Experiment<'_> {
        Experiment { session: self, cfg, workload: Workload::ResNet18Full, model: None }
    }

    /// The memoized, validated graph for a workload (built on first use).
    pub fn graph(&self, w: Workload) -> Result<Arc<Graph>> {
        let mut m = self.graphs.lock().unwrap();
        if let Some(g) = m.get(&w) {
            return Ok(g.clone());
        }
        self.counters.graph_builds.fetch_add(1, Ordering::Relaxed);
        let g = w.graph();
        g.validate()
            .map_err(anyhow::Error::msg)
            .with_context(|| format!("workload {} built an invalid graph", w.name()))?;
        let g = Arc::new(g);
        m.insert(w, g.clone());
        Ok(g)
    }

    /// The memoized baseline report for a workload under the baseline
    /// config's own engine. See [`Session::baseline_for`].
    pub fn baseline(&self, w: Workload) -> Result<Arc<PpaReport>> {
        self.baseline_for(w, self.baseline_cfg.engine)
    }

    /// The memoized baseline report for a workload under an explicit
    /// engine and the baseline config's own host-residency model. See
    /// [`Session::baseline_matched`] for the general per-axis lookup.
    pub fn baseline_for(&self, w: Workload, engine: Engine) -> Result<Arc<PpaReport>> {
        let cfg = self.baseline_cfg.clone().with_engine(engine);
        self.baseline_matched(w, &cfg)
    }

    /// The memoized baseline report matching an experiment config's
    /// normalization axes — engine, host-residency model, slice
    /// pipelining **and** open-row reuse: one evaluation of
    /// [`Session::baseline_config`] per distinct `(workload, engine,
    /// host_residency, slice_pipelining, open_row_reuse)` tuple, shared
    /// by every normalization afterwards. Any axis that changes what a
    /// cycle count *means* must match between numerator and baseline,
    /// or the ratio mixes models.
    ///
    /// Fault injection is deliberately **not** a normalization axis: a
    /// degraded config is normalized against the *healthy* baseline, so
    /// the ratio reads directly as "slowdown caused by the faults".
    pub fn baseline_matched(&self, w: Workload, cfg: &ArchConfig) -> Result<Arc<PpaReport>> {
        let key = (
            w,
            cfg.engine,
            cfg.host_residency,
            cfg.slice_pipelining,
            cfg.open_row_reuse,
            cfg.channels,
            cfg.partition,
        );
        let mut m = self.baselines.lock().unwrap();
        if let Some(b) = m.get(&key) {
            return Ok(b.clone());
        }
        self.counters.baseline_runs.fetch_add(1, Ordering::Relaxed);
        let baseline_cfg = self
            .baseline_cfg
            .clone()
            .with_engine(cfg.engine)
            .with_host_residency(cfg.host_residency)
            .with_slice_pipelining(cfg.slice_pipelining)
            .with_open_row_reuse(cfg.open_row_reuse)
            .with_channels(cfg.channels)
            .with_partition(cfg.partition);
        let r = Arc::new(
            self.run_with_model(&baseline_cfg, w, self.model)
                .with_context(|| format!("evaluating baseline {}", baseline_cfg.label()))?,
        );
        m.insert(key, r.clone());
        Ok(r)
    }

    /// Evaluate one configuration on one workload end-to-end, reusing the
    /// session's memoized graph and plan. Equivalent to
    /// `session.experiment(cfg).workload(w).run()`.
    pub fn run(&self, cfg: &ArchConfig, w: Workload) -> Result<PpaReport> {
        self.run_with_model(cfg, w, self.model)
    }

    /// [`Session::run`] plus normalization against the memoized baseline
    /// report for the same workload, the same engine, the same
    /// host-residency model, **and** the same slice-pipelining model (so
    /// no axis ever skews a ratio).
    pub fn normalized(&self, cfg: &ArchConfig, w: Workload) -> Result<Normalized> {
        let r = self.run(cfg, w)?;
        let b = self.baseline_matched(w, cfg)?;
        Ok(r.normalize(&b))
    }

    /// Snapshot the cache/work counters.
    pub fn stats(&self) -> SessionStats {
        SessionStats {
            graph_builds: self.counters.graph_builds.load(Ordering::Relaxed),
            plan_builds: self.counters.plan_builds.load(Ordering::Relaxed),
            baseline_runs: self.counters.baseline_runs.load(Ordering::Relaxed),
            points_run: self.counters.points_run.load(Ordering::Relaxed),
            channel_set_builds: self.counters.channel_set_builds.load(Ordering::Relaxed),
        }
    }

    /// Ensure the graph, plan, and (for multi-channel configs) channel
    /// set for this point are memoized. The sweep executor calls this
    /// from its serial warm-up so parallel workers never build inside
    /// the cache mutexes — they only take cache hits.
    pub(crate) fn warm(&self, cfg: &ArchConfig, w: Workload) -> Result<()> {
        let g = self.graph(w)?;
        self.plan_for(&g, cfg, w)?;
        if cfg.channels > 1 {
            self.channel_set(cfg, w, self.model)?;
        }
        Ok(())
    }

    /// The memoized plan for `(workload, cfg.dataflow)`; validated once.
    fn plan_for(&self, g: &Graph, cfg: &ArchConfig, w: Workload) -> Result<Arc<Plan>> {
        let key = (w, cfg.dataflow);
        let mut m = self.plans.lock().unwrap();
        if let Some(p) = m.get(&key) {
            return Ok(p.clone());
        }
        self.counters.plan_builds.fetch_add(1, Ordering::Relaxed);
        let p = plan(g, cfg);
        p.validate(g)
            .map_err(anyhow::Error::msg)
            .with_context(|| format!("mapper produced an invalid plan for {}", w.name()))?;
        let p = Arc::new(p);
        m.insert(key, p.clone());
        Ok(p)
    }

    /// The full mapper → trace → simulator → energy pipeline with an
    /// explicit cost model (cache-bypassing callers: model overrides).
    pub(crate) fn run_with_model(
        &self,
        cfg: &ArchConfig,
        w: Workload,
        model: CostModel,
    ) -> Result<PpaReport> {
        cfg.validate()
            .map_err(anyhow::Error::msg)
            .context("invalid architecture config")?;
        if cfg.channels > 1 {
            return self.run_multi_channel(cfg, w, model);
        }
        let g = self.graph(w)?;
        let p = self.plan_for(&g, cfg, w)?;
        let trace = generate(&g, cfg, &p, model);
        // With tracing on (event engine only — the analytic engine has no
        // schedule to trace), run the scheduler once in recording mode and
        // keep the captured timeline; otherwise take the ordinary path, so
        // tracing-off runs are byte-identical to a build without the
        // observability layer.
        let (out, schedule) = if cfg.tracing && cfg.engine == Engine::Event {
            let (report, st) = crate::obs::ScheduleTrace::capture(cfg, &trace);
            let out = crate::sim::SimOutcome {
                result: report.result,
                occupancy: Some(report.occupancy),
            };
            (out, Some(st))
        } else {
            (crate::sim::run(cfg, &trace), None)
        };
        let e = energy::energy(cfg, &out.result.actions);
        let a = energy::area(cfg);
        self.counters.points_run.fetch_add(1, Ordering::Relaxed);
        Ok(PpaReport {
            label: cfg.label(),
            workload: w.name().to_string(),
            engine: cfg.engine,
            cycles: out.result.cycles,
            energy_pj: e.total_pj(),
            area_mm2: a.total_mm2(),
            sim: out.result,
            energy: e,
            area: a,
            occupancy: out.occupancy,
            schedule,
            channels: None,
        })
    }

    /// The memoized [`ChannelSet`] for `(workload, config)`. Per-channel
    /// traces depend on neither the engine nor the tracing flag, so both
    /// axes are canonicalized out of the key and one partitioning serves
    /// every engine. A model override bypasses the cache — the memo
    /// belongs to the session model, exactly like the baseline memo.
    fn channel_set(
        &self,
        cfg: &ArchConfig,
        w: Workload,
        model: CostModel,
    ) -> Result<Arc<ChannelSet>> {
        let g = self.graph(w)?;
        let build = || -> Result<ChannelSet> {
            self.counters.channel_set_builds.fetch_add(1, Ordering::Relaxed);
            build_channels(&g, cfg, model).map_err(anyhow::Error::msg).with_context(|| {
                format!("partitioning {} across {} channels", w.name(), cfg.channels)
            })
        };
        if model != self.model {
            return Ok(Arc::new(build()?));
        }
        let key = (w, cfg.clone().with_engine(Engine::Analytic).with_tracing(false));
        let mut m = self.channel_sets.lock().unwrap();
        if let Some(s) = m.get(&key) {
            return Ok(s.clone());
        }
        let s = Arc::new(build()?);
        m.insert(key, s.clone());
        Ok(s)
    }

    /// The multi-channel pipeline (`cfg.channels > 1`): partition the
    /// graph into per-channel traces (memoized), schedule every channel
    /// independently, meter cross-channel exchanges on the shared host
    /// interconnect, and compose the totals
    /// ([`crate::sim::channel::run_channels`]). With tracing on, the
    /// captured timeline is channel 0's schedule with the committed
    /// `CH_XCHG` interconnect spans folded in
    /// ([`crate::obs::ScheduleTrace::attach_exchanges`]).
    fn run_multi_channel(
        &self,
        cfg: &ArchConfig,
        w: Workload,
        model: CostModel,
    ) -> Result<PpaReport> {
        let set = self.channel_set(cfg, w, model)?;
        let outcome = crate::sim::channel::run_channels(cfg, &set);
        let schedule = if cfg.tracing && cfg.engine == Engine::Event {
            let (_, mut st) = crate::obs::ScheduleTrace::capture(cfg, &set.traces[0]);
            st.attach_exchanges(&outcome.report, outcome.result.cycles);
            Some(st)
        } else {
            None
        };
        let e = energy::energy(cfg, &outcome.result.actions);
        let a = energy::area(cfg);
        self.counters.points_run.fetch_add(1, Ordering::Relaxed);
        Ok(PpaReport {
            label: cfg.label(),
            workload: w.name().to_string(),
            engine: cfg.engine,
            cycles: outcome.result.cycles,
            energy_pj: e.total_pj(),
            area_mm2: a.total_mm2(),
            sim: outcome.result,
            energy: e,
            area: a,
            occupancy: outcome.occupancy,
            schedule,
            channels: Some(outcome.report),
        })
    }

    /// Publish the session's cache/work counters into a metrics registry
    /// (`session.*` namespace). See [`crate::obs::MetricsRegistry`].
    pub fn publish_metrics(&self, m: &crate::obs::MetricsRegistry) {
        let st = self.stats();
        m.add("session.graph_builds", st.graph_builds as u64);
        m.add("session.plan_builds", st.plan_builds as u64);
        m.add("session.baseline_runs", st.baseline_runs as u64);
        m.add("session.points_run", st.points_run as u64);
        m.add("session.channel_set_builds", st.channel_set_builds as u64);
    }
}

impl Default for Session {
    fn default() -> Self {
        Self::new()
    }
}

/// Builder for one PPA evaluation on a [`Session`]:
/// `session.experiment(cfg).workload(w).run()`.
#[must_use = "an Experiment does nothing until .run() or .normalized()"]
pub struct Experiment<'s> {
    session: &'s Session,
    cfg: ArchConfig,
    workload: Workload,
    model: Option<CostModel>,
}

impl Experiment<'_> {
    /// Select the workload (default: [`Workload::ResNet18Full`]).
    pub fn workload(mut self, w: Workload) -> Self {
        self.workload = w;
        self
    }

    /// Override the session's cost model for this experiment only.
    /// Normalization then also re-evaluates the baseline under the
    /// override (the memoized baseline belongs to the session model).
    pub fn model(mut self, m: CostModel) -> Self {
        self.model = Some(m);
        self
    }

    /// Run the experiment end-to-end.
    pub fn run(self) -> Result<PpaReport> {
        let model = self.model.unwrap_or(self.session.model);
        self.session.run_with_model(&self.cfg, self.workload, model)
    }

    /// Run and normalize against the session baseline on the same workload.
    pub fn normalized(self) -> Result<Normalized> {
        match self.model {
            None => self.session.normalized(&self.cfg, self.workload),
            Some(m) => {
                let r = self.session.run_with_model(&self.cfg, self.workload, m)?;
                let baseline_cfg = self
                    .session
                    .baseline_cfg
                    .clone()
                    .with_engine(self.cfg.engine)
                    .with_channels(self.cfg.channels)
                    .with_partition(self.cfg.partition);
                let b = self.session.run_with_model(&baseline_cfg, self.workload, m)?;
                Ok(r.normalize(&b))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::System;

    #[test]
    fn experiment_matches_direct_run() {
        let s = Session::new();
        let cfg = ArchConfig::system(System::Fused4, 32 * 1024, 256);
        let a = s.experiment(cfg.clone()).workload(Workload::ResNet18First8).run().unwrap();
        let b = s.run(&cfg, Workload::ResNet18First8).unwrap();
        assert_eq!(a.cycles, b.cycles);
        assert_eq!(a.energy_pj, b.energy_pj);
        assert_eq!(a.label, "Fused4/G32K_L256");
    }

    #[test]
    fn graph_and_plan_are_memoized() {
        let s = Session::new();
        let cfg = ArchConfig::system(System::Fused16, 2048, 0);
        for lbuf in [0usize, 64, 128] {
            let mut c = cfg.clone();
            c.lbuf_bytes = lbuf;
            s.run(&c, Workload::Fig3).unwrap();
        }
        let st = s.stats();
        assert_eq!(st.graph_builds, 1, "one graph for one workload");
        assert_eq!(st.plan_builds, 1, "buffer-only changes share the plan");
        assert_eq!(st.points_run, 3);
    }

    #[test]
    fn distinct_dataflows_get_distinct_plans() {
        let s = Session::new();
        let fused = ArchConfig::system(System::Fused4, 2048, 0);
        let mut lbl = fused.clone();
        lbl.dataflow = crate::config::Dataflow::LayerByLayer;
        let rf = s.run(&fused, Workload::Fig1).unwrap();
        let rl = s.run(&lbl, Workload::Fig1).unwrap();
        assert_ne!(rf.cycles, rl.cycles, "dataflow must change the outcome");
        assert_eq!(s.stats().plan_builds, 2);
        assert_eq!(s.stats().graph_builds, 1);
    }

    #[test]
    fn baseline_is_evaluated_once_per_workload() {
        let s = Session::new();
        let cfg = ArchConfig::system(System::Fused4, 8192, 128);
        let n1 = s.normalized(&cfg, Workload::Fig1).unwrap();
        let n2 = s.normalized(&cfg, Workload::Fig1).unwrap();
        assert_eq!(n1, n2);
        assert_eq!(s.stats().baseline_runs, 1);
        s.normalized(&cfg, Workload::Fig3).unwrap();
        assert_eq!(s.stats().baseline_runs, 2);
    }

    #[test]
    fn baselines_are_keyed_by_engine() {
        use crate::config::Engine;
        let s = Session::new();
        let cfg = ArchConfig::system(System::Fused4, 8192, 128);
        s.normalized(&cfg, Workload::Fig1).unwrap();
        assert_eq!(s.stats().baseline_runs, 1);
        let ev = cfg.with_engine(Engine::Event);
        s.normalized(&ev, Workload::Fig1).unwrap();
        assert_eq!(s.stats().baseline_runs, 2, "event engine gets its own baseline");
        // Event baseline vs itself normalizes to exactly 1, and is served
        // from the per-engine cache.
        let base_ev = ArchConfig::baseline().with_engine(Engine::Event);
        let nb = s.normalized(&base_ev, Workload::Fig1).unwrap();
        assert!((nb.cycles - 1.0).abs() < 1e-12);
        assert_eq!(s.stats().baseline_runs, 2, "baseline memoized per (workload, engine)");
    }

    #[test]
    fn baselines_are_keyed_by_host_residency() {
        // A --host-residency off point must normalize against an
        // interface-only baseline (compare like with like): the baseline
        // config itself, residency off, is exactly 1.0 and earns its own
        // cache entry.
        let s = Session::new();
        let base_off = ArchConfig::baseline().with_host_residency(false);
        s.normalized(&ArchConfig::baseline(), Workload::Fig1).unwrap();
        assert_eq!(s.stats().baseline_runs, 1);
        let n = s.normalized(&base_off, Workload::Fig1).unwrap();
        assert!((n.cycles - 1.0).abs() < 1e-12, "interface-only self-normalization");
        assert_eq!(s.stats().baseline_runs, 2, "residency gets its own baseline");
    }

    #[test]
    fn baselines_are_keyed_by_slice_pipelining() {
        // A --slice-pipelining off point must normalize against a
        // rigid-stagger baseline: the baseline config itself, pipelining
        // off, is exactly 1.0 and earns its own cache entry.
        let s = Session::new();
        let base_ev = ArchConfig::baseline().with_engine(crate::config::Engine::Event);
        let base_off = base_ev.clone().with_slice_pipelining(false);
        s.normalized(&base_ev, Workload::Fig1).unwrap();
        assert_eq!(s.stats().baseline_runs, 1);
        let n = s.normalized(&base_off, Workload::Fig1).unwrap();
        assert!((n.cycles - 1.0).abs() < 1e-12, "rigid-stagger self-normalization");
        assert_eq!(s.stats().baseline_runs, 2, "slice pipelining gets its own baseline");
    }

    #[test]
    fn baselines_are_keyed_by_open_row() {
        // An --open-row off point must normalize against an
        // every-command-reopens baseline: the baseline config itself,
        // reuse off, is exactly 1.0 and earns its own cache entry.
        let s = Session::new();
        let base_off = ArchConfig::baseline().with_open_row_reuse(false);
        s.normalized(&ArchConfig::baseline(), Workload::Fig1).unwrap();
        assert_eq!(s.stats().baseline_runs, 1);
        let n = s.normalized(&base_off, Workload::Fig1).unwrap();
        assert!((n.cycles - 1.0).abs() < 1e-12, "reuse-off self-normalization");
        assert_eq!(s.stats().baseline_runs, 2, "open-row reuse gets its own baseline");
    }

    #[test]
    fn engine_choice_shares_the_mapped_plan() {
        use crate::config::Engine;
        let s = Session::new();
        let cfg = ArchConfig::system(System::Fused16, 2048, 0);
        s.run(&cfg, Workload::Fig3).unwrap();
        s.run(&cfg.clone().with_engine(Engine::Event), Workload::Fig3).unwrap();
        // The plan depends only on the dataflow, never on the engine.
        assert_eq!(s.stats().plan_builds, 1);
        assert_eq!(s.stats().graph_builds, 1);
    }

    #[test]
    fn degraded_configs_normalize_against_the_healthy_baseline() {
        use crate::fault::FaultConfig;
        let s = Session::new();
        let cfg = ArchConfig::system(System::Fused4, 8192, 128);
        let healthy = s.normalized(&cfg, Workload::Fig1).unwrap();
        assert_eq!(s.stats().baseline_runs, 1);
        let degraded = cfg
            .clone()
            .with_faults(FaultConfig { retired_banks: 4, ..Default::default() });
        let n = s.normalized(&degraded, Workload::Fig1).unwrap();
        assert!(
            n.cycles >= healthy.cycles,
            "losing banks cannot speed things up: {} < {}",
            n.cycles,
            healthy.cycles
        );
        assert_eq!(
            s.stats().baseline_runs,
            1,
            "faults are not a normalization axis — the healthy baseline is reused"
        );
    }

    #[test]
    fn channel_sets_are_memoized_across_engines() {
        let s = Session::new();
        let cfg = ArchConfig::system(System::Fused4, 32 * 1024, 256)
            .with_channels(2)
            .with_partition(PartitionKind::Model);
        s.run(&cfg, Workload::Fig1).unwrap();
        s.run(&cfg.clone().with_engine(Engine::Event), Workload::Fig1).unwrap();
        assert_eq!(s.stats().channel_set_builds, 1, "one partitioning serves both engines");
        s.run(&cfg.clone().with_channels(4), Workload::Fig1).unwrap();
        assert_eq!(s.stats().channel_set_builds, 2, "a new channel count re-partitions");
    }

    #[test]
    fn multi_channel_reports_carry_the_channel_summary() {
        let s = Session::new();
        let base = ArchConfig::system(System::Fused4, 32 * 1024, 256);
        let cfg = base.clone().with_channels(2).with_partition(PartitionKind::Model);
        let r = s.run(&cfg, Workload::Fig1).unwrap();
        let c = r.channels.as_ref().expect("multi-channel runs carry the summary");
        assert_eq!(c.channels, 2);
        assert!(c.interconnect_busy > 0, "model partition crosses the interconnect");
        assert!(r.interconnect_utilization().unwrap() > 0.0);
        assert!(r.label.ends_with("/c2-model"), "label grows the channel suffix: {}", r.label);
        let single = s.run(&base, Workload::Fig1).unwrap();
        assert!(single.channels.is_none(), "single-channel reports carry no channel summary");
        assert_eq!(single.interconnect_utilization(), None);
    }

    #[test]
    fn baselines_are_keyed_by_channels_and_partition() {
        let s = Session::new();
        let cfg = ArchConfig::system(System::Fused4, 8192, 128);
        s.normalized(&cfg, Workload::Fig1).unwrap();
        assert_eq!(s.stats().baseline_runs, 1);
        // A scaled-out point is normalized against the baseline scaled
        // out the same way — and the baseline config itself, scaled out,
        // self-normalizes to exactly 1.
        let scaled = ArchConfig::baseline().with_channels(2).with_partition(PartitionKind::Model);
        let n = s.normalized(&scaled, Workload::Fig1).unwrap();
        assert!((n.cycles - 1.0).abs() < 1e-12, "scaled-out self-normalization");
        assert_eq!(s.stats().baseline_runs, 2, "the channel axis gets its own baseline");
        let data = scaled.clone().with_partition(PartitionKind::Data);
        s.normalized(&data, Workload::Fig1).unwrap();
        assert_eq!(s.stats().baseline_runs, 3, "each partition gets its own baseline");
    }

    #[test]
    fn invalid_config_is_rejected() {
        let s = Session::new();
        let mut cfg = ArchConfig::baseline();
        cfg.banks_per_pimcore = 3; // doesn't divide 16
        assert!(s.run(&cfg, Workload::Fig1).is_err());
    }

    #[test]
    fn custom_baseline_changes_normalization() {
        let well = ArchConfig::system(System::AimLike, 32 * 1024, 256);
        let s = Session::new().with_baseline(well.clone());
        let n = s.normalized(&well, Workload::Fig1).unwrap();
        assert!((n.cycles - 1.0).abs() < 1e-12, "self-normalization is 1.0");
        assert_eq!(s.baseline_config().label(), "AiM-like/G32K_L256");
    }

    #[test]
    fn model_override_is_self_consistent() {
        let s = Session::new();
        let mut m = CostModel::default();
        m.lbl_feed_lsat *= 2.0;
        let cfg = ArchConfig::baseline();
        // Baseline vs itself under any model must normalize to exactly 1.
        let n = s.experiment(cfg).workload(Workload::Fig1).model(m).normalized().unwrap();
        assert!((n.cycles - 1.0).abs() < 1e-12);
        assert!((n.energy - 1.0).abs() < 1e-12);
    }
}
