//! Functional validation of the PIMfused dataflow on real tensor data.
//!
//! The cycle simulator proves the fused dataflow is *fast*; this module
//! proves it is *correct*: [`run_reference`] executes a CNN graph
//! layer-by-layer in f32, and [`run_plan_tiled`] executes the same graph
//! the way the PIMfused mapper schedules it — fused segments computed one
//! spatial tile at a time from exactly the haloed input regions the
//! [`crate::dataflow::tiling`] demands say each PIMcore may touch — then
//! reassembles the tiles. The two must agree bit-for-bit (identical
//! f32 operation order per output element), which catches any halo or
//! partitioning bug the cycle model cannot see.
//!
//! The e2e example goes one step further and checks [`run_reference`]
//! against the JAX/Pallas AOT artifacts through PJRT.

pub mod tensor;

use crate::cnn::{Graph, Node, NodeId, Op, PoolKind};
use crate::dataflow::tiling::{demand_for_tile, tile_grid, Rect};
use crate::dataflow::{Plan, PlanStep};
use crate::util::rng::XorShift64;
use std::collections::HashMap;
use tensor::Tensor;

/// Deterministic synthetic weights for a conv/fc node (seeded per node).
pub fn synth_weights(node: &Node, seed: u64) -> Vec<f32> {
    let count = node.weight_bytes() / crate::config::ELEM_BYTES;
    let mut rng = XorShift64::new(seed ^ (node.id as u64 + 1).wrapping_mul(0x9E37_79B9));
    (0..count).map(|_| rng.next_f32_signed() * 0.25).collect()
}

/// Deterministic synthetic input for a graph.
pub fn synth_input(g: &Graph, seed: u64) -> Tensor {
    let s = g.nodes[0].shape;
    let mut rng = XorShift64::new(seed);
    Tensor::from_fn(s.c, s.h, s.w, |_, _, _| rng.next_f32_signed())
}

fn apply_node(node: &Node, inputs: &[&Tensor], weights: &[f32]) -> Tensor {
    match node.op {
        Op::Input => inputs[0].clone(),
        Op::Conv { cout, k, stride, pad, bn, relu } => {
            // BN is folded into the weights at compile time (identity
            // scale/shift in the synthetic setting); ReLU applies after.
            let _ = bn;
            inputs[0].conv2d(weights, cout, k, stride, pad, relu)
        }
        Op::Pool { kind: PoolKind::Max, k, stride, pad } => inputs[0].maxpool(k, stride, pad),
        Op::Pool { kind: PoolKind::Avg, k, stride, pad } => inputs[0].avgpool(k, stride, pad),
        Op::GlobalAvgPool => inputs[0].global_avg(),
        Op::AddRelu => inputs[0].add_relu(inputs[1]),
        Op::Fc { cout } => inputs[0].fc(weights, cout),
    }
}

/// Execute the whole graph layer-by-layer; returns every node's output.
pub fn run_reference(g: &Graph, input: &Tensor, weight_seed: u64) -> Vec<Tensor> {
    let mut outs: Vec<Tensor> = Vec::with_capacity(g.nodes.len());
    for node in &g.nodes {
        let t = if node.id == 0 {
            input.clone()
        } else {
            let ins: Vec<&Tensor> = node.inputs.iter().map(|&i| &outs[i]).collect();
            let w = synth_weights(node, weight_seed);
            apply_node(node, &ins, &w)
        };
        outs.push(t);
    }
    outs
}

/// Execute one fused segment for one output tile, reading only the haloed
/// regions the tile demand grants, exactly as a PIMcore would.
fn run_segment_tile(
    g: &Graph,
    start: NodeId,
    end: NodeId,
    out_rect: Rect,
    ext: &HashMap<NodeId, Tensor>,
    weight_seed: u64,
) -> Tensor {
    let demand = demand_for_tile(g, start, end, out_rect);
    // Per-node tile outputs, indexed by node id, each tagged with the
    // region of the full feature map it covers.
    let mut partial: HashMap<NodeId, (Rect, Tensor)> = HashMap::new();
    for (&pid, r) in demand.external.iter() {
        let full = ext
            .get(&pid)
            .unwrap_or_else(|| panic!("missing external producer {pid}"));
        partial.insert(pid, (*r, full.slice(r)));
    }
    for id in start..=end {
        let Some(&region) = demand.per_node.get(&id) else { continue };
        let node = &g.nodes[id];
        let t = match node.op {
            Op::Conv { cout, k, stride, pad, relu, .. } => {
                let (in_rect, in_t) = &partial[&node.inputs[0]];
                let w = synth_weights(node, weight_seed);
                in_t.conv2d_region(&w, cout, k, stride, pad, relu, *in_rect, region)
            }
            Op::Pool { kind, k, stride, pad } => {
                let (in_rect, in_t) = &partial[&node.inputs[0]];
                match kind {
                    PoolKind::Max => in_t.maxpool_region(k, stride, pad, *in_rect, region),
                    PoolKind::Avg => in_t.avgpool_region(k, stride, pad, *in_rect, region),
                }
            }
            Op::AddRelu => {
                let (ra, ta) = &partial[&node.inputs[0]];
                let (rb, tb) = &partial[&node.inputs[1]];
                ta.slice_rel(ra, &region).add_relu(&tb.slice_rel(rb, &region))
            }
            _ => unreachable!("non-tileable op inside fused segment"),
        };
        partial.insert(id, (region, t));
    }
    let (r, t) = &partial[&end];
    t.slice_rel(r, &demand.out_rect)
}

/// Execute the graph under a PIMfused [`Plan`]: fused segments run
/// tile-by-tile (each tile independent, as on separate PIMcores) and are
/// stitched back together; layer-by-layer steps run whole.
pub fn run_plan_tiled(g: &Graph, plan: &Plan, input: &Tensor, weight_seed: u64) -> Vec<Tensor> {
    let mut outs: HashMap<NodeId, Tensor> = HashMap::new();
    outs.insert(0, input.clone());
    for step in &plan.steps {
        match *step {
            PlanStep::Lbl { node } => {
                let n = &g.nodes[node];
                let ins: Vec<&Tensor> = n.inputs.iter().map(|i| &outs[i]).collect();
                let w = synth_weights(n, weight_seed);
                let t = apply_node(n, &ins, &w);
                outs.insert(node, t);
            }
            PlanStep::Fused { start, end, grid } => {
                let shape = g.nodes[end].shape;
                let mut full = Tensor::zeros(shape.c, shape.h, shape.w);
                for rect in tile_grid(shape.h, shape.w, grid.0, grid.1) {
                    let tile = run_segment_tile(g, start, end, rect, &outs, weight_seed);
                    full.paste(&rect, &tile);
                }
                // Intermediate fused nodes are never materialized whole —
                // exactly the PIMfused property (they live in LBUF/local
                // banks only). Only the segment output is visible.
                outs.insert(end, full);
            }
        }
    }
    let mut v = Vec::with_capacity(g.nodes.len());
    for id in 0..g.nodes.len() {
        v.push(outs.remove(&id).unwrap_or_else(Tensor::empty));
    }
    v
}

/// Validate a plan end-to-end: tiled execution must equal the reference
/// everywhere the plan materializes a tensor. Returns the max |Δ| found.
pub fn validate_plan(g: &Graph, plan: &Plan, seed: u64) -> Result<f32, String> {
    let input = synth_input(g, seed);
    let reference = run_reference(g, &input, seed);
    let tiled = run_plan_tiled(g, plan, &input, seed);
    let mut max_delta = 0.0f32;
    for (id, t) in tiled.iter().enumerate() {
        if t.is_empty() {
            continue; // fused-internal node, never materialized
        }
        let r = &reference[id];
        if t.dims() != r.dims() {
            return Err(format!("node {id} shape mismatch {:?} vs {:?}", t.dims(), r.dims()));
        }
        for (a, b) in t.data().iter().zip(r.data().iter()) {
            let d = (a - b).abs();
            if d > max_delta {
                max_delta = d;
            }
            if d > 1e-4 {
                return Err(format!("node {id} ({}) diverges by {d}", g.nodes[id].name));
            }
        }
    }
    Ok(max_delta)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::resnet::{fig1_example, fig3_example, resnet18_at};
    use crate::config::{ArchConfig, System};
    use crate::dataflow::plan;

    #[test]
    fn fig1_two_conv_fusion_is_exact() {
        let g = fig1_example();
        let cfg = ArchConfig::system(System::Fused4, 2048, 0);
        let p = plan(&g, &cfg);
        assert!(p.num_fused_kernels() >= 1, "fig1 should fuse");
        let delta = validate_plan(&g, &p, 42).unwrap();
        assert_eq!(delta, 0.0, "identical op order must be bit-exact");
    }

    #[test]
    fn fig3_graph_with_residuals_is_exact() {
        let g = fig3_example();
        for sys in [System::Fused16, System::Fused4] {
            let cfg = ArchConfig::system(sys, 2048, 128);
            let p = plan(&g, &cfg);
            let delta = validate_plan(&g, &p, 7).unwrap();
            assert_eq!(delta, 0.0, "{sys:?}");
        }
    }

    #[test]
    fn small_resnet_validates_on_both_fused_systems() {
        // 32px keeps debug-mode convolutions fast; tile grids stay valid
        // (first-8 output is 8x8 -> 2x2 tiles under Fused16's 4x4 grid).
        let g = resnet18_at(32);
        for sys in [System::Fused16, System::Fused4] {
            let cfg = ArchConfig::system(sys, 32 * 1024, 256);
            let p = plan(&g, &cfg);
            p.validate(&g).unwrap();
            let delta = validate_plan(&g, &p, 1234).unwrap();
            assert_eq!(delta, 0.0, "{sys:?}");
        }
    }

    #[test]
    fn lbl_plan_trivially_validates() {
        let g = resnet18_at(32);
        let cfg = ArchConfig::baseline();
        let p = plan(&g, &cfg);
        let delta = validate_plan(&g, &p, 5).unwrap();
        assert_eq!(delta, 0.0);
    }

    #[test]
    fn corrupted_halo_is_caught() {
        // Shrink a demanded region by one pixel: the validator must
        // detect the divergence (guards the guard).
        let g = fig1_example();
        let input = synth_input(&g, 9);
        let reference = run_reference(&g, &input, 9);
        // Tile with a wrong (too small) input slice: emulate by slicing
        // the input to the *output* rect (no halo) and running the conv.
        let out_rect = Rect::new(0, 0, 8, 8);
        let bad_in = input.slice(&out_rect);
        let w = synth_weights(&g.nodes[1], 9);
        let bad = bad_in.conv2d(&w, 16, 3, 1, 1, true);
        let good_slice = reference[1].slice(&out_rect);
        let diverges = bad
            .data()
            .iter()
            .zip(good_slice.data().iter())
            .any(|(a, b)| (a - b).abs() > 1e-4);
        assert!(diverges, "missing halo must corrupt border pixels");
    }
}
