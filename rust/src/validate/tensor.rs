//! A minimal CHW f32 tensor with the CNN ops the validator needs.
//!
//! Operation order per output element is fixed (channel-major, then
//! `ky`, `kx`), so full-map and region-wise execution produce *identical*
//! f32 results — the property the dataflow validator relies on for its
//! bit-exact comparison.

use crate::dataflow::tiling::Rect;

/// Channel-major (c, h, w) tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    c: usize,
    h: usize,
    w: usize,
    data: Vec<f32>,
}

impl Tensor {
    /// An all-zero `c`×`h`×`w` tensor.
    pub fn zeros(c: usize, h: usize, w: usize) -> Self {
        Self { c, h, w, data: vec![0.0; c * h * w] }
    }

    /// The 0×0×0 tensor (placeholder for not-yet-materialized outputs).
    pub fn empty() -> Self {
        Self { c: 0, h: 0, w: 0, data: vec![] }
    }

    /// True for the [`Tensor::empty`] placeholder (no elements).
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Build a `c`×`h`×`w` tensor element-wise from `f(c, y, x)`.
    pub fn from_fn(c: usize, h: usize, w: usize, mut f: impl FnMut(usize, usize, usize) -> f32) -> Self {
        let mut t = Self::zeros(c, h, w);
        for ci in 0..c {
            for y in 0..h {
                for x in 0..w {
                    let v = f(ci, y, x);
                    t.data[(ci * h + y) * w + x] = v;
                }
            }
        }
        t
    }

    /// The `(c, h, w)` shape.
    pub fn dims(&self) -> (usize, usize, usize) {
        (self.c, self.h, self.w)
    }

    /// The backing storage, channel-major `(c, h, w)` row-major.
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Element at `(c, y, x)` (debug-asserted in bounds).
    #[inline]
    pub fn at(&self, c: usize, y: usize, x: usize) -> f32 {
        debug_assert!(c < self.c && y < self.h && x < self.w);
        self.data[(c * self.h + y) * self.w + x]
    }

    #[inline]
    fn set(&mut self, c: usize, y: usize, x: usize, v: f32) {
        self.data[(c * self.h + y) * self.w + x] = v;
    }

    /// Copy out a spatial rectangle (all channels).
    pub fn slice(&self, r: &Rect) -> Tensor {
        let mut out = Tensor::zeros(self.c, r.h(), r.w());
        for c in 0..self.c {
            for y in 0..r.h() {
                for x in 0..r.w() {
                    out.set(c, y, x, self.at(c, r.y0 + y, r.x0 + x));
                }
            }
        }
        out
    }

    /// Slice `want` (absolute coords) out of a tensor that itself covers
    /// the absolute region `have`.
    pub fn slice_rel(&self, have: &Rect, want: &Rect) -> Tensor {
        assert!(have.contains(want), "want {want:?} outside have {have:?}");
        let rel = Rect::new(want.x0 - have.x0, want.y0 - have.y0, want.x1 - have.x0, want.y1 - have.y0);
        self.slice(&rel)
    }

    /// Paste a tile (covering absolute region `r`) into this full map.
    pub fn paste(&mut self, r: &Rect, tile: &Tensor) {
        assert_eq!(tile.dims(), (self.c, r.h(), r.w()));
        for c in 0..self.c {
            for y in 0..r.h() {
                for x in 0..r.w() {
                    self.set(c, r.y0 + y, r.x0 + x, tile.at(c, y, x));
                }
            }
        }
    }

    /// Plain conv2d producing the full output map. Weights are
    /// `[cout][cin][k][k]` row-major; accumulation order is (cin, ky, kx).
    pub fn conv2d(&self, w: &[f32], cout: usize, k: usize, stride: usize, pad: usize, relu: bool) -> Tensor {
        let oh = (self.h + 2 * pad - k) / stride + 1;
        let ow = (self.w + 2 * pad - k) / stride + 1;
        self.conv2d_region(w, cout, k, stride, pad, relu, Rect::full(self.h, self.w), Rect::full(oh, ow))
    }

    /// Conv2d over an output region, reading from a tensor that covers the
    /// absolute input region `in_rect`. Out-of-region (but in-map) taps
    /// must not occur — the tiling demands guarantee the halo is present;
    /// taps outside the *feature map* are zero padding as usual.
    #[allow(clippy::too_many_arguments)]
    pub fn conv2d_region(
        &self,
        w: &[f32],
        cout: usize,
        k: usize,
        stride: usize,
        pad: usize,
        relu: bool,
        in_rect: Rect,
        out_region: Rect,
    ) -> Tensor {
        let cin = self.c;
        assert_eq!(w.len(), cout * cin * k * k, "weight count mismatch");
        let mut out = Tensor::zeros(cout, out_region.h(), out_region.w());
        // Absolute input map extent (for zero padding): reconstructed
        // from the slice position — anything < 0 or >= map edge is pad.
        for co in 0..cout {
            for oy in out_region.y0..out_region.y1 {
                for ox in out_region.x0..out_region.x1 {
                    let mut acc = 0.0f32;
                    for ci in 0..cin {
                        for ky in 0..k {
                            for kx in 0..k {
                                let iy = (oy * stride + ky) as isize - pad as isize;
                                let ix = (ox * stride + kx) as isize - pad as isize;
                                if iy < 0 || ix < 0 {
                                    continue; // zero pad
                                }
                                let (iy, ix) = (iy as usize, ix as usize);
                                // Taps beyond the demanded rect only occur
                                // past the map edge (clamped demand) —
                                // treat as pad.
                                if iy < in_rect.y0 || iy >= in_rect.y1 || ix < in_rect.x0 || ix >= in_rect.x1 {
                                    continue;
                                }
                                let v = self.at(ci, iy - in_rect.y0, ix - in_rect.x0);
                                acc += v * w[((co * cin + ci) * k + ky) * k + kx];
                            }
                        }
                    }
                    if relu && acc < 0.0 {
                        acc = 0.0;
                    }
                    out.set(co, oy - out_region.y0, ox - out_region.x0, acc);
                }
            }
        }
        out
    }

    /// Max-pool producing the full output map (pad taps ignored).
    pub fn maxpool(&self, k: usize, stride: usize, pad: usize) -> Tensor {
        let oh = (self.h + 2 * pad - k) / stride + 1;
        let ow = (self.w + 2 * pad - k) / stride + 1;
        self.maxpool_region(k, stride, pad, Rect::full(self.h, self.w), Rect::full(oh, ow))
    }

    /// Max-pool over an output region; input covers absolute `in_rect`
    /// (same halo contract as [`Tensor::conv2d_region`]).
    pub fn maxpool_region(&self, k: usize, stride: usize, pad: usize, in_rect: Rect, out_region: Rect) -> Tensor {
        self.pool_region(k, stride, pad, in_rect, out_region, true)
    }

    /// Average-pool producing the full output map (`count_include_pad`,
    /// the torch default: divisor is always `k*k`).
    pub fn avgpool(&self, k: usize, stride: usize, pad: usize) -> Tensor {
        let oh = (self.h + 2 * pad - k) / stride + 1;
        let ow = (self.w + 2 * pad - k) / stride + 1;
        self.avgpool_region(k, stride, pad, Rect::full(self.h, self.w), Rect::full(oh, ow))
    }

    /// Average-pool over an output region; input covers absolute `in_rect`
    /// (same halo contract as [`Tensor::conv2d_region`]).
    pub fn avgpool_region(&self, k: usize, stride: usize, pad: usize, in_rect: Rect, out_region: Rect) -> Tensor {
        self.pool_region(k, stride, pad, in_rect, out_region, false)
    }

    fn pool_region(&self, k: usize, stride: usize, pad: usize, in_rect: Rect, out_region: Rect, is_max: bool) -> Tensor {
        let mut out = Tensor::zeros(self.c, out_region.h(), out_region.w());
        for c in 0..self.c {
            for oy in out_region.y0..out_region.y1 {
                for ox in out_region.x0..out_region.x1 {
                    let mut m = f32::NEG_INFINITY;
                    let mut s = 0.0f32;
                    let mut cnt = 0usize;
                    for ky in 0..k {
                        for kx in 0..k {
                            let iy = (oy * stride + ky) as isize - pad as isize;
                            let ix = (ox * stride + kx) as isize - pad as isize;
                            if iy < 0 || ix < 0 {
                                continue;
                            }
                            let (iy, ix) = (iy as usize, ix as usize);
                            if iy < in_rect.y0 || iy >= in_rect.y1 || ix < in_rect.x0 || ix >= in_rect.x1 {
                                continue;
                            }
                            let v = self.at(c, iy - in_rect.y0, ix - in_rect.x0);
                            m = m.max(v);
                            s += v;
                            cnt += 1;
                        }
                    }
                    let v = if is_max {
                        if cnt == 0 { 0.0 } else { m }
                    } else if cnt == 0 {
                        0.0
                    } else {
                        s / (k * k) as f32 // count_include_pad, torch default
                    };
                    out.set(c, oy - out_region.y0, ox - out_region.x0, v);
                }
            }
        }
        out
    }

    /// Global average pool: each channel collapses to its spatial mean.
    pub fn global_avg(&self) -> Tensor {
        let mut out = Tensor::zeros(self.c, 1, 1);
        let n = (self.h * self.w) as f32;
        for c in 0..self.c {
            let mut s = 0.0;
            for y in 0..self.h {
                for x in 0..self.w {
                    s += self.at(c, y, x);
                }
            }
            out.set(c, 0, 0, s / n);
        }
        out
    }

    /// Element-wise residual add followed by ReLU (`max(a + b, 0)`).
    pub fn add_relu(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.dims(), other.dims());
        let mut out = Tensor::zeros(self.c, self.h, self.w);
        for (o, (a, b)) in out.data.iter_mut().zip(self.data.iter().zip(other.data.iter())) {
            *o = (a + b).max(0.0);
        }
        out
    }

    /// Fully connected over a flattened (c,1,1) input. Weights `[cout][cin]`.
    pub fn fc(&self, w: &[f32], cout: usize) -> Tensor {
        let cin = self.c * self.h * self.w;
        assert_eq!(w.len(), cout * cin);
        let mut out = Tensor::zeros(cout, 1, 1);
        for co in 0..cout {
            let mut acc = 0.0f32;
            for ci in 0..cin {
                acc += self.data[ci] * w[co * cin + ci];
            }
            out.set(co, 0, 0, acc);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_identity_kernel() {
        // 1x1 conv with identity weights reproduces the input channel.
        let t = Tensor::from_fn(2, 3, 3, |c, y, x| (c * 9 + y * 3 + x) as f32);
        let w = vec![1.0, 0.0, 0.0, 1.0]; // cout=2 cin=2 k=1: identity
        let o = t.conv2d(&w, 2, 1, 1, 0, false);
        assert_eq!(o.data(), t.data());
    }

    #[test]
    fn conv_known_answer() {
        // 3x3 all-ones kernel, 1 channel: output = window sums.
        let t = Tensor::from_fn(1, 3, 3, |_, y, x| (y * 3 + x) as f32);
        let w = vec![1.0; 9];
        let o = t.conv2d(&w, 1, 3, 1, 0, false);
        assert_eq!(o.dims(), (1, 1, 1));
        assert_eq!(o.at(0, 0, 0), 36.0); // 0+1+..+8
    }

    #[test]
    fn conv_region_matches_full() {
        let t = Tensor::from_fn(3, 8, 8, |c, y, x| ((c + 2 * y + 3 * x) % 7) as f32 - 3.0);
        let w: Vec<f32> = (0..4 * 3 * 9).map(|i| ((i % 5) as f32 - 2.0) * 0.1).collect();
        let full = t.conv2d(&w, 4, 3, 1, 1, true);
        // Compute an interior region from its demanded slice only.
        let out_region = Rect::new(2, 3, 6, 7);
        let in_demand = out_region.window_demand(3, 1, 1, 8, 8);
        let sliced = t.slice(&in_demand);
        let region = sliced.conv2d_region(&w, 4, 3, 1, 1, true, in_demand, out_region);
        let expect = full.slice(&out_region);
        assert_eq!(region.data(), expect.data());
    }

    #[test]
    fn maxpool_region_matches_full() {
        let t = Tensor::from_fn(2, 8, 8, |c, y, x| ((3 * c + y * x) % 11) as f32);
        let full = t.maxpool(3, 2, 1);
        let out_region = Rect::new(0, 0, 2, 4);
        let in_demand = out_region.window_demand(3, 2, 1, 8, 8);
        let region = t.slice(&in_demand).maxpool_region(3, 2, 1, in_demand, out_region);
        assert_eq!(region.data(), full.slice(&out_region).data());
    }

    #[test]
    fn paste_and_slice_roundtrip() {
        let t = Tensor::from_fn(2, 6, 6, |c, y, x| (c * 36 + y * 6 + x) as f32);
        let r = Rect::new(1, 2, 4, 5);
        let s = t.slice(&r);
        let mut copy = Tensor::zeros(2, 6, 6);
        copy.paste(&r, &s);
        assert_eq!(copy.slice(&r).data(), s.data());
    }

    #[test]
    fn add_relu_clamps() {
        let a = Tensor::from_fn(1, 1, 2, |_, _, x| if x == 0 { -2.0 } else { 1.0 });
        let b = Tensor::from_fn(1, 1, 2, |_, _, _| 0.5);
        let o = a.add_relu(&b);
        assert_eq!(o.data(), &[0.0, 1.5]);
    }

    #[test]
    fn global_avg_is_mean() {
        let t = Tensor::from_fn(1, 2, 2, |_, y, x| (y * 2 + x) as f32);
        assert_eq!(t.global_avg().at(0, 0, 0), 1.5);
    }

    #[test]
    fn fc_known_answer() {
        let t = Tensor::from_fn(3, 1, 1, |c, _, _| c as f32 + 1.0); // [1,2,3]
        let w = vec![1.0, 1.0, 1.0, 0.0, 1.0, 0.0]; // rows: sum, pick-2nd
        let o = t.fc(&w, 2);
        assert_eq!(o.data(), &[6.0, 2.0]);
    }
}
