//! Trace generation: lower a [`Plan`] onto the PIMfused architecture,
//! emitting the Table-I command stream with analytic transfer volumes.
//!
//! This is the "CNN application + mapping strategy → command trace" box of
//! the paper's profiling framework (Fig. 4). The reuse formulas live in
//! [`crate::dataflow::CostModel`]; this module decides *which path* each
//! byte takes (near-bank, bank↔LBUF, or the sequential cross-bank
//! GBUF route) based on the current data layout of every feature map.

use crate::cnn::{Graph, NodeId, Op};
use crate::config::{ArchConfig, ELEM_BYTES, ROW_BYTES};
use crate::dataflow::tiling::{tile_grid, tile_segment, TileDemand};
use crate::dataflow::{CostModel, Plan, PlanStep};
use crate::fault::FaultPlan;
use crate::trace::{CmdKind, ExecFlags, PerCore, RowMap, RowSpan, Trace, MAX_CORES};
use std::collections::HashMap;

/// Where a feature map currently lives in the channel.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Layout {
    /// Partitioned across banks by output channel (layer-by-layer layout).
    CoutBanked,
    /// Partitioned across banks by spatial tile of the given grid
    /// (fused-kernel layout).
    Spatial { ty: usize, tx: usize },
}

/// Trace generator state.
pub struct TraceGen<'a> {
    g: &'a Graph,
    cfg: &'a ArchConfig,
    model: CostModel,
    /// The config's resolved fault plan. When it retires topology
    /// (`is_degraded`), the generator remaps every per-core workload and
    /// host row map onto the surviving cores and banks — a healthy plan
    /// leaves the emitted trace byte-identical to the pre-fault path.
    fplan: FaultPlan,
    layout: HashMap<NodeId, Layout>,
    /// Base row of each feature map's region in the trace-global
    /// per-bank row address space (see [`TraceGen::row_base`]).
    row_regions: HashMap<NodeId, u64>,
    /// Next unallocated base row.
    next_row: u64,
    trace: Trace,
}

/// Generate the command trace for `plan` on `cfg`.
pub fn generate(g: &Graph, cfg: &ArchConfig, plan: &Plan, model: CostModel) -> Trace {
    let fplan = FaultPlan::build(cfg);
    let mut tg = TraceGen {
        g,
        cfg,
        model,
        fplan,
        layout: HashMap::new(),
        row_regions: HashMap::new(),
        next_row: 0,
        trace: Trace::default(),
    };
    tg.run(plan);
    tg.trace
}

impl<'a> TraceGen<'a> {
    fn run(&mut self, plan: &Plan) {
        // Host loads the network input. If the first step is fused, the
        // host writes it already spatially partitioned (Fig. 3(c): "all
        // PIMcores fetch L0 inputs from banks, each handling a different
        // spatial segment") — halo replication is still charged when the
        // fused kernel fetches it.
        // Either way the input is partitioned across every bank in the
        // channel; the row map records how many DRAM rows land in each.
        let input_bytes = self.g.nodes[0].shape.bytes() as u64;
        let first_layout = match plan.steps.first() {
            Some(PlanStep::Fused { grid, .. }) => Layout::Spatial { ty: grid.0, tx: grid.1 },
            _ => Layout::CoutBanked,
        };
        let rows = self.host_row_map(0, first_layout);
        self.trace.push_dep(0, CmdKind::HostWrite { bytes: input_bytes, rows }, &[], Some(0));
        self.layout.insert(0, first_layout);

        for step in &plan.steps {
            match *step {
                PlanStep::Lbl { node } => self.emit_lbl(node),
                PlanStep::Fused { start, end, grid } => self.emit_fused(start, end, grid),
            }
        }

        // Host reads the final output from wherever its layout placed it
        // (both layouts stripe the map across all banks; the recorded
        // layout of the last layer decides each bank's row count).
        let out = self.g.nodes.last().unwrap();
        let out_id = out.id;
        let out_bytes = out.shape.bytes() as u64;
        let out_layout = self.layout.get(&out_id).copied().unwrap_or(Layout::CoutBanked);
        let rows = self.host_row_map(out_id, out_layout);
        let span = Some(self.span_of(out_id, &rows));
        self.trace.push_dep_rows(
            out_id,
            CmdKind::HostRead { bytes: out_bytes, rows },
            &[out_id],
            None,
            span,
        );
    }

    /// The per-bank row map of node `id`'s feature map under `layout` —
    /// what the host I/O commands are annotated with (DESIGN.md §6.2).
    ///
    /// * `CoutBanked` maps stripe their bytes evenly across the channel
    ///   (channel-interleaved placement), so each bank activates the
    ///   rows of its 1/N byte share — with the remainder rows skewed to
    ///   the lowest banks.
    /// * `Spatial` maps give each PIMcore its own tile: the tile's
    ///   demanded bytes (its pixel share of the map) land in that core's
    ///   banks, so uneven tile grids produce genuinely uneven row maps.
    ///
    /// Under a degraded fault plan both layouts stripe over the
    /// *surviving* banks instead — a retired bank must never appear in a
    /// row map (the host cannot address it), and we model the degraded
    /// placement as channel-interleaved even for spatial layouts (the
    /// per-tile bank affinity is already broken by the core remap).
    fn host_row_map(&self, id: NodeId, layout: Layout) -> RowMap {
        let n = self.cfg.num_banks.min(MAX_CORES);
        let shape = &self.g.nodes[id].shape;
        if self.fplan.is_degraded() {
            return RowMap::striped_over(shape.bytes() as u64, self.fplan.surviving_banks());
        }
        match layout {
            Layout::CoutBanked => RowMap::striped(shape.bytes() as u64, n),
            Layout::Spatial { ty, tx } => {
                let bpc = self.cfg.banks_per_pimcore;
                let mut m = RowMap::EMPTY;
                for (core, rect) in tile_grid(shape.h, shape.w, ty, tx).iter().enumerate() {
                    let bytes = (rect.pixels() * shape.c * ELEM_BYTES) as u64;
                    // The tile stripes across its core's bank fan-in.
                    let banks = bpc as u64;
                    let (per, rem) = (bytes / banks, bytes % banks);
                    for k in 0..bpc {
                        let b = core * bpc + k;
                        if b >= n {
                            break;
                        }
                        let share = per + u64::from((k as u64) < rem);
                        m.set(b, share.div_ceil(ROW_BYTES as u64));
                    }
                }
                m
            }
        }
    }

    /// Base row of node `id`'s feature map in the trace-global per-bank
    /// row address space. Every map gets a distinct region sized by its
    /// full row footprint, so [`RowSpan`]s of different maps never
    /// compare equal and open-row reuse only triggers on genuinely
    /// re-read data (DESIGN.md §6.2).
    fn row_base(&mut self, id: NodeId) -> u64 {
        if let Some(&b) = self.row_regions.get(&id) {
            return b;
        }
        let rows = (self.g.nodes[id].shape.bytes() as u64).div_ceil(ROW_BYTES as u64).max(1);
        let base = self.next_row;
        self.next_row += rows;
        self.row_regions.insert(id, base);
        base
    }

    /// The [`RowSpan`] a stream with per-bank row map `rows` covers
    /// inside node `id`'s region: the region base through its deepest
    /// per-bank row.
    fn span_of(&mut self, id: NodeId, rows: &RowMap) -> RowSpan {
        let base = self.row_base(id);
        let depth = rows.iter().map(|(_, r)| r).max().unwrap_or(1).max(1);
        RowSpan { first: base, last: base + depth - 1 }
    }

    /// The [`RowSpan`] of a full-map stream of node `id` under its
    /// currently recorded layout.
    fn map_span(&mut self, id: NodeId) -> RowSpan {
        let layout = self.layout.get(&id).copied().unwrap_or(Layout::CoutBanked);
        let rows = self.host_row_map(id, layout);
        self.span_of(id, &rows)
    }

    /// Per-bank rows a cross-bank gather of the given feature maps
    /// reads: each producer's layout-derived map (the same `tile_grid`
    /// split host I/O uses), summed bank-wise for multi-operand gathers.
    fn gather_rows(&self, ids: &[NodeId]) -> RowMap {
        let mut m = RowMap::EMPTY;
        for &id in ids {
            let layout = self.layout.get(&id).copied().unwrap_or(Layout::CoutBanked);
            for (b, r) in self.host_row_map(id, layout).iter() {
                m.set(b, m.get(b) + r);
            }
        }
        m
    }

    /// Per-bank rows of a `bytes`-sized partial stream of one feature
    /// map (fused halo / reorganization traffic): striped like a
    /// `CoutBanked` map, over the surviving banks when degraded.
    fn partial_rows(&self, bytes: u64) -> RowMap {
        if self.fplan.is_degraded() {
            RowMap::striped_over(bytes, self.fplan.surviving_banks())
        } else {
            RowMap::striped(bytes, self.cfg.num_banks.min(MAX_CORES))
        }
    }

    // ---------------------------------------------------------------
    // Layer-by-layer emission (Fig. 3(b))
    // ---------------------------------------------------------------

    fn emit_lbl(&mut self, id: NodeId) {
        let n = &self.g.nodes[id];
        match n.op {
            Op::Conv { bn, relu, .. } => {
                let flags = if relu { ExecFlags::ConvBnRelu } else { ExecFlags::ConvBn };
                let _ = bn;
                self.emit_lbl_mac(id, flags);
            }
            Op::Fc { .. } => self.emit_lbl_mac(id, ExecFlags::Gemv),
            Op::Pool { .. } => self.emit_lbl_gbcore(id, ExecFlags::Pool),
            Op::AddRelu => self.emit_lbl_gbcore(id, ExecFlags::AddRelu),
            Op::GlobalAvgPool => self.emit_lbl_gbcore(id, ExecFlags::Gap),
            Op::Input => unreachable!("input is never a plan step"),
        }
    }

    /// CONV/FC on PIMcores: weights stream from local banks (cout split),
    /// activations broadcast from the GBUF (§IV "Layer-by-layer dataflow").
    ///
    /// The per-MAC weight feed is the AiM per-pixel GEMV: 2 bytes/MAC
    /// stream from the open row of the local bank. An LBUF intercepts a
    /// fraction `1 − φ` of that feed ([`CostModel::lbl_feed_phi`]); what
    /// remains occupies the bank as row-buffer-hit reads.
    fn emit_lbl_mac(&mut self, id: NodeId, flags: ExecFlags) {
        let n = &self.g.nodes[id];
        let p = self.cfg.num_pimcores();
        // The cout split runs over the surviving cores: with a healthy
        // fault plan `k == p` and `uniform_alive` degenerates to the
        // plain uniform split, so the emitted trace is byte-identical;
        // degraded, each survivor carries a `1/k` share and dead cores
        // stay at zero everywhere.
        let k = (self.fplan.alive_core_count().max(1)) as u64;
        let in_bytes: u64 = n.inputs.iter().map(|&i| self.g.nodes[i].shape.bytes() as u64).sum();

        // Gather input activations into the GBUF (cross-bank, sequential).
        let rows = self.gather_rows(&n.inputs);
        let span = match n.inputs[..] {
            [src] => Some(self.map_span(src)),
            _ => None, // multi-map gathers interleave rows: no single identity
        };
        self.trace.push_dep_rows(id, CmdKind::Bk2Gbuf { bytes: in_bytes, rows }, &n.inputs, None, span);

        let w_total = n.weight_bytes() as u64;
        let w_core = w_total / k;
        let phi = self.model.lbl_feed_phi(n.shape.c, self.cfg.lbuf_bytes);

        // Resident weight slice loads into the LBUF once (if any). Weights
        // are static (host pre-distributed), so the fill reads no feature
        // map.
        let resident = (self.cfg.lbuf_bytes as u64).min(w_core);
        if resident > 0 {
            self.trace.push_dep(
                id,
                CmdKind::Bk2Lbuf { bytes: self.fplan.uniform_alive(p, resident) },
                &[],
                None,
            );
        }

        let macs_core = (n.macs() as u64) / k;
        let feed = (2.0 * macs_core as f64 * phi).round() as u64;
        // The non-LBUF-resident weights stream from the bank at least
        // once (unique first touch, counted in `bank_read`); the rest of
        // the surviving feed hits the open row buffer.
        let unique = w_core - resident; // resident part was read by Bk2Lbuf
        let hit = feed.saturating_sub(unique);
        let out_core = (n.shape.bytes() as u64) / k;
        let elt_core = (n.eltwise_ops() as u64) / k;

        self.trace.push_dep(
            id,
            CmdKind::PimcoreCmp {
                flags,
                macs: self.fplan.uniform_alive(p, macs_core),
                eltwise: self.fplan.uniform_alive(p, elt_core),
                bank_read: self.fplan.uniform_alive(p, unique),
                bank_read_hit: self.fplan.uniform_alive(p, hit),
                bank_write: self.fplan.uniform_alive(p, out_core),
                gbuf_stream: (in_bytes as f64 * self.model.broadcast_pace).round() as u64,
            },
            &n.inputs,
            Some(id),
        );
        self.layout.insert(id, Layout::CoutBanked);
    }

    /// POOL/ADD_RELU/GAP on the GBcore: gather → compute → scatter, all
    /// through the sequential GBUF path (the Fig. 3(b) bottleneck).
    fn emit_lbl_gbcore(&mut self, id: NodeId, flags: ExecFlags) {
        let n = &self.g.nodes[id];
        let in_bytes: u64 = n.inputs.iter().map(|&i| self.g.nodes[i].shape.bytes() as u64).sum();
        let out_bytes = n.shape.bytes() as u64;
        let rows = self.gather_rows(&n.inputs);
        let span = match n.inputs[..] {
            [src] => Some(self.map_span(src)),
            _ => None, // multi-map gathers interleave rows: no single identity
        };
        self.trace.push_dep_rows(id, CmdKind::Bk2Gbuf { bytes: in_bytes, rows }, &n.inputs, None, span);
        self.trace.push_dep(id, CmdKind::GbcoreCmp { flags, eltwise: n.eltwise_ops() as u64 }, &[], None);
        // The scatter places the result in banks: it defines `id`'s layout.
        let out_rows = self.host_row_map(id, Layout::CoutBanked);
        self.trace.push_dep(id, CmdKind::Gbuf2Bk { bytes: out_bytes, rows: out_rows }, &[], Some(id));
        self.layout.insert(id, Layout::CoutBanked);
    }

    // ---------------------------------------------------------------
    // Fused-kernel emission (Fig. 3(c))
    // ---------------------------------------------------------------

    fn emit_fused(&mut self, start: NodeId, end: NodeId, grid: (usize, usize)) {
        let (ty, tx) = grid;
        let tiles = tile_segment(self.g, start, end, ty, tx);
        let p = tiles.len();
        debug_assert_eq!(p, self.cfg.num_pimcores());

        self.fetch_fused_inputs(start, &tiles, grid);

        for id in start..=end {
            self.emit_fused_layer(id, start, &tiles);
        }

        // The kernel output lives spatially tiled across banks.
        self.layout.insert(end, Layout::Spatial { ty, tx });
    }

    /// Stage the external inputs of a fused segment. Bytes whose source
    /// bank differs from the consuming PIMcore's bank must route through
    /// the GBUF (read + write over the shared bus); bytes already local
    /// are fetched near-bank during compute and cost nothing here.
    fn fetch_fused_inputs(&mut self, seg_start: NodeId, tiles: &[TileDemand], grid: (usize, usize)) {
        let mut ext_ids: Vec<NodeId> =
            tiles.iter().flat_map(|t| t.external.keys()).collect();
        ext_ids.sort_unstable();
        ext_ids.dedup();

        for pid in ext_ids {
            let prod = &self.g.nodes[pid];
            let demanded: u64 = tiles
                .iter()
                .filter_map(|t| t.external.get(&pid))
                .map(|r| (r.pixels() * prod.shape.c * ELEM_BYTES) as u64)
                .sum();
            let full = prod.shape.bytes() as u64;
            let matching = matches!(
                self.layout.get(&pid),
                Some(Layout::Spatial { ty, tx }) if (*ty, *tx) == grid
            );
            // Matching spatial layout: only the halo surplus crosses banks.
            // Any other layout: the whole demanded volume is reorganized
            // (the orange "reorganize" boxes of Fig. 3(c)).
            let cross = if matching { demanded.saturating_sub(full) } else { demanded };
            if cross > 0 {
                // The reorganization *rewrites* producer `pid`'s bank
                // placement: readers of `pid` inside the segment must wait
                // for the scatter, which is why it registers as the new
                // writer of `pid`.
                let rows = self.partial_rows(cross);
                let span = Some(self.span_of(pid, &rows));
                self.trace.push_dep_rows(
                    seg_start,
                    CmdKind::Bk2Gbuf { bytes: cross, rows },
                    &[pid],
                    None,
                    span,
                );
                self.trace.push_dep(
                    seg_start,
                    CmdKind::Gbuf2Bk { bytes: cross, rows: self.partial_rows(cross) },
                    &[],
                    Some(pid),
                );
            }
        }
    }

    /// One layer inside a fused kernel: weights gathered to the GBUF and
    /// broadcast; each PIMcore computes its tile's demanded region with
    /// activations from LBUF/local bank (§IV "Fused-layer dataflow").
    fn emit_fused_layer(&mut self, id: NodeId, seg_start: NodeId, tiles: &[TileDemand]) {
        let n = &self.g.nodes[id];
        let p = tiles.len();
        let lbuf = self.cfg.lbuf_bytes;

        // Per-tile demanded output pixels of this node.
        let out_pix: Vec<u64> = tiles
            .iter()
            .map(|t| t.per_node.get(&id).map_or(0, |r| r.pixels() as u64))
            .collect();
        // Per-tile demanded *input* volume (activations the core streams).
        let in_bytes: Vec<u64> = tiles
            .iter()
            .map(|t| {
                n.inputs
                    .iter()
                    .map(|i| {
                        let r = t
                            .per_node
                            .get(i)
                            .or_else(|| t.external.get(i))
                            .copied()
                            .unwrap_or(crate::dataflow::tiling::Rect::new(0, 0, 0, 0));
                        (r.pixels() * self.g.nodes[*i].shape.c * ELEM_BYTES) as u64
                    })
                    .sum()
            })
            .collect();

        let full_pix = (n.shape.h * n.shape.w) as u64;
        let scale = |total: u64, pix: u64| -> u64 {
            ((total as f64) * (pix as f64) / (full_pix as f64)).round() as u64
        };

        let (flags, w_total) = match n.op {
            Op::Conv { relu, .. } => (
                if relu { ExecFlags::ConvBnRelu } else { ExecFlags::ConvBn },
                n.weight_bytes() as u64,
            ),
            Op::Pool { .. } => (ExecFlags::Pool, 0),
            Op::AddRelu => (ExecFlags::AddRelu, 0),
            _ => unreachable!("non-tileable op {:?} inside fused kernel", n.op),
        };

        // Weights are static, so the host pre-distributes (and, for fused
        // kernels, replicates) them across banks at model-load time — no
        // runtime reorganization. During execution they stream through
        // the GBUF to all PIMcores in lockstep; buffers too small to keep
        // them (or the activation window) resident force re-broadcasts —
        // up to once per output pixel in the per-pixel GEMV limit
        // (Takeaway 1's mechanism).
        let cin = self.g.nodes[n.inputs[0]].shape.c;
        let tile_pixels_max = out_pix.iter().copied().max().unwrap_or(0) as usize;
        let passes = if w_total > 0 {
            self.model.fused_bcast_restream(
                tile_pixels_max,
                self.cfg.gbuf_bytes,
                lbuf,
                w_total as usize,
                cin,
            )
        } else {
            1.0
        };
        let bcast = (w_total as f64 * passes * self.model.broadcast_pace).round() as u64;

        // Activations: whether the per-tile working set is LBUF-resident
        // decides if intermediates spill to the local bank, and the LBUF
        // suppresses per-broadcast-pass re-reads of the spilled data.
        let mut bank_read = PerCore::zero(p);
        let mut bank_hit = PerCore::zero(p);
        let mut bank_write = PerCore::zero(p);
        let mut macs = PerCore::zero(p);
        let mut eltwise = PerCore::zero(p);
        let mut lbuf_fill = PerCore::zero(p);

        for t in 0..p {
            let out_b = scale(n.shape.bytes() as u64, out_pix[t]);
            let working = in_bytes[t] + out_b;
            let resident = (lbuf as u64) >= working;
            if resident {
                // Fill once from the local bank only if the producer was
                // external to the segment (intermediates are born in LBUF).
                if n.inputs.iter().any(|i| *i < seg_start) {
                    lbuf_fill.set(t, in_bytes[t]);
                }
            } else {
                // Spilled working set: one unique stream, plus an open-row
                // re-walk of the activations for each surviving extra
                // weight-broadcast pass.
                bank_read.set(t, in_bytes[t]);
                let rereads = (in_bytes[t] as f64 * (passes - 1.0)).round() as u64;
                bank_hit.set(t, rereads);
                bank_write.set(t, out_b);
            }
            macs.set(t, scale(n.macs() as u64, out_pix[t]));
            eltwise.set(t, scale(n.eltwise_ops() as u64, out_pix[t]));
        }

        // Degraded remap: the tile geometry (and so the residency and
        // re-broadcast decisions above) is evaluated per nominal tile,
        // then the work redistributes evenly over the surviving cores —
        // sums are conserved exactly, each survivor carries at most a
        // `ceil(total/k)` share, and dead cores end at zero. Healthy
        // plans skip this, keeping the per-tile skew byte-identical.
        if self.fplan.is_degraded() {
            bank_read = self.fplan.spread_even(bank_read.sum(), p);
            bank_hit = self.fplan.spread_even(bank_hit.sum(), p);
            bank_write = self.fplan.spread_even(bank_write.sum(), p);
            macs = self.fplan.spread_even(macs.sum(), p);
            eltwise = self.fplan.spread_even(eltwise.sum(), p);
            lbuf_fill = self.fplan.spread_even(lbuf_fill.sum(), p);
        }

        if lbuf_fill.sum() > 0 {
            self.trace.push_dep(id, CmdKind::Bk2Lbuf { bytes: lbuf_fill }, &n.inputs, None);
        }
        self.trace.push_dep(
            id,
            CmdKind::PimcoreCmp {
                flags,
                macs,
                eltwise,
                bank_read,
                bank_read_hit: bank_hit,
                bank_write,
                gbuf_stream: bcast,
            },
            &n.inputs,
            Some(id),
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::resnet::{resnet18, resnet18_first8};
    use crate::config::System;
    use crate::dataflow::plan;

    fn trace_for(sys: System, g: &Graph, gbuf: usize, lbuf: usize) -> Trace {
        let cfg = ArchConfig::system(sys, gbuf, lbuf);
        let p = plan(g, &cfg);
        p.validate(g).unwrap();
        generate(g, &cfg, &p, CostModel::default())
    }

    #[test]
    fn fused_cuts_cross_bank_traffic_on_first8() {
        // The motivating claim (Fig. 1): fused-layer dataflow reduces
        // cross-bank transfers vs layer-by-layer on the same workload.
        let g = resnet18_first8();
        let lbl = trace_for(System::AimLike, &g, 2048, 0).stats();
        let fused = trace_for(System::Fused16, &g, 2048, 0).stats();
        assert!(
            fused.cross_bank_total() < lbl.cross_bank_total() / 2,
            "fused {} vs lbl {}",
            fused.cross_bank_total(),
            lbl.cross_bank_total()
        );
    }

    #[test]
    fn lbl_gathers_every_layer_fused_does_not() {
        let g = resnet18_first8();
        let lbl = trace_for(System::AimLike, &g, 2048, 0);
        let fused = trace_for(System::Fused16, &g, 2048, 0);
        let gathers = |t: &Trace| {
            t.cmds
                .iter()
                .filter(|c| matches!(c.kind, CmdKind::Bk2Gbuf { .. }))
                .count()
        };
        // LbL: one activation gather per layer (8) at least.
        assert!(gathers(&lbl) >= 8);
        // Fused: weight gathers + halo only; fewer big activation moves.
        let lbl_bytes = lbl.stats().cross_bank_read;
        let fused_bytes = fused.stats().cross_bank_read;
        assert!(fused_bytes < lbl_bytes);
    }

    #[test]
    fn lbuf_reduces_near_bank_reads_lbl() {
        let g = resnet18_first8();
        let l0 = trace_for(System::AimLike, &g, 2048, 0).stats();
        let l256 = trace_for(System::AimLike, &g, 2048, 256).stats();
        assert!(l256.near_bank_read < l0.near_bank_read);
    }

    #[test]
    fn gbuf_reduces_fused_rebroadcasts_and_rereads() {
        let g = resnet18_first8();
        let g2k = trace_for(System::Fused16, &g, 2048, 0).stats();
        let g32k = trace_for(System::Fused16, &g, 32 * 1024, 0).stats();
        // A larger GBUF keeps fused weights resident: fewer weight
        // re-broadcasts and fewer open-row activation re-reads.
        assert!(g32k.broadcast < g2k.broadcast);
        assert!(g32k.near_bank_hit < g2k.near_bank_hit);
        // Unique (first-touch) volumes are unchanged.
        assert_eq!(g32k.near_bank_read, g2k.near_bank_read);
    }

    #[test]
    fn macs_are_conserved_lbl_and_inflated_fused() {
        // LbL executes exactly the graph's MACs; fused adds the halo
        // redundancy (§V-D), bounded well below 2x for ResNet18 tilings.
        let g = resnet18_first8();
        let total = g.total_macs() as u64;
        let lbl = trace_for(System::AimLike, &g, 2048, 0).stats();
        assert_eq!(lbl.total_macs, {
            // allow integer division remainders per layer
            let diff = (lbl.total_macs as i64 - total as i64).abs();
            assert!(diff < 1024, "lbl macs {} vs graph {}", lbl.total_macs, total);
            lbl.total_macs
        });
        let fused = trace_for(System::Fused16, &g, 2048, 0).stats();
        assert!(fused.total_macs > total);
        assert!((fused.total_macs as f64) < total as f64 * 1.6);
    }

    #[test]
    fn full_resnet_traces_on_all_systems() {
        let g = resnet18();
        for sys in System::ALL {
            let t = trace_for(sys, &g, 2048, 0);
            let s = t.stats();
            assert!(s.num_cmds > 50, "{sys:?} trace too small");
            assert!(s.total_macs > 1_500_000_000, "{sys:?} lost MACs");
            // Host writes input and reads output exactly once, and both
            // row maps span the full channel with at least one row per
            // bank (ResNet18's input and output stripe across all banks).
            let hw = t.cmds.iter().filter(|c| matches!(c.kind, CmdKind::HostWrite { .. })).count();
            let hr = t.cmds.iter().filter(|c| matches!(c.kind, CmdKind::HostRead { .. })).count();
            assert_eq!((hw, hr), (1, 1));
            for c in &t.cmds {
                if let CmdKind::HostWrite { rows, .. } | CmdKind::HostRead { rows, .. } = c.kind {
                    assert_eq!(rows.bank_count(), 16, "{sys:?}: host I/O spans every bank");
                    assert!(rows.total() >= 16, "{sys:?}: every bank activates a row");
                }
            }
        }
    }

    #[test]
    fn host_row_maps_follow_the_tensor_layout() {
        let g = resnet18();
        let input_rows = |t: &Trace| match &t.cmds[0].kind {
            CmdKind::HostWrite { rows, .. } => *rows,
            k => panic!("trace must open with the host input write, got {k:?}"),
        };
        // CoutBanked input: 224·224·3·2 B striped across 16 banks is
        // 18816 B per bank — exactly 10 rows each.
        let lbl = input_rows(&trace_for(System::AimLike, &g, 2048, 0));
        assert!(lbl.iter().all(|(_, r)| r == 10), "{lbl:?}");
        // Spatial input (Fused4, 2×2 grid): each 112×112 tile stripes
        // its 75264 B over the core's 4 banks — the same 10 rows per
        // bank, but derived from the tile geometry.
        let fused = input_rows(&trace_for(System::Fused4, &g, 2048, 0));
        assert_eq!(fused, lbl, "even tilings agree with the striped map");
        // Output (FC, 2000 B): 125 B per bank still opens one row each.
        let t = trace_for(System::Fused16, &g, 2048, 0);
        match &t.cmds.last().unwrap().kind {
            CmdKind::HostRead { rows, .. } => {
                assert_eq!(rows.bank_count(), 16);
                assert!(rows.iter().all(|(_, r)| r == 1), "{rows:?}");
            }
            k => panic!("trace must end with the host output read, got {k:?}"),
        }
    }

    #[test]
    fn cross_bank_row_maps_follow_producer_layouts() {
        let g = resnet18();
        // Layer-by-layer: every cross-bank transfer is annotated with a
        // non-empty row map spanning the full channel, and a gather of a
        // CoutBanked producer carries exactly the producer's striped map.
        let t = trace_for(System::AimLike, &g, 2048, 0);
        let mut gathers = 0;
        for c in &t.cmds {
            if let CmdKind::Bk2Gbuf { rows, .. } | CmdKind::Gbuf2Bk { rows, .. } = &c.kind {
                gathers += 1;
                assert!(!rows.is_empty(), "cross-bank command without a row map");
                assert_eq!(rows.bank_count(), 16, "LbL maps stripe the whole channel");
            }
        }
        assert!(gathers > 20, "ResNet18 LbL must gather every layer");
        // A single-producer gather's map is the producer's full-map
        // stripe: node 1 gathers the input (id 0, 150528 B over 16
        // banks = 10 rows/bank, the figure host_row_maps pins).
        let first_gather = t
            .cmds
            .iter()
            .find_map(|c| match &c.kind {
                CmdKind::Bk2Gbuf { rows, .. } if c.node == 1 => Some(*rows),
                _ => None,
            })
            .expect("layer 1 gathers its input");
        assert!(first_gather.iter().all(|(_, r)| r == 10), "{first_gather:?}");
    }

    #[test]
    fn row_spans_give_distinct_maps_distinct_identities() {
        let g = resnet18();
        let t = trace_for(System::AimLike, &g, 2048, 0);
        // Single-producer gathers carry a row span; spans of different
        // producers never collide (each map owns a distinct row region).
        let mut by_producer: HashMap<NodeId, crate::trace::RowSpan> = HashMap::new();
        for c in &t.cmds {
            if let CmdKind::Bk2Gbuf { .. } = c.kind {
                if let (1, Some(span)) = (c.reads.len(), c.row_span) {
                    let src = c.reads.iter().next().unwrap();
                    if let Some(prev) = by_producer.insert(src, span) {
                        assert_eq!(prev.first, span.first, "same map, same region base");
                    }
                }
            }
        }
        assert!(by_producer.len() > 10, "most LbL gathers are single-producer");
        let mut firsts: Vec<u64> = by_producer.values().map(|s| s.first).collect();
        firsts.sort_unstable();
        firsts.dedup();
        assert_eq!(firsts.len(), by_producer.len(), "regions must not collide");
        // Writes and multi-operand gathers stay span-less.
        for c in &t.cmds {
            if matches!(c.kind, CmdKind::Gbuf2Bk { .. } | CmdKind::HostWrite { .. }) {
                assert!(c.row_span.is_none(), "writes carry no reuse identity");
            }
        }
    }

    #[test]
    fn degraded_traces_keep_dead_cores_idle_and_avoid_retired_banks() {
        use crate::fault::{FaultConfig, FaultPlan};
        let g = resnet18_first8();
        for sys in System::ALL {
            let base = ArchConfig::system(sys, 8192, 128);
            let cfg = base.clone().with_faults(FaultConfig {
                seed: 11,
                retired_banks: base.banks_per_pimcore,
                dead_cores: 1,
                transient_ppm: 0,
                max_retries: 0,
                dead_channels: 0,
            });
            let fplan = FaultPlan::build(&cfg);
            assert!(fplan.is_degraded(), "{sys:?}: the plan must retire topology");
            let alive_banks = fplan.surviving_banks();
            let p = base.num_pimcores();
            let pl = plan(&g, &cfg);
            let t = generate(&g, &cfg, &pl, CostModel::default());
            for c in &t.cmds {
                match &c.kind {
                    CmdKind::HostWrite { rows, .. } | CmdKind::HostRead { rows, .. } => {
                        for (b, _) in rows.iter() {
                            assert!(
                                alive_banks.contains(b),
                                "{sys:?}: retired bank {b} in a host row map"
                            );
                        }
                    }
                    CmdKind::PimcoreCmp { macs, bank_read, bank_read_hit, bank_write, .. } => {
                        for core in 0..p {
                            if !fplan.core_alive(core) {
                                let touched = macs.get(core)
                                    + bank_read.get(core)
                                    + bank_read_hit.get(core)
                                    + bank_write.get(core);
                                assert_eq!(touched, 0, "{sys:?}: dead core {core} works");
                            }
                        }
                    }
                    CmdKind::Bk2Lbuf { bytes } | CmdKind::Lbuf2Bk { bytes } => {
                        for core in 0..p {
                            if !fplan.core_alive(core) {
                                assert_eq!(
                                    bytes.get(core),
                                    0,
                                    "{sys:?}: dead core {core} streams its bank"
                                );
                            }
                        }
                    }
                    CmdKind::Bk2Gbuf { rows, .. } | CmdKind::Gbuf2Bk { rows, .. } => {
                        for (b, _) in rows.iter() {
                            assert!(
                                alive_banks.contains(b),
                                "{sys:?}: retired bank {b} in a cross-bank row map"
                            );
                        }
                    }
                    _ => {}
                }
            }
            // The remap conserves compute: total MACs stay within
            // integer-division remainders of the healthy trace (fused
            // spreads conserve their per-tile sums exactly).
            let healthy = generate(&g, &base, &plan(&g, &base), CostModel::default());
            let (d, h) = (t.stats().total_macs as i64, healthy.stats().total_macs as i64);
            assert!((d - h).abs() < 4096, "{sys:?}: degraded {d} vs healthy {h} MACs");
        }
    }

    #[test]
    fn huge_lbuf_eliminates_fused_spills() {
        let g = resnet18_first8();
        let small = trace_for(System::Fused4, &g, 64 * 1024, 256).stats();
        let paper_ideal = trace_for(System::Fused4, &g, 64 * 1024, 100 * 1024).stats();
        // An "ideal" LBUF holding every per-tile working set (the stem's
        // haloed 112x112 demands reach ~600KB) removes all spills.
        let ideal = trace_for(System::Fused4, &g, 64 * 1024, 1024 * 1024).stats();
        assert!(paper_ideal.near_bank_read + paper_ideal.near_bank_write
            <= small.near_bank_read + small.near_bank_write);
        assert!(ideal.near_bank_read + ideal.near_bank_write
            < small.near_bank_read + small.near_bank_write);
        assert!(ideal.lbuf_fill > 0);
    }
}
