//! PIM command traces — the interface between the dataflow mapper and the
//! cycle simulator, mirroring the paper's Table I custom commands.
//!
//! Commands here are *macro* commands: one `PIM_BK2GBUF` entry carries the
//! total bytes of a logically-contiguous sequential transfer, which the
//! engine expands analytically into column/row timing (the same
//! information a per-column Ramulator2 trace would carry, ~10^6× smaller;
//! DESIGN.md §5). Each command records the graph node it serves so traces
//! can be audited per layer.
//!
//! Commands additionally carry *dependency annotations* ([`Cmd::reads`],
//! [`Cmd::writes`]): the feature maps whose current bank layout the
//! command consumes, and the feature map whose data (or layout — fused
//! reorganizations rewrite a producer's placement) it defines. The
//! event-driven engine ([`crate::sim::event`]) derives command ordering
//! from these instead of executing the trace back-to-back; the analytic
//! engine ignores them. Traces built through [`Trace::push`] get empty
//! annotations, which the event engine treats as "ordered only against
//! commands of the same node".

pub mod gen;
pub mod partition;

use crate::cnn::NodeId;

/// Upper bound on PIMcores per channel (16 banks, 1-bank PIMcores).
pub const MAX_CORES: usize = 16;

/// A fixed-size per-PIMcore quantity (bytes, MACs, ...). Fixed array to
/// keep the hot trace free of heap allocation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PerCore {
    vals: [u64; MAX_CORES],
    n: usize,
}

impl PerCore {
    /// Zero on each of `n` cores (1 ≤ `n` ≤ [`MAX_CORES`]).
    pub fn zero(n: usize) -> Self {
        assert!(n >= 1 && n <= MAX_CORES);
        Self { vals: [0; MAX_CORES], n }
    }

    /// Same value on every core (layer-by-layer symmetric partitions).
    pub fn uniform(n: usize, v: u64) -> Self {
        let mut pc = Self::zero(n);
        pc.vals[..n].fill(v);
        pc
    }

    /// One value per core, in core order.
    pub fn from_slice(vs: &[u64]) -> Self {
        let mut pc = Self::zero(vs.len());
        pc.vals[..vs.len()].copy_from_slice(vs);
        pc
    }

    /// Number of cores the array covers.
    pub fn len(&self) -> usize {
        self.n
    }

    /// Whether the array covers no cores at all.
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Core `i`'s value (panics out of range).
    pub fn get(&self, i: usize) -> u64 {
        assert!(i < self.n);
        self.vals[i]
    }

    /// Set core `i`'s value (panics out of range).
    pub fn set(&mut self, i: usize, v: u64) {
        assert!(i < self.n);
        self.vals[i] = v;
    }

    /// The largest per-core value (what bounds a lockstep command).
    pub fn max(&self) -> u64 {
        self.vals[..self.n].iter().copied().max().unwrap_or(0)
    }

    /// The sum across cores (what the energy model tallies).
    pub fn sum(&self) -> u64 {
        self.vals[..self.n].iter().sum()
    }

    /// Per-core values in core order.
    pub fn iter(&self) -> impl Iterator<Item = u64> + '_ {
        self.vals[..self.n].iter().copied()
    }
}

/// A set of banks, as a bitmask over the channel's (≤ [`MAX_CORES`])
/// banks. The engines themselves consume the finer-grained [`RowMap`]
/// (which generalizes and superseded this type on the host-I/O path);
/// the mask survives as the compact public "which banks at all" view a
/// [`RowMap::banks`] projects out for downstream tooling.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct BankMask(u16);

impl BankMask {
    /// No banks — host traffic with no modeled residency.
    pub const EMPTY: BankMask = BankMask(0);

    /// The first `n` banks of the channel.
    pub fn all(n: usize) -> Self {
        assert!(n <= MAX_CORES);
        if n == 0 {
            BankMask(0)
        } else {
            BankMask(u16::MAX >> (MAX_CORES - n))
        }
    }

    /// The banks of the first `n` for which `f(b)` holds — how the
    /// fault layer projects a surviving-bank set out of its core map.
    pub fn from_fn(n: usize, f: impl Fn(usize) -> bool) -> Self {
        assert!(n <= MAX_CORES);
        let mut bits = 0u16;
        for b in (0..n).filter(|&b| f(b)) {
            bits |= 1 << b;
        }
        BankMask(bits)
    }

    /// Whether bank `b` is in the set (out-of-range banks never are).
    pub fn contains(&self, b: usize) -> bool {
        b < MAX_CORES && self.0 & (1 << b) != 0
    }

    /// Number of banks in the set.
    pub fn count(&self) -> usize {
        self.0.count_ones() as usize
    }

    /// Whether the set holds no banks at all.
    pub fn is_empty(&self) -> bool {
        self.0 == 0
    }

    /// Bank indices in ascending order.
    pub fn iter(&self) -> impl Iterator<Item = usize> + '_ {
        (0..MAX_CORES).filter(|&b| self.contains(b))
    }
}

/// Per-bank DRAM row counts of a host I/O command: which of the
/// channel's (≤ [`MAX_CORES`]) banks the stream physically lands in, and
/// how many 2-KB rows ([`crate::config::ROW_BYTES`]) it activates in
/// each. Generalizes [`BankMask`] — where the mask only said *which*
/// banks host traffic touches, the row map says *how much* lands in
/// each, so the event engine can meter every bank's slice span and every
/// bank group's ACT window from the rows that actually hit it instead of
/// even `div_ceil` shares (DESIGN.md §6.2). The trace generator computes
/// it from the feature map's tensor layout ([`gen`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct RowMap {
    rows: [u32; MAX_CORES],
}

impl RowMap {
    /// No banks — host traffic with no modeled residency.
    pub const EMPTY: RowMap = RowMap { rows: [0; MAX_CORES] };

    /// Row counts per bank, in bank order (`vs[b]` rows land in bank `b`).
    pub fn from_rows(vs: &[u64]) -> Self {
        assert!(vs.len() <= MAX_CORES, "row map wider than the channel");
        let mut m = RowMap::EMPTY;
        for (b, &r) in vs.iter().enumerate() {
            m.set(b, r);
        }
        m
    }

    /// The same row count in each of the first `n` banks.
    pub fn uniform(n: usize, rows: u64) -> Self {
        assert!(n <= MAX_CORES);
        let mut m = RowMap::EMPTY;
        for b in 0..n {
            m.set(b, rows);
        }
        m
    }

    /// The row map of `bytes` striped evenly across the first `n` banks
    /// (remainder bytes to the lowest banks), each bank activating
    /// `ceil(its bytes / ROW_BYTES)` rows — the channel-interleaved
    /// layout of a `CoutBanked` feature map.
    pub fn striped(bytes: u64, n: usize) -> Self {
        assert!(n <= MAX_CORES);
        let mut m = RowMap::EMPTY;
        if bytes == 0 || n == 0 {
            return m;
        }
        let (per, rem) = (bytes / n as u64, bytes % n as u64);
        for b in 0..n {
            let share = per + u64::from((b as u64) < rem);
            m.set(b, share.div_ceil(crate::config::ROW_BYTES as u64));
        }
        m
    }

    /// The row map of `bytes` striped evenly across the given bank set
    /// (remainder bytes to the lowest banks of the set) — the degraded
    /// analogue of [`RowMap::striped`] when retired banks shrink the
    /// channel. `striped_over(b, BankMask::all(n))` equals
    /// `striped(b, n)`.
    pub fn striped_over(bytes: u64, banks: BankMask) -> Self {
        let n = banks.count();
        let mut m = RowMap::EMPTY;
        if bytes == 0 || n == 0 {
            return m;
        }
        let (per, rem) = (bytes / n as u64, bytes % n as u64);
        for (i, b) in banks.iter().enumerate() {
            let share = per + u64::from((i as u64) < rem);
            m.set(b, share.div_ceil(crate::config::ROW_BYTES as u64));
        }
        m
    }

    /// Set bank `b`'s row count.
    pub fn set(&mut self, b: usize, rows: u64) {
        assert!(b < MAX_CORES);
        self.rows[b] = u32::try_from(rows).expect("per-bank row count exceeds u32");
    }

    /// Rows landing in bank `b` (0 for out-of-range banks).
    pub fn get(&self, b: usize) -> u64 {
        if b < MAX_CORES {
            self.rows[b] as u64
        } else {
            0
        }
    }

    /// Total rows across all banks. Per-bank rounding means this can
    /// exceed `ceil(bytes / ROW_BYTES)` — each bank opens its own rows.
    pub fn total(&self) -> u64 {
        self.rows.iter().map(|&r| r as u64).sum()
    }

    /// The banks with at least one row, as a [`BankMask`].
    pub fn banks(&self) -> BankMask {
        let mut bits = 0u16;
        for (b, &r) in self.rows.iter().enumerate() {
            if r > 0 {
                bits |= 1 << b;
            }
        }
        BankMask(bits)
    }

    /// Number of banks with at least one row.
    pub fn bank_count(&self) -> usize {
        self.rows.iter().filter(|&&r| r > 0).count()
    }

    /// Whether no bank holds any rows (interface-only host traffic).
    pub fn is_empty(&self) -> bool {
        self.rows.iter().all(|&r| r == 0)
    }

    /// `(bank, rows)` pairs for every non-empty bank, ascending.
    pub fn iter(&self) -> impl Iterator<Item = (usize, u64)> + '_ {
        self.rows.iter().enumerate().filter(|(_, &r)| r > 0).map(|(b, &r)| (b, r as u64))
    }
}

/// Execution flags of the compute commands (Table I note).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ExecFlags {
    /// Convolution with fused batch-norm.
    ConvBn,
    /// Convolution with fused batch-norm and ReLU.
    ConvBnRelu,
    /// Max/average pooling.
    Pool,
    /// Residual add with fused ReLU.
    AddRelu,
    /// FC runs on the MAC datapath like CONV (1×1 spatial).
    Gemv,
    /// Global average pool reduction.
    Gap,
}

/// One PIM command (Table I) or host I/O event, with analytic volumes.
// `PIMcore_CMP` carries five inline `PerCore` arrays, dwarfing the other
// variants — accepted: boxing it would put a heap allocation on the hot
// trace path this type exists to avoid.
#[allow(clippy::large_enum_variant)]
#[derive(Debug, Clone, PartialEq)]
pub enum CmdKind {
    /// `PIMcore_CMP` — all PIMcores execute concurrently.
    PimcoreCmp {
        /// Fused-operation selector (Table I note).
        flags: ExecFlags,
        /// MACs retired per core (max across cores bounds compute time).
        macs: PerCore,
        /// Element-wise ops per core (BN/ReLU/pool/add).
        eltwise: PerCore,
        /// First-touch bytes each core streams from its local bank(s):
        /// full near-bank access energy, row activations charged.
        bank_read: PerCore,
        /// Operand-feed re-read bytes served by the open row buffer
        /// (cheap column-mux energy, but they occupy the bank — this is
        /// where buffer-starved configs burn their memory cycles).
        bank_read_hit: PerCore,
        /// Bytes each core writes back to its local bank(s).
        bank_write: PerCore,
        /// Bytes broadcast from the GBUF over the shared bus (serial,
        /// snooped by all cores at once).
        gbuf_stream: u64,
    },
    /// `GBcore_CMP` — pool/add/gap on the channel-level GBcore.
    GbcoreCmp {
        /// Fused-operation selector (POOL / ADD_RELU / GAP).
        flags: ExecFlags,
        /// Element-wise ops the GBcore retires.
        eltwise: u64,
    },
    /// `PIM_BK2LBUF` — parallel bank→LBUF fill (all cores at once).
    Bk2Lbuf {
        /// Bytes each core fills from its local bank(s).
        bytes: PerCore,
    },
    /// `PIM_LBUF2BK` — parallel LBUF→bank spill.
    Lbuf2Bk {
        /// Bytes each core spills to its local bank(s).
        bytes: PerCore,
    },
    /// `PIM_BK2GBUF` — sequential bank-at-a-time gather into the GBUF
    /// (the cross-bank read path).
    Bk2Gbuf {
        /// Total bytes gathered over the shared bus.
        bytes: u64,
        /// Per-bank DRAM rows the gather reads, from the producing
        /// layer's tensor layout ([`RowMap`]). [`RowMap::EMPTY`] means
        /// the generator had no layout (synthetic traces): the engines
        /// fall back to splitting `ceil(bytes/ROW_BYTES)` activations
        /// evenly across the touched bank groups.
        rows: RowMap,
    },
    /// `PIM_GBUF2BK` — sequential GBUF→bank scatter (cross-bank write).
    Gbuf2Bk {
        /// Total bytes scattered over the shared bus.
        bytes: u64,
        /// Per-bank DRAM rows the scatter writes, from the destination
        /// layout ([`RowMap`]); see [`CmdKind::Bk2Gbuf`] for the
        /// [`RowMap::EMPTY`] fallback.
        rows: RowMap,
    },
    /// Host writes network input into banks over the channel interface,
    /// streaming bank-at-a-time through the banks of its row map (which
    /// records how many DRAM rows land in each destination bank).
    HostWrite {
        /// Bytes crossing the off-chip interface.
        bytes: u64,
        /// Per-bank DRAM rows the stream lands in ([`RowMap`]).
        rows: RowMap,
    },
    /// Host reads network output from the banks its row map says hold it.
    HostRead {
        /// Bytes crossing the off-chip interface.
        bytes: u64,
        /// Per-bank DRAM rows the stream reads back ([`RowMap`]).
        rows: RowMap,
    },
}

impl CmdKind {
    /// The Table-I mnemonic of this command (`PIM_BK2GBUF`, `HOST_WRITE`,
    /// ...): the stable name the trace dump and the observability
    /// exporters ([`crate::obs`]) label commands with.
    pub fn mnemonic(&self) -> &'static str {
        match self {
            CmdKind::PimcoreCmp { .. } => "PIMcore_CMP",
            CmdKind::GbcoreCmp { .. } => "GBcore_CMP",
            CmdKind::Bk2Lbuf { .. } => "PIM_BK2LBUF",
            CmdKind::Lbuf2Bk { .. } => "PIM_LBUF2BK",
            CmdKind::Bk2Gbuf { .. } => "PIM_BK2GBUF",
            CmdKind::Gbuf2Bk { .. } => "PIM_GBUF2BK",
            CmdKind::HostWrite { .. } => "HOST_WRITE",
            CmdKind::HostRead { .. } => "HOST_READ",
        }
    }
}

/// Upper bound on feature maps one command reads (`ADD_RELU`'s operand
/// pair is the widest consumer in the IR).
pub const MAX_DEPS: usize = 2;

/// A fixed-size set of feature-map ids a command depends on (heap-free,
/// like [`PerCore`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Deps {
    ids: [NodeId; MAX_DEPS],
    n: u8,
}

impl Deps {
    /// No dependencies (what [`Trace::push`] records).
    pub const EMPTY: Deps = Deps { ids: [0; MAX_DEPS], n: 0 };

    /// The dependency set of the given feature-map ids (≤ [`MAX_DEPS`]).
    pub fn from_slice(ids: &[NodeId]) -> Self {
        assert!(ids.len() <= MAX_DEPS, "command reads more than {MAX_DEPS} feature maps");
        let mut d = Deps::EMPTY;
        for &id in ids {
            d.ids[d.n as usize] = id;
            d.n += 1;
        }
        d
    }

    /// Number of feature maps in the set.
    pub fn len(&self) -> usize {
        self.n as usize
    }

    /// Whether the set is empty (no cross-node ordering constraints).
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// The feature-map ids, in insertion order.
    pub fn iter(&self) -> impl Iterator<Item = NodeId> + '_ {
        self.ids[..self.n as usize].iter().copied()
    }
}

/// The per-bank row-address range one command streams, in the trace's
/// row address space (the generator gives every feature map a distinct
/// row region, so spans only compare equal when the data is the same).
/// A command walks its banks from `first` to `last`; the open-row
/// tracker (DESIGN.md §6.2) waives a re-open when a read's `first` row
/// is the row its banks left open, and records `last` as the row left
/// open afterwards.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RowSpan {
    /// First per-bank row the stream touches.
    pub first: u64,
    /// Last per-bank row the stream touches (`≥ first`).
    pub last: u64,
}

/// A command tagged with the graph node it serves and its data-flow
/// annotations (see the module docs).
#[derive(Debug, Clone, PartialEq)]
pub struct Cmd {
    /// The graph node this command serves (per-layer auditing).
    pub node: NodeId,
    /// The macro command and its analytic volumes.
    pub kind: CmdKind,
    /// Feature maps whose current layout this command consumes.
    pub reads: Deps,
    /// Feature map whose data or layout this command (re)defines.
    pub writes: Option<NodeId>,
    /// Row identity of the stream ([`RowSpan`]), when the generator
    /// knows it (single-map transfers). `None` disables open-row reuse
    /// for this command and conservatively closes the banks it touches.
    pub row_span: Option<RowSpan>,
}

/// A full workload trace.
#[derive(Debug, Clone, Default)]
pub struct Trace {
    /// The command stream, in controller issue order.
    pub cmds: Vec<Cmd>,
}

/// Aggregate transfer statistics of a trace — the quantities Fig. 1
/// contrasts (cross-bank bytes vs local reuse).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct TraceStats {
    /// Commands in the trace.
    pub num_cmds: usize,
    /// Bytes moved over the shared bus through the GBUF, bank→GBUF.
    pub cross_bank_read: u64,
    /// Bytes moved GBUF→bank.
    pub cross_bank_write: u64,
    /// Bytes broadcast from GBUF to PIMcores during compute.
    pub broadcast: u64,
    /// Near-bank first-touch bytes read by PIMcores from local banks.
    pub near_bank_read: u64,
    /// Near-bank row-buffer-hit feed bytes (operand restreaming).
    pub near_bank_hit: u64,
    /// Near-bank bytes written.
    pub near_bank_write: u64,
    /// Parallel bank→LBUF fill bytes (sum over cores).
    pub lbuf_fill: u64,
    /// Parallel LBUF→bank spill bytes (sum over cores).
    pub lbuf_spill: u64,
    /// Host interface bytes.
    pub host_bytes: u64,
    /// Total MACs (for energy).
    pub total_macs: u64,
    /// Total PIMcore element-wise ops (BN/ReLU/pool/add).
    pub total_eltwise: u64,
    /// Element-wise ops executed on the channel-level GBcore.
    pub gbcore_eltwise: u64,
}

impl TraceStats {
    /// Total cross-bank transfer volume (the paper's headline quantity).
    pub fn cross_bank_total(&self) -> u64 {
        self.cross_bank_read + self.cross_bank_write
    }
}

impl Trace {
    /// Append a command with no dependency annotations (tests, synthetic
    /// traces). The generator uses [`Trace::push_dep`].
    pub fn push(&mut self, node: NodeId, kind: CmdKind) {
        self.push_dep(node, kind, &[], None);
    }

    /// Append a command with explicit data-flow annotations: the feature
    /// maps it `reads` and the one it `writes` (if any).
    pub fn push_dep(
        &mut self,
        node: NodeId,
        kind: CmdKind,
        reads: &[NodeId],
        writes: Option<NodeId>,
    ) {
        self.push_dep_rows(node, kind, reads, writes, None);
    }

    /// Append a command with data-flow annotations *and* row identity:
    /// what [`Trace::push_dep`] records, plus the [`RowSpan`] the stream
    /// covers (the generator sets this on single-map transfers so the
    /// open-row tracker can recognise reuse).
    pub fn push_dep_rows(
        &mut self,
        node: NodeId,
        kind: CmdKind,
        reads: &[NodeId],
        writes: Option<NodeId>,
        row_span: Option<RowSpan>,
    ) {
        self.cmds.push(Cmd { node, kind, reads: Deps::from_slice(reads), writes, row_span });
    }

    /// Largest node id any command references (its own node, its `reads`,
    /// or its `writes`); `0` for an empty trace. The event engine's
    /// dependency builder sizes its dense per-feature-map tables with
    /// this instead of hashing node ids.
    pub fn max_node(&self) -> NodeId {
        let mut m = 0;
        for c in &self.cmds {
            m = m.max(c.node);
            for r in c.reads.iter() {
                m = m.max(r);
            }
            if let Some(w) = c.writes {
                m = m.max(w);
            }
        }
        m
    }

    /// Aggregate the trace's transfer volumes by path ([`TraceStats`]).
    pub fn stats(&self) -> TraceStats {
        let mut s = TraceStats { num_cmds: self.cmds.len(), ..Default::default() };
        for c in &self.cmds {
            match &c.kind {
                CmdKind::PimcoreCmp {
                    macs, eltwise, bank_read, bank_read_hit, bank_write, gbuf_stream, ..
                } => {
                    s.total_macs += macs.sum();
                    s.total_eltwise += eltwise.sum();
                    s.near_bank_read += bank_read.sum();
                    s.near_bank_hit += bank_read_hit.sum();
                    s.near_bank_write += bank_write.sum();
                    s.broadcast += gbuf_stream;
                }
                CmdKind::GbcoreCmp { eltwise, .. } => s.gbcore_eltwise += eltwise,
                CmdKind::Bk2Lbuf { bytes } => s.lbuf_fill += bytes.sum(),
                CmdKind::Lbuf2Bk { bytes } => s.lbuf_spill += bytes.sum(),
                CmdKind::Bk2Gbuf { bytes, .. } => s.cross_bank_read += bytes,
                CmdKind::Gbuf2Bk { bytes, .. } => s.cross_bank_write += bytes,
                CmdKind::HostWrite { bytes, .. } | CmdKind::HostRead { bytes, .. } => {
                    s.host_bytes += bytes
                }
            }
        }
        s
    }

    /// Pretty one-line-per-command dump (for `pimfused trace`).
    pub fn dump(&self, limit: usize) -> String {
        let mut out = String::new();
        for (i, c) in self.cmds.iter().take(limit).enumerate() {
            let desc = match &c.kind {
                CmdKind::PimcoreCmp { flags, macs, bank_read, bank_read_hit, gbuf_stream, .. } => {
                    format!(
                        "PIMcore_CMP  {:?} macs(max)={} bank_rd(max)={}B hit(max)={}B bcast={}B",
                        flags,
                        macs.max(),
                        bank_read.max(),
                        bank_read_hit.max(),
                        gbuf_stream
                    )
                }
                CmdKind::GbcoreCmp { flags, eltwise } => {
                    format!("GBcore_CMP   {flags:?} eltwise={eltwise}")
                }
                CmdKind::Bk2Lbuf { bytes } => {
                    format!("PIM_BK2LBUF  {}B/core (parallel)", bytes.max())
                }
                CmdKind::Lbuf2Bk { bytes } => {
                    format!("PIM_LBUF2BK  {}B/core (parallel)", bytes.max())
                }
                CmdKind::Bk2Gbuf { bytes, rows } if rows.is_empty() => {
                    format!("PIM_BK2GBUF  {bytes}B (sequential)")
                }
                CmdKind::Bk2Gbuf { bytes, rows } => {
                    format!(
                        "PIM_BK2GBUF  {bytes}B (sequential) <- {} banks / {} rows",
                        rows.bank_count(),
                        rows.total()
                    )
                }
                CmdKind::Gbuf2Bk { bytes, rows } if rows.is_empty() => {
                    format!("PIM_GBUF2BK  {bytes}B (sequential)")
                }
                CmdKind::Gbuf2Bk { bytes, rows } => {
                    format!(
                        "PIM_GBUF2BK  {bytes}B (sequential) -> {} banks / {} rows",
                        rows.bank_count(),
                        rows.total()
                    )
                }
                CmdKind::HostWrite { bytes, rows } => {
                    format!(
                        "HOST_WRITE   {bytes}B -> {} banks / {} rows",
                        rows.bank_count(),
                        rows.total()
                    )
                }
                CmdKind::HostRead { bytes, rows } => {
                    format!(
                        "HOST_READ    {bytes}B <- {} banks / {} rows",
                        rows.bank_count(),
                        rows.total()
                    )
                }
            };
            out += &format!("{i:>5}  node {:>3}  {desc}\n", c.node);
        }
        if self.cmds.len() > limit {
            out += &format!("  ... {} more commands\n", self.cmds.len() - limit);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percore_ops() {
        let u = PerCore::uniform(4, 10);
        assert_eq!(u.sum(), 40);
        assert_eq!(u.max(), 10);
        let mut v = PerCore::from_slice(&[1, 5, 3]);
        assert_eq!(v.max(), 5);
        v.set(0, 9);
        assert_eq!(v.get(0), 9);
        assert_eq!(v.len(), 3);
    }

    #[test]
    #[should_panic]
    fn percore_bounds_checked() {
        let p = PerCore::zero(2);
        p.get(2);
    }

    #[test]
    fn stats_accumulate_by_kind() {
        let mut t = Trace::default();
        t.push(1, CmdKind::Bk2Gbuf { bytes: 100, rows: RowMap::EMPTY });
        t.push(1, CmdKind::Gbuf2Bk { bytes: 50, rows: RowMap::EMPTY });
        t.push(2, CmdKind::PimcoreCmp {
            flags: ExecFlags::ConvBnRelu,
            macs: PerCore::uniform(4, 1000),
            eltwise: PerCore::uniform(4, 10),
            bank_read: PerCore::uniform(4, 64),
            bank_read_hit: PerCore::uniform(4, 16),
            bank_write: PerCore::uniform(4, 32),
            gbuf_stream: 256,
        });
        let s = t.stats();
        assert_eq!(s.cross_bank_total(), 150);
        assert_eq!(s.total_macs, 4000);
        assert_eq!(s.near_bank_read, 256);
        assert_eq!(s.near_bank_hit, 64);
        assert_eq!(s.near_bank_write, 128);
        assert_eq!(s.broadcast, 256);
        assert_eq!(s.num_cmds, 3);
    }

    #[test]
    fn deps_annotations_roundtrip() {
        let mut t = Trace::default();
        t.push(3, CmdKind::Bk2Gbuf { bytes: 8, rows: RowMap::EMPTY });
        assert!(t.cmds[0].reads.is_empty());
        assert_eq!(t.cmds[0].writes, None);
        t.push_dep(4, CmdKind::Gbuf2Bk { bytes: 8, rows: RowMap::EMPTY }, &[1, 2], Some(4));
        let c = &t.cmds[1];
        assert_eq!(c.reads.iter().collect::<Vec<_>>(), vec![1, 2]);
        assert_eq!(c.reads.len(), 2);
        assert_eq!(c.writes, Some(4));
    }

    #[test]
    #[should_panic(expected = "more than")]
    fn deps_bounded() {
        Deps::from_slice(&[1, 2, 3]);
    }

    #[test]
    fn max_node_covers_reads_and_writes() {
        assert_eq!(Trace::default().max_node(), 0);
        let mut t = Trace::default();
        t.push_dep(3, CmdKind::Bk2Gbuf { bytes: 8, rows: RowMap::EMPTY }, &[7], None);
        assert_eq!(t.max_node(), 7);
        t.push_dep(2, CmdKind::Gbuf2Bk { bytes: 8, rows: RowMap::EMPTY }, &[], Some(9));
        assert_eq!(t.max_node(), 9);
    }

    #[test]
    fn mnemonics_are_the_table_i_names() {
        let cases: Vec<(CmdKind, &str)> = vec![
            (CmdKind::Bk2Lbuf { bytes: PerCore::zero(1) }, "PIM_BK2LBUF"),
            (CmdKind::Lbuf2Bk { bytes: PerCore::zero(1) }, "PIM_LBUF2BK"),
            (CmdKind::Bk2Gbuf { bytes: 1, rows: RowMap::EMPTY }, "PIM_BK2GBUF"),
            (CmdKind::Gbuf2Bk { bytes: 1, rows: RowMap::EMPTY }, "PIM_GBUF2BK"),
            (CmdKind::HostWrite { bytes: 1, rows: RowMap::EMPTY }, "HOST_WRITE"),
            (CmdKind::HostRead { bytes: 1, rows: RowMap::EMPTY }, "HOST_READ"),
            (CmdKind::GbcoreCmp { flags: ExecFlags::Pool, eltwise: 1 }, "GBcore_CMP"),
        ];
        for (kind, want) in &cases {
            assert_eq!(kind.mnemonic(), *want);
            // The dump uses the same names, so the exporters and the
            // `trace` subcommand cannot drift apart.
            let mut t = Trace::default();
            t.push(0, kind.clone());
            assert!(t.dump(1).contains(want), "{want} missing from dump");
        }
    }

    #[test]
    fn dump_is_line_per_cmd() {
        let mut t = Trace::default();
        t.push(0, CmdKind::HostWrite { bytes: 42, rows: RowMap::uniform(16, 1) });
        t.push(1, CmdKind::Bk2Gbuf { bytes: 7, rows: RowMap::EMPTY });
        let d = t.dump(10);
        assert_eq!(d.lines().count(), 2);
        assert!(d.contains("PIM_BK2GBUF"));
        assert!(d.contains("-> 16 banks / 16 rows"), "host dump names its row map: {d}");
    }

    #[test]
    fn bank_mask_set_operations() {
        assert!(BankMask::EMPTY.is_empty());
        assert_eq!(BankMask::all(0), BankMask::EMPTY);
        let all = BankMask::all(16);
        assert_eq!(all.count(), 16);
        assert_eq!(all.iter().count(), 16);
        let four = BankMask::all(4);
        assert_eq!(four.iter().collect::<Vec<_>>(), vec![0, 1, 2, 3]);
        assert!(four.contains(3) && !four.contains(4));
        assert!(!all.contains(16), "out-of-range queries are just absent");
    }

    #[test]
    #[should_panic]
    fn bank_mask_bounds_checked() {
        BankMask::all(17);
    }

    #[test]
    fn bank_mask_from_fn_selects_exactly() {
        let evens = BankMask::from_fn(8, |b| b % 2 == 0);
        assert_eq!(evens.iter().collect::<Vec<_>>(), vec![0, 2, 4, 6]);
        assert_eq!(BankMask::from_fn(16, |_| true), BankMask::all(16));
        assert_eq!(BankMask::from_fn(16, |_| false), BankMask::EMPTY);
    }

    #[test]
    fn striped_over_matches_striped_on_full_masks_and_skips_holes() {
        use crate::config::ROW_BYTES;
        let row = ROW_BYTES as u64;
        for bytes in [0u64, 3, 16 * 10 * row, 16 * 10 * row + 1] {
            assert_eq!(
                RowMap::striped_over(bytes, BankMask::all(16)),
                RowMap::striped(bytes, 16),
                "{bytes} bytes"
            );
        }
        // A 12-bank survivor set (banks 4..16): bank 0..4 stay empty and
        // the shares split 12 ways.
        let mask = BankMask::from_fn(16, |b| b >= 4);
        let m = RowMap::striped_over(12 * 10 * row, mask);
        assert_eq!(m.get(0), 0);
        assert!(m.iter().all(|(b, r)| b >= 4 && r == 10), "{m:?}");
        assert_eq!(m.bank_count(), 12);
    }

    #[test]
    fn row_map_accessors() {
        assert!(RowMap::EMPTY.is_empty());
        assert_eq!(RowMap::EMPTY.total(), 0);
        let m = RowMap::from_rows(&[3, 0, 5]);
        assert_eq!(m.get(0), 3);
        assert_eq!(m.get(1), 0);
        assert_eq!(m.get(2), 5);
        assert_eq!(m.get(99), 0, "out-of-range banks hold nothing");
        assert_eq!(m.total(), 8);
        assert_eq!(m.bank_count(), 2);
        assert_eq!(m.iter().collect::<Vec<_>>(), vec![(0, 3), (2, 5)]);
        // The mask view lists exactly the non-empty banks.
        assert!(m.banks().contains(0) && !m.banks().contains(1) && m.banks().contains(2));
        assert_eq!(m.banks().count(), 2);
        let u = RowMap::uniform(4, 2);
        assert_eq!(u.total(), 8);
        assert_eq!(u.banks().iter().collect::<Vec<_>>(), vec![0, 1, 2, 3]);
    }

    #[test]
    fn row_map_striped_splits_bytes_then_rounds_rows() {
        use crate::config::ROW_BYTES;
        let row = ROW_BYTES as u64;
        // 16 banks × exactly 10 rows each.
        let even = RowMap::striped(16 * 10 * row, 16);
        assert!(even.iter().all(|(_, r)| r == 10));
        assert_eq!(even.total(), 160);
        // A remainder byte lands in bank 0 and costs it one extra row.
        let skew = RowMap::striped(16 * 10 * row + 1, 16);
        assert_eq!(skew.get(0), 11);
        assert_eq!(skew.get(1), 10);
        // Fewer bytes than banks: the lowest banks carry one row each.
        let tiny = RowMap::striped(3, 16);
        assert_eq!(tiny.bank_count(), 3);
        assert_eq!(tiny.total(), 3);
        assert_eq!(RowMap::striped(0, 16), RowMap::EMPTY);
    }

    #[test]
    #[should_panic]
    fn row_map_bounds_checked() {
        let mut m = RowMap::EMPTY;
        m.set(MAX_CORES, 1);
    }
}
