//! Multi-channel graph partitioning (DESIGN.md §12): turn one CNN graph
//! into per-channel command traces plus the cross-channel exchange
//! boundaries the shared host interconnect meters
//! ([`crate::sim::channel`]).
//!
//! Two partition strategies ([`crate::config::PartitionKind`]):
//!
//! * **Data-parallel** shards the *batch*: each channel runs the whole
//!   network on its share of the requests. A single inference therefore
//!   occupies exactly one channel (channel 0 gets the full trace, the
//!   rest idle) and needs no exchanges — the extra channels pay off as
//!   serving throughput ([`crate::serve`] splits batches across them),
//!   not as single-shot latency.
//! * **Model-parallel** shards every layer's *output channels* (Cout):
//!   channel `i` computes `c/W + (i < c mod W)` of each layer's output
//!   channels from the **full** input feature map, so at every plan-step
//!   boundary the sharded outputs must all-gather over the host
//!   interconnect before the next step's full-Cin compute can see them.
//!
//! Sharded graphs keep `cached_cin` / `cached_in_elems` at their *full*
//! values: model-parallel compute is full-Cin × Cout-shard, which is
//! exactly what [`crate::cnn::Node::macs`] / `weight_bytes` derive from
//! the cached producer width. The effective width is capped at the
//! narrowest layer so no shard is ever empty (a zero-channel feature map
//! would fail [`crate::cnn::Graph::validate`]); channels beyond the cap
//! idle, and channels retired by
//! [`crate::fault::FaultConfig::dead_channels`] (the highest-indexed
//! ones) are excluded before the cap applies.

use crate::cnn::{Graph, NodeId, Op};
use crate::config::{ArchConfig, PartitionKind};
use crate::dataflow::{plan, CostModel, Plan, PlanStep};
use crate::trace::gen::generate;
use crate::trace::Trace;

/// One cross-channel exchange contribution: at a plan-step boundary,
/// one channel's shard of the step's output feature map crosses the
/// host interconnect.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ExchangePoint {
    /// Index of the last command of the producing step in this channel's
    /// trace — the exchange becomes *ready* when the channel's analytic
    /// prefix through this command completes.
    pub cmd: usize,
    /// The step's last graph node (what the exchange gathers).
    pub node: NodeId,
    /// Shard bytes this channel contributes to the gather.
    pub bytes: u64,
}

/// The partitioned form of one workload on one multi-channel config:
/// per-channel command traces plus the exchange boundaries between them.
///
/// Built once per `(workload, config)` by [`build_channels`] and memoized
/// by the session ([`crate::coordinator::Session`]); consumed by the
/// multi-channel driver ([`crate::sim::channel::run_channels`]).
#[derive(Debug, Clone)]
pub struct ChannelSet {
    /// Configured channel count (including idle and retired channels).
    pub channels: usize,
    /// Channels that actually execute work (`traces.len()`): 1 for
    /// data-parallel single-shot runs, `min(surviving channels,
    /// narrowest layer width)` for model-parallel.
    pub width: usize,
    /// Channels retired by the fault config (highest-indexed first).
    pub dead_channels: usize,
    /// The partition strategy that produced this set.
    pub partition: PartitionKind,
    /// One command trace per active channel.
    pub traces: Vec<Trace>,
    /// Per active channel, one [`ExchangePoint`] per plan-step boundary
    /// (every step except the last; empty for data-parallel). All
    /// channels have the same boundary count, in the same step order.
    pub exchanges: Vec<Vec<ExchangePoint>>,
}

impl ChannelSet {
    /// Boundary count (exchanges per channel).
    pub fn num_boundaries(&self) -> usize {
        self.exchanges.first().map(|v| v.len()).unwrap_or(0)
    }

    /// Total bytes that cross the interconnect across all boundaries and
    /// channels.
    pub fn total_exchange_bytes(&self) -> u64 {
        self.exchanges.iter().flatten().map(|x| x.bytes).sum()
    }
}

/// `c` output channels sharded `width` ways: shard `ch` gets
/// `c/width + (ch < c mod width)`.
fn shard_c(c: usize, ch: usize, width: usize) -> usize {
    c / width + usize::from(ch < c % width)
}

/// Clone `g` with every non-input feature map (and Conv/Fc `cout`)
/// narrowed to channel `ch`'s Cout shard. Producer caches stay full
/// (see the module docs).
fn shard_graph(g: &Graph, ch: usize, width: usize) -> Graph {
    let mut sg = g.clone();
    for n in sg.nodes.iter_mut().skip(1) {
        let sc = shard_c(n.shape.c, ch, width);
        n.shape.c = sc;
        match &mut n.op {
            Op::Conv { cout, .. } => *cout = sc,
            Op::Fc { cout } => *cout = sc,
            _ => {}
        }
    }
    sg.name = format!("{}_ch{}of{}", g.name, ch, width);
    sg
}

/// The last node a plan step produces (what crosses a boundary).
fn step_last_node(s: &PlanStep) -> NodeId {
    match *s {
        PlanStep::Fused { end, .. } => end,
        PlanStep::Lbl { node } => node,
    }
}

/// Per plan step, the index of its last command in `trace`. Commands are
/// generated in step order, so each step's commands are contiguous; a
/// step that generated no commands inherits the previous step's boundary
/// (its readiness is unchanged). Input-node commands (the host staging
/// the network input) belong to the first step.
fn step_boundaries(trace: &Trace, p: &Plan) -> Vec<usize> {
    let mut node_step = vec![0usize; 1 + p.steps.iter().map(step_last_node).max().unwrap_or(0)];
    for (si, s) in p.steps.iter().enumerate() {
        match *s {
            PlanStep::Fused { start, end, .. } => {
                for n in start..=end {
                    node_step[n] = si;
                }
            }
            PlanStep::Lbl { node } => node_step[node] = si,
        }
    }
    let mut last = vec![usize::MAX; p.steps.len()];
    for (i, c) in trace.cmds.iter().enumerate() {
        let si = node_step.get(c.node).copied().unwrap_or(0);
        last[si] = i;
    }
    // Carry forward over command-less steps.
    let mut prev = 0usize;
    for l in last.iter_mut() {
        if *l == usize::MAX {
            *l = prev;
        } else {
            prev = *l;
        }
    }
    last
}

/// Partition `g` across `cfg.channels` and build the per-channel traces
/// and exchange boundaries. `cfg.channels` may be 1 (one full trace, no
/// exchanges) — the single-channel pipeline does not call this, but the
/// property suite uses it to cross-check.
pub fn build_channels(g: &Graph, cfg: &ArchConfig, model: CostModel) -> Result<ChannelSet, String> {
    let dead = cfg.faults.dead_channels;
    let alive = cfg
        .channels
        .checked_sub(dead)
        .filter(|&a| a > 0)
        .ok_or_else(|| format!("dead_channels {dead} retires all {} channels", cfg.channels))?;
    match cfg.partition {
        _ if alive == 1 => build_single(g, cfg, model),
        PartitionKind::Data => build_single(g, cfg, model),
        PartitionKind::Model => build_model(g, cfg, model, alive),
    }
    .map(|mut set| {
        set.channels = cfg.channels;
        set.dead_channels = dead;
        set.partition = cfg.partition;
        set
    })
}

/// Data-parallel (or one surviving channel): channel 0 runs the whole
/// network, every other channel idles, nothing crosses the interconnect.
fn build_single(g: &Graph, cfg: &ArchConfig, model: CostModel) -> Result<ChannelSet, String> {
    let p = plan(g, cfg);
    p.validate(g)?;
    let trace = generate(g, cfg, &p, model);
    Ok(ChannelSet {
        channels: cfg.channels,
        width: 1,
        dead_channels: 0,
        partition: cfg.partition,
        traces: vec![trace],
        exchanges: vec![Vec::new()],
    })
}

/// Model-parallel: Cout shards across the surviving channels, one
/// all-gather boundary after every plan step but the last.
fn build_model(
    g: &Graph,
    cfg: &ArchConfig,
    model: CostModel,
    alive: usize,
) -> Result<ChannelSet, String> {
    let min_c = g.layers().map(|n| n.shape.c).min().unwrap_or(1).max(1);
    let width = alive.min(min_c);
    let mut traces = Vec::with_capacity(width);
    let mut exchanges = Vec::with_capacity(width);
    for ch in 0..width {
        let sg = shard_graph(g, ch, width);
        sg.validate()?;
        let p = plan(&sg, cfg);
        p.validate(&sg)?;
        let trace = generate(&sg, cfg, &p, model);
        let last = step_boundaries(&trace, &p);
        // One exchange per step boundary — every step except the final
        // one must all-gather its sharded output before the next step's
        // full-Cin compute.
        let mut xs = Vec::with_capacity(p.steps.len().saturating_sub(1));
        for (si, s) in p.steps.iter().enumerate().take(p.steps.len().saturating_sub(1)) {
            let node = step_last_node(s);
            xs.push(ExchangePoint {
                cmd: last[si],
                node,
                bytes: sg.nodes[node].shape.bytes() as u64,
            });
        }
        traces.push(trace);
        exchanges.push(xs);
    }
    // The scheduler pairs boundary b of every channel into one gather, so
    // the shard plans must agree on the step structure. Shard Cout deltas
    // are at most one output channel, which never flips a fusion decision
    // today — fail loudly rather than mis-pair if that ever changes.
    for xs in exchanges.iter().skip(1) {
        if xs.len() != exchanges[0].len()
            || xs.iter().zip(&exchanges[0]).any(|(a, b)| a.node != b.node)
        {
            return Err(format!(
                "model partition produced misaligned step boundaries across channel shards of {}",
                g.name
            ));
        }
    }
    Ok(ChannelSet {
        channels: cfg.channels,
        width,
        dead_channels: 0,
        partition: cfg.partition,
        traces,
        exchanges,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::System;
    use crate::workload::Workload;

    fn cfg(channels: usize, p: PartitionKind) -> ArchConfig {
        ArchConfig::system(System::Fused4, 32 * 1024, 256)
            .with_channels(channels)
            .with_partition(p)
    }

    #[test]
    fn shard_widths_sum_to_full() {
        for c in [3usize, 10, 64, 512] {
            for w in 1..=4 {
                let total: usize = (0..w).map(|ch| shard_c(c, ch, w)).sum();
                assert_eq!(total, c, "c={c} w={w}");
                // Balanced within one.
                let max = (0..w).map(|ch| shard_c(c, ch, w)).max().unwrap();
                let min = (0..w).map(|ch| shard_c(c, ch, w)).min().unwrap();
                assert!(max - min <= 1);
            }
        }
    }

    #[test]
    fn sharded_graphs_conserve_macs_and_output_bytes() {
        let g = Workload::ResNet18First8.graph();
        let w = 4;
        let shards: Vec<Graph> = (0..w).map(|ch| shard_graph(&g, ch, w)).collect();
        for sg in &shards {
            sg.validate().unwrap();
        }
        for id in 1..g.nodes.len() {
            let full = &g.nodes[id];
            let macs: usize = shards.iter().map(|sg| sg.nodes[id].macs()).sum();
            assert_eq!(macs, full.macs(), "node {id} MAC shards must sum to the full layer");
            let bytes: usize = shards.iter().map(|sg| sg.nodes[id].shape.bytes()).sum();
            assert_eq!(bytes, full.shape.bytes(), "node {id} output shards must tile the map");
        }
    }

    #[test]
    fn data_partition_is_channel_zero_plus_idlers() {
        let g = Workload::Fig1.graph();
        let set = build_channels(&g, &cfg(4, PartitionKind::Data), CostModel::default()).unwrap();
        assert_eq!(set.channels, 4);
        assert_eq!(set.width, 1);
        assert_eq!(set.num_boundaries(), 0);
        assert_eq!(set.total_exchange_bytes(), 0);
        // Channel 0's trace is the unpartitioned single-channel trace.
        let c1 = build_channels(&g, &cfg(1, PartitionKind::Data), CostModel::default()).unwrap();
        assert_eq!(set.traces[0].cmds, c1.traces[0].cmds);
    }

    #[test]
    fn model_partition_exchanges_cover_every_boundary() {
        let g = Workload::Fig1.graph();
        let c = cfg(2, PartitionKind::Model);
        let set = build_channels(&g, &c, CostModel::default()).unwrap();
        assert_eq!(set.width, 2);
        let p = plan(&g, &c);
        assert_eq!(set.num_boundaries(), p.steps.len() - 1);
        for xs in &set.exchanges {
            assert_eq!(xs.len(), set.num_boundaries(), "same boundary count per channel");
            let mut prev = 0;
            for x in xs {
                assert!(x.bytes > 0, "every shard moves bytes");
                assert!(x.cmd >= prev, "boundaries advance through the trace");
                prev = x.cmd;
            }
        }
        // The gathered bytes at each boundary tile the full feature map.
        for b in 0..set.num_boundaries() {
            let node = set.exchanges[0][b].node;
            let total: u64 = set.exchanges.iter().map(|xs| xs[b].bytes).sum();
            assert_eq!(total, g.nodes[node].shape.bytes() as u64);
        }
    }

    #[test]
    fn width_caps_at_the_narrowest_layer() {
        // Fig1 is a single shallow layer stack; its narrowest layer width
        // bounds how many channels can hold a non-empty Cout shard.
        let g = Workload::Fig1.graph();
        let min_c = g.layers().map(|n| n.shape.c).min().unwrap();
        let set =
            build_channels(&g, &cfg(16, PartitionKind::Model), CostModel::default()).unwrap();
        assert_eq!(set.width, 16.min(min_c));
        for t in &set.traces {
            assert!(!t.cmds.is_empty(), "active channels execute work");
        }
    }

    #[test]
    fn dead_channels_shrink_the_active_width() {
        let g = Workload::Fig1.graph();
        let mut c = cfg(4, PartitionKind::Model);
        c.faults.dead_channels = 2;
        let set = build_channels(&g, &c, CostModel::default()).unwrap();
        assert_eq!(set.dead_channels, 2);
        assert_eq!(set.width, 2, "retired channels take no work");
        // The survivors' shards still tile the full map.
        let b0_node = set.exchanges[0][0].node;
        let total: u64 = set.exchanges.iter().map(|xs| xs[0].bytes).sum();
        assert_eq!(total, g.nodes[b0_node].shape.bytes() as u64);
    }
}
