//! Minimal benchmarking harness (offline substitute for `criterion`).
//!
//! Benches in `rust/benches/` use `harness = false` and call
//! [`bench`] / [`section`]: warmup, N timed iterations, and a
//! median/mean/min report. Paper-reproduction benches mostly print
//! *figures* (tables of normalized PPA), for which wall-clock is
//! secondary; [`bench`] is used for the §Perf hot-path measurements.

use std::time::{Duration, Instant};

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Benchmark label, as passed to [`bench`].
    pub name: String,
    /// Timed iterations the statistics were computed over.
    pub iters: usize,
    /// Arithmetic mean of the timed iterations.
    pub mean: Duration,
    /// Median of the timed iterations (the stable number to track).
    pub median: Duration,
    /// Fastest timed iteration.
    pub min: Duration,
}

impl BenchResult {
    /// One-line `name iters=N min=… median=… mean=…` report (what
    /// [`bench`] prints).
    pub fn report(&self) -> String {
        format!(
            "{:<44} iters={:<4} min={:>10.3?} median={:>10.3?} mean={:>10.3?}",
            self.name, self.iters, self.min, self.median, self.mean
        )
    }
}

/// Time `f` over `iters` iterations (after `warmup` unmeasured runs).
pub fn bench<T>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> BenchResult {
    assert!(iters > 0);
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples: Vec<Duration> = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed());
    }
    samples.sort_unstable();
    let min = samples[0];
    let median = samples[iters / 2];
    let mean = samples.iter().sum::<Duration>() / iters as u32;
    let r = BenchResult { name: name.to_string(), iters, mean, median, min };
    println!("{}", r.report());
    r
}

/// Print a section banner (to structure bench output like the paper's
/// figure captions).
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_stats() {
        let r = bench("noop", 1, 5, || 1 + 1);
        assert_eq!(r.iters, 5);
        assert!(r.min <= r.median && r.median <= r.mean * 5);
        assert!(r.report().contains("noop"));
    }
}
