//! Workload scenarios evaluated by the paper (§V-A2).
//!
//! Every variant's canonical name and CLI aliases live in one `TABLE`;
//! [`Workload::ALL`], [`Workload::name`] and [`Workload::parse`] are all
//! driven from it, so adding a workload is a one-row change (plus its
//! graph builder) and the accessors cannot drift apart.

use crate::cnn::resnet::{fig1_example, fig3_example, resnet18, resnet18_at, resnet18_first8};
use crate::cnn::Graph;

/// Benchmark workloads.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Workload {
    /// End-to-end ResNet18 at 224×224 (`ResNet18_Full`).
    ResNet18Full,
    /// First 8 layers only (`ResNet18_First8Layers`): quantifies the pure
    /// fused-vs-layer-by-layer contrast.
    ResNet18First8,
    /// The Fig. 3(a) walkthrough graph.
    Fig3,
    /// The Fig. 1 two-conv motivating example.
    Fig1,
    /// Reduced-resolution ResNet18 for fast tests / the e2e example.
    ResNet18Small,
}

/// One row per variant: (variant, canonical name, CLI aliases). The
/// canonical name (case-insensitively) always parses too.
const TABLE: &[(Workload, &str, &[&str])] = &[
    (Workload::ResNet18Full, "ResNet18_Full", &["full", "resnet18"]),
    (Workload::ResNet18First8, "ResNet18_First8Layers", &["first8", "resnet18_first8"]),
    (Workload::Fig3, "Fig3_Example", &["fig3"]),
    (Workload::Fig1, "Fig1_Example", &["fig1"]),
    (Workload::ResNet18Small, "ResNet18_64px", &["small", "resnet18_small"]),
];

impl Workload {
    /// Every workload, in `TABLE` order (checked by a test).
    pub const ALL: [Workload; 5] = [
        Workload::ResNet18Full,
        Workload::ResNet18First8,
        Workload::Fig3,
        Workload::Fig1,
        Workload::ResNet18Small,
    ];

    /// The two workloads the paper's figures evaluate.
    pub const PAPER: [Workload; 2] = [Workload::ResNet18First8, Workload::ResNet18Full];

    /// Build the workload's validated CNN graph.
    pub fn graph(&self) -> Graph {
        match self {
            Workload::ResNet18Full => resnet18(),
            Workload::ResNet18First8 => resnet18_first8(),
            Workload::Fig3 => fig3_example(),
            Workload::Fig1 => fig1_example(),
            Workload::ResNet18Small => resnet18_at(64),
        }
    }

    fn row(&self) -> &'static (Workload, &'static str, &'static [&'static str]) {
        TABLE
            .iter()
            .find(|row| row.0 == *self)
            .expect("every Workload variant must have a TABLE row")
    }

    /// Canonical name, e.g. `ResNet18_First8Layers`.
    pub fn name(&self) -> &'static str {
        self.row().1
    }

    /// CLI aliases (the first one is the short form shown in usage text).
    pub fn aliases(&self) -> &'static [&'static str] {
        self.row().2
    }

    /// Parse a CLI spelling: any alias or the canonical name,
    /// case-insensitively.
    pub fn parse(s: &str) -> Result<Self, String> {
        let t = s.trim().to_ascii_lowercase();
        for &(w, name, aliases) in TABLE {
            if t == name.to_ascii_lowercase() || aliases.contains(&t.as_str()) {
                return Ok(w);
            }
        }
        let short: Vec<&str> = TABLE.iter().map(|row| row.2[0]).collect();
        Err(format!("unknown workload {s:?} ({})", short.join("|")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_workloads_build_valid_graphs() {
        for w in Workload::ALL {
            let g = w.graph();
            g.validate().unwrap();
            assert!(g.num_layers() >= 2, "{} too small", w.name());
        }
    }

    #[test]
    fn table_covers_all_in_order() {
        assert_eq!(TABLE.len(), Workload::ALL.len());
        for (row, w) in TABLE.iter().zip(Workload::ALL) {
            assert_eq!(row.0, w, "TABLE and ALL must list variants in the same order");
            assert!(!row.2.is_empty(), "{} needs at least one alias", row.1);
        }
    }

    #[test]
    fn names_and_aliases_roundtrip_through_parse() {
        for w in Workload::ALL {
            assert_eq!(Workload::parse(w.name()).unwrap(), w, "canonical {}", w.name());
            assert_eq!(Workload::parse(&w.name().to_ascii_uppercase()).unwrap(), w);
            for a in w.aliases() {
                assert_eq!(Workload::parse(a).unwrap(), w, "alias {a}");
            }
        }
    }

    #[test]
    fn aliases_are_unique_across_workloads() {
        let mut seen: Vec<String> = Vec::new();
        for &(_, name, aliases) in TABLE {
            for s in aliases.iter().map(|a| a.to_string()).chain([name.to_ascii_lowercase()]) {
                assert!(!seen.contains(&s), "duplicate spelling {s:?}");
                seen.push(s);
            }
        }
    }

    #[test]
    fn parse_roundtrip() {
        assert_eq!(Workload::parse("full").unwrap(), Workload::ResNet18Full);
        assert_eq!(Workload::parse("First8").unwrap(), Workload::ResNet18First8);
        assert!(Workload::parse("nope").is_err());
        assert!(Workload::parse("nope").unwrap_err().contains("full|first8"));
    }
}
