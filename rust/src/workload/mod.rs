//! Workload scenarios evaluated by the paper (§V-A2).

use crate::cnn::resnet::{fig1_example, fig3_example, resnet18, resnet18_at, resnet18_first8};
use crate::cnn::Graph;

/// Benchmark workloads.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Workload {
    /// End-to-end ResNet18 at 224×224 (`ResNet18_Full`).
    ResNet18Full,
    /// First 8 layers only (`ResNet18_First8Layers`): quantifies the pure
    /// fused-vs-layer-by-layer contrast.
    ResNet18First8,
    /// The Fig. 3(a) walkthrough graph.
    Fig3,
    /// The Fig. 1 two-conv motivating example.
    Fig1,
    /// Reduced-resolution ResNet18 for fast tests / the e2e example.
    ResNet18Small,
}

impl Workload {
    pub const PAPER: [Workload; 2] = [Workload::ResNet18First8, Workload::ResNet18Full];

    pub fn graph(&self) -> Graph {
        match self {
            Workload::ResNet18Full => resnet18(),
            Workload::ResNet18First8 => resnet18_first8(),
            Workload::Fig3 => fig3_example(),
            Workload::Fig1 => fig1_example(),
            Workload::ResNet18Small => resnet18_at(64),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Workload::ResNet18Full => "ResNet18_Full",
            Workload::ResNet18First8 => "ResNet18_First8Layers",
            Workload::Fig3 => "Fig3_Example",
            Workload::Fig1 => "Fig1_Example",
            Workload::ResNet18Small => "ResNet18_64px",
        }
    }

    pub fn parse(s: &str) -> Result<Self, String> {
        match s.to_ascii_lowercase().as_str() {
            "full" | "resnet18" | "resnet18_full" => Ok(Workload::ResNet18Full),
            "first8" | "resnet18_first8" | "resnet18_first8layers" => Ok(Workload::ResNet18First8),
            "fig3" => Ok(Workload::Fig3),
            "fig1" => Ok(Workload::Fig1),
            "small" | "resnet18_small" => Ok(Workload::ResNet18Small),
            _ => Err(format!("unknown workload {s:?} (full|first8|fig1|fig3|small)")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_workloads_build_valid_graphs() {
        for w in [
            Workload::ResNet18Full,
            Workload::ResNet18First8,
            Workload::Fig3,
            Workload::Fig1,
            Workload::ResNet18Small,
        ] {
            let g = w.graph();
            g.validate().unwrap();
            assert!(g.num_layers() >= 2, "{} too small", w.name());
        }
    }

    #[test]
    fn parse_roundtrip() {
        assert_eq!(Workload::parse("full").unwrap(), Workload::ResNet18Full);
        assert_eq!(Workload::parse("First8").unwrap(), Workload::ResNet18First8);
        assert!(Workload::parse("nope").is_err());
    }
}
