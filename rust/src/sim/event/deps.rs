//! Dependency tracking: turn a trace's per-node data-flow annotations
//! into a command-level DAG.
//!
//! The rules (DESIGN.md §6.2): commands serving the **same node** execute
//! in trace order relative to each other (gather → fill → compute →
//! scatter is a controller-sequenced program per layer). Across nodes, a
//! command waits on the **last writer** of each feature map it reads
//! (RAW), and a command that (re)defines a feature map additionally waits
//! on that map's previous writer (WAW) and on every reader issued since
//! (WAR) — a fused reorganization must not rewrite a map's bank placement
//! while an earlier command is still streaming the old layout. Host I/O
//! takes part like any other command: `HOST_WRITE` registers as the input
//! map's writer (everything consuming the input waits for the host
//! stream — and, with host bank residency modeled, for its bank slices to
//! drain), and `HOST_READ` reads the output map, so it waits on the final
//! layer's scatter. Everything else is free to overlap, subject to
//! resource availability.
//!
//! [`build`] returns a [`Dag`]: the per-command predecessor lists plus
//! the successor/indegree view the ready-heap scheduler consumes. The
//! builder keeps all per-feature-map state in dense `Vec`s indexed by
//! node id (sized by [`crate::trace::Trace::max_node`]) and deduplicates
//! predecessor edges with an O(1) per-command stamp instead of a linear
//! `contains` scan.

use crate::trace::Trace;

/// "No command" sentinel for the dense per-map tables.
const NONE: usize = usize::MAX;

/// Indices of the commands one command must wait for (deduplicated,
/// unbounded: a map rewrite waits on arbitrarily many open readers).
#[derive(Debug, Clone, Default)]
pub(crate) struct Preds {
    idx: Vec<usize>,
}

impl Preds {
    pub(crate) fn iter(&self) -> impl Iterator<Item = usize> + '_ {
        self.idx.iter().copied()
    }

    #[cfg(test)]
    pub(crate) fn len(&self) -> usize {
        self.idx.len()
    }

    #[cfg(test)]
    fn sorted(&self) -> Vec<usize> {
        let mut v = self.idx.clone();
        v.sort_unstable();
        v
    }
}

/// The command DAG: predecessor lists plus the derived successor lists
/// and indegrees (what the scheduler's ready heap is seeded from). Edges
/// always point from a lower to a higher trace index, so the graph is
/// acyclic by construction.
#[derive(Debug, Clone, Default)]
pub(crate) struct Dag {
    pub(crate) preds: Vec<Preds>,
    /// Flattened (CSR) successor lists: the successors of command `i`
    /// are `succs[succ_off[i]..succ_off[i + 1]]`.
    succs: Vec<u32>,
    succ_off: Vec<u32>,
    indeg: Vec<u32>,
}

impl Dag {
    pub(crate) fn len(&self) -> usize {
        self.preds.len()
    }

    /// Commands that wait on command `i`.
    pub(crate) fn succs(&self, i: usize) -> &[u32] {
        &self.succs[self.succ_off[i] as usize..self.succ_off[i + 1] as usize]
    }

    /// Number of predecessors per command (0 ⇒ ready at cycle 0).
    pub(crate) fn indegree(&self) -> &[u32] {
        &self.indeg
    }
}

/// Build the command DAG for a trace.
pub(crate) fn build(trace: &Trace) -> Dag {
    let n = trace.cmds.len();
    debug_assert!(n <= u32::MAX as usize, "trace too large for u32 CSR indices");
    let maps = trace.max_node() + 1;
    let mut last_writer = vec![NONE; maps];
    let mut last_same_node = vec![NONE; maps];
    // Readers of each map since its last write — what a rewrite must
    // drain before it may change the layout.
    let mut open_readers: Vec<Vec<usize>> = vec![Vec::new(); maps];
    // `seen[j] == i` marks j as already recorded as a predecessor of i.
    let mut seen = vec![NONE; n];
    let mut preds = Vec::with_capacity(n);
    let mut indeg = vec![0u32; n];
    for (i, cmd) in trace.cmds.iter().enumerate() {
        let mut p = Preds::default();
        let mut add = |p: &mut Preds, j: usize| {
            if seen[j] != i {
                seen[j] = i;
                p.idx.push(j);
            }
        };
        if last_same_node[cmd.node] != NONE {
            add(&mut p, last_same_node[cmd.node]);
        }
        for r in cmd.reads.iter() {
            // Feature maps with no recorded writer (e.g. static weights
            // or un-annotated test traces) impose no ordering.
            if last_writer[r] != NONE {
                add(&mut p, last_writer[r]);
            }
        }
        if let Some(w) = cmd.writes {
            if last_writer[w] != NONE {
                add(&mut p, last_writer[w]); // WAW
            }
            for &j in &open_readers[w] {
                add(&mut p, j); // WAR
            }
        }
        indeg[i] = p.idx.len() as u32;
        preds.push(p);
        last_same_node[cmd.node] = i;
        for r in cmd.reads.iter() {
            open_readers[r].push(i);
        }
        if let Some(w) = cmd.writes {
            last_writer[w] = i;
            open_readers[w].clear();
        }
    }

    // Successor CSR from the predecessor lists (counting sort by source).
    let mut succ_off = vec![0u32; n + 1];
    for p in &preds {
        for j in p.iter() {
            succ_off[j + 1] += 1;
        }
    }
    for k in 1..=n {
        succ_off[k] += succ_off[k - 1];
    }
    let mut cursor: Vec<u32> = succ_off[..n].to_vec();
    let mut succs = vec![0u32; succ_off[n] as usize];
    for (i, p) in preds.iter().enumerate() {
        for j in p.iter() {
            succs[cursor[j] as usize] = i as u32;
            cursor[j] += 1;
        }
    }
    Dag { preds, succs, succ_off, indeg }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{CmdKind, RowMap, Trace};

    #[test]
    fn same_node_commands_chain() {
        let mut t = Trace::default();
        t.push(1, CmdKind::Bk2Gbuf { bytes: 64, rows: RowMap::EMPTY });
        t.push(1, CmdKind::Gbuf2Bk { bytes: 64, rows: RowMap::EMPTY });
        let d = build(&t);
        assert_eq!(d.preds[0].len(), 0);
        assert_eq!(d.preds[1].sorted(), vec![0]);
        assert_eq!(d.succs(0), [1]);
        assert!(d.succs(1).is_empty());
        assert_eq!(d.indegree(), [0, 1]);
    }

    #[test]
    fn readers_wait_on_last_writer_only() {
        let mut t = Trace::default();
        t.push_dep(1, CmdKind::Bk2Gbuf { bytes: 64, rows: RowMap::EMPTY }, &[], Some(1));
        t.push_dep(2, CmdKind::Bk2Gbuf { bytes: 64, rows: RowMap::EMPTY }, &[], Some(2));
        // Node 3 reads 1 only: independent of command 1 (node 2's write).
        t.push_dep(3, CmdKind::Bk2Gbuf { bytes: 64, rows: RowMap::EMPTY }, &[1], None);
        // Node 4 reads both.
        t.push_dep(4, CmdKind::Bk2Gbuf { bytes: 64, rows: RowMap::EMPTY }, &[1, 2], None);
        let d = build(&t);
        assert_eq!(d.preds[2].sorted(), vec![0]);
        assert_eq!(d.preds[3].sorted(), vec![0, 1]);
        // Successor view mirrors the predecessor edges.
        assert_eq!(d.succs(0), [2, 3]);
        assert_eq!(d.succs(1), [3]);
        assert_eq!(d.indegree(), [0, 0, 1, 2]);
    }

    #[test]
    fn rewriting_a_map_retargets_readers() {
        let mut t = Trace::default();
        t.push_dep(1, CmdKind::Bk2Gbuf { bytes: 64, rows: RowMap::EMPTY }, &[], Some(1));
        // A fused reorganization rewrites node 1's layout...
        t.push_dep(5, CmdKind::Gbuf2Bk { bytes: 64, rows: RowMap::EMPTY }, &[], Some(1));
        // ...so a later reader of 1 waits for the reorganization.
        t.push_dep(6, CmdKind::Bk2Gbuf { bytes: 64, rows: RowMap::EMPTY }, &[1], None);
        let d = build(&t);
        assert_eq!(d.preds[2].sorted(), vec![1]);
    }

    #[test]
    fn rewriters_wait_for_open_readers_and_prior_writer() {
        let mut t = Trace::default();
        t.push_dep(1, CmdKind::Bk2Gbuf { bytes: 64, rows: RowMap::EMPTY }, &[], Some(1)); // writes map 1
        t.push_dep(2, CmdKind::Bk2Gbuf { bytes: 64, rows: RowMap::EMPTY }, &[1], None); // reader A
        t.push_dep(3, CmdKind::Bk2Gbuf { bytes: 64, rows: RowMap::EMPTY }, &[1], None); // reader B
        // A reorganization rewriting map 1 must drain both in-flight
        // readers (WAR) and order after the original write (WAW).
        t.push_dep(7, CmdKind::Gbuf2Bk { bytes: 64, rows: RowMap::EMPTY }, &[], Some(1));
        let d = build(&t);
        assert_eq!(d.preds[3].sorted(), vec![0, 1, 2]);
        // A write retires the open-reader set: a second rewrite waits on
        // the first rewrite only, not the long-retired readers.
        let mut t2 = t.clone();
        t2.push_dep(8, CmdKind::Gbuf2Bk { bytes: 64, rows: RowMap::EMPTY }, &[], Some(1));
        let d2 = build(&t2);
        assert_eq!(d2.preds[4].sorted(), vec![3]);
    }

    #[test]
    fn host_io_bounds_the_dag() {
        // HOST_WRITE defines the input map: the first consumer waits on
        // it. HOST_READ consumes the output map: it waits on the final
        // writer, but not on unrelated commands.
        let rows = RowMap::striped(1024, 16);
        let mut t = Trace::default();
        t.push_dep(0, CmdKind::HostWrite { bytes: 1024, rows }, &[], Some(0));
        t.push_dep(1, CmdKind::Bk2Gbuf { bytes: 1024, rows: RowMap::EMPTY }, &[0], None);
        t.push_dep(2, CmdKind::Gbuf2Bk { bytes: 512, rows: RowMap::EMPTY }, &[], Some(2));
        t.push_dep(2, CmdKind::HostRead { bytes: 512, rows }, &[2], None);
        let d = build(&t);
        assert_eq!(d.preds[1].sorted(), vec![0], "consumer waits on the host write");
        assert_eq!(d.preds[3].sorted(), vec![2], "host read waits on the output's writer");
        assert_eq!(d.indegree(), [0, 1, 0, 1]);
    }

    #[test]
    fn unannotated_traces_only_chain_per_node() {
        let mut t = Trace::default();
        t.push(1, CmdKind::Bk2Gbuf { bytes: 64, rows: RowMap::EMPTY });
        t.push(2, CmdKind::Bk2Gbuf { bytes: 64, rows: RowMap::EMPTY });
        let d = build(&t);
        assert_eq!(d.preds[1].len(), 0, "different nodes, no annotations: independent");
        assert_eq!(d.indegree(), [0, 0]);
    }

    #[test]
    fn duplicate_edges_are_stamped_out() {
        // Same-node chaining and RAW both point at command 0: the stamp
        // dedup must record the edge once (so indegree stays consistent
        // with the successor count).
        let mut t = Trace::default();
        t.push_dep(1, CmdKind::Bk2Gbuf { bytes: 64, rows: RowMap::EMPTY }, &[], Some(1));
        t.push_dep(1, CmdKind::Gbuf2Bk { bytes: 64, rows: RowMap::EMPTY }, &[1], Some(1));
        let d = build(&t);
        assert_eq!(d.preds[1].sorted(), vec![0]);
        assert_eq!(d.succs(0), [1]);
        assert_eq!(d.indegree()[1], 1);
    }

    #[test]
    fn empty_trace_builds_empty_dag() {
        let d = build(&Trace::default());
        assert_eq!(d.len(), 0);
        assert!(d.indegree().is_empty());
    }
}
