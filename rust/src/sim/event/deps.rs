//! Dependency tracking: turn a trace's per-node data-flow annotations
//! into a command-level DAG.
//!
//! The rules (DESIGN.md §6.2): commands serving the **same node** execute
//! in trace order relative to each other (gather → fill → compute →
//! scatter is a controller-sequenced program per layer). Across nodes, a
//! command waits on the **last writer** of each feature map it reads
//! (RAW), and a command that (re)defines a feature map additionally waits
//! on that map's previous writer (WAW) and on every reader issued since
//! (WAR) — a fused reorganization must not rewrite a map's bank placement
//! while an earlier command is still streaming the old layout. Everything
//! else is free to overlap, subject to resource availability.

use crate::cnn::NodeId;
use crate::trace::Trace;
use std::collections::HashMap;

/// Indices of the commands one command must wait for (deduplicated,
/// unbounded: a map rewrite waits on arbitrarily many open readers).
#[derive(Debug, Clone, Default)]
pub(crate) struct Preds {
    idx: Vec<usize>,
}

impl Preds {
    pub(crate) fn add(&mut self, i: usize) {
        if !self.idx.contains(&i) {
            self.idx.push(i);
        }
    }

    pub(crate) fn iter(&self) -> impl Iterator<Item = usize> + '_ {
        self.idx.iter().copied()
    }

    #[cfg(test)]
    pub(crate) fn len(&self) -> usize {
        self.idx.len()
    }

    #[cfg(test)]
    fn sorted(&self) -> Vec<usize> {
        let mut v = self.idx.clone();
        v.sort_unstable();
        v
    }
}

/// Build the predecessor list for every command in the trace.
pub(crate) fn build(trace: &Trace) -> Vec<Preds> {
    let mut last_writer: HashMap<NodeId, usize> = HashMap::new();
    // Readers of each map since its last write — what a rewrite must
    // drain before it may change the layout.
    let mut open_readers: HashMap<NodeId, Vec<usize>> = HashMap::new();
    let mut last_same_node: HashMap<NodeId, usize> = HashMap::new();
    let mut preds = Vec::with_capacity(trace.cmds.len());
    for (i, cmd) in trace.cmds.iter().enumerate() {
        let mut p = Preds::default();
        if let Some(&j) = last_same_node.get(&cmd.node) {
            p.add(j);
        }
        for r in cmd.reads.iter() {
            // Feature maps with no recorded writer (e.g. static weights
            // or un-annotated test traces) impose no ordering.
            if let Some(&j) = last_writer.get(&r) {
                p.add(j);
            }
        }
        if let Some(w) = cmd.writes {
            if let Some(&j) = last_writer.get(&w) {
                p.add(j); // WAW
            }
            for &j in open_readers.get(&w).into_iter().flatten() {
                p.add(j); // WAR
            }
        }
        preds.push(p);
        last_same_node.insert(cmd.node, i);
        for r in cmd.reads.iter() {
            open_readers.entry(r).or_default().push(i);
        }
        if let Some(w) = cmd.writes {
            last_writer.insert(w, i);
            open_readers.entry(w).or_default().clear();
        }
    }
    preds
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{CmdKind, Trace};

    #[test]
    fn same_node_commands_chain() {
        let mut t = Trace::default();
        t.push(1, CmdKind::Bk2Gbuf { bytes: 64 });
        t.push(1, CmdKind::Gbuf2Bk { bytes: 64 });
        let p = build(&t);
        assert_eq!(p[0].len(), 0);
        assert_eq!(p[1].sorted(), vec![0]);
    }

    #[test]
    fn readers_wait_on_last_writer_only() {
        let mut t = Trace::default();
        t.push_dep(1, CmdKind::Bk2Gbuf { bytes: 64 }, &[], Some(1));
        t.push_dep(2, CmdKind::Bk2Gbuf { bytes: 64 }, &[], Some(2));
        // Node 3 reads 1 only: independent of command 1 (node 2's write).
        t.push_dep(3, CmdKind::Bk2Gbuf { bytes: 64 }, &[1], None);
        // Node 4 reads both.
        t.push_dep(4, CmdKind::Bk2Gbuf { bytes: 64 }, &[1, 2], None);
        let p = build(&t);
        assert_eq!(p[2].sorted(), vec![0]);
        assert_eq!(p[3].sorted(), vec![0, 1]);
    }

    #[test]
    fn rewriting_a_map_retargets_readers() {
        let mut t = Trace::default();
        t.push_dep(1, CmdKind::Bk2Gbuf { bytes: 64 }, &[], Some(1));
        // A fused reorganization rewrites node 1's layout...
        t.push_dep(5, CmdKind::Gbuf2Bk { bytes: 64 }, &[], Some(1));
        // ...so a later reader of 1 waits for the reorganization.
        t.push_dep(6, CmdKind::Bk2Gbuf { bytes: 64 }, &[1], None);
        let p = build(&t);
        assert_eq!(p[2].sorted(), vec![1]);
    }

    #[test]
    fn rewriters_wait_for_open_readers_and_prior_writer() {
        let mut t = Trace::default();
        t.push_dep(1, CmdKind::Bk2Gbuf { bytes: 64 }, &[], Some(1)); // writes map 1
        t.push_dep(2, CmdKind::Bk2Gbuf { bytes: 64 }, &[1], None); // reader A
        t.push_dep(3, CmdKind::Bk2Gbuf { bytes: 64 }, &[1], None); // reader B
        // A reorganization rewriting map 1 must drain both in-flight
        // readers (WAR) and order after the original write (WAW).
        t.push_dep(7, CmdKind::Gbuf2Bk { bytes: 64 }, &[], Some(1));
        let p = build(&t);
        assert_eq!(p[3].sorted(), vec![0, 1, 2]);
        // A write retires the open-reader set: a second rewrite waits on
        // the first rewrite only, not the long-retired readers.
        let mut t2 = t.clone();
        t2.push_dep(8, CmdKind::Gbuf2Bk { bytes: 64 }, &[], Some(1));
        let p2 = build(&t2);
        assert_eq!(p2[4].sorted(), vec![3]);
    }

    #[test]
    fn unannotated_traces_only_chain_per_node() {
        let mut t = Trace::default();
        t.push(1, CmdKind::Bk2Gbuf { bytes: 64 });
        t.push(2, CmdKind::Bk2Gbuf { bytes: 64 });
        let p = build(&t);
        assert_eq!(p[1].len(), 0, "different nodes, no annotations: independent");
    }

    #[test]
    fn preds_deduplicate() {
        let mut p = Preds::default();
        p.add(3);
        p.add(3);
        p.add(7);
        assert_eq!(p.sorted(), vec![3, 7]);
    }
}
