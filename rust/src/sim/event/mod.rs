//! Discrete-event channel simulator — the overlap-aware alternative to
//! the analytic back-to-back engine (select with
//! [`Engine::Event`](crate::config::Engine)).
//!
//! The analytic engine charges every command serially, so it cannot model
//! host I/O hidden under compute, GBUF gathers overlapping an independent
//! branch's MACs, or bus contention over time — it is systematically
//! conservative about exactly the cross-bank savings PIMfused optimizes.
//! This engine instead runs a ready-queue list scheduler (scheduler v2,
//! DESIGN.md §6.2):
//!
//! 1. `deps` derives a command DAG from the trace's data-flow
//!    annotations: same-node commands chain; across nodes a command waits
//!    on the last writer of each feature map it reads (RAW), and a map
//!    rewrite additionally drains the map's prior writer and every open
//!    reader (WAW/WAR). The DAG exposes successor lists and indegrees.
//! 2. `resources` keeps an *interval timeline* (sorted gap list) per
//!    resource: every bank, every PIMcore, the shared internal bus /
//!    GBUF port, the GBcore, the host interface, the contended command
//!    bus, and a tFAW/tRRD activation window per bank group. Short
//!    commands back-fill idle windows earlier reservations left behind.
//!    Host I/O holds per-bank slices of its destination banks (true bank
//!    residency) sized by the trace's [`RowMap`] — the rows that
//!    actually land in each bank — and row activations spread over a
//!    command's data span as per-row interleaved ACT slots. With
//!    [`ArchConfig::slice_pipelining`](crate::config::ArchConfig::slice_pipelining)
//!    a transfer's per-bank slices may *slide* inside the bus interval
//!    to dodge busy banks — see the module docs there.
//! 3. Commands issue in *readiness order*: a binary min-heap of
//!    `(ready_cycle, trace_index)` pops the earliest-ready command, the
//!    timelines find the earliest start where its issue slot and every
//!    resource interval it needs fit, and completion updates the
//!    successors' ready cycles.
//!
//! Three invariants hold by construction (property-tested in
//! `tests/engine_agreement.rs`, see the proof sketch in DESIGN.md §6.2):
//!
//! * action counts — and therefore energy — are identical to the
//!   analytic engine's (same `engine::tally` path);
//! * total cycles never exceed the analytic serial sum (every
//!   reservation a command makes ends by its completion, so a popped
//!   command can always start by the latest completion so far — and a
//!   sliding slice placement degrades to the rigid stagger on idle
//!   banks, so the bound survives slice pipelining);
//! * total cycles never undercut the busiest single resource's occupancy
//!   (reservations on one timeline cannot overlap — [`audit`] certifies
//!   this together with dependency correctness).
//!
//! [`RowMap`]: crate::trace::RowMap

mod deps;
pub(crate) mod resources;

pub use resources::ResourceOccupancy;

use resources::NUM_ACT_GROUPS;

use super::engine::{self, charge, cost, duration, expand, tally, CmdCost};
use super::SimResult;
use crate::config::ArchConfig;
use crate::fault::FaultPlan;
use crate::trace::{CmdKind, Trace, MAX_CORES};
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Event-engine output: the [`SimResult`] (with `cycles` = schedule
/// makespan and every other field identical to the analytic engine's)
/// plus the per-resource occupancy breakdown.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EventReport {
    /// Cycles, action counts, and per-path breakdowns.
    pub result: SimResult,
    /// Per-resource busy-cycle breakdown of the schedule.
    pub occupancy: ResourceOccupancy,
}

/// Simulate a full trace with the event-driven scheduler.
pub fn simulate(cfg: &ArchConfig, trace: &Trace) -> EventReport {
    let dag = deps::build(trace);
    run_schedule(cfg, trace, &dag, false).0
}

/// Simulate in recording mode, returning the report together with the
/// per-command schedule (starts/completions in trace order) and the
/// committed reservation records — per command, one [`IssueRecord`] per
/// issue attempt (exactly one unless a transient fault plan forced
/// replays) — the raw material [`crate::obs::ScheduleTrace`] promotes
/// into a stable timeline.
///
/// [`IssueRecord`]: resources::IssueRecord
pub(crate) fn simulate_recorded(
    cfg: &ArchConfig,
    trace: &Trace,
) -> (EventReport, ScheduleAudit, Vec<Vec<resources::IssueRecord>>) {
    let dag = deps::build(trace);
    run_schedule(cfg, trace, &dag, true)
}

/// Per-command schedule record, in trace order: issue-slot start and
/// completion cycle (completion includes the `t_cmd` issue slot, the
/// data span, and any write-recovery window).
#[derive(Debug, Clone, Default)]
pub struct ScheduleAudit {
    /// Issue-slot start cycle per command, in trace order.
    pub starts: Vec<u64>,
    /// Completion cycle per command, in trace order.
    pub dones: Vec<u64>,
    /// Total busy cycles the scheduler back-filled into timeline gaps.
    pub backfilled: u64,
    /// Bank cycles certified as host-residency slices (zero when the
    /// config runs the interface-only host model).
    pub host_bank_cycles: u64,
    /// Reserved tFAW/tRRD window cycles certified across all bank groups.
    pub act_window_cycles: u64,
    /// Slice cycles certified at placements past their rigid stagger
    /// offsets — the slice-pipelining relaxation at work. Always zero
    /// when `ArchConfig::slice_pipelining` is off (the audit rejects a
    /// slid slice outright in that case).
    pub slid_cycles: u64,
    /// Cycles certified inside replay attempts (issue slot, data span,
    /// and recovery of every attempt after a command's first) — the
    /// independently re-derived counterpart of
    /// [`SimResult::replayed_cycles`]. Zero without a transient fault
    /// plan.
    pub replayed_cycles: u64,
    /// Row-open cycles certified as waived by open-row reuse: the audit
    /// replays the open-row state machine in trace order and admits
    /// exactly one `row_open_cycles()` waiver per command whose banks
    /// all left the resumed row open. Always zero when
    /// [`ArchConfig::open_row_reuse`](crate::config::ArchConfig::open_row_reuse)
    /// is off.
    pub waived_open_cycles: u64,
}

/// Re-run the schedule in recording mode and certify its legality:
///
/// * every command starts at or after every predecessor's completion,
///   and completions bound the reported makespan;
/// * no resource interval is double-booked — replayed independently of
///   the timelines' `reserve` asserts, by sorting every command's
///   recorded reservations per resource and scanning for overlap (this
///   covers the host-command bank slices in particular: two host phases,
///   or a host phase and a PIM stream, can never hold one bank at once);
/// * host commands reserve bank slices exactly on their annotated
///   destination banks, inside their own data window, each span equal to
///   that bank's share of the trace's row map and the per-group ACT
///   metering equal to the map's per-bank row counts — and reserve none
///   when the config disables host residency;
/// * sliding slices are legal: every cross-bank and host slice sits
///   at-or-after its rigid stagger offset (exactly on it when
///   `slice_pipelining` is off), still inside its command's window, and
///   the audit reports the certified slid cycles
///   ([`ScheduleAudit::slid_cycles`]);
/// * every row activation lands in a legal tFAW/tRRD slot: each ACT
///   reservation lies within its command's data window, and per bank
///   group the reserved window cycles cover the command's activations at
///   `act_slot_cycles()` per ACT (saturated groups are capped at the
///   data span — the bulk-window degradation `DramTiming::act_layout`
///   documents). Cross-command spacing follows from the no-overlap check;
/// * under a transient fault plan, every command records exactly one
///   attempt plus the replays the plan dictates for its trace index,
///   each replay starting at-or-after the prior attempt's completion and
///   passing every per-attempt check above in its *own* window; the
///   certified replay cycles are reported
///   ([`ScheduleAudit::replayed_cycles`]).
pub fn audit(cfg: &ArchConfig, trace: &Trace) -> Result<ScheduleAudit, String> {
    let dag = deps::build(trace);
    let (report, mut sched, records) = run_schedule(cfg, trace, &dag, true);
    let mut max_done = 0;
    for i in 0..dag.len() {
        for j in dag.preds[i].iter() {
            if sched.starts[i] < sched.dones[j] {
                return Err(format!(
                    "command {i} starts at {} before predecessor {j} completes at {}",
                    sched.starts[i], sched.dones[j]
                ));
            }
        }
        max_done = max_done.max(sched.dones[i]);
    }
    if max_done != report.result.cycles {
        return Err(format!(
            "makespan {} disagrees with the latest completion {max_done}",
            report.result.cycles
        ));
    }

    // Independent double-booking replay over every resource (replay
    // attempts included — a retry may not overlap anything either).
    let mut per_res: Vec<Vec<(u64, u64, usize)>> = vec![Vec::new(); resources::NUM_RES];
    for (i, recs) in records.iter().enumerate() {
        for rec in recs {
            for rv in &rec.resv {
                per_res[rv.res].push((rv.start, rv.end, i));
            }
        }
    }
    for (res, iv) in per_res.iter_mut().enumerate() {
        iv.sort_unstable();
        for w in iv.windows(2) {
            if w[1].0 < w[0].1 {
                return Err(format!(
                    "resource {res}: command {} holds [{}, {}) while command {} holds [{}, {})",
                    w[0].2, w[0].0, w[0].1, w[1].2, w[1].0, w[1].1
                ));
            }
        }
    }

    let plan = FaultPlan::build(cfg);
    let t_cmd = cfg.timing.t_cmd;
    let act_slot = cfg.timing.act_slot_cycles();
    // The audit replays the open-row state machine itself, in trace
    // order, so every waived re-open charge is certified independently
    // of the scheduler's bookkeeping.
    let mut replay = SimResult::default();
    for (i, recs) in records.iter().enumerate() {
        // Replay accounting: the scheduler must have issued exactly one
        // attempt plus the replays the fault plan dictates for this
        // trace index, framed by the schedule's reported start (first
        // attempt) and completion (last attempt), each replay waiting
        // for the failed attempt to finish.
        let rep = plan.replays_for(i);
        if recs.len() != 1 + rep.count as usize {
            return Err(format!(
                "command {i}: {} issue attempts recorded, the fault plan dictates {}",
                recs.len(),
                1 + rep.count
            ));
        }
        if recs[0].start != sched.starts[i] {
            return Err(format!(
                "command {i}: first attempt starts at {} but the schedule says {}",
                recs[0].start, sched.starts[i]
            ));
        }
        let last_done = recs.last().map(|r| r.done).unwrap_or(0);
        if last_done != sched.dones[i] {
            return Err(format!(
                "command {i}: last attempt completes at {last_done} but the schedule says {}",
                sched.dones[i]
            ));
        }
        // One expansion per command — replays reuse it, exactly as the
        // scheduler (and the analytic engine's replay path) did. The
        // difference against the un-waived base cost is the open-row
        // waiver, admissible only with the toggle on, only with a row
        // identity, and only at exactly one `row_open_cycles()`.
        let base = cost(cfg, &trace.cmds[i]);
        let exp = expand(cfg, &trace.cmds[i], &mut replay);
        let d_base = duration(cfg, &base);
        let d_exp = duration(cfg, &exp);
        if d_exp > d_base {
            return Err(format!(
                "command {i}: expansion grew the serial duration ({d_exp} > {d_base})"
            ));
        }
        let waived = d_base - d_exp;
        if waived != 0 {
            if !cfg.open_row_reuse {
                return Err(format!(
                    "command {i}: waived {waived} cycles with open-row reuse off"
                ));
            }
            if waived != cfg.timing.row_open_cycles() {
                return Err(format!(
                    "command {i}: waived {waived} cycles, a row resume waives exactly {}",
                    cfg.timing.row_open_cycles()
                ));
            }
            if trace.cmds[i].row_span.is_none() {
                return Err(format!("command {i}: open-row waiver without a row identity"));
            }
            sched.waived_open_cycles += waived;
        }

        let mut prev_done = 0u64;
        for (attempt, rec) in recs.iter().enumerate() {
            if attempt > 0 {
                if rec.start < prev_done {
                    return Err(format!(
                        "command {i}: replay {attempt} starts at {} before the failed attempt completes at {prev_done}",
                        rec.start
                    ));
                }
                sched.replayed_cycles += rec.done - rec.start;
            }
            prev_done = rec.done;

            let data_lo = rec.start + t_cmd;
            let data_hi = data_lo + rec.data_span;

            // The recorded data window must be the *expanded* cost's —
            // a waived re-open really shrank the reserved interval.
            if let CmdCost::CrossBank { total, .. } | CmdCost::Host { total, .. } = &exp {
                if rec.data_span != *total {
                    return Err(format!(
                        "command {i}: recorded data span {} disagrees with the expanded cost {total}",
                        rec.data_span
                    ));
                }
            }

            // Host bank residency: every slice sits on an annotated bank,
            // inside the attempt's window, with exactly the span its share
            // of the trace's row map dictates — and at or after its rigid
            // stagger offset (exactly on it when slice pipelining is off).
            if let CmdKind::HostWrite { rows, .. } | CmdKind::HostRead { rows, .. } =
                &trace.cmds[i].kind
            {
                let resident = matches!(&exp, CmdCost::Host { rows: r, .. } if !r.is_empty());
                // Expected per-bank (rigid offset, span), recomputed from
                // the row map independently of the scheduler's arithmetic.
                let mut want = [(0u64, 0u64); MAX_CORES];
                let in_channel: u64 =
                    rows.iter().filter(|&(b, _)| b < cfg.num_banks).map(|(_, r)| r).sum();
                if resident && in_channel > 0 {
                    let mut acc = 0u64;
                    for (b, r) in rows.iter() {
                        if b >= cfg.num_banks {
                            continue;
                        }
                        let lo = rec.data_span * acc / in_channel;
                        acc += r;
                        let hi = rec.data_span * acc / in_channel;
                        want[b] = (lo, hi - lo);
                    }
                }
                let mut seen = [0u64; MAX_CORES];
                for rv in &rec.resv {
                    let (s, e, span) = (rv.start, rv.end, rv.span);
                    if let Some(b) = resources::res_bank(rv.res) {
                        if !resident {
                            return Err(format!(
                                "host command {i} reserved bank {b} with residency off"
                            ));
                        }
                        if b >= cfg.num_banks || rows.get(b) == 0 {
                            return Err(format!(
                                "host command {i} reserved bank {b} outside its destination set"
                            ));
                        }
                        if s < data_lo || e > rec.done || s + span > data_hi {
                            return Err(format!(
                                "host command {i}: bank {b} slice [{s}, {e}) escapes its window [{data_lo}, {})",
                                rec.done
                            ));
                        }
                        if span != want[b].1 {
                            return Err(format!(
                                "host command {i}: bank {b} slice span {span} disagrees with its row share {}",
                                want[b].1
                            ));
                        }
                        if s < data_lo + want[b].0 {
                            return Err(format!(
                                "host command {i}: bank {b} slice at {s} precedes its stagger offset"
                            ));
                        }
                        if s != data_lo + want[b].0 {
                            if !cfg.slice_pipelining {
                                return Err(format!(
                                    "host command {i}: bank {b} slice slid with pipelining off"
                                ));
                            }
                            sched.slid_cycles += span;
                        }
                        // Recovery tails are reserved but not streamed.
                        seen[b] += span;
                    }
                }
                for b in 0..cfg.num_banks.min(MAX_CORES) {
                    if seen[b] != want[b].1 {
                        return Err(format!(
                            "host command {i}: bank {b} reserved {} slice cycles, the row map expects {}",
                            seen[b], want[b].1
                        ));
                    }
                }
                sched.host_bank_cycles += seen.iter().sum::<u64>();

                // The scheduler's per-group ACT metering must equal the
                // trace's per-bank row counts, group by group — the audit
                // certifies no `div_ceil` share survives on the host path.
                let mut want_acts = [0u64; NUM_ACT_GROUPS];
                if resident {
                    for (b, r) in rows.iter() {
                        if b < cfg.num_banks {
                            want_acts[b / resources::GROUP_BANKS] += r;
                        }
                    }
                }
                if rec.group_acts != want_acts {
                    return Err(format!(
                        "host command {i}: metered ACT counts {:?} disagree with the row map's {:?}",
                        rec.group_acts, want_acts
                    ));
                }
            }

            // Cross-bank slices: the uniform 1/N walk over the cost's
            // bank set (the whole channel when healthy, the fault plan's
            // survivors when degraded — rigid offsets follow the walk
            // position, so holes in the set do not open gaps), each slice
            // in-window and at-or-after its rigid offset (exactly on it
            // when slice pipelining is off).
            if matches!(trace.cmds[i].kind, CmdKind::Bk2Gbuf { .. } | CmdKind::Gbuf2Bk { .. }) {
                let mut want = [(0u64, 0u64); MAX_CORES];
                if let CmdCost::CrossBank { total, slice, banks, .. } = &exp {
                    let (total, slice) = (*total, *slice);
                    if slice > 0 {
                        for (k, b) in banks.iter().enumerate() {
                            if b >= cfg.num_banks || b >= MAX_CORES {
                                break;
                            }
                            let off = k as u64 * slice;
                            if off >= total {
                                break;
                            }
                            want[b] = (off, slice.min(total - off));
                        }
                    }
                }
                let mut seen = [0u64; MAX_CORES];
                for rv in &rec.resv {
                    let (s, e, span) = (rv.start, rv.end, rv.span);
                    if let Some(b) = resources::res_bank(rv.res) {
                        if b >= MAX_CORES || want[b].1 == 0 {
                            return Err(format!(
                                "cross-bank command {i} reserved bank {b} outside its walk"
                            ));
                        }
                        if s < data_lo || e > rec.done || s + span > data_hi {
                            return Err(format!(
                                "cross-bank command {i}: bank {b} slice [{s}, {e}) escapes its window"
                            ));
                        }
                        if span != want[b].1 || s < data_lo + want[b].0 {
                            return Err(format!(
                                "cross-bank command {i}: bank {b} slice [{s}, {e}) breaks the 1/N walk"
                            ));
                        }
                        if s != data_lo + want[b].0 {
                            if !cfg.slice_pipelining {
                                return Err(format!(
                                    "cross-bank command {i}: bank {b} slice slid with pipelining off"
                                ));
                            }
                            sched.slid_cycles += span;
                        }
                        seen[b] += span;
                    }
                }
                for b in 0..MAX_CORES {
                    if seen[b] != want[b].1 {
                        return Err(format!(
                            "cross-bank command {i}: bank {b} reserved {} slice cycles, expected {}",
                            seen[b], want[b].1
                        ));
                    }
                }

                // Per-group ACT metering: a row-mapped transfer charges
                // each group for the rows that actually land in its
                // banks; an un-annotated one falls back to the even
                // `div_ceil` split across the groups its walk touches.
                let mut want_acts = [0u64; NUM_ACT_GROUPS];
                if let CmdCost::CrossBank { acts, banks, rows, .. } = &exp {
                    if !rows.is_empty() {
                        for (b, r) in rows.iter() {
                            if b < cfg.num_banks {
                                want_acts[b / resources::GROUP_BANKS] += r;
                            }
                        }
                    } else {
                        let mut gset = [false; NUM_ACT_GROUPS];
                        let mut ng = 0u64;
                        for b in banks.iter() {
                            if b >= cfg.num_banks {
                                break;
                            }
                            let g = (b / resources::GROUP_BANKS).min(NUM_ACT_GROUPS - 1);
                            if !gset[g] {
                                gset[g] = true;
                                ng += 1;
                            }
                        }
                        if ng > 0 {
                            let per_group = acts.div_ceil(ng);
                            for (g, hit) in gset.iter().enumerate() {
                                if *hit {
                                    want_acts[g] = per_group;
                                }
                            }
                        }
                    }
                }
                if rec.group_acts != want_acts {
                    return Err(format!(
                        "cross-bank command {i}: metered ACT counts {:?} disagree with the expected {:?}",
                        rec.group_acts, want_acts
                    ));
                }
            }

            // ACT slots: in-window, and enough reserved cycles per group
            // to cover the command's activations at the legal rate.
            let mut reserved = [0u64; NUM_ACT_GROUPS];
            for rv in &rec.resv {
                let (s, e) = (rv.start, rv.end);
                if let Some(g) = resources::res_act_group(rv.res) {
                    if s < data_lo || e > data_hi {
                        return Err(format!(
                            "command {i}: ACT window [{s}, {e}) escapes the data phase [{data_lo}, {data_hi})"
                        ));
                    }
                    reserved[g] += e - s;
                }
            }
            for g in 0..NUM_ACT_GROUPS {
                let want = (rec.group_acts[g] * act_slot).min(rec.data_span);
                if reserved[g] < want {
                    return Err(format!(
                        "command {i}: group {g} reserved {} ACT-window cycles for {} activations (needs {want})",
                        reserved[g], rec.group_acts[g]
                    ));
                }
                sched.act_window_cycles += reserved[g];
            }
        }
    }
    if replay.open_row_hits != report.result.open_row_hits {
        return Err(format!(
            "open-row replay certifies {} hits, the engine reported {}",
            replay.open_row_hits, report.result.open_row_hits
        ));
    }
    Ok(sched)
}

/// The scheduler core shared by [`simulate`] and [`audit`] (which pass
/// in the DAG so it is built exactly once per call). With `record` set,
/// every issue attempt's committed reservation intervals are captured
/// (grouped per command, in trace order) for the audit's independent
/// replay.
fn run_schedule(
    cfg: &ArchConfig,
    trace: &Trace,
    dag: &deps::Dag,
    record: bool,
) -> (EventReport, ScheduleAudit, Vec<Vec<resources::IssueRecord>>) {
    let n = trace.cmds.len();
    let mut r = SimResult::default();
    // Transient-fault replays, resolved up front in trace order: the
    // per-command draw depends only on the plan's seed and the trace
    // index, so the heap's issue order cannot perturb which commands
    // replay (and serial vs. threaded sweeps stay byte-identical).
    let plan = (cfg.faults.transient_ppm > 0).then(|| FaultPlan::build(cfg));
    let mut replays = vec![0u32; n];
    // Expand costs and tallies in trace order, so action counts, the
    // per-path cycle breakdowns, and the open-row waivers (`expand`
    // resolves hits against the controller's issue order — the trace
    // order — in both engines) are engine-identical by construction
    // regardless of the issue order the heap picks below. Every replay
    // attempt tallies and charges again — exactly the analytic engine's
    // replay accounting, so the faulty results stay engine-equal too.
    let mut costs = Vec::with_capacity(n);
    for (i, cmd) in trace.cmds.iter().enumerate() {
        let c = expand(cfg, cmd, &mut r);
        let rep = plan.as_ref().map(|p| p.replays_for(i)).unwrap_or_default();
        replays[i] = rep.count;
        if rep.escalated {
            r.escalated_cmds += 1;
        }
        for attempt in 0..=rep.count {
            tally(cmd, &mut r.actions);
            // `charge` returns the serial duration, which we discard in
            // favor of the scheduled completion below — except on the
            // replay ledger, which both engines count serially.
            let d = charge(cfg, &c, &mut r);
            if attempt > 0 {
                r.replayed_cycles += d;
            }
        }
        costs.push(c);
    }

    let mut tl = if record {
        resources::Timelines::with_recording(cfg)
    } else {
        resources::Timelines::new(cfg)
    };
    let mut ready = vec![0u64; n];
    let mut indeg = dag.indegree().to_vec();
    // Ready heap: earliest-ready command first, trace index as the
    // deterministic tie-break.
    let mut heap: BinaryHeap<Reverse<(u64, usize)>> =
        (0..n).filter(|&i| indeg[i] == 0).map(|i| Reverse((0, i))).collect();
    let mut starts = vec![0u64; n];
    let mut dones = vec![0u64; n];
    let mut makespan = 0u64;
    let mut issued = 0usize;
    // The heap issues in readiness order, but the audit wants records in
    // trace order: remember which command each record belongs to.
    let mut issue_order = Vec::with_capacity(if record { n } else { 0 });
    while let Some(Reverse((at, i))) = heap.pop() {
        // First attempt at readiness; each replay re-reserves every
        // resource from scratch at the failed attempt's completion (the
        // error is only detected when the command finishes), so retries
        // queue behind whatever the channel is doing by then.
        let mut iss = tl.issue(at, &costs[i]);
        starts[i] = iss.start;
        if record {
            issue_order.push(i);
        }
        for _ in 0..replays[i] {
            iss = tl.issue(iss.done, &costs[i]);
            if record {
                issue_order.push(i);
            }
        }
        dones[i] = iss.done;
        makespan = makespan.max(iss.done);
        issued += 1;
        for &s in dag.succs(i) {
            let s = s as usize;
            ready[s] = ready[s].max(iss.done);
            indeg[s] -= 1;
            if indeg[s] == 0 {
                heap.push(Reverse((ready[s], s)));
            }
        }
    }
    debug_assert_eq!(issued, n, "the dependency DAG must drain completely");
    r.cycles = makespan;
    let mut flat = tl.take_records();
    // Group the issue-order records into per-command attempt lists in
    // trace order (one command's attempts issue consecutively, so their
    // order survives the grouping).
    let mut records: Vec<Vec<resources::IssueRecord>> = vec![Vec::new(); n];
    if record {
        for (k, rec) in flat.drain(..).enumerate() {
            records[issue_order[k]].push(rec);
        }
    }
    let occupancy = tl.into_occupancy(makespan);
    let backfilled = occupancy.backfilled;
    let sched = ScheduleAudit { starts, dones, backfilled, ..Default::default() };
    (EventReport { result: r, occupancy }, sched, records)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::resnet::resnet18_first8;
    use crate::config::System;
    use crate::dataflow::{plan, CostModel};
    use crate::sim::dram;
    use crate::trace::gen::generate;
    use crate::trace::{CmdKind, PerCore, RowMap};

    fn paper_trace(sys: System) -> (ArchConfig, Trace) {
        let g = resnet18_first8();
        let cfg = ArchConfig::system(sys, 8192, 128);
        let p = plan(&g, &cfg);
        let t = generate(&g, &cfg, &p, CostModel::default());
        (cfg, t)
    }

    fn serial_cycles(cfg: &ArchConfig, trace: &Trace) -> u64 {
        engine::simulate(cfg, trace).cycles
    }

    #[test]
    fn empty_trace_is_zero_cycles() {
        let cfg = ArchConfig::baseline();
        let r = simulate(&cfg, &Trace::default());
        assert_eq!(r.result.cycles, 0);
        assert_eq!(r.occupancy.makespan, 0);
    }

    #[test]
    fn chained_commands_match_analytic_exactly() {
        // A strictly-dependent chain has no overlap to find: the event
        // engine must degrade to the analytic serial total (including
        // the scatter's write-recovery window, charged by both engines).
        let cfg = ArchConfig::baseline();
        let mut t = Trace::default();
        t.push_dep(1, CmdKind::Bk2Gbuf { bytes: 4096, rows: RowMap::EMPTY }, &[], Some(1));
        t.push_dep(2, CmdKind::Bk2Gbuf { bytes: 2048, rows: RowMap::EMPTY }, &[1], Some(2));
        t.push_dep(3, CmdKind::Gbuf2Bk { bytes: 1024, rows: RowMap::EMPTY }, &[2], Some(3));
        let ev = simulate(&cfg, &t);
        assert_eq!(ev.result.cycles, serial_cycles(&cfg, &t));
    }

    #[test]
    fn independent_commands_on_disjoint_resources_overlap() {
        // A parallel LBUF fill (cores + banks) and GBcore compute (bus +
        // GBcore port) share nothing but the command bus: the event
        // engine runs their data phases concurrently, strictly beating
        // the analytic serial sum.
        let cfg = ArchConfig::baseline();
        let mut t = Trace::default();
        t.push_dep(1, CmdKind::Bk2Lbuf { bytes: PerCore::uniform(16, 64 * 1024) }, &[], None);
        let gb = CmdKind::GbcoreCmp { flags: crate::trace::ExecFlags::Pool, eltwise: 64 * 1024 };
        t.push_dep(2, gb, &[], None);
        let ev = simulate(&cfg, &t);
        let serial = serial_cycles(&cfg, &t);
        assert!(
            ev.result.cycles < serial,
            "event {} !< serial {}",
            ev.result.cycles,
            serial
        );
        // Both still bounded below by the busiest resource.
        assert!(ev.result.cycles >= ev.occupancy.busiest());
    }

    #[test]
    fn contended_resource_serializes() {
        // Two independent cross-bank transfers both need the bus: their
        // data phases cannot overlap. Only the second command's issue
        // slot (`t_cmd`) hides under the first transfer.
        let cfg = ArchConfig::baseline();
        let mut t = Trace::default();
        t.push_dep(1, CmdKind::Bk2Gbuf { bytes: 4096, rows: RowMap::EMPTY }, &[], None);
        t.push_dep(2, CmdKind::Bk2Gbuf { bytes: 4096, rows: RowMap::EMPTY }, &[], None);
        let ev = simulate(&cfg, &t);
        let serial = serial_cycles(&cfg, &t);
        assert_eq!(ev.result.cycles, ev.occupancy.bus_busy + cfg.timing.t_cmd);
        assert_eq!(serial - ev.result.cycles, cfg.timing.t_cmd);
    }

    #[test]
    fn rewrite_waits_for_inflight_reader() {
        // Anti-dependency: a reorganization rewriting map 1's layout may
        // not overlap the LBUF fill still streaming the old layout, even
        // though the two occupy mostly disjoint resources.
        let cfg = ArchConfig::baseline();
        let mut t = Trace::default();
        t.push_dep(1, CmdKind::Bk2Gbuf { bytes: 4096, rows: RowMap::EMPTY }, &[], Some(1));
        t.push_dep(2, CmdKind::Bk2Lbuf { bytes: PerCore::uniform(16, 64 * 1024) }, &[1], None);
        t.push_dep(5, CmdKind::Gbuf2Bk { bytes: 4096, rows: RowMap::EMPTY }, &[], Some(1));
        let ev = simulate(&cfg, &t);
        // RAW then WAR chain every command: no overlap is legal.
        assert_eq!(ev.result.cycles, serial_cycles(&cfg, &t));
    }

    #[test]
    fn read_after_write_pays_the_turnaround_window() {
        // Satellite (tWR): a read reservation on a bank timeline that
        // follows a write must start >= t_wr after the write's data
        // completes. Two *independent* commands (different nodes, no
        // annotations) hitting the same banks make the gap observable.
        let cfg = ArchConfig::baseline();
        let mut t = Trace::default();
        t.push(1, CmdKind::Lbuf2Bk { bytes: PerCore::uniform(16, 4096) }); // bank write
        t.push(2, CmdKind::Bk2Lbuf { bytes: PerCore::uniform(16, 4096) }); // bank read
        let d = dram::near_bank_stream_cycles(&cfg.timing, 4096);
        let t_cmd = cfg.timing.t_cmd;
        let a = audit(&cfg, &t).expect("legal schedule");
        // Write data occupies [t_cmd, t_cmd + d); the read's data phase
        // begins exactly t_wr after it.
        assert_eq!(a.starts[0], 0);
        assert_eq!(a.starts[1] + t_cmd, (a.starts[0] + t_cmd + d) + cfg.timing.t_wr);

        // Zeroing t_wr removes exactly that gap.
        let mut cfg0 = cfg.clone();
        cfg0.timing.t_wr = 0;
        let ev0 = simulate(&cfg0, &t);
        let ev = simulate(&cfg, &t);
        assert_eq!(ev.result.cycles - ev0.result.cycles, cfg.timing.t_wr);
    }

    #[test]
    fn issue_slots_backfill_the_command_bus() {
        // Two bus-contended transfers, then an independent host read: the
        // host command's issue slot lands in the command-bus gap behind
        // the second transfer's slot, and its data hides under the bus
        // traffic entirely.
        let cfg = ArchConfig::baseline();
        let mut t = Trace::default();
        t.push_dep(1, CmdKind::Bk2Gbuf { bytes: 64 * 1024, rows: RowMap::EMPTY }, &[], None);
        t.push_dep(2, CmdKind::Bk2Gbuf { bytes: 4096, rows: RowMap::EMPTY }, &[], None);
        // Interface-only host read (no bank annotation): its data hides
        // fully under the bus traffic without touching the banks.
        t.push_dep(3, CmdKind::HostRead { bytes: 4096, rows: RowMap::EMPTY }, &[], None);
        let ev = simulate(&cfg, &t);
        let a = audit(&cfg, &t).unwrap();
        assert!(a.backfilled > 0, "the host issue slot back-fills");
        assert_eq!(a.starts[2], cfg.timing.t_cmd, "host issues right behind cmd 1's slot");
        assert!(ev.result.cycles < serial_cycles(&cfg, &t));
    }

    #[test]
    fn ready_order_beats_trace_order() {
        // Command 3 is independent but sits behind a dependent chain in
        // trace order; the ready heap issues it first, so its bus work
        // hides under the chain instead of waiting for it.
        let cfg = ArchConfig::baseline();
        let mut t = Trace::default();
        t.push_dep(1, CmdKind::Bk2Lbuf { bytes: PerCore::uniform(16, 64 * 1024) }, &[], Some(1));
        t.push_dep(1, CmdKind::Lbuf2Bk { bytes: PerCore::uniform(16, 64 * 1024) }, &[], Some(1));
        t.push_dep(7, CmdKind::Bk2Gbuf { bytes: 4096, rows: RowMap::EMPTY }, &[], None);
        let a = audit(&cfg, &t).unwrap();
        assert!(
            a.starts[2] < a.starts[1],
            "independent command {} should issue before the chained one {}",
            a.starts[2],
            a.starts[1]
        );
    }

    #[test]
    fn audit_certifies_host_bank_slices_and_act_slots() {
        // A resident host write, a dependent near-bank fill, and a host
        // read back: the audit's independent replay must certify the
        // bank slices and ACT windows, and report their cycle totals.
        let cfg = ArchConfig::baseline();
        let rows = RowMap::striped(64 * 1024, 16);
        let mut t = Trace::default();
        t.push_dep(0, CmdKind::HostWrite { bytes: 64 * 1024, rows }, &[], Some(0));
        t.push_dep(1, CmdKind::Bk2Lbuf { bytes: PerCore::uniform(16, 4096) }, &[0], None);
        t.push_dep(1, CmdKind::HostRead { bytes: 4096, rows }, &[0], None);
        let a = audit(&cfg, &t).unwrap();
        assert!(a.host_bank_cycles > 0, "host slices certified on the banks");
        assert!(a.act_window_cycles > 0, "ACT slots certified in the windows");
        // Residency off: same trace, no bank slices, audit still legal.
        let off = cfg.clone().with_host_residency(false);
        let a_off = audit(&off, &t).unwrap();
        assert_eq!(a_off.host_bank_cycles, 0);
    }

    #[test]
    fn host_residency_makes_dependent_fill_wait_and_charges_banks() {
        // With residency on, the host write's completion (and its bank
        // slices) push the dependent near-bank fill later than the
        // interface-only model allows; bank occupancy grows by exactly
        // the certified host slices.
        let cfg = ArchConfig::baseline();
        let off = cfg.clone().with_host_residency(false);
        let rows = RowMap::striped(64 * 1024, 16);
        let mut t = Trace::default();
        t.push_dep(0, CmdKind::HostWrite { bytes: 64 * 1024, rows }, &[], Some(0));
        t.push_dep(1, CmdKind::Bk2Lbuf { bytes: PerCore::uniform(16, 4096) }, &[0], None);
        let on_ev = simulate(&cfg, &t);
        let off_ev = simulate(&off, &t);
        let on_banks: u64 = on_ev.occupancy.bank_busy.iter().sum();
        let off_banks: u64 = off_ev.occupancy.bank_busy.iter().sum();
        assert!(on_banks > off_banks, "host residency must charge the banks");
        let a = audit(&cfg, &t).unwrap();
        assert_eq!(on_banks - off_banks, a.host_bank_cycles);
        assert_eq!(on_ev.occupancy.host_bank_total(), a.host_bank_cycles);
        // Action counts (energy) stay residency-independent.
        assert_eq!(on_ev.result.actions, off_ev.result.actions);
    }

    #[test]
    fn sliding_slices_overlap_where_the_rigid_stagger_cannot() {
        // An independent near-bank stream holds bank 0 while a
        // cross-bank gather wants the channel: with slice pipelining the
        // gather's bank-0 slice slides behind the stream and the
        // transfer starts almost immediately; with the rigid stagger the
        // whole transfer queues until bank 0 frees.
        let on = ArchConfig::baseline();
        let off = on.clone().with_slice_pipelining(false);
        let mut t = Trace::default();
        let mut c0 = PerCore::zero(16);
        c0.set(0, 4096);
        t.push(1, CmdKind::Bk2Lbuf { bytes: c0 });
        t.push(2, CmdKind::Bk2Gbuf { bytes: 4096, rows: RowMap::EMPTY });
        let ev_on = simulate(&on, &t);
        let ev_off = simulate(&off, &t);
        assert!(
            ev_on.result.cycles < ev_off.result.cycles,
            "sliding {} must beat rigid {}",
            ev_on.result.cycles,
            ev_off.result.cycles
        );
        assert_eq!(ev_on.occupancy.slid_slices, 40, "exactly the 40-cycle bank-0 slice slid");
        assert_eq!(ev_off.occupancy.slid_slices, 0);
        // The audit certifies the slid cycles and stays legal either way.
        let a_on = audit(&on, &t).unwrap();
        let a_off = audit(&off, &t).unwrap();
        assert_eq!(a_on.slid_cycles, 40);
        assert_eq!(a_off.slid_cycles, 0);
        // Both placements keep the three engine-agreement invariants.
        for (cfg, ev) in [(&on, &ev_on), (&off, &ev_off)] {
            let an = engine::simulate(cfg, &t);
            assert_eq!(ev.result.actions, an.actions);
            assert!(ev.result.cycles <= an.cycles);
            assert!(ev.result.cycles >= ev.occupancy.busiest());
        }
    }

    #[test]
    fn actions_and_breakdowns_match_analytic_on_paper_traces() {
        for sys in System::ALL {
            let (cfg, t) = paper_trace(sys);
            let an = engine::simulate(&cfg, &t);
            let ev = simulate(&cfg, &t);
            assert_eq!(ev.result.actions, an.actions, "{sys:?}");
            assert_eq!(ev.result.cross_bank_cycles, an.cross_bank_cycles, "{sys:?}");
            assert_eq!(ev.result.near_bank_cycles, an.near_bank_cycles, "{sys:?}");
            assert_eq!(ev.result.gbcore_cycles, an.gbcore_cycles, "{sys:?}");
            assert_eq!(ev.result.host_cycles, an.host_cycles, "{sys:?}");
            assert!(ev.result.cycles <= an.cycles, "{sys:?}: event must not exceed serial");
            assert!(ev.result.cycles >= ev.occupancy.busiest(), "{sys:?}: below resource bound");
            audit(&cfg, &t).unwrap_or_else(|e| panic!("{sys:?}: {e}"));
        }
    }

    #[test]
    fn audit_certifies_open_row_waivers() {
        use crate::trace::RowSpan;
        // Three independent reads of the same single-row map serialize
        // on the bus; the second and third resume the row the first
        // left open. The audit's trace-order replay must certify both
        // waivers, and turning reuse off must restore the full cost.
        let cfg = ArchConfig::baseline();
        let off = cfg.clone().with_open_row_reuse(false);
        let span = Some(RowSpan { first: 5, last: 5 });
        let mut t = Trace::default();
        for _ in 0..3 {
            t.push_dep_rows(1, CmdKind::Bk2Gbuf { bytes: 2048, rows: RowMap::EMPTY }, &[], None, span);
        }
        let a_on = audit(&cfg, &t).unwrap();
        let a_off = audit(&off, &t).unwrap();
        assert_eq!(a_on.waived_open_cycles, 2 * cfg.timing.row_open_cycles());
        assert_eq!(a_off.waived_open_cycles, 0);
        let ev_on = simulate(&cfg, &t);
        let ev_off = simulate(&off, &t);
        assert_eq!(ev_on.result.open_row_hits, 2);
        assert_eq!(ev_off.result.open_row_hits, 0);
        // Bus-serialized chain: the makespan shrinks by exactly the
        // certified waivers, and energy is reuse-independent.
        assert_eq!(
            ev_off.result.cycles - ev_on.result.cycles,
            a_on.waived_open_cycles
        );
        assert_eq!(ev_on.result.actions, ev_off.result.actions);
    }

    #[test]
    fn transient_replays_reissue_and_the_audit_recertifies() {
        use crate::fault::{FaultConfig, PPM_SCALE};
        // Certain failure with one retry doubles every command on a
        // strictly-dependent chain; the audit must re-derive the same
        // attempt structure and replay-cycle total independently.
        let healthy = ArchConfig::baseline();
        let cfg = ArchConfig::baseline().with_faults(FaultConfig {
            seed: 5,
            transient_ppm: PPM_SCALE,
            max_retries: 1,
            ..FaultConfig::default()
        });
        let mut t = Trace::default();
        t.push_dep(1, CmdKind::Bk2Gbuf { bytes: 4096, rows: RowMap::EMPTY }, &[], Some(1));
        t.push_dep(2, CmdKind::Bk2Gbuf { bytes: 2048, rows: RowMap::EMPTY }, &[1], Some(2));
        t.push_dep(3, CmdKind::Gbuf2Bk { bytes: 1024, rows: RowMap::EMPTY }, &[2], Some(3));
        let ev = simulate(&cfg, &t);
        let an = engine::simulate(&cfg, &t);
        assert_eq!(ev.result.cycles, 2 * simulate(&healthy, &t).result.cycles);
        assert_eq!(ev.result.actions, an.actions);
        assert_eq!(ev.result.replayed_cycles, an.replayed_cycles);
        assert_eq!(ev.result.escalated_cmds, 3, "retries exhausted on every command");
        assert!(ev.result.cycles <= an.cycles);
        let a = audit(&cfg, &t).expect("replayed schedule stays legal");
        assert_eq!(a.replayed_cycles, ev.result.replayed_cycles);
        assert!(a.replayed_cycles > 0);
    }

    #[test]
    fn degraded_paper_trace_completes_and_audits() {
        use crate::fault::FaultConfig;
        // Retired banks, a dead core, and sparse transients together on a
        // paper trace: the degraded schedule must drain end-to-end, keep
        // the three engine-agreement invariants, and re-certify.
        let g = resnet18_first8();
        let cfg = ArchConfig::system(System::Fused16, 8192, 128).with_faults(FaultConfig {
            seed: 7,
            retired_banks: 3,
            dead_cores: 1,
            transient_ppm: 2_000,
            max_retries: 3,
            dead_channels: 0,
        });
        let p = plan(&g, &cfg);
        let t = generate(&g, &cfg, &p, CostModel::default());
        let ev = simulate(&cfg, &t);
        let an = engine::simulate(&cfg, &t);
        assert_eq!(ev.result.actions, an.actions);
        assert!(ev.result.cycles <= an.cycles);
        assert!(ev.result.cycles >= ev.occupancy.busiest());
        audit(&cfg, &t).unwrap_or_else(|e| panic!("degraded schedule must certify: {e}"));
    }

    #[test]
    fn occupancy_report_is_populated() {
        let (cfg, t) = paper_trace(System::Fused16);
        let ev = simulate(&cfg, &t);
        let occ = ev.occupancy;
        assert_eq!(occ.num_cores, 16);
        assert_eq!(occ.num_banks, 16);
        assert_eq!(occ.makespan, ev.result.cycles);
        assert!(occ.bus_busy > 0);
        assert!(occ.host_busy > 0);
        assert!(occ.cmdbus_busy > 0, "every command pays an issue slot");
        assert!(occ.core_busy[..occ.num_cores].iter().all(|&b| b > 0));
        assert!(occ.bank_busy[..occ.num_banks].iter().all(|&b| b > 0));
        assert_eq!(occ.num_groups, 4);
        assert!(occ.host_bank_total() > 0, "paper traces stream host I/O through banks");
        assert!(occ.act_busy_total() > 0, "row activations reserve window slots");
        let rendered = occ.render();
        assert!(rendered.contains("pimcore (max)"));
        assert!(rendered.contains("cmd bus"));
        assert!(rendered.contains("back-filled"));
        assert!(rendered.contains("host/bank (max)"));
        assert!(rendered.contains("act window (max)"));
    }
}
