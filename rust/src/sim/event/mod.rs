//! Discrete-event channel simulator — the overlap-aware alternative to
//! the analytic back-to-back engine (select with
//! [`Engine::Event`](crate::config::Engine)).
//!
//! The analytic engine charges every command serially, so it cannot model
//! host I/O hidden under compute, GBUF gathers overlapping an independent
//! branch's MACs, or bus contention over time — it is systematically
//! conservative about exactly the cross-bank savings PIMfused optimizes.
//! This engine instead runs a greedy earliest-issue list scheduler
//! (DESIGN.md §6.2):
//!
//! 1. [`deps`] derives a command DAG from the trace's data-flow
//!    annotations: same-node commands chain; across nodes a command waits
//!    on the last writer of each feature map it reads (RAW), and a map
//!    rewrite additionally drains the map's prior writer and every open
//!    reader (WAW/WAR).
//! 2. [`resources`] keeps a busy-until timeline per bank, per PIMcore,
//!    for the shared internal bus / GBUF port, the GBcore, and the host
//!    interface.
//! 3. Commands are visited in trace order; each starts at the earliest
//!    cycle where its predecessors have completed *and* every resource it
//!    occupies is free, reserving those resources for the durations the
//!    shared [`engine::cost`] expansion assigns.
//!
//! Three invariants hold by construction (property-tested in
//! `tests/engine_agreement.rs`):
//!
//! * action counts — and therefore energy — are identical to the
//!   analytic engine's (same [`engine::tally`] path);
//! * total cycles never exceed the analytic serial sum (a command never
//!   starts later than the previous command's completion);
//! * total cycles never undercut the busiest single resource's occupancy
//!   (reservations on one timeline cannot overlap).

mod deps;
mod resources;

pub use resources::ResourceOccupancy;

use super::engine::{self, charge, cost, tally, CmdCost};
use super::SimResult;
use crate::config::ArchConfig;
use crate::trace::Trace;

/// Event-engine output: the [`SimResult`] (with `cycles` = schedule
/// makespan and every other field identical to the analytic engine's)
/// plus the per-resource occupancy breakdown.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EventReport {
    pub result: SimResult,
    pub occupancy: ResourceOccupancy,
}

/// Simulate a full trace with the event-driven scheduler.
pub fn simulate(cfg: &ArchConfig, trace: &Trace) -> EventReport {
    let preds = deps::build(trace);
    let mut tl = resources::Timelines::new(cfg);
    let mut done: Vec<u64> = vec![0; trace.cmds.len()];
    let mut r = SimResult::default();
    let mut makespan = 0u64;
    let t_cmd = cfg.timing.t_cmd;

    for (i, cmd) in trace.cmds.iter().enumerate() {
        tally(cmd, &mut r.actions);
        let c = cost(cfg, cmd);
        // Keep the per-path occupancy breakdown (near/cross/gbcore/host
        // cycles) on the analytic engine's accounting, so the two engines
        // differ only in `cycles`. `charge` returns the serial duration,
        // which we discard in favor of the scheduled completion below.
        let _serial = charge(cfg, &c, &mut r);
        let ready = preds[i].iter().map(|j| done[j]).max().unwrap_or(0);
        let (start, span) = match &c {
            CmdCost::Pimcore { core, bcast } => tl.issue_lockstep(ready, core, *bcast),
            CmdCost::NearBank(core) => tl.issue_lockstep(ready, core, 0),
            CmdCost::Gbcore(d) => (tl.issue_gbcore(ready, *d), *d),
            CmdCost::CrossBank(d) => (tl.issue_bus(ready, *d), *d),
            CmdCost::Host(d) => (tl.issue_host(ready, *d), *d),
        };
        done[i] = start + span + t_cmd;
        makespan = makespan.max(done[i]);
    }

    r.cycles = makespan;
    EventReport { result: r, occupancy: tl.into_occupancy(makespan) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::resnet::resnet18_first8;
    use crate::config::System;
    use crate::dataflow::{plan, CostModel};
    use crate::trace::gen::generate;
    use crate::trace::{CmdKind, PerCore};

    fn paper_trace(sys: System) -> (ArchConfig, Trace) {
        let g = resnet18_first8();
        let cfg = ArchConfig::system(sys, 8192, 128);
        let p = plan(&g, &cfg);
        let t = generate(&g, &cfg, &p, CostModel::default());
        (cfg, t)
    }

    fn serial_cycles(cfg: &ArchConfig, trace: &Trace) -> u64 {
        engine::simulate(cfg, trace).cycles
    }

    #[test]
    fn empty_trace_is_zero_cycles() {
        let cfg = ArchConfig::baseline();
        let r = simulate(&cfg, &Trace::default());
        assert_eq!(r.result.cycles, 0);
        assert_eq!(r.occupancy.makespan, 0);
    }

    #[test]
    fn chained_commands_match_analytic_exactly() {
        // A strictly-dependent chain has no overlap to find: the event
        // engine must degrade to the analytic serial total.
        let cfg = ArchConfig::baseline();
        let mut t = Trace::default();
        t.push_dep(1, CmdKind::Bk2Gbuf { bytes: 4096 }, &[], Some(1));
        t.push_dep(2, CmdKind::Bk2Gbuf { bytes: 2048 }, &[1], Some(2));
        t.push_dep(3, CmdKind::Gbuf2Bk { bytes: 1024 }, &[2], Some(3));
        let ev = simulate(&cfg, &t);
        assert_eq!(ev.result.cycles, serial_cycles(&cfg, &t));
    }

    #[test]
    fn independent_commands_on_disjoint_resources_overlap() {
        // A bus transfer and a per-core LBUF fill share nothing: the
        // event engine runs them concurrently, strictly beating the
        // analytic serial sum.
        let cfg = ArchConfig::baseline();
        let mut t = Trace::default();
        t.push_dep(1, CmdKind::Bk2Gbuf { bytes: 64 * 1024 }, &[], None);
        t.push_dep(2, CmdKind::Bk2Lbuf { bytes: PerCore::uniform(16, 64 * 1024) }, &[], None);
        let ev = simulate(&cfg, &t);
        let serial = serial_cycles(&cfg, &t);
        assert!(
            ev.result.cycles < serial,
            "event {} !< serial {}",
            ev.result.cycles,
            serial
        );
        // Both still bounded below by the busiest resource.
        assert!(ev.result.cycles >= ev.occupancy.busiest());
    }

    #[test]
    fn contended_resource_serializes() {
        // Two independent cross-bank transfers both need the bus: their
        // data phases cannot overlap. Only the second command's issue
        // slot (`t_cmd`) hides under the first transfer.
        let cfg = ArchConfig::baseline();
        let mut t = Trace::default();
        t.push_dep(1, CmdKind::Bk2Gbuf { bytes: 4096 }, &[], None);
        t.push_dep(2, CmdKind::Bk2Gbuf { bytes: 4096 }, &[], None);
        let ev = simulate(&cfg, &t);
        let serial = serial_cycles(&cfg, &t);
        assert_eq!(ev.result.cycles, ev.occupancy.bus_busy + cfg.timing.t_cmd);
        assert_eq!(serial - ev.result.cycles, cfg.timing.t_cmd);
    }

    #[test]
    fn rewrite_waits_for_inflight_reader() {
        // Anti-dependency: a reorganization rewriting map 1's layout may
        // not overlap the LBUF fill still streaming the old layout, even
        // though the two occupy disjoint resources (bus vs cores).
        let cfg = ArchConfig::baseline();
        let mut t = Trace::default();
        t.push_dep(1, CmdKind::Bk2Gbuf { bytes: 4096 }, &[], Some(1));
        t.push_dep(2, CmdKind::Bk2Lbuf { bytes: PerCore::uniform(16, 64 * 1024) }, &[1], None);
        t.push_dep(5, CmdKind::Gbuf2Bk { bytes: 4096 }, &[], Some(1));
        let ev = simulate(&cfg, &t);
        // RAW then WAR chain every command: no overlap is legal.
        assert_eq!(ev.result.cycles, serial_cycles(&cfg, &t));
    }

    #[test]
    fn actions_and_breakdowns_match_analytic_on_paper_traces() {
        for sys in System::ALL {
            let (cfg, t) = paper_trace(sys);
            let an = engine::simulate(&cfg, &t);
            let ev = simulate(&cfg, &t);
            assert_eq!(ev.result.actions, an.actions, "{sys:?}");
            assert_eq!(ev.result.cross_bank_cycles, an.cross_bank_cycles, "{sys:?}");
            assert_eq!(ev.result.near_bank_cycles, an.near_bank_cycles, "{sys:?}");
            assert_eq!(ev.result.gbcore_cycles, an.gbcore_cycles, "{sys:?}");
            assert_eq!(ev.result.host_cycles, an.host_cycles, "{sys:?}");
            assert!(ev.result.cycles <= an.cycles, "{sys:?}: event must not exceed serial");
            assert!(ev.result.cycles >= ev.occupancy.busiest(), "{sys:?}: below resource bound");
        }
    }

    #[test]
    fn occupancy_report_is_populated() {
        let (cfg, t) = paper_trace(System::Fused16);
        let ev = simulate(&cfg, &t);
        let occ = ev.occupancy;
        assert_eq!(occ.num_cores, 16);
        assert_eq!(occ.num_banks, 16);
        assert_eq!(occ.makespan, ev.result.cycles);
        assert!(occ.bus_busy > 0);
        assert!(occ.host_busy > 0);
        assert!(occ.core_busy[..occ.num_cores].iter().all(|&b| b > 0));
        assert!(occ.render().contains("pimcore (max)"));
    }
}
