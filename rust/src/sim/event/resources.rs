//! Per-resource interval timelines for the event engine's scheduler.
//!
//! Each hardware resource — every bank, every PIMcore, the shared
//! internal bus / GBUF port, the GBcore, the host interface, the command
//! bus, and one row-activation window per bank group — is a sorted list
//! of reserved `[start, end)` intervals. Unlike the scalar *busy-until*
//! model this replaces, a gap an earlier reservation left behind can be
//! **back-filled** by a later, shorter command ([`Timeline::earliest_fit`]
//! finds the first gap that fits). Reservations are asserted
//! non-overlapping, which is what makes a schedule trivially legal and
//! lets `tests/engine_agreement.rs` certify it.
//!
//! [`Timelines::issue`] is the one entry point: given a command's
//! [`CmdCost`] it builds the command's *reservation request* — a set of
//! `(resource, offset, span)` items — finds the earliest common start
//! where every item fits, and commits it. The request encodes the
//! scheduler-v2 refinements (DESIGN.md §6.2):
//!
//! * the `t_cmd` issue slot is metered on a contended **command bus**
//!   timeline (one command per slot), and the data phase begins only
//!   after the issue slot;
//! * a sequential cross-bank transfer reserves, besides the bus, a 1/N
//!   **slice of each bank's timeline** — the bank-at-a-time occupancy
//!   that conflicts with near-bank streams. With
//!   [`ArchConfig::slice_pipelining`] (the default) each slice *slides*
//!   to its bank's earliest fit at-or-after its staggered offset inside
//!   the data window (the controller serves a busy bank later in its
//!   burst order — slid lock windows of one transfer may then overlap
//!   across banks, a documented relaxation: the bus interval still
//!   serializes the data, DESIGN.md §6.3). When no sliding placement
//!   fits, the whole command slides forward minimally, degenerating to
//!   the rigid stagger in the worst case; with the toggle off every
//!   slice sits at its fixed `i/N` offset;
//! * host I/O (`HOST_WRITE`/`HOST_READ`) occupies the off-chip interface
//!   for its whole duration **and** — when the config models host bank
//!   residency — streams through its destination banks bank-at-a-time:
//!   a slice of each annotated bank's timeline sized by that bank's
//!   share of the command's [`RowMap`] (the same sliding placement as
//!   the cross-bank path), with the write-recovery tail on writes, plus
//!   ACT-window slots metered per bank group from the rows that
//!   actually land in it. Host phases therefore contend with PIM
//!   traffic for exactly the banks they load;
//! * commands that write banks extend each bank reservation by the `tWR`
//!   **write-recovery tail** (reserved, but not tallied as busy work), so
//!   a read landing on that bank starts at least `tWR` after the write's
//!   data completes;
//! * row activations are metered per **bank group** on an activation
//!   window timeline at [`DramTiming::act_slot_cycles`] per ACT (the
//!   tFAW/tRRD constraint). A cross-bank command that carries a
//!   [`RowMap`] charges each group for the rows that actually land in
//!   its banks — the same metering as the host path; an un-annotated
//!   command falls back to an even `div_ceil` split across the groups
//!   its bank walk touches. [`DramTiming::act_layout`] spreads a
//!   command's activations across its data span as **per-row interleaved
//!   slots** (up to [`MAX_ACT_SLOTS`] windows per group), so two
//!   dense-activation commands can interleave within one window instead
//!   of queueing behind a front-loaded bulk reservation; a saturated
//!   group degrades to the bulk window capped at the data span, which
//!   keeps the analytic serial sum an upper bound on the schedule.
//!
//! [`DramTiming::act_slot_cycles`]: crate::config::DramTiming::act_slot_cycles
//! [`DramTiming::act_layout`]: crate::config::DramTiming::act_layout
//! [`MAX_ACT_SLOTS`]: crate::config::MAX_ACT_SLOTS
//! [`ArchConfig::slice_pipelining`]: crate::config::ArchConfig::slice_pipelining
//! [`RowMap`]: crate::trace::RowMap

use crate::config::{ArchConfig, DramTiming};
use crate::sim::engine::CmdCost;
use crate::trace::{PerCore, MAX_CORES};

/// Banks per tFAW/tRRD activation-window group (the GDDR6 bank-group
/// granularity the rank-level ACT constraints apply to).
pub const GROUP_BANKS: usize = 4;

/// Activation-window groups in a full channel (one per [`GROUP_BANKS`]
/// banks) — the size of [`ResourceOccupancy::act_busy`].
pub const NUM_ACT_GROUPS: usize = MAX_CORES.div_ceil(GROUP_BANKS);

/// Busy-cycle totals per resource, plus the schedule makespan — the
/// event engine's per-resource utilization breakdown.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ResourceOccupancy {
    /// PIMcores in the channel (valid prefix of the per-core arrays).
    pub num_cores: usize,
    /// Banks in the channel (valid prefix of `bank_busy`).
    pub num_banks: usize,
    /// Activation-window bank groups (valid prefix of `act_busy`).
    pub num_groups: usize,
    /// Total schedule length in cycles (== the event engine's `cycles`).
    pub makespan: u64,
    /// Busy cycles per PIMcore datapath (streams + broadcast snooping).
    pub core_busy: [u64; MAX_CORES],
    /// Busy cycles per bank (near-bank streams + cross-bank slices;
    /// write-recovery tails are reserved but not counted as busy).
    pub bank_busy: [u64; MAX_CORES],
    /// Busy cycles of the shared internal bus / GBUF port.
    pub bus_busy: u64,
    /// Busy cycles of the GBcore's compute datapath.
    pub gbcore_busy: u64,
    /// Busy cycles of the off-chip host interface.
    pub host_busy: u64,
    /// Busy cycles of the contended command bus (one issue slot of
    /// `t_cmd` cycles per command).
    pub cmdbus_busy: u64,
    /// Busy cycles the scheduler placed into gaps *behind* a resource's
    /// frontier — work the v1 scalar busy-until timelines could never
    /// back-fill. Summed over all resources.
    pub backfilled: u64,
    /// Per-bank slice cycles the scheduler placed *off* their rigid
    /// stagger offsets (slice pipelining): how much of the cross-bank
    /// and host bank-at-a-time traffic the modeled controller reordered
    /// around busy banks. Zero when `slice_pipelining` is off. Summed
    /// over all banks.
    pub slid_slices: u64,
    /// Host-slice busy cycles per bank: the share of `bank_busy` charged
    /// by `HOST_WRITE`/`HOST_READ` residency (zero when the config runs
    /// the interface-only host model).
    pub host_bank_busy: [u64; MAX_CORES],
    /// Reserved ACT-window cycles per bank group — tFAW/tRRD throttling
    /// slots, reserved but not tallied as busy work (so they never enter
    /// `busiest`).
    pub act_busy: [u64; NUM_ACT_GROUPS],
}

impl ResourceOccupancy {
    /// The busiest single resource's occupancy — a lower bound on any
    /// legal schedule's makespan.
    pub fn busiest(&self) -> u64 {
        let cores = self.core_busy[..self.num_cores].iter().copied().max().unwrap_or(0);
        let banks = self.bank_busy[..self.num_banks].iter().copied().max().unwrap_or(0);
        cores
            .max(banks)
            .max(self.bus_busy)
            .max(self.gbcore_busy)
            .max(self.host_busy)
            .max(self.cmdbus_busy)
    }

    /// Idle cycles of the bottleneck resource: even the busiest timeline
    /// spends this many cycles waiting on dependencies or other
    /// resources. Zero means the schedule is resource-bound.
    pub fn bottleneck_idle(&self) -> u64 {
        self.makespan.saturating_sub(self.busiest())
    }

    /// Total bank cycles charged to host I/O residency across the channel.
    pub fn host_bank_total(&self) -> u64 {
        self.host_bank_busy[..self.num_banks].iter().sum()
    }

    /// Total reserved ACT-window cycles across all bank groups.
    pub fn act_busy_total(&self) -> u64 {
        self.act_busy[..self.num_groups].iter().sum()
    }

    /// ACT-slot utilization: the share of all groups' window-cycles the
    /// tFAW/tRRD slots reserve (1.0 ⇒ every group's activation window is
    /// saturated for the whole schedule).
    pub fn act_utilization(&self) -> f64 {
        let denom = self.num_groups as u64 * self.makespan;
        if denom == 0 {
            0.0
        } else {
            self.act_busy_total() as f64 / denom as f64
        }
    }

    fn stat(vals: &[u64]) -> (u64, u64) {
        let max = vals.iter().copied().max().unwrap_or(0);
        let mean = if vals.is_empty() { 0 } else { vals.iter().sum::<u64>() / vals.len() as u64 };
        (max, mean)
    }

    /// Render the utilization table the CLI prints for `--engine event`
    /// (bus / GBcore / host / command bus individually; cores and banks
    /// summarized; per-row idle cycles plus the back-filled total).
    pub fn render(&self) -> String {
        use crate::util::table::{pct, Table};
        let share = |busy: u64| {
            if self.makespan == 0 {
                pct(0.0)
            } else {
                pct(busy as f64 / self.makespan as f64)
            }
        };
        let idle = |busy: u64| self.makespan.saturating_sub(busy).to_string();
        let (core_max, core_mean) = Self::stat(&self.core_busy[..self.num_cores]);
        let (bank_max, bank_mean) = Self::stat(&self.bank_busy[..self.num_banks]);
        let (hostbk_max, hostbk_mean) = Self::stat(&self.host_bank_busy[..self.num_banks]);
        let (act_max, act_mean) = Self::stat(&self.act_busy[..self.num_groups]);
        let mut t = Table::new(vec!["resource", "busy_cycles", "idle_cycles", "utilization"]);
        let mut line = |name: &str, busy: u64| {
            t.row(vec![name.to_string(), busy.to_string(), idle(busy), share(busy)]);
        };
        line("bus/GBUF port", self.bus_busy);
        line("gbcore", self.gbcore_busy);
        line("host i/f", self.host_busy);
        line("cmd bus", self.cmdbus_busy);
        line("pimcore (max)", core_max);
        line("pimcore (mean)", core_mean);
        line("bank (max)", bank_max);
        line("bank (mean)", bank_mean);
        // Host residency's share of the bank rows above, and the
        // tFAW/tRRD window occupancy per 4-bank group (reserved
        // throttling, so "busy" here means "no further ACT may land").
        line("host/bank (max)", hostbk_max);
        line("host/bank (mean)", hostbk_mean);
        line("act window (max)", act_max);
        line("act window (mean)", act_mean);
        // Aggregates across all resources, so neither an idle count nor
        // a single-resource utilization applies (the sums can exceed the
        // makespan).
        t.row(vec![
            "back-filled".to_string(),
            self.backfilled.to_string(),
            "-".to_string(),
            "-".to_string(),
        ]);
        t.row(vec![
            "slid slices".to_string(),
            self.slid_slices.to_string(),
            "-".to_string(),
            "-".to_string(),
        ]);
        t.render()
    }
}

/// One resource's reservations: sorted, disjoint `[start, end)` pairs
/// plus busy/back-fill tallies. Reservation is O(log n) to locate and
/// amortized O(1) to insert in the common append case; touching
/// neighbours merge so long runs of back-to-back work stay one entry.
#[derive(Debug, Clone, Default)]
struct Timeline {
    iv: Vec<(u64, u64)>,
    busy: u64,
    backfilled: u64,
}

impl Timeline {
    /// Earliest `start >= from` such that `[start, start + span)` is free.
    fn earliest_fit(&self, from: u64, span: u64) -> u64 {
        if span == 0 {
            return from;
        }
        let mut t = from;
        let i = self.iv.partition_point(|&(_, end)| end <= from);
        for &(s, e) in &self.iv[i..] {
            if t + span <= s {
                break;
            }
            t = e;
        }
        t
    }

    /// Reserve `[start, start + span + tail)`, tallying only `span` as
    /// busy work (`tail` models write recovery: the resource is blocked
    /// but not doing anything). Panics if the interval overlaps an
    /// existing reservation — the schedule-legality invariant the
    /// engine-agreement audit relies on.
    fn reserve(&mut self, start: u64, span: u64, tail: u64, tally: bool) {
        let len = span + tail;
        if len == 0 {
            return;
        }
        let end = start + len;
        let i = self.iv.partition_point(|&(s, _)| s < start);
        assert!(i == 0 || self.iv[i - 1].1 <= start, "double-booked resource interval");
        assert!(i == self.iv.len() || end <= self.iv[i].0, "double-booked resource interval");
        if tally {
            self.busy += span;
            if i < self.iv.len() {
                self.backfilled += span;
            }
        }
        let merge_prev = i > 0 && self.iv[i - 1].1 == start;
        let merge_next = i < self.iv.len() && self.iv[i].0 == end;
        match (merge_prev, merge_next) {
            (true, true) => {
                self.iv[i - 1].1 = self.iv[i].1;
                self.iv.remove(i);
            }
            (true, false) => self.iv[i - 1].1 = end,
            (false, true) => self.iv[i].0 = start,
            (false, false) => self.iv.insert(i, (start, end)),
        }
    }
}

/// One item of a command's reservation request: resource `res` is needed
/// for `[t + off, t + off + span + tail)` when the command issues at `t`.
#[derive(Debug, Clone, Copy)]
struct ReqItem {
    res: usize,
    off: u64,
    span: u64,
    tail: u64,
    tally: bool,
}

/// One per-bank slice of a sequential bank-at-a-time transfer: `span`
/// cycles on `bank`, nominally at `off` into the data window (the rigid
/// stagger — the running sum of the preceding slices' spans). With slice
/// pipelining the scheduler may place it later than `off`, wherever the
/// bank's timeline first fits it inside the window.
#[derive(Debug, Clone, Copy)]
struct SliceReq {
    bank: usize,
    off: u64,
    span: u64,
}

// Fixed arena layout: the scalar resources, then the ACT windows, then
// cores and banks (always MAX_CORES of each; unused ones stay empty).
// The scalar indices are pub(crate) so the observability layer can map
// recorded reservations back to named resources.
pub(crate) const CMDBUS: usize = 0;
pub(crate) const BUS: usize = 1;
pub(crate) const GBCORE: usize = 2;
pub(crate) const HOST: usize = 3;
const ACT0: usize = 4;
const CORE0: usize = ACT0 + NUM_ACT_GROUPS;
const BANK0: usize = CORE0 + MAX_CORES;
pub(crate) const NUM_RES: usize = BANK0 + MAX_CORES;

/// Which bank a resource-arena index addresses, if any (for the audit's
/// independent replay of recorded reservations).
pub(crate) fn res_bank(res: usize) -> Option<usize> {
    if (BANK0..BANK0 + MAX_CORES).contains(&res) {
        Some(res - BANK0)
    } else {
        None
    }
}

/// Which ACT-window group a resource-arena index addresses, if any.
pub(crate) fn res_act_group(res: usize) -> Option<usize> {
    if (ACT0..ACT0 + NUM_ACT_GROUPS).contains(&res) {
        Some(res - ACT0)
    } else {
        None
    }
}

/// Which PIMcore a resource-arena index addresses, if any (for the
/// observability layer's resource naming).
pub(crate) fn res_core(res: usize) -> Option<usize> {
    if (CORE0..CORE0 + MAX_CORES).contains(&res) {
        Some(res - CORE0)
    } else {
        None
    }
}

/// One committed reservation of a recorded command: resource `res` held
/// `[start, end)` (recovery tails included), of which `span` cycles were
/// streamed data. `tally` mirrors the [`Timeline::reserve`] busy flag —
/// only tallied reservations count toward a resource's busy cycles (ACT
/// window slots and the GBcore's bus-blocking port hold are reserved but
/// never busy). `slid` is how far a per-bank slice was committed past
/// its rigid stagger offset (always 0 for non-slice reservations).
#[derive(Debug, Clone, Copy, Default)]
pub(crate) struct Resv {
    pub(crate) res: usize,
    pub(crate) start: u64,
    pub(crate) end: u64,
    pub(crate) span: u64,
    pub(crate) slid: u64,
    pub(crate) tally: bool,
}

/// One issue attempt's committed reservations, captured when the
/// scheduler runs in audit mode: per resource the absolute `[start, end)`
/// interval (recovery tails included) plus the streamed span without the
/// tail, the attempt's data span, and the per-group activation counts
/// its reservation request metered. `start`/`done` are the attempt's own
/// issue-slot start and completion — under transient-fault replay a
/// command owns several records (one per attempt), each with its own
/// window, and the audit checks every attempt against its own `start`
/// rather than the command's first.
#[derive(Debug, Clone, Default)]
pub(crate) struct IssueRecord {
    pub(crate) start: u64,
    pub(crate) done: u64,
    pub(crate) data_span: u64,
    pub(crate) group_acts: [u64; NUM_ACT_GROUPS],
    pub(crate) resv: Vec<Resv>,
}

/// Issue result: the command's issue-slot start and its completion
/// (issue slot + data span + any write-recovery window).
#[derive(Debug, Clone, Copy)]
pub(crate) struct Issue {
    pub(crate) start: u64,
    pub(crate) done: u64,
}

/// The scheduler's mutable state: one interval [`Timeline`] per resource
/// plus a reusable request buffer.
pub(crate) struct Timelines {
    num_cores: usize,
    num_banks: usize,
    banks_per_core: usize,
    t_cmd: u64,
    t_wr: u64,
    timing: DramTiming,
    /// Whether per-bank slices may slide off their rigid stagger
    /// offsets ([`ArchConfig::slice_pipelining`]).
    sliding: bool,
    tl: Vec<Timeline>,
    req: Vec<ReqItem>,
    /// The current command's per-bank slice group (empty for commands
    /// without a bank-at-a-time walk).
    slices: Vec<SliceReq>,
    /// Write-recovery tail on every slice of the current group.
    slice_tail: u64,
    /// The data-window length the slices must stay inside.
    slice_window: u64,
    /// Absolute start cycle [`Timelines::fit`] chose for each slice.
    place: Vec<u64>,
    group_acts: [u64; NUM_ACT_GROUPS],
    /// Host-slice cycles charged per bank (occupancy attribution).
    host_bank: [u64; MAX_CORES],
    /// Reserved ACT-window cycles per group (occupancy attribution).
    act_resv: [u64; NUM_ACT_GROUPS],
    /// Slice cycles committed off their rigid stagger offsets.
    slid: u64,
    /// Per-command reservation records, kept only in audit mode.
    records: Option<Vec<IssueRecord>>,
}

impl Timelines {
    pub(crate) fn new(cfg: &ArchConfig) -> Self {
        Timelines {
            num_cores: cfg.num_pimcores().min(MAX_CORES),
            num_banks: cfg.num_banks.min(MAX_CORES),
            banks_per_core: cfg.banks_per_pimcore,
            t_cmd: cfg.timing.t_cmd,
            t_wr: cfg.timing.t_wr,
            timing: cfg.timing,
            sliding: cfg.slice_pipelining,
            tl: vec![Timeline::default(); NUM_RES],
            req: Vec::with_capacity(2 + NUM_ACT_GROUPS + 2 * MAX_CORES),
            slices: Vec::with_capacity(MAX_CORES),
            slice_tail: 0,
            slice_window: 0,
            place: Vec::with_capacity(MAX_CORES),
            group_acts: [0; NUM_ACT_GROUPS],
            host_bank: [0; MAX_CORES],
            act_resv: [0; NUM_ACT_GROUPS],
            slid: 0,
            records: None,
        }
    }

    /// A scheduler that additionally records every command's committed
    /// reservation intervals — what [`crate::sim::event::audit`] replays
    /// to certify the schedule independently of `reserve`'s asserts.
    pub(crate) fn with_recording(cfg: &ArchConfig) -> Self {
        let mut t = Self::new(cfg);
        t.records = Some(Vec::new());
        t
    }

    /// Take the recorded per-command reservations (empty unless built
    /// via [`Timelines::with_recording`]).
    pub(crate) fn take_records(&mut self) -> Vec<IssueRecord> {
        self.records.take().unwrap_or_default()
    }

    /// Bank indices owned by PIMcore `i`, clamped to the channel.
    fn banks_of(&self, core: usize) -> std::ops::Range<usize> {
        let lo = (core * self.banks_per_core).min(self.num_banks);
        let hi = ((core + 1) * self.banks_per_core).min(self.num_banks);
        lo..hi
    }

    /// Schedule one command no earlier than `ready`: find the earliest
    /// start where its issue slot and every resource interval it needs
    /// are simultaneously free (back-filling gaps where possible),
    /// reserve them all — per-bank slices at the placements [`fit`]
    /// chose, which may slide off the rigid stagger — and return the
    /// issue time and completion.
    ///
    /// [`fit`]: Timelines::fit
    pub(crate) fn issue(&mut self, ready: u64, c: &CmdCost) -> Issue {
        self.req.clear();
        self.slices.clear();
        self.place.clear();
        if self.t_cmd > 0 {
            // The issue slot on the contended command bus: one command
            // per slot; the data phase starts after it.
            self.req.push(ReqItem { res: CMDBUS, off: 0, span: self.t_cmd, tail: 0, tally: true });
        }
        let (span, post) = self.build_request(c);
        let start = self.fit(ready);
        for it in &self.req {
            self.tl[it.res].reserve(start + it.off, it.span, it.tail, it.tally);
        }
        debug_assert_eq!(self.place.len(), self.slices.len());
        for (k, s) in self.slices.iter().enumerate() {
            let at = self.place[k];
            self.tl[BANK0 + s.bank].reserve(at, s.span, self.slice_tail, true);
            if at != start + self.t_cmd + s.off {
                self.slid += s.span;
            }
        }
        if let Some(records) = &mut self.records {
            let mut resv = Vec::with_capacity(self.req.len() + self.slices.len());
            for it in &self.req {
                if it.span + it.tail > 0 {
                    resv.push(Resv {
                        res: it.res,
                        start: start + it.off,
                        end: start + it.off + it.span + it.tail,
                        span: it.span,
                        slid: 0,
                        tally: it.tally,
                    });
                }
            }
            for (k, s) in self.slices.iter().enumerate() {
                let at = self.place[k];
                resv.push(Resv {
                    res: BANK0 + s.bank,
                    start: at,
                    end: at + s.span + self.slice_tail,
                    span: s.span,
                    slid: at - (start + self.t_cmd + s.off),
                    tally: true,
                });
            }
            records.push(IssueRecord {
                start,
                done: start + self.t_cmd + span + post,
                data_span: span,
                group_acts: self.group_acts,
                resv,
            });
        }
        Issue { start, done: start + self.t_cmd + span + post }
    }

    /// Earliest `t >= ready` where every request item fits: repeatedly
    /// push `t` past each item's nearest conflict until a fixed point.
    /// Each pass either returns or strictly advances `t` beyond at least
    /// one existing reservation, so the loop terminates.
    ///
    /// The per-bank slice group is placed once the plain items fit. With
    /// slice pipelining, each slice slides to its bank's earliest fit
    /// at-or-after its rigid offset; the placement is accepted as long
    /// as every slice still ends inside the data window. A free bank
    /// yields exactly its rigid offset (`earliest_fit` of a free
    /// interval is its start), so sliding strictly relaxes the rigid
    /// constraint set — wherever the rigid stagger fits, sliding places
    /// identically, and a command never starts later than it would
    /// under the rigid stagger. When some slice cannot fit its window,
    /// the whole command slides forward *minimally* — just far enough
    /// for that bank's earliest fit to sit inside the window (in the
    /// worst case that degenerates to queueing behind the bank, i.e.
    /// the rigid shape). With pipelining off, the rigid offsets
    /// constrain `t` like any other item.
    fn fit(&mut self, ready: u64) -> u64 {
        let mut t = ready;
        loop {
            let mut moved = false;
            for it in &self.req {
                let s = self.tl[it.res].earliest_fit(t + it.off, it.span + it.tail);
                if s > t + it.off {
                    t = s - it.off;
                    moved = true;
                }
            }
            if moved {
                continue;
            }
            if self.slices.is_empty() {
                return t;
            }
            if self.sliding {
                self.place.clear();
                let data = t + self.t_cmd;
                let mut push_to = None;
                for s in &self.slices {
                    let len = s.span + self.slice_tail;
                    let at = self.tl[BANK0 + s.bank].earliest_fit(data + s.off, len);
                    if at + s.span <= data + self.slice_window {
                        self.place.push(at);
                    } else {
                        // The fit lies past the window: slide the whole
                        // command forward just far enough for it to sit
                        // inside (at + span > data + window, so this
                        // strictly advances `t` and cannot underflow).
                        push_to = Some(at + s.span - self.t_cmd - self.slice_window);
                        break;
                    }
                }
                match push_to {
                    None => return t,
                    Some(next) if next > t => {
                        t = next;
                        continue;
                    }
                    // Defensive (unreachable: the failing fit lies past
                    // the window): fall through to the rigid push below
                    // so the loop always advances.
                    Some(_) => {}
                }
            }
            // Rigid stagger (pipelining off): every slice constrains
            // `t` at its fixed offset.
            let mut moved = false;
            for s in &self.slices {
                let off = self.t_cmd + s.off;
                let at = self.tl[BANK0 + s.bank].earliest_fit(t + off, s.span + self.slice_tail);
                if at > t + off {
                    t = at - off;
                    moved = true;
                }
            }
            if !moved {
                self.place.clear();
                for s in &self.slices {
                    self.place.push(t + self.t_cmd + s.off);
                }
                return t;
            }
        }
    }

    /// Expand a [`CmdCost`] into request items (offsets relative to the
    /// data phase start, i.e. `t_cmd` after issue). Returns the
    /// command's data span and its write-recovery window.
    fn build_request(&mut self, c: &CmdCost) -> (u64, u64) {
        let t_cmd = self.t_cmd;
        self.group_acts = [0; NUM_ACT_GROUPS];
        match c {
            CmdCost::Pimcore { core, bcast, write, acts } => {
                let post = if *write { self.t_wr } else { 0 };
                let span = self.lockstep_items(core, *bcast, acts, post);
                self.act_items(span);
                (span, post)
            }
            CmdCost::NearBank { core, write, acts } => {
                let post = if *write { self.t_wr } else { 0 };
                let span = self.lockstep_items(core, 0, acts, post);
                self.act_items(span);
                (span, post)
            }
            CmdCost::Gbcore(d) => {
                // GBcore compute streams operands through the
                // single-ported GBUF, so it blocks the shared bus for its
                // whole duration; busy cycles are tallied to the GBcore
                // only (the port reservation serializes, not double-counts).
                self.req.push(ReqItem { res: BUS, off: t_cmd, span: *d, tail: 0, tally: false });
                self.req.push(ReqItem { res: GBCORE, off: t_cmd, span: *d, tail: 0, tally: true });
                (*d, 0)
            }
            CmdCost::CrossBank { total, slice, write, acts, banks, rows } => {
                let post = if *write { self.t_wr } else { 0 };
                self.req.push(ReqItem { res: BUS, off: t_cmd, span: *total, tail: 0, tally: true });
                // The bank walk visits every bank in the walk set (all
                // channel banks when healthy, the survivors under a
                // degraded fault plan) for one 1/N share of the interval.
                // Rigid offsets follow the walk *position*, not the bank
                // index, so holes in the set do not open gaps.
                let mut spans = [(0usize, 0u64); MAX_CORES];
                let mut n = 0;
                if *slice > 0 {
                    for (k, b) in banks.iter().enumerate() {
                        if b >= self.num_banks {
                            break;
                        }
                        let off = k as u64 * *slice;
                        if off >= *total {
                            break;
                        }
                        spans[n] = (b, (*slice).min(*total - off));
                        n += 1;
                    }
                }
                self.slice_items(&spans[..n], post, false, *total);
                if !rows.is_empty() {
                    // The feature map's row map says exactly how many
                    // rows land in each bank: meter each bank group's
                    // ACT window at its real share, like the host path.
                    for (b, r) in rows.iter() {
                        if b < self.num_banks {
                            self.group_acts[b / GROUP_BANKS] += r;
                        }
                    }
                } else {
                    // No row map (open-row reuse off, or an un-annotated
                    // synthetic trace): activations split evenly across
                    // the bank groups the walk set touches — the legacy
                    // metering. On a healthy full mask this is the
                    // channel's every group.
                    let mut gset = [false; NUM_ACT_GROUPS];
                    let mut ng = 0u64;
                    for b in banks.iter() {
                        if b >= self.num_banks {
                            break;
                        }
                        let g = (b / GROUP_BANKS).min(NUM_ACT_GROUPS - 1);
                        if !gset[g] {
                            gset[g] = true;
                            ng += 1;
                        }
                    }
                    if ng > 0 {
                        let per_group = acts.div_ceil(ng);
                        for (g, hit) in gset.iter().enumerate() {
                            if *hit {
                                self.group_acts[g] = per_group;
                            }
                        }
                    }
                }
                self.act_items(*total);
                (*total, post)
            }
            CmdCost::Host { total, rows, write } => {
                self.req.push(ReqItem { res: HOST, off: t_cmd, span: *total, tail: 0, tally: true });
                // Rows on banks outside the channel cannot be resident.
                let in_channel: u64 =
                    rows.iter().filter(|&(b, _)| b < self.num_banks).map(|(_, r)| r).sum();
                let resident = in_channel > 0 && *total > 0;
                let post = if *write && resident { self.t_wr } else { 0 };
                if resident {
                    // Physically the host stream also moves through its
                    // destination banks — the same bank-at-a-time slices
                    // as the cross-bank path (shared `slice_items`, so
                    // the two placement models cannot diverge), but each
                    // bank's span is its share of the rows that actually
                    // land there: the cumulative rounding below
                    // partitions the interval exactly, with no
                    // `div_ceil` share left on the host path.
                    let mut spans = [(0usize, 0u64); MAX_CORES];
                    let mut n = 0;
                    let mut acc = 0u64;
                    for (b, r) in rows.iter() {
                        if b >= self.num_banks {
                            continue;
                        }
                        let lo = *total * acc / in_channel;
                        acc += r;
                        let hi = *total * acc / in_channel;
                        spans[n] = (b, hi - lo);
                        n += 1;
                        // The rows activate in the bank group they land
                        // in — metered exactly, per the trace's map.
                        self.group_acts[b / GROUP_BANKS] += r;
                    }
                    self.slice_items(&spans[..n], post, true, *total);
                    self.act_items(*total);
                }
                (*total, post)
            }
        }
    }

    /// Queue the per-bank slice group of a sequential bank-at-a-time
    /// transfer. `spans` lists `(bank, span)` in the controller's
    /// nominal walk order; each slice's rigid offset is the running sum
    /// of the spans before it, and [`Timelines::fit`] decides whether it
    /// stays there or slides later inside the data window. One shared
    /// implementation for the cross-bank and host paths, so the two
    /// placement models cannot diverge. Callers pass only in-channel
    /// banks; zero-span entries are skipped. With `attribute_host` set
    /// the slice spans are additionally tallied into the per-bank
    /// host-residency breakdown.
    fn slice_items(
        &mut self,
        spans: &[(usize, u64)],
        tail: u64,
        attribute_host: bool,
        window: u64,
    ) {
        let mut off = 0u64;
        for &(b, span) in spans {
            if span == 0 {
                continue;
            }
            debug_assert!(b < self.num_banks);
            if attribute_host {
                self.host_bank[b] += span;
            }
            self.slices.push(SliceReq { bank: b, off, span });
            off += span;
        }
        self.slice_tail = tail;
        self.slice_window = window;
    }

    /// Items for a lockstep all-PIMcores command (`PIMcore_CMP`,
    /// `PIM_BK2LBUF`, `PIM_LBUF2BK`): every participating core starts
    /// together (the macro command is broadcast once); core `i` streams
    /// its banks for `dur[i]` cycles, and a non-zero `bcast` additionally
    /// occupies the bus while every core snoops it. Accumulates each
    /// core's row activations into its bank group and returns the span
    /// (the slowest participant's busy interval).
    fn lockstep_items(&mut self, dur: &PerCore, bcast: u64, acts: &PerCore, post: u64) -> u64 {
        let t_cmd = self.t_cmd;
        let n = dur.len().min(MAX_CORES);
        let mut span = 0;
        for i in 0..n {
            let d = dur.get(i);
            if d == 0 && bcast == 0 {
                continue;
            }
            // A core snooping a broadcast longer than its own streams
            // stays occupied until the broadcast completes.
            let busy = d.max(bcast);
            span = span.max(busy);
            self.req.push(ReqItem { res: CORE0 + i, off: t_cmd, span: busy, tail: 0, tally: true });
            if d > 0 {
                let banks = self.banks_of(i);
                // The core's activations spread evenly over its banks, so
                // a core spanning several 4-bank groups meters each
                // group's window by its share.
                let share = acts.get(i).div_ceil(banks.len().max(1) as u64);
                for b in banks {
                    self.group_acts[b / GROUP_BANKS] += share;
                    self.req.push(ReqItem {
                        res: BANK0 + b,
                        off: t_cmd,
                        span: d,
                        tail: post,
                        tally: true,
                    });
                }
            }
        }
        if bcast > 0 {
            self.req.push(ReqItem { res: BUS, off: t_cmd, span: bcast, tail: 0, tally: true });
        }
        span
    }

    /// Activation-window items from the accumulated per-group ACT
    /// counts: each group sustains at most one ACT per
    /// `act_slot_cycles()`. [`DramTiming::act_layout`] spreads the
    /// command's activations across its data span as per-row interleaved
    /// slots — up to `MAX_ACT_SLOTS` disjoint windows per group — so an
    /// independent command's windows can land in the gaps. A saturated
    /// group degrades to one bulk window capped at the data span, which
    /// keeps a command's schedule charge bounded by its analytic charge.
    fn act_items(&mut self, span: u64) {
        let t_cmd = self.t_cmd;
        for g in 0..NUM_ACT_GROUPS {
            let a = self.group_acts[g];
            if a == 0 {
                continue;
            }
            let l = self.timing.act_layout(a, span);
            for k in 0..l.slots {
                self.req.push(ReqItem {
                    res: ACT0 + g,
                    off: t_cmd + k * l.stride,
                    span: l.span,
                    tail: 0,
                    tally: false,
                });
            }
            self.act_resv[g] += l.slots * l.span;
        }
    }

    pub(crate) fn into_occupancy(self, makespan: u64) -> ResourceOccupancy {
        let mut occ = ResourceOccupancy {
            num_cores: self.num_cores,
            num_banks: self.num_banks,
            num_groups: self.num_banks.div_ceil(GROUP_BANKS).max(1).min(NUM_ACT_GROUPS),
            makespan,
            ..Default::default()
        };
        occ.bus_busy = self.tl[BUS].busy;
        occ.gbcore_busy = self.tl[GBCORE].busy;
        occ.host_busy = self.tl[HOST].busy;
        occ.cmdbus_busy = self.tl[CMDBUS].busy;
        for i in 0..MAX_CORES {
            occ.core_busy[i] = self.tl[CORE0 + i].busy;
            occ.bank_busy[i] = self.tl[BANK0 + i].busy;
        }
        occ.host_bank_busy = self.host_bank;
        occ.act_busy = self.act_resv;
        occ.backfilled = self.tl.iter().map(|t| t.backfilled).sum();
        occ.slid_slices = self.slid;
        occ
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{BankMask, RowMap};

    fn tl() -> Timelines {
        Timelines::new(&ArchConfig::baseline())
    }

    fn cross(total: u64) -> CmdCost {
        CmdCost::CrossBank {
            total,
            slice: total.div_ceil(16),
            write: false,
            acts: 0,
            banks: BankMask::all(16),
            rows: RowMap::EMPTY,
        }
    }

    /// Interface-only host I/O (no bank residency), as a residency-off
    /// config would expand it.
    fn host_io(total: u64) -> CmdCost {
        CmdCost::Host { total, rows: RowMap::EMPTY, write: false }
    }

    /// Resident host I/O with one row in each of the first `n` banks
    /// (the uniform map degenerates to the even 1/N slice split).
    fn host_resident(total: u64, n: usize, write: bool) -> CmdCost {
        CmdCost::Host { total, rows: RowMap::uniform(n, 1), write }
    }

    #[test]
    fn timeline_finds_gaps_and_appends() {
        let mut t = Timeline::default();
        t.reserve(10, 5, 0, true);
        assert_eq!(t.earliest_fit(0, 5), 0, "gap before the reservation fits");
        assert_eq!(t.earliest_fit(0, 11), 15, "too long for the gap: after");
        assert_eq!(t.earliest_fit(12, 2), 15, "from inside: pushed past the end");
        assert_eq!(t.earliest_fit(0, 10), 0);
        t.reserve(0, 5, 0, true);
        assert_eq!(t.backfilled, 5, "placed behind the frontier");
        t.reserve(5, 5, 0, true);
        assert_eq!(t.iv, vec![(0, 15)], "touching reservations merge");
        assert_eq!(t.busy, 15);
    }

    #[test]
    fn timeline_tail_blocks_but_is_not_busy() {
        let mut t = Timeline::default();
        t.reserve(0, 10, 24, true);
        assert_eq!(t.busy, 10);
        assert_eq!(t.earliest_fit(0, 1), 34, "recovery tail blocks the window");
    }

    #[test]
    #[should_panic(expected = "double-booked")]
    fn timeline_rejects_overlap() {
        let mut t = Timeline::default();
        t.reserve(0, 5, 0, true);
        t.reserve(3, 4, 0, true);
    }

    #[test]
    fn serial_resources_queue() {
        let mut t = tl();
        let a = t.issue(0, &cross(10));
        assert_eq!(a.start, 0);
        assert_eq!(a.done, 11, "issue slot + data");
        // Ready earlier than the bus frees: data queues behind.
        let b = t.issue(3, &cross(5));
        assert_eq!(b.start, 10, "data phase starts when the bus frees");
        // Ready later than everything: starts at ready.
        let c = t.issue(100, &cross(1));
        assert_eq!(c.start, 100);
        assert_eq!(t.tl[BUS].busy, 16);
        assert_eq!(t.tl[CMDBUS].busy, 3, "one t_cmd slot per command");
    }

    #[test]
    fn gbcore_shares_the_gbuf_port_with_cross_bank_traffic() {
        let mut t = tl();
        assert_eq!(t.issue(0, &cross(50)).start, 0);
        // GBcore compute streams through the single-ported GBUF: it
        // queues behind the in-flight cross-bank transfer...
        let g = t.issue(0, &CmdCost::Gbcore(20));
        assert_eq!(g.start, 50);
        // ...and subsequent cross-bank traffic queues behind it in turn,
        // while only the GBcore tally grows.
        assert_eq!(t.issue(0, &cross(5)).start, 70);
        assert_eq!(t.tl[GBCORE].busy, 20);
        assert_eq!(t.tl[BUS].busy, 55);
    }

    fn near(core: PerCore, write: bool) -> CmdCost {
        let acts = PerCore::zero(core.len());
        CmdCost::NearBank { core, write, acts }
    }

    #[test]
    fn lockstep_waits_for_all_participants() {
        let mut t = tl();
        // Core 0 busy via a solo stream.
        let mut solo = PerCore::zero(16);
        solo.set(0, 30);
        let a = t.issue(0, &near(solo, false));
        assert_eq!((a.start, a.done), (0, 31));
        // An all-cores command must wait for core 0 even though the rest
        // are idle (lockstep issue).
        let all = PerCore::uniform(16, 5);
        let b = t.issue(0, &near(all, false));
        assert_eq!(b.start, 30, "data phase starts when core 0 frees");
        assert_eq!(b.done, 36);
    }

    #[test]
    fn idle_cores_do_not_block() {
        let mut t = tl();
        let mut c0 = PerCore::zero(16);
        c0.set(0, 100);
        t.issue(0, &near(c0, false));
        // A stream that only uses core 1 overlaps core 0's work; only the
        // command-bus issue slot staggers it.
        let mut c1 = PerCore::zero(16);
        c1.set(1, 10);
        let b = t.issue(0, &near(c1, false));
        assert_eq!(b.start, 1, "waits one t_cmd issue slot, not core 0");
    }

    #[test]
    fn broadcast_occupies_bus_and_snooping_cores() {
        let mut t = tl();
        let zero = PerCore::zero(16);
        let a = t.issue(
            0,
            &CmdCost::Pimcore { core: zero, bcast: 40, write: false, acts: zero },
        );
        assert_eq!((a.start, a.done), (0, 41));
        assert_eq!(t.tl[BUS].busy, 40);
        // Every core snooped the broadcast...
        assert_eq!(t.tl[CORE0].busy, 40);
        // ...but no bank traffic occurred.
        assert_eq!(t.tl[BANK0].busy, 0);
        // The next bus user's data queues behind the broadcast.
        assert_eq!(t.issue(0, &cross(1)).start, 40);
    }

    #[test]
    fn cross_bank_slices_stagger_across_banks() {
        let mut t = tl();
        t.issue(0, &cross(160)); // slice = 10 per bank
        assert_eq!(t.tl[BANK0].iv, vec![(1, 11)]);
        assert_eq!(t.tl[BANK0 + 15].iv, vec![(1 + 150, 1 + 160)]);
        assert_eq!(t.tl[BANK0 + 3].busy, 10);
        // A near-bank stream on core 0 cannot start under bank 0's slice
        // but can back-fill nothing here; it queues after the slice.
        let mut c0 = PerCore::zero(16);
        c0.set(0, 5);
        let b = t.issue(0, &near(c0, false));
        assert_eq!(b.start + 1, 11, "bank 0 frees after its slice");
    }

    #[test]
    fn write_recovery_tail_delays_bank_reuse() {
        let mut t = tl();
        let mut c0 = PerCore::zero(16);
        c0.set(0, 10);
        // A spill (bank write) on core 0: bank 0 blocked for t_wr after.
        let w = t.issue(0, &near(c0, true));
        assert_eq!(w.done, 11 + 24, "completion includes the recovery window");
        // An independent read of the same bank starts t_wr after the
        // write's data end (1 + 10), not right after it.
        let r = t.issue(0, &near(c0, false));
        assert_eq!(r.start + 1, 11 + 24);
        // The recovery is reserved but not busy.
        assert_eq!(t.tl[BANK0].busy, 20);
    }

    #[test]
    fn act_window_throttles_dense_activations_in_a_group() {
        // Two independent single-core streams on cores 0 and 1 (banks 0
        // and 1, same bank group). With an extreme tFAW the second's
        // activations cannot start until the first's window drains.
        let mut cfg = ArchConfig::baseline();
        cfg.timing.t_faw = 4000; // act_slot = 1000, capped at the span
        let mut t = Timelines::new(&cfg);
        let mut c0 = PerCore::zero(16);
        c0.set(0, 112);
        let mut a0 = PerCore::zero(16);
        a0.set(0, 1);
        let mut c1 = PerCore::zero(16);
        c1.set(1, 112);
        let mut a1 = PerCore::zero(16);
        a1.set(1, 1);
        let first = t.issue(0, &CmdCost::NearBank { core: c0, write: false, acts: a0 });
        assert_eq!(first.start, 0);
        let second = t.issue(0, &CmdCost::NearBank { core: c1, write: false, acts: a1 });
        // The ACT window (capped at span 112) fully serializes the group.
        assert_eq!(second.start, 112);

        // Under default GDDR6 timing the same pair only staggers by the
        // 8-cycle ACT slot.
        let mut td = tl();
        td.issue(0, &CmdCost::NearBank { core: c0, write: false, acts: a0 });
        let s = td.issue(0, &CmdCost::NearBank { core: c1, write: false, acts: a1 });
        assert_eq!(s.start, 8);
    }

    #[test]
    fn timeline_earliest_fit_edge_cases() {
        let mut t = Timeline::default();
        t.reserve(10, 5, 0, true);
        t.reserve(20, 5, 0, true);
        // Zero-span requests always fit at the asked-for time, even
        // inside a reservation.
        assert_eq!(t.earliest_fit(0, 0), 0);
        assert_eq!(t.earliest_fit(12, 0), 12);
        // Gaps exactly the requested span fit flush at both boundaries.
        assert_eq!(t.earliest_fit(0, 10), 0);
        assert_eq!(t.earliest_fit(15, 5), 15);
        assert_eq!(t.earliest_fit(11, 5), 15, "mid-reservation start pushes to the gap");
        // Reserving exactly a between-gap coalesces all three intervals.
        t.reserve(15, 5, 0, true);
        assert_eq!(t.iv, vec![(10, 25)], "adjacent reservations coalesce");
        assert_eq!(t.earliest_fit(10, 1), 25, "the merged run is solid");
        // Reserve flush against the run's front (merge-next path).
        t.reserve(5, 5, 0, true);
        assert_eq!(t.iv, vec![(5, 25)]);
        // Fits starting exactly on a gap boundary.
        t.reserve(30, 5, 0, true);
        assert_eq!(t.earliest_fit(25, 5), 25);
        assert_eq!(t.earliest_fit(25, 6), 35, "one cycle too long for the gap");
    }

    #[test]
    fn host_slices_stagger_and_conflict_with_near_bank_streams() {
        let mut t = tl();
        // A resident host stream across all 16 banks: slice = 10. On
        // idle banks the sliding placement is exactly the rigid stagger.
        let h = t.issue(0, &host_resident(160, 16, false));
        assert_eq!((h.start, h.done), (0, 161));
        assert_eq!(t.tl[BANK0].iv, vec![(1, 11)], "bank 0 holds the first slice");
        assert_eq!(t.tl[BANK0 + 15].iv, vec![(151, 161)], "bank 15 the last");
        assert_eq!(t.tl[HOST].busy, 160);
        // A near-bank stream on core 0 queues behind bank 0's host slice
        // — host phases are no longer invisible to bank contention.
        let mut c0 = PerCore::zero(16);
        c0.set(0, 5);
        let b = t.issue(0, &near(c0, false));
        assert_eq!(b.start + 1, 11, "bank 0 frees after its host slice");
        let occ = t.into_occupancy(200);
        assert_eq!(occ.host_bank_busy[0], 10);
        assert_eq!(occ.host_bank_total(), 160, "slices partition the stream");
        assert_eq!(occ.bank_busy[0], 15, "host slice + near-bank stream");
    }

    #[test]
    fn interface_only_host_leaves_banks_idle() {
        let mut t = tl();
        t.issue(0, &host_io(160));
        assert_eq!(t.tl[HOST].busy, 160);
        let occ = t.into_occupancy(200);
        assert_eq!(occ.host_bank_total(), 0);
        assert!(occ.bank_busy.iter().all(|&b| b == 0));
    }

    #[test]
    fn host_write_recovery_blocks_bank_reuse() {
        let mut t = tl();
        let w = t.issue(0, &host_resident(160, 16, true));
        assert_eq!(w.done, 1 + 160 + 24, "completion includes the recovery window");
        // An independent read of bank 15 too long to back-fill the gap
        // before the slice starts >= t_wr after the slice's data end
        // (151 + 10), not right after it.
        let mut c15 = PerCore::zero(16);
        c15.set(15, 150);
        let r = t.issue(0, &near(c15, false));
        assert_eq!(r.start + 1, 161 + 24);
        assert_eq!(t.tl[BANK0 + 15].busy, 160, "recovery reserved, not busy");
    }

    #[test]
    fn host_acts_meter_the_groups_its_banks_span() {
        // A resident host stream whose rows land only in banks 0 and 1
        // (group 0) reserves that group's window for exactly its two
        // activations; group 1 stays untouched.
        let mut t = tl();
        t.issue(0, &CmdCost::Host { total: 160, rows: RowMap::from_rows(&[1, 1]), write: false });
        assert!(t.tl[ACT0].iv.len() == 2, "two interleaved ACT slots: {:?}", t.tl[ACT0].iv);
        assert!(t.tl[ACT0 + 1].iv.is_empty());
        let occ = t.into_occupancy(200);
        assert_eq!(occ.act_busy[0], 16, "2 ACTs * 8-cycle slot");
        assert_eq!(occ.act_busy_total(), 16);
        assert!(occ.act_utilization() > 0.0);
    }

    #[test]
    fn host_rows_in_one_bank_hold_it_for_the_whole_stream() {
        // A skewed row map with every row in bank 0: its slice is the
        // entire data interval, no other bank is touched, and only
        // group 0's ACT window is metered — at the exact row count.
        let mut t = tl();
        t.issue(0, &CmdCost::Host { total: 160, rows: RowMap::from_rows(&[4]), write: false });
        assert_eq!(t.tl[BANK0].iv, vec![(1, 161)], "bank 0 holds the full stream");
        assert!(t.tl[BANK0 + 1].iv.is_empty());
        let occ = t.into_occupancy(200);
        assert_eq!(occ.host_bank_busy[0], 160);
        assert_eq!(occ.host_bank_total(), 160);
        assert_eq!(occ.act_busy[0], 4 * 8, "4 ACTs at one 8-cycle slot each");
        assert_eq!(occ.act_busy_total(), 32);
    }

    #[test]
    fn host_row_map_skew_meters_act_windows_exactly() {
        // Rows split 7/1 across banks 0 (group 0) and 4 (group 1). The
        // old `div_ceil` share metered ceil(8/2) = 4 ACTs per spanned
        // group — under-reserving group 0 (7 real rows) and
        // over-reserving group 1 (1 real row) — and gave both banks an
        // even half of the interval. The row map meters each group at
        // its actual count and sizes each bank's slice by its row share.
        let mut t = tl();
        let mut rows = RowMap::EMPTY;
        rows.set(0, 7);
        rows.set(4, 1);
        t.issue(0, &CmdCost::Host { total: 160, rows, write: false });
        assert_eq!(t.tl[BANK0].iv, vec![(1, 141)], "bank 0 carries 7/8 of the interval");
        assert_eq!(t.tl[BANK0 + 4].iv, vec![(141, 161)], "bank 4 the remaining 1/8");
        let occ = t.into_occupancy(200);
        assert_eq!(occ.host_bank_busy[0], 140);
        assert_eq!(occ.host_bank_busy[4], 20);
        assert_eq!(occ.act_busy[0], 7 * 8, "group 0 reserved for its 7 real ACTs, not 4");
        assert_eq!(occ.act_busy[1], 8, "group 1 for its 1 real ACT, not 4");
        assert_eq!(occ.act_busy[2], 0);
    }

    #[test]
    fn cross_bank_row_map_meters_act_windows_exactly() {
        // Same 7/1 row skew on the cross-bank path. The legacy metering
        // spread `acts.div_ceil(groups)` = 2 ACTs over every group the
        // full bank mask touches; the row map charges group 0 for its 7
        // real rows, group 1 for its 1, and groups 2/3 for none.
        let mut t = tl();
        let mut rows = RowMap::EMPTY;
        rows.set(0, 7);
        rows.set(4, 1);
        t.issue(
            0,
            &CmdCost::CrossBank {
                total: 160,
                slice: 10,
                write: false,
                acts: 8,
                banks: BankMask::all(16),
                rows,
            },
        );
        let occ = t.into_occupancy(200);
        assert_eq!(occ.act_busy[0], 7 * 8, "group 0 reserved for its 7 real ACTs");
        assert_eq!(occ.act_busy[1], 8, "group 1 for its 1 real ACT");
        assert_eq!(occ.act_busy[2], 0, "untouched groups reserve nothing");
        assert_eq!(occ.act_busy[3], 0);
    }

    #[test]
    fn per_row_act_slots_let_dense_commands_interleave() {
        // Satellite: two dense-activation commands on one 4-bank group
        // must overlap tighter than the old bulk-window bound
        // (acts * act_slot reserved at the front), but never tighter than
        // one act_slot_cycles() per row.
        let mut t = tl(); // act_slot = 8
        let span = crate::sim::dram::near_bank_stream_cycles(&ArchConfig::baseline().timing, 4096);
        assert_eq!(span, 224, "2-row stream: 128 cols + 2 row opens");
        let dense = |core_idx: usize| {
            let mut c = PerCore::zero(16);
            c.set(core_idx, 4096 / 32 + 96); // 224-cycle stream
            let mut a = PerCore::zero(16);
            a.set(core_idx, 2);
            CmdCost::NearBank { core: c, write: false, acts: a }
        };
        let first = t.issue(0, &dense(0));
        assert_eq!(first.start, 0);
        // The first command's 2 ACT slots sit at the span's ends, not as
        // a bulk [0, 16) window.
        assert_eq!(t.tl[ACT0].iv, vec![(1, 9), (217, 225)]);
        // Banks 0/1 are distinct, so only the ACT window couples the two:
        // the second command slots in one act_slot later — tighter than
        // the 16-cycle bulk bound, exactly one slot per row.
        let second = t.issue(0, &dense(1));
        assert_eq!(second.start, 8, "one act_slot, not the 16-cycle bulk window");
        // A third dense command pays one more slot.
        let third = t.issue(0, &dense(2));
        assert_eq!(third.start, 16, "two act_slots behind the first");
    }

    #[test]
    fn saturated_act_group_still_serializes() {
        // The act_window_throttles test's extreme-tFAW case relies on the
        // saturated fallback: acts * slot >= span reserves one bulk
        // window capped at the span, fully serializing the group.
        let mut cfg = ArchConfig::baseline();
        cfg.timing.t_faw = 4000; // act_slot = 1000 >> span
        let mut t = Timelines::new(&cfg);
        let mut c0 = PerCore::zero(16);
        c0.set(0, 112);
        let mut a0 = PerCore::zero(16);
        a0.set(0, 4);
        t.issue(0, &CmdCost::NearBank { core: c0, write: false, acts: a0 });
        assert_eq!(t.tl[ACT0].iv, vec![(1, 113)], "bulk window capped at the data span");
    }

    #[test]
    fn sliding_slices_dodge_a_busy_bank() {
        // A near-bank stream holds bank 0; an independent cross-bank
        // transfer's rigid walk starts with bank 0 and would have to
        // wait for it. Slice pipelining instead serves bank 0 later in
        // the burst order: the transfer starts as soon as the command
        // bus frees, and only bank 0's slice slides past the stream.
        let mut t = tl();
        let mut c0 = PerCore::zero(16);
        c0.set(0, 50);
        t.issue(0, &near(c0, false)); // bank 0 busy [1, 51)
        let x = t.issue(0, &cross(160)); // slice 10, bank 0's rigid offset 0
        assert_eq!(x.start, 1, "only the cmd-bus slot delays the transfer");
        assert_eq!(x.done, 1 + 1 + 160);
        // Bank 0's slice slid behind the stream; bank 1 kept its offset.
        assert_eq!(t.tl[BANK0].iv, vec![(1, 61)], "stream [1,51) + slid slice [51,61)");
        assert_eq!(t.tl[BANK0 + 1].iv, vec![(12, 22)]);
        let occ = t.into_occupancy(400);
        assert_eq!(occ.slid_slices, 10, "exactly bank 0's slice slid");
    }

    #[test]
    fn sliding_window_slides_forward_minimally_when_a_slice_cannot_fit() {
        // Bank 0 is busy for almost the whole transfer window: its
        // earliest fit [156, 166) cannot sit inside the window at t = 1
        // ([2, 162)). Instead of queueing the entire walk behind bank 0
        // (the rigid start would be 155), the command slides forward
        // just far enough — start 5, window [6, 166) — for the slice to
        // fit at the window's very end.
        let mut t = tl();
        let mut c0 = PerCore::zero(16);
        c0.set(0, 155);
        t.issue(0, &near(c0, false)); // bank 0 busy [1, 156)
        let x = t.issue(0, &cross(160));
        assert_eq!(x.start, 5, "minimal forward slide, not the rigid wait");
        assert_eq!(t.tl[BANK0].iv, vec![(1, 166)], "stream [1,156) + slid slice [156,166)");
        let occ = t.into_occupancy(400);
        assert_eq!(occ.slid_slices, 10);

        // The same scenario under the rigid stagger queues behind bank 0.
        let cfg = ArchConfig::baseline().with_slice_pipelining(false);
        let mut tr = Timelines::new(&cfg);
        let mut c0 = PerCore::zero(16);
        c0.set(0, 155);
        tr.issue(0, &near(c0, false));
        assert_eq!(tr.issue(0, &cross(160)).start, 155);
    }

    #[test]
    fn rigid_stagger_waits_for_the_busy_bank() {
        // The same scenario with slice pipelining off: the whole
        // transfer queues until bank 0 can take the first slice, and
        // nothing slides.
        let cfg = ArchConfig::baseline().with_slice_pipelining(false);
        let mut t = Timelines::new(&cfg);
        let mut c0 = PerCore::zero(16);
        c0.set(0, 50);
        t.issue(0, &near(c0, false));
        let x = t.issue(0, &cross(160));
        assert_eq!(x.start, 50, "the rigid walk waits for bank 0");
        assert_eq!(t.tl[BANK0].iv, vec![(1, 61)], "stream, then the first slice right behind");
        assert_eq!(t.tl[BANK0 + 1].iv, vec![(61, 71)]);
        let occ = t.into_occupancy(400);
        assert_eq!(occ.slid_slices, 0);
    }

    #[test]
    fn sliding_host_slices_also_dodge_busy_banks() {
        // The host path shares the sliding placement: a resident host
        // stream behind a near-bank stream on bank 0 starts immediately
        // and slides only that bank's slice.
        let mut t = tl();
        let mut c0 = PerCore::zero(16);
        c0.set(0, 50);
        t.issue(0, &near(c0, false));
        let h = t.issue(0, &host_resident(160, 16, false));
        assert_eq!(h.start, 1);
        let occ = t.into_occupancy(400);
        assert_eq!(occ.slid_slices, 10);
        assert_eq!(occ.host_bank_total(), 160, "slices still partition the stream");
    }

    #[test]
    fn backfill_places_short_work_into_gaps() {
        let mut t = tl();
        // Two bus transfers leave the command bus with a gap [1, 160+1).
        t.issue(0, &cross(160));
        t.issue(0, &cross(16));
        // An independent host transfer back-fills its issue slot into
        // that gap instead of queuing behind the second command's slot
        // (interface-only here: bank slices would conflict with the
        // cross-bank transfers' own slices).
        let h = t.issue(0, &host_io(40));
        assert_eq!(h.start, 1);
        let occ = t.into_occupancy(400);
        assert_eq!(occ.backfilled, 1, "the back-filled cmd-bus slot");
        assert_eq!(occ.cmdbus_busy, 3);
        assert_eq!(occ.host_busy, 40);
    }

    #[test]
    fn occupancy_render_has_new_columns() {
        let mut occ = ResourceOccupancy {
            num_cores: 2,
            num_banks: 2,
            num_groups: 1,
            makespan: 100,
            bus_busy: 40,
            gbcore_busy: 10,
            host_busy: 5,
            cmdbus_busy: 8,
            backfilled: 12,
            slid_slices: 9,
            ..Default::default()
        };
        occ.core_busy[0] = 60;
        occ.core_busy[1] = 20;
        occ.bank_busy[0] = 30;
        occ.bank_busy[1] = 10;
        occ.host_bank_busy[0] = 6;
        occ.host_bank_busy[1] = 2;
        occ.act_busy[0] = 50;
        assert_eq!(occ.busiest(), 60);
        assert_eq!(occ.bottleneck_idle(), 40);
        assert_eq!(occ.host_bank_total(), 8);
        assert_eq!(occ.act_busy_total(), 50);
        assert!((occ.act_utilization() - 0.5).abs() < 1e-12);
        let s = occ.render();
        assert!(s.contains("idle_cycles"), "{s}");
        // bus row: busy 40, idle 60, 40.0%.
        assert!(s.contains("| bus/GBUF port "), "{s}");
        assert!(s.contains("40.0%"), "{s}");
        assert!(s.contains("| cmd bus "), "{s}");
        assert!(s.contains("8.0%"), "{s}");
        // The back-filled and slid-slices rows are cross-resource
        // aggregates: they report cycle counts with no idle/utilization
        // cells.
        assert!(s.contains("| back-filled "), "{s}");
        assert!(s.contains(" 12 |"), "{s}");
        assert!(s.contains("| slid slices "), "{s}");
        assert!(s.contains(" 9 |"), "{s}");
        // pimcore mean = 40, bank mean = 20.
        assert!(s.contains("20.0%"), "{s}");
        // Host-residency and ACT-window rows: host/bank max 6 (6.0%),
        // act window max 50 (50.0%).
        assert!(s.contains("| host/bank (max) "), "{s}");
        assert!(s.contains("6.0%"), "{s}");
        assert!(s.contains("| act window (max) "), "{s}");
        assert!(s.contains("50.0%"), "{s}");
    }

    #[test]
    fn zero_makespan_renders_zero_utilization() {
        let occ = ResourceOccupancy::default();
        assert_eq!(occ.busiest(), 0);
        assert_eq!(occ.act_utilization(), 0.0, "empty schedule is 0, not NaN");
        assert!(occ.render().contains("0.0%"));
    }
}
