//! Per-resource occupancy timelines for the event engine.
//!
//! Each hardware resource — every bank, every PIMcore, the shared
//! internal bus / GBUF port, the GBcore, and the host interface — is a
//! scalar *busy-until* timeline: the greedy scheduler reserves an
//! interval by advancing `free_at` and tallying busy cycles. Scalar
//! timelines cannot represent gaps, which keeps reservations O(1) and the
//! schedule trivially legal; the cost is that a reservation can never be
//! back-filled (an accepted conservatism, see DESIGN.md §6.2).

use crate::config::ArchConfig;
use crate::trace::{PerCore, MAX_CORES};

/// Busy-cycle totals per resource, plus the schedule makespan — the
/// event engine's per-resource utilization breakdown.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ResourceOccupancy {
    /// PIMcores in the channel (valid prefix of the per-core arrays).
    pub num_cores: usize,
    /// Banks in the channel (valid prefix of `bank_busy`).
    pub num_banks: usize,
    /// Total schedule length in cycles (== the event engine's `cycles`).
    pub makespan: u64,
    /// Busy cycles per PIMcore datapath (streams + broadcast snooping).
    pub core_busy: [u64; MAX_CORES],
    /// Busy cycles per bank (near-bank column traffic).
    pub bank_busy: [u64; MAX_CORES],
    /// Busy cycles of the shared internal bus / GBUF port.
    pub bus_busy: u64,
    /// Busy cycles of the GBcore's compute datapath.
    pub gbcore_busy: u64,
    /// Busy cycles of the off-chip host interface.
    pub host_busy: u64,
}

impl ResourceOccupancy {
    /// The busiest single resource's occupancy — a lower bound on any
    /// legal schedule's makespan.
    pub fn busiest(&self) -> u64 {
        let cores = self.core_busy[..self.num_cores].iter().copied().max().unwrap_or(0);
        let banks = self.bank_busy[..self.num_banks].iter().copied().max().unwrap_or(0);
        cores.max(banks).max(self.bus_busy).max(self.gbcore_busy).max(self.host_busy)
    }

    fn stat(vals: &[u64]) -> (u64, u64) {
        let max = vals.iter().copied().max().unwrap_or(0);
        let mean = if vals.is_empty() { 0 } else { vals.iter().sum::<u64>() / vals.len() as u64 };
        (max, mean)
    }

    /// Render the utilization table the CLI prints for `--engine event`
    /// (bus / GBcore / host individually; cores and banks summarized).
    pub fn render(&self) -> String {
        use crate::util::table::{pct, Table};
        let share = |busy: u64| {
            if self.makespan == 0 {
                pct(0.0)
            } else {
                pct(busy as f64 / self.makespan as f64)
            }
        };
        let (core_max, core_mean) = Self::stat(&self.core_busy[..self.num_cores]);
        let (bank_max, bank_mean) = Self::stat(&self.bank_busy[..self.num_banks]);
        let mut t = Table::new(vec!["resource", "busy_cycles", "utilization"]);
        t.row(vec!["bus/GBUF port".to_string(), self.bus_busy.to_string(), share(self.bus_busy)]);
        t.row(vec!["gbcore".to_string(), self.gbcore_busy.to_string(), share(self.gbcore_busy)]);
        t.row(vec!["host i/f".to_string(), self.host_busy.to_string(), share(self.host_busy)]);
        t.row(vec!["pimcore (max)".to_string(), core_max.to_string(), share(core_max)]);
        t.row(vec!["pimcore (mean)".to_string(), core_mean.to_string(), share(core_mean)]);
        t.row(vec!["bank (max)".to_string(), bank_max.to_string(), share(bank_max)]);
        t.row(vec!["bank (mean)".to_string(), bank_mean.to_string(), share(bank_mean)]);
        t.render()
    }
}

/// The scheduler's mutable state: one `free_at` per resource, plus the
/// busy tallies that become the [`ResourceOccupancy`] report.
pub(crate) struct Timelines {
    num_banks: usize,
    banks_per_core: usize,
    core_free: [u64; MAX_CORES],
    bank_free: [u64; MAX_CORES],
    bus_free: u64,
    gbcore_free: u64,
    host_free: u64,
    occ: ResourceOccupancy,
}

impl Timelines {
    pub(crate) fn new(cfg: &ArchConfig) -> Self {
        let num_cores = cfg.num_pimcores().min(MAX_CORES);
        let num_banks = cfg.num_banks.min(MAX_CORES);
        Timelines {
            num_banks,
            banks_per_core: cfg.banks_per_pimcore,
            core_free: [0; MAX_CORES],
            bank_free: [0; MAX_CORES],
            bus_free: 0,
            gbcore_free: 0,
            host_free: 0,
            occ: ResourceOccupancy { num_cores, num_banks, ..Default::default() },
        }
    }

    /// Bank indices owned by PIMcore `i`, clamped to the channel.
    fn banks_of(&self, core: usize) -> std::ops::Range<usize> {
        let lo = (core * self.banks_per_core).min(self.num_banks);
        let hi = ((core + 1) * self.banks_per_core).min(self.num_banks);
        lo..hi
    }

    /// Issue a lockstep all-PIMcores command (`PIMcore_CMP`, `PIM_BK2LBUF`,
    /// `PIM_LBUF2BK`). Every participating core starts together (the macro
    /// command is broadcast once); core `i` streams its banks for
    /// `dur[i]` cycles, and a non-zero `bcast` additionally occupies the
    /// bus while every core snoops it. Returns `(start, span)` where
    /// `span` is the slowest participant's busy interval.
    pub(crate) fn issue_lockstep(&mut self, ready: u64, dur: &PerCore, bcast: u64) -> (u64, u64) {
        let n = dur.len();
        let participates = |i: usize| dur.get(i) > 0 || bcast > 0;
        let mut start = ready;
        for i in 0..n {
            if !participates(i) {
                continue;
            }
            start = start.max(self.core_free[i]);
            if dur.get(i) > 0 {
                for b in self.banks_of(i) {
                    start = start.max(self.bank_free[b]);
                }
            }
        }
        if bcast > 0 {
            start = start.max(self.bus_free);
        }
        let mut span = 0;
        for i in 0..n {
            if !participates(i) {
                continue;
            }
            // A core snooping a broadcast longer than its own streams
            // stays occupied until the broadcast completes.
            let busy = dur.get(i).max(bcast);
            span = span.max(busy);
            self.core_free[i] = start + busy;
            self.occ.core_busy[i] += busy;
            if dur.get(i) > 0 {
                for b in self.banks_of(i) {
                    self.bank_free[b] = start + dur.get(i);
                    self.occ.bank_busy[b] += dur.get(i);
                }
            }
        }
        if bcast > 0 {
            self.bus_free = start + bcast;
            self.occ.bus_busy += bcast;
        }
        (start, span)
    }

    /// Issue a command on a single serial resource; returns its start.
    fn issue_serial(free: &mut u64, busy: &mut u64, ready: u64, dur: u64) -> u64 {
        let start = ready.max(*free);
        *free = start + dur;
        *busy += dur;
        start
    }

    /// Sequential cross-bank transfer: occupies the shared bus / GBUF
    /// port. Individual banks are touched one-at-a-time for 1/N of the
    /// interval each — a conflict the scalar timelines deliberately do
    /// not model (ROADMAP "bank-conflict refinement").
    pub(crate) fn issue_bus(&mut self, ready: u64, dur: u64) -> u64 {
        Self::issue_serial(&mut self.bus_free, &mut self.occ.bus_busy, ready, dur)
    }

    /// GBcore compute streams its operands through the single-ported
    /// GBUF, so it occupies the shared bus / GBUF port for its whole
    /// duration as well as the GBcore datapath. Busy cycles are tallied
    /// to `gbcore_busy` only — the port reservation exists to serialize
    /// GBcore work against cross-bank traffic, not to double-count it.
    pub(crate) fn issue_gbcore(&mut self, ready: u64, dur: u64) -> u64 {
        let start = ready.max(self.gbcore_free).max(self.bus_free);
        self.gbcore_free = start + dur;
        self.bus_free = start + dur;
        self.occ.gbcore_busy += dur;
        start
    }

    pub(crate) fn issue_host(&mut self, ready: u64, dur: u64) -> u64 {
        Self::issue_serial(&mut self.host_free, &mut self.occ.host_busy, ready, dur)
    }

    pub(crate) fn into_occupancy(mut self, makespan: u64) -> ResourceOccupancy {
        self.occ.makespan = makespan;
        self.occ
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tl() -> Timelines {
        Timelines::new(&ArchConfig::baseline())
    }

    #[test]
    fn serial_resources_queue() {
        let mut t = tl();
        assert_eq!(t.issue_bus(0, 10), 0);
        // Ready earlier than the bus frees: waits.
        assert_eq!(t.issue_bus(3, 5), 10);
        // Ready later than the bus frees: starts at ready.
        assert_eq!(t.issue_bus(100, 1), 100);
        assert_eq!(t.occ.bus_busy, 16);
    }

    #[test]
    fn distinct_resources_overlap() {
        let mut t = tl();
        assert_eq!(t.issue_bus(0, 50), 0);
        assert_eq!(t.issue_host(0, 20), 0, "host i/f is independent of the bus");
        let mut cores = PerCore::zero(16);
        cores.set(0, 10);
        let (s, _) = t.issue_lockstep(0, &cores, 0);
        assert_eq!(s, 0, "near-bank streams are independent of the bus");
    }

    #[test]
    fn gbcore_shares_the_gbuf_port_with_cross_bank_traffic() {
        let mut t = tl();
        assert_eq!(t.issue_bus(0, 50), 0);
        // GBcore compute streams through the single-ported GBUF: it
        // queues behind the in-flight cross-bank transfer...
        assert_eq!(t.issue_gbcore(0, 20), 50);
        // ...and subsequent cross-bank traffic queues behind it in turn,
        // while only the GBcore tally grows.
        assert_eq!(t.issue_bus(0, 5), 70);
        assert_eq!(t.occ.gbcore_busy, 20);
        assert_eq!(t.occ.bus_busy, 55);
    }

    #[test]
    fn lockstep_waits_for_all_participants() {
        let mut t = tl();
        // Core 0 busy until 30 via a solo stream.
        let mut solo = PerCore::zero(16);
        solo.set(0, 30);
        let (s0, span0) = t.issue_lockstep(0, &solo, 0);
        assert_eq!((s0, span0), (0, 30));
        // An all-cores command must wait for core 0 even though the rest
        // are idle (lockstep issue).
        let all = PerCore::uniform(16, 5);
        let (s1, span1) = t.issue_lockstep(0, &all, 0);
        assert_eq!((s1, span1), (30, 5));
    }

    #[test]
    fn idle_cores_do_not_block() {
        let mut t = tl();
        let mut c0 = PerCore::zero(16);
        c0.set(0, 100);
        t.issue_lockstep(0, &c0, 0);
        // A stream that only uses core 1 ignores core 0's reservation.
        let mut c1 = PerCore::zero(16);
        c1.set(1, 10);
        let (s, _) = t.issue_lockstep(0, &c1, 0);
        assert_eq!(s, 0);
    }

    #[test]
    fn broadcast_occupies_bus_and_snooping_cores() {
        let mut t = tl();
        let (s, span) = t.issue_lockstep(0, &PerCore::zero(16), 40);
        assert_eq!((s, span), (0, 40));
        assert_eq!(t.occ.bus_busy, 40);
        // Every core snooped the broadcast...
        assert_eq!(t.occ.core_busy[0], 40);
        // ...but no bank traffic occurred.
        assert_eq!(t.occ.bank_busy[0], 0);
        // The next bus user queues behind the broadcast.
        assert_eq!(t.issue_bus(0, 1), 40);
    }

    #[test]
    fn occupancy_busiest_and_render() {
        let mut t = tl();
        t.issue_bus(0, 70);
        t.issue_gbcore(0, 30);
        let occ = t.into_occupancy(100);
        assert_eq!(occ.busiest(), 70);
        let s = occ.render();
        assert!(s.contains("bus/GBUF port"));
        assert!(s.contains("70.0%"));
        assert!(s.contains("30.0%"));
    }
}
