//! The analytic (back-to-back) simulation engine: command stream → memory
//! cycles + action counts.
//!
//! This module also owns the pieces both engines share: `cost` expands a
//! macro command into the per-resource cycle demands of `CmdCost`, and
//! `tally` accumulates its [`ActionCounts`]. The analytic engine sums
//! command durations; the event engine ([`super::event`]) schedules the
//! same costs onto per-resource timelines. Because both tally through the
//! same code path, their action counts — and therefore energy reports —
//! are identical by construction.

use super::dram;
use super::ActionCounts;
use crate::config::ArchConfig;
use crate::fault::FaultPlan;
use crate::trace::{BankMask, Cmd, CmdKind, PerCore, RowMap, Trace, MAX_CORES};

/// Per-bank open-row tracker (DESIGN.md §6.2): the row each bank's row
/// buffer last held open, stamped with when it was touched. Lives inside
/// [`SimResult`] so both engines — and the audit's replay — advance one
/// copy per run by expanding the trace-order command stream through
/// [`expand`], which keeps waivers engine-identical by construction.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub(crate) struct OpenRows {
    banks: [OpenRow; MAX_CORES],
    /// Serial trace-order clock, advanced by every expanded command's
    /// duration. Used only to expire rows after refresh-scale gaps
    /// ([`crate::config::DramTiming::t_refi`]); deliberately
    /// engine-independent, since the event engine's placement is not
    /// known until the schedule settles.
    clock: u64,
}

#[derive(Debug, Clone, Copy, Default, PartialEq)]
struct OpenRow {
    row: u64,
    touched: u64,
    valid: bool,
}

impl OpenRows {
    /// Whether every bank of a non-empty set still holds `row` open,
    /// touched within the refresh scale of `now`.
    fn all_open_at(&self, banks: BankMask, row: u64, now: u64, t_refi: u64) -> bool {
        !banks.is_empty()
            && banks.iter().all(|b| {
                let s = &self.banks[b];
                s.valid && s.row == row && now.saturating_sub(s.touched) <= t_refi
            })
    }

    /// Record `row` as left open in every bank of the set.
    fn open(&mut self, banks: BankMask, row: u64, now: u64) {
        for b in banks.iter() {
            self.banks[b] = OpenRow { row, touched: now, valid: true };
        }
    }

    /// Close every bank of the set (writes, unknown row identity).
    fn close(&mut self, banks: BankMask) {
        for b in banks.iter() {
            self.banks[b].valid = false;
        }
    }
}

/// Result of simulating one trace on one architecture.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct SimResult {
    /// Memory-system cycles (the paper's performance metric).
    pub cycles: u64,
    /// Event tallies for the energy model.
    pub actions: ActionCounts,
    /// Cycles attributable to cross-bank (GBUF-routed) transfers — the
    /// quantity PIMfused optimizes.
    pub cross_bank_cycles: u64,
    /// Cycles of parallel near-bank streaming (max-over-cores per cmd).
    pub near_bank_cycles: u64,
    /// Cycles of GBcore compute occupancy.
    pub gbcore_cycles: u64,
    /// Cycles of host interface occupancy.
    pub host_cycles: u64,
    /// Cycles spent re-executing transiently-failed commands (replay
    /// attempts beyond each command's first). Zero without fault
    /// injection; identical across engines because every replay is
    /// charged its serial duration ([`charge`]) in both.
    pub replayed_cycles: u64,
    /// Commands whose transient failures exhausted the retry budget and
    /// escalated to the host as permanent faults (DESIGN.md §11).
    pub escalated_cmds: u64,
    /// Commands whose leading `tRP + tRCD` row open was waived because
    /// every bank they touch still held their first row open (open-row
    /// reuse, DESIGN.md §6.2). Zero with
    /// [`ArchConfig::open_row_reuse`] off; identical across engines
    /// because both expand the trace-order stream through the same
    /// state machine.
    pub open_row_hits: u64,
    /// Open-row tracker state after the last expanded command.
    pub(crate) open: OpenRows,
}

/// Simulate a full trace.
pub fn simulate(cfg: &ArchConfig, trace: &Trace) -> SimResult {
    let mut r = SimResult::default();
    if cfg.faults.transient_ppm == 0 {
        for cmd in &trace.cmds {
            step(cfg, cmd, &mut r);
        }
        return r;
    }
    // Transient faults: each command executes 1 + replays times, every
    // attempt tallied (re-executed work moves real data) and charged its
    // full serial duration.
    let plan = FaultPlan::build(cfg);
    for (i, cmd) in trace.cmds.iter().enumerate() {
        let rep = plan.replays_for(i);
        // One expansion per command, reused across replay attempts:
        // every replay charges exactly the first attempt's duration and
        // the open-row state advances once per command.
        let c = expand(cfg, cmd, &mut r);
        for attempt in 0..=rep.count {
            tally(cmd, &mut r.actions);
            let d = charge(cfg, &c, &mut r);
            r.cycles += d;
            if attempt > 0 {
                r.replayed_cycles += d;
            }
        }
        if rep.escalated {
            r.escalated_cmds += 1;
        }
    }
    r
}

/// A macro command's cycle demand on each resource class it occupies.
/// Both engines derive timing from this one expansion ([`cost`]).
///
/// Beyond raw durations, the expansion carries what the event engine's
/// scheduler needs for its finer-grained reservations (DESIGN.md §6.2):
/// `write` marks commands whose bank occupancy must be extended by the
/// `tWR` write-recovery window, `acts` counts the row activations the
/// tFAW/tRRD window meters per bank group, and `slice` is the 1/N
/// per-bank share of a sequential cross-bank transfer.
#[derive(Debug, Clone, Copy)]
pub(crate) enum CmdCost {
    /// `PIMcore_CMP`: per-core bank-stream cycles (reads + writes + open-row
    /// hit feed) and the serial GBUF-broadcast bus cycles all cores snoop.
    Pimcore { core: PerCore, bcast: u64, write: bool, acts: PerCore },
    /// `GBcore_CMP`: GBcore compute occupancy (command issue excluded).
    Gbcore(u64),
    /// `PIM_BK2LBUF` / `PIM_LBUF2BK`: parallel per-core bank-stream cycles.
    NearBank { core: PerCore, write: bool, acts: PerCore },
    /// `PIM_BK2GBUF` / `PIM_GBUF2BK`: sequential bus / GBUF-port occupancy
    /// (`total`), touching each bank of the `banks` walk set for one
    /// `slice` of the interval. On a healthy channel the walk covers all
    /// banks; retired banks shrink it (and grow the slice accordingly).
    /// With open-row reuse on, `rows` carries the feature map's per-bank
    /// row map so the scheduler meters each bank group's ACT window at
    /// its real row share; [`RowMap::EMPTY`] (reuse off, or un-annotated
    /// synthetic traces) falls back to splitting `acts` evenly across
    /// the walk's groups.
    CrossBank { total: u64, slice: u64, write: bool, acts: u64, banks: BankMask, rows: RowMap },
    /// `HOST_WRITE` / `HOST_READ`: off-chip interface occupancy (`total`)
    /// plus — when the config models host bank residency — a slice of
    /// each destination bank's timeline sized by its share of the `rows`
    /// map, whose per-bank counts also meter the tFAW/tRRD windows of
    /// the groups they land in. An empty map (residency off or no
    /// annotated banks) degrades to the interface-only model.
    Host { total: u64, rows: RowMap, write: bool },
}

/// Expand one macro command into its per-resource cycle demands using the
/// [`dram`] bank timing formulas.
pub(crate) fn cost(cfg: &ArchConfig, cmd: &Cmd) -> CmdCost {
    let t = &cfg.timing;
    // A multi-bank PIMcore stripes its streams across its banks (one
    // 256-bit column per bank per cycle — the Fig. 2 4-bank PIMcore has a
    // matching 64-lane datapath), so per-core transfer time divides by
    // the bank fan-in.
    let fanin = cfg.banks_per_pimcore as u64;
    match &cmd.kind {
        CmdKind::PimcoreCmp { bank_read, bank_read_hit, bank_write, gbuf_stream, .. } => {
            // Per-core streams run concurrently; the slowest core bounds.
            // Row-hit feed moves one column per cycle with no row opens.
            let mut core = PerCore::zero(bank_read.len());
            let mut acts = PerCore::zero(bank_read.len());
            for i in 0..bank_read.len() {
                core.set(
                    i,
                    dram::near_bank_stream_cycles(t, bank_read.get(i).div_ceil(fanin))
                        + dram::near_bank_stream_cycles(t, bank_write.get(i).div_ceil(fanin))
                        + dram::row_hit_stream_cycles(bank_read_hit.get(i).div_ceil(fanin)),
                );
                acts.set(i, rows_touched(bank_read.get(i) + bank_write.get(i)));
            }
            CmdCost::Pimcore {
                core,
                bcast: dram::broadcast_cycles(*gbuf_stream),
                write: bank_write.sum() > 0,
                acts,
            }
        }
        CmdKind::GbcoreCmp { eltwise, .. } => {
            CmdCost::Gbcore(eltwise.div_ceil(cfg.gbcore_eltwise_per_cycle as u64))
        }
        CmdKind::Bk2Lbuf { bytes } | CmdKind::Lbuf2Bk { bytes } => {
            let mut core = PerCore::zero(bytes.len());
            let mut acts = PerCore::zero(bytes.len());
            for i in 0..bytes.len() {
                core.set(i, dram::near_bank_stream_cycles(t, bytes.get(i).div_ceil(fanin)));
                acts.set(i, rows_touched(bytes.get(i)));
            }
            let write = matches!(cmd.kind, CmdKind::Lbuf2Bk { .. });
            CmdCost::NearBank { core, write, acts }
        }
        CmdKind::Bk2Gbuf { bytes, rows } | CmdKind::Gbuf2Bk { bytes, rows } => {
            let total = dram::cross_bank_stream_cycles(t, *bytes);
            // Retired banks drop out of the sequential walk: the same
            // total spreads over fewer banks, so each surviving bank's
            // slice grows. The healthy path keeps the exact 1/N split.
            let (n, banks) = if cfg.faults.has_permanent() {
                let plan = FaultPlan::build(cfg);
                (plan.surviving_bank_count().max(1) as u64, plan.surviving_banks())
            } else {
                (cfg.num_banks.max(1) as u64, BankMask::all(cfg.num_banks.min(MAX_CORES)))
            };
            CmdCost::CrossBank {
                total,
                slice: total.div_ceil(n),
                write: matches!(cmd.kind, CmdKind::Gbuf2Bk { .. }),
                acts: rows_touched(*bytes),
                banks,
                // The row-map ACT metering rides the open-row toggle so
                // `--open-row off` restores the legacy even split.
                rows: if cfg.open_row_reuse { *rows } else { RowMap::EMPTY },
            }
        }
        CmdKind::HostWrite { bytes, rows } | CmdKind::HostRead { bytes, rows } => {
            let total = dram::host_stream_cycles(t, *bytes);
            let resident = cfg.host_residency && !rows.is_empty() && total > 0;
            CmdCost::Host {
                total,
                rows: if resident { *rows } else { RowMap::EMPTY },
                write: matches!(cmd.kind, CmdKind::HostWrite { .. }),
            }
        }
    }
}

/// Accumulate one command's event tallies for the energy model. Shared by
/// both engines, so action counts cannot depend on engine choice.
pub(crate) fn tally(cmd: &Cmd, a: &mut ActionCounts) {
    match &cmd.kind {
        CmdKind::PimcoreCmp {
            macs, eltwise, bank_read, bank_read_hit, bank_write, gbuf_stream, ..
        } => {
            a.pimcore_macs += macs.sum();
            a.pimcore_eltwise += eltwise.sum();
            a.near_col_read_bytes += bank_read.sum();
            a.near_col_hit_bytes += bank_read_hit.sum();
            a.near_col_write_bytes += bank_write.sum();
            a.bus_bytes += gbuf_stream;
            a.gbuf_read_bytes += gbuf_stream;
            // Row activations track unique data only; hit traffic stays
            // in the open row by construction.
            a.row_activations += rows_touched(bank_read.sum() + bank_write.sum());
        }
        CmdKind::GbcoreCmp { eltwise, .. } => {
            a.gbcore_eltwise += eltwise;
            // GBcore streams operands through the GBUF port.
            a.gbuf_read_bytes += eltwise * 2; // operand bytes (bf16)
        }
        CmdKind::Bk2Lbuf { bytes } => {
            a.near_col_read_bytes += bytes.sum();
            a.lbuf_write_bytes += bytes.sum();
            a.row_activations += rows_touched(bytes.sum());
        }
        CmdKind::Lbuf2Bk { bytes } => {
            a.near_col_write_bytes += bytes.sum();
            a.lbuf_read_bytes += bytes.sum();
            a.row_activations += rows_touched(bytes.sum());
        }
        CmdKind::Bk2Gbuf { bytes, rows } => {
            a.cross_col_read_bytes += bytes;
            a.gbuf_write_bytes += bytes;
            a.bus_bytes += bytes;
            a.row_activations += map_acts(*bytes, rows);
        }
        CmdKind::Gbuf2Bk { bytes, rows } => {
            a.cross_col_write_bytes += bytes;
            a.gbuf_read_bytes += bytes;
            a.bus_bytes += bytes;
            a.row_activations += map_acts(*bytes, rows);
        }
        CmdKind::HostWrite { bytes, rows } | CmdKind::HostRead { bytes, rows } => {
            a.host_bytes += bytes;
            a.row_activations += map_acts(*bytes, rows);
        }
    }
}

/// Row activations of a bank-striped stream: the row map's per-bank
/// total when the command carries one, else the contiguous-volume
/// estimate. This is the same count the event scheduler meters into the
/// bank groups' ACT windows, so ACT energy and the schedule price the
/// exact same activations (the §6.3 tally/scheduler reconciliation).
/// Deliberately independent of `open_row_reuse`: row *opens* that the
/// reuse waiver skips are timing, not unique-data activations.
fn map_acts(bytes: u64, rows: &RowMap) -> u64 {
    if rows.is_empty() {
        rows_touched(bytes)
    } else {
        rows.total()
    }
}

/// Accumulate one command's occupancy into the [`SimResult`] breakdown
/// fields and return its serial duration (the analytic engine's charge).
/// Shared with the event engine so the per-path breakdowns agree.
///
/// Commands that write DRAM banks additionally charge the `tWR`
/// write-recovery window: the bank cannot serve the next access until
/// the write has restored, so both engines count those cycles in the
/// command's duration (keeping the event engine's schedule bounded by
/// the analytic serial sum even when a read queues behind the recovery).
pub(crate) fn charge(cfg: &ArchConfig, c: &CmdCost, r: &mut SimResult) -> u64 {
    let d = duration(cfg, c);
    match c {
        CmdCost::Pimcore { core, .. } => r.near_bank_cycles += core.max(),
        CmdCost::Gbcore(_) => r.gbcore_cycles += d,
        CmdCost::NearBank { .. } => r.near_bank_cycles += d,
        CmdCost::CrossBank { .. } => r.cross_bank_cycles += d,
        CmdCost::Host { .. } => r.host_cycles += d,
    }
    d
}

/// The serial duration of an expanded command — the pure arithmetic
/// [`charge`] accumulates, factored out so [`expand`] can advance the
/// open-row clock (and the audit can certify waivers) without touching
/// any breakdown field.
pub(crate) fn duration(cfg: &ArchConfig, c: &CmdCost) -> u64 {
    let t_cmd = cfg.timing.t_cmd;
    let recovery = |write: bool| if write { cfg.timing.t_wr } else { 0 };
    match c {
        CmdCost::Pimcore { core, bcast, write, .. } => {
            core.max().max(*bcast) + t_cmd + recovery(*write)
        }
        CmdCost::Gbcore(c) => c + t_cmd,
        CmdCost::NearBank { core, write, .. } => core.max() + t_cmd + recovery(*write),
        CmdCost::CrossBank { total, write, .. } => total + t_cmd + recovery(*write),
        // With bank residency modeled, a host write's destination banks
        // must restore before the next access — the same tWR the event
        // engine's slice tails reserve.
        CmdCost::Host { total, rows, write } => total + t_cmd + recovery(*write && !rows.is_empty()),
    }
}

/// The banks a command physically streams, as a conservative superset:
/// per-core commands touch their active cores' bank fan-in, row-mapped
/// transfers touch their map's banks, and un-annotated bank streams
/// touch the whole channel. `GBcore_CMP` touches none.
fn touched_banks(cfg: &ArchConfig, cmd: &Cmd) -> BankMask {
    let n = cfg.num_banks.min(MAX_CORES);
    let fanin = cfg.banks_per_pimcore.max(1);
    match &cmd.kind {
        CmdKind::PimcoreCmp { bank_read, bank_read_hit, bank_write, .. } => {
            BankMask::from_fn(n, |b| {
                let i = b / fanin;
                i < bank_read.len()
                    && bank_read.get(i) + bank_read_hit.get(i) + bank_write.get(i) > 0
            })
        }
        CmdKind::GbcoreCmp { .. } => BankMask::EMPTY,
        CmdKind::Bk2Lbuf { bytes } | CmdKind::Lbuf2Bk { bytes } => {
            BankMask::from_fn(n, |b| {
                let i = b / fanin;
                i < bytes.len() && bytes.get(i) > 0
            })
        }
        CmdKind::Bk2Gbuf { rows, .. }
        | CmdKind::Gbuf2Bk { rows, .. }
        | CmdKind::HostWrite { rows, .. }
        | CmdKind::HostRead { rows, .. } => {
            if rows.is_empty() {
                BankMask::all(n)
            } else {
                rows.banks()
            }
        }
    }
}

/// Expand one command into its charged cost, resolving open-row reuse
/// against the per-run [`OpenRows`] state (DESIGN.md §6.2). This is the
/// one entry point both engines — and the audit's replay — use, called
/// exactly once per command in trace order, so waivers, hit counts, and
/// the refresh clock are engine-identical by construction (invariant 1),
/// and the event engine merely overlaps the already-reduced durations
/// (invariant 2).
///
/// The policy: a *read* carrying a [`crate::trace::RowSpan`] hits when
/// every bank it touches still holds the span's first row, touched
/// within `tREFI`; the hit waives one `tRP + tRCD` from the command and
/// the banks are left open at the span's last row. Bank writes close
/// their banks (auto-precharge policy), as do bank streams with no row
/// identity. With [`ArchConfig::open_row_reuse`] off the state is never
/// touched and the cost is returned unmodified.
pub(crate) fn expand(cfg: &ArchConfig, cmd: &Cmd, r: &mut SimResult) -> CmdCost {
    let mut c = cost(cfg, cmd);
    if !cfg.open_row_reuse {
        return c;
    }
    let t = &cfg.timing;
    let now = r.open.clock;
    let banks = touched_banks(cfg, cmd);
    // Reads with a known row identity may resume the open row. The
    // waiver is capped at one row open per command: only the *leading*
    // open is a potential hit — within one sequential macro command the
    // row walk never revisits a row.
    let mut reused = false;
    let mut left_open = None;
    match (&mut c, cmd.row_span) {
        (CmdCost::CrossBank { total, write: false, .. }, Some(span)) => {
            if *total >= t.row_open_cycles() && r.open.all_open_at(banks, span.first, now, t.t_refi)
            {
                *total -= t.row_open_cycles();
                reused = true;
            }
            left_open = Some(span.last);
        }
        (CmdCost::Host { total, rows, write: false }, Some(span)) => {
            // Interface-only host reads (empty map) model no banks, so
            // they neither hit nor leave rows open.
            if !rows.is_empty() {
                if *total >= t.row_open_cycles()
                    && r.open.all_open_at(banks, span.first, now, t.t_refi)
                {
                    *total -= t.row_open_cycles();
                    reused = true;
                }
                left_open = Some(span.last);
            }
        }
        _ => {}
    }
    r.open.clock = now + duration(cfg, &c);
    match left_open {
        Some(row) => r.open.open(banks, row, r.open.clock),
        None => r.open.close(banks),
    }
    if reused {
        r.open_row_hits += 1;
    }
    c
}

/// Advance the simulation by one command (exposed for incremental use by
/// the validator and the property tests).
pub fn step(cfg: &ArchConfig, cmd: &Cmd, r: &mut SimResult) {
    tally(cmd, &mut r.actions);
    let c = expand(cfg, cmd, r);
    let d = charge(cfg, &c, r);
    r.cycles += d;
}

fn rows_touched(bytes: u64) -> u64 {
    bytes.div_ceil(crate::config::ROW_BYTES as u64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::resnet::{resnet18, resnet18_first8};
    use crate::config::System;
    use crate::dataflow::{plan, CostModel};
    use crate::trace::gen::generate;
    use crate::trace::{CmdKind, PerCore, Trace};
    use crate::util::prop::{check_no_shrink, Gen};

    fn run(sys: System, first8: bool, gbuf: usize, lbuf: usize) -> SimResult {
        let g = if first8 { resnet18_first8() } else { resnet18() };
        let cfg = ArchConfig::system(sys, gbuf, lbuf);
        let p = plan(&g, &cfg);
        let t = generate(&g, &cfg, &p, CostModel::default());
        simulate(&cfg, &t)
    }

    #[test]
    fn single_command_durations() {
        let cfg = ArchConfig::baseline();
        let mut r = SimResult::default();
        let mut tr = Trace::default();
        tr.push(0, CmdKind::Bk2Gbuf { bytes: 1024, rows: RowMap::EMPTY });
        step(&cfg, &tr.cmds[0], &mut r);
        assert!(r.cycles > 0);
        assert_eq!(r.cycles, r.cross_bank_cycles + 0);
        assert_eq!(r.actions.cross_col_read_bytes, 1024);
    }

    #[test]
    fn bank_writes_charge_write_recovery() {
        // A scatter (bank write) costs exactly tWR more than the gather
        // (bank read) moving the same bytes: the write-recovery window is
        // part of the command's bank occupancy in both engines.
        let cfg = ArchConfig::baseline();
        let mut rd = SimResult::default();
        let mut tr = Trace::default();
        tr.push(0, CmdKind::Bk2Gbuf { bytes: 1024, rows: RowMap::EMPTY });
        step(&cfg, &tr.cmds[0], &mut rd);
        let mut wr = SimResult::default();
        let mut tw = Trace::default();
        tw.push(0, CmdKind::Gbuf2Bk { bytes: 1024, rows: RowMap::EMPTY });
        step(&cfg, &tw.cmds[0], &mut wr);
        assert_eq!(wr.cycles - rd.cycles, cfg.timing.t_wr);
        // Same for the parallel near-bank spill vs fill.
        let mut fill = SimResult::default();
        let mut tf = Trace::default();
        tf.push(0, CmdKind::Bk2Lbuf { bytes: PerCore::uniform(16, 1024) });
        step(&cfg, &tf.cmds[0], &mut fill);
        let mut spill = SimResult::default();
        let mut ts = Trace::default();
        ts.push(0, CmdKind::Lbuf2Bk { bytes: PerCore::uniform(16, 1024) });
        step(&cfg, &ts.cmds[0], &mut spill);
        assert_eq!(spill.cycles - fill.cycles, cfg.timing.t_wr);
    }

    #[test]
    fn host_write_residency_charges_write_recovery() {
        use crate::trace::RowMap;
        // With bank residency on, a host write's destination banks must
        // restore (tWR) before the next access; a host read pays nothing
        // extra, and turning residency off restores the old charge.
        let cfg = ArchConfig::baseline();
        let run_one = |cfg: &ArchConfig, kind: CmdKind| {
            let mut r = SimResult::default();
            let mut t = Trace::default();
            t.push(0, kind);
            step(cfg, &t.cmds[0], &mut r);
            r
        };
        let rows = RowMap::striped(4096, 16);
        let wr = run_one(&cfg, CmdKind::HostWrite { bytes: 4096, rows });
        let rd = run_one(&cfg, CmdKind::HostRead { bytes: 4096, rows });
        assert_eq!(wr.cycles - rd.cycles, cfg.timing.t_wr);
        let off = cfg.clone().with_host_residency(false);
        let wr_off = run_one(&off, CmdKind::HostWrite { bytes: 4096, rows });
        assert_eq!(wr_off.cycles, rd.cycles, "residency off: interface-only charge");
        // An un-annotated host command also degrades to interface-only.
        let wr_norows = run_one(&cfg, CmdKind::HostWrite { bytes: 4096, rows: RowMap::EMPTY });
        assert_eq!(wr_norows.cycles, rd.cycles);
        // Action counts (energy) never depend on the residency switch.
        assert_eq!(wr.actions, wr_off.actions);
    }

    #[test]
    fn parallel_lbuf_fill_uses_max_not_sum() {
        let cfg = ArchConfig::baseline();
        let mut one = SimResult::default();
        let mut tr1 = Trace::default();
        tr1.push(0, CmdKind::Bk2Lbuf { bytes: PerCore::uniform(1, 4096) });
        step(&cfg, &tr1.cmds[0], &mut one);

        let mut many = SimResult::default();
        let mut tr16 = Trace::default();
        tr16.push(0, CmdKind::Bk2Lbuf { bytes: PerCore::uniform(16, 4096) });
        step(&cfg, &tr16.cmds[0], &mut many);

        // 16 cores moving the same per-core volume take the same time.
        assert_eq!(one.cycles, many.cycles);
        // ... but touch 16x the data (energy).
        assert_eq!(many.actions.near_col_read_bytes, 16 * one.actions.near_col_read_bytes);
    }

    #[test]
    fn fused_beats_lbl_on_first8_cycles() {
        // The headline direction: fused-layer dataflow cuts memory cycles
        // on the shallow-layer workload.
        let base = run(System::AimLike, true, 2048, 0);
        let f16 = run(System::Fused16, true, 2048, 0);
        assert!(
            f16.cycles < base.cycles,
            "fused {} !< lbl {}",
            f16.cycles,
            base.cycles
        );
    }

    #[test]
    fn lbuf_improves_all_systems_first8() {
        for sys in System::ALL {
            let l0 = run(sys, true, 2048, 0);
            let l256 = run(sys, true, 2048, 256);
            assert!(
                l256.cycles < l0.cycles,
                "{sys:?}: L256 {} !< L0 {}",
                l256.cycles,
                l0.cycles
            );
        }
    }

    #[test]
    fn gbuf_helps_fused_more_than_aim() {
        // Takeaway 1: AiM-like is insensitive to GBUF; Fused16 gains.
        let aim_g2 = run(System::AimLike, false, 2048, 0);
        let aim_g32 = run(System::AimLike, false, 32 * 1024, 0);
        let f_g2 = run(System::Fused16, false, 2048, 0);
        let f_g32 = run(System::Fused16, false, 32 * 1024, 0);
        let aim_gain = aim_g2.cycles as f64 / aim_g32.cycles as f64;
        let fused_gain = f_g2.cycles as f64 / f_g32.cycles as f64;
        assert!(fused_gain > aim_gain, "fused {fused_gain:.2} vs aim {aim_gain:.2}");
        assert!(aim_gain < 1.1, "AiM-like should be nearly flat, got {aim_gain:.2}");
    }

    #[test]
    fn cycles_monotone_in_buffer_sizes() {
        check_no_shrink(
            "cycles-monotone-buffers",
            12,
            |g: &mut Gen| {
                let sys = *g.choose(&System::ALL);
                let gb = *g.choose(&[2048usize, 8192, 32768]);
                let lb = *g.choose(&[0usize, 128, 512]);
                (sys, gb, lb)
            },
            |&(sys, gb, lb)| {
                let small = run(sys, true, gb, lb);
                let bigger_g = run(sys, true, gb * 2, lb);
                let bigger_l = run(sys, true, gb, lb + 256);
                bigger_g.cycles <= small.cycles && bigger_l.cycles <= small.cycles
            },
        );
    }

    #[test]
    fn cycles_additive_over_trace_splits() {
        // Property: simulating a trace equals summing its per-command steps.
        let g = resnet18_first8();
        let cfg = ArchConfig::system(System::Fused4, 8192, 128);
        let p = plan(&g, &cfg);
        let t = generate(&g, &cfg, &p, CostModel::default());
        let whole = simulate(&cfg, &t);
        let mut acc = SimResult::default();
        for c in &t.cmds {
            step(&cfg, c, &mut acc);
        }
        assert_eq!(whole, acc);
    }

    #[test]
    fn fused_spends_fewer_absolute_cross_bank_cycles() {
        // The paper's mechanism: fused kernels eliminate the per-layer
        // activation gathers, so absolute cross-bank cycles drop (even if
        // their *share* of the much-smaller total rises).
        let base = run(System::AimLike, true, 2048, 256);
        let f16 = run(System::Fused16, true, 2048, 256);
        assert!(
            f16.cross_bank_cycles < base.cross_bank_cycles,
            "fused {} !< base {}",
            f16.cross_bank_cycles,
            base.cross_bank_cycles
        );
    }

    #[test]
    fn transient_replays_add_serial_cycles() {
        use crate::fault::FaultConfig;
        let g = resnet18_first8();
        let cfg = ArchConfig::system(System::Fused16, 2048, 0);
        let p = plan(&g, &cfg);
        let t = generate(&g, &cfg, &p, CostModel::default());
        let healthy = simulate(&cfg, &t);
        let faulty_cfg = cfg.clone().with_faults(FaultConfig {
            seed: 9,
            transient_ppm: 200_000,
            max_retries: 3,
            ..Default::default()
        });
        let faulty = simulate(&faulty_cfg, &t);
        assert!(faulty.replayed_cycles > 0, "p=0.2 over a full trace must replay something");
        // Replays are pure serial additions: the faulty total is exactly
        // the healthy total plus the replayed cycles.
        assert_eq!(faulty.cycles, healthy.cycles + faulty.replayed_cycles);
        // Re-executed commands move real data again.
        assert!(faulty.actions.pimcore_macs > healthy.actions.pimcore_macs);
    }

    #[test]
    fn certain_transient_failure_triples_cycles_and_escalates() {
        use crate::fault::FaultConfig;
        let mut tr = Trace::default();
        for i in 0..8 {
            tr.push(i, CmdKind::Bk2Gbuf { bytes: 256, rows: RowMap::EMPTY });
        }
        let cfg = ArchConfig::baseline().with_faults(FaultConfig {
            seed: 1,
            transient_ppm: 1_000_000,
            max_retries: 2,
            ..Default::default()
        });
        let r = simulate(&cfg, &tr);
        assert_eq!(r.escalated_cmds, 8, "p=1 exhausts every retry budget");
        let healthy = simulate(&ArchConfig::baseline(), &tr);
        // Every command runs 1 + max_retries times before escalating.
        assert_eq!(r.cycles, healthy.cycles * 3);
        assert_eq!(r.replayed_cycles, healthy.cycles * 2);
    }

    #[test]
    fn retired_banks_shrink_the_cross_bank_walk_and_grow_its_slice() {
        use crate::fault::FaultConfig;
        let mut tr = Trace::default();
        tr.push(0, CmdKind::Bk2Gbuf { bytes: 4096, rows: RowMap::EMPTY });
        let healthy = ArchConfig::baseline();
        let faulty = ArchConfig::baseline()
            .with_faults(FaultConfig { seed: 2, retired_banks: 8, ..Default::default() });
        let ch = cost(&healthy, &tr.cmds[0]);
        let cf = cost(&faulty, &tr.cmds[0]);
        let (th, sh, bh) = match ch {
            CmdCost::CrossBank { total, slice, banks, .. } => (total, slice, banks),
            _ => panic!("expected a CrossBank cost"),
        };
        let (tf, sf, bf) = match cf {
            CmdCost::CrossBank { total, slice, banks, .. } => (total, slice, banks),
            _ => panic!("expected a CrossBank cost"),
        };
        assert_eq!(th, tf, "the sequential total is geometry-independent");
        assert_eq!(bh.count(), 16);
        assert_eq!(bf.count(), 8, "8 retired banks leave an 8-bank walk");
        assert_eq!(sh, th.div_ceil(16));
        assert_eq!(sf, tf.div_ceil(8));
        assert!(sf > sh);
        // The serial charge is the total either way: degraded cross-bank
        // commands never get cheaper.
        let mut rh = SimResult::default();
        let mut rf = SimResult::default();
        assert_eq!(charge(&healthy, &ch, &mut rh), charge(&faulty, &cf, &mut rf));
    }

    #[test]
    fn full_network_simulates_for_all_systems() {
        for sys in System::ALL {
            let r = run(sys, false, 2048, 0);
            assert!(r.cycles > 100_000, "{sys:?} suspiciously fast: {}", r.cycles);
            assert!(r.actions.pimcore_macs > 1_500_000_000);
        }
    }

    // --- open-row reuse (DESIGN.md §6.2) -----------------------------

    use crate::trace::RowSpan;

    /// A 1-row gather at row `first` (2048 B = exactly one 2-KB row).
    fn read_row(t: &mut Trace, first: u64) {
        t.push_dep_rows(
            0,
            CmdKind::Bk2Gbuf { bytes: 2048, rows: RowMap::EMPTY },
            &[],
            None,
            Some(RowSpan { first, last: first }),
        );
    }

    fn on_off(t: &Trace) -> (SimResult, SimResult) {
        let on = simulate(&ArchConfig::baseline(), t);
        let off = simulate(&ArchConfig::baseline().with_open_row_reuse(false), t);
        (on, off)
    }

    #[test]
    fn same_row_stream_waives_one_open_per_follow_up_command() {
        let mut t = Trace::default();
        for _ in 0..3 {
            read_row(&mut t, 5);
        }
        let (on, off) = on_off(&t);
        assert_eq!(off.open_row_hits, 0, "reuse off never waives");
        assert_eq!(on.open_row_hits, 2, "first command misses, the rest hit");
        let w = ArchConfig::baseline().timing.row_open_cycles();
        assert_eq!(off.cycles - on.cycles, 2 * w);
        assert_eq!(on.actions, off.actions, "waivers are timing, not energy");
    }

    #[test]
    fn alternating_rows_reopen_every_command() {
        let mut t = Trace::default();
        for i in 0..4 {
            read_row(&mut t, if i % 2 == 0 { 5 } else { 9 });
        }
        let (on, off) = on_off(&t);
        assert_eq!(on.open_row_hits, 0, "a ping-pong stream never resumes its row");
        assert_eq!(on.cycles, off.cycles);
    }

    #[test]
    fn writes_close_the_open_row() {
        let mut t = Trace::default();
        read_row(&mut t, 5); // miss: opens row 5 everywhere
        read_row(&mut t, 5); // hit
        // A scatter to the same banks closes them (auto-precharge policy).
        t.push(0, CmdKind::Gbuf2Bk { bytes: 2048, rows: RowMap::EMPTY });
        read_row(&mut t, 5); // miss again
        let (on, off) = on_off(&t);
        assert_eq!(on.open_row_hits, 1);
        let w = ArchConfig::baseline().timing.row_open_cycles();
        assert_eq!(off.cycles - on.cycles, w);
    }

    #[test]
    fn refresh_scale_gaps_expire_open_rows() {
        let cfg = ArchConfig::baseline();
        // A GBcore interlude long enough to cross tREFI (it touches no
        // bank, so only the clock gap matters).
        let gap_elt = (cfg.timing.t_refi + 1_000) * cfg.gbcore_eltwise_per_cycle as u64;
        let mut t = Trace::default();
        read_row(&mut t, 5);
        t.push(0, CmdKind::GbcoreCmp { flags: crate::trace::ExecFlags::Pool, eltwise: gap_elt });
        read_row(&mut t, 5);
        let r = simulate(&cfg, &t);
        assert_eq!(r.open_row_hits, 0, "a refresh-scale gap closes the row");
        // A short interlude keeps it open.
        let mut t2 = Trace::default();
        read_row(&mut t2, 5);
        t2.push(0, CmdKind::GbcoreCmp { flags: crate::trace::ExecFlags::Pool, eltwise: 64 });
        read_row(&mut t2, 5);
        assert_eq!(simulate(&cfg, &t2).open_row_hits, 1);
    }

    #[test]
    fn cross_bank_cost_carries_its_row_map_only_with_reuse_on() {
        let mut t = Trace::default();
        t.push(0, CmdKind::Bk2Gbuf { bytes: 4096, rows: RowMap::striped(4096, 16) });
        let rows_of = |cfg: &ArchConfig| match cost(cfg, &t.cmds[0]) {
            CmdCost::CrossBank { rows, .. } => rows,
            _ => panic!("expected a CrossBank cost"),
        };
        assert!(!rows_of(&ArchConfig::baseline()).is_empty());
        // Off restores the legacy even ACT split (empty map sentinel).
        assert!(rows_of(&ArchConfig::baseline().with_open_row_reuse(false)).is_empty());
    }

    #[test]
    fn row_mapped_commands_price_act_energy_off_the_map() {
        // The §6.3 reconciliation: a skewed map's activation count is the
        // map's total, not ceil(bytes/ROW_BYTES) on the contiguous volume.
        let mut a = ActionCounts::default();
        let mut t = Trace::default();
        let rows = RowMap::from_rows(&[7, 1, 1, 1]);
        t.push(0, CmdKind::Bk2Gbuf { bytes: 4096, rows });
        t.push(0, CmdKind::HostWrite { bytes: 4096, rows });
        tally(&t.cmds[0], &mut a);
        assert_eq!(a.row_activations, 10, "map total, not ceil(4096/2048) = 2");
        tally(&t.cmds[1], &mut a);
        assert_eq!(a.row_activations, 20, "host path prices the same map");
    }
}
