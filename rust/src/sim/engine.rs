//! The analytic (back-to-back) simulation engine: command stream → memory
//! cycles + action counts.
//!
//! This module also owns the pieces both engines share: `cost` expands a
//! macro command into the per-resource cycle demands of `CmdCost`, and
//! `tally` accumulates its [`ActionCounts`]. The analytic engine sums
//! command durations; the event engine ([`super::event`]) schedules the
//! same costs onto per-resource timelines. Because both tally through the
//! same code path, their action counts — and therefore energy reports —
//! are identical by construction.

use super::dram;
use super::ActionCounts;
use crate::config::ArchConfig;
use crate::fault::FaultPlan;
use crate::trace::{BankMask, Cmd, CmdKind, PerCore, RowMap, Trace, MAX_CORES};

/// Result of simulating one trace on one architecture.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct SimResult {
    /// Memory-system cycles (the paper's performance metric).
    pub cycles: u64,
    /// Event tallies for the energy model.
    pub actions: ActionCounts,
    /// Cycles attributable to cross-bank (GBUF-routed) transfers — the
    /// quantity PIMfused optimizes.
    pub cross_bank_cycles: u64,
    /// Cycles of parallel near-bank streaming (max-over-cores per cmd).
    pub near_bank_cycles: u64,
    /// Cycles of GBcore compute occupancy.
    pub gbcore_cycles: u64,
    /// Cycles of host interface occupancy.
    pub host_cycles: u64,
    /// Cycles spent re-executing transiently-failed commands (replay
    /// attempts beyond each command's first). Zero without fault
    /// injection; identical across engines because every replay is
    /// charged its serial duration ([`charge`]) in both.
    pub replayed_cycles: u64,
    /// Commands whose transient failures exhausted the retry budget and
    /// escalated to the host as permanent faults (DESIGN.md §11).
    pub escalated_cmds: u64,
}

/// Simulate a full trace.
pub fn simulate(cfg: &ArchConfig, trace: &Trace) -> SimResult {
    let mut r = SimResult::default();
    if cfg.faults.transient_ppm == 0 {
        for cmd in &trace.cmds {
            step(cfg, cmd, &mut r);
        }
        return r;
    }
    // Transient faults: each command executes 1 + replays times, every
    // attempt tallied (re-executed work moves real data) and charged its
    // full serial duration.
    let plan = FaultPlan::build(cfg);
    for (i, cmd) in trace.cmds.iter().enumerate() {
        let rep = plan.replays_for(i);
        let c = cost(cfg, cmd);
        for attempt in 0..=rep.count {
            tally(cmd, &mut r.actions);
            let d = charge(cfg, &c, &mut r);
            r.cycles += d;
            if attempt > 0 {
                r.replayed_cycles += d;
            }
        }
        if rep.escalated {
            r.escalated_cmds += 1;
        }
    }
    r
}

/// A macro command's cycle demand on each resource class it occupies.
/// Both engines derive timing from this one expansion ([`cost`]).
///
/// Beyond raw durations, the expansion carries what the event engine's
/// scheduler needs for its finer-grained reservations (DESIGN.md §6.2):
/// `write` marks commands whose bank occupancy must be extended by the
/// `tWR` write-recovery window, `acts` counts the row activations the
/// tFAW/tRRD window meters per bank group, and `slice` is the 1/N
/// per-bank share of a sequential cross-bank transfer.
#[derive(Debug, Clone, Copy)]
pub(crate) enum CmdCost {
    /// `PIMcore_CMP`: per-core bank-stream cycles (reads + writes + open-row
    /// hit feed) and the serial GBUF-broadcast bus cycles all cores snoop.
    Pimcore { core: PerCore, bcast: u64, write: bool, acts: PerCore },
    /// `GBcore_CMP`: GBcore compute occupancy (command issue excluded).
    Gbcore(u64),
    /// `PIM_BK2LBUF` / `PIM_LBUF2BK`: parallel per-core bank-stream cycles.
    NearBank { core: PerCore, write: bool, acts: PerCore },
    /// `PIM_BK2GBUF` / `PIM_GBUF2BK`: sequential bus / GBUF-port occupancy
    /// (`total`), touching each bank of the `banks` walk set for one
    /// `slice` of the interval. On a healthy channel the walk covers all
    /// banks; retired banks shrink it (and grow the slice accordingly).
    CrossBank { total: u64, slice: u64, write: bool, acts: u64, banks: BankMask },
    /// `HOST_WRITE` / `HOST_READ`: off-chip interface occupancy (`total`)
    /// plus — when the config models host bank residency — a slice of
    /// each destination bank's timeline sized by its share of the `rows`
    /// map, whose per-bank counts also meter the tFAW/tRRD windows of
    /// the groups they land in. An empty map (residency off or no
    /// annotated banks) degrades to the interface-only model.
    Host { total: u64, rows: RowMap, write: bool },
}

/// Expand one macro command into its per-resource cycle demands using the
/// [`dram`] bank timing formulas.
pub(crate) fn cost(cfg: &ArchConfig, cmd: &Cmd) -> CmdCost {
    let t = &cfg.timing;
    // A multi-bank PIMcore stripes its streams across its banks (one
    // 256-bit column per bank per cycle — the Fig. 2 4-bank PIMcore has a
    // matching 64-lane datapath), so per-core transfer time divides by
    // the bank fan-in.
    let fanin = cfg.banks_per_pimcore as u64;
    match &cmd.kind {
        CmdKind::PimcoreCmp { bank_read, bank_read_hit, bank_write, gbuf_stream, .. } => {
            // Per-core streams run concurrently; the slowest core bounds.
            // Row-hit feed moves one column per cycle with no row opens.
            let mut core = PerCore::zero(bank_read.len());
            let mut acts = PerCore::zero(bank_read.len());
            for i in 0..bank_read.len() {
                core.set(
                    i,
                    dram::near_bank_stream_cycles(t, bank_read.get(i).div_ceil(fanin))
                        + dram::near_bank_stream_cycles(t, bank_write.get(i).div_ceil(fanin))
                        + dram::row_hit_stream_cycles(bank_read_hit.get(i).div_ceil(fanin)),
                );
                acts.set(i, rows_touched(bank_read.get(i) + bank_write.get(i)));
            }
            CmdCost::Pimcore {
                core,
                bcast: dram::broadcast_cycles(*gbuf_stream),
                write: bank_write.sum() > 0,
                acts,
            }
        }
        CmdKind::GbcoreCmp { eltwise, .. } => {
            CmdCost::Gbcore(eltwise.div_ceil(cfg.gbcore_eltwise_per_cycle as u64))
        }
        CmdKind::Bk2Lbuf { bytes } | CmdKind::Lbuf2Bk { bytes } => {
            let mut core = PerCore::zero(bytes.len());
            let mut acts = PerCore::zero(bytes.len());
            for i in 0..bytes.len() {
                core.set(i, dram::near_bank_stream_cycles(t, bytes.get(i).div_ceil(fanin)));
                acts.set(i, rows_touched(bytes.get(i)));
            }
            let write = matches!(cmd.kind, CmdKind::Lbuf2Bk { .. });
            CmdCost::NearBank { core, write, acts }
        }
        CmdKind::Bk2Gbuf { bytes } | CmdKind::Gbuf2Bk { bytes } => {
            let total = dram::cross_bank_stream_cycles(t, *bytes);
            // Retired banks drop out of the sequential walk: the same
            // total spreads over fewer banks, so each surviving bank's
            // slice grows. The healthy path keeps the exact 1/N split.
            let (n, banks) = if cfg.faults.has_permanent() {
                let plan = FaultPlan::build(cfg);
                (plan.surviving_bank_count().max(1) as u64, plan.surviving_banks())
            } else {
                (cfg.num_banks.max(1) as u64, BankMask::all(cfg.num_banks.min(MAX_CORES)))
            };
            CmdCost::CrossBank {
                total,
                slice: total.div_ceil(n),
                write: matches!(cmd.kind, CmdKind::Gbuf2Bk { .. }),
                acts: rows_touched(*bytes),
                banks,
            }
        }
        CmdKind::HostWrite { bytes, rows } | CmdKind::HostRead { bytes, rows } => {
            let total = dram::host_stream_cycles(t, *bytes);
            let resident = cfg.host_residency && !rows.is_empty() && total > 0;
            CmdCost::Host {
                total,
                rows: if resident { *rows } else { RowMap::EMPTY },
                write: matches!(cmd.kind, CmdKind::HostWrite { .. }),
            }
        }
    }
}

/// Accumulate one command's event tallies for the energy model. Shared by
/// both engines, so action counts cannot depend on engine choice.
pub(crate) fn tally(cmd: &Cmd, a: &mut ActionCounts) {
    match &cmd.kind {
        CmdKind::PimcoreCmp {
            macs, eltwise, bank_read, bank_read_hit, bank_write, gbuf_stream, ..
        } => {
            a.pimcore_macs += macs.sum();
            a.pimcore_eltwise += eltwise.sum();
            a.near_col_read_bytes += bank_read.sum();
            a.near_col_hit_bytes += bank_read_hit.sum();
            a.near_col_write_bytes += bank_write.sum();
            a.bus_bytes += gbuf_stream;
            a.gbuf_read_bytes += gbuf_stream;
            // Row activations track unique data only; hit traffic stays
            // in the open row by construction.
            a.row_activations += rows_touched(bank_read.sum() + bank_write.sum());
        }
        CmdKind::GbcoreCmp { eltwise, .. } => {
            a.gbcore_eltwise += eltwise;
            // GBcore streams operands through the GBUF port.
            a.gbuf_read_bytes += eltwise * 2; // operand bytes (bf16)
        }
        CmdKind::Bk2Lbuf { bytes } => {
            a.near_col_read_bytes += bytes.sum();
            a.lbuf_write_bytes += bytes.sum();
            a.row_activations += rows_touched(bytes.sum());
        }
        CmdKind::Lbuf2Bk { bytes } => {
            a.near_col_write_bytes += bytes.sum();
            a.lbuf_read_bytes += bytes.sum();
            a.row_activations += rows_touched(bytes.sum());
        }
        CmdKind::Bk2Gbuf { bytes } => {
            a.cross_col_read_bytes += bytes;
            a.gbuf_write_bytes += bytes;
            a.bus_bytes += bytes;
            a.row_activations += rows_touched(*bytes);
        }
        CmdKind::Gbuf2Bk { bytes } => {
            a.cross_col_write_bytes += bytes;
            a.gbuf_read_bytes += bytes;
            a.bus_bytes += bytes;
            a.row_activations += rows_touched(*bytes);
        }
        CmdKind::HostWrite { bytes, .. } | CmdKind::HostRead { bytes, .. } => {
            a.host_bytes += bytes;
            a.row_activations += rows_touched(*bytes);
        }
    }
}

/// Accumulate one command's occupancy into the [`SimResult`] breakdown
/// fields and return its serial duration (the analytic engine's charge).
/// Shared with the event engine so the per-path breakdowns agree.
///
/// Commands that write DRAM banks additionally charge the `tWR`
/// write-recovery window: the bank cannot serve the next access until
/// the write has restored, so both engines count those cycles in the
/// command's duration (keeping the event engine's schedule bounded by
/// the analytic serial sum even when a read queues behind the recovery).
pub(crate) fn charge(cfg: &ArchConfig, c: &CmdCost, r: &mut SimResult) -> u64 {
    let t_cmd = cfg.timing.t_cmd;
    let recovery = |write: bool| if write { cfg.timing.t_wr } else { 0 };
    match c {
        CmdCost::Pimcore { core, bcast, write, .. } => {
            let core_max = core.max();
            r.near_bank_cycles += core_max;
            core_max.max(*bcast) + t_cmd + recovery(*write)
        }
        CmdCost::Gbcore(c) => {
            let d = c + t_cmd;
            r.gbcore_cycles += d;
            d
        }
        CmdCost::NearBank { core, write, .. } => {
            let d = core.max() + t_cmd + recovery(*write);
            r.near_bank_cycles += d;
            d
        }
        CmdCost::CrossBank { total, write, .. } => {
            let d = total + t_cmd + recovery(*write);
            r.cross_bank_cycles += d;
            d
        }
        CmdCost::Host { total, rows, write } => {
            // With bank residency modeled, a host write's destination
            // banks must restore before the next access — the same tWR
            // the event engine's slice tails reserve.
            let d = total + t_cmd + recovery(*write && !rows.is_empty());
            r.host_cycles += d;
            d
        }
    }
}

/// Advance the simulation by one command (exposed for incremental use by
/// the validator and the property tests).
pub fn step(cfg: &ArchConfig, cmd: &Cmd, r: &mut SimResult) {
    tally(cmd, &mut r.actions);
    let c = cost(cfg, cmd);
    let d = charge(cfg, &c, r);
    r.cycles += d;
}

fn rows_touched(bytes: u64) -> u64 {
    bytes.div_ceil(crate::config::ROW_BYTES as u64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::resnet::{resnet18, resnet18_first8};
    use crate::config::System;
    use crate::dataflow::{plan, CostModel};
    use crate::trace::gen::generate;
    use crate::trace::{CmdKind, PerCore, Trace};
    use crate::util::prop::{check_no_shrink, Gen};

    fn run(sys: System, first8: bool, gbuf: usize, lbuf: usize) -> SimResult {
        let g = if first8 { resnet18_first8() } else { resnet18() };
        let cfg = ArchConfig::system(sys, gbuf, lbuf);
        let p = plan(&g, &cfg);
        let t = generate(&g, &cfg, &p, CostModel::default());
        simulate(&cfg, &t)
    }

    #[test]
    fn single_command_durations() {
        let cfg = ArchConfig::baseline();
        let mut r = SimResult::default();
        let mut tr = Trace::default();
        tr.push(0, CmdKind::Bk2Gbuf { bytes: 1024 });
        step(&cfg, &tr.cmds[0], &mut r);
        assert!(r.cycles > 0);
        assert_eq!(r.cycles, r.cross_bank_cycles + 0);
        assert_eq!(r.actions.cross_col_read_bytes, 1024);
    }

    #[test]
    fn bank_writes_charge_write_recovery() {
        // A scatter (bank write) costs exactly tWR more than the gather
        // (bank read) moving the same bytes: the write-recovery window is
        // part of the command's bank occupancy in both engines.
        let cfg = ArchConfig::baseline();
        let mut rd = SimResult::default();
        let mut tr = Trace::default();
        tr.push(0, CmdKind::Bk2Gbuf { bytes: 1024 });
        step(&cfg, &tr.cmds[0], &mut rd);
        let mut wr = SimResult::default();
        let mut tw = Trace::default();
        tw.push(0, CmdKind::Gbuf2Bk { bytes: 1024 });
        step(&cfg, &tw.cmds[0], &mut wr);
        assert_eq!(wr.cycles - rd.cycles, cfg.timing.t_wr);
        // Same for the parallel near-bank spill vs fill.
        let mut fill = SimResult::default();
        let mut tf = Trace::default();
        tf.push(0, CmdKind::Bk2Lbuf { bytes: PerCore::uniform(16, 1024) });
        step(&cfg, &tf.cmds[0], &mut fill);
        let mut spill = SimResult::default();
        let mut ts = Trace::default();
        ts.push(0, CmdKind::Lbuf2Bk { bytes: PerCore::uniform(16, 1024) });
        step(&cfg, &ts.cmds[0], &mut spill);
        assert_eq!(spill.cycles - fill.cycles, cfg.timing.t_wr);
    }

    #[test]
    fn host_write_residency_charges_write_recovery() {
        use crate::trace::RowMap;
        // With bank residency on, a host write's destination banks must
        // restore (tWR) before the next access; a host read pays nothing
        // extra, and turning residency off restores the old charge.
        let cfg = ArchConfig::baseline();
        let run_one = |cfg: &ArchConfig, kind: CmdKind| {
            let mut r = SimResult::default();
            let mut t = Trace::default();
            t.push(0, kind);
            step(cfg, &t.cmds[0], &mut r);
            r
        };
        let rows = RowMap::striped(4096, 16);
        let wr = run_one(&cfg, CmdKind::HostWrite { bytes: 4096, rows });
        let rd = run_one(&cfg, CmdKind::HostRead { bytes: 4096, rows });
        assert_eq!(wr.cycles - rd.cycles, cfg.timing.t_wr);
        let off = cfg.clone().with_host_residency(false);
        let wr_off = run_one(&off, CmdKind::HostWrite { bytes: 4096, rows });
        assert_eq!(wr_off.cycles, rd.cycles, "residency off: interface-only charge");
        // An un-annotated host command also degrades to interface-only.
        let wr_norows = run_one(&cfg, CmdKind::HostWrite { bytes: 4096, rows: RowMap::EMPTY });
        assert_eq!(wr_norows.cycles, rd.cycles);
        // Action counts (energy) never depend on the residency switch.
        assert_eq!(wr.actions, wr_off.actions);
    }

    #[test]
    fn parallel_lbuf_fill_uses_max_not_sum() {
        let cfg = ArchConfig::baseline();
        let mut one = SimResult::default();
        let mut tr1 = Trace::default();
        tr1.push(0, CmdKind::Bk2Lbuf { bytes: PerCore::uniform(1, 4096) });
        step(&cfg, &tr1.cmds[0], &mut one);

        let mut many = SimResult::default();
        let mut tr16 = Trace::default();
        tr16.push(0, CmdKind::Bk2Lbuf { bytes: PerCore::uniform(16, 4096) });
        step(&cfg, &tr16.cmds[0], &mut many);

        // 16 cores moving the same per-core volume take the same time.
        assert_eq!(one.cycles, many.cycles);
        // ... but touch 16x the data (energy).
        assert_eq!(many.actions.near_col_read_bytes, 16 * one.actions.near_col_read_bytes);
    }

    #[test]
    fn fused_beats_lbl_on_first8_cycles() {
        // The headline direction: fused-layer dataflow cuts memory cycles
        // on the shallow-layer workload.
        let base = run(System::AimLike, true, 2048, 0);
        let f16 = run(System::Fused16, true, 2048, 0);
        assert!(
            f16.cycles < base.cycles,
            "fused {} !< lbl {}",
            f16.cycles,
            base.cycles
        );
    }

    #[test]
    fn lbuf_improves_all_systems_first8() {
        for sys in System::ALL {
            let l0 = run(sys, true, 2048, 0);
            let l256 = run(sys, true, 2048, 256);
            assert!(
                l256.cycles < l0.cycles,
                "{sys:?}: L256 {} !< L0 {}",
                l256.cycles,
                l0.cycles
            );
        }
    }

    #[test]
    fn gbuf_helps_fused_more_than_aim() {
        // Takeaway 1: AiM-like is insensitive to GBUF; Fused16 gains.
        let aim_g2 = run(System::AimLike, false, 2048, 0);
        let aim_g32 = run(System::AimLike, false, 32 * 1024, 0);
        let f_g2 = run(System::Fused16, false, 2048, 0);
        let f_g32 = run(System::Fused16, false, 32 * 1024, 0);
        let aim_gain = aim_g2.cycles as f64 / aim_g32.cycles as f64;
        let fused_gain = f_g2.cycles as f64 / f_g32.cycles as f64;
        assert!(fused_gain > aim_gain, "fused {fused_gain:.2} vs aim {aim_gain:.2}");
        assert!(aim_gain < 1.1, "AiM-like should be nearly flat, got {aim_gain:.2}");
    }

    #[test]
    fn cycles_monotone_in_buffer_sizes() {
        check_no_shrink(
            "cycles-monotone-buffers",
            12,
            |g: &mut Gen| {
                let sys = *g.choose(&System::ALL);
                let gb = *g.choose(&[2048usize, 8192, 32768]);
                let lb = *g.choose(&[0usize, 128, 512]);
                (sys, gb, lb)
            },
            |&(sys, gb, lb)| {
                let small = run(sys, true, gb, lb);
                let bigger_g = run(sys, true, gb * 2, lb);
                let bigger_l = run(sys, true, gb, lb + 256);
                bigger_g.cycles <= small.cycles && bigger_l.cycles <= small.cycles
            },
        );
    }

    #[test]
    fn cycles_additive_over_trace_splits() {
        // Property: simulating a trace equals summing its per-command steps.
        let g = resnet18_first8();
        let cfg = ArchConfig::system(System::Fused4, 8192, 128);
        let p = plan(&g, &cfg);
        let t = generate(&g, &cfg, &p, CostModel::default());
        let whole = simulate(&cfg, &t);
        let mut acc = SimResult::default();
        for c in &t.cmds {
            step(&cfg, c, &mut acc);
        }
        assert_eq!(whole, acc);
    }

    #[test]
    fn fused_spends_fewer_absolute_cross_bank_cycles() {
        // The paper's mechanism: fused kernels eliminate the per-layer
        // activation gathers, so absolute cross-bank cycles drop (even if
        // their *share* of the much-smaller total rises).
        let base = run(System::AimLike, true, 2048, 256);
        let f16 = run(System::Fused16, true, 2048, 256);
        assert!(
            f16.cross_bank_cycles < base.cross_bank_cycles,
            "fused {} !< base {}",
            f16.cross_bank_cycles,
            base.cross_bank_cycles
        );
    }

    #[test]
    fn transient_replays_add_serial_cycles() {
        use crate::fault::FaultConfig;
        let g = resnet18_first8();
        let cfg = ArchConfig::system(System::Fused16, 2048, 0);
        let p = plan(&g, &cfg);
        let t = generate(&g, &cfg, &p, CostModel::default());
        let healthy = simulate(&cfg, &t);
        let faulty_cfg = cfg.clone().with_faults(FaultConfig {
            seed: 9,
            transient_ppm: 200_000,
            max_retries: 3,
            ..Default::default()
        });
        let faulty = simulate(&faulty_cfg, &t);
        assert!(faulty.replayed_cycles > 0, "p=0.2 over a full trace must replay something");
        // Replays are pure serial additions: the faulty total is exactly
        // the healthy total plus the replayed cycles.
        assert_eq!(faulty.cycles, healthy.cycles + faulty.replayed_cycles);
        // Re-executed commands move real data again.
        assert!(faulty.actions.pimcore_macs > healthy.actions.pimcore_macs);
    }

    #[test]
    fn certain_transient_failure_triples_cycles_and_escalates() {
        use crate::fault::FaultConfig;
        let mut tr = Trace::default();
        for i in 0..8 {
            tr.push(i, CmdKind::Bk2Gbuf { bytes: 256 });
        }
        let cfg = ArchConfig::baseline().with_faults(FaultConfig {
            seed: 1,
            transient_ppm: 1_000_000,
            max_retries: 2,
            ..Default::default()
        });
        let r = simulate(&cfg, &tr);
        assert_eq!(r.escalated_cmds, 8, "p=1 exhausts every retry budget");
        let healthy = simulate(&ArchConfig::baseline(), &tr);
        // Every command runs 1 + max_retries times before escalating.
        assert_eq!(r.cycles, healthy.cycles * 3);
        assert_eq!(r.replayed_cycles, healthy.cycles * 2);
    }

    #[test]
    fn retired_banks_shrink_the_cross_bank_walk_and_grow_its_slice() {
        use crate::fault::FaultConfig;
        let mut tr = Trace::default();
        tr.push(0, CmdKind::Bk2Gbuf { bytes: 4096 });
        let healthy = ArchConfig::baseline();
        let faulty = ArchConfig::baseline()
            .with_faults(FaultConfig { seed: 2, retired_banks: 8, ..Default::default() });
        let ch = cost(&healthy, &tr.cmds[0]);
        let cf = cost(&faulty, &tr.cmds[0]);
        let (th, sh, bh) = match ch {
            CmdCost::CrossBank { total, slice, banks, .. } => (total, slice, banks),
            _ => panic!("expected a CrossBank cost"),
        };
        let (tf, sf, bf) = match cf {
            CmdCost::CrossBank { total, slice, banks, .. } => (total, slice, banks),
            _ => panic!("expected a CrossBank cost"),
        };
        assert_eq!(th, tf, "the sequential total is geometry-independent");
        assert_eq!(bh.count(), 16);
        assert_eq!(bf.count(), 8, "8 retired banks leave an 8-bank walk");
        assert_eq!(sh, th.div_ceil(16));
        assert_eq!(sf, tf.div_ceil(8));
        assert!(sf > sh);
        // The serial charge is the total either way: degraded cross-bank
        // commands never get cheaper.
        let mut rh = SimResult::default();
        let mut rf = SimResult::default();
        assert_eq!(charge(&healthy, &ch, &mut rh), charge(&faulty, &cf, &mut rf));
    }

    #[test]
    fn full_network_simulates_for_all_systems() {
        for sys in System::ALL {
            let r = run(sys, false, 2048, 0);
            assert!(r.cycles > 100_000, "{sys:?} suspiciously fast: {}", r.cycles);
            assert!(r.actions.pimcore_macs > 1_500_000_000);
        }
    }
}
