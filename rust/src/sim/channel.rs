//! Multi-channel scale-out driver (DESIGN.md §12): schedule each
//! channel's trace independently on its own per-resource timelines, then
//! meter the cross-channel exchanges on a single shared
//! **host-interconnect** interval timeline so channel counts are not a
//! free lunch.
//!
//! ## Composition model
//!
//! Channels are embarrassingly parallel inside a step: every channel's
//! trace runs through the ordinary engine selected by
//! [`crate::config::ArchConfig::engine`] with its own
//! [`crate::sim::event::resources`] arena, untouched. What couples them
//! is the exchange schedule: each [`ExchangePoint`] becomes *ready* when
//! its channel's **analytic prefix** through the boundary command
//! completes (engine-independent, so both engines agree on the exchange
//! record), and then claims the interconnect timeline first-fit at or
//! after its ready time, one transfer at a time — the gather serializes
//! exactly like the command bus serializes issue slots.
//!
//! Totals compose so the single-channel engine invariants survive:
//!
//! * **event** = `max(max_c event_c, last exchange end)` — still ≥ every
//!   per-resource busy sum, including the interconnect's;
//! * **analytic** = `max_c analytic_c + Σ exchange durations` — still
//!   ≥ the event total, because an exchange's ready time is an analytic
//!   prefix (≤ `max_c analytic_c`) and the queue adds at most the serial
//!   sum of durations;
//! * **actions** = `Σ_c actions_c` plus the exchange bytes tallied once
//!   as host-interface traffic — identical under both engines, so energy
//!   stays engine-equal.
//!
//! [`ExchangePoint`]: crate::trace::partition::ExchangePoint

use crate::cnn::NodeId;
use crate::config::{ArchConfig, Engine, PartitionKind};
use crate::sim::{self, dram, engine, ResourceOccupancy, SimResult};
use crate::trace::partition::ChannelSet;

/// Upper bound on [`ArchConfig::channels`] — keeps per-channel vectors
/// small and the CLI honest about what the model has been tested at.
pub const MAX_CHANNELS: usize = 16;

/// A single-resource interval timeline with first-fit placement — the
/// host interconnect's analogue of the command bus: one transfer holds
/// the whole resource, reservations never overlap, and a transfer may
/// backfill an earlier gap if one fits entirely.
#[derive(Debug, Clone, Default)]
pub struct IntervalTimeline {
    /// Committed `[start, end)` reservations, kept sorted by start.
    spans: Vec<(u64, u64)>,
}

impl IntervalTimeline {
    /// An empty timeline.
    pub fn new() -> Self {
        Self::default()
    }

    /// Reserve `dur` cycles at the earliest start ≥ `at_or_after` where
    /// the whole interval fits; returns the committed start. Zero-length
    /// reservations commit nothing and return `at_or_after`.
    pub fn reserve(&mut self, at_or_after: u64, dur: u64) -> u64 {
        if dur == 0 {
            return at_or_after;
        }
        let mut start = at_or_after;
        let mut at = 0usize;
        for (i, &(s, e)) in self.spans.iter().enumerate() {
            if start + dur <= s {
                break;
            }
            if start < e {
                start = e;
            }
            at = i + 1;
        }
        self.spans.insert(at, (start, start + dur));
        start
    }

    /// Total reserved cycles.
    pub fn busy(&self) -> u64 {
        self.spans.iter().map(|&(s, e)| e - s).sum()
    }

    /// End of the last reservation (0 when empty).
    pub fn end(&self) -> u64 {
        self.spans.iter().map(|&(_, e)| e).max().unwrap_or(0)
    }

    /// Number of committed reservations.
    pub fn len(&self) -> usize {
        self.spans.len()
    }

    /// Whether nothing has been reserved.
    pub fn is_empty(&self) -> bool {
        self.spans.is_empty()
    }
}

/// One committed cross-channel transfer on the interconnect timeline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ExchangeSpan {
    /// Source channel of the shard.
    pub channel: usize,
    /// Graph node whose sharded output crossed.
    pub node: NodeId,
    /// Shard bytes moved.
    pub bytes: u64,
    /// When the shard became ready (analytic prefix completion).
    pub ready: u64,
    /// Committed start on the interconnect timeline.
    pub start: u64,
    /// Committed end (`start` + transfer duration).
    pub end: u64,
}

/// The multi-channel summary a [`crate::ppa::PpaReport`] carries when
/// `channels > 1` (absent — and therefore byte-invisible — otherwise).
#[derive(Debug, Clone, PartialEq)]
pub struct ChannelReport {
    /// Configured channel count.
    pub channels: usize,
    /// Channels that executed work (see
    /// [`crate::trace::partition::ChannelSet::width`]).
    pub width: usize,
    /// Channels retired by the fault config.
    pub dead_channels: usize,
    /// Partition strategy.
    pub partition: PartitionKind,
    /// Per configured channel, that channel's own schedule length in
    /// cycles (0 for idle and retired channels).
    pub channel_cycles: Vec<u64>,
    /// Busy cycles on the shared host interconnect.
    pub interconnect_busy: u64,
    /// Total bytes that crossed the interconnect.
    pub exchange_bytes: u64,
    /// Committed transfers, in interconnect-schedule order.
    pub exchanges: Vec<ExchangeSpan>,
}

impl ChannelReport {
    /// Interconnect utilization: busy share of the composed makespan.
    pub fn interconnect_utilization(&self, makespan: u64) -> f64 {
        if makespan == 0 {
            0.0
        } else {
            self.interconnect_busy as f64 / makespan as f64
        }
    }
}

/// Result of running a [`ChannelSet`]: the composed [`SimResult`],
/// channel 0's occupancy breakdown (event engine), and the channel
/// summary.
#[derive(Debug, Clone)]
pub struct ChannelOutcome {
    /// Composed cycles/actions/breakdowns (see the module docs).
    pub result: SimResult,
    /// Channel 0's per-resource occupancy (event engine only). The
    /// channels are geometry-identical clones, so channel 0 is the
    /// representative timeline; per-channel makespans live in
    /// [`ChannelOutcome::report`].
    pub occupancy: Option<ResourceOccupancy>,
    /// The multi-channel summary.
    pub report: ChannelReport,
}

/// Analytic prefix completion times for each channel's exchange
/// boundaries: entry `b` is the serial cycle count through the boundary
/// command of exchange `b`. A pure function of the trace (no replay
/// draws), so both engines — and every thread — derive identical
/// readiness.
fn boundary_readiness(cfg: &ArchConfig, set: &ChannelSet, ch: usize) -> Vec<u64> {
    let xs = &set.exchanges[ch];
    if xs.is_empty() {
        return Vec::new();
    }
    let mut ready = vec![0u64; xs.len()];
    let mut scratch = SimResult::default();
    let mut next = 0usize;
    for (i, cmd) in set.traces[ch].cmds.iter().enumerate() {
        engine::step(cfg, cmd, &mut scratch);
        while next < xs.len() && xs[next].cmd == i {
            ready[next] = scratch.cycles;
            next += 1;
        }
    }
    // Boundaries past the trace end (defensive): ready at the full prefix.
    for r in ready.iter_mut().skip(next) {
        *r = scratch.cycles;
    }
    ready
}

/// Run every channel of `set` under `cfg.engine`, schedule the exchange
/// boundaries on the shared interconnect timeline, and compose the
/// totals (see the module docs for the exact composition rules).
pub fn run_channels(cfg: &ArchConfig, set: &ChannelSet) -> ChannelOutcome {
    let outs: Vec<sim::SimOutcome> = set.traces.iter().map(|t| sim::run(cfg, t)).collect();
    let readiness: Vec<Vec<u64>> =
        (0..set.width).map(|ch| boundary_readiness(cfg, set, ch)).collect();

    // Exchange schedule: boundary-major, channel-minor — the gather at
    // boundary b must drain before boundary b+1's shards queue up, and
    // within a boundary channels take the interconnect in index order.
    let mut timeline = IntervalTimeline::new();
    let mut exchanges = Vec::new();
    for b in 0..set.num_boundaries() {
        for ch in 0..set.width {
            let xp = set.exchanges[ch][b];
            let dur = dram::host_stream_cycles(&cfg.timing, xp.bytes);
            if dur == 0 {
                continue;
            }
            let ready = readiness[ch][b];
            let start = timeline.reserve(ready, dur);
            exchanges.push(ExchangeSpan {
                channel: ch,
                node: xp.node,
                bytes: xp.bytes,
                ready,
                start,
                end: start + dur,
            });
        }
    }
    let interconnect_busy = timeline.busy();
    let last_end = exchanges.iter().map(|x| x.end).max().unwrap_or(0);

    // Compose the per-channel results.
    let mut result = outs[0].result;
    for o in &outs[1..] {
        result.actions.add(&o.result.actions);
        result.cross_bank_cycles += o.result.cross_bank_cycles;
        result.near_bank_cycles += o.result.near_bank_cycles;
        result.gbcore_cycles += o.result.gbcore_cycles;
        result.host_cycles += o.result.host_cycles;
        result.replayed_cycles += o.result.replayed_cycles;
        result.escalated_cmds += o.result.escalated_cmds;
        result.open_row_hits += o.result.open_row_hits;
    }
    let exchange_bytes = set.total_exchange_bytes();
    result.actions.host_bytes += exchange_bytes;
    let compute_max = outs.iter().map(|o| o.result.cycles).max().unwrap_or(0);
    result.cycles = match cfg.engine {
        Engine::Analytic => compute_max + interconnect_busy,
        Engine::Event => compute_max.max(last_end),
    };

    let mut channel_cycles = vec![0u64; set.channels];
    for (ch, o) in outs.iter().enumerate() {
        channel_cycles[ch] = o.result.cycles;
    }
    ChannelOutcome {
        result,
        occupancy: outs[0].occupancy,
        report: ChannelReport {
            channels: set.channels,
            width: set.width,
            dead_channels: set.dead_channels,
            partition: set.partition,
            channel_cycles,
            interconnect_busy,
            exchange_bytes,
            exchanges,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::System;
    use crate::dataflow::CostModel;
    use crate::trace::partition::build_channels;
    use crate::workload::Workload;

    #[test]
    fn timeline_serializes_and_backfills() {
        let mut tl = IntervalTimeline::new();
        assert_eq!(tl.reserve(10, 5), 10); // [10,15)
        assert_eq!(tl.reserve(12, 5), 15, "overlap pushes to the free point"); // [15,20)
        assert_eq!(tl.reserve(0, 5), 0, "a leading gap backfills"); // [0,5)
        assert_eq!(tl.reserve(0, 6), 20, "a 6-cycle hole doesn't exist before 20");
        assert_eq!(tl.reserve(5, 5), 5, "the [5,10) hole fits exactly");
        assert_eq!(tl.busy(), 5 + 5 + 5 + 6 + 5);
        assert_eq!(tl.end(), 26);
        assert_eq!(tl.len(), 5);
        assert_eq!(tl.reserve(99, 0), 99, "zero-length reservations commit nothing");
        assert_eq!(tl.len(), 5);
    }

    #[test]
    fn timeline_reservations_never_overlap() {
        let mut tl = IntervalTimeline::new();
        let mut spans = Vec::new();
        let mut seed = 0x2545F491_4F6C_DD1Du64;
        for _ in 0..200 {
            seed ^= seed << 13;
            seed ^= seed >> 7;
            seed ^= seed << 17;
            let at = seed % 500;
            let dur = 1 + (seed >> 32) % 40;
            let start = tl.reserve(at, dur);
            assert!(start >= at);
            spans.push((start, start + dur));
        }
        spans.sort_unstable();
        for w in spans.windows(2) {
            assert!(w[0].1 <= w[1].0, "{:?} overlaps {:?}", w[0], w[1]);
        }
        assert_eq!(tl.busy(), spans.iter().map(|&(s, e)| e - s).sum::<u64>());
    }

    fn channel_cfg(sys: System, channels: usize, p: PartitionKind, e: Engine) -> ArchConfig {
        ArchConfig::system(sys, 32 * 1024, 256)
            .with_channels(channels)
            .with_partition(p)
            .with_engine(e)
    }

    #[test]
    fn data_partition_matches_single_channel() {
        for e in Engine::ALL {
            let c1 = channel_cfg(System::Fused4, 1, PartitionKind::Data, e);
            let g = Workload::Fig1.graph();
            let set1 = build_channels(&g, &c1, CostModel::default()).unwrap();
            let o1 = run_channels(&c1, &set1);
            let c4 = c1.clone().with_channels(4);
            let set4 = build_channels(&g, &c4, CostModel::default()).unwrap();
            let o4 = run_channels(&c4, &set4);
            assert_eq!(o1.result, o4.result, "batch-sharded single shot is channel 0 alone");
            assert_eq!(o4.report.interconnect_busy, 0);
            assert_eq!(o4.report.channel_cycles[1..], [0, 0, 0]);
        }
    }

    #[test]
    fn model_partition_preserves_engine_invariants() {
        let g = Workload::Fig1.graph();
        for channels in [2usize, 4] {
            let ca = channel_cfg(System::Fused4, channels, PartitionKind::Model, Engine::Analytic);
            let ce = ca.clone().with_engine(Engine::Event);
            let set = build_channels(&g, &ca, CostModel::default()).unwrap();
            let oa = run_channels(&ca, &set);
            let oe = run_channels(&ce, &set);
            assert_eq!(
                oa.result.actions, oe.result.actions,
                "actions engine-equal at {channels} channels"
            );
            assert!(oe.result.cycles <= oa.result.cycles, "event ≤ analytic");
            assert!(
                oe.result.cycles >= oe.report.interconnect_busy,
                "event ≥ interconnect busy"
            );
            assert_eq!(oa.report.exchanges, oe.report.exchanges, "exchange schedule engine-equal");
            assert!(oa.report.interconnect_busy > 0, "model partition moves shards");
        }
    }

    #[test]
    fn exchange_bytes_are_tallied_as_host_traffic_once() {
        let g = Workload::Fig1.graph();
        let cfg = channel_cfg(System::Fused4, 2, PartitionKind::Model, Engine::Analytic);
        let set = build_channels(&g, &cfg, CostModel::default()).unwrap();
        let o = run_channels(&cfg, &set);
        let per_channel: u64 = set
            .traces
            .iter()
            .map(|t| sim::run(&cfg, t).result.actions.host_bytes)
            .sum();
        assert_eq!(o.result.actions.host_bytes, per_channel + set.total_exchange_bytes());
    }
}
