//! Analytic expansion of byte streams into GDDR6 bank timing.
//!
//! The trace carries macro commands ("stream N bytes from this bank");
//! this module converts them to cycles under the bank's timing state
//! machine: a burst train of 32-B columns paced by `tCCD`, a pipeline
//! fill of `tCL`, and a `tRP + tRCD` row-open penalty per 2-KB row the
//! stream walks (plus `tRAS` enforcement on short rows). These formulas
//! price every row as a miss; when a command resumes the exact row its
//! banks left open, the engines' shared expansion
//! ([`crate::sim::engine`]) waives the leading re-open instead of
//! changing the per-stream arithmetic here (DESIGN.md §6.2).

use crate::config::{DramTiming, COL_BYTES, ROW_BYTES};

/// Cycles for a PIMcore to stream `bytes` from/to its local bank(s)
/// through the near-bank path: one column per cycle (the AiM internal
/// datapath is not throttled by the external `tCCD`), with row-open
/// penalties amortized per row.
pub fn near_bank_stream_cycles(t: &DramTiming, bytes: u64) -> u64 {
    if bytes == 0 {
        return 0;
    }
    let cols = bytes.div_ceil(COL_BYTES as u64);
    let rows = bytes.div_ceil(ROW_BYTES as u64);
    // Row open cost per row, with tRAS floor (a row must stay open tRAS).
    let per_row_cols = (ROW_BYTES / COL_BYTES) as u64;
    let open = t.row_open_cycles();
    let row_cost = open.max(t.t_ras.saturating_sub(per_row_cols));
    cols + rows * row_cost
}

/// Cycles for a sequential cross-bank transfer of `bytes` through the
/// GBUF: bank-at-a-time, `tCCD` column pacing plus the shared-bus hop,
/// one `tCL` fill per command, row opens per crossed row.
pub fn cross_bank_stream_cycles(t: &DramTiming, bytes: u64) -> u64 {
    if bytes == 0 {
        return 0;
    }
    let cols = bytes.div_ceil(COL_BYTES as u64);
    let rows = bytes.div_ceil(ROW_BYTES as u64);
    t.t_cl + cols * (t.t_ccd + t.t_bus_hop) + rows * t.row_open_cycles()
}

/// Cycles to broadcast `bytes` from the GBUF over the shared bus to all
/// PIMcores (single-ported SRAM: one 32-B word per cycle).
pub fn broadcast_cycles(bytes: u64) -> u64 {
    bytes.div_ceil(COL_BYTES as u64)
}

/// Cycles for operand-feed bytes served by the already-open row buffer:
/// one column per cycle, no row opens (the AiM MAC datapath consumes one
/// 256-bit column per cycle from the open row).
pub fn row_hit_stream_cycles(bytes: u64) -> u64 {
    bytes.div_ceil(COL_BYTES as u64)
}

/// Cycles for the host to move `bytes` over the off-chip interface.
/// GDDR6 at burst length 16 moves 32 B per two command cycles per device;
/// we charge `tCCD` per column like an ordinary read/write stream.
pub fn host_stream_cycles(t: &DramTiming, bytes: u64) -> u64 {
    if bytes == 0 {
        return 0;
    }
    let cols = bytes.div_ceil(COL_BYTES as u64);
    let rows = bytes.div_ceil(ROW_BYTES as u64);
    t.t_cl + cols * t.t_ccd + rows * t.row_open_cycles()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t() -> DramTiming {
        DramTiming::gddr6()
    }

    #[test]
    fn zero_bytes_zero_cycles() {
        assert_eq!(near_bank_stream_cycles(&t(), 0), 0);
        assert_eq!(cross_bank_stream_cycles(&t(), 0), 0);
        assert_eq!(broadcast_cycles(0), 0);
        assert_eq!(host_stream_cycles(&t(), 0), 0);
    }

    #[test]
    fn near_bank_is_one_col_per_cycle_plus_rows() {
        let tm = t();
        // One full row: 64 columns + one row open (tRAS floor saturates).
        let c = near_bank_stream_cycles(&tm, ROW_BYTES as u64);
        assert_eq!(c, 64 + tm.row_open_cycles().max(tm.t_ras.saturating_sub(64)));
    }

    #[test]
    fn cross_bank_slower_than_near_bank() {
        let tm = t();
        for bytes in [64u64, 2048, 1 << 20] {
            assert!(
                cross_bank_stream_cycles(&tm, bytes) > near_bank_stream_cycles(&tm, bytes),
                "cross must cost more at {bytes}B"
            );
        }
    }

    #[test]
    fn cycles_monotone_in_bytes() {
        let tm = t();
        let mut prev = 0;
        for kb in 1..64u64 {
            let c = cross_bank_stream_cycles(&tm, kb * 1024);
            assert!(c >= prev);
            prev = c;
        }
    }

    #[test]
    fn broadcast_is_bus_limited() {
        assert_eq!(broadcast_cycles(32), 1);
        assert_eq!(broadcast_cycles(33), 2);
        assert_eq!(broadcast_cycles(1024), 32);
    }

    #[test]
    fn large_stream_asymptote_matches_pacing() {
        // For large transfers the per-column pacing dominates: near-bank
        // ~1.75 cyc/col with row costs, cross-bank ~(tCCD+hop) + rows.
        let tm = t();
        let bytes = 32u64 << 20;
        let cols = bytes / 32;
        let near = near_bank_stream_cycles(&tm, bytes) as f64 / cols as f64;
        let cross = cross_bank_stream_cycles(&tm, bytes) as f64 / cols as f64;
        assert!((1.0..2.5).contains(&near), "near {near}");
        assert!((4.0..6.0).contains(&cross), "cross {cross}");
    }
}
