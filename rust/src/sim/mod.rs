//! Trace-driven GDDR6-PIM channel simulator — the Ramulator2-extension box
//! of the paper's profiling framework (Fig. 4, §V-A1).
//!
//! The engine walks a Table-I command trace and reports **memory-system
//! cycles**: the occupancy of banks, the shared internal bus / GBUF port,
//! and the PIM transfer paths. PIMcore arithmetic overlaps with operand
//! streaming (near-bank MACs run at bank-read bandwidth, as in AiM [3,4]),
//! so a command's duration is bounded by its *data movement*, not its
//! FLOPs — matching the paper's use of Ramulator2 cycle counts as the
//! performance metric while studying data-transfer optimization.
//!
//! Timing rules per command (see [`dram`] for the bank expansion):
//!
//! * near-bank streams (`PIMcore_CMP` operand reads/writes, `PIM_BK2LBUF`,
//!   `PIM_LBUF2BK`) run on all PIMcores concurrently, one 32-B column per
//!   cycle per core, paying a row-open penalty every crossed DRAM row;
//! * cross-bank transfers (`PIM_BK2GBUF`, `PIM_GBUF2BK`) are sequential,
//!   bank-at-a-time, and additionally pay the shared-bus hop per column
//!   (the AiM GBUF conflict-avoidance rule, §III-B);
//! * GBUF broadcasts share the single bus: one column per cycle, serial;
//! * `GBcore_CMP` streams operands through the GBUF port (16 elem/cycle);
//! * host I/O crosses the off-chip interface at the external burst rate
//!   and, with `ArchConfig::host_residency` (the default), also streams
//!   through its destination banks — so host phases contend with PIM
//!   traffic for banks and tFAW/tRRD activation windows.
//!
//! Two engines turn those per-command costs into total cycles, selected
//! by [`crate::config::Engine`] on the `ArchConfig` (DESIGN.md §6):
//!
//! * [`engine`] — the **analytic** engine: commands execute back-to-back
//!   and total cycles are the serial sum. Fast and conservative.
//! * [`event`] — the **event-driven** engine: a ready-heap list
//!   scheduler over per-resource *interval timelines* (per bank, per
//!   PIMcore, the shared bus / GBUF port, the GBcore, the host
//!   interface, the contended command bus, and a tFAW/tRRD activation
//!   window per bank group), with command ordering derived from the
//!   trace's per-node data-flow annotations. Independent commands
//!   overlap, short commands back-fill idle gaps, cross-bank transfers
//!   reserve per-bank slices that can slide around busy banks
//!   (`ArchConfig::slice_pipelining`), host I/O is metered per bank by
//!   the trace's row map, and bank writes charge `tWR` recovery; the
//!   result adds a per-resource [`ResourceOccupancy`] breakdown.
//!
//! Both engines tally identical [`ActionCounts`] for the energy model,
//! so energy reports never depend on engine choice.

pub mod channel;
pub mod dram;
pub mod engine;
pub mod event;

pub use channel::{ChannelOutcome, ChannelReport, ExchangeSpan, IntervalTimeline};
pub use engine::{simulate, SimResult};
pub use event::{EventReport, ResourceOccupancy};

use crate::config::{ArchConfig, Engine};
use crate::trace::Trace;

/// Result of running a trace under the engine `cfg.engine` selects:
/// the [`SimResult`], plus the per-resource occupancy breakdown when the
/// event engine produced one.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SimOutcome {
    /// Cycles, action counts, and per-path breakdowns.
    pub result: SimResult,
    /// Per-resource busy-cycle breakdown (event engine only).
    pub occupancy: Option<ResourceOccupancy>,
}

/// Simulate a trace with the engine selected by `cfg.engine`.
pub fn run(cfg: &ArchConfig, trace: &Trace) -> SimOutcome {
    match cfg.engine {
        Engine::Analytic => SimOutcome { result: engine::simulate(cfg, trace), occupancy: None },
        Engine::Event => {
            let r = event::simulate(cfg, trace);
            SimOutcome { result: r.result, occupancy: Some(r.occupancy) }
        }
    }
}

/// Architecture-event tallies consumed by [`crate::energy`].
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ActionCounts {
    /// DRAM row activations (ACT+PRE pairs).
    pub row_activations: u64,
    /// Near-bank column reads, in bytes (PIMcore←local bank).
    pub near_col_read_bytes: u64,
    /// Near-bank column writes, in bytes (PIMcore→local bank).
    pub near_col_write_bytes: u64,
    /// Near-bank operand-feed bytes served by the open row buffer
    /// (column-mux energy only; see DESIGN.md §5).
    pub near_col_hit_bytes: u64,
    /// Cross-bank column reads, in bytes (bank→GBUF via the bus).
    pub cross_col_read_bytes: u64,
    /// Cross-bank column writes, in bytes (GBUF→bank via the bus).
    pub cross_col_write_bytes: u64,
    /// Bytes that crossed the shared internal bus (cross-bank + broadcast).
    pub bus_bytes: u64,
    /// GBUF SRAM reads, bytes.
    pub gbuf_read_bytes: u64,
    /// GBUF SRAM writes, bytes.
    pub gbuf_write_bytes: u64,
    /// LBUF SRAM reads, bytes.
    pub lbuf_read_bytes: u64,
    /// LBUF SRAM writes, bytes.
    pub lbuf_write_bytes: u64,
    /// MACs retired across all PIMcores.
    pub pimcore_macs: u64,
    /// Element-wise ops retired across all PIMcores.
    pub pimcore_eltwise: u64,
    /// Element-wise ops retired on the channel-level GBcore.
    pub gbcore_eltwise: u64,
    /// Off-chip host interface bytes.
    pub host_bytes: u64,
}

impl ActionCounts {
    /// Element-wise accumulate (used when merging per-step results).
    pub fn add(&mut self, o: &ActionCounts) {
        self.row_activations += o.row_activations;
        self.near_col_read_bytes += o.near_col_read_bytes;
        self.near_col_write_bytes += o.near_col_write_bytes;
        self.near_col_hit_bytes += o.near_col_hit_bytes;
        self.cross_col_read_bytes += o.cross_col_read_bytes;
        self.cross_col_write_bytes += o.cross_col_write_bytes;
        self.bus_bytes += o.bus_bytes;
        self.gbuf_read_bytes += o.gbuf_read_bytes;
        self.gbuf_write_bytes += o.gbuf_write_bytes;
        self.lbuf_read_bytes += o.lbuf_read_bytes;
        self.lbuf_write_bytes += o.lbuf_write_bytes;
        self.pimcore_macs += o.pimcore_macs;
        self.pimcore_eltwise += o.pimcore_eltwise;
        self.gbcore_eltwise += o.gbcore_eltwise;
        self.host_bytes += o.host_bytes;
    }

    /// Total DRAM bytes touched (near + cross, read + write).
    pub fn dram_bytes(&self) -> u64 {
        self.near_col_read_bytes
            + self.near_col_write_bytes
            + self.cross_col_read_bytes
            + self.cross_col_write_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_dispatches_on_engine() {
        use crate::trace::{CmdKind, RowMap};
        let mut t = Trace::default();
        t.push(0, CmdKind::Bk2Gbuf { bytes: 2048, rows: RowMap::EMPTY });
        let cfg = ArchConfig::baseline();
        let analytic = run(&cfg, &t);
        assert!(analytic.occupancy.is_none());
        assert_eq!(analytic.result, engine::simulate(&cfg, &t));
        let ev = run(&cfg.clone().with_engine(Engine::Event), &t);
        let occ = ev.occupancy.expect("event engine reports occupancy");
        assert_eq!(occ.makespan, ev.result.cycles);
        assert_eq!(ev.result.actions, analytic.result.actions);
    }

    #[test]
    fn action_counts_add() {
        let mut a = ActionCounts { row_activations: 1, pimcore_macs: 10, ..Default::default() };
        let b = ActionCounts { row_activations: 2, bus_bytes: 5, ..Default::default() };
        a.add(&b);
        assert_eq!(a.row_activations, 3);
        assert_eq!(a.pimcore_macs, 10);
        assert_eq!(a.bus_bytes, 5);
    }
}
