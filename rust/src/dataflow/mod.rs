//! Dataflow mapping: how CNN layers are assigned to PIMcores and how data
//! moves through LBUF/GBUF — the paper's §IV.
//!
//! * [`tiling`] — exact spatial-tile / halo math for fused kernels.
//! * [`fused`] — the fused-kernel partitioner (which layer ranges fuse).
//! * [`plan`] — builds a [`Plan`]: the per-layer strategy sequence that the
//!   trace generator ([`crate::trace::gen`]) turns into Table-I commands.
//!
//! ## Cost model
//!
//! The paper evaluates *memory-system cycles* (Ramulator2's metric, §V-A1):
//! the occupancy of banks, the shared internal bus, and the buffers.
//! PIMcore arithmetic overlaps with operand streaming (near-bank MAC runs
//! at bank-read bandwidth, as in AiM/Newton), so what the simulator times
//! is data movement. How much data moves depends on *reuse*, and reuse
//! depends on buffer sizes. The exact loop nests of the paper's in-house
//! trace generator are not published, so [`CostModel`] expresses reuse as
//! explicitly-documented saturating interpolations with named calibration
//! constants; EXPERIMENTS.md records the calibrated values and the
//! paper-vs-measured outcome for every figure. The *shapes* (who wins,
//! where gains saturate, which buffer matters for which dataflow) emerge
//! from the structure, not the constants.

pub mod fused;
pub mod tiling;

use crate::cnn::{Graph, NodeId};
use crate::config::{ArchConfig, Dataflow};

/// One scheduling step of the hybrid PIMfused dataflow (Fig. 3(c)).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PlanStep {
    /// Execute nodes `[start, end]` as one fused kernel, spatially tiled
    /// `grid.0 × grid.1` across PIMcores.
    Fused { start: NodeId, end: NodeId, grid: (usize, usize) },
    /// Execute one layer in the conventional layer-by-layer dataflow
    /// (cout-partitioned on PIMcores, or on the GBcore for non-MAC ops).
    Lbl { node: NodeId },
}

/// The full execution plan for a workload on an architecture.
#[derive(Debug, Clone)]
pub struct Plan {
    /// Scheduling steps in execution order.
    pub steps: Vec<PlanStep>,
}

impl Plan {
    /// Number of fused kernels in the plan.
    pub fn num_fused_kernels(&self) -> usize {
        self.steps
            .iter()
            .filter(|s| matches!(s, PlanStep::Fused { .. }))
            .count()
    }

    /// Node ids executed under the fused-layer dataflow.
    pub fn fused_nodes(&self) -> Vec<NodeId> {
        let mut v = Vec::new();
        for s in &self.steps {
            if let PlanStep::Fused { start, end, .. } = s {
                v.extend(*start..=*end);
            }
        }
        v
    }

    /// Every node id appears exactly once across the plan, in order.
    pub fn validate(&self, g: &Graph) -> Result<(), String> {
        let mut expect = 1; // node 0 is the input
        for s in &self.steps {
            match *s {
                PlanStep::Fused { start, end, grid } => {
                    if start != expect {
                        return Err(format!("fused step starts at {start}, expected {expect}"));
                    }
                    if end < start || end >= g.nodes.len() {
                        return Err(format!("bad fused range [{start},{end}]"));
                    }
                    if grid.0 == 0 || grid.1 == 0 {
                        return Err("empty tile grid".into());
                    }
                    expect = end + 1;
                }
                PlanStep::Lbl { node } => {
                    if node != expect {
                        return Err(format!("lbl step at {node}, expected {expect}"));
                    }
                    expect = node + 1;
                }
            }
        }
        if expect != g.nodes.len() {
            return Err(format!("plan covers {} of {} nodes", expect - 1, g.nodes.len() - 1));
        }
        Ok(())
    }
}

/// Build the execution plan for a graph on an architecture (§IV):
/// layer-by-layer systems map every layer individually; PIMfused systems
/// fuse maximal shallow segments (subject to the tile-divisibility rule of
/// §V-A3) and fall back to layer-by-layer for the rest.
pub fn plan(g: &Graph, cfg: &ArchConfig) -> Plan {
    match cfg.dataflow {
        Dataflow::LayerByLayer => Plan {
            steps: (1..g.nodes.len()).map(|n| PlanStep::Lbl { node: n }).collect(),
        },
        Dataflow::PimFused { tiles_x, tiles_y } => {
            fused::plan_fused(g, tiles_y, tiles_x, fused::MAX_FUSE_DEPTH)
        }
    }
}

/// Calibration constants for the reuse interpolations (see module docs).
///
/// The central quantity is the **DRAM-feed fraction** φ ∈ (0, 1]: the
/// share of a PIMcore's operand feed that must come from its DRAM bank
/// (occupying memory cycles) rather than from a buffer. φ follows a
/// harmonic saturation `φ = 1/(1 + B/Bsat)` in the relevant buffer size
/// `B` — reuse grows with buffer capacity and saturates, matching the
/// paper's Takeaway 2 (small LBUFs capture most of the benefit) — with
/// `Bsat` scaled by the layer's working set (deeper layers need
/// proportionally more buffer, which is why ResNet18_Full improves less
/// than First8Layers in Fig. 6). Calibrated values are recorded in
/// EXPERIMENTS.md §Calibration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CostModel {
    /// Implicit per-PIMcore register bytes available even with LBUF = 0
    /// (AiM PIMcores have a small register file; FIM has 4-32 registers).
    pub reg_bytes: usize,
    /// LBUF bytes at which the layer-by-layer *weight feed* (per-pixel
    /// GEMV streaming from the local bank, AiM-style) is half suppressed,
    /// for a 64-output-channel layer.
    pub lbl_feed_lsat: f64,
    /// LBUF bytes at which the fused-dataflow *activation feed* is half
    /// suppressed, for a 64-channel layer.
    pub fused_act_lsat: f64,
    /// GBUF bytes at which fused weight *re-broadcasts* (one pass per
    /// output pixel at GBUF→0) are half suppressed.
    pub fused_bcast_gsat: f64,
    /// Fraction of a GBUF-broadcast byte's bus slot consumed when all
    /// PIMcores snoop the broadcast (1.0 = full serial slot).
    pub broadcast_pace: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        Self {
            reg_bytes: 32,
            lbl_feed_lsat: 96.0,
            fused_act_lsat: 96.0,
            fused_bcast_gsat: 1024.0,
            broadcast_pace: 1.0,
        }
    }
}

impl CostModel {
    fn phi(buf: usize, floor: usize, sat: f64) -> f64 {
        let b = buf.max(floor) as f64;
        1.0 / (1.0 + b / sat)
    }

    /// Layer-by-layer DRAM-feed fraction: the share of the per-MAC weight
    /// feed that streams from the bank (row-buffer hits) instead of the
    /// LBUF. `Lsat` scales with `cout/64`: deeper layers hold bigger
    /// weight working sets, so the same LBUF suppresses less (Fig. 6's
    /// smaller full-network gains).
    pub fn lbl_feed_phi(&self, cout: usize, lbuf: usize) -> f64 {
        let sat = self.lbl_feed_lsat * (cout as f64 / 64.0).max(0.25);
        Self::phi(lbuf, self.reg_bytes, sat)
    }

    /// Fused-dataflow activation re-read fraction (per weight-broadcast
    /// pass) surviving an LBUF of the given size.
    pub fn fused_act_phi(&self, cin: usize, lbuf: usize) -> f64 {
        let sat = self.fused_act_lsat * (cin as f64 / 64.0).max(0.25);
        Self::phi(lbuf, self.reg_bytes, sat)
    }

    /// Fused weight broadcast restream factor: with tiny buffers, the
    /// per-pixel GEMV structure re-broadcasts the layer's weights once per
    /// output pixel. Residency on *either* side suppresses the repeats —
    /// a weight-resident GBUF lets one broadcast serve many pixels
    /// (Takeaway 1), and an activation-resident LBUF lets one broadcast
    /// chunk be applied across the cached window before the next pass
    /// (Takeaway 2) — hence the product of the two survival fractions,
    /// which is also why combining both buffers beats growing either
    /// alone (Takeaway 3).
    ///
    /// Both saturation points scale with the layer's working sets: the
    /// GBUF must cover more of a bigger weight tensor (`w_bytes`, ref. the
    /// 64→64 3×3 conv's 72 KB) and the LBUF a wider activation window
    /// (`cin`), so deeper fused kernels benefit less — the effect that
    /// keeps Fused4's third fused kernel (stage 3, 1.2 MB weights) from
    /// being free and preserves Fused16's overall performance lead.
    pub fn fused_bcast_restream(
        &self,
        tile_pixels: usize,
        gbuf: usize,
        lbuf: usize,
        w_bytes: usize,
        cin: usize,
    ) -> f64 {
        const W_REF: f64 = 73_728.0; // 64→64 3×3 conv weights, bytes
        // Square-root scaling: a 16x weight tensor needs ~4x the GBUF for
        // the same suppression (chunked residency is partially effective).
        let gsat = self.fused_bcast_gsat * (w_bytes as f64 / W_REF).max(0.125).sqrt();
        let g = 1.0 / (1.0 + gbuf.max(512) as f64 / gsat);
        let lsat = self.fused_act_lsat * (cin as f64 / 64.0).max(0.25);
        let l = 1.0 / (1.0 + lbuf.max(self.reg_bytes) as f64 / lsat);
        1.0 + (tile_pixels.max(1) - 1) as f64 * g * l
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::resnet::{resnet18, resnet18_first8};
    use crate::config::System;

    #[test]
    fn lbl_plan_covers_all_layers() {
        let g = resnet18();
        let cfg = ArchConfig::baseline();
        let p = plan(&g, &cfg);
        p.validate(&g).unwrap();
        assert_eq!(p.num_fused_kernels(), 0);
        assert_eq!(p.steps.len(), g.num_layers());
    }

    #[test]
    fn fused4_plan_has_three_kernels_of_8_7_7() {
        // §V-A3: Fused4 fuses 8 + 7 + 7 layers; the rest run layer-by-layer.
        let g = resnet18();
        let cfg = ArchConfig::system(System::Fused4, 2048, 0);
        let p = plan(&g, &cfg);
        p.validate(&g).unwrap();
        let fused: Vec<(usize, usize)> = p
            .steps
            .iter()
            .filter_map(|s| match *s {
                PlanStep::Fused { start, end, .. } => Some((start, end)),
                _ => None,
            })
            .collect();
        assert_eq!(fused, vec![(1, 8), (9, 15), (16, 22)]);
    }

    #[test]
    fn fused16_plan_has_two_kernels_of_8_7() {
        // §V-A3: Fused16 fuses 8 + 7 (stage 3's 14x14 maps don't tile 4x4
        // evenly), the rest layer-by-layer.
        let g = resnet18();
        let cfg = ArchConfig::system(System::Fused16, 2048, 0);
        let p = plan(&g, &cfg);
        p.validate(&g).unwrap();
        let fused: Vec<(usize, usize)> = p
            .steps
            .iter()
            .filter_map(|s| match *s {
                PlanStep::Fused { start, end, .. } => Some((start, end)),
                _ => None,
            })
            .collect();
        assert_eq!(fused, vec![(1, 8), (9, 15)]);
    }

    #[test]
    fn first8_fuses_entirely_on_both() {
        let g = resnet18_first8();
        for sys in [System::Fused16, System::Fused4] {
            let cfg = ArchConfig::system(sys, 2048, 0);
            let p = plan(&g, &cfg);
            p.validate(&g).unwrap();
            assert_eq!(p.num_fused_kernels(), 1);
            assert_eq!(p.fused_nodes(), (1..=8).collect::<Vec<_>>());
        }
    }

    #[test]
    fn feed_fractions_monotone_in_buffers() {
        let m = CostModel::default();
        let p0 = m.lbl_feed_phi(64, 0);
        let p256 = m.lbl_feed_phi(64, 256);
        let p512 = m.lbl_feed_phi(64, 512);
        assert!(p0 > p256 && p256 > p512 && p512 > 0.0);
        assert!(p0 <= 1.0);
        // Deeper layers (bigger cout) need more LBUF for the same cut.
        assert!(m.lbl_feed_phi(512, 256) > m.lbl_feed_phi(64, 256));
        // Fused activation re-reads saturate toward zero.
        assert!(m.fused_act_phi(64, 0) > m.fused_act_phi(64, 256));
        assert!(m.fused_act_phi(64, 100 * 1024) < 0.01);
    }

    #[test]
    fn bcast_restream_shrinks_with_either_buffer() {
        let m = CostModel::default();
        let w = 73_728;
        let r2k = m.fused_bcast_restream(196, 2048, 0, w, 64);
        let r32k = m.fused_bcast_restream(196, 32 * 1024, 0, w, 64);
        let r2k_l256 = m.fused_bcast_restream(196, 2048, 256, w, 64);
        assert!(r2k > r32k && r32k >= 1.0);
        assert!(r2k > r2k_l256, "LBUF must also suppress re-broadcasts");
        // Bigger tiles (Fused4's 28x28 vs Fused16's 14x14) restream more:
        // the "lower PIMcore parallelism" penalty of §V-B.
        assert!(m.fused_bcast_restream(784, 2048, 0, w, 64) > r2k);
        // Deeper layers (16x the weights, 4x the cin) keep restreaming at
        // buffer sizes that fully suppress shallow layers.
        let deep = m.fused_bcast_restream(49, 32 * 1024, 256, 16 * w, 256);
        let shallow = m.fused_bcast_restream(49, 32 * 1024, 256, w, 64);
        assert!(deep > shallow);
    }
}
