//! Spatial tiling and halo (receptive-field) back-propagation for
//! fused-layer kernels — the math behind Fig. 1(b) and the §V-D cost
//! statement (fusing ResNet18's first 8 layers into 4 tiles costs +18.2%
//! data replication and +17.3% redundant computation).
//!
//! Given a fused segment (a contiguous node-id range whose only externally
//! consumed value is the last node's output) and a spatial tile of that
//! output, [`demand_for_tile`] walks the segment backwards and computes,
//! for every node, the exact output region the tile requires — growing by
//! the layer's window geometry and clamping at feature-map borders.

use crate::cnn::{Graph, NodeId, Op};

/// Half-open spatial rectangle over a feature map: `x` indexes width,
/// `y` height. Channels are never tiled by the PIMfused dataflow (§IV).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Rect {
    /// Inclusive left edge.
    pub x0: usize,
    /// Inclusive top edge.
    pub y0: usize,
    /// Exclusive right edge.
    pub x1: usize,
    /// Exclusive bottom edge.
    pub y1: usize,
}

impl Rect {
    /// The rectangle `[x0, x1) × [y0, y1)`.
    pub fn new(x0: usize, y0: usize, x1: usize, y1: usize) -> Self {
        debug_assert!(x0 <= x1 && y0 <= y1);
        Self { x0, y0, x1, y1 }
    }

    /// The full extent of an `h × w` feature map.
    pub fn full(h: usize, w: usize) -> Self {
        Self::new(0, 0, w, h)
    }

    /// Width in pixels.
    pub fn w(&self) -> usize {
        self.x1 - self.x0
    }

    /// Height in pixels.
    pub fn h(&self) -> usize {
        self.y1 - self.y0
    }

    /// Area in pixels.
    pub fn pixels(&self) -> usize {
        self.w() * self.h()
    }

    /// Whether the rect covers no pixels.
    pub fn is_empty(&self) -> bool {
        self.pixels() == 0
    }

    /// Smallest rect covering both.
    pub fn union(&self, o: &Rect) -> Rect {
        if self.is_empty() {
            return *o;
        }
        if o.is_empty() {
            return *self;
        }
        Rect::new(
            self.x0.min(o.x0),
            self.y0.min(o.y0),
            self.x1.max(o.x1),
            self.y1.max(o.y1),
        )
    }

    /// Whether `o` lies entirely inside this rect (empty rects always do).
    pub fn contains(&self, o: &Rect) -> bool {
        o.is_empty() || (self.x0 <= o.x0 && self.y0 <= o.y0 && self.x1 >= o.x1 && self.y1 >= o.y1)
    }

    /// Input region a `(k, stride, pad)` window layer needs to produce
    /// this output region, clamped to an `h × w` input map.
    pub fn window_demand(&self, k: usize, stride: usize, pad: usize, in_h: usize, in_w: usize) -> Rect {
        if self.is_empty() {
            return Rect::new(0, 0, 0, 0);
        }
        let lo = |o: usize| (o * stride).saturating_sub(pad);
        let hi = |o: usize, lim: usize| ((o - 1) * stride + k).saturating_sub(pad).min(lim);
        Rect::new(
            lo(self.x0),
            lo(self.y0),
            hi(self.x1, in_w),
            hi(self.y1, in_h),
        )
    }
}

/// Even spatial partition of an `h × w` map into a `ty × tx` grid.
/// Remainder pixels go to the last tile in each dimension.
pub fn tile_grid(h: usize, w: usize, ty: usize, tx: usize) -> Vec<Rect> {
    assert!(tx > 0 && ty > 0 && tx <= w && ty <= h, "grid {ty}x{tx} too fine for {h}x{w}");
    let (bh, bw) = (h / ty, w / tx);
    let mut out = Vec::with_capacity(tx * ty);
    for j in 0..ty {
        for i in 0..tx {
            let y1 = if j + 1 == ty { h } else { (j + 1) * bh };
            let x1 = if i + 1 == tx { w } else { (i + 1) * bw };
            out.push(Rect::new(i * bw, j * bh, x1, y1));
        }
    }
    out
}

/// A tiny node-id → rect map. Fused segments hold ≲10 entries, where a
/// sorted `Vec` beats a `HashMap` by ~2× on the trace-generation hot path
/// (EXPERIMENTS.md §Perf iteration 1).
#[derive(Debug, Clone, Default)]
pub struct DemandMap {
    entries: Vec<(NodeId, Rect)>,
}

impl DemandMap {
    /// The demand rect recorded for `id`, if any.
    pub fn get(&self, id: &NodeId) -> Option<&Rect> {
        self.entries
            .binary_search_by_key(id, |e| e.0)
            .ok()
            .map(|i| &self.entries[i].1)
    }

    /// Union `r` into the entry for `id` (inserting if absent).
    pub fn union_insert(&mut self, id: NodeId, r: Rect) {
        match self.entries.binary_search_by_key(&id, |e| e.0) {
            Ok(i) => self.entries[i].1 = self.entries[i].1.union(&r),
            Err(i) => self.entries.insert(i, (id, r)),
        }
    }

    /// All `(node, rect)` entries in ascending node-id order.
    pub fn iter(&self) -> impl Iterator<Item = (&NodeId, &Rect)> {
        self.entries.iter().map(|(k, v)| (k, v))
    }

    /// All node ids with an entry, ascending.
    pub fn keys(&self) -> impl Iterator<Item = NodeId> + '_ {
        self.entries.iter().map(|(k, _)| *k)
    }

    /// Number of entries.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Whether the map holds no entries.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

impl std::ops::Index<&NodeId> for DemandMap {
    type Output = Rect;
    fn index(&self, id: &NodeId) -> &Rect {
        self.get(id).unwrap_or_else(|| panic!("no demand for node {id}"))
    }
}

/// Demanded output region per node for one output tile of a fused segment.
#[derive(Debug, Clone)]
pub struct TileDemand {
    /// The tile of the segment's final output this demand serves.
    pub out_rect: Rect,
    /// Demanded output region of every node in `[seg_start, seg_end]`,
    /// keyed by node id.
    pub per_node: DemandMap,
    /// Demanded region of each *external* producer feeding the segment
    /// (the data this tile must fetch from banks — includes replication).
    pub external: DemandMap,
}

/// Back-propagate an output tile's demand through a fused segment.
///
/// `seg` is the inclusive node-id range `[start, end]`; the caller must
/// have verified it is a valid fusion segment (see
/// [`crate::dataflow::fused::segment_is_fusable`]).
pub fn demand_for_tile(g: &Graph, start: NodeId, end: NodeId, out_rect: Rect) -> TileDemand {
    let mut per_node = DemandMap::default();
    let mut external = DemandMap::default();
    per_node.union_insert(end, out_rect);

    // Node ids are topological, so one reverse sweep settles all demands.
    for id in (start..=end).rev() {
        let Some(&dem) = per_node.get(&id) else { continue };
        let node = &g.nodes[id];
        let in_demand: Vec<(NodeId, Rect)> = match node.op {
            Op::Input => vec![],
            Op::Conv { k, stride, pad, .. } | Op::Pool { k, stride, pad, .. } => {
                let p = &g.nodes[node.inputs[0]];
                vec![(
                    node.inputs[0],
                    dem.window_demand(k, stride, pad, p.shape.h, p.shape.w),
                )]
            }
            Op::GlobalAvgPool | Op::Fc { .. } => {
                // Spatial collapse: needs the producer's full map.
                let p = &g.nodes[node.inputs[0]];
                vec![(node.inputs[0], Rect::full(p.shape.h, p.shape.w))]
            }
            Op::AddRelu => node.inputs.iter().map(|&i| (i, dem)).collect(),
        };
        for (pid, r) in in_demand {
            let slot = if pid >= start { &mut per_node } else { &mut external };
            slot.union_insert(pid, r);
        }
    }
    TileDemand { out_rect, per_node, external }
}

/// Replication / redundancy statistics for tiling a segment into a grid
/// (the quantities reported in §I / §V-D).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FusionCost {
    /// Σ tiled intermediate+input elements / Σ untiled elements.
    /// 1.182 would be the paper's "+18.2% data replication".
    pub replication: f64,
    /// Σ tiled MACs / untiled MACs ("redundant computation", paper +17.3%).
    pub redundant_macs: f64,
    /// Same ratio for element-wise work (pool/BN/ReLU/add).
    pub redundant_eltwise: f64,
    /// Largest per-tile working set of any single node's demanded region,
    /// in elements (drives LBUF sizing).
    pub max_tile_node_elems: usize,
}

/// All per-tile demands for a segment under a `ty × tx` output grid.
pub fn tile_segment(g: &Graph, start: NodeId, end: NodeId, ty: usize, tx: usize) -> Vec<TileDemand> {
    let out = g.nodes[end].shape;
    tile_grid(out.h, out.w, ty, tx)
        .into_iter()
        .map(|r| demand_for_tile(g, start, end, r))
        .collect()
}

/// Compute [`FusionCost`] for a tiled segment.
pub fn fusion_cost(g: &Graph, start: NodeId, end: NodeId, tiles: &[TileDemand]) -> FusionCost {
    let mut full_elems = 0usize;
    let mut tiled_elems = 0usize;
    let mut full_macs = 0usize;
    let mut tiled_macs = 0usize;
    let mut full_elt = 0usize;
    let mut tiled_elt = 0usize;
    let mut max_tile_node_elems = 0usize;

    // Intermediate + output fmaps of the segment itself.
    for id in start..=end {
        let n = &g.nodes[id];
        full_elems += n.shape.elems();
        full_macs += n.macs();
        full_elt += n.eltwise_ops();
        let (pix_full, mac_per_pix, elt_per_pix) = (
            n.shape.h * n.shape.w,
            n.macs() as f64 / (n.shape.h * n.shape.w) as f64,
            n.eltwise_ops() as f64 / (n.shape.h * n.shape.w) as f64,
        );
        let _ = pix_full;
        for t in tiles {
            if let Some(r) = t.per_node.get(&id) {
                let e = r.pixels() * n.shape.c;
                tiled_elems += e;
                max_tile_node_elems = max_tile_node_elems.max(e);
                tiled_macs += (r.pixels() as f64 * mac_per_pix).round() as usize;
                tiled_elt += (r.pixels() as f64 * elt_per_pix).round() as usize;
            }
        }
    }
    // External inputs the tiles must fetch (replicated halo reads).
    let mut ext_ids: Vec<NodeId> = tiles
        .iter()
        .flat_map(|t| t.external.keys())
        .collect();
    ext_ids.sort_unstable();
    ext_ids.dedup();
    for pid in ext_ids {
        let p = &g.nodes[pid];
        full_elems += p.shape.elems();
        for t in tiles {
            if let Some(r) = t.external.get(&pid) {
                let e = r.pixels() * p.shape.c;
                tiled_elems += e;
                max_tile_node_elems = max_tile_node_elems.max(e);
            }
        }
    }

    FusionCost {
        replication: tiled_elems as f64 / full_elems.max(1) as f64,
        redundant_macs: tiled_macs as f64 / full_macs.max(1) as f64,
        redundant_eltwise: if full_elt == 0 {
            1.0
        } else {
            tiled_elt as f64 / full_elt as f64
        },
        max_tile_node_elems,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::resnet::{fig1_example, resnet18_first8};
    use crate::cnn::Shape;
    use crate::util::prop::{check_no_shrink, Gen};

    #[test]
    fn rect_window_demand_same_pad_conv() {
        // 3x3 stride-1 pad-1 on a 8x8 map: interior tile grows by 1/side.
        let r = Rect::new(2, 2, 4, 4);
        let d = r.window_demand(3, 1, 1, 8, 8);
        assert_eq!(d, Rect::new(1, 1, 5, 5));
        // Corner tile clamps at the border.
        let c = Rect::new(0, 0, 2, 2).window_demand(3, 1, 1, 8, 8);
        assert_eq!(c, Rect::new(0, 0, 3, 3));
    }

    #[test]
    fn rect_window_demand_strided() {
        // 2x2 stride-2 pool: no halo, exact 2x scaling.
        let d = Rect::new(1, 1, 3, 3).window_demand(2, 2, 0, 8, 8);
        assert_eq!(d, Rect::new(2, 2, 6, 6));
    }

    #[test]
    fn tile_grid_partitions_exactly() {
        let tiles = tile_grid(56, 56, 2, 2);
        assert_eq!(tiles.len(), 4);
        let total: usize = tiles.iter().map(Rect::pixels).sum();
        assert_eq!(total, 56 * 56);
        assert_eq!(tiles[3], Rect::new(28, 28, 56, 56));
        // Uneven split: remainder goes to the last tile.
        let t = tile_grid(7, 7, 2, 2);
        assert_eq!(t.iter().map(Rect::pixels).sum::<usize>(), 49);
        assert_eq!(t[3], Rect::new(3, 3, 7, 7));
    }

    #[test]
    fn demand_grows_through_two_convs() {
        // Fig. 1(b): two fused 3x3 convs; interior tile halo = 2 per side.
        let g = fig1_example();
        let d = demand_for_tile(&g, 1, 2, Rect::new(4, 4, 8, 8));
        assert_eq!(d.per_node[&2], Rect::new(4, 4, 8, 8));
        assert_eq!(d.per_node[&1], Rect::new(3, 3, 9, 9));
        assert_eq!(d.external[&0], Rect::new(2, 2, 10, 10));
    }

    #[test]
    fn residual_demand_is_union_of_branches() {
        // Through first8 the skip edge (maxpool out -> add) demands a
        // smaller region than the conv branch; union must win.
        let g = resnet18_first8();
        let tiles = tile_segment(&g, 1, 8, 2, 2);
        for t in &tiles {
            // maxpool output feeds conv (halo-grown) and both adds.
            let pool = t.per_node[&2];
            let conv_in_demand = t.per_node[&3].window_demand(3, 1, 1, 56, 56);
            assert!(pool.contains(&conv_in_demand));
        }
    }

    #[test]
    fn paper_v_d_first8_fusion_cost() {
        // §V-D: first 8 layers into 4 tiles → +18.2% replication,
        // +17.3% redundant computation, per the paper. Our exact halo math
        // lands within a couple of points of those (the paper does not
        // spell out whether the network input map is included; we include
        // it). Assert the reproduced band.
        let g = resnet18_first8();
        let tiles = tile_segment(&g, 1, 8, 2, 2);
        let c = fusion_cost(&g, 1, 8, &tiles);
        assert!(
            (1.12..1.30).contains(&c.replication),
            "replication {:.3} outside band",
            c.replication
        );
        assert!(
            (1.10..1.25).contains(&c.redundant_macs),
            "redundant macs {:.3} outside band",
            c.redundant_macs
        );
    }

    #[test]
    fn finer_grids_cost_more() {
        let g = resnet18_first8();
        let c2 = fusion_cost(&g, 1, 8, &tile_segment(&g, 1, 8, 2, 2));
        let c4 = fusion_cost(&g, 1, 8, &tile_segment(&g, 1, 8, 4, 4));
        assert!(c4.replication > c2.replication);
        assert!(c4.redundant_macs > c2.redundant_macs);
        // Matches the Fig. 7 observation: Fused4 (2x2) duplicates less
        // than Fused16 (4x4).
    }

    #[test]
    fn untiled_segment_has_no_overhead() {
        let g = resnet18_first8();
        let tiles = tile_segment(&g, 1, 8, 1, 1);
        let c = fusion_cost(&g, 1, 8, &tiles);
        assert!((c.replication - 1.0).abs() < 1e-9);
        assert!((c.redundant_macs - 1.0).abs() < 1e-9);
    }

    #[test]
    fn prop_tile_demands_cover_full_output_and_nest() {
        // Property: tiles' demanded regions always cover the union of the
        // output grid, and every demand nests inside the feature map.
        check_no_shrink(
            "tile-demand-covers",
            64,
            |g: &mut Gen| {
                let grid = *g.choose(&[(1usize, 1usize), (2, 2), (4, 4), (2, 4)]);
                let seg_end = g.usize_in(2, 8);
                (grid, seg_end)
            },
            |&((ty, tx), seg_end)| {
                let g = resnet18_first8();
                let shape: Shape = g.nodes[seg_end].shape;
                if shape.h < ty || shape.w < tx {
                    return true; // grid finer than the map: skip
                }
                let tiles = tile_segment(&g, 1, seg_end, ty, tx);
                let covered: usize = tiles.iter().map(|t| t.out_rect.pixels()).sum();
                if covered != shape.h * shape.w {
                    return false;
                }
                tiles.iter().all(|t| {
                    t.per_node.iter().all(|(&id, r)| {
                        let s = g.nodes[id].shape;
                        Rect::full(s.h, s.w).contains(r)
                    })
                })
            },
        );
    }
}
