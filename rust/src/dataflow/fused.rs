//! Fused-kernel partitioner: decides which contiguous layer ranges execute
//! as fused kernels and which fall back to layer-by-layer (§IV, §V-A3).
//!
//! A node-id range `[start, end]` is a valid *fusion segment* when
//! 1. no data edge crosses the cut after `end` other than `end`'s own
//!    output (residual skips must close inside the segment), and
//! 2. the segment output's spatial dims divide evenly by the tile grid
//!    (the paper's "layers that cannot fit evenly into a 4×4 tiling
//!    follow a layer-by-layer dataflow"), and
//! 3. every layer in the segment is spatially tileable (no global
//!    pooling / FC inside a fused kernel).
//!
//! The partitioner greedily grows segments up to [`MAX_FUSE_DEPTH`] layers,
//! cutting at the deepest valid point. On ResNet18 this yields exactly the
//! paper's kernels: 8+7 for Fused16 and 8+7+7 for Fused4.

use super::{Plan, PlanStep};
use crate::cnn::{Graph, NodeId, Op};

/// Maximum layers per fused kernel. The paper's deepest kernel is 8
/// layers (ResNet18 stem + stage 1).
pub const MAX_FUSE_DEPTH: usize = 8;

/// Can node `id`'s output be a segment boundary? True iff every edge that
/// leaves `[start, id]` originates at `id` itself.
pub fn is_cut_point(_g: &Graph, start: NodeId, id: NodeId, consumers: &[Vec<NodeId>]) -> bool {
    for n in start..=id {
        if n == id {
            continue;
        }
        if consumers[n].iter().any(|&c| c > id) {
            return false;
        }
    }
    true
}

/// Is every op in `[start, end]` spatially tileable?
fn segment_tileable(g: &Graph, start: NodeId, end: NodeId) -> bool {
    (start..=end).all(|i| {
        matches!(
            g.nodes[i].op,
            Op::Conv { .. } | Op::Pool { .. } | Op::AddRelu
        )
    })
}

/// Full fusability check for `[start, end]` under a `ty × tx` output grid.
pub fn segment_is_fusable(
    g: &Graph,
    start: NodeId,
    end: NodeId,
    ty: usize,
    tx: usize,
    consumers: &[Vec<NodeId>],
) -> bool {
    if !segment_tileable(g, start, end) {
        return false;
    }
    if !is_cut_point(g, start, end, consumers) {
        return false;
    }
    let s = g.nodes[end].shape;
    // The paper requires even tiling of the kernel output.
    s.h % ty == 0 && s.w % tx == 0 && s.h / ty >= 1 && s.w / tx >= 1 && (s.h / ty) * (s.w / tx) > 1
}

/// Greedy fused-kernel planner (see module docs).
pub fn plan_fused(g: &Graph, ty: usize, tx: usize, max_depth: usize) -> Plan {
    let consumers = g.consumers();
    let mut steps = Vec::new();
    let mut cur = 1usize; // node 0 is the input
    let last = g.nodes.len() - 1;
    while cur <= last {
        // Deepest fusable cut within max_depth of cur.
        let mut best: Option<NodeId> = None;
        let hi = (cur + max_depth - 1).min(last);
        for end in (cur..=hi).rev() {
            if segment_is_fusable(g, cur, end, ty, tx, &consumers) {
                best = Some(end);
                break;
            }
        }
        match best {
            // A 1-layer "fused" segment is just layer-by-layer execution
            // with a spatial partition; treat it as fused only if it spans
            // 2+ layers (fusion exists to break *inter*-layer deps).
            Some(end) if end > cur => {
                steps.push(PlanStep::Fused { start: cur, end, grid: (ty, tx) });
                cur = end + 1;
            }
            _ => {
                steps.push(PlanStep::Lbl { node: cur });
                cur += 1;
            }
        }
    }
    Plan { steps }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::resnet::{fig3_example, resnet18};
    use crate::cnn::{Graph, Op, Shape};

    #[test]
    fn cut_points_respect_residual_skips() {
        let g = resnet18();
        let cons = g.consumers();
        // L2 (s1b0.conv1, node 3): the maxpool->add skip crosses it.
        assert!(!is_cut_point(&g, 1, 3, &cons));
        // L4 (s1b0.add, node 5): all edges close.
        assert!(is_cut_point(&g, 1, 5, &cons));
        // L7 (s1b1.add, node 8): stage boundary.
        assert!(is_cut_point(&g, 1, 8, &cons));
    }

    #[test]
    fn fig3_fuses_two_kernels() {
        // Fig. 3(c): L0-L4 then L5-L7 (+ downsample) as the second kernel.
        let g = fig3_example();
        let p = plan_fused(&g, 2, 2, MAX_FUSE_DEPTH);
        p.validate(&g).unwrap();
        assert_eq!(p.num_fused_kernels(), 2);
    }

    #[test]
    fn gap_and_fc_never_fuse() {
        let g = resnet18();
        let p = plan_fused(&g, 2, 2, MAX_FUSE_DEPTH);
        let fused = p.fused_nodes();
        let gap = g.nodes.iter().find(|n| n.name == "gap").unwrap().id;
        let fc = g.nodes.iter().find(|n| n.name == "fc").unwrap().id;
        assert!(!fused.contains(&gap));
        assert!(!fused.contains(&fc));
    }

    #[test]
    fn uneven_maps_fall_back_to_lbl() {
        // Stage 4 of ResNet18 produces 7x7 maps: odd, so a 2x2 grid cannot
        // tile it evenly and the partitioner must not fuse it.
        let g = resnet18();
        let p = plan_fused(&g, 2, 2, MAX_FUSE_DEPTH);
        let fused = p.fused_nodes();
        assert!(!fused.iter().any(|&n| g.nodes[n].name.starts_with("s4")));
    }

    #[test]
    fn single_conv_graph_stays_lbl() {
        let mut g = Graph::new("one", Shape::new(8, 16, 16));
        g.add(
            "c",
            Op::Conv { cout: 8, k: 3, stride: 1, pad: 1, bn: true, relu: true },
            vec![0],
        );
        let p = plan_fused(&g, 2, 2, MAX_FUSE_DEPTH);
        assert_eq!(p.num_fused_kernels(), 0);
        assert_eq!(p.steps, vec![PlanStep::Lbl { node: 1 }]);
    }
}
