//! Hand-rolled CLI (the offline image has no `clap`).
//!
//! ```text
//! pimfused simulate --config fused4:G32K_L256 --workload full
//! pimfused fig5|fig6|fig7|takeaways|headline
//! pimfused sweep --systems aim,fused16,fused4 --gbuf 2K,32K --lbuf 0,256 --workload full
//! pimfused trace --config fused16:G2K_L0 --workload fig3 [--limit 40]
//! pimfused validate --config fused4:G8K_L128
//! pimfused cmdset
//! ```

use crate::config::{ArchConfig, System};
use crate::coordinator::{experiments, run_ppa, sweep, SweepPoint};
use crate::dataflow::{plan, CostModel};
use crate::trace::gen::generate;
use crate::util::size::parse_bytes;
use crate::workload::Workload;
use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;

/// Parsed command line: subcommand plus `--key value` options.
#[derive(Debug, Clone)]
pub struct Args {
    pub cmd: String,
    pub opts: HashMap<String, String>,
}

/// Parse a raw argv (without the binary name).
pub fn parse_args(argv: &[String]) -> Result<Args> {
    let Some(cmd) = argv.first() else {
        bail!("usage: pimfused <simulate|sweep|fig5|fig6|fig7|takeaways|headline|trace|validate|cmdset> [--key value]...");
    };
    let mut opts = HashMap::new();
    let mut i = 1;
    while i < argv.len() {
        let k = argv[i]
            .strip_prefix("--")
            .ok_or_else(|| anyhow!("expected --option, got {:?}", argv[i]))?;
        let v = argv
            .get(i + 1)
            .ok_or_else(|| anyhow!("--{k} needs a value"))?;
        opts.insert(k.to_string(), v.clone());
        i += 2;
    }
    Ok(Args { cmd: cmd.clone(), opts })
}

impl Args {
    fn config(&self) -> Result<ArchConfig> {
        let spec = self.opts.get("config").map(String::as_str).unwrap_or("fused4:G32K_L256");
        ArchConfig::parse(spec).map_err(anyhow::Error::msg)
    }

    fn workload(&self) -> Result<Workload> {
        let w = self.opts.get("workload").map(String::as_str).unwrap_or("full");
        Workload::parse(w).map_err(anyhow::Error::msg)
    }
}

/// Run the CLI; returns the text to print.
pub fn run(args: &Args) -> Result<String> {
    let model = CostModel::default();
    match args.cmd.as_str() {
        "simulate" => {
            let cfg = args.config()?;
            let w = args.workload()?;
            let r = run_ppa(&cfg, w)?;
            let base = run_ppa(&ArchConfig::baseline(), w)?;
            let n = r.normalize(&base);
            Ok(format!(
                "{} on {}\n  memory cycles : {}\n  energy        : {:.3} mJ\n  area          : {:.3} mm2\n  vs AiM-like/G2K_L0: {}\n",
                r.label,
                r.workload,
                r.cycles,
                r.energy_pj / 1e9,
                r.area_mm2,
                n.render()
            ))
        }
        "sweep" => {
            let systems: Vec<System> = args
                .opts
                .get("systems")
                .map(String::as_str)
                .unwrap_or("aim,fused16,fused4")
                .split(',')
                .map(System::parse)
                .collect::<Result<_, _>>()
                .map_err(anyhow::Error::msg)?;
            let parse_list = |key: &str, def: &str| -> Result<Vec<usize>> {
                args.opts
                    .get(key)
                    .map(String::as_str)
                    .unwrap_or(def)
                    .split(',')
                    .map(|s| parse_bytes(s).map_err(anyhow::Error::msg))
                    .collect()
            };
            let gbufs = parse_list("gbuf", "2K,8K,16K,32K,64K")?;
            let lbufs = parse_list("lbuf", "0,64,128,256,512")?;
            let w = args.workload()?;
            let mut points: Vec<SweepPoint> = Vec::new();
            for &s in &systems {
                for &g in &gbufs {
                    for &l in &lbufs {
                        points.push(SweepPoint { cfg: ArchConfig::system(s, g, l), workload: w });
                    }
                }
            }
            let base = run_ppa(&ArchConfig::baseline(), w)?;
            let results = sweep(&points, model);
            let mut t = crate::util::table::Table::new(vec!["config", "cycles", "energy", "area"]);
            for r in results {
                let r = r?;
                let n = r.normalize(&base);
                t.row(vec![
                    r.label.clone(),
                    crate::util::table::pct_or_x(n.cycles),
                    crate::util::table::pct_or_x(n.energy),
                    crate::util::table::pct_or_x(n.area),
                ]);
            }
            Ok(t.render())
        }
        "fig5" => Ok(experiments::render(&experiments::fig5(model)?)),
        "fig6" => Ok(experiments::render(&experiments::fig6(model)?)),
        "fig7" => Ok(experiments::render(&experiments::fig7(model)?)),
        "takeaways" => {
            let s = experiments::vd_stats(model)?;
            Ok(format!(
                "Fusing ResNet18 first-8 layers into 2x2 tiles (paper §V-D):\n  data replication     : +{:.1}% (paper +18.2%)\n  redundant computation: +{:.1}% (paper +17.3%)\n  performance improvement: {:.1}% (paper 91.2%)\n",
                (s.fusion.replication - 1.0) * 100.0,
                (s.fusion.redundant_macs - 1.0) * 100.0,
                s.perf_improvement * 100.0
            ))
        }
        "headline" => {
            let n = experiments::headline(model)?;
            Ok(format!(
                "Fused4 @ G32K_L256 vs AiM-like @ G2K_L0 (ResNet18_Full):\n  measured: {}\n  paper   : cycles=30.6% energy=83.4% area=76.5%\n",
                n.render()
            ))
        }
        "trace" => {
            let cfg = args.config()?;
            let w = args.workload()?;
            let limit: usize = args
                .opts
                .get("limit")
                .map(|s| s.parse())
                .transpose()?
                .unwrap_or(60);
            let g = w.graph();
            let p = plan(&g, &cfg);
            let tr = generate(&g, &cfg, &p, model);
            let stats = tr.stats();
            Ok(format!(
                "{}\ncommands={} cross_bank={}B broadcast={}B near_bank={}B (hit {}B)\n",
                tr.dump(limit),
                stats.num_cmds,
                stats.cross_bank_total(),
                stats.broadcast,
                stats.near_bank_read + stats.near_bank_write,
                stats.near_bank_hit,
            ))
        }
        "validate" => {
            let cfg = args.config()?;
            // Reduced resolution keeps the f32 reference fast.
            let g = Workload::ResNet18Small.graph();
            let p = plan(&g, &cfg);
            let delta = crate::validate::validate_plan(&g, &p, 0xC0FFEE)
                .map_err(anyhow::Error::msg)?;
            Ok(format!(
                "functional validation of {} on {}: OK (max |Δ| = {delta})\n",
                cfg.label(),
                g.name
            ))
        }
        "cmdset" => Ok("\
Custom PIM commands (Table I):
  PIMcore_CMP   Perform fused operations in all PIMcores
                flags: CONV_BN | CONV_BN_RELU | POOL | ADD_RELU
  GBcore_CMP    Perform operations in GBcore
                flags: POOL | ADD_RELU
  PIM_BK2LBUF   Data transfer between all banks and LBUFs (parallel)
  PIM_LBUF2BK   Data transfer between all LBUFs and banks (parallel)
  PIM_BK2GBUF   Data transfer between one bank and GBUF (sequential)
  PIM_GBUF2BK   Data transfer between GBUF and one bank (sequential)
"
        .to_string()),
        other => bail!("unknown subcommand {other:?}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn parse_subcommand_and_options() {
        let a = parse_args(&argv("simulate --config fused4:G32K_L256 --workload first8")).unwrap();
        assert_eq!(a.cmd, "simulate");
        assert_eq!(a.opts["config"], "fused4:G32K_L256");
        assert!(parse_args(&[]).is_err());
        assert!(parse_args(&argv("simulate --config")).is_err());
        assert!(parse_args(&argv("simulate config x")).is_err());
    }

    #[test]
    fn simulate_command_reports() {
        let a = parse_args(&argv("simulate --config aim:G2K_L0 --workload first8")).unwrap();
        let out = run(&a).unwrap();
        assert!(out.contains("AiM-like/G2K_L0"));
        assert!(out.contains("memory cycles"));
    }

    #[test]
    fn headline_and_takeaways_run() {
        let h = run(&parse_args(&argv("headline")).unwrap()).unwrap();
        assert!(h.contains("paper   : cycles=30.6%"));
        let t = run(&parse_args(&argv("takeaways")).unwrap()).unwrap();
        assert!(t.contains("replication"));
    }

    #[test]
    fn trace_command_dumps_table_i_commands() {
        let a = parse_args(&argv("trace --config fused16:G2K_L0 --workload fig3 --limit 10")).unwrap();
        let out = run(&a).unwrap();
        assert!(out.contains("PIMcore_CMP"));
        assert!(out.contains("cross_bank="));
    }

    #[test]
    fn cmdset_lists_all_six() {
        let out = run(&parse_args(&argv("cmdset")).unwrap()).unwrap();
        for c in ["PIMcore_CMP", "GBcore_CMP", "PIM_BK2LBUF", "PIM_LBUF2BK", "PIM_BK2GBUF", "PIM_GBUF2BK"] {
            assert!(out.contains(c), "{c} missing");
        }
    }

    #[test]
    fn unknown_subcommand_errors() {
        assert!(run(&parse_args(&argv("bogus")).unwrap()).is_err());
    }

    #[test]
    fn sweep_small_grid() {
        let a = parse_args(&argv(
            "sweep --systems fused4 --gbuf 2K,32K --lbuf 0,256 --workload first8",
        ))
        .unwrap();
        let out = run(&a).unwrap();
        assert_eq!(out.matches("Fused4/").count(), 4);
    }
}
