//! Hand-rolled CLI (the offline image has no `clap`).
//!
//! ```text
//! pimfused simulate --config fused4:G32K_L256 --workload full [--engine event] [--json]
//! pimfused profile --workload full [--config fused4:G32K_L256] [--top 5] [--trace-out chrome|csv]
//! pimfused fig5|fig6|fig7|takeaways|headline
//! pimfused sweep --systems aim,fused16,fused4 --gbuf 2K,32K --lbuf 0,256 --workload full [--engine event] [--json]
//! pimfused serve --workload full --rate 20000 --requests 1000 --batch 8 [--json|--csv]
//! pimfused trace --config fused16:G2K_L0 --workload fig3 [--limit 40]
//! pimfused validate --config fused4:G8K_L128
//! pimfused cmdset
//! ```
//!
//! All PPA subcommands run through the coordinator's [`Session`] /
//! [`SweepGrid`] (Experiment API v2); `--json` emits the
//! [`SweepResults::to_json`] schema. Bad subcommands or options fail with
//! a non-zero exit and the usage text.

use crate::config::{ArchConfig, Engine, PartitionKind, System};
use crate::coordinator::{
    experiments, serve_to_csv, serve_to_json, Session, SweepGrid, SweepPoint, SweepResults,
};
use crate::dataflow::{plan, CostModel};
use crate::serve::{ArrivalKind, ServeConfig};
use crate::trace::gen::generate;
use crate::util::size::parse_bytes;
use crate::workload::Workload;
use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;

/// Usage text printed on bad invocations (and by `main` on any error).
pub const USAGE: &str = "\
usage: pimfused <command> [--key value]... [--json]
commands:
  simulate   one PPA point          --config <sys:GmK_Ln> --workload <w>
                                    [--engine analytic|event] [--json]
                                    [--host-residency on|off]
                                    [--slice-pipelining on|off]
                                    [--open-row on|off]
                                    [--trace-out chrome|csv] [--faults <spec>]
                                    [--channels N] [--partition data|model]
  profile    schedule profiling     --workload <w> [--config <sys:GmK_Ln>]
                                    [--top N] [--trace-out chrome|csv]
                                    [--host-residency on|off]
                                    [--slice-pipelining on|off]
                                    [--open-row on|off] [--faults <spec>]
                                    [--channels N] [--partition data|model]
  sweep      buffer design sweep    --systems aim,fused16,fused4 --gbuf 2K,32K
                                    --lbuf 0,256 --workload <w>
                                    [--engine analytic|event] [--json]
                                    [--channels n1,n2,..] [--partition data|model]
  fig5 | fig6 | fig7                regenerate the paper's figures
                                    [--engine analytic|event]
  takeaways | headline              §V-D statistics / the headline claim
  serve      request-stream serving --workload <w> --rate <req/s> | --rates r1,r2,..
                                    [--requests N] [--batch K] [--batch-timeout CYC]
                                    [--queue-depth D] [--seed S] [--warmup F]
                                    [--arrival poisson|fixed] [--config <sys:GmK_Ln>]
                                    [--engine analytic|event] [--json|--csv]
                                    [--open-row on|off]
                                    [--trace-out chrome|csv] [--faults <spec>]
                                    [--deadline CYC] [--retries N] [--backoff CYC]
                                    [--channels N] [--partition data|model]
  degrade    graceful-degradation   --workload <w> [--config <sys:GmK_Ln>]
             sweep                  [--requests N] [--rate <req/s>] [--seed S]
                                    [--step BANKS] [--faults <spec>] [--json|--csv]
  trace      dump a command trace   --config <sys:GmK_Ln> --workload <w> [--limit N]
  validate   functional validation  --config <sys:GmK_Ln>
  cmdset     list the Table-I PIM commands
workloads: full | first8 | fig1 | fig3 | small
systems:   aim | fused16 | fused4        bufcfg: e.g. fused4:G32K_L256
engines:   analytic (serial sum) | event (overlap-aware, reports utilization)
host-residency: model host I/O's bank occupancy (default on; off = interface-only)
slice-pipelining: let per-bank transfer slices slide around busy banks (default on;
                  off = rigid i/N stagger)
open-row: reuse rows banks left open — a read resuming the exact open row skips
          one tRP+tRCD re-open (default on; off = every command reopens)
serve: open-loop steady-state latency/throughput (DESIGN.md §9); --rates sweeps
       the offered load for the utilization-vs-latency curve; defaults to the
       event engine (batching only pipelines there)
profile: capture the event schedule timeline and print a per-layer phase
         breakdown plus the busiest commands (DESIGN.md §10)
trace-out: emit the captured timeline instead of the report — chrome is
           chrome://tracing / Perfetto trace_events JSON (ts in cycles),
           csv one row per reservation (event engine only)
faults: inject failures, e.g. --faults banks=4,cores=1,p=0.001,retries=3,seed=7
        banks=N retired banks, cores=N dead PIMcores (permanent; work remaps
        onto the survivors), channels=N retired DRAM channels (multi-channel
        configs only; survivors absorb the shards), p = per-command transient
        error probability in [0,1] (errored commands replay up to retries
        times), seed for the deterministic fault plan
channels: scale out across N independent DRAM channels sharing one host
          interconnect (DESIGN.md §12); --partition data shards the batch
          (no cross-channel traffic), model shards every layer's output
          channels and gathers the shards over the interconnect at each
          fused-step boundary; sweep --channels without --partition sweeps
          both partitions
degrade: sweep retired banks from 0 to num_banks - banks_per_pimcore (step
         defaults to one PIMcore's banks) and serve the same stream at each
         point; analytic engine, batch 1, drop-free queue, so goodput decays
         monotonically as capacity is lost
deadline/retries/backoff: per-request SLO in cycles (admission sheds doomed
         requests, late completions count as misses); rejected clients
         re-offer up to N times with exponential backoff
";

/// Options that are flags (no value); everything else takes `--key value`.
const FLAGS: &[&str] = &["json", "csv"];

/// Parsed command line: subcommand plus `--key value` options.
#[derive(Debug, Clone)]
pub struct Args {
    /// The subcommand word, e.g. `simulate`.
    pub cmd: String,
    /// `--key value` options (flags store `"true"`).
    pub opts: HashMap<String, String>,
}

/// Parse a raw argv (without the binary name).
pub fn parse_args(argv: &[String]) -> Result<Args> {
    let Some(cmd) = argv.first() else {
        bail!("no command given\n{USAGE}");
    };
    let mut opts = HashMap::new();
    let mut i = 1;
    while i < argv.len() {
        let k = argv[i]
            .strip_prefix("--")
            .ok_or_else(|| anyhow!("expected --option, got {:?}\n{USAGE}", argv[i]))?;
        if FLAGS.contains(&k) {
            opts.insert(k.to_string(), "true".to_string());
            i += 1;
            continue;
        }
        let v = argv
            .get(i + 1)
            .ok_or_else(|| anyhow!("--{k} needs a value\n{USAGE}"))?;
        opts.insert(k.to_string(), v.clone());
        i += 2;
    }
    Ok(Args { cmd: cmd.clone(), opts })
}

impl Args {
    fn config(&self) -> Result<ArchConfig> {
        let spec = self.opts.get("config").map(String::as_str).unwrap_or("fused4:G32K_L256");
        ArchConfig::parse(spec).map_err(anyhow::Error::msg)
    }

    fn workload(&self) -> Result<Workload> {
        let w = self.opts.get("workload").map(String::as_str).unwrap_or("full");
        Workload::parse(w).map_err(anyhow::Error::msg)
    }

    fn engine(&self) -> Result<Engine> {
        self.engine_or(Engine::Analytic)
    }

    /// `--engine`, defaulting to `default` when absent (`serve` defaults
    /// to the event engine; everything else to analytic).
    fn engine_or(&self, default: Engine) -> Result<Engine> {
        match self.opts.get("engine") {
            None => Ok(default),
            Some(e) => Engine::parse(e).map_err(anyhow::Error::msg),
        }
    }

    fn host_residency(&self) -> Result<bool> {
        match self.opts.get("host-residency").map(String::as_str) {
            None | Some("on") => Ok(true),
            Some("off") => Ok(false),
            Some(other) => bail!("--host-residency must be on|off, got {other:?}\n{USAGE}"),
        }
    }

    fn slice_pipelining(&self) -> Result<bool> {
        match self.opts.get("slice-pipelining").map(String::as_str) {
            None | Some("on") => Ok(true),
            Some("off") => Ok(false),
            Some(other) => bail!("--slice-pipelining must be on|off, got {other:?}\n{USAGE}"),
        }
    }

    fn open_row(&self) -> Result<bool> {
        match self.opts.get("open-row").map(String::as_str) {
            None | Some("on") => Ok(true),
            Some("off") => Ok(false),
            Some(other) => bail!("--open-row must be on|off, got {other:?}\n{USAGE}"),
        }
    }

    /// `--channels N` (default 1 = the classic single-channel model).
    /// Range checks beyond `>= 1` stay in [`ArchConfig::validate`].
    fn channels(&self) -> Result<usize> {
        match self.opts.get("channels") {
            None => Ok(1),
            Some(s) => {
                let n: usize = s.parse().map_err(|_| {
                    anyhow!("--channels must be an integer, got {s:?}\n{USAGE}")
                })?;
                if n == 0 {
                    bail!("--channels must be >= 1\n{USAGE}");
                }
                Ok(n)
            }
        }
    }

    /// `--partition data|model`, when given.
    fn partition(&self) -> Result<Option<PartitionKind>> {
        match self.opts.get("partition") {
            None => Ok(None),
            Some(s) => Ok(Some(
                PartitionKind::parse(s).map_err(|e| anyhow!("{e}\n{USAGE}"))?,
            )),
        }
    }

    /// `--trace-out chrome|csv`, when given.
    fn trace_out(&self) -> Result<Option<crate::obs::TraceFormat>> {
        match self.opts.get("trace-out") {
            None => Ok(None),
            Some(s) => match crate::obs::TraceFormat::parse(s) {
                Some(f) => Ok(Some(f)),
                None => bail!("--trace-out must be chrome|csv, got {s:?}\n{USAGE}"),
            },
        }
    }

    /// `--faults banks=N,cores=N,p=F,retries=R,seed=S` (all parts
    /// optional, any order). `p` is a probability in `[0, 1]`, converted
    /// to the fault model's integer parts-per-million.
    fn faults(&self) -> Result<Option<crate::fault::FaultConfig>> {
        let Some(spec) = self.opts.get("faults") else {
            return Ok(None);
        };
        let mut fc = crate::fault::FaultConfig::default();
        for part in spec.split(',').filter(|s| !s.trim().is_empty()) {
            let (k, v) = part
                .split_once('=')
                .ok_or_else(|| anyhow!("--faults parts are key=value, got {part:?}\n{USAGE}"))?;
            let (k, v) = (k.trim(), v.trim());
            let int = || {
                v.parse::<u64>()
                    .map_err(|_| anyhow!("--faults {k} must be an integer, got {v:?}\n{USAGE}"))
            };
            match k {
                "banks" => fc.retired_banks = int()? as usize,
                "cores" => fc.dead_cores = int()? as usize,
                "channels" => fc.dead_channels = int()? as usize,
                "p" => {
                    let p: f64 = v.parse().map_err(|_| {
                        anyhow!("--faults p must be a number, got {v:?}\n{USAGE}")
                    })?;
                    if !p.is_finite() || !(0.0..=1.0).contains(&p) {
                        bail!("--faults p must be in [0, 1], got {v:?}\n{USAGE}");
                    }
                    fc.transient_ppm = (p * 1_000_000.0).round() as u32;
                }
                "retries" => fc.max_retries = int()? as u32,
                "seed" => fc.seed = int()?,
                other => {
                    bail!(
                        "unknown --faults key {other:?} (banks|cores|channels|p|retries|seed)\n{USAGE}"
                    )
                }
            }
        }
        Ok(Some(fc))
    }

    /// Apply `--faults` to a config, validating the fault counts against
    /// the config's geometry up front so impossible plans (e.g. retiring
    /// every bank) fail with the usage text instead of deep in a run.
    fn with_faults_checked(&self, cfg: ArchConfig) -> Result<ArchConfig> {
        match self.faults()? {
            None => Ok(cfg),
            Some(fc) => {
                fc.validate(cfg.num_banks, cfg.banks_per_pimcore, cfg.channels)
                    .map_err(|e| anyhow!("{e}\n{USAGE}"))?;
                Ok(cfg.with_faults(fc))
            }
        }
    }

    fn flag(&self, name: &str) -> bool {
        self.opts.get(name).map(String::as_str) == Some("true")
    }

    /// Reject options the subcommand doesn't understand.
    fn check_opts(&self, allowed: &[&str]) -> Result<()> {
        for k in self.opts.keys() {
            if !allowed.contains(&k.as_str()) {
                bail!("unknown option --{k} for {:?}\n{USAGE}", self.cmd);
            }
        }
        Ok(())
    }
}

/// Run the CLI; returns the text to print.
pub fn run(args: &Args) -> Result<String> {
    let model = CostModel::default();
    let session = Session::with_model(model);
    match args.cmd.as_str() {
        "simulate" => {
            args.check_opts(&[
                "config",
                "workload",
                "engine",
                "json",
                "host-residency",
                "slice-pipelining",
                "open-row",
                "trace-out",
                "faults",
                "channels",
                "partition",
            ])?;
            let trace_out = args.trace_out()?;
            if trace_out.is_some() && args.flag("json") {
                bail!("--trace-out and --json are mutually exclusive\n{USAGE}");
            }
            // --trace-out implies the event engine (the analytic engine
            // has no schedule to trace) and turns capture on.
            let engine = args
                .engine_or(if trace_out.is_some() { Engine::Event } else { Engine::Analytic })?;
            if trace_out.is_some() && engine != Engine::Event {
                bail!("--trace-out needs --engine event\n{USAGE}");
            }
            let cfg = args.with_faults_checked(
                args.config()?
                    .with_engine(engine)
                    .with_host_residency(args.host_residency()?)
                    .with_slice_pipelining(args.slice_pipelining()?)
                    .with_open_row_reuse(args.open_row()?)
                    .with_channels(args.channels()?)
                    .with_partition(args.partition()?.unwrap_or(PartitionKind::Data))
                    .with_tracing(trace_out.is_some()),
            )?;
            let faults = cfg.faults;
            let w = args.workload()?;
            let results = SweepGrid::from_points(vec![SweepPoint { cfg, workload: w }])
                .run(&session)?;
            results.ensure_ok()?;
            if args.flag("json") {
                return Ok(results.to_json());
            }
            let row = &results.rows[0];
            if let Some(fmt) = trace_out {
                let st = row
                    .report
                    .as_ref()
                    .expect("ensure_ok")
                    .schedule
                    .as_ref()
                    .expect("tracing was on");
                return Ok(fmt.export(st));
            }
            let r = row.report.as_ref().expect("ensure_ok");
            let n = row.norm.expect("ensure_ok");
            let mut out = format!(
                "{} on {} ({} engine)\n  memory cycles : {}\n  energy        : {:.3} mJ\n  area          : {:.3} mm2\n  vs {}: {}\n",
                r.label,
                r.workload,
                r.engine.name(),
                r.cycles,
                r.energy_pj / 1e9,
                r.area_mm2,
                results.baseline_label,
                n.render()
            );
            if let Some(occ) = &r.occupancy {
                out.push_str("per-resource occupancy:\n");
                out.push_str(&occ.render());
                if let Some(u) = r.bottleneck_utilization() {
                    out.push_str(&format!(
                        "bottleneck utilization: {} ({} idle cycles on the critical resource)\n",
                        crate::util::table::pct(u),
                        occ.bottleneck_idle(),
                    ));
                }
                if let (Some(h), Some(a)) = (r.host_bank_share(), r.act_utilization()) {
                    out.push_str(&format!(
                        "host bank residency: {} of bank occupancy | act-slot utilization: {}\n",
                        crate::util::table::pct(h),
                        crate::util::table::pct(a),
                    ));
                }
                out.push_str(&format!(
                    "slice pipelining: {} slice-cycles slid off the rigid stagger\n",
                    occ.slid_slices,
                ));
            }
            if let Some(ch) = &r.channels {
                let dead = if ch.dead_channels > 0 {
                    format!(", {} dead", ch.dead_channels)
                } else {
                    String::new()
                };
                out.push_str(&format!(
                    "channels: {} ({} partition, width {}{})\n  per-channel cycles: {:?}\n  interconnect: {} busy cycles ({} of makespan) | {} exchanges, {} B\n",
                    ch.channels,
                    ch.partition.name(),
                    ch.width,
                    dead,
                    ch.channel_cycles,
                    ch.interconnect_busy,
                    crate::util::table::pct(ch.interconnect_utilization(r.cycles)),
                    ch.exchanges.len(),
                    ch.exchange_bytes,
                ));
            }
            if !faults.is_none() {
                out.push_str(&format!(
                    "faults: {}\n  replayed cycles: {} | escalated commands: {}\n",
                    faults.summary(),
                    r.sim.replayed_cycles,
                    r.sim.escalated_cmds,
                ));
            }
            Ok(out)
        }
        "sweep" => {
            args.check_opts(&[
                "systems", "gbuf", "lbuf", "workload", "engine", "json", "channels", "partition",
            ])?;
            let systems: Vec<System> = args
                .opts
                .get("systems")
                .map(String::as_str)
                .unwrap_or("aim,fused16,fused4")
                .split(',')
                .map(System::parse)
                .collect::<Result<_, _>>()
                .map_err(anyhow::Error::msg)?;
            let parse_list = |key: &str, def: &str| -> Result<Vec<usize>> {
                args.opts
                    .get(key)
                    .map(String::as_str)
                    .unwrap_or(def)
                    .split(',')
                    .map(|s| parse_bytes(s).map_err(anyhow::Error::msg))
                    .collect()
            };
            let gbufs = parse_list("gbuf", "2K,8K,16K,32K,64K")?;
            let lbufs = parse_list("lbuf", "0,64,128,256,512")?;
            // --channels n1,n2,... adds the scale-out axis; without an
            // explicit --partition the sweep covers both strategies.
            let channels: Option<Vec<usize>> = args
                .opts
                .get("channels")
                .map(|s| {
                    s.split(',')
                        .map(|c| {
                            let n: usize = c.trim().parse().map_err(|_| {
                                anyhow!(
                                    "--channels must be comma-separated integers, got {c:?}\n{USAGE}"
                                )
                            })?;
                            if n == 0 {
                                bail!("--channels must be >= 1\n{USAGE}");
                            }
                            Ok(n)
                        })
                        .collect::<Result<Vec<usize>>>()
                })
                .transpose()?;
            let w = args.workload()?;
            let mut grid = SweepGrid::new()
                .systems(systems)
                .gbuf_bytes(gbufs)
                .lbuf_bytes(lbufs)
                .workload(w)
                .engine(args.engine()?);
            match (channels, args.partition()?) {
                (Some(chs), Some(p)) => grid = grid.channels(chs).partition(p),
                (Some(chs), None) => grid = grid.channels(chs).partitions(PartitionKind::ALL),
                (None, Some(p)) => grid = grid.partition(p),
                (None, None) => {}
            }
            let results: SweepResults = grid.run(&session)?;
            results.ensure_ok()?;
            if args.flag("json") {
                return Ok(results.to_json());
            }
            Ok(results.table())
        }
        "fig5" => {
            args.check_opts(&["engine"])?;
            Ok(experiments::render(&experiments::fig5_with(&session, args.engine()?)?))
        }
        "fig6" => {
            args.check_opts(&["engine"])?;
            Ok(experiments::render(&experiments::fig6_with(&session, args.engine()?)?))
        }
        "fig7" => {
            args.check_opts(&["engine"])?;
            Ok(experiments::render(&experiments::fig7_with(&session, args.engine()?)?))
        }
        "takeaways" => {
            args.check_opts(&[])?;
            let s = experiments::vd_stats(model)?;
            Ok(format!(
                "Fusing ResNet18 first-8 layers into 2x2 tiles (paper §V-D):\n  data replication     : +{:.1}% (paper +18.2%)\n  redundant computation: +{:.1}% (paper +17.3%)\n  performance improvement: {:.1}% (paper 91.2%)\n",
                (s.fusion.replication - 1.0) * 100.0,
                (s.fusion.redundant_macs - 1.0) * 100.0,
                s.perf_improvement * 100.0
            ))
        }
        "headline" => {
            args.check_opts(&[])?;
            let n = experiments::headline(model)?;
            Ok(format!(
                "Fused4 @ G32K_L256 vs AiM-like @ G2K_L0 (ResNet18_Full):\n  measured: {}\n  paper   : cycles=30.6% energy=83.4% area=76.5%\n",
                n.render()
            ))
        }
        "serve" => {
            args.check_opts(&[
                "config",
                "workload",
                "engine",
                "rate",
                "rates",
                "requests",
                "batch",
                "batch-timeout",
                "queue-depth",
                "seed",
                "arrival",
                "warmup",
                "deadline",
                "retries",
                "backoff",
                "faults",
                "json",
                "csv",
                "host-residency",
                "slice-pipelining",
                "open-row",
                "trace-out",
                "channels",
                "partition",
            ])?;
            if args.flag("json") && args.flag("csv") {
                bail!("--json and --csv are mutually exclusive\n{USAGE}");
            }
            if args.trace_out()?.is_some() && (args.flag("json") || args.flag("csv")) {
                bail!("--trace-out and --json/--csv are mutually exclusive\n{USAGE}");
            }
            let num = |key: &str| -> Result<Option<f64>> {
                args.opts
                    .get(key)
                    .map(|s| {
                        s.parse::<f64>()
                            .map_err(|_| anyhow!("--{key} must be a number, got {s:?}\n{USAGE}"))
                    })
                    .transpose()
            };
            let int = |key: &str| -> Result<Option<u64>> {
                args.opts
                    .get(key)
                    .map(|s| {
                        s.parse::<u64>()
                            .map_err(|_| anyhow!("--{key} must be an integer, got {s:?}\n{USAGE}"))
                    })
                    .transpose()
            };
            let rate = num("rate")?;
            let rates: Option<Vec<f64>> = args
                .opts
                .get("rates")
                .map(|s| {
                    s.split(',')
                        .map(|r| {
                            r.trim().parse::<f64>().map_err(|_| {
                                anyhow!("--rates must be comma-separated numbers, got {r:?}\n{USAGE}")
                            })
                        })
                        .collect()
                })
                .transpose()?;
            if rate.is_some() && rates.is_some() {
                bail!("--rate and --rates are mutually exclusive\n{USAGE}");
            }
            if rate.is_none() && rates.is_none() {
                bail!("serve needs --rate <req/s> or --rates r1,r2,...\n{USAGE}");
            }
            for r in rate.iter().chain(rates.iter().flatten()) {
                if !r.is_finite() || *r <= 0.0 {
                    bail!("--rate must be > 0 (got {r})\n{USAGE}");
                }
            }
            let batch = int("batch")?.unwrap_or(1) as usize;
            if batch < 1 {
                bail!("--batch must be >= 1\n{USAGE}");
            }
            // The default queue depth grows to fit one full batch.
            let queue_depth = int("queue-depth")?.map(|d| d as usize).unwrap_or(64.max(batch));
            if queue_depth < batch {
                bail!("--queue-depth must be >= --batch ({queue_depth} < {batch})\n{USAGE}");
            }
            let arrival = match args.opts.get("arrival") {
                None => ArrivalKind::Poisson,
                Some(a) => ArrivalKind::parse(a).map_err(anyhow::Error::msg)?,
            };
            let cfg = args.with_faults_checked(
                args.config()?
                    .with_engine(args.engine_or(Engine::Event)?)
                    .with_host_residency(args.host_residency()?)
                    .with_slice_pipelining(args.slice_pipelining()?)
                    .with_open_row_reuse(args.open_row()?)
                    .with_channels(args.channels()?)
                    .with_partition(args.partition()?.unwrap_or(PartitionKind::Data)),
            )?;
            let sc = ServeConfig::new(cfg, args.workload()?, rate.unwrap_or(1.0))
                .arrival(arrival)
                .requests(int("requests")?.unwrap_or(1000) as usize)
                .batch(batch)
                .batch_timeout(int("batch-timeout")?.unwrap_or(0))
                .queue_depth(queue_depth)
                .seed(int("seed")?.unwrap_or(42))
                .warmup(num("warmup")?.unwrap_or(0.1))
                .deadline(int("deadline")?.unwrap_or(0))
                .client_retries(int("retries")?.unwrap_or(0) as u32)
                .backoff(int("backoff")?.unwrap_or(0));
            if let Some(fmt) = args.trace_out()? {
                // Export the single-inference schedule the serving
                // profile replays (what every batch's cost derives from).
                if sc.cfg.engine != Engine::Event {
                    bail!("--trace-out needs --engine event\n{USAGE}");
                }
                let traced = sc.cfg.clone().with_tracing(true);
                let r = session.run(&traced, sc.workload)?;
                let st = r.schedule.as_ref().expect("tracing was on");
                return Ok(fmt.export(st));
            }
            match rates {
                None => {
                    let r = session.serve(&sc)?;
                    if args.flag("json") {
                        Ok(serve_to_json(&[r]))
                    } else if args.flag("csv") {
                        Ok(serve_to_csv(&[r]))
                    } else {
                        Ok(r.render())
                    }
                }
                Some(rates) => {
                    let reports = session.serve_sweep(&sc, &rates, true)?;
                    if args.flag("json") {
                        return Ok(serve_to_json(&reports));
                    }
                    if args.flag("csv") {
                        return Ok(serve_to_csv(&reports));
                    }
                    let mut t = crate::util::table::Table::new(vec![
                        "rate req/s",
                        "tput req/s",
                        "p50 cyc",
                        "p99 cyc",
                        "mean cyc",
                        "util",
                        "queue",
                        "dropped",
                    ]);
                    for r in &reports {
                        t.row(vec![
                            format!("{:.0}", r.rate_rps),
                            format!("{:.0}", r.throughput_rps),
                            r.latency.p50.to_string(),
                            r.latency.p99.to_string(),
                            format!("{:.0}", r.latency.mean),
                            crate::util::table::pct(r.utilization),
                            format!("{:.2}", r.queue_mean),
                            r.dropped.to_string(),
                        ]);
                    }
                    Ok(format!(
                        "serve sweep: {} on {} ({} engine, batch<={}, seed {})\n{}",
                        sc.cfg.label(),
                        sc.workload.name(),
                        sc.cfg.engine.name(),
                        sc.batch,
                        sc.seed,
                        t.render()
                    ))
                }
            }
        }
        "degrade" => {
            args.check_opts(&[
                "config", "workload", "requests", "rate", "seed", "step", "faults", "json", "csv",
            ])?;
            if args.flag("json") && args.flag("csv") {
                bail!("--json and --csv are mutually exclusive\n{USAGE}");
            }
            let int = |key: &str| -> Result<Option<u64>> {
                args.opts
                    .get(key)
                    .map(|s| {
                        s.parse::<u64>()
                            .map_err(|_| anyhow!("--{key} must be an integer, got {s:?}\n{USAGE}"))
                    })
                    .transpose()
            };
            // The analytic engine keeps the sweep's monotone-goodput
            // guarantee (the event engine's list scheduler can exhibit
            // timing anomalies); --faults contributes the per-step
            // constants (dead cores, transient rate, seed) while the
            // sweep itself drives the retired-bank count.
            let cfg = args.with_faults_checked(args.config()?)?;
            let requests = int("requests")?.unwrap_or(200) as usize;
            if requests == 0 {
                bail!("--requests must be >= 1\n{USAGE}");
            }
            let clock = cfg.timing.clock_hz();
            let rate = match args.opts.get("rate") {
                // Default: one request per cycle — service-bound, so
                // goodput reads directly as serving capacity.
                None => clock,
                Some(s) => {
                    let r: f64 = s.parse().map_err(|_| {
                        anyhow!("--rate must be a number, got {s:?}\n{USAGE}")
                    })?;
                    if !r.is_finite() || r <= 0.0 {
                        bail!("--rate must be > 0 (got {r})\n{USAGE}");
                    }
                    r
                }
            };
            let step = match int("step")? {
                None => cfg.banks_per_pimcore,
                Some(0) => bail!("--step must be >= 1\n{USAGE}"),
                Some(s) => s as usize,
            };
            let sc = ServeConfig::new(cfg, args.workload()?, rate)
                .arrival(ArrivalKind::Fixed)
                .requests(requests)
                .queue_depth(requests)
                .seed(int("seed")?.unwrap_or(42));
            let r = session.degrade_sweep(&sc, step)?;
            if args.flag("json") {
                Ok(r.to_json())
            } else if args.flag("csv") {
                Ok(r.to_csv())
            } else {
                Ok(r.render())
            }
        }
        "profile" => {
            args.check_opts(&[
                "config",
                "workload",
                "top",
                "trace-out",
                "host-residency",
                "slice-pipelining",
                "open-row",
                "faults",
                "channels",
                "partition",
            ])?;
            let top: usize = args
                .opts
                .get("top")
                .map(|s| s.parse())
                .transpose()
                .map_err(|_| anyhow!("--top must be an integer\n{USAGE}"))?
                .unwrap_or(5);
            let cfg = args.with_faults_checked(
                args.config()?
                    .with_engine(Engine::Event)
                    .with_host_residency(args.host_residency()?)
                    .with_slice_pipelining(args.slice_pipelining()?)
                    .with_open_row_reuse(args.open_row()?)
                    .with_channels(args.channels()?)
                    .with_partition(args.partition()?.unwrap_or(PartitionKind::Data))
                    .with_tracing(true),
            )?;
            let w = args.workload()?;
            let r = session.run(&cfg, w)?;
            let st = r.schedule.as_ref().expect("tracing was on");
            if let Some(fmt) = args.trace_out()? {
                return Ok(fmt.export(st));
            }
            let occ = r.occupancy.as_ref().expect("event engine");
            // Certify the trace against the occupancy tallies before
            // reporting anything derived from it. Multi-channel traces
            // carry appended interconnect spans and a composed makespan
            // the per-channel occupancy doesn't tally, so the exact
            // cross-check only applies to single-channel schedules.
            if r.channels.is_none() {
                st.verify(occ).map_err(anyhow::Error::msg)?;
            }
            let profile = crate::obs::PhaseProfile::from_trace(st);
            let metrics = crate::obs::MetricsRegistry::new();
            session.publish_metrics(&metrics);
            let mut out = format!(
                "profile: {} on {} (event engine)\nmakespan {} cycles, {} commands, {} reservations\n",
                r.label,
                r.workload,
                st.makespan,
                st.cmds.len(),
                st.spans.len(),
            );
            out.push_str(&profile.render(top));
            out.push_str("session metrics:\n");
            out.push_str(&metrics.to_json());
            Ok(out)
        }
        "trace" => {
            args.check_opts(&["config", "workload", "limit"])?;
            let cfg = args.config()?;
            let w = args.workload()?;
            let limit: usize = args
                .opts
                .get("limit")
                .map(|s| s.parse())
                .transpose()?
                .unwrap_or(60);
            let g = session.graph(w)?;
            let p = plan(&g, &cfg);
            let tr = generate(&g, &cfg, &p, model);
            let stats = tr.stats();
            Ok(format!(
                "{}\ncommands={} cross_bank={}B broadcast={}B near_bank={}B (hit {}B)\n",
                tr.dump(limit),
                stats.num_cmds,
                stats.cross_bank_total(),
                stats.broadcast,
                stats.near_bank_read + stats.near_bank_write,
                stats.near_bank_hit,
            ))
        }
        "validate" => {
            args.check_opts(&["config"])?;
            let cfg = args.config()?;
            // Reduced resolution keeps the f32 reference fast.
            let g = session.graph(Workload::ResNet18Small)?;
            let p = plan(&g, &cfg);
            let delta = crate::validate::validate_plan(&g, &p, 0xC0FFEE)
                .map_err(anyhow::Error::msg)?;
            Ok(format!(
                "functional validation of {} on {}: OK (max |Δ| = {delta})\n",
                cfg.label(),
                g.name
            ))
        }
        "cmdset" => {
            args.check_opts(&[])?;
            Ok("\
Custom PIM commands (Table I):
  PIMcore_CMP   Perform fused operations in all PIMcores
                flags: CONV_BN | CONV_BN_RELU | POOL | ADD_RELU
  GBcore_CMP    Perform operations in GBcore
                flags: POOL | ADD_RELU
  PIM_BK2LBUF   Data transfer between all banks and LBUFs (parallel)
  PIM_LBUF2BK   Data transfer between all LBUFs and banks (parallel)
  PIM_BK2GBUF   Data transfer between one bank and GBUF (sequential)
  PIM_GBUF2BK   Data transfer between GBUF and one bank (sequential)
"
            .to_string())
        }
        other => bail!("unknown subcommand {other:?}\n{USAGE}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn parse_subcommand_and_options() {
        let a = parse_args(&argv("simulate --config fused4:G32K_L256 --workload first8")).unwrap();
        assert_eq!(a.cmd, "simulate");
        assert_eq!(a.opts["config"], "fused4:G32K_L256");
        assert!(parse_args(&[]).is_err());
        assert!(parse_args(&argv("simulate --config")).is_err());
        assert!(parse_args(&argv("simulate config x")).is_err());
    }

    #[test]
    fn json_is_a_flag_not_a_key_value() {
        let a = parse_args(&argv("simulate --json --config aim:G2K_L0")).unwrap();
        assert!(a.flag("json"));
        assert_eq!(a.opts["config"], "aim:G2K_L0");
        let b = parse_args(&argv("sweep --json")).unwrap();
        assert!(b.flag("json"));
        assert!(!parse_args(&argv("sweep")).unwrap().flag("json"));
    }

    #[test]
    fn simulate_command_reports() {
        let a = parse_args(&argv("simulate --config aim:G2K_L0 --workload first8")).unwrap();
        let out = run(&a).unwrap();
        assert!(out.contains("AiM-like/G2K_L0"));
        assert!(out.contains("memory cycles"));
    }

    #[test]
    fn simulate_json_emits_schema() {
        let a =
            parse_args(&argv("simulate --config fused4:G8K_L128 --workload fig1 --json")).unwrap();
        let out = run(&a).unwrap();
        assert!(out.trim_start().starts_with('{'));
        assert!(out.contains("\"baseline\": \"AiM-like/G2K_L0\""));
        assert!(out.contains("\"config\": \"Fused4/G8K_L128\""));
        assert!(out.contains("\"norm\": {\"cycles\": "));
        assert!(out.contains("\"error\": null"));
    }

    #[test]
    fn sweep_json_has_one_row_per_point() {
        let a = parse_args(&argv(
            "sweep --systems fused4 --gbuf 2K,32K --lbuf 0 --workload fig1 --json",
        ))
        .unwrap();
        let out = run(&a).unwrap();
        assert_eq!(out.matches("\"config\":").count(), 2);
        assert_eq!(out.matches("\"error\": null").count(), 2);
    }

    #[test]
    fn simulate_event_engine_reports_utilization_everywhere() {
        // Acceptance: `simulate --engine event --json` runs for every
        // workload × system and reports per-resource utilization.
        use crate::workload::Workload;
        for w in Workload::ALL {
            for sys in System::ALL {
                let spec = format!(
                    "simulate --config {}:G8K_L128 --workload {} --engine event --json",
                    sys.name().to_ascii_lowercase(),
                    w.name()
                );
                let out = run(&parse_args(&argv(&spec)).unwrap())
                    .unwrap_or_else(|e| panic!("{spec}: {e}"));
                assert!(out.contains("\"engine\": \"event\""), "{spec}");
                assert!(out.contains("\"utilization\": {\"makespan\": "), "{spec}");
                assert!(out.contains("\"cores\": ["), "{spec}");
            }
        }
    }

    #[test]
    fn simulate_event_text_output_renders_occupancy() {
        let a = parse_args(&argv(
            "simulate --config fused4:G32K_L256 --workload fig1 --engine event",
        ))
        .unwrap();
        let out = run(&a).unwrap();
        assert!(out.contains("(event engine)"));
        assert!(out.contains("per-resource occupancy:"));
        assert!(out.contains("bus/GBUF port"));
        assert!(out.contains("cmd bus"));
        assert!(out.contains("bottleneck utilization:"));
        assert!(out.contains("host/bank (max)"));
        assert!(out.contains("act window (max)"));
        assert!(out.contains("host bank residency:"));
        assert!(out.contains("act-slot utilization:"));
        assert!(out.contains("slice pipelining:"));
        assert!(out.contains("slid slices"));
        // The analytic default prints no occupancy table.
        let b = parse_args(&argv("simulate --config fused4:G32K_L256 --workload fig1")).unwrap();
        let out = run(&b).unwrap();
        assert!(out.contains("(analytic engine)"));
        assert!(!out.contains("per-resource occupancy"));
        assert!(!out.contains("slice pipelining:"));
    }

    #[test]
    fn simulate_host_residency_flag() {
        // --host-residency off runs the interface-only model: no bank
        // cycles attributed to the host.
        let base = "simulate --config aim:G2K_L0 --workload fig1 --engine event --json";
        let a = parse_args(&argv(base)).unwrap();
        let on = run(&a).unwrap();
        let spec = format!("{} --host-residency off", base.trim_end_matches(" --json"));
        let b = parse_args(&argv(&format!("{spec} --json"))).unwrap();
        let off = run(&b).unwrap();
        let host_banks = |json: &str| -> u64 {
            let tail = json.split("\"host_banks\": [").nth(1).expect("field present");
            tail.split(']')
                .next()
                .unwrap()
                .split(',')
                .map(|v| v.trim().parse::<u64>().unwrap())
                .sum()
        };
        assert!(host_banks(&on) > 0, "resident host I/O charges banks: {on}");
        assert_eq!(host_banks(&off), 0, "interface-only host I/O leaves banks alone");
        // Bad values fail with usage.
        let bad = parse_args(&argv("simulate --workload fig1 --host-residency maybe")).unwrap();
        let e = run(&bad).unwrap_err().to_string();
        assert!(e.contains("--host-residency must be on|off"), "{e}");
        // Other subcommands reject the option.
        let e = run(&parse_args(&argv("fig5 --host-residency off")).unwrap())
            .unwrap_err()
            .to_string();
        assert!(e.contains("unknown option --host-residency"), "{e}");
    }

    #[test]
    fn simulate_slice_pipelining_flag() {
        // --slice-pipelining off pins slices at the rigid stagger: the
        // JSON utilization reports zero slid cycles.
        let base = "simulate --config aim:G2K_L0 --workload fig1 --engine event --json";
        let off_spec = format!("{base} --slice-pipelining off");
        let off = run(&parse_args(&argv(&off_spec)).unwrap()).unwrap();
        assert!(off.contains("\"slid\": 0"), "rigid stagger never slides: {off}");
        // The default (on) still serializes the field.
        let on = run(&parse_args(&argv(base)).unwrap()).unwrap();
        assert!(on.contains("\"slid\": "), "{on}");
        // Bad values fail with usage; other subcommands reject the flag.
        let bad = parse_args(&argv("simulate --workload fig1 --slice-pipelining maybe")).unwrap();
        let e = run(&bad).unwrap_err().to_string();
        assert!(e.contains("--slice-pipelining must be on|off"), "{e}");
        let e = run(&parse_args(&argv("sweep --slice-pipelining off")).unwrap())
            .unwrap_err()
            .to_string();
        assert!(e.contains("unknown option --slice-pipelining"), "{e}");
    }

    #[test]
    fn simulate_open_row_flag() {
        // Both settings run; every-command-reopens can never be faster
        // than open-row reuse on the same point.
        let cycles = |spec: &str| -> u64 {
            let out = run(&parse_args(&argv(spec)).unwrap()).unwrap();
            let tail = out.split("memory cycles : ").nth(1).expect("cycles line");
            tail.split_whitespace().next().unwrap().parse().unwrap()
        };
        let base = "simulate --config fused4:G32K_L256 --workload fig1";
        let on = cycles(base);
        let off = cycles(&format!("{base} --open-row off"));
        assert!(on <= off, "reuse can only help: on {on} > off {off}");
        // Bad values fail with usage; other subcommands reject the flag.
        let bad = parse_args(&argv("simulate --workload fig1 --open-row maybe")).unwrap();
        let e = run(&bad).unwrap_err().to_string();
        assert!(e.contains("--open-row must be on|off"), "{e}");
        let e = run(&parse_args(&argv("sweep --open-row off")).unwrap())
            .unwrap_err()
            .to_string();
        assert!(e.contains("unknown option --open-row"), "{e}");
    }

    #[test]
    fn sweep_accepts_engine_option() {
        let a = parse_args(&argv(
            "sweep --systems fused4 --gbuf 2K --lbuf 0 --workload fig1 --engine event --json",
        ))
        .unwrap();
        let out = run(&a).unwrap();
        assert!(out.contains("\"engine\": \"event\""));
        let bad = parse_args(&argv("simulate --engine warp --workload fig1")).unwrap();
        let e = run(&bad).unwrap_err().to_string();
        assert!(e.contains("unknown engine"), "{e}");
    }

    #[test]
    fn bad_options_error_with_usage() {
        let a = parse_args(&argv("simulate --bogus 1")).unwrap();
        let e = run(&a).unwrap_err().to_string();
        assert!(e.contains("unknown option --bogus"), "{e}");
        assert!(e.contains("usage: pimfused"), "{e}");
        let e = run(&parse_args(&argv("headline --config aim:G2K_L0")).unwrap())
            .unwrap_err()
            .to_string();
        assert!(e.contains("unknown option --config"), "{e}");
    }

    #[test]
    fn fig_commands_accept_engine() {
        let out = run(&parse_args(&argv("fig7 --engine event")).unwrap()).unwrap();
        assert!(out.contains("event"));
        assert!(out.contains("Fused4"));
        let e = run(&parse_args(&argv("fig5 --engine warp")).unwrap())
            .unwrap_err()
            .to_string();
        assert!(e.contains("unknown engine"), "{e}");
    }

    #[test]
    fn headline_and_takeaways_run() {
        let h = run(&parse_args(&argv("headline")).unwrap()).unwrap();
        assert!(h.contains("paper   : cycles=30.6%"));
        let t = run(&parse_args(&argv("takeaways")).unwrap()).unwrap();
        assert!(t.contains("replication"));
    }

    #[test]
    fn trace_command_dumps_table_i_commands() {
        let a = parse_args(&argv("trace --config fused16:G2K_L0 --workload fig3 --limit 10")).unwrap();
        let out = run(&a).unwrap();
        assert!(out.contains("PIMcore_CMP"));
        assert!(out.contains("cross_bank="));
    }

    #[test]
    fn cmdset_lists_all_six() {
        let out = run(&parse_args(&argv("cmdset")).unwrap()).unwrap();
        for c in ["PIMcore_CMP", "GBcore_CMP", "PIM_BK2LBUF", "PIM_LBUF2BK", "PIM_BK2GBUF", "PIM_GBUF2BK"] {
            assert!(out.contains(c), "{c} missing");
        }
    }

    #[test]
    fn unknown_subcommand_errors_with_usage() {
        let e = run(&parse_args(&argv("bogus")).unwrap()).unwrap_err().to_string();
        assert!(e.contains("unknown subcommand"));
        assert!(e.contains("usage: pimfused"));
    }

    #[test]
    fn serve_runs_and_reports() {
        let a = parse_args(&argv(
            "serve --config fused4:G32K_L256 --workload fig1 --rate 50000 --requests 100 --seed 7",
        ))
        .unwrap();
        let out = run(&a).unwrap();
        assert!(out.contains("serve: Fused4/G32K_L256 on Fig1_Example"), "{out}");
        assert!(out.contains("(event engine"), "serve defaults to the event engine: {out}");
        assert!(out.contains("p99 latency"), "{out}");
        assert!(out.contains("throughput"), "{out}");
        // Deterministic: same invocation, same bytes.
        assert_eq!(run(&a).unwrap(), out);
    }

    #[test]
    fn serve_json_and_csv_outputs() {
        let base = "serve --workload fig1 --rate 50000 --requests 100";
        let json = run(&parse_args(&argv(&format!("{base} --json"))).unwrap()).unwrap();
        assert!(json.trim_start().starts_with('{'), "{json}");
        assert!(json.contains("\"engine\": \"event\""), "{json}");
        assert!(json.contains("\"arrival\": \"poisson\""), "{json}");
        assert!(json.contains("\"p99_cycles\": "), "{json}");
        assert!(json.contains("\"throughput_rps\": "), "{json}");
        let csv = run(&parse_args(&argv(&format!("{base} --csv"))).unwrap()).unwrap();
        let header = csv.lines().next().unwrap();
        assert!(header.starts_with("config,system,workload,engine,arrival,rate_rps,"), "{header}");
        assert_eq!(csv.lines().count(), 2, "header + one row: {csv}");
    }

    #[test]
    fn serve_rates_sweeps_the_offered_load() {
        let a = parse_args(&argv(
            "serve --workload fig1 --rates 10000,20000,40000 --requests 100",
        ))
        .unwrap();
        let out = run(&a).unwrap();
        assert!(out.contains("serve sweep:"), "{out}");
        assert!(out.contains("rate req/s"), "{out}");
        assert_eq!(out.matches("req/s |").count(), 2, "two rate-ish headers: {out}");
        let json = run(&parse_args(&argv(
            "serve --workload fig1 --rates 10000,20000,40000 --requests 100 --json",
        ))
        .unwrap())
        .unwrap();
        assert_eq!(json.matches("\"rate_rps\":").count(), 3, "{json}");
    }

    #[test]
    fn serve_validates_its_options() {
        let err = |s: &str| run(&parse_args(&argv(s)).unwrap()).unwrap_err().to_string();
        let e = err("serve --workload fig1");
        assert!(e.contains("needs --rate"), "{e}");
        let e = err("serve --workload fig1 --rate 0");
        assert!(e.contains("--rate must be > 0"), "{e}");
        let e = err("serve --workload fig1 --rate -3");
        assert!(e.contains("--rate must be > 0"), "{e}");
        let e = err("serve --workload fig1 --rate 100 --batch 0");
        assert!(e.contains("--batch must be >= 1"), "{e}");
        let e = err("serve --workload fig1 --rate 100 --batch 8 --queue-depth 2");
        assert!(e.contains("--queue-depth must be >= --batch"), "{e}");
        let e = err("serve --workload fig1 --rate 100 --rates 1,2");
        assert!(e.contains("mutually exclusive"), "{e}");
        let e = err("serve --workload fig1 --rate 100 --json --csv");
        assert!(e.contains("--json and --csv are mutually exclusive"), "{e}");
        let e = err("serve --workload fig1 --rate abc");
        assert!(e.contains("--rate must be a number"), "{e}");
        let e = err("serve --workload fig1 --rate 100 --arrival sometimes");
        assert!(e.contains("unknown arrival process"), "{e}");
        let e = err("serve --workload fig1 --rate 100 --bogus 1");
        assert!(e.contains("unknown option --bogus"), "{e}");
        assert!(e.contains("usage: pimfused"), "{e}");
    }

    #[test]
    fn faults_option_parses_and_validates() {
        let a = parse_args(&argv(
            "simulate --config fused4:G8K_L128 --workload fig1 --engine event \
             --faults banks=4,cores=1,p=0.001,retries=3,seed=9",
        ))
        .unwrap();
        let out = run(&a).unwrap();
        assert!(out.contains("faults: "), "{out}");
        assert!(out.contains("replayed cycles"), "{out}");
        // Deterministic: same invocation, same bytes.
        assert_eq!(run(&a).unwrap(), out);

        let err = |s: &str| run(&parse_args(&argv(s)).unwrap()).unwrap_err().to_string();
        let e = err("simulate --workload fig1 --faults p=1.5");
        assert!(e.contains("--faults p must be in [0, 1]"), "{e}");
        assert!(e.contains("usage: pimfused"), "{e}");
        let e = err("simulate --workload fig1 --faults p=-0.1");
        assert!(e.contains("--faults p must be in [0, 1]"), "{e}");
        let e = err("simulate --workload fig1 --faults banks=16");
        assert!(e.contains("usage: pimfused"), "retiring every bank must fail: {e}");
        let e = err("simulate --workload fig1 --faults banks=two");
        assert!(e.contains("--faults banks must be an integer"), "{e}");
        let e = err("simulate --workload fig1 --faults junk=1");
        assert!(e.contains("unknown --faults key"), "{e}");
        let e = err("simulate --workload fig1 --faults banks");
        assert!(e.contains("--faults parts are key=value"), "{e}");
        let e = err("sweep --faults banks=1");
        assert!(e.contains("unknown option --faults"), "{e}");
    }

    #[test]
    fn degrade_sweeps_and_reports() {
        let a = parse_args(&argv(
            "degrade --config fused4:G8K_L128 --workload fig1 --requests 20",
        ))
        .unwrap();
        let out = run(&a).unwrap();
        assert!(out.contains("degrade: Fused4/G8K_L128 on Fig1_Example"), "{out}");
        assert!(out.contains("goodput_rps"), "{out}");
        assert_eq!(run(&a).unwrap(), out, "deterministic");
        let json = run(&parse_args(&argv(
            "degrade --config fused4:G8K_L128 --workload fig1 --requests 20 --json",
        ))
        .unwrap())
        .unwrap();
        assert!(json.contains("\"retired_banks\": 0"), "{json}");
        assert!(json.contains("\"retired_banks\": 12"), "worst case always measured: {json}");
        let csv = run(&parse_args(&argv(
            "degrade --config fused4:G8K_L128 --workload fig1 --requests 20 --csv",
        ))
        .unwrap())
        .unwrap();
        assert!(
            csv.lines().next().unwrap().starts_with("retired_banks,alive_cores,surviving_banks,"),
            "{csv}"
        );
        let err = |s: &str| run(&parse_args(&argv(s)).unwrap()).unwrap_err().to_string();
        let e = err("degrade --workload fig1 --step 0");
        assert!(e.contains("--step must be >= 1"), "{e}");
        let e = err("degrade --workload fig1 --engine event");
        assert!(e.contains("unknown option --engine"), "degrade is analytic-only: {e}");
        let e = err("degrade --workload fig1 --rate 0");
        assert!(e.contains("--rate must be > 0"), "{e}");
    }

    #[test]
    fn serve_deadline_and_retry_flags() {
        let json = run(&parse_args(&argv(
            "serve --workload fig1 --rate 50000 --requests 100 \
             --deadline 200000 --retries 2 --backoff 1000 --json",
        ))
        .unwrap())
        .unwrap();
        assert!(json.contains("\"deadline_cycles\": 200000"), "{json}");
        assert!(json.contains("\"client_retries\": 2"), "{json}");
        assert!(json.contains("\"backoff_cycles\": 1000"), "{json}");
        assert!(json.contains("\"dropped_queue_full\": "), "{json}");
        assert!(json.contains("\"dropped_deadline_shed\": "), "{json}");
        assert!(json.contains("\"dropped_deadline_miss\": "), "{json}");
        assert!(json.contains("\"dropped_retry_exhausted\": "), "{json}");
        assert!(json.contains("\"goodput_rps\": "), "{json}");
        // Text output surfaces the SLO line and the drop split.
        let text = run(&parse_args(&argv(
            "serve --workload fig1 --rate 50000 --requests 100 --deadline 200000",
        ))
        .unwrap())
        .unwrap();
        assert!(text.contains("deadline 200000 cyc"), "{text}");
        assert!(text.contains("drop split"), "{text}");
        assert!(text.contains("goodput"), "{text}");
        let e = run(&parse_args(&argv("serve --workload fig1 --rate 100 --deadline soon")).unwrap())
            .unwrap_err()
            .to_string();
        assert!(e.contains("--deadline must be an integer"), "{e}");
    }

    #[test]
    fn serve_default_queue_depth_fits_the_batch() {
        // --batch 100 with no --queue-depth must not trip the
        // queue>=batch validation: the default grows to fit.
        let a = parse_args(&argv(
            "serve --workload fig1 --rate 50000 --requests 50 --batch 100 --json",
        ))
        .unwrap();
        let out = run(&a).unwrap();
        assert!(out.contains("\"queue_depth\": 100"), "{out}");
    }

    #[test]
    fn profile_command_prints_phase_breakdown() {
        let a = parse_args(&argv("profile --config fused4:G32K_L256 --workload fig1 --top 3"))
            .unwrap();
        let out = run(&a).unwrap();
        assert!(out.contains("profile: Fused4/G32K_L256 on Fig1_Example"), "{out}");
        assert!(out.contains("makespan"), "{out}");
        assert!(out.contains("compute"), "{out}");
        assert!(out.contains("near-bank"), "{out}");
        assert!(out.contains("cross-bank"), "{out}");
        assert!(out.contains("stall"), "{out}");
        assert!(out.contains("top 3 commands by busy cycles:"), "{out}");
        assert!(out.contains("session metrics:"), "{out}");
        assert!(out.contains("\"session.points_run\": 1"), "{out}");
        // Deterministic: same invocation, same bytes.
        assert_eq!(run(&a).unwrap(), out);
        // --top must be an integer.
        let e = run(&parse_args(&argv("profile --workload fig1 --top many")).unwrap())
            .unwrap_err()
            .to_string();
        assert!(e.contains("--top must be an integer"), "{e}");
    }

    #[test]
    fn profile_trace_out_exports_the_timeline() {
        let json = run(&parse_args(&argv("profile --workload fig1 --trace-out chrome")).unwrap())
            .unwrap();
        assert!(json.trim_start().starts_with('{'), "{json}");
        assert!(json.contains("\"traceEvents\""), "{json}");
        assert!(json.contains("\"ph\": \"X\""), "{json}");
        assert!(json.contains("\"cat\": \"cmdbus\""), "{json}");
        assert!(json.contains("\"name\": \"process_name\""), "{json}");
        let csv = run(&parse_args(&argv("profile --workload fig1 --trace-out csv")).unwrap())
            .unwrap();
        assert!(csv.starts_with("cmd,node,kind,resource,res_index,start,end,busy,slid\n"), "{csv}");
        // perfetto is an accepted alias for the chrome format.
        let alias =
            run(&parse_args(&argv("profile --workload fig1 --trace-out perfetto")).unwrap())
                .unwrap();
        assert_eq!(alias, json);
        let e = run(&parse_args(&argv("profile --workload fig1 --trace-out bogus")).unwrap())
            .unwrap_err()
            .to_string();
        assert!(e.contains("--trace-out must be chrome|csv"), "{e}");
    }

    #[test]
    fn simulate_and_serve_accept_trace_out() {
        // simulate --trace-out defaults the engine to event.
        let out = run(&parse_args(&argv(
            "simulate --config aim:G2K_L0 --workload fig1 --trace-out chrome",
        ))
        .unwrap())
        .unwrap();
        assert!(out.contains("\"traceEvents\""), "{out}");
        let e = run(&parse_args(&argv(
            "simulate --workload fig1 --engine analytic --trace-out csv",
        ))
        .unwrap())
        .unwrap_err()
        .to_string();
        assert!(e.contains("--trace-out needs --engine event"), "{e}");
        // serve --trace-out exports the single-inference schedule.
        let out = run(&parse_args(&argv(
            "serve --workload fig1 --rate 50000 --requests 10 --trace-out csv",
        ))
        .unwrap())
        .unwrap();
        assert!(out.starts_with("cmd,node,kind,"), "{out}");
        let e = run(&parse_args(&argv(
            "serve --workload fig1 --rate 100 --trace-out chrome --json",
        ))
        .unwrap())
        .unwrap_err()
        .to_string();
        assert!(e.contains("mutually exclusive"), "{e}");
        // Subcommands without the flag reject it.
        let e = run(&parse_args(&argv("sweep --trace-out chrome")).unwrap())
            .unwrap_err()
            .to_string();
        assert!(e.contains("unknown option --trace-out"), "{e}");
    }

    #[test]
    fn sweep_small_grid() {
        let a = parse_args(&argv(
            "sweep --systems fused4 --gbuf 2K,32K --lbuf 0,256 --workload first8",
        ))
        .unwrap();
        let out = run(&a).unwrap();
        assert_eq!(out.matches("Fused4/").count(), 4);
    }

    #[test]
    fn simulate_channels_flag_reports_scale_out() {
        let a = parse_args(&argv(
            "simulate --config fused4:G8K_L128 --workload fig1 --engine event \
             --channels 2 --partition model",
        ))
        .unwrap();
        let out = run(&a).unwrap();
        assert!(out.contains("/c2-model"), "{out}");
        assert!(out.contains("channels: 2 (model partition"), "{out}");
        assert!(out.contains("interconnect:"), "{out}");
        assert_eq!(run(&a).unwrap(), out, "deterministic");
        // --channels 1 is byte-identical to a run without the flag.
        let base = "simulate --config fused4:G8K_L128 --workload fig1 --engine event";
        let plain = run(&parse_args(&argv(base)).unwrap()).unwrap();
        let one = run(&parse_args(&argv(&format!("{base} --channels 1"))).unwrap()).unwrap();
        assert_eq!(plain, one);
        assert!(!plain.contains("interconnect:"), "{plain}");
    }

    #[test]
    fn channels_bad_specs_are_rejected() {
        let err = |s: &str| run(&parse_args(&argv(s)).unwrap()).unwrap_err().to_string();
        let e = err("simulate --workload fig1 --channels 0");
        assert!(e.contains("--channels must be >= 1"), "{e}");
        let e = err("simulate --workload fig1 --channels two");
        assert!(e.contains("--channels must be an integer"), "{e}");
        let e = err("simulate --workload fig1 --channels 99");
        assert!(e.contains("exceeds the supported maximum"), "{e}");
        let e = err("simulate --workload fig1 --partition diagonal");
        assert!(e.contains("unknown partition"), "{e}");
        let e = err("sweep --channels 0,2");
        assert!(e.contains("--channels must be >= 1"), "{e}");
        let e = err("fig5 --channels 2");
        assert!(e.contains("unknown option --channels"), "{e}");
        // Retiring every channel (or any channel of a single-channel
        // config) fails the fault geometry check up front.
        let e = err("simulate --workload fig1 --faults channels=1");
        assert!(e.contains("must leave at least one"), "{e}");
        let e = err("simulate --workload fig1 --channels 2 --faults channels=2");
        assert!(e.contains("must leave at least one"), "{e}");
    }

    #[test]
    fn sweep_channels_axis_covers_both_partitions() {
        let a = parse_args(&argv(
            "sweep --systems fused4 --gbuf 2K --lbuf 0 --workload fig1 --channels 1,2",
        ))
        .unwrap();
        let out = run(&a).unwrap();
        // 1 system x 1 gbuf x 1 lbuf x {1,2} channels x both partitions.
        assert_eq!(out.matches("Fused4/").count(), 4, "{out}");
        assert!(out.contains("/c2-data"), "{out}");
        assert!(out.contains("/c2-model"), "{out}");
        // An explicit --partition pins the strategy.
        let json = run(&parse_args(&argv(
            "sweep --systems fused4 --gbuf 2K --lbuf 0 --workload fig1 \
             --channels 2 --partition model --json",
        ))
        .unwrap())
        .unwrap();
        assert_eq!(json.matches("\"config\":").count(), 1, "{json}");
        assert!(json.contains("/c2-model"), "{json}");
        assert!(json.contains("\"channels\": {"), "{json}");
        assert!(json.contains("\"interconnect_busy\": "), "{json}");
    }

    #[test]
    fn serve_accepts_channels() {
        let out = run(&parse_args(&argv(
            "serve --workload fig1 --rate 50000 --requests 100 --channels 2 --partition data",
        ))
        .unwrap())
        .unwrap();
        assert!(out.contains("/c2-data"), "{out}");
        assert!(out.contains("p99 latency"), "{out}");
        // degrade doesn't take the flag.
        let e = run(&parse_args(&argv("degrade --workload fig1 --channels 2")).unwrap())
            .unwrap_err()
            .to_string();
        assert!(e.contains("unknown option --channels"), "{e}");
    }

    #[test]
    fn profile_multi_channel_shows_cross_channel_phase() {
        let a = parse_args(&argv(
            "profile --config fused4:G8K_L128 --workload fig1 --channels 2 --partition model",
        ))
        .unwrap();
        let out = run(&a).unwrap();
        assert!(out.contains("profile: Fused4/G8K_L128/c2-model"), "{out}");
        assert!(out.contains("cross-chan"), "{out}");
        assert_eq!(run(&a).unwrap(), out, "deterministic");
        // Single-channel profiles keep the classic header.
        let plain = run(&parse_args(&argv(
            "profile --config fused4:G8K_L128 --workload fig1",
        ))
        .unwrap())
        .unwrap();
        assert!(!plain.contains("cross-chan"), "{plain}");
    }
}
