//! `pimfused` — the PIMfused reproduction CLI (leader entrypoint).
//!
//! Run `pimfused` with no arguments for usage. Typical session:
//!
//! ```text
//! $ pimfused headline
//! $ pimfused fig5
//! $ pimfused simulate --config fused4:G32K_L256 --workload full
//! $ pimfused sweep --systems fused4 --gbuf 2K,32K --lbuf 0,256 --json
//! $ pimfused trace --config fused16:G2K_L0 --workload fig3
//! ```
//!
//! Bad subcommands or options print the usage text and exit non-zero.

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match pimfused::cli::parse_args(&argv).and_then(|a| pimfused::cli::run(&a)) {
        Ok(out) => print!("{out}"),
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(1);
        }
    }
}
