//! # PIMfused — near-bank DRAM-PIM with fused-layer dataflow
//!
//! A from-scratch reproduction of *"PIMfused: Near-Bank DRAM-PIM with
//! Fused-layer Dataflow for CNN Data Transfer Optimization"* (Yang et al.,
//! cs.AR 2025): a GDDR6-AiM-like near-bank DRAM-PIM architecture, the
//! PIMfused hybrid dataflow, and the PPA profiling framework (Ramulator2-
//! like cycle simulator + Accelergy-like energy/area estimator) the paper
//! uses to evaluate it.
//!
//! ## Crate layout (see DESIGN.md §1 for the full inventory)
//!
//! * [`config`] — architecture geometry, buffer configs (`GmK_Ln`), DRAM
//!   timing, the three named systems (AiM-like / Fused16 / Fused4), and
//!   the [`config::Engine`] simulation-engine selector.
//! * [`cnn`] — CNN graph IR + ResNet18 builder (paper layer counting).
//! * [`dataflow`] — layer-by-layer and fused-layer mappers, halo math.
//! * [`trace`] — Table-I PIM command traces with per-node data-flow
//!   annotations, and their generator.
//! * [`sim`] — GDDR6 channel simulators (memory cycles): the analytic
//!   back-to-back engine ([`sim::engine`]) and the event-driven
//!   per-resource scheduler ([`sim::event`]).
//! * [`energy`] — component-level energy/area models @22nm.
//! * [`fault`] — seeded fault injection: retired banks, dead PIMcores,
//!   transient command errors, and the deterministic [`fault::FaultPlan`]
//!   that degraded execution remaps onto.
//! * [`ppa`] — PPA reports and normalization against the baseline.
//! * [`workload`] — the paper's workload scenarios (one table drives
//!   names, aliases and [`workload::Workload::ALL`]).
//! * [`coordinator`] — **Experiment API v2**: a memoizing
//!   [`coordinator::Session`] (baselines cached per workload × engine),
//!   the [`coordinator::Experiment`] builder, the
//!   [`coordinator::SweepGrid`] cartesian sweep runner (threaded,
//!   progress callbacks, engine axis) and [`coordinator::SweepResults`]
//!   with JSON/CSV serialization; plus [`coordinator::experiments`], the
//!   paper-figure registry.
//! * [`serve`] — request-stream serving simulator: open-loop arrivals,
//!   a bounded batching queue, and steady-state p50/p99/throughput on
//!   top of the memoized schedules ([`coordinator::Session::serve`] /
//!   `pimfused serve`).
//! * [`obs`] — observability: [`obs::ScheduleTrace`] timeline capture
//!   from the event scheduler's recording mode, Chrome-trace/CSV
//!   exporters, per-layer [`obs::PhaseProfile`]s, and the
//!   [`obs::MetricsRegistry`] (`pimfused profile`).
//! * [`runtime`] — PJRT loader for the JAX/Pallas AOT artifacts (stubbed
//!   unless built with the `pjrt` feature).
//! * [`validate`] — functional dataflow validator (real tensor movement).
//!
//! See the top-level `README.md` for the CLI quickstart and the
//! paper-figure reproduction guide, and `DESIGN.md` for the module-level
//! design reference (the §-references in doc comments point there).

// Public items must be documented. The modules the rustdoc pass has
// covered so far hold the line (the `docs` CI job runs `cargo doc` with
// `-D warnings`); the ones still carrying `allow(missing_docs)` below
// are the remaining frontier — remove an `allow` when you finish
// documenting that module.
#![warn(missing_docs)]

pub mod benchkit;
pub mod cli;
pub mod cnn;
pub mod coordinator;
pub mod dataflow;
pub mod energy;
pub mod fault;
pub mod obs;
pub mod ppa;
pub mod serve;
pub mod workload;
pub mod sim;
pub mod trace;
pub mod config;
#[allow(missing_docs)]
pub mod runtime;
pub mod util;
pub mod validate;
