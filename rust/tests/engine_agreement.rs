//! Engine-agreement invariants: the event-driven engine must tally the
//! exact same action counts as the analytic engine (so energy reports are
//! byte-identical), never exceed the analytic serial cycle total, and
//! never undercut the busiest single resource's occupancy. Scheduler-v2
//! legality (no command before a predecessor's completion, no
//! double-booked resource interval) is certified by the event engine's
//! audit over random configs × all workloads.

use pimfused::config::{ArchConfig, Engine, System};
use pimfused::coordinator::Session;
use pimfused::dataflow::{plan, CostModel};
use pimfused::ppa::PpaReport;
use pimfused::sim::event;
use pimfused::trace::gen::generate;
use pimfused::util::prop::{check_no_shrink, Gen};
use pimfused::workload::Workload;

fn pair(session: &Session, cfg: &ArchConfig, w: Workload) -> (PpaReport, PpaReport) {
    let analytic = session.run(&cfg.clone().with_engine(Engine::Analytic), w).unwrap();
    let event = session.run(&cfg.clone().with_engine(Engine::Event), w).unwrap();
    (analytic, event)
}

fn assert_agreement(analytic: &PpaReport, event: &PpaReport, ctx: &str) {
    assert_eq!(
        event.sim.actions, analytic.sim.actions,
        "{ctx}: engines must tally identical action counts"
    );
    assert_eq!(
        event.energy_pj, analytic.energy_pj,
        "{ctx}: identical actions must give byte-identical energy"
    );
    assert!(
        event.cycles <= analytic.cycles,
        "{ctx}: event {} must not exceed analytic {}",
        event.cycles,
        analytic.cycles
    );
    let occ = event.occupancy.expect("event engine reports occupancy");
    assert!(
        event.cycles >= occ.busiest(),
        "{ctx}: event {} below the busiest resource's occupancy {}",
        event.cycles,
        occ.busiest()
    );
    assert_eq!(occ.makespan, event.cycles, "{ctx}: makespan is the cycle count");
}

#[test]
fn engines_agree_on_every_workload_and_system() {
    let session = Session::new();
    for w in Workload::ALL {
        for sys in System::ALL {
            let cfg = ArchConfig::system(sys, 2048, 0);
            let (a, e) = pair(&session, &cfg, w);
            assert_agreement(&a, &e, &format!("{} on {sys:?}", w.name()));
        }
    }
}

#[test]
fn event_beats_serial_on_full_resnet18_everywhere() {
    // Acceptance: on end-to-end ResNet18 the event engine reports cycles
    // <= the analytic engine for every system, with identical action
    // counts (checked by assert_agreement).
    let session = Session::new();
    for sys in System::ALL {
        let cfg = ArchConfig::system(sys, 32 * 1024, 256);
        let (a, e) = pair(&session, &cfg, Workload::ResNet18Full);
        assert_agreement(&a, &e, &format!("ResNet18_Full on {sys:?}"));
    }
}

#[test]
fn engines_agree_on_random_configs() {
    // Random (system, buffers, workload) points over all Workload::ALL
    // plans: the agreement invariants are config-independent.
    let session = Session::new();
    check_no_shrink(
        "engine-agreement-random",
        24,
        |g: &mut Gen| {
            let sys = *g.choose(&System::ALL);
            let gbuf = *g.choose(&[2048usize, 8192, 32768]);
            let lbuf = *g.choose(&[0usize, 64, 256]);
            let w = *g.choose(&Workload::ALL);
            (sys, gbuf, lbuf, w)
        },
        |&(sys, gbuf, lbuf, w)| {
            let cfg = ArchConfig::system(sys, gbuf, lbuf);
            let (a, e) = pair(&session, &cfg, w);
            assert_agreement(&a, &e, &format!("{} on {}", w.name(), cfg.label()));
            true
        },
    );
}

#[test]
fn backfilled_schedules_stay_legal_on_random_configs() {
    // Property (scheduler v2): across random (system, buffers, workload)
    // points, the schedule audit replays the ready-heap schedule and
    // verifies that no command's issue starts before any predecessor's
    // completion and that the makespan is the latest completion.
    // Double-booking an interval on one resource is impossible to
    // observe from outside only because the timelines' reserve() asserts
    // non-overlap on every reservation — producing a schedule at all
    // certifies it, and this property run exercises that assert across
    // the whole config space.
    check_no_shrink(
        "schedule-legality",
        18,
        |g: &mut Gen| {
            let sys = *g.choose(&System::ALL);
            let gbuf = *g.choose(&[2048usize, 8192, 32768]);
            let lbuf = *g.choose(&[0usize, 64, 256]);
            let w = *g.choose(&Workload::ALL);
            (sys, gbuf, lbuf, w)
        },
        |&(sys, gbuf, lbuf, w)| {
            let cfg = ArchConfig::system(sys, gbuf, lbuf);
            let graph = w.graph();
            let p = plan(&graph, &cfg);
            let tr = generate(&graph, &cfg, &p, CostModel::default());
            let a = event::audit(&cfg, &tr)
                .unwrap_or_else(|e| panic!("{} on {}: {e}", w.name(), cfg.label()));
            a.starts.len() == tr.cmds.len() && a.dones.len() == tr.cmds.len()
        },
    );
}

#[test]
fn normalization_is_engine_consistent() {
    // Each engine normalizes against its own baseline, so the baseline
    // config itself is exactly 1.0 under both engines.
    let session = Session::new();
    for engine in Engine::ALL {
        let cfg = ArchConfig::baseline().with_engine(engine);
        let n = session.normalized(&cfg, Workload::ResNet18First8).unwrap();
        assert!((n.cycles - 1.0).abs() < 1e-12, "{engine:?} self-normalization");
        assert!((n.energy - 1.0).abs() < 1e-12);
    }
}
