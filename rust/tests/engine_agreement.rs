//! Engine-agreement invariants: the event-driven engine must tally the
//! exact same action counts as the analytic engine (so energy reports are
//! byte-identical), never exceed the analytic serial cycle total, and
//! never undercut the busiest single resource's occupancy. Scheduler-v2
//! legality (no command before a predecessor's completion, no
//! double-booked resource interval) is certified by the event engine's
//! audit over random configs × all workloads.

use pimfused::config::{ArchConfig, Engine, System};
use pimfused::coordinator::Session;
use pimfused::dataflow::{plan, CostModel};
use pimfused::ppa::PpaReport;
use pimfused::sim::event;
use pimfused::trace::gen::generate;
use pimfused::util::prop::{check_no_shrink, Gen};
use pimfused::workload::Workload;

fn pair(session: &Session, cfg: &ArchConfig, w: Workload) -> (PpaReport, PpaReport) {
    let analytic = session.run(&cfg.clone().with_engine(Engine::Analytic), w).unwrap();
    let event = session.run(&cfg.clone().with_engine(Engine::Event), w).unwrap();
    (analytic, event)
}

fn assert_agreement(analytic: &PpaReport, event: &PpaReport, ctx: &str) {
    assert_eq!(
        event.sim.actions, analytic.sim.actions,
        "{ctx}: engines must tally identical action counts"
    );
    assert_eq!(
        event.energy_pj, analytic.energy_pj,
        "{ctx}: identical actions must give byte-identical energy"
    );
    assert!(
        event.cycles <= analytic.cycles,
        "{ctx}: event {} must not exceed analytic {}",
        event.cycles,
        analytic.cycles
    );
    let occ = event.occupancy.expect("event engine reports occupancy");
    assert!(
        event.cycles >= occ.busiest(),
        "{ctx}: event {} below the busiest resource's occupancy {}",
        event.cycles,
        occ.busiest()
    );
    assert_eq!(occ.makespan, event.cycles, "{ctx}: makespan is the cycle count");
}

#[test]
fn engines_agree_on_every_workload_and_system() {
    let session = Session::new();
    for w in Workload::ALL {
        for sys in System::ALL {
            let cfg = ArchConfig::system(sys, 2048, 0);
            let (a, e) = pair(&session, &cfg, w);
            assert_agreement(&a, &e, &format!("{} on {sys:?}", w.name()));
        }
    }
}

#[test]
fn event_beats_serial_on_full_resnet18_everywhere() {
    // Acceptance: on end-to-end ResNet18 the event engine reports cycles
    // <= the analytic engine for every system, with identical action
    // counts (checked by assert_agreement).
    let session = Session::new();
    for sys in System::ALL {
        let cfg = ArchConfig::system(sys, 32 * 1024, 256);
        let (a, e) = pair(&session, &cfg, Workload::ResNet18Full);
        assert_agreement(&a, &e, &format!("ResNet18_Full on {sys:?}"));
    }
}

#[test]
fn engines_agree_on_random_configs() {
    // Random (system, buffers, workload, host-residency,
    // slice-pipelining, open-row) points over all Workload::ALL plans:
    // the agreement invariants are config-independent and hold for both
    // host models (resident bank slices and interface-only), both slice
    // placements (sliding and rigid stagger), and both row models
    // (open-row reuse and every-command-reopens).
    let session = Session::new();
    check_no_shrink(
        "engine-agreement-random",
        24,
        |g: &mut Gen| {
            let sys = *g.choose(&System::ALL);
            let gbuf = *g.choose(&[2048usize, 8192, 32768]);
            let lbuf = *g.choose(&[0usize, 64, 256]);
            let w = *g.choose(&Workload::ALL);
            let residency = g.bool();
            let pipelining = g.bool();
            let reuse = g.bool();
            (sys, gbuf, lbuf, w, residency, pipelining, reuse)
        },
        |&(sys, gbuf, lbuf, w, residency, pipelining, reuse)| {
            let cfg = ArchConfig::system(sys, gbuf, lbuf)
                .with_host_residency(residency)
                .with_slice_pipelining(pipelining)
                .with_open_row_reuse(reuse);
            let (a, e) = pair(&session, &cfg, w);
            assert_agreement(
                &a,
                &e,
                &format!(
                    "{} on {} (residency {residency}, pipelining {pipelining}, open-row {reuse})",
                    w.name(),
                    cfg.label()
                ),
            );
            true
        },
    );
}

#[test]
fn backfilled_schedules_stay_legal_on_random_configs() {
    // Property (scheduler v2 + host residency): across random (system,
    // buffers, workload, residency) points, the schedule audit replays
    // the ready-heap schedule and independently re-certifies it — no
    // command's issue before any predecessor's completion, makespan =
    // latest completion, no double-booked interval on any resource
    // (re-checked from the recorded reservations, not just reserve()'s
    // asserts), host bank slices exactly on their annotated destination
    // banks, and every row activation covered by a legal tFAW/tRRD slot.
    check_no_shrink(
        "schedule-legality",
        18,
        |g: &mut Gen| {
            let sys = *g.choose(&System::ALL);
            let gbuf = *g.choose(&[2048usize, 8192, 32768]);
            let lbuf = *g.choose(&[0usize, 64, 256]);
            let w = *g.choose(&Workload::ALL);
            let residency = g.bool();
            let pipelining = g.bool();
            let reuse = g.bool();
            (sys, gbuf, lbuf, w, residency, pipelining, reuse)
        },
        |&(sys, gbuf, lbuf, w, residency, pipelining, reuse)| {
            let cfg = ArchConfig::system(sys, gbuf, lbuf)
                .with_host_residency(residency)
                .with_slice_pipelining(pipelining)
                .with_open_row_reuse(reuse);
            let graph = w.graph();
            let p = plan(&graph, &cfg);
            let tr = generate(&graph, &cfg, &p, CostModel::default());
            let ctx = format!(
                "{} on {} (residency {residency}, pipelining {pipelining}, open-row {reuse})",
                w.name(),
                cfg.label()
            );
            let a = event::audit(&cfg, &tr).unwrap_or_else(|e| panic!("{ctx}: {e}"));
            // The audit's certified host-bank traffic exists exactly when
            // residency is on (every generated trace has host I/O).
            assert_eq!(a.host_bank_cycles > 0, residency, "{ctx}");
            assert!(a.act_window_cycles > 0, "{ctx}: traces always activate rows");
            // The rigid stagger never slides a slice; the audit would
            // have rejected one outright.
            if !pipelining {
                assert_eq!(a.slid_cycles, 0, "{ctx}");
            }
            a.starts.len() == tr.cmds.len() && a.dones.len() == tr.cmds.len()
        },
    );
}

#[test]
fn host_residency_charges_banks_during_host_phases_on_resnet18() {
    // Targeted regression (ISSUE 4 acceptance): with host residency on,
    // the event engine's bank occupancy on full ResNet18 is strictly
    // higher than the pre-change (interface-only) model for every
    // system, the extra occupancy is exactly the audit-certified host
    // slices, and banks are demonstrably busy *during* the host phases.
    use pimfused::trace::CmdKind;
    for sys in System::ALL {
        let on = ArchConfig::system(sys, 8192, 128).with_engine(Engine::Event);
        let off = on.clone().with_host_residency(false);
        let graph = Workload::ResNet18Full.graph();
        let p = plan(&graph, &on);
        let tr = generate(&graph, &on, &p, CostModel::default());
        let ev_on = event::simulate(&on, &tr);
        let ev_off = event::simulate(&off, &tr);
        let banks_on: u64 = ev_on.occupancy.bank_busy.iter().sum();
        let banks_off: u64 = ev_off.occupancy.bank_busy.iter().sum();
        assert!(
            banks_on > banks_off,
            "{sys:?}: resident bank occupancy {banks_on} must exceed interface-only {banks_off}"
        );
        assert_eq!(banks_on - banks_off, ev_on.occupancy.host_bank_total(), "{sys:?}");

        // Banks are busy during the host write's scheduled window: its
        // first bank slice begins as soon as the data phase does.
        let a = event::audit(&on, &tr).unwrap_or_else(|e| panic!("{sys:?}: {e}"));
        assert_eq!(a.host_bank_cycles, ev_on.occupancy.host_bank_total(), "{sys:?}");
        let hw = tr
            .cmds
            .iter()
            .position(|c| matches!(c.kind, CmdKind::HostWrite { .. }))
            .expect("trace writes the input");
        assert!(
            ev_on.occupancy.host_bank_busy.iter().any(|&b| b > 0),
            "{sys:?}: some bank must carry host slices"
        );
        assert!(a.dones[hw] > a.starts[hw], "{sys:?}: host phase occupies a real window");

        // Both runs keep the three agreement invariants.
        for (cfg, ev) in [(&on, &ev_on), (&off, &ev_off)] {
            let an = pimfused::sim::simulate(cfg, &tr);
            assert_eq!(ev.result.actions, an.actions, "{sys:?}");
            assert!(ev.result.cycles <= an.cycles, "{sys:?}");
            assert!(ev.result.cycles >= ev.occupancy.busiest(), "{sys:?}");
        }
    }
}

#[test]
fn slice_pipelining_never_slows_resnet18() {
    // Pinned acceptance (ISSUE 5): on full ResNet18, letting slices
    // slide never *increases* event cycles versus the rigid stagger, for
    // every system. Per command the sliding constraint set is strictly
    // weaker than the rigid one (a command never starts later), which
    // makes this hold in practice — but greedy list schedulers admit
    // anomalies in principle, so treat this as an empirical regression
    // pin: if a model change trips it, diff the two schedules before
    // hunting for a scheduler bug. Both runs must also keep all three
    // engine-agreement invariants.
    for sys in System::ALL {
        let on = ArchConfig::system(sys, 8192, 128).with_engine(Engine::Event);
        let off = on.clone().with_slice_pipelining(false);
        let graph = Workload::ResNet18Full.graph();
        let p = plan(&graph, &on);
        let tr = generate(&graph, &on, &p, CostModel::default());
        let ev_on = event::simulate(&on, &tr);
        let ev_off = event::simulate(&off, &tr);
        assert!(
            ev_on.result.cycles <= ev_off.result.cycles,
            "{sys:?}: sliding {} must not exceed rigid {}",
            ev_on.result.cycles,
            ev_off.result.cycles
        );
        // The rigid run never slides; both runs' audits certify legal
        // schedules and agree with the occupancy's slid tally.
        assert_eq!(ev_off.occupancy.slid_slices, 0, "{sys:?}");
        let a_on = event::audit(&on, &tr).unwrap_or_else(|e| panic!("{sys:?}: {e}"));
        let a_off = event::audit(&off, &tr).unwrap_or_else(|e| panic!("{sys:?}: {e}"));
        assert_eq!(a_on.slid_cycles, ev_on.occupancy.slid_slices, "{sys:?}");
        assert_eq!(a_off.slid_cycles, 0, "{sys:?}");
        for (cfg, ev) in [(&on, &ev_on), (&off, &ev_off)] {
            let an = pimfused::sim::simulate(cfg, &tr);
            assert_eq!(ev.result.actions, an.actions, "{sys:?}");
            assert!(ev.result.cycles <= an.cycles, "{sys:?}");
            assert!(ev.result.cycles >= ev.occupancy.busiest(), "{sys:?}");
        }
    }
}

#[test]
fn open_row_never_slows_resnet18() {
    // Pinned acceptance (ISSUE 9): on full ResNet18, letting banks keep
    // rows open never *increases* event cycles versus the
    // every-command-reopens model, for every system. Per command the
    // reuse expansion only ever subtracts one row-open charge, so the
    // serial sum shrinks monotonically; the greedy list scheduler could
    // in principle turn shorter commands into a longer schedule, so
    // treat this as an empirical regression pin. Both runs must also
    // audit and keep all three engine-agreement invariants.
    for sys in System::ALL {
        let on = ArchConfig::system(sys, 8192, 128).with_engine(Engine::Event);
        let off = on.clone().with_open_row_reuse(false);
        let graph = Workload::ResNet18Full.graph();
        let p = plan(&graph, &on);
        let tr = generate(&graph, &on, &p, CostModel::default());
        let ev_on = event::simulate(&on, &tr);
        let ev_off = event::simulate(&off, &tr);
        assert!(
            ev_on.result.cycles <= ev_off.result.cycles,
            "{sys:?}: reuse {} must not exceed reopen-always {}",
            ev_on.result.cycles,
            ev_off.result.cycles
        );
        // Reuse off tracks nothing; the audits replay the open-row state
        // machine and certify every waived charge (acceptance: certified
        // open-row replay on full ResNet18 for every system).
        assert_eq!(ev_off.result.open_row_hits, 0, "{sys:?}");
        let a_on = event::audit(&on, &tr).unwrap_or_else(|e| panic!("{sys:?}: {e}"));
        let a_off = event::audit(&off, &tr).unwrap_or_else(|e| panic!("{sys:?}: {e}"));
        assert_eq!(
            a_on.waived_open_cycles,
            ev_on.result.open_row_hits * on.timing.row_open_cycles(),
            "{sys:?}"
        );
        assert_eq!(a_off.waived_open_cycles, 0, "{sys:?}");
        for (cfg, ev) in [(&on, &ev_on), (&off, &ev_off)] {
            let an = pimfused::sim::simulate(cfg, &tr);
            assert_eq!(ev.result.actions, an.actions, "{sys:?}");
            assert_eq!(ev.result.open_row_hits, an.open_row_hits, "{sys:?}");
            assert!(ev.result.cycles <= an.cycles, "{sys:?}");
            assert!(ev.result.cycles >= ev.occupancy.busiest(), "{sys:?}");
        }
    }
}

#[test]
fn normalization_is_engine_consistent() {
    // Each (engine, host-residency, slice-pipelining, open-row)
    // combination normalizes against its own baseline, so the baseline
    // config itself is exactly 1.0 under every combination — no ratio
    // ever mixes models.
    let session = Session::new();
    for engine in Engine::ALL {
        for residency in [true, false] {
            for pipelining in [true, false] {
                for reuse in [true, false] {
                    let cfg = ArchConfig::baseline()
                        .with_engine(engine)
                        .with_host_residency(residency)
                        .with_slice_pipelining(pipelining)
                        .with_open_row_reuse(reuse);
                    let n = session.normalized(&cfg, Workload::ResNet18First8).unwrap();
                    assert!(
                        (n.cycles - 1.0).abs() < 1e-12,
                        "{engine:?} residency={residency} pipelining={pipelining} open-row={reuse}"
                    );
                    assert!((n.energy - 1.0).abs() < 1e-12);
                }
            }
        }
    }
}
