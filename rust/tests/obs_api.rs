//! Cross-layer tests of the observability surface (`pimfused::obs`):
//! captured schedule traces certified against the occupancy tallies
//! across the config grid, byte-exact exporter goldens on a synthetic
//! schedule, phase attribution, metrics publishing, and the guarantee
//! that tracing/metering never perturbs the numbers.

use pimfused::config::{ArchConfig, Engine, System};
use pimfused::coordinator::{Session, SweepGrid, SweepPoint};
use pimfused::dataflow::{plan, CostModel};
use pimfused::obs::{
    chrome_trace_json, trace_csv, BenchRecord, CmdMeta, MetricsRegistry, PhaseProfile,
    ResourceClass, ResourceId, ScheduleTrace, TraceFormat, TraceSpan, TRACE_CSV_HEADER,
};
use pimfused::serve::{simulate_stream_metered, ServeConfig, ServeDriver};
use pimfused::sim::event;
use pimfused::trace::gen::generate;
use pimfused::trace::Trace;
use pimfused::workload::Workload;

/// Build the event-engine trace for a workload the same way the
/// coordinator pipeline does.
fn trace_for(session: &Session, cfg: &ArchConfig, w: Workload) -> Trace {
    let g = session.graph(w).unwrap();
    let p = plan(&g, cfg);
    generate(&g, cfg, &p, CostModel::default())
}

/// Every captured trace must certify against its own run's occupancy,
/// and recording must not perturb the schedule, over the full
/// system × residency × pipelining × workload grid.
#[test]
fn captured_traces_certify_across_the_config_grid() {
    let session = Session::new();
    for sys in System::ALL {
        for (hr, sp) in [(true, true), (true, false), (false, true), (false, false)] {
            let cfg = ArchConfig::system(sys, 32 * 1024, 256)
                .with_engine(Engine::Event)
                .with_host_residency(hr)
                .with_slice_pipelining(sp);
            for w in [Workload::Fig1, Workload::Fig3, Workload::ResNet18Small] {
                let tr = trace_for(&session, &cfg, w);
                let (report, st) = ScheduleTrace::capture(&cfg, &tr);
                let plain = event::simulate(&cfg, &tr);
                assert_eq!(plain, report, "recording mode must not perturb the schedule");
                st.verify(&report.occupancy).unwrap_or_else(|e| {
                    panic!("{} {} hr={hr} sp={sp}: {e}", sys.name(), w.name())
                });
                assert_eq!(st.cmds.len(), tr.cmds.len());
                assert!(!st.spans.is_empty());
            }
        }
    }
}

/// The paper's acceptance check: on full ResNet18, the exported trace's
/// per-resource-class busy totals equal the [`ResourceOccupancy`]
/// tallies exactly, and both exporters stay structurally sound at scale.
///
/// [`ResourceOccupancy`]: pimfused::sim::ResourceOccupancy
#[test]
fn resnet18_trace_busy_totals_match_occupancy_exactly() {
    let session = Session::new();
    let cfg = ArchConfig::system(System::Fused4, 32 * 1024, 256).with_engine(Engine::Event);
    let tr = trace_for(&session, &cfg, Workload::ResNet18Full);
    let (report, st) = ScheduleTrace::capture(&cfg, &tr);
    let occ = &report.occupancy;
    st.verify(occ).unwrap();

    let busy_of = |class: ResourceClass| -> u64 {
        st.spans.iter().filter(|s| s.res.class() == class).map(|s| s.busy).sum()
    };
    assert_eq!(busy_of(ResourceClass::CmdBus), occ.cmdbus_busy);
    assert_eq!(busy_of(ResourceClass::Bus), occ.bus_busy);
    assert_eq!(busy_of(ResourceClass::Gbcore), occ.gbcore_busy);
    assert_eq!(busy_of(ResourceClass::Host), occ.host_busy);
    assert_eq!(busy_of(ResourceClass::Core), occ.core_busy.iter().sum::<u64>());
    assert_eq!(busy_of(ResourceClass::Bank), occ.bank_busy.iter().sum::<u64>());
    let act_reserved: u64 = st
        .spans
        .iter()
        .filter(|s| s.res.class() == ResourceClass::Act)
        .map(|s| s.end - s.start)
        .sum();
    assert_eq!(act_reserved, occ.act_busy.iter().sum::<u64>());

    let json = chrome_trace_json(&st);
    assert!(json.starts_with("{\n  \"displayTimeUnit\": \"ns\",\n  \"traceEvents\": [\n"));
    assert!(json.ends_with("  ]\n}\n"));
    assert_eq!(json.matches("\"ph\": \"X\"").count(), st.spans.len());
    let csv = trace_csv(&st);
    assert!(csv.starts_with(TRACE_CSV_HEADER));
    assert_eq!(csv.lines().count(), st.spans.len() + 1);
}

/// A tiny hand-built schedule whose exports are computed by hand: two
/// commands, four spans, one indexed resource. Pins both wire formats
/// byte-for-byte.
fn tiny_trace() -> ScheduleTrace {
    ScheduleTrace {
        makespan: 20,
        num_cores: 1,
        num_banks: 2,
        num_groups: 1,
        cmds: vec![
            CmdMeta { node: 1, kind: "PIM_BK2GBUF", start: 0, done: 12 },
            CmdMeta { node: 2, kind: "HOST_READ", start: 12, done: 20 },
        ],
        spans: vec![
            TraceSpan {
                cmd: 0,
                node: 1,
                kind: "PIM_BK2GBUF",
                res: ResourceId::CmdBus,
                start: 0,
                end: 2,
                busy: 2,
                slid: 0,
            },
            TraceSpan {
                cmd: 0,
                node: 1,
                kind: "PIM_BK2GBUF",
                res: ResourceId::Bus,
                start: 2,
                end: 10,
                busy: 8,
                slid: 0,
            },
            TraceSpan {
                cmd: 0,
                node: 1,
                kind: "PIM_BK2GBUF",
                res: ResourceId::Bank(1),
                start: 2,
                end: 12,
                busy: 8,
                slid: 3,
            },
            TraceSpan {
                cmd: 1,
                node: 2,
                kind: "HOST_READ",
                res: ResourceId::Host,
                start: 12,
                end: 20,
                busy: 8,
                slid: 0,
            },
        ],
    }
}

const TINY_CHROME: &str = r#"{
  "displayTimeUnit": "ns",
  "traceEvents": [
    {"name": "process_name", "ph": "M", "pid": 1, "args": {"name": "cmdbus"}},
    {"name": "process_name", "ph": "M", "pid": 2, "args": {"name": "bus"}},
    {"name": "process_name", "ph": "M", "pid": 4, "args": {"name": "host"}},
    {"name": "process_name", "ph": "M", "pid": 7, "args": {"name": "bank"}},
    {"name": "thread_name", "ph": "M", "pid": 1, "tid": 0, "args": {"name": "cmdbus"}},
    {"name": "thread_name", "ph": "M", "pid": 2, "tid": 0, "args": {"name": "bus"}},
    {"name": "thread_name", "ph": "M", "pid": 4, "tid": 0, "args": {"name": "host"}},
    {"name": "thread_name", "ph": "M", "pid": 7, "tid": 1, "args": {"name": "bank1"}},
    {"name": "PIM_BK2GBUF", "cat": "cmdbus", "ph": "X", "ts": 0, "dur": 2, "pid": 1, "tid": 0, "args": {"cmd": 0, "node": 1, "busy": 2, "slid": 0}},
    {"name": "PIM_BK2GBUF", "cat": "bus", "ph": "X", "ts": 2, "dur": 8, "pid": 2, "tid": 0, "args": {"cmd": 0, "node": 1, "busy": 8, "slid": 0}},
    {"name": "PIM_BK2GBUF", "cat": "bank", "ph": "X", "ts": 2, "dur": 10, "pid": 7, "tid": 1, "args": {"cmd": 0, "node": 1, "busy": 8, "slid": 3}},
    {"name": "HOST_READ", "cat": "host", "ph": "X", "ts": 12, "dur": 8, "pid": 4, "tid": 0, "args": {"cmd": 1, "node": 2, "busy": 8, "slid": 0}}
  ]
}
"#;

const TINY_CSV: &str = "cmd,node,kind,resource,res_index,start,end,busy,slid
0,1,PIM_BK2GBUF,cmdbus,0,0,2,2,0
0,1,PIM_BK2GBUF,bus,0,2,10,8,0
0,1,PIM_BK2GBUF,bank,1,2,12,8,3
1,2,HOST_READ,host,0,12,20,8,0
";

#[test]
fn chrome_trace_golden_is_byte_exact() {
    let t = tiny_trace();
    assert_eq!(chrome_trace_json(&t), TINY_CHROME);
    assert_eq!(TraceFormat::Chrome.export(&t), TINY_CHROME);
}

#[test]
fn trace_csv_golden_is_byte_exact() {
    let t = tiny_trace();
    assert_eq!(trace_csv(&t), TINY_CSV);
    assert_eq!(TraceFormat::Csv.export(&t), TINY_CSV);
}

/// Phase attribution on the hand-built schedule, checked against hand
/// computation: the cross-bank move's bus+bank busy lands in
/// `cross_bank`, its issue slot in `cmdbus`, the host read in `host`,
/// and `stall` is the window minus the union of busy intervals.
#[test]
fn phase_attribution_matches_hand_computation() {
    let t = tiny_trace();
    let p = PhaseProfile::from_trace(&t);
    assert_eq!(p.makespan, 20);
    assert_eq!(p.layers.len(), 2);

    let l1 = &p.layers[0];
    assert_eq!((l1.node, l1.cmds, l1.start, l1.end), (1, 1, 0, 12));
    assert_eq!(l1.cmdbus, 2);
    assert_eq!(l1.cross_bank, 16, "bus 8 + bank 8");
    assert_eq!((l1.compute, l1.near_bank, l1.host, l1.act_window), (0, 0, 0, 0));
    // Busy intervals (0,2), (2,10), (2,10) union to (0,10); window is 12.
    assert_eq!(l1.stall, 2);

    let l2 = &p.layers[1];
    assert_eq!((l2.node, l2.cmds, l2.start, l2.end), (2, 1, 12, 20));
    assert_eq!(l2.host, 8);
    assert_eq!(l2.stall, 0);

    assert_eq!(p.top.len(), 2);
    assert_eq!((p.top[0].cmd, p.top[0].busy), (0, 18));
    assert_eq!((p.top[1].cmd, p.top[1].busy), (1, 8));
    assert_eq!(p.top_k(1).len(), 1);
    assert_eq!(p.top_k(99).len(), 2);

    let rendered = p.render(2);
    assert!(rendered.contains("total"));
    assert!(rendered.contains("top 2 commands by busy cycles:"));
    assert!(rendered.contains("PIM_BK2GBUF"));
}

/// `ArchConfig::tracing` controls capture through the session pipeline:
/// on → a certified [`ScheduleTrace`] rides on the report; off (or the
/// analytic engine) → `None`, and the numbers are identical either way.
#[test]
fn session_tracing_flag_controls_schedule_capture() {
    let session = Session::new();
    let cfg = ArchConfig::system(System::Fused4, 32 * 1024, 256).with_engine(Engine::Event);

    let off = session.run(&cfg, Workload::Fig1).unwrap();
    assert!(off.schedule.is_none(), "tracing defaults off");
    assert!(off.phase_profile().is_none());

    let on = session.run(&cfg.clone().with_tracing(true), Workload::Fig1).unwrap();
    let st = on.schedule.as_ref().expect("tracing on captures a schedule");
    st.verify(on.occupancy.as_ref().unwrap()).unwrap();
    assert_eq!(off.cycles, on.cycles, "tracing must not change the result");
    assert_eq!(off.occupancy, on.occupancy);
    let prof = on.phase_profile().expect("profile rides on the traced report");
    assert_eq!(prof.makespan, on.occupancy.as_ref().unwrap().makespan);

    let analytic = ArchConfig::system(System::Fused4, 32 * 1024, 256).with_tracing(true);
    let an = session.run(&analytic, Workload::Fig1).unwrap();
    assert!(an.schedule.is_none(), "the analytic engine has no schedule to trace");
}

/// Sweep serialization is byte-identical with tracing on or off — the
/// schedule is observability-only and never leaks into reports.
#[test]
fn tracing_does_not_change_sweep_serialization() {
    let session = Session::new();
    let run = |tracing: bool| {
        let cfg = ArchConfig::system(System::Fused4, 32 * 1024, 256)
            .with_engine(Engine::Event)
            .with_tracing(tracing);
        let grid = SweepGrid::from_points(vec![SweepPoint { cfg, workload: Workload::Fig1 }]);
        grid.run(&session).unwrap()
    };
    let off = run(false);
    let on = run(true);
    assert_eq!(off.to_json(), on.to_json());
    assert_eq!(off.to_csv(), on.to_csv());
    assert_eq!(off.table(), on.table());
}

/// Session, sweep, serving driver, and serving report all publish into
/// one registry, and the serving loop's live tap (queue-depth and
/// latency series) records exactly one sample per dispatch/completion
/// without changing the report.
#[test]
fn metrics_registry_collects_all_publishers() {
    let session = Session::new();
    let cfg = ArchConfig::system(System::Fused4, 32 * 1024, 256).with_engine(Engine::Event);
    let m = MetricsRegistry::new();

    let grid = SweepGrid::from_points(vec![SweepPoint {
        cfg: cfg.clone(),
        workload: Workload::Fig1,
    }]);
    let results = grid.run(&session).unwrap();
    results.publish_metrics(&m);
    session.publish_metrics(&m);
    assert_eq!(m.counter("sweep.points"), 1);
    assert_eq!(m.counter("sweep.errors"), 0);
    assert_eq!(m.series_len("sweep.cycles"), 1);
    assert!(m.counter("session.points_run") >= 1);

    let single = session.run(&cfg, Workload::Fig1).unwrap().cycles.max(1);
    let rate = 1.2 * cfg.timing.clock_hz() / single as f64;
    let sc = ServeConfig::new(cfg.clone(), Workload::Fig1, rate)
        .requests(200)
        .batch(4)
        .queue_depth(32);
    let driver = ServeDriver::new(&session);
    let r = driver.run(&sc).unwrap();
    let prof = driver.profile(Workload::Fig1, &cfg).unwrap();

    let tap = MetricsRegistry::new();
    let r_tap = simulate_stream_metered(&sc, prof, Some(&tap));
    assert_eq!(r_tap, r, "metering must not change the report");
    assert_eq!(tap.series_len("serve.queue_depth"), r.batches);
    assert_eq!(tap.series_len("serve.latency_cycles"), r.completed);

    r.publish_metrics(&tap);
    driver.publish_metrics(&tap);
    assert_eq!(tap.counter("serve.requests"), 200);
    assert_eq!(tap.counter("serve.completed"), r.completed as u64);
    assert_eq!(tap.counter("serve.schedule_runs"), 1);
    assert!(tap.gauge_value("serve.latency_p99").is_some());

    let snapshot = tap.to_json();
    assert!(snapshot.starts_with("{\n  \"schema\": \"pimfused-metrics-v1\",\n"));
    assert_eq!(snapshot.matches('{').count(), snapshot.matches('}').count());
}

/// The unified bench schema round-trips to disk byte-for-byte — the
/// `--json` path `bench_sched` / `bench_serve` use.
#[test]
fn bench_record_round_trips_to_disk() {
    let rec = BenchRecord::new("bench_obs_api", "smoke");
    rec.metrics.gauge("sched.worst_ratio", 1.25);
    rec.metrics.add("sched.systems", 3);
    let path = std::env::temp_dir().join("pimfused_obs_api_bench.json");
    rec.write(&path).unwrap();
    let back = std::fs::read_to_string(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(back, rec.to_json());
    assert!(back.contains("\"bench\": \"bench_obs_api\""));
    assert!(back.contains("\"mode\": \"smoke\""));
    assert!(back.contains("\"sched.worst_ratio\": 1.25"));
}
