//! Cross-channel certification suite (DESIGN.md §12): the multi-channel
//! scale-out axis must preserve every single-channel engine invariant —
//! actions and energy engine-equal, event ≤ analytic, per-channel
//! schedules audit-legal — over random configs × workloads × channel
//! counts × both partitions; `channels = 1` must stay byte-identical to
//! the pre-axis pipeline; the scaling laws must hold (data-parallel
//! never slower with more channels, model-parallel sub-linear once the
//! interconnect is contended); and the sweep/serve paths must stay
//! deterministic across the serial and threaded executors.

use pimfused::config::{ArchConfig, Engine, PartitionKind, System};
use pimfused::coordinator::{Session, SweepGrid};
use pimfused::dataflow::CostModel;
use pimfused::ppa::PpaReport;
use pimfused::serve::ServeConfig;
use pimfused::sim::channel::run_channels;
use pimfused::sim::event;
use pimfused::trace::partition::{build_channels, ChannelSet, ExchangePoint};
use pimfused::trace::{Cmd, CmdKind, Deps, RowMap, Trace};
use pimfused::util::prop::{check_no_shrink, Gen};
use pimfused::workload::Workload;

fn fused4(channels: usize, p: PartitionKind) -> ArchConfig {
    ArchConfig::system(System::Fused4, 32 * 1024, 256)
        .with_channels(channels)
        .with_partition(p)
}

/// The single-channel engine-agreement contract, extended across the
/// channels axis: identical actions and energy under both engines, event
/// ≤ analytic, event ≥ the interconnect's busy cycles, and an
/// engine-equal exchange schedule (readiness is an analytic prefix, so
/// the engines cannot disagree about it).
fn assert_channel_agreement(session: &Session, cfg: &ArchConfig, w: Workload, ctx: &str) {
    let a = session.run(&cfg.clone().with_engine(Engine::Analytic), w).unwrap();
    let e = session.run(&cfg.clone().with_engine(Engine::Event), w).unwrap();
    assert_eq!(e.sim.actions, a.sim.actions, "{ctx}: actions must be engine-equal");
    assert_eq!(e.energy_pj, a.energy_pj, "{ctx}: energy must be engine-equal");
    assert!(
        e.cycles <= a.cycles,
        "{ctx}: event {} must not exceed analytic {}",
        e.cycles,
        a.cycles
    );
    let occ = e.occupancy.as_ref().expect("event engine reports occupancy");
    assert!(
        e.cycles >= occ.busiest(),
        "{ctx}: event {} below channel 0's busiest resource {}",
        e.cycles,
        occ.busiest()
    );
    if cfg.channels > 1 {
        let ca = a.channels.as_ref().expect("multi-channel analytic summary");
        let ce = e.channels.as_ref().expect("multi-channel event summary");
        assert_eq!(ca.exchanges, ce.exchanges, "{ctx}: exchange schedule engine-equal");
        assert_eq!(ca.exchange_bytes, ce.exchange_bytes, "{ctx}");
        assert!(
            e.cycles >= ce.interconnect_busy,
            "{ctx}: event {} below interconnect busy {}",
            e.cycles,
            ce.interconnect_busy
        );
        for (ch, &c) in ce.channel_cycles.iter().enumerate() {
            assert!(
                c <= e.cycles,
                "{ctx}: channel {ch} makespan {c} exceeds composed {}",
                e.cycles
            );
        }
    } else {
        assert!(a.channels.is_none(), "{ctx}: single-channel reports carry no channel summary");
        assert!(e.channels.is_none(), "{ctx}");
    }
}

#[test]
fn engines_agree_across_channels_and_partitions() {
    // Random (system, buffers, workload) points × {1, 2, 4} channels ×
    // both partitions: the agreement invariants are axis-independent.
    let session = Session::new();
    check_no_shrink(
        "channel-agreement-random",
        16,
        |g: &mut Gen| {
            let sys = *g.choose(&System::ALL);
            let gbuf = *g.choose(&[8192usize, 32768]);
            let lbuf = *g.choose(&[0usize, 256]);
            let w = *g.choose(&[Workload::Fig1, Workload::Fig3, Workload::ResNet18First8]);
            let channels = *g.choose(&[1usize, 2, 4]);
            let p = *g.choose(&PartitionKind::ALL);
            (sys, gbuf, lbuf, w, channels, p)
        },
        |&(sys, gbuf, lbuf, w, channels, p)| {
            let cfg = ArchConfig::system(sys, gbuf, lbuf)
                .with_channels(channels)
                .with_partition(p);
            let ctx = format!("{} on {} x{channels} {}", w.name(), cfg.label(), p.name());
            assert_channel_agreement(&session, &cfg, w, &ctx);
            true
        },
    );
}

#[test]
fn per_channel_traces_pass_the_scheduler_audit() {
    // Every shard trace the partitioner emits must be a legal input to
    // the event scheduler: the audit replays dependencies, resource
    // exclusivity, and the open-row state machine per channel.
    for p in PartitionKind::ALL {
        for channels in [2usize, 4] {
            let cfg = fused4(channels, p).with_engine(Engine::Event);
            let g = Workload::Fig3.graph();
            let set = build_channels(&g, &cfg, CostModel::default()).unwrap();
            for (ch, t) in set.traces.iter().enumerate() {
                if let Err(e) = event::audit(&cfg, t) {
                    panic!("{} x{channels} channel {ch}: illegal schedule: {e}", p.name());
                }
            }
        }
    }
}

#[test]
fn single_channel_results_are_byte_identical_to_the_pre_axis_pipeline() {
    // A sweep that spells out `channels = [1]` / `partition = data` must
    // serialize byte-for-byte like one that never mentions the axis:
    // the channels axis is invisible until it is actually used.
    let base_grid = SweepGrid::new()
        .systems([System::AimLike, System::Fused4])
        .gbuf_bytes([2048, 32768])
        .lbuf_bytes([0, 256])
        .workload(Workload::Fig1)
        .engines(Engine::ALL);
    let axis_grid = SweepGrid::new()
        .systems([System::AimLike, System::Fused4])
        .gbuf_bytes([2048, 32768])
        .lbuf_bytes([0, 256])
        .workload(Workload::Fig1)
        .engines(Engine::ALL)
        .channels([1])
        .partition(PartitionKind::Data);
    let base = base_grid.run(&Session::new()).unwrap();
    let axis = axis_grid.run(&Session::new()).unwrap();
    assert_eq!(base.to_json(), axis.to_json(), "JSON golden unchanged by channels=1");
    assert_eq!(base.to_csv(), axis.to_csv(), "CSV golden unchanged by channels=1");

    // Same for serving: an explicit single-channel config reproduces the
    // pre-axis report exactly.
    let session = Session::new();
    let plain = ServeConfig::new(
        ArchConfig::system(System::Fused4, 32 * 1024, 256).with_engine(Engine::Event),
        Workload::Fig1,
        20_000.0,
    )
    .requests(100)
    .batch(4)
    .seed(7);
    let spelled = ServeConfig::new(
        fused4(1, PartitionKind::Data).with_engine(Engine::Event),
        Workload::Fig1,
        20_000.0,
    )
    .requests(100)
    .batch(4)
    .seed(7);
    assert_eq!(
        session.serve(&plain).unwrap(),
        session.serve(&spelled).unwrap(),
        "serving is byte-identical at channels=1"
    );
}

#[test]
fn data_parallel_cycles_never_increase_with_channel_count() {
    // Batch sharding gives a single inference exactly one channel, so
    // single-shot cycles are monotone non-increasing (in fact constant)
    // in the channel count; the extra channels pay off as serving lanes.
    let session = Session::new();
    for e in Engine::ALL {
        let one = session
            .run(&fused4(1, PartitionKind::Data).with_engine(e), Workload::ResNet18First8)
            .unwrap()
            .cycles;
        let mut prev = one;
        for channels in [2usize, 4, 8] {
            let r = session
                .run(
                    &fused4(channels, PartitionKind::Data).with_engine(e),
                    Workload::ResNet18First8,
                )
                .unwrap();
            assert!(
                r.cycles <= prev,
                "{} channels regressed ResNet18 cycles: {} > {prev} ({e:?})",
                channels,
                r.cycles
            );
            assert_eq!(r.cycles, one, "data partition single shot is channel 0 alone ({e:?})");
            let ch = r.channels.as_ref().unwrap();
            assert_eq!(ch.interconnect_busy, 0, "batch sharding moves nothing cross-channel");
            prev = r.cycles;
        }
    }
}

#[test]
fn model_parallel_speedup_is_sublinear_under_interconnect_contention() {
    // Cout sharding buys real single-shot speedup, but every plan-step
    // boundary all-gathers over the shared interconnect — so once the
    // interconnect reports busy cycles, speedup(C) must be < C.
    let session = Session::new();
    let base = session
        .run(&fused4(1, PartitionKind::Data).with_engine(Engine::Event), Workload::ResNet18First8)
        .unwrap()
        .cycles;
    for channels in [2usize, 4] {
        let r = session
            .run(
                &fused4(channels, PartitionKind::Model).with_engine(Engine::Event),
                Workload::ResNet18First8,
            )
            .unwrap();
        let ch = r.channels.as_ref().unwrap();
        assert!(ch.interconnect_busy > 0, "model partition must contend for the interconnect");
        let util = r.interconnect_utilization().unwrap();
        assert!(util > 0.0 && util <= 1.0, "utilization {util} out of range");
        assert!(
            r.cycles * channels as u64 > base,
            "{channels}-channel model partition speedup must be sub-linear: \
             {} * {channels} <= {base}",
            r.cycles
        );
    }
}

#[test]
fn simultaneous_gathers_serialize_on_the_interconnect() {
    // Hand-built two-channel set: identical one-command shard traces, so
    // both boundary shards become ready at the same instant and the
    // second transfer has no choice but to queue behind the first on the
    // interval timeline.
    let cfg = fused4(2, PartitionKind::Model).with_engine(Engine::Event);
    let shard = || Trace {
        cmds: vec![Cmd {
            node: 1,
            kind: CmdKind::HostRead { bytes: 4096, rows: RowMap::EMPTY },
            reads: Deps::EMPTY,
            writes: None,
            row_span: None,
        }],
    };
    let set = ChannelSet {
        channels: 2,
        width: 2,
        dead_channels: 0,
        partition: PartitionKind::Model,
        traces: vec![shard(), shard()],
        exchanges: vec![
            vec![ExchangePoint { cmd: 0, node: 1, bytes: 4096 }],
            vec![ExchangePoint { cmd: 0, node: 1, bytes: 4096 }],
        ],
    };
    let o = run_channels(&cfg, &set);
    let x = &o.report.exchanges;
    assert_eq!(x.len(), 2);
    assert_eq!(x[0].ready, x[1].ready, "identical shards become ready together");
    assert!(x[0].start >= x[0].ready);
    assert_eq!(x[1].start, x[0].end, "the second gather starts exactly when the first ends");
    assert!(x[1].start > x[1].ready, "provably serialized: it waited past its ready time");
    assert_eq!(
        o.report.interconnect_busy,
        (x[0].end - x[0].start) + (x[1].end - x[1].start),
        "no overlap on the shared resource"
    );
    assert!(o.result.cycles >= x[1].end, "the makespan covers the queued gather");
}

#[test]
fn threaded_sweep_with_channel_axis_is_byte_identical_to_serial() {
    // 3 systems × 3 GBUFs × 2 LBUFs × 2 channel counts × 2 partitions =
    // 72 points: above the executor's serial threshold (64), so this
    // exercises per-channel scheduling on the threaded path.
    let grid = SweepGrid::new()
        .systems(System::ALL)
        .gbuf_bytes([2048, 8192, 32768])
        .lbuf_bytes([0, 256])
        .workload(Workload::Fig1)
        .channels([1, 2])
        .partitions(PartitionKind::ALL);
    let points = grid.points();
    assert!(points.len() > 64, "need the threaded path, got {} points", points.len());

    let r1 = grid.run(&Session::new()).unwrap();
    let r2 = grid.run(&Session::new()).unwrap();
    r1.ensure_ok().unwrap();
    assert_eq!(r1.to_json(), r2.to_json(), "threaded sweep is run-to-run byte-identical");
    assert_eq!(r1.to_csv(), r2.to_csv());

    // Every threaded row matches an independent serial run.
    let serial = Session::new();
    for row in &r1 {
        let want: PpaReport = serial.run(&row.point.cfg, row.point.workload).unwrap();
        let got = row.report.as_ref().unwrap();
        assert_eq!(got.cycles, want.cycles, "{}", row.point.cfg.label());
        assert_eq!(got.energy_pj, want.energy_pj, "{}", row.point.cfg.label());
    }
}

#[test]
fn serve_sweep_with_channels_is_deterministic_and_lanes_help() {
    // The serving path over a multi-channel config: identical reports
    // from two fresh sessions (covers the parallel serve_sweep path),
    // and four data-parallel lanes never serve a saturating load worse
    // than one channel.
    let rates = [10_000.0, 20_000.0, 40_000.0];
    let sc = |channels: usize| {
        ServeConfig::new(
            fused4(channels, PartitionKind::Data).with_engine(Engine::Event),
            Workload::Fig1,
            20_000.0,
        )
        .requests(200)
        .batch(8)
        .seed(7)
    };
    let a = Session::new().serve_sweep(&sc(4), &rates, true).unwrap();
    let b = Session::new().serve_sweep(&sc(4), &rates, true).unwrap();
    assert_eq!(a, b, "serve sweep is deterministic across sessions and threads");

    let single = Session::new().serve_sweep(&sc(1), &rates, true).unwrap();
    for (wide, narrow) in a.iter().zip(&single) {
        assert!(
            wide.throughput_rps >= narrow.throughput_rps,
            "4 data-parallel lanes must not lose throughput at rate {}: {} < {}",
            narrow.rate_rps,
            wide.throughput_rps,
            narrow.throughput_rps
        );
    }
}

#[test]
fn channel_partitioning_runs_exactly_once_per_config() {
    // The session memoizes the partitioned ChannelSet across engines and
    // repeats: stats() proves the per-channel traces were generated once.
    let session = Session::new();
    let cfg = fused4(2, PartitionKind::Model);
    session.run(&cfg.clone().with_engine(Engine::Analytic), Workload::Fig1).unwrap();
    session.run(&cfg.clone().with_engine(Engine::Event), Workload::Fig1).unwrap();
    session.run(&cfg.clone().with_engine(Engine::Event), Workload::Fig1).unwrap();
    assert_eq!(
        session.stats().channel_set_builds,
        1,
        "both engines and the repeat must share one partitioning"
    );
    // A different channel count is a different partitioning.
    session.run(&fused4(4, PartitionKind::Model), Workload::Fig1).unwrap();
    assert_eq!(session.stats().channel_set_builds, 2);
}
