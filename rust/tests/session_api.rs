//! Experiment API v2 integration tests: `SweepGrid` ordering and
//! determinism against serial `Session` runs (including the parallel
//! executor path), baseline-cache correctness, the graph/baseline
//! reuse-exactly-once guarantee, and JSON/CSV golden outputs.

use pimfused::config::{ArchConfig, Engine, System};
use pimfused::coordinator::{Session, SweepGrid, SweepPoint, SweepResults, SweepRow};
use pimfused::energy::{AreaReport, EnergyReport};
use pimfused::ppa::{Normalized, PpaReport};
use pimfused::sim::{ResourceOccupancy, SimResult};
use pimfused::workload::Workload;

#[test]
fn parallel_sweep_matches_serial_session_and_keeps_order() {
    // 3 systems × 5 GBUFs × 5 LBUFs = 75 points: above the executor's
    // serial threshold (64), so this exercises the threaded path.
    let grid = SweepGrid::new()
        .systems(System::ALL)
        .gbuf_bytes([2048, 4096, 8192, 16384, 32768])
        .lbuf_bytes([0, 64, 128, 256, 512])
        .workload(Workload::Fig1);
    let points = grid.points();
    assert_eq!(points.len(), 75);

    let session = Session::new();
    let results = grid.run(&session).unwrap();
    results.ensure_ok().unwrap();
    assert_eq!(results.len(), points.len());

    let serial = Session::new();
    for (pt, row) in points.iter().zip(&results) {
        assert_eq!(row.point, *pt, "result order must match point order");
        let want = serial.run(&pt.cfg, pt.workload).unwrap();
        let got = row.report.as_ref().unwrap();
        assert_eq!(got.cycles, want.cycles, "{}", pt.cfg.label());
        assert_eq!(got.energy_pj, want.energy_pj, "{}", pt.cfg.label());
        assert_eq!(got.label, pt.cfg.label());
    }
}

#[test]
fn sweep_reuses_graph_and_baseline_exactly_once_per_workload() {
    let session = Session::new();
    let grid = SweepGrid::new()
        .systems([System::AimLike, System::Fused4])
        .gbuf_bytes([2048, 8192])
        .lbuf_bytes([0, 128])
        .workloads([Workload::Fig1, Workload::Fig3]);
    let results = grid.run(&session).unwrap();
    results.ensure_ok().unwrap();
    assert_eq!(results.len(), 16);

    let st = session.stats();
    assert_eq!(st.graph_builds, 2, "one graph build per workload, shared with the baseline");
    assert_eq!(st.baseline_runs, 2, "one baseline report per workload");
    // 16 points + 2 baselines.
    assert_eq!(st.points_run, 18);

    // A second identical sweep re-runs points but rebuilds nothing.
    grid.run(&session).unwrap().ensure_ok().unwrap();
    let st2 = session.stats();
    assert_eq!(st2.graph_builds, 2);
    assert_eq!(st2.baseline_runs, 2);
    assert_eq!(st2.plan_builds, st.plan_builds);
}

#[test]
fn cached_baseline_normalization_equals_fresh() {
    let cfg = ArchConfig::system(System::Fused16, 8192, 128);
    let session = Session::new();
    let first = session.normalized(&cfg, Workload::Fig3).unwrap();
    let cached = session.normalized(&cfg, Workload::Fig3).unwrap();
    let fresh = Session::new().normalized(&cfg, Workload::Fig3).unwrap();
    assert_eq!(first, cached, "cache must not change the result");
    assert_eq!(first, fresh, "memoized normalization must equal from-scratch");
    assert_eq!(session.stats().baseline_runs, 1);
}

#[test]
fn grid_norms_match_explicit_normalization() {
    let session = Session::new();
    let results = SweepGrid::new()
        .systems([System::Fused4])
        .bufcfgs([(2048, 0), (32 * 1024, 256)])
        .workload(Workload::Fig3)
        .run(&session)
        .unwrap();
    for row in &results {
        let n = row.norm.unwrap();
        let want = session.normalized(&row.point.cfg, row.point.workload).unwrap();
        assert_eq!(n, want);
    }
}

/// Handcrafted results for byte-exact serializer goldens (the pipeline's
/// own numbers are model-calibration-dependent; the *format* is the
/// contract).
fn golden_results() -> SweepResults {
    let dummy_area = AreaReport {
        pimcores_mm2: 0.25,
        gbcore_mm2: 0.0,
        gbuf_mm2: 0.0,
        lbufs_mm2: 0.0,
        control_mm2: 0.0,
    };
    let ok_cfg = ArchConfig::system(System::Fused4, 2048, 0);
    let ok_report = PpaReport {
        label: ok_cfg.label(),
        workload: Workload::Fig1.name().to_string(),
        engine: Engine::Analytic,
        cycles: 100,
        energy_pj: 1.5,
        area_mm2: 0.25,
        sim: SimResult::default(),
        energy: EnergyReport { components: vec![] },
        area: dummy_area.clone(),
        occupancy: None,
        schedule: None,
        channels: None,
    };
    // A Fused4 event-engine row with a hand-built occupancy (4 cores,
    // 16 banks) locks the utilization schema.
    let ev_cfg = ArchConfig::system(System::Fused4, 2048, 0).with_engine(Engine::Event);
    let mut occ = ResourceOccupancy {
        num_cores: 4,
        num_banks: 16,
        num_groups: 4,
        makespan: 90,
        bus_busy: 40,
        gbcore_busy: 10,
        host_busy: 5,
        cmdbus_busy: 3,
        backfilled: 7,
        slid_slices: 4,
        ..Default::default()
    };
    for i in 0..4 {
        occ.core_busy[i] = 80 - i as u64;
    }
    for b in 0..16 {
        occ.bank_busy[b] = b as u64;
        occ.host_bank_busy[b] = (b % 4) as u64;
    }
    occ.act_busy = [12, 9, 6, 3];
    let ev_report = PpaReport {
        label: ev_cfg.label(),
        workload: Workload::Fig1.name().to_string(),
        engine: Engine::Event,
        cycles: 90,
        energy_pj: 1.5,
        area_mm2: 0.25,
        sim: SimResult::default(),
        energy: EnergyReport { components: vec![] },
        area: dummy_area,
        occupancy: Some(occ),
        schedule: None,
        channels: None,
    };
    let err_cfg = ArchConfig::system(System::AimLike, 2048, 0);
    SweepResults {
        baseline_label: "AiM-like/G2K_L0".to_string(),
        rows: vec![
            SweepRow {
                point: SweepPoint { cfg: ok_cfg, workload: Workload::Fig1 },
                report: Ok(ok_report),
                norm: Some(Normalized { cycles: 0.5, energy: 0.75, area: 1.0 }),
            },
            SweepRow {
                point: SweepPoint { cfg: ev_cfg, workload: Workload::Fig1 },
                report: Ok(ev_report),
                norm: Some(Normalized { cycles: 0.45, energy: 0.75, area: 1.0 }),
            },
            SweepRow {
                point: SweepPoint { cfg: err_cfg, workload: Workload::Fig1 },
                report: Err(anyhow::anyhow!("boom \"quoted\"")),
                norm: None,
            },
        ],
    }
}

#[test]
fn json_golden_output() {
    let want = r#"{
  "baseline": "AiM-like/G2K_L0",
  "rows": [
    {
      "config": "Fused4/G2K_L0",
      "system": "Fused4",
      "gbuf_bytes": 2048,
      "lbuf_bytes": 0,
      "workload": "Fig1_Example",
      "engine": "analytic",
      "cycles": 100,
      "energy_pj": 1.5,
      "area_mm2": 0.25,
      "norm": {"cycles": 0.5, "energy": 0.75, "area": 1},
      "utilization": null,
      "error": null
    },
    {
      "config": "Fused4/G2K_L0",
      "system": "Fused4",
      "gbuf_bytes": 2048,
      "lbuf_bytes": 0,
      "workload": "Fig1_Example",
      "engine": "event",
      "cycles": 90,
      "energy_pj": 1.5,
      "area_mm2": 0.25,
      "norm": {"cycles": 0.45, "energy": 0.75, "area": 1},
      "utilization": {"makespan": 90, "bus": 40, "cmdbus": 3, "gbcore": 10, "host": 5, "backfilled": 7, "slid": 4, "cores": [80, 79, 78, 77], "banks": [0, 1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12, 13, 14, 15], "host_banks": [0, 1, 2, 3, 0, 1, 2, 3, 0, 1, 2, 3, 0, 1, 2, 3], "act_windows": [12, 9, 6, 3]},
      "error": null
    },
    {
      "config": "AiM-like/G2K_L0",
      "system": "AiM-like",
      "gbuf_bytes": 2048,
      "lbuf_bytes": 0,
      "workload": "Fig1_Example",
      "engine": "analytic",
      "cycles": null,
      "energy_pj": null,
      "area_mm2": null,
      "norm": null,
      "utilization": null,
      "error": "boom \"quoted\""
    }
  ]
}
"#;
    assert_eq!(golden_results().to_json(), want);
}

#[test]
fn csv_golden_output() {
    let want = "config,system,gbuf_bytes,lbuf_bytes,workload,engine,cycles,energy_pj,area_mm2,norm_cycles,norm_energy,norm_area,host_bank_busy,act_window_busy,slid_slices,error\n\
                Fused4/G2K_L0,Fused4,2048,0,Fig1_Example,analytic,100,1.5,0.25,0.5,0.75,1,,,,\n\
                Fused4/G2K_L0,Fused4,2048,0,Fig1_Example,event,90,1.5,0.25,0.45,0.75,1,24,30,4,\n\
                AiM-like/G2K_L0,AiM-like,2048,0,Fig1_Example,analytic,,,,,,,,,,\"boom \"\"quoted\"\"\"\n";
    assert_eq!(golden_results().to_csv(), want);
}

#[test]
fn real_sweep_serializes_consistently() {
    let session = Session::new();
    let results = SweepGrid::new()
        .systems([System::Fused4, System::Fused16])
        .gbuf_bytes([2048, 8192])
        .engines(Engine::ALL)
        .workload(Workload::Fig1)
        .run(&session)
        .unwrap();
    results.ensure_ok().unwrap();

    let json = results.to_json();
    assert_eq!(json.matches("\"config\":").count(), results.len());
    assert_eq!(json.matches("\"error\": null").count(), results.len());
    assert_eq!(json.matches('{').count(), json.matches('}').count());
    // Event rows carry the host-residency and ACT-window breakdowns.
    assert_eq!(json.matches("\"host_banks\": [").count(), results.len() / 2);
    assert_eq!(json.matches("\"act_windows\": [").count(), results.len() / 2);

    let csv = results.to_csv();
    let lines: Vec<&str> = csv.trim_end().lines().collect();
    assert_eq!(lines.len(), results.len() + 1, "header + one line per row");
    let cols = lines[0].split(',').count();
    for l in &lines {
        assert_eq!(l.split(',').count(), cols, "ragged CSV row: {l}");
    }
}

#[test]
fn table_lists_every_point() {
    let session = Session::new();
    let results = SweepGrid::new()
        .systems([System::Fused4])
        .gbuf_bytes([2048, 8192])
        .lbuf_bytes([0, 256])
        .workload(Workload::Fig1)
        .run(&session)
        .unwrap();
    let t = results.table();
    assert_eq!(t.matches("Fused4/").count(), 4);
    assert!(t.contains("workload"));
    assert!(t.contains("Fig1_Example"));
    // Rows name their engine, so dual-engine sweeps stay distinguishable.
    assert!(t.contains("engine"));
    assert_eq!(t.matches("analytic").count(), 4);
}
