//! AOT-artifact integration: load every `artifacts/*.hlo.txt` through the
//! PJRT runtime and check its numerics against the Rust reference.
//!
//! These tests skip (with a notice) when artifacts haven't been built —
//! `make test` builds them first; plain `cargo test` stays green either
//! way.

use pimfused::cnn::{Graph, Op, Shape};
use pimfused::runtime::{artifacts_dir, Runtime};
use pimfused::util::rng::XorShift64;
use pimfused::validate::tensor::Tensor;

fn have_artifacts() -> bool {
    if !Runtime::available() {
        eprintln!("skipping artifact roundtrip: built without the `pjrt` feature");
        return false;
    }
    let ok = artifacts_dir().join("tile_conv_bn_relu.hlo.txt").exists();
    if !ok {
        eprintln!("skipping artifact roundtrip: run `make artifacts` first");
    }
    ok
}

fn rand_vec(n: usize, seed: u64) -> Vec<f32> {
    let mut r = XorShift64::new(seed);
    (0..n).map(|_| r.next_f32_signed()).collect()
}

#[test]
fn tile_conv_artifact_matches_rust_conv() {
    if !have_artifacts() {
        return;
    }
    let rt = Runtime::cpu().unwrap();
    let m = rt.load_hlo(artifacts_dir().join("tile_conv_bn_relu.hlo.txt")).unwrap();
    let x = rand_vec(8 * 10 * 10, 1);
    let w = rand_vec(8 * 8 * 3 * 3, 2);
    let out = m
        .run_f32(&[(&x, &[8usize, 10, 10][..]), (&w, &[8usize, 8, 3, 3][..])])
        .unwrap();

    // Rust reference: VALID conv + relu.
    let xt = Tensor::from_fn(8, 10, 10, |c, y, xx| x[(c * 10 + y) * 10 + xx]);
    let want = xt.conv2d(&w, 8, 3, 1, 0, true);
    assert_eq!(out[0].len(), want.data().len());
    for (a, b) in out[0].iter().zip(want.data()) {
        assert!((a - b).abs() < 1e-4, "{a} vs {b}");
    }
}

#[test]
fn add_relu_artifact_matches() {
    if !have_artifacts() {
        return;
    }
    let rt = Runtime::cpu().unwrap();
    let m = rt.load_hlo(artifacts_dir().join("add_relu_tile.hlo.txt")).unwrap();
    let a = rand_vec(8 * 8 * 8, 3);
    let b = rand_vec(8 * 8 * 8, 4);
    let out = m
        .run_f32(&[(&a, &[8usize, 8, 8][..]), (&b, &[8usize, 8, 8][..])])
        .unwrap();
    for ((x, y), got) in a.iter().zip(&b).zip(&out[0]) {
        assert_eq!(*got, (x + y).max(0.0));
    }
}

#[test]
fn maxpool_artifact_matches() {
    if !have_artifacts() {
        return;
    }
    let rt = Runtime::cpu().unwrap();
    let m = rt.load_hlo(artifacts_dir().join("maxpool_tile.hlo.txt")).unwrap();
    let x = rand_vec(8 * 17 * 17, 5);
    let out = m.run_f32(&[(&x, &[8usize, 17, 17][..])]).unwrap();
    let xt = Tensor::from_fn(8, 17, 17, |c, y, xx| x[(c * 17 + y) * 17 + xx]);
    let want = xt.maxpool(3, 2, 1);
    assert_eq!(out[0].len(), want.data().len());
    for (a, b) in out[0].iter().zip(want.data()) {
        assert_eq!(a, b, "maxpool must be exact (no accumulation)");
    }
}

#[test]
fn first8_artifact_matches_rust_reference() {
    if !have_artifacts() {
        return;
    }
    use pimfused::cnn::resnet::resnet18_at;
    use pimfused::validate::{run_reference, synth_input, synth_weights};

    let rt = Runtime::cpu().unwrap();
    let m = rt
        .load_hlo(artifacts_dir().join("resnet18_first8_32.hlo.txt"))
        .unwrap();

    let g = resnet18_at(32).prefix(8);
    let input = synth_input(&g, 77);
    let reference = run_reference(&g, &input, 77);
    let want = reference.last().unwrap();

    let mut datas = vec![input.data().to_vec()];
    let mut shapes: Vec<Vec<usize>> = vec![vec![3, 32, 32]];
    for n in &g.nodes {
        if let Op::Conv { cout, k, .. } = n.op {
            datas.push(synth_weights(n, 77));
            shapes.push(vec![cout, g.nodes[n.inputs[0]].shape.c, k, k]);
        }
    }
    let args: Vec<(&[f32], &[usize])> =
        datas.iter().zip(&shapes).map(|(d, s)| (d.as_slice(), s.as_slice())).collect();
    let out = m.run_f32(&args).unwrap();
    assert_eq!(out[0].len(), want.data().len());
    let mut worst = 0.0f32;
    for (a, b) in out[0].iter().zip(want.data()) {
        worst = worst.max((a - b).abs() / b.abs().max(1.0));
    }
    assert!(worst < 1e-3, "first8 golden mismatch: {worst}");
}

#[test]
fn fused_block_tile_artifact_matches_demand_sliced_reference() {
    if !have_artifacts() {
        return;
    }
    use pimfused::dataflow::tiling::{demand_for_tile, Rect};
    use pimfused::validate::{run_reference, synth_input, synth_weights};

    let mut g = Graph::new("pair", Shape::new(8, 20, 20));
    let conv = |relu| Op::Conv { cout: 8, k: 3, stride: 1, pad: 1, bn: true, relu };
    let c1 = g.add("c1", conv(true), vec![0]);
    let c2 = g.add("c2", conv(false), vec![c1]);

    let input = synth_input(&g, 11);
    let reference = run_reference(&g, &input, 11);
    let tile = Rect::new(6, 6, 14, 14);
    let demand = demand_for_tile(&g, 1, 2, tile);
    let halo = input.slice(&demand.external[&0]);
    let w1 = synth_weights(&g.nodes[c1], 11);
    let w2 = synth_weights(&g.nodes[c2], 11);

    let rt = Runtime::cpu().unwrap();
    let m = rt.load_hlo(artifacts_dir().join("fused_block_tile.hlo.txt")).unwrap();
    let out = m
        .run_f32(&[
            (halo.data(), &[8usize, 12, 12][..]),
            (&w1, &[8usize, 8, 3, 3][..]),
            (&w2, &[8usize, 8, 3, 3][..]),
        ])
        .unwrap();
    let want = reference[c2].slice(&tile);
    for (a, b) in out[0].iter().zip(want.data()) {
        assert!((a - b).abs() < 1e-4);
    }
}
