//! Cross-module integration tests: plan → trace → simulate → PPA over
//! the full workload/system matrix, plus property-based invariants.

use pimfused::config::{ArchConfig, System};
use pimfused::coordinator::Session;
use pimfused::dataflow::{plan, CostModel};
use pimfused::sim::simulate;
use pimfused::trace::gen::generate;
use pimfused::trace::CmdKind;
use pimfused::util::prop::{check_no_shrink, Gen};
use pimfused::workload::Workload;

#[test]
fn every_system_runs_every_workload() {
    let session = Session::new();
    for sys in System::ALL {
        for w in Workload::ALL {
            let cfg = ArchConfig::system(sys, 8 * 1024, 128);
            let r = session.run(&cfg, w).unwrap_or_else(|e| panic!("{sys:?}/{w:?}: {e}"));
            assert!(r.cycles > 0);
            assert!(r.energy_pj > 0.0);
            assert!(r.area_mm2 > 0.0);
        }
    }
    // One graph and (at most) one plan per dataflow were built per
    // workload, no matter how many systems ran it.
    assert_eq!(session.stats().graph_builds, Workload::ALL.len());
}

#[test]
fn headline_beats_baseline_on_all_axes() {
    let n = Session::new()
        .experiment(ArchConfig::system(System::Fused4, 32 * 1024, 256))
        .workload(Workload::ResNet18Full)
        .normalized()
        .unwrap();
    // Paper: 30.6% / 83.4% / 76.5%. Keep generous reproduction bands so
    // recalibration doesn't thrash CI, but the win must be simultaneous.
    assert!((0.2..0.45).contains(&n.cycles), "cycles {}", n.cycles);
    assert!((0.7..0.95).contains(&n.energy), "energy {}", n.energy);
    assert!((0.55..0.95).contains(&n.area), "area {}", n.area);
}

#[test]
fn fused_first8_improvement_matches_paper_band() {
    // §V-D: ~91.2% improvement for fused first-8 on good buffers.
    let stats = pimfused::coordinator::experiments::vd_stats(CostModel::default()).unwrap();
    assert!(
        (0.75..0.99).contains(&stats.perf_improvement),
        "perf improvement {}",
        stats.perf_improvement
    );
}

#[test]
fn traces_only_use_table_i_commands_plus_host_io() {
    for sys in System::ALL {
        let cfg = ArchConfig::system(sys, 2048, 64);
        let g = Workload::ResNet18Full.graph();
        let p = plan(&g, &cfg);
        let t = generate(&g, &cfg, &p, CostModel::default());
        for c in &t.cmds {
            match c.kind {
                CmdKind::PimcoreCmp { .. }
                | CmdKind::GbcoreCmp { .. }
                | CmdKind::Bk2Lbuf { .. }
                | CmdKind::Lbuf2Bk { .. }
                | CmdKind::Bk2Gbuf { .. }
                | CmdKind::Gbuf2Bk { .. }
                | CmdKind::HostWrite { .. }
                | CmdKind::HostRead { .. } => {}
            }
            assert!(c.node < g.nodes.len());
        }
    }
}

#[test]
fn prop_cycles_monotone_in_buffers_full_matrix() {
    check_no_shrink(
        "integration-monotone",
        10,
        |g: &mut Gen| {
            let sys = *g.choose(&System::ALL);
            let w = *g.choose(&[Workload::ResNet18First8, Workload::ResNet18Full]);
            let gb = *g.choose(&[2048usize, 8192, 16384, 32768]);
            let lb = *g.choose(&[0usize, 64, 128, 256]);
            (sys, w, gb, lb)
        },
        |&(sys, w, gb, lb)| {
            let s = Session::new();
            let small = s.run(&ArchConfig::system(sys, gb, lb), w).unwrap();
            let big = s.run(&ArchConfig::system(sys, gb * 2, lb + 128), w).unwrap();
            big.cycles <= small.cycles && big.energy_pj <= small.energy_pj * 1.02
        },
    );
}

#[test]
fn prop_energy_scales_with_work() {
    // More layers -> strictly more energy and cycles at fixed config.
    check_no_shrink(
        "integration-work-scaling",
        8,
        |g: &mut Gen| *g.choose(&System::ALL),
        |&sys| {
            let s = Session::new();
            let cfg = ArchConfig::system(sys, 8192, 128);
            let first8 = s.run(&cfg, Workload::ResNet18First8).unwrap();
            let full = s.run(&cfg, Workload::ResNet18Full).unwrap();
            full.cycles > first8.cycles && full.energy_pj > first8.energy_pj
        },
    );
}

#[test]
fn cross_bank_reduction_is_the_mechanism() {
    // The paper's thesis: PIMfused's win comes from cutting cross-bank
    // transfers. Verify the causal chain on first8: fused moves fewer
    // bytes through the GBUF *and* spends fewer cycles there.
    let g = Workload::ResNet18First8.graph();
    let m = CostModel::default();
    let base_cfg = ArchConfig::baseline();
    let base_t = generate(&g, &base_cfg, &plan(&g, &base_cfg), m);
    let f_cfg = ArchConfig::system(System::Fused16, 2048, 0);
    let f_t = generate(&g, &f_cfg, &plan(&g, &f_cfg), m);
    let (bs, fs) = (base_t.stats(), f_t.stats());
    assert!(fs.cross_bank_total() < bs.cross_bank_total() / 2);
    let br = simulate(&base_cfg, &base_t);
    let fr = simulate(&f_cfg, &f_t);
    assert!(fr.cross_bank_cycles < br.cross_bank_cycles);
}

#[test]
fn workload_prefix_consistency() {
    // First8 is literally the prefix of Full: the baseline trace of Full
    // must start with (almost) the same commands.
    let m = CostModel::default();
    let cfg = ArchConfig::baseline();
    let g8 = Workload::ResNet18First8.graph();
    let gf = Workload::ResNet18Full.graph();
    let t8 = generate(&g8, &cfg, &plan(&g8, &cfg), m);
    let tf = generate(&gf, &cfg, &plan(&gf, &cfg), m);
    // Ignore the trailing HostRead of the first8 trace.
    let n = t8.cmds.len() - 1;
    assert_eq!(&tf.cmds[..n], &t8.cmds[..n]);
}
