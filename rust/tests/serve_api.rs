//! Serving-simulator integration tests: queueing-theory sanity checks
//! (low-load latency, wait monotonicity in offered rate, saturation
//! behavior), byte-exact determinism of `ServeReport` serialization
//! across runs and across the serial/threaded sweep paths, schedule
//! memoization, the hockey-stick latency curve, and the
//! batching-raises-throughput acceptance criterion.

use pimfused::config::{ArchConfig, Engine, System};
use pimfused::coordinator::{serve_to_csv, serve_to_json, Session};
use pimfused::serve::{ArrivalKind, LatencyStats, ServeConfig, ServeDriver, ServeReport};
use pimfused::workload::Workload;

/// The single-inference service rate (req/s) of `cfg` on `w`, from the
/// same schedule the serving driver memoizes.
fn service_rate(session: &Session, cfg: &ArchConfig, w: Workload) -> f64 {
    let single = session.run(cfg, w).unwrap().cycles;
    cfg.timing.clock_hz() / single as f64
}

fn event_cfg() -> ArchConfig {
    ArchConfig::system(System::Fused4, 32 * 1024, 256).with_engine(Engine::Event)
}

#[test]
fn low_load_latency_approaches_service_time() {
    // Queueing sanity: offered load far below capacity with deterministic
    // arrivals → no request ever queues, so every latency equals the
    // single-inference service time exactly.
    let session = Session::new();
    let cfg = event_cfg();
    let single = session.run(&cfg, Workload::Fig1).unwrap().cycles;
    let mu = service_rate(&session, &cfg, Workload::Fig1);
    let sc = ServeConfig::new(cfg, Workload::Fig1, mu / 10.0)
        .arrival(ArrivalKind::Fixed)
        .requests(200)
        .warmup(0.0);
    let r = session.serve(&sc).unwrap();
    assert_eq!(r.completed, 200);
    assert_eq!(r.dropped, 0);
    assert_eq!(r.latency.p50, single);
    assert_eq!(r.latency.p99, single);
    assert_eq!(r.latency.max, single);
    assert_eq!(r.latency.mean, single as f64);
    assert!(r.utilization < 0.2, "10x headroom: {}", r.utilization);
}

#[test]
fn mean_wait_is_monotone_in_offered_rate() {
    // Queueing sanity: with the same seed, scaling the rate scales the
    // whole arrival stream, so the G/D/1 waiting-time recurrence makes
    // mean latency non-decreasing in offered load (2-cycle slack absorbs
    // per-arrival rounding wobble).
    let session = Session::new();
    let cfg = event_cfg();
    let mu = service_rate(&session, &cfg, Workload::Fig1);
    let mut prev = 0.0f64;
    for frac in [0.2, 0.5, 0.8, 0.95, 1.1] {
        let sc = ServeConfig::new(cfg.clone(), Workload::Fig1, mu * frac)
            .requests(400)
            .queue_depth(10_000)
            .warmup(0.0);
        let r = session.serve(&sc).unwrap();
        assert_eq!(r.dropped, 0, "queue sized to never drop");
        assert!(
            r.latency.mean >= prev - 2.0,
            "mean latency fell from {prev} to {} at {frac}x capacity",
            r.latency.mean
        );
        prev = r.latency.mean;
    }
}

#[test]
fn saturation_pegs_utilization_and_overflows_the_queue() {
    // Queueing sanity: offered load 3x capacity → the server never
    // idles after startup and the bounded queue drops the excess.
    let session = Session::new();
    let cfg = event_cfg();
    let mu = service_rate(&session, &cfg, Workload::Fig1);
    let sc = ServeConfig::new(cfg, Workload::Fig1, mu * 3.0).requests(300).queue_depth(8);
    let r = session.serve(&sc).unwrap();
    assert!(r.dropped > 0, "overload must overflow the queue");
    assert_eq!(r.completed + r.dropped, 300);
    assert_eq!(r.queue_max, 8, "queue pegged at capacity");
    assert!(r.utilization > 0.98, "saturated server idles: {}", r.utilization);
}

#[test]
fn reports_are_byte_deterministic_across_runs_and_paths() {
    // Two fresh sessions, same config → byte-identical JSON and CSV; and
    // the threaded sweep path serializes identically to the serial one.
    let mk = || {
        let session = Session::new();
        let sc = ServeConfig::new(event_cfg(), Workload::Fig1, 40_000.0).requests(500).seed(42);
        session.serve(&sc).unwrap()
    };
    let (a, b) = (mk(), mk());
    assert_eq!(a, b);
    assert_eq!(serve_to_json(&[a.clone()]), serve_to_json(&[b.clone()]));
    assert_eq!(serve_to_csv(&[a]), serve_to_csv(&[b]));

    let session = Session::new();
    let base = ServeConfig::new(event_cfg(), Workload::Fig1, 1.0).requests(300);
    let rates = [10_000.0, 20_000.0, 40_000.0, 80_000.0];
    let serial = session.serve_sweep(&base, &rates, false).unwrap();
    let threaded = session.serve_sweep(&base, &rates, true).unwrap();
    assert_eq!(serve_to_json(&serial), serve_to_json(&threaded));
    assert_eq!(serve_to_csv(&serial), serve_to_csv(&threaded));
}

#[test]
fn schedule_is_memoized_across_a_long_run() {
    // Satellite acceptance: a 10k-request run schedules the workload
    // once, not 10k times — the per-request cost is a profile lookup.
    let session = Session::new();
    let driver = ServeDriver::new(&session);
    let sc = ServeConfig::new(event_cfg(), Workload::Fig1, 50_000.0).requests(10_000);
    let r = driver.run(&sc).unwrap();
    assert_eq!(r.completed + r.dropped, 10_000);
    assert_eq!(driver.schedule_runs(), 1, "one schedule per (workload, cfg)");
    assert_eq!(session.stats().points_run, 1, "one pipeline evaluation total");
    // A second run at another rate reuses the same profile.
    let mut sc2 = sc.clone();
    sc2.rate = 25_000.0;
    driver.run(&sc2).unwrap();
    assert_eq!(driver.schedule_runs(), 1);
    assert_eq!(session.stats().points_run, 1);
}

#[test]
fn rate_sweep_shows_the_hockey_stick() {
    // Acceptance: the utilization-vs-latency curve has the queueing
    // hockey stick — p99 latency near/past saturation dwarfs p99 at low
    // load, while low-load p99 stays near the bare service time.
    let session = Session::new();
    let cfg = event_cfg();
    let single = session.run(&cfg, Workload::Fig1).unwrap().cycles;
    let mu = service_rate(&session, &cfg, Workload::Fig1);
    let base = ServeConfig::new(cfg, Workload::Fig1, 1.0).requests(400).queue_depth(10_000);
    let rates: Vec<f64> = [0.3, 0.6, 0.9, 1.2].iter().map(|f| mu * f).collect();
    let reports = session.serve_sweep(&base, &rates, true).unwrap();
    let p99: Vec<u64> = reports.iter().map(|r| r.latency.p99).collect();
    assert!(
        p99[0] < 4 * single,
        "low-load p99 {} should stay within a few service times of {single}",
        p99[0]
    );
    assert!(
        p99[3] > 5 * p99[0],
        "past saturation p99 {} must dwarf low-load p99 {}",
        p99[3],
        p99[0]
    );
    // Utilization climbs toward 1 along the curve.
    assert!(reports[3].utilization > 0.98);
    assert!(reports[0].utilization < reports[3].utilization);
}

#[test]
fn batching_raises_max_sustainable_throughput() {
    // Acceptance: batching strictly increases max sustainable throughput
    // vs --batch 1 on at least one system (the event engine pipelines
    // batches at the bottleneck-resource interval), and never hurts.
    let session = Session::new();
    let mut improved = false;
    for sys in System::ALL {
        let cfg = ArchConfig::system(sys, 32 * 1024, 256).with_engine(Engine::Event);
        let mu = service_rate(&session, &cfg, Workload::Fig1);
        let mk = |batch: usize| {
            let sc = ServeConfig::new(cfg.clone(), Workload::Fig1, mu * 2.0)
                .requests(400)
                .batch(batch)
                .queue_depth(1_000);
            session.serve(&sc).unwrap()
        };
        let (r1, r8) = (mk(1), mk(8));
        assert!(
            r8.throughput_rps >= r1.throughput_rps - 1e-6,
            "{sys:?}: batching must never reduce throughput ({} < {})",
            r8.throughput_rps,
            r1.throughput_rps
        );
        if r8.throughput_rps > r1.throughput_rps * 1.05 {
            assert!(r8.mean_batch > 1.0);
            improved = true;
        }
    }
    assert!(improved, "batching must strictly help on at least one system");
}

#[test]
fn analytic_engine_serves_but_batching_degenerates() {
    // Both engines run the serving loop (acceptance); under the analytic
    // engine there is no occupancy breakdown, so a batch of b costs
    // exactly b singles and batching cannot raise throughput.
    let session = Session::new();
    let cfg = ArchConfig::system(System::Fused4, 32 * 1024, 256);
    assert_eq!(cfg.engine, Engine::Analytic);
    let sc = ServeConfig::new(cfg, Workload::Fig1, 30_000.0).requests(200).batch(8);
    let r = session.serve(&sc).unwrap();
    assert_eq!(r.service_steady, r.service_single, "analytic profile is flat");
    assert_eq!(r.completed + r.dropped, 200);
}

#[test]
fn serve_json_and_csv_goldens() {
    // Golden outputs over a handcrafted report: freezes the serialization
    // schema byte-for-byte (round-number floats keep Display stable).
    let report = ServeReport {
        label: "Fused4/G32K_L256".to_string(),
        system: "Fused4".to_string(),
        workload: "Fig1_Example".to_string(),
        engine: Engine::Event,
        arrival: ArrivalKind::Poisson,
        rate_rps: 50000.0,
        requests: 100,
        batch: 4,
        batch_timeout: 0,
        queue_depth: 64,
        seed: 42,
        deadline: 0,
        client_retries: 0,
        backoff: 0,
        completed: 100,
        dropped: 0,
        dropped_queue_full: 0,
        dropped_deadline_shed: 0,
        dropped_deadline_miss: 0,
        dropped_retry_exhausted: 0,
        batches: 25,
        mean_batch: 4.0,
        warmup_trimmed: 10,
        latency: LatencyStats {
            samples: 90,
            p50: 5000,
            p95: 7000,
            p99: 7500,
            mean: 5100.5,
            max: 8000,
        },
        throughput_rps: 49000.25,
        goodput_rps: 49000.25,
        utilization: 0.75,
        queue_mean: 1.5,
        queue_max: 9,
        service_single: 4000,
        service_steady: 1500,
        batch_shapes: 3,
        makespan_cycles: 272000,
    };
    let want_json = r#"{
  "rows": [
    {
      "config": "Fused4/G32K_L256",
      "system": "Fused4",
      "workload": "Fig1_Example",
      "engine": "event",
      "arrival": "poisson",
      "rate_rps": 50000,
      "seed": 42,
      "requests": 100,
      "batch": 4,
      "batch_timeout": 0,
      "queue_depth": 64,
      "deadline_cycles": 0,
      "client_retries": 0,
      "backoff_cycles": 0,
      "completed": 100,
      "dropped": 0,
      "dropped_queue_full": 0,
      "dropped_deadline_shed": 0,
      "dropped_deadline_miss": 0,
      "dropped_retry_exhausted": 0,
      "batches": 25,
      "mean_batch": 4,
      "warmup_trimmed": 10,
      "p50_cycles": 5000,
      "p95_cycles": 7000,
      "p99_cycles": 7500,
      "mean_cycles": 5100.5,
      "max_cycles": 8000,
      "throughput_rps": 49000.25,
      "goodput_rps": 49000.25,
      "utilization": 0.75,
      "queue_depth_mean": 1.5,
      "queue_depth_max": 9,
      "service_single_cycles": 4000,
      "service_steady_cycles": 1500,
      "batch_shapes": 3,
      "makespan_cycles": 272000
    }
  ]
}
"#;
    assert_eq!(serve_to_json(&[report.clone()]), want_json);
    let want_csv = "config,system,workload,engine,arrival,rate_rps,seed,requests,batch,\
                    batch_timeout,queue_depth,deadline_cycles,client_retries,backoff_cycles,\
                    completed,dropped,dropped_queue_full,dropped_deadline_shed,\
                    dropped_deadline_miss,dropped_retry_exhausted,batches,mean_batch,\
                    warmup_trimmed,p50_cycles,p95_cycles,p99_cycles,mean_cycles,max_cycles,\
                    throughput_rps,goodput_rps,utilization,queue_depth_mean,queue_depth_max,\
                    service_single_cycles,service_steady_cycles,batch_shapes,makespan_cycles\n\
                    Fused4/G32K_L256,Fused4,Fig1_Example,event,poisson,50000,42,100,4,0,64,\
                    0,0,0,100,0,0,0,0,0,25,4,10,5000,7000,7500,5100.5,8000,49000.25,49000.25,\
                    0.75,1.5,9,4000,1500,3,272000\n";
    assert_eq!(serve_to_csv(&[report]), want_csv);
}

#[test]
fn acceptance_cli_style_run_on_both_engines() {
    // Acceptance criterion shape: resnet18 at a fixed seed runs on both
    // engines and yields deterministic p50/p99/throughput/utilization.
    // ResNet18Small keeps the schedule fast; the CLI path is covered in
    // src/cli.rs tests.
    let session = Session::new();
    for engine in Engine::ALL {
        let cfg = ArchConfig::system(System::Fused4, 32 * 1024, 256).with_engine(engine);
        let mu = service_rate(&session, &cfg, Workload::ResNet18Small);
        let sc = ServeConfig::new(cfg, Workload::ResNet18Small, mu * 0.8)
            .requests(200)
            .seed(42);
        let a = session.serve(&sc).unwrap();
        let b = session.serve(&sc).unwrap();
        assert_eq!(a, b, "{engine:?} must be deterministic");
        assert!(a.latency.p50 > 0 && a.latency.p99 >= a.latency.p50);
        assert!(a.throughput_rps > 0.0);
        assert!(a.utilization > 0.0 && a.utilization <= 1.0);
    }
}
