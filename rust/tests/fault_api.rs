//! Fault-injection property suite (DESIGN.md §11): zero-fault configs
//! are byte-identical to fault-free ones, degradation is monotone in the
//! retired-bank count, degraded event schedules stay audit-legal with
//! replays, and fault plans are reproducible serial-vs-threaded.

use pimfused::config::{ArchConfig, Engine, System};
use pimfused::coordinator::{serve_to_json, Session, SweepGrid};
use pimfused::dataflow::{plan, CostModel};
use pimfused::fault::{FaultConfig, FaultPlan};
use pimfused::serve::{ArrivalKind, ServeConfig};
use pimfused::sim::event;
use pimfused::trace::gen::generate;
use pimfused::workload::Workload;

fn fused4(gbuf: usize, lbuf: usize) -> ArchConfig {
    ArchConfig::system(System::Fused4, gbuf, lbuf)
}

/// Acceptance gate: a `FaultConfig::default()` (all-zero) fault block is
/// *exactly* the fault-free path — same cycles, same energy, same serve
/// JSON — so every pre-existing golden stays byte-identical.
#[test]
fn zero_fault_configs_are_byte_identical_to_fault_free_ones() {
    let session = Session::new();
    for engine in [Engine::Analytic, Engine::Event] {
        let plain = fused4(8192, 128).with_engine(engine);
        let zeroed = plain.clone().with_faults(FaultConfig::default());
        let a = session.run(&plain, Workload::ResNet18First8).unwrap();
        let b = session.run(&zeroed, Workload::ResNet18First8).unwrap();
        assert_eq!(a.cycles, b.cycles, "{engine:?}: zero faults must not change cycles");
        assert_eq!(a.energy_pj, b.energy_pj, "{engine:?}: zero faults must not change energy");
        assert_eq!(a.sim.actions, b.sim.actions);
        assert_eq!(b.sim.replayed_cycles, 0);
        assert_eq!(b.sim.escalated_cmds, 0);
        assert_eq!(b.replay_overhead(), 0.0);
    }

    let sc = |cfg: ArchConfig| {
        ServeConfig::new(cfg, Workload::Fig1, 40_000.0)
            .arrival(ArrivalKind::Fixed)
            .requests(60)
            .batch(4)
    };
    let plain = session.serve(&sc(fused4(8192, 128))).unwrap();
    let zeroed =
        session.serve(&sc(fused4(8192, 128).with_faults(FaultConfig::default()))).unwrap();
    assert_eq!(
        serve_to_json(&plain),
        serve_to_json(&zeroed),
        "serve reports must serialize byte-identically under zero faults"
    );
}

/// Retiring banks takes whole PIMcores offline and the analytic engine's
/// per-core charge is bounded by the slowest core, so cycles are monotone
/// non-decreasing in the retired-bank count (nested retirement sets make
/// this a per-step guarantee, not just a trend).
#[test]
fn analytic_cycles_are_monotone_in_retired_banks() {
    let session = Session::new();
    let base = fused4(8192, 128);
    let bpc = base.banks_per_pimcore;
    let max = base.num_banks - bpc;
    let mut prev = 0u64;
    let mut counts = Vec::new();
    let mut cycles = Vec::new();
    let mut retired = 0usize;
    loop {
        let cfg = base
            .clone()
            .with_faults(FaultConfig { retired_banks: retired, ..Default::default() });
        let r = session.run(&cfg, Workload::ResNet18First8).unwrap();
        assert!(
            r.cycles >= prev,
            "retiring {retired} banks must not speed the run up ({} < {prev})",
            r.cycles
        );
        prev = r.cycles;
        counts.push(retired);
        cycles.push(r.cycles);
        if retired >= max {
            break;
        }
        retired = (retired + bpc).min(max);
    }
    assert!(counts.len() >= 3, "the sweep must exercise several degradation levels");
    assert!(
        cycles.last().unwrap() > cycles.first().unwrap(),
        "losing {}/{} cores must cost cycles somewhere: {cycles:?} at {counts:?}",
        max / bpc,
        base.num_banks / bpc
    );
}

/// The acceptance scenario: ResNet18 on a channel with retired banks, a
/// dead PIMcore, and a transient error rate completes end-to-end on both
/// engines, the engines agree on actions and replay totals, and the
/// recorded event schedule passes the full legality audit.
#[test]
fn degraded_resnet_completes_and_passes_the_schedule_audit() {
    let fc = FaultConfig {
        seed: 7,
        retired_banks: 4,
        dead_cores: 1,
        transient_ppm: 20_000, // 2% per command — replays are guaranteed
        max_retries: 3,
    };
    let base = fused4(8192, 128).with_faults(fc);
    assert_eq!(FaultPlan::build(&base).alive_core_count(), 2);

    let session = Session::new();
    for w in [Workload::ResNet18First8, Workload::ResNet18Full] {
        let a = session.run(&base.clone().with_engine(Engine::Analytic), w).unwrap();
        let e = session.run(&base.clone().with_engine(Engine::Event), w).unwrap();
        assert!(a.cycles > 0 && e.cycles > 0, "{}: degraded run must complete", w.name());
        assert_eq!(a.sim.actions, e.sim.actions, "{}: engine action agreement", w.name());
        assert_eq!(
            a.sim.replayed_cycles,
            e.sim.replayed_cycles,
            "{}: engines must draw identical replays",
            w.name()
        );
        assert_eq!(a.sim.escalated_cmds, e.sim.escalated_cmds);
        assert!(a.sim.replayed_cycles > 0, "{}: 2% ppm over ResNet must replay", w.name());
        assert!(a.replay_overhead() > 0.0 && a.replay_overhead() < 1.0);
        assert!(e.cycles <= a.cycles, "{}: event must not exceed serial", w.name());
    }

    // Scheduler-v2 legality certificate on the degraded trace, replays
    // included: every command (and every replay attempt) issues on a
    // legal slot with no resource double-booking.
    let g = Workload::ResNet18First8.graph();
    let p = plan(&g, &base);
    let tr = generate(&g, &base, &p, CostModel::default());
    let audit = event::audit(&base, &tr).expect("degraded schedule must pass the audit");
    assert_eq!(audit.starts.len(), tr.cmds.len());
}

/// Fault expansion is a pure function of (seed, geometry): equal configs
/// give `Eq` plans, different seeds give different retirement sets (at
/// levels where choice exists), and threaded sweeps match serial runs
/// byte-for-byte — including the degrade sweep re-run end to end.
#[test]
fn fault_plans_are_deterministic_serial_vs_threaded() {
    let fc = FaultConfig {
        seed: 99,
        retired_banks: 6,
        dead_cores: 1,
        transient_ppm: 5_000,
        max_retries: 2,
    };
    let cfg = fused4(8192, 128).with_faults(fc);
    assert_eq!(FaultPlan::build(&cfg), FaultPlan::build(&cfg), "equal configs, equal plans");
    let reseeded = cfg.clone().with_faults(FaultConfig { seed: 100, ..fc });
    assert_ne!(
        FaultPlan::build(&cfg),
        FaultPlan::build(&reseeded),
        "six retired banks leave room for the seed to pick differently"
    );

    // Threaded sweep vs serial session over a grid of faulted configs.
    let session = Session::new();
    let points: Vec<_> = [0usize, 4, 8]
        .iter()
        .map(|&n| {
            fused4(8192, 128)
                .with_faults(FaultConfig { retired_banks: n, transient_ppm: 2_000, ..fc })
        })
        .collect();
    let grid = SweepGrid::from_points(
        points
            .iter()
            .cloned()
            .map(|cfg| pimfused::coordinator::SweepPoint { cfg, workload: Workload::Fig1 })
            .collect::<Vec<_>>(),
    );
    let threaded = grid.run(&session).unwrap();
    threaded.ensure_ok().unwrap();
    let serial = Session::new();
    for (cfg, row) in points.iter().zip(&threaded) {
        let want = serial.run(cfg, Workload::Fig1).unwrap();
        let got = row.report.as_ref().unwrap();
        assert_eq!(got.cycles, want.cycles, "threaded/serial divergence at {}", got.label);
        assert_eq!(got.energy_pj, want.energy_pj);
        assert_eq!(got.sim.replayed_cycles, want.sim.replayed_cycles);
    }

    // The degrade sweep is equally reproducible end to end.
    let sc = ServeConfig::new(fused4(8192, 128), Workload::Fig1, 1e9)
        .arrival(ArrivalKind::Fixed)
        .requests(30)
        .queue_depth(30);
    let a = Session::new().degrade_sweep(&sc, 4).unwrap();
    let b = Session::new().degrade_sweep(&sc, 4).unwrap();
    assert_eq!(a.to_json(), b.to_json(), "degrade sweeps must be byte-reproducible");
}
