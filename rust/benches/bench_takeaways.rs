//! Regenerates the **§I / §V-D fusion-cost statistics**: fusing the first
//! 8 layers of ResNet18 into 4 spatial tiles costs +18.2% data
//! replication and +17.3% redundant computation while improving
//! performance by 91.2% (paper numbers) — plus per-grid sensitivity.

use pimfused::benchkit::{bench, section};
use pimfused::cnn::resnet::resnet18_first8;
use pimfused::coordinator::experiments::vd_stats;
use pimfused::dataflow::tiling::{fusion_cost, tile_segment};
use pimfused::dataflow::CostModel;

fn main() {
    section("§V-D fusion costs (first 8 layers, 2x2 tiles)");
    let s = vd_stats(CostModel::default()).expect("vd_stats");
    println!(
        "  data replication       : paper +18.2%   measured +{:.1}%",
        (s.fusion.replication - 1.0) * 100.0
    );
    println!(
        "  redundant computation  : paper +17.3%   measured +{:.1}%",
        (s.fusion.redundant_macs - 1.0) * 100.0
    );
    println!(
        "  performance improvement: paper  91.2%   measured  {:.1}%",
        s.perf_improvement * 100.0
    );

    section("grid sensitivity (fusion cost vs tile count)");
    let g = resnet18_first8();
    for (ty, tx) in [(1, 1), (2, 2), (4, 4), (8, 8)] {
        let tiles = tile_segment(&g, 1, 8, ty, tx);
        let c = fusion_cost(&g, 1, 8, &tiles);
        println!(
            "  {:>2}x{:<2} tiles: replication {:+.1}%  redundant MACs {:+.1}%  max tile working set {} KB",
            ty,
            tx,
            (c.replication - 1.0) * 100.0,
            (c.redundant_macs - 1.0) * 100.0,
            c.max_tile_node_elems * 2 / 1024
        );
    }

    section("timing");
    bench("halo demand propagation (first8, 4x4)", 2, 10, || {
        tile_segment(&g, 1, 8, 4, 4).len()
    });
}
