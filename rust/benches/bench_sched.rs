//! Scheduling-throughput bench: commands scheduled per second on the
//! full ResNet18 traces across the paper's three systems — the analytic
//! engine's linear walk vs the event engine's ready-heap + interval-
//! timeline scheduler (deps build included, since a caller pays both).
//!
//! The acceptance bar for the event scheduler is that its throughput
//! stays within ~3x of the analytic walk (no super-linear blowup from
//! the interval model, the per-bank host slices, or the per-row ACT
//! slots); the `ratio` column below is the number to watch.
//!
//! CI runs this as a guardrail: `cargo bench --bench bench_sched --
//! --assert-ratio 3` prints one machine-readable `guardrail:` line per
//! system (plus a degraded `Fused4-faulty` point that times the replay
//! loop, a `Fused4-openrow-off` point that times the legacy
//! every-command-reopens expansion, and a `Fused4-4ch` point that times
//! the 4-channel model-parallel scale-out — four shard schedules plus
//! the host-interconnect gather serialization) and a `guardrail-summary:` line,
//! and exits non-zero if the
//! worst event/analytic ratio exceeds the bar. `--json <path>` writes
//! the same numbers as a `pimfused-bench-v1` [`pimfused::obs::BenchRecord`]
//! snapshot; both the stdout and the JSON are uploaded as build
//! artifacts so the tracked number has history.

use pimfused::benchkit::{bench, section};
use pimfused::cnn::resnet::resnet18;
use pimfused::config::{ArchConfig, Engine, PartitionKind, System};
use pimfused::dataflow::{plan, CostModel};
use pimfused::fault::FaultConfig;
use pimfused::obs::BenchRecord;
use pimfused::sim::channel::run_channels;
use pimfused::sim::{event, simulate};
use pimfused::trace::gen::generate;
use pimfused::trace::partition::build_channels;

fn main() {
    let mut args = std::env::args().skip(1);
    let mut assert_ratio: Option<f64> = None;
    let mut json_out: Option<std::path::PathBuf> = None;
    while let Some(a) = args.next() {
        match a.as_str() {
            "--assert-ratio" => {
                let v = args.next().expect("--assert-ratio needs a value");
                assert_ratio = Some(v.parse().expect("--assert-ratio must be a number"));
            }
            "--json" => {
                json_out = Some(args.next().expect("--json needs a path").into());
            }
            // Cargo appends `--bench` to every bench executable it runs.
            "--bench" => {}
            other => panic!(
                "unknown bench_sched option {other:?} (supported: --assert-ratio N, --json PATH)"
            ),
        }
    }

    let model = CostModel::default();
    let g = resnet18();
    let mut worst: (f64, &str) = (0.0, "");
    let rec = BenchRecord::new("bench_sched", "full");

    section("scheduling throughput, ResNet18_Full @ G32K_L256");
    for sys in System::ALL {
        let cfg = ArchConfig::system(sys, 32 * 1024, 256);
        let p = plan(&g, &cfg);
        let tr = generate(&g, &cfg, &p, model);
        let n = tr.cmds.len();
        let an = bench(
            &format!("{:<8} analytic walk ({n} cmds)", sys.name()),
            3,
            200,
            || simulate(&cfg, &tr).cycles,
        );
        let ev = bench(
            &format!("{:<8} event schedule ({n} cmds)", sys.name()),
            3,
            200,
            || event::simulate(&cfg, &tr).result.cycles,
        );
        let per_sec = |d: std::time::Duration| n as f64 / d.as_secs_f64();
        let ratio = ev.median.as_secs_f64() / an.median.as_secs_f64().max(f64::MIN_POSITIVE);
        if ratio > worst.0 {
            worst = (ratio, sys.name());
        }
        println!(
            "  guardrail: system={} analytic_cmds_per_s={:.0} event_cmds_per_s={:.0} ratio={:.3}",
            sys.name(),
            per_sec(an.median),
            per_sec(ev.median),
            ratio,
        );
        rec.metrics.inc("sched.systems");
        rec.metrics.add(&format!("sched.{}.cmds", sys.name()), n as u64);
        rec.metrics.gauge(&format!("sched.{}.analytic_cmds_per_s", sys.name()), per_sec(an.median));
        rec.metrics.gauge(&format!("sched.{}.event_cmds_per_s", sys.name()), per_sec(ev.median));
        rec.metrics.gauge(&format!("sched.{}.ratio", sys.name()), ratio);
    }
    // Degraded path: the replay loop and survivor remap must not blow the
    // scheduler's throughput past the same bar. One representative point
    // (Fused4, 4 retired banks, 2% transient rate) keeps the bench cheap;
    // its ratio folds into the guardrail summary like any system's.
    section("scheduling throughput, degraded (faults banks=4,p=0.02,retries=3)");
    {
        let cfg = ArchConfig::system(System::Fused4, 32 * 1024, 256).with_faults(FaultConfig {
            seed: 7,
            retired_banks: 4,
            dead_cores: 0,
            transient_ppm: 20_000,
            max_retries: 3,
            dead_channels: 0,
        });
        let p = plan(&g, &cfg);
        let tr = generate(&g, &cfg, &p, model);
        let n = tr.cmds.len();
        let an = bench(&format!("Fused4   analytic walk, faulty ({n} cmds)"), 3, 200, || {
            simulate(&cfg, &tr).cycles
        });
        let ev = bench(&format!("Fused4   event schedule, faulty ({n} cmds)"), 3, 200, || {
            event::simulate(&cfg, &tr).result.cycles
        });
        let per_sec = |d: std::time::Duration| n as f64 / d.as_secs_f64();
        let ratio = ev.median.as_secs_f64() / an.median.as_secs_f64().max(f64::MIN_POSITIVE);
        if ratio > worst.0 {
            worst = (ratio, "Fused4-faulty");
        }
        println!(
            "  guardrail: system=Fused4-faulty analytic_cmds_per_s={:.0} event_cmds_per_s={:.0} ratio={:.3}",
            per_sec(an.median),
            per_sec(ev.median),
            ratio,
        );
        rec.metrics.add("sched.faulty.cmds", n as u64);
        rec.metrics.gauge("sched.faulty.analytic_cmds_per_s", per_sec(an.median));
        rec.metrics.gauge("sched.faulty.event_cmds_per_s", per_sec(ev.median));
        rec.metrics.gauge("sched.faulty.ratio", ratio);
    }
    // Open-row reuse off: the legacy every-command-reopens expansion
    // (and the even-split ACT metering that rides with it) must hold the
    // same bar — a regression here means the gating itself got slow.
    section("scheduling throughput, open-row reuse off");
    {
        let cfg = ArchConfig::system(System::Fused4, 32 * 1024, 256).with_open_row_reuse(false);
        let p = plan(&g, &cfg);
        let tr = generate(&g, &cfg, &p, model);
        let n = tr.cmds.len();
        let an = bench(&format!("Fused4   analytic walk, open-row off ({n} cmds)"), 3, 200, || {
            simulate(&cfg, &tr).cycles
        });
        let ev = bench(&format!("Fused4   event schedule, open-row off ({n} cmds)"), 3, 200, || {
            event::simulate(&cfg, &tr).result.cycles
        });
        let per_sec = |d: std::time::Duration| n as f64 / d.as_secs_f64();
        let ratio = ev.median.as_secs_f64() / an.median.as_secs_f64().max(f64::MIN_POSITIVE);
        if ratio > worst.0 {
            worst = (ratio, "Fused4-openrow-off");
        }
        println!(
            "  guardrail: system=Fused4-openrow-off analytic_cmds_per_s={:.0} event_cmds_per_s={:.0} ratio={:.3}",
            per_sec(an.median),
            per_sec(ev.median),
            ratio,
        );
        rec.metrics.add("sched.openrow_off.cmds", n as u64);
        rec.metrics.gauge("sched.openrow_off.analytic_cmds_per_s", per_sec(an.median));
        rec.metrics.gauge("sched.openrow_off.event_cmds_per_s", per_sec(ev.median));
        rec.metrics.gauge("sched.openrow_off.ratio", ratio);
    }
    // Multi-channel scale-out: four model-parallel shard schedules plus
    // the shared host-interconnect gather timeline. The composed run is
    // four independent schedules, so the per-command cost must stay on
    // the same bar — a regression here means the cross-channel plumbing
    // (boundary readiness, interval reservation) itself got slow.
    section("scheduling throughput, 4 channels (model partition)");
    {
        let cfg = ArchConfig::system(System::Fused4, 32 * 1024, 256)
            .with_channels(4)
            .with_partition(PartitionKind::Model);
        let cfg_ev = cfg.clone().with_engine(Engine::Event);
        let set = build_channels(&g, &cfg, model).expect("partition ResNet18 across 4 channels");
        let n: usize = set.traces.iter().map(|t| t.cmds.len()).sum();
        let an = bench(&format!("Fused4   analytic walk, 4ch ({n} cmds)"), 3, 200, || {
            run_channels(&cfg, &set).result.cycles
        });
        let ev = bench(&format!("Fused4   event schedule, 4ch ({n} cmds)"), 3, 200, || {
            run_channels(&cfg_ev, &set).result.cycles
        });
        let per_sec = |d: std::time::Duration| n as f64 / d.as_secs_f64();
        let ratio = ev.median.as_secs_f64() / an.median.as_secs_f64().max(f64::MIN_POSITIVE);
        if ratio > worst.0 {
            worst = (ratio, "Fused4-4ch");
        }
        println!(
            "  guardrail: system=Fused4-4ch analytic_cmds_per_s={:.0} event_cmds_per_s={:.0} ratio={:.3}",
            per_sec(an.median),
            per_sec(ev.median),
            ratio,
        );
        rec.metrics.add("sched.channels4.cmds", n as u64);
        rec.metrics.gauge("sched.channels4.analytic_cmds_per_s", per_sec(an.median));
        rec.metrics.gauge("sched.channels4.event_cmds_per_s", per_sec(ev.median));
        rec.metrics.gauge("sched.channels4.ratio", ratio);
        // Per-channel makespans and interconnect load, so the artifact
        // history shows load balance across shards, not just the total.
        let out = run_channels(&cfg_ev, &set);
        for (ch, &cycles) in out.report.channel_cycles.iter().enumerate() {
            rec.metrics.add(&format!("sched.channels4.ch{ch}.cycles"), cycles);
        }
        rec.metrics.add("sched.channels4.interconnect_busy", out.report.interconnect_busy);
        rec.metrics.add("sched.channels4.exchange_bytes", out.report.exchange_bytes);
        rec.metrics.gauge(
            "sched.channels4.interconnect_utilization",
            out.report.interconnect_utilization(out.result.cycles),
        );
    }

    println!(
        "guardrail-summary: worst_ratio={:.3} worst_system={} bar={}",
        worst.0,
        worst.1,
        assert_ratio.map(|b| b.to_string()).unwrap_or_else(|| "none".into()),
    );
    rec.metrics.gauge("sched.worst_ratio", worst.0);
    if let Some(bar) = assert_ratio {
        rec.metrics.gauge("sched.bar", bar);
    }
    // Write before the bar check so a failed run still leaves its numbers.
    if let Some(path) = &json_out {
        rec.write(path).expect("write --json output");
        println!("bench_sched record written to {}", path.display());
    }
    if let Some(bar) = assert_ratio {
        if worst.0 > bar {
            eprintln!(
                "bench_sched guardrail FAILED: event/analytic ratio {:.3} on {} exceeds the <= {bar}x bar",
                worst.0, worst.1
            );
            std::process::exit(1);
        }
        println!("bench_sched guardrail OK: worst ratio {:.3} <= {bar}x", worst.0);
    }
}
