//! Scheduling-throughput bench: commands scheduled per second on the
//! full ResNet18 traces across the paper's three systems — the analytic
//! engine's linear walk vs the event engine's ready-heap + interval-
//! timeline scheduler (deps build included, since a caller pays both).
//!
//! The acceptance bar for scheduler v2 is that event throughput stays
//! within ~3x of the analytic walk (no super-linear blowup from the
//! interval model); the `ratio` column below is the number to watch.

use pimfused::benchkit::{bench, section};
use pimfused::cnn::resnet::resnet18;
use pimfused::config::{ArchConfig, System};
use pimfused::dataflow::{plan, CostModel};
use pimfused::sim::{event, simulate};
use pimfused::trace::gen::generate;

fn main() {
    let model = CostModel::default();
    let g = resnet18();

    section("scheduling throughput, ResNet18_Full @ G32K_L256");
    for sys in System::ALL {
        let cfg = ArchConfig::system(sys, 32 * 1024, 256);
        let p = plan(&g, &cfg);
        let tr = generate(&g, &cfg, &p, model);
        let n = tr.cmds.len();
        let an = bench(
            &format!("{:<8} analytic walk ({n} cmds)", sys.name()),
            3,
            200,
            || simulate(&cfg, &tr).cycles,
        );
        let ev = bench(
            &format!("{:<8} event schedule ({n} cmds)", sys.name()),
            3,
            200,
            || event::simulate(&cfg, &tr).result.cycles,
        );
        let per_sec = |d: std::time::Duration| n as f64 / d.as_secs_f64();
        println!(
            "  {:<8} analytic {:>12.0} cmd/s | event {:>12.0} cmd/s | ratio {:.2}x",
            sys.name(),
            per_sec(an.median),
            per_sec(ev.median),
            ev.median.as_secs_f64() / an.median.as_secs_f64().max(f64::MIN_POSITIVE),
        );
    }
}
