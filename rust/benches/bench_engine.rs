//! Engine comparison on end-to-end ResNet18: modeled cycles (analytic
//! serial sum vs event-driven overlap) and simulator wall-clock for each
//! of the paper's three systems.
//!
//! The "saved" column is the overlap the analytic engine cannot see —
//! host I/O and GBUF gathers hidden under compute, and independent
//! residual-branch commands running concurrently. Energy is byte-
//! identical between engines by construction, so it is not re-reported.

use pimfused::benchkit::{bench, section};
use pimfused::cnn::resnet::resnet18;
use pimfused::config::{ArchConfig, System};
use pimfused::dataflow::{plan, CostModel};
use pimfused::sim::{event, simulate};
use pimfused::trace::gen::generate;
use pimfused::util::table::{pct, Table};

fn main() {
    let model = CostModel::default();
    let g = resnet18();

    section("modeled cycles, ResNet18_Full @ G32K_L256 (analytic vs event)");
    let mut t = Table::new(vec!["system", "analytic", "event", "saved", "busiest resource"]);
    for sys in System::ALL {
        let cfg = ArchConfig::system(sys, 32 * 1024, 256);
        let p = plan(&g, &cfg);
        let tr = generate(&g, &cfg, &p, model);
        let an = simulate(&cfg, &tr);
        let ev = event::simulate(&cfg, &tr);
        assert_eq!(an.actions, ev.result.actions, "engines must agree on actions");
        assert!(ev.result.cycles <= an.cycles, "event must not exceed analytic");
        let saved = 1.0 - ev.result.cycles as f64 / an.cycles as f64;
        t.row(vec![
            sys.name().to_string(),
            an.cycles.to_string(),
            ev.result.cycles.to_string(),
            pct(saved),
            ev.occupancy.busiest().to_string(),
        ]);
    }
    print!("{}", t.render());

    section("simulator wall-clock, ResNet18_Full @ G32K_L256 (Fused4 trace)");
    let cfg = ArchConfig::system(System::Fused4, 32 * 1024, 256);
    let p = plan(&g, &cfg);
    let tr = generate(&g, &cfg, &p, model);
    bench("analytic engine", 3, 200, || simulate(&cfg, &tr).cycles);
    bench("event engine (deps + schedule)", 3, 200, || {
        event::simulate(&cfg, &tr).result.cycles
    });
}
