//! Regenerates **Fig. 7**: normalized PPA with both buffers scaled
//! (ResNet18_Full), the paper's headline row, and the Takeaway-3 checks.

use pimfused::benchkit::{bench, section};
use pimfused::config::System;
use pimfused::coordinator::experiments::{fig7, fig7_in, headline, render};
use pimfused::coordinator::Session;
use pimfused::dataflow::CostModel;

fn main() {
    let model = CostModel::default();
    section("Fig. 7 — PPA vs joint LBUF+GBUF scaling (ResNet18_Full)");
    let session = Session::with_model(model);
    let rows = fig7_in(&session).expect("fig7");
    println!("{}", render(&rows));

    section("headline (§V-D)");
    let n = headline(model).expect("headline");
    println!("  Fused4 @ G32K_L256 vs AiM-like @ G2K_L0:");
    println!("    paper   : cycles=30.6% energy=83.4% area=76.5%");
    println!("    measured: {}", n.render());

    let get = |s: System, g: usize, l: usize| {
        rows.iter()
            .find(|r| r.system == s && r.gbuf == g && r.lbuf == l)
            .unwrap()
            .norm
    };
    section("Takeaway 3 checks");
    let joint = get(System::Fused4, 32 * 1024, 256).cycles;
    let g_only = get(System::Fused4, 2 * 1024, 0).cycles; // Fig. 5/6 ends
    println!(
        "  joint scaling {:.1}% beats single-buffer paths (G2K_L0 {:.1}%)",
        joint * 100.0,
        g_only * 100.0
    );
    let ideal = get(System::Fused4, 64 * 1024, 100 * 1024);
    let modest = get(System::Fused4, 64 * 1024, 256);
    println!(
        "  ideal 100K LBUF: cycles {:.1}% vs {:.1}% at 256B, but area {:.2}x vs {:.2}x (paper: 'rise dramatically')",
        ideal.cycles * 100.0,
        modest.cycles * 100.0,
        ideal.area,
        modest.area
    );

    section("timing");
    bench("fig7 full grid (18 sim points)", 1, 3, || fig7(model).unwrap().len());
}
