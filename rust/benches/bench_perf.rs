//! §Perf hot-path microbenchmarks: the numbers EXPERIMENTS.md §Perf
//! tracks across optimization iterations. Wall-clock here is *our*
//! simulator's speed (the paper's "fast evaluation" claim for its
//! profiling framework), not the modeled hardware's.
//!
//! The sweep section drives the Experiment API v2 [`SweepGrid`]; the
//! cold-vs-warm pair quantifies what [`Session`] memoization buys.

use pimfused::benchkit::{bench, section};
use pimfused::cnn::resnet::resnet18;
use pimfused::config::{ArchConfig, System};
use pimfused::coordinator::{Session, SweepGrid};
use pimfused::dataflow::{plan, CostModel};
use pimfused::sim::simulate;
use pimfused::trace::gen::generate;
use pimfused::workload::Workload;

fn main() {
    let model = CostModel::default();
    let g = resnet18();
    let cfg = ArchConfig::system(System::Fused4, 32 * 1024, 256);
    let p = plan(&g, &cfg);
    let t = generate(&g, &cfg, &p, model);
    println!("trace: {} commands for ResNet18_Full on {}", t.cmds.len(), cfg.label());

    section("pipeline stages (ResNet18_Full, Fused4/G32K_L256)");
    bench("graph build (resnet18 @224)", 3, 50, resnet18);
    bench("plan (partitioner)", 3, 200, || plan(&g, &cfg).steps.len());
    bench("trace generation", 3, 50, || generate(&g, &cfg, &p, model).cmds.len());
    bench("cycle simulation", 3, 200, || simulate(&cfg, &t).cycles);
    bench("full PPA point (cold session)", 3, 20, || {
        // A fresh session per iteration: end-to-end cost including the
        // graph build and mapping, like the old free-function pipeline.
        Session::with_model(model)
            .experiment(cfg.clone())
            .workload(Workload::ResNet18Full)
            .run()
            .unwrap()
            .cycles
    });
    let warm = Session::with_model(model);
    bench("full PPA point (warm session)", 3, 20, || {
        // Memoized graph + plan: only trace + sim + energy remain.
        warm.experiment(cfg.clone()).workload(Workload::ResNet18Full).run().unwrap().cycles
    });

    section("sweep throughput (the Fig. 7 grid)");
    let grid = SweepGrid::new()
        .systems(System::ALL)
        .bufcfgs([(2048, 0), (8192, 128), (16384, 256), (32768, 256), (65536, 256), (65536, 102400)])
        .workload(Workload::ResNet18Full);
    let session = Session::with_model(model);
    bench("fig7 grid, SweepGrid::run (18 pts)", 1, 5, || {
        grid.run(&session).unwrap().len()
    });
    let points = grid.points();
    bench("fig7 grid, serial Session (18 pts)", 1, 3, || {
        points
            .iter()
            .map(|pt| session.run(&pt.cfg, pt.workload).unwrap().cycles)
            .sum::<u64>()
    });
}
