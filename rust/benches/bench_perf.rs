//! §Perf hot-path microbenchmarks: the numbers EXPERIMENTS.md §Perf
//! tracks across optimization iterations. Wall-clock here is *our*
//! simulator's speed (the paper's "fast evaluation" claim for its
//! profiling framework), not the modeled hardware's.

use pimfused::benchkit::{bench, section};
use pimfused::cnn::resnet::resnet18;
use pimfused::config::{ArchConfig, System};
use pimfused::coordinator::{run_ppa_with, sweep, SweepPoint};
use pimfused::dataflow::{plan, CostModel};
use pimfused::sim::simulate;
use pimfused::trace::gen::generate;
use pimfused::workload::Workload;

fn main() {
    let model = CostModel::default();
    let g = resnet18();
    let cfg = ArchConfig::system(System::Fused4, 32 * 1024, 256);
    let p = plan(&g, &cfg);
    let t = generate(&g, &cfg, &p, model);
    println!("trace: {} commands for ResNet18_Full on {}", t.cmds.len(), cfg.label());

    section("pipeline stages (ResNet18_Full, Fused4/G32K_L256)");
    bench("graph build (resnet18 @224)", 3, 50, resnet18);
    bench("plan (partitioner)", 3, 200, || plan(&g, &cfg).steps.len());
    bench("trace generation", 3, 50, || generate(&g, &cfg, &p, model).cmds.len());
    bench("cycle simulation", 3, 200, || simulate(&cfg, &t).cycles);
    bench("full PPA point (end-to-end)", 3, 20, || {
        run_ppa_with(&cfg, Workload::ResNet18Full, model).unwrap().cycles
    });

    section("sweep throughput (the Fig. 7 grid)");
    let points: Vec<SweepPoint> = System::ALL
        .iter()
        .flat_map(|&s| {
            [(2048, 0), (8192, 128), (16384, 256), (32768, 256), (65536, 256), (65536, 102400)]
                .into_iter()
                .map(move |(gb, lb)| SweepPoint {
                    cfg: ArchConfig::system(s, gb, lb),
                    workload: Workload::ResNet18Full,
                })
        })
        .collect();
    bench("fig7 grid, parallel sweep (18 pts)", 1, 5, || {
        sweep(&points, model).len()
    });
    bench("fig7 grid, serial (18 pts)", 1, 3, || {
        points
            .iter()
            .map(|pt| run_ppa_with(&pt.cfg, pt.workload, model).unwrap().cycles)
            .sum::<u64>()
    });
}
