//! Ablations of PIMfused's design choices (DESIGN.md §4):
//!
//! 1. **Hybrid vs pure layer-by-layer** on the same PIMfused hardware —
//!    isolates the dataflow's contribution from the architecture's.
//! 2. **Maximum fusion depth** — why the paper stops at 8-layer kernels.
//! 3. **Tile grid granularity** — the Fused16 (4×4) vs Fused4 (2×2)
//!    replication/parallelism trade at fixed hardware.

use pimfused::benchkit::section;
use pimfused::config::{ArchConfig, Dataflow, System};
use pimfused::coordinator::Session;
use pimfused::dataflow::fused::plan_fused;
use pimfused::dataflow::tiling::{fusion_cost, tile_segment};
use pimfused::dataflow::CostModel;
use pimfused::sim::simulate;
use pimfused::trace::gen::generate;
use pimfused::workload::Workload;

fn main() {
    let m = CostModel::default();
    let session = Session::with_model(m);

    section("ablation 1 — dataflow on fixed hardware (Fused4/G32K_L256, ResNet18_Full)");
    let fused_cfg = ArchConfig::system(System::Fused4, 32 * 1024, 256);
    let mut lbl_cfg = fused_cfg.clone();
    lbl_cfg.dataflow = Dataflow::LayerByLayer;
    let fused =
        session.experiment(fused_cfg.clone()).workload(Workload::ResNet18Full).run().unwrap();
    let lbl = session.experiment(lbl_cfg).workload(Workload::ResNet18Full).run().unwrap();
    println!(
        "  PIMfused hybrid dataflow : {:>10} cycles   {:>8.3} mJ",
        fused.cycles,
        fused.energy_pj / 1e9
    );
    println!(
        "  layer-by-layer dataflow  : {:>10} cycles   {:>8.3} mJ",
        lbl.cycles,
        lbl.energy_pj / 1e9
    );
    println!(
        "  -> the dataflow alone contributes a {:.2}x cycle reduction",
        lbl.cycles as f64 / fused.cycles as f64
    );

    section("ablation 2 — maximum fusion depth (Fused4 grid, ResNet18_Full)");
    let g = Workload::ResNet18Full.graph();
    for depth in [2, 4, 8, 16] {
        let p = plan_fused(&g, 2, 2, depth);
        let t = generate(&g, &fused_cfg, &p, m);
        let r = simulate(&fused_cfg, &t);
        println!(
            "  max depth {:>2}: {} fused kernels, {:>10} cycles",
            depth,
            p.num_fused_kernels(),
            r.cycles
        );
    }

    section("ablation 3 — tile grid granularity (first8 fusion costs)");
    let g8 = Workload::ResNet18First8.graph();
    for (ty, tx, cores) in [(2, 2, "4 cores"), (4, 4, "16 cores"), (8, 8, "64 cores*")] {
        let tiles = tile_segment(&g8, 1, 8, ty, tx);
        let c = fusion_cost(&g8, 1, 8, &tiles);
        println!(
            "  {ty}x{tx} ({cores:>9}): replication {:+.1}%  redundant MACs {:+.1}%",
            (c.replication - 1.0) * 100.0,
            (c.redundant_macs - 1.0) * 100.0,
        );
    }
    println!("  (*hypothetical: more PIMcores than the 16-bank channel provides)");
}
