//! Regenerates the **Fig. 1 motivating comparison**: cross-bank transfer
//! volume for two consecutive CONV layers under the layer-by-layer vs the
//! fused-layer dataflow (4 banks / 4 PIMcores).

use pimfused::benchkit::{bench, section};
use pimfused::config::{ArchConfig, System};
use pimfused::dataflow::{plan, CostModel};
use pimfused::sim::simulate;
use pimfused::trace::gen::generate;
use pimfused::workload::Workload;

fn main() {
    section("Fig. 1 — cross-bank transfers, two fused CONVs");
    let g = Workload::Fig1.graph();
    let model = CostModel::default();

    let report = |name: &str, cfg: &ArchConfig| {
        let p = plan(&g, cfg);
        let t = generate(&g, cfg, &p, model);
        let s = t.stats();
        let r = simulate(cfg, &t);
        println!(
            "  {:<26} cross-bank {:>8} B   broadcast {:>8} B   memory cycles {:>8}",
            name,
            s.cross_bank_total(),
            s.broadcast,
            r.cycles
        );
        (s.cross_bank_total(), r.cycles)
    };

    let lbl_cfg = {
        let mut c = ArchConfig::system(System::Fused4, 2048, 128);
        c.dataflow = pimfused::config::Dataflow::LayerByLayer;
        c
    };
    let (lbl_cross, lbl_cycles) = report("layer-by-layer (Fig. 1a)", &lbl_cfg);
    let fused_cfg = ArchConfig::system(System::Fused4, 2048, 128);
    let (f_cross, f_cycles) = report("fused-layer   (Fig. 1b)", &fused_cfg);

    println!(
        "\n  fused eliminates {:.1}% of cross-bank bytes and {:.1}% of memory cycles",
        (1.0 - f_cross as f64 / lbl_cross as f64) * 100.0,
        (1.0 - f_cycles as f64 / lbl_cycles as f64) * 100.0
    );

    section("timing");
    bench("fig1 end-to-end pipeline point", 2, 20, || {
        let p = plan(&g, &fused_cfg);
        let t = generate(&g, &fused_cfg, &p, model);
        simulate(&fused_cfg, &t).cycles
    });
}
