//! Regenerates **Fig. 5**: normalized system PPA with increasing GBUF and
//! no LBUF (w.r.t. AiM-like @ G2K_L0), for ResNet18_First8Layers and
//! ResNet18_Full, and checks the paper's four observations.

use pimfused::benchkit::{bench, section};
use pimfused::config::System;
use pimfused::coordinator::experiments::{fig5, fig5_in, render};
use pimfused::coordinator::Session;
use pimfused::dataflow::CostModel;
use pimfused::workload::Workload;

fn main() {
    section("Fig. 5 — PPA vs GBUF (LBUF = 0)");
    let session = Session::new();
    let rows = fig5_in(&session).expect("fig5");
    println!("{}", render(&rows));

    let get = |s: System, gk: usize, w: Workload| {
        rows.iter()
            .find(|r| r.system == s && r.gbuf == gk * 1024 && r.workload == w)
            .unwrap()
            .norm
    };

    println!("paper anchors vs measured:");
    let f16_first8 = get(System::Fused16, 32, Workload::ResNet18First8);
    println!(
        "  Fused16 G32K first8 cycles : paper  6.5%  measured {:>6.1}%",
        f16_first8.cycles * 100.0
    );
    let f16_full = get(System::Fused16, 32, Workload::ResNet18Full);
    println!(
        "  Fused16 G32K full   cycles : paper 57.7%  measured {:>6.1}%",
        f16_full.cycles * 100.0
    );
    let aim_flat = get(System::AimLike, 64, Workload::ResNet18Full).cycles
        / get(System::AimLike, 2, Workload::ResNet18Full).cycles;
    println!(
        "  AiM-like GBUF sensitivity  : paper ~flat  measured {:.3}x (G64K/G2K)",
        aim_flat
    );
    let f4_area_lo = get(System::Fused4, 2, Workload::ResNet18Full).area;
    let f4_area_hi = get(System::Fused4, 64, Workload::ResNet18Full).area;
    println!(
        "  Fused4 area range          : paper 44.6-63.1%  measured {:.1}-{:.1}%",
        f4_area_lo * 100.0,
        f4_area_hi * 100.0
    );

    section("timing");
    bench("fig5 full grid (30 sim points)", 1, 3, || {
        fig5(CostModel::default()).unwrap().len()
    });
}
