//! Regenerates **Fig. 6**: normalized system PPA with increasing LBUF and
//! fixed GBUF = 2 KB (w.r.t. AiM-like @ G2K_L0), plus Takeaway-2 anchors.

use pimfused::benchkit::{bench, section};
use pimfused::config::System;
use pimfused::coordinator::experiments::{fig6, fig6_in, render};
use pimfused::coordinator::Session;
use pimfused::dataflow::CostModel;
use pimfused::workload::Workload;

fn main() {
    section("Fig. 6 — PPA vs LBUF (GBUF = 2K)");
    let session = Session::new();
    let rows = fig6_in(&session).expect("fig6");
    println!("{}", render(&rows));

    let get = |s: System, l: usize, w: Workload| {
        rows.iter()
            .find(|r| r.system == s && r.lbuf == l && r.workload == w)
            .unwrap()
            .norm
    };

    println!("paper anchors (64-512B LBUF) vs measured (at 512B):");
    for (sys, first8_paper, full_paper) in [
        (System::AimLike, "30.2%", "67.9%"),
        (System::Fused16, " 3.8%", "43.7%"),
        (System::Fused4, "14.2%", "1.10x"),
    ] {
        let f8 = get(sys, 512, Workload::ResNet18First8).cycles;
        let fl = get(sys, 512, Workload::ResNet18Full).cycles;
        println!(
            "  {:<9} first8 cycles: paper {first8_paper}  measured {:>6.1}%   full: paper {full_paper}  measured {:>6.1}%",
            sys.name(),
            f8 * 100.0,
            fl * 100.0
        );
    }
    // Saturation beyond 256B (Takeaway 2).
    let c256 = get(System::AimLike, 256, Workload::ResNet18First8).cycles;
    let c512 = get(System::AimLike, 512, Workload::ResNet18First8).cycles;
    let c0 = get(System::AimLike, 0, Workload::ResNet18First8).cycles;
    println!(
        "  saturation: 0->256B gains {:.1}pp, 256->512B gains {:.1}pp (paper: saturates after 256B)",
        (c0 - c256) * 100.0,
        (c256 - c512) * 100.0
    );

    section("timing");
    bench("fig6 full grid (30 sim points)", 1, 3, || {
        fig6(CostModel::default()).unwrap().len()
    });
}
