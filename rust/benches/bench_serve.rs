//! Serving-throughput bench: simulated requests per wall-clock second
//! through the serving driver (`ServeDriver::run`). The per-`(workload,
//! config)` schedule is memoized, so after the first run the steady-state
//! loop is a pure queue replay — the `schedule_runs` count printed below
//! must stay at 1 no matter how many streams replay.
//!
//! CI runs this in `--smoke` mode (one timed iteration per shape) and
//! uploads the stdout next to `bench_sched.txt`; the machine-readable
//! `serve-bench:` lines carry the tracked numbers, and `--json <path>`
//! writes the same numbers as a `pimfused-bench-v1`
//! [`pimfused::obs::BenchRecord`] snapshot.

use pimfused::benchkit::{bench, section};
use pimfused::config::{ArchConfig, Engine, System};
use pimfused::coordinator::Session;
use pimfused::obs::BenchRecord;
use pimfused::serve::{ServeConfig, ServeDriver};
use pimfused::workload::Workload;

fn main() {
    let mut args = std::env::args().skip(1);
    let mut smoke = false;
    let mut json_out: Option<std::path::PathBuf> = None;
    while let Some(a) = args.next() {
        match a.as_str() {
            "--smoke" => smoke = true,
            "--json" => {
                json_out = Some(args.next().expect("--json needs a path").into());
            }
            // Cargo appends `--bench` to every bench executable it runs.
            "--bench" => {}
            other => {
                panic!("unknown bench_serve option {other:?} (supported: --smoke, --json PATH)")
            }
        }
    }
    let (requests, warmup, iters) = if smoke { (10_000usize, 1, 3) } else { (100_000, 2, 20) };

    let session = Session::new();
    let cfg = ArchConfig::system(System::Fused4, 32 * 1024, 256).with_engine(Engine::Event);
    let workload = Workload::ResNet18Small;
    // Offer 1.2x the single-inference service rate: past the knee, so the
    // queue stays busy and batching has work to amortize.
    let single = session.run(&cfg, workload).expect("schedule workload").cycles.max(1);
    let rate = 1.2 * cfg.timing.clock_hz() / single as f64;

    section(&format!(
        "serving replay throughput, {} on {} ({requests} requests/stream)",
        cfg.label(),
        workload.name()
    ));
    let driver = ServeDriver::new(&session);
    let rec = BenchRecord::new("bench_serve", if smoke { "smoke" } else { "full" });
    rec.metrics.add("serve.requests_per_stream", requests as u64);
    for batch in [1usize, 8] {
        let sc = ServeConfig::new(cfg.clone(), workload, rate)
            .requests(requests)
            .batch(batch)
            .queue_depth(1024.max(batch));
        // Warm the schedule memo so the timed loop measures replay only.
        let r = driver.run(&sc).expect("serve run");
        let b = bench(
            &format!("batch={batch:<3} stream replay ({requests} reqs)"),
            warmup,
            iters,
            || driver.run(&sc).expect("serve run").completed,
        );
        let simulated_rps = requests as f64 / b.median.as_secs_f64().max(f64::MIN_POSITIVE);
        println!(
            "  serve-bench: batch={} requests={} simulated_req_per_s={:.0} schedule_runs={} \
             completed={} dropped={} sustained_rps={:.0} p99_cycles={}",
            batch,
            requests,
            simulated_rps,
            driver.schedule_runs(),
            r.completed,
            r.dropped,
            r.throughput_rps,
            r.latency.p99,
        );
        let key = |m: &str| format!("serve.batch{batch}.{m}");
        rec.metrics.gauge(&key("simulated_req_per_s"), simulated_rps);
        rec.metrics.gauge(&key("sustained_rps"), r.throughput_rps);
        rec.metrics.gauge(&key("p99_cycles"), r.latency.p99 as f64);
        rec.metrics.add(&key("completed"), r.completed as u64);
        rec.metrics.add(&key("dropped"), r.dropped as u64);
        assert_eq!(driver.schedule_runs(), 1, "replays must not reschedule");
    }
    driver.publish_metrics(&rec.metrics);
    if let Some(path) = &json_out {
        rec.write(path).expect("write --json output");
        println!("bench_serve record written to {}", path.display());
    }
}
