//! Serving-throughput bench: simulated requests per wall-clock second
//! through the serving driver (`ServeDriver::run`). The per-`(workload,
//! config)` schedule is memoized, so after the first run the steady-state
//! loop is a pure queue replay — the `schedule_runs` count printed below
//! must stay at 1 no matter how many streams replay.
//!
//! CI runs this in `--smoke` mode (one timed iteration per shape) and
//! uploads the stdout next to `bench_sched.txt`; the machine-readable
//! `serve-bench:` lines carry the tracked numbers.

use pimfused::benchkit::{bench, section};
use pimfused::config::{ArchConfig, Engine, System};
use pimfused::coordinator::Session;
use pimfused::serve::{ServeConfig, ServeDriver};
use pimfused::workload::Workload;

fn main() {
    let mut smoke = false;
    for a in std::env::args().skip(1) {
        match a.as_str() {
            "--smoke" => smoke = true,
            // Cargo appends `--bench` to every bench executable it runs.
            "--bench" => {}
            other => panic!("unknown bench_serve option {other:?} (supported: --smoke)"),
        }
    }
    let (requests, warmup, iters) = if smoke { (10_000usize, 1, 3) } else { (100_000, 2, 20) };

    let session = Session::new();
    let cfg = ArchConfig::system(System::Fused4, 32 * 1024, 256).with_engine(Engine::Event);
    let workload = Workload::ResNet18Small;
    // Offer 1.2x the single-inference service rate: past the knee, so the
    // queue stays busy and batching has work to amortize.
    let single = session.run(&cfg, workload).expect("schedule workload").cycles.max(1);
    let rate = 1.2 * cfg.timing.clock_hz() / single as f64;

    section(&format!(
        "serving replay throughput, {} on {} ({requests} requests/stream)",
        cfg.label(),
        workload.name()
    ));
    let driver = ServeDriver::new(&session);
    for batch in [1usize, 8] {
        let sc = ServeConfig::new(cfg.clone(), workload, rate)
            .requests(requests)
            .batch(batch)
            .queue_depth(1024.max(batch));
        // Warm the schedule memo so the timed loop measures replay only.
        let r = driver.run(&sc).expect("serve run");
        let b = bench(
            &format!("batch={batch:<3} stream replay ({requests} reqs)"),
            warmup,
            iters,
            || driver.run(&sc).expect("serve run").completed,
        );
        let simulated_rps = requests as f64 / b.median.as_secs_f64().max(f64::MIN_POSITIVE);
        println!(
            "  serve-bench: batch={} requests={} simulated_req_per_s={:.0} schedule_runs={} \
             completed={} dropped={} sustained_rps={:.0} p99_cycles={}",
            batch,
            requests,
            simulated_rps,
            driver.schedule_runs(),
            r.completed,
            r.dropped,
            r.throughput_rps,
            r.latency.p99,
        );
        assert_eq!(driver.schedule_runs(), 1, "replays must not reschedule");
    }
}
