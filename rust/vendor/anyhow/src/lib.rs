//! Offline stand-in for the [`anyhow`](https://docs.rs/anyhow) crate.
//!
//! The build image has no crates.io access, so this vendored shim provides
//! the (small) slice of `anyhow`'s API that pimfused uses, source-compatibly:
//!
//! * [`Error`] — an opaque error value built from any message or any
//!   `std::error::Error`, carrying a context chain.
//! * [`Result<T>`] — `std::result::Result<T, Error>` with a default type
//!   parameter, like `anyhow::Result`.
//! * [`Context`] — `.context(...)` / `.with_context(...)` on any `Result`
//!   whose error converts into [`Error`].
//! * [`anyhow!`] and [`bail!`] — format-style error construction.
//!
//! Differences from the real crate are deliberate simplifications: the
//! error stores its cause chain as rendered strings (no downcasting, no
//! backtraces), and `Display` prints the whole chain joined with `": "`
//! so single-line `error: {e}` reports stay informative.

use std::fmt;

/// An opaque error: a rendered message plus outer-to-inner context chain.
pub struct Error {
    /// `chain[0]` is the outermost context, `chain.last()` the root cause.
    chain: Vec<String>,
}

impl Error {
    /// Construct an error from any displayable message
    /// (`anyhow::Error::msg` equivalent).
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The outer-to-inner chain of messages.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.chain.split_first() {
            None => Ok(()),
            Some((head, rest)) => {
                write!(f, "{head}")?;
                if !rest.is_empty() {
                    write!(f, "\n\nCaused by:")?;
                    for c in rest {
                        write!(f, "\n    {c}")?;
                    }
                }
                Ok(())
            }
        }
    }
}

// NOTE: `Error` intentionally does NOT implement `std::error::Error`; that
// is what makes this blanket conversion coherent (same trick as the real
// anyhow crate).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result`: a `Result` defaulting its error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(...)` / `.with_context(...)` to results.
pub trait Context<T> {
    /// Wrap the error (if any) with an outer context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;

    /// Like [`Context::context`], evaluating the message lazily.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

/// Construct an [`Error`] from a format string (like `anyhow::anyhow!`).
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] (like `anyhow::bail!`).
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_err() -> Result<i32> {
        let n: i32 = "not-a-number".parse()?; // From<ParseIntError>
        Ok(n)
    }

    #[test]
    fn display_joins_context_chain() {
        let e = Error::msg("root").context("mid").context("outer");
        assert_eq!(e.to_string(), "outer: mid: root");
        assert_eq!(e.root_cause(), "root");
        assert_eq!(e.chain().count(), 3);
    }

    #[test]
    fn std_errors_convert_via_question_mark() {
        let e = parse_err().unwrap_err();
        assert!(e.to_string().contains("invalid digit"));
    }

    #[test]
    fn context_on_results() {
        let r: Result<()> = Err(Error::msg("boom"));
        let e = r.context("while exploding").unwrap_err();
        assert_eq!(e.to_string(), "while exploding: boom");

        let r: std::result::Result<(), std::num::ParseIntError> =
            "x".parse::<i32>().map(|_| ());
        let e = r.with_context(|| format!("parsing {}", "x")).unwrap_err();
        assert!(e.to_string().starts_with("parsing x: "));
    }

    #[test]
    fn macros_format_and_bail() {
        fn f(fail: bool) -> Result<u32> {
            if fail {
                bail!("failed with code {}", 7);
            }
            Ok(1)
        }
        assert_eq!(f(false).unwrap(), 1);
        assert_eq!(f(true).unwrap_err().to_string(), "failed with code 7");
        let e = anyhow!("x = {x}", x = 3);
        assert_eq!(e.to_string(), "x = 3");
    }

    #[test]
    fn debug_renders_caused_by() {
        let e = Error::msg("root").context("outer");
        let d = format!("{e:?}");
        assert!(d.contains("outer"));
        assert!(d.contains("Caused by:"));
        assert!(d.contains("root"));
    }
}
