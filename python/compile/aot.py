"""AOT export: lower the Layer-2/Layer-1 computations to HLO **text**
artifacts the Rust runtime loads via PJRT.

Text, not ``.serialize()``: jax ≥ 0.5 emits HloModuleProtos with 64-bit
instruction ids that the image's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the HLO text parser reassigns ids, so text
round-trips cleanly (see /opt/xla-example/README.md).

Artifacts (all f32, shapes chosen so CPU-PJRT compiles in milliseconds):

* ``tile_conv_bn_relu.hlo.txt``  — Pallas VALID 3×3 conv+ReLU on a tile:
  x(8,10,10), w(8,8,3,3) → (8,8,8).
* ``fused_block_tile.hlo.txt``   — two chained Pallas convs on a haloed
  tile (halo 2): x(8,12,12), w1, w2 → (8,8,8). The Fig. 1(b) contract.
* ``maxpool_tile.hlo.txt``       — Pallas 3×3/2 max pool: x(8,17,17).
* ``add_relu_tile.hlo.txt``      — Pallas residual ADD_RELU: (8,8,8)².
* ``resnet18_32.hlo.txt``        — full ResNet18 @32px (ref ops; weights
  as parameters in Rust node order).
* ``resnet18_first8_32.hlo.txt`` — the First8Layers workload @32px.

Usage: ``python -m compile.aot --out-dir ../artifacts [--report]``
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .kernels import pim_kernels as K
from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def artifact_entries():
    """(name, fn, example_args) for every artifact."""
    res = 32
    wspecs = [s.shape for s in model.weight_specs(res)]
    w_args = [_spec(*s) for s in wspecs]
    first8_w = w_args[:5]

    def tile_conv(x, w):
        return (K.conv2d_tile(x, w, stride=1, relu=True),)

    def fused_tile(x, w1, w2):
        return (K.fused_two_conv_tile(x, w1, w2, relu1=True, relu2=False),)

    def pool_tile(x):
        return (K.maxpool(x, 3, 2, 1),)

    def addrelu_tile(a, b):
        return (K.add_relu(a, b),)

    def rn18(x, *w):
        return (model.resnet18(x, list(w)),)

    def rn18_first8(x, *w):
        return (model.resnet18_first8(x, list(w)),)

    return [
        ("tile_conv_bn_relu", tile_conv, [_spec(8, 10, 10), _spec(8, 8, 3, 3)]),
        (
            "fused_block_tile",
            fused_tile,
            [_spec(8, 12, 12), _spec(8, 8, 3, 3), _spec(8, 8, 3, 3)],
        ),
        ("maxpool_tile", pool_tile, [_spec(8, 17, 17)]),
        ("add_relu_tile", addrelu_tile, [_spec(8, 8, 8), _spec(8, 8, 8)]),
        ("resnet18_32", rn18, [_spec(3, res, res)] + w_args),
        ("resnet18_first8_32", rn18_first8, [_spec(3, res, res)] + first8_w),
    ]


def report():
    """Analytic VMEM-footprint / MXU-occupancy estimates for the Pallas
    kernels on a real TPU (interpret=True gives no hardware timing; see
    DESIGN.md §Perf)."""
    lines = ["L1 kernel analytic report (bf16 deployment estimates)"]
    for (tile, cin, cout, k) in [(16, 64, 64, 3), (28, 64, 64, 3), (16, 128, 128, 3)]:
        ih = tile + k - 1
        vmem = (cin * ih * ih + cout * cin * k * k + cout * tile * tile) * 2
        occ = min(cout, 128) / 128.0
        lines.append(
            f"  conv{k}x{k} tile={tile} cin={cin} cout={cout}: "
            f"VMEM={vmem/1024:.1f}KB  MXU lane occupancy={occ:.0%}"
        )
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="artifact name filter")
    ap.add_argument("--report", action="store_true")
    args = ap.parse_args()

    if args.report:
        print(report())
        return

    os.makedirs(args.out_dir, exist_ok=True)
    for name, fn, specs in artifact_entries():
        if args.only and args.only != name:
            continue
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")


if __name__ == "__main__":
    main()
